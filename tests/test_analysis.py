"""The contract checker (repro.analysis): every detector demonstrated firing
on a known-bad fixture, every shipped contract passing on the real artifacts,
and the source tree lint-clean.

Structure:
  * jaxpr plane — walk/count primitives through nested pjit/scan/cond/
    shard_map bodies; the PrimitiveBudget / NoHostCallbacks /
    CollectiveBudget rules each fire on a bad program and stay silent on a
    good one;
  * sharding plane — find_sharding_leaks and the PR-8 regression: an
    artifact whose leaves are committed-REPLICATED over the mesh (the exact
    shard_map ``out_specs=P()`` escape) is caught by check_contracts;
  * ledger plane — LedgerAccounting vs a doctored wire ledger;
  * trace plane — check_contracts is trace-neutral; retrace_budget raises on
    an over-budget block;
  * source plane — each lint rule on a synthetic source, and the real tree
    clean.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.compat import make_mesh, shard_map
from repro.core import split_machines, fit, predict
from repro.core.protocols import serve_trace_count
from repro.analysis import (
    COLLECTIVE_PRIMITIVES,
    FACTORIZATION_PRIMITIVES,
    CollectiveBudget,
    ContractViolation,
    NoHostCallbacks,
    NoShardingLeak,
    check_contracts,
    collective_stats,
    contract_for,
    find_sharding_leaks,
    forbid_primitives,
    primitive_counts,
    register_contract,
    retrace_budget,
    walk_jaxpr,
)
from repro.analysis.contracts import Contract, LedgerAccounting, _CheckContext
from repro.analysis.lint import RULES, lint_paths, lint_source

P = jax.sharding.PartitionSpec


# --------------------------------------------------------------------------
# fixtures
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def art_center():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(64, 3)).astype(np.float32)
    y = rng.normal(size=64).astype(np.float32)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(0))
    return fit(parts, 16, "center", steps=1)


@pytest.fixture(scope="module")
def Xq():
    return np.random.default_rng(1).normal(size=(8, 3)).astype(np.float32)


# --------------------------------------------------------------------------
# jaxpr plane: recursive walk
# --------------------------------------------------------------------------


def test_walk_descends_into_scan_and_cond():
    def body(c, _):
        L = jnp.linalg.cholesky(c)
        return L @ L.T, None

    def prog(M, flag):
        M, _ = jax.lax.scan(body, M, None, length=2)
        return jax.lax.cond(flag, jnp.linalg.cholesky, lambda x: x, M)

    cj = jax.make_jaxpr(prog)(jnp.eye(3), True)
    counts = primitive_counts(cj, names=FACTORIZATION_PRIMITIVES)
    # one cholesky inside the scan body + one inside a cond branch
    assert counts["cholesky"] == 2


def test_walk_descends_into_pjit():
    inner = jax.jit(lambda M: jnp.linalg.cholesky(M))
    cj = jax.make_jaxpr(lambda M: inner(M) @ inner(M).T)(jnp.eye(3))
    assert primitive_counts(cj, names=("cholesky",))["cholesky"] >= 1


def test_walk_descends_into_shard_map():
    devs = jax.devices()
    mesh = make_mesh((len(devs),), ("m",))
    f = shard_map(lambda x: jax.lax.psum(x, "m"),
                  mesh=mesh, in_specs=P("m"), out_specs=P())
    cj = jax.make_jaxpr(f)(jnp.ones(len(devs)))
    stats = collective_stats(cj)
    # check_rep=True shard_map spells the reduction psum2; either counts
    (name,) = stats.keys()
    assert name in ("psum", "psum2")
    assert stats[name]["count"] == 1
    assert stats[name]["bytes"] == 4  # one f32 scalar per participant


# --------------------------------------------------------------------------
# jaxpr plane: detectors firing on known-bad programs
# --------------------------------------------------------------------------


def _ctx(fn, *args):
    return _CheckContext(jaxpr=jax.make_jaxpr(fn)(*args))


def test_primitive_budget_fires_on_unbudgeted_cholesky():
    ctx = _ctx(lambda M: jnp.linalg.cholesky(M @ M.T + jnp.eye(4)),
               jnp.ones((4, 4)))
    assert forbid_primitives("cholesky").check(ctx)
    # a triangular solve against a cached factor is NOT a factorization
    ok = _ctx(lambda L, b: jax.scipy.linalg.solve_triangular(L, b, lower=True),
              jnp.eye(4), jnp.ones(4))
    assert not forbid_primitives().check(ok)


def test_no_host_callbacks_fires_on_pure_callback():
    def bad(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) * 2.0,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    ctx = _ctx(bad, jnp.ones(3))
    findings = NoHostCallbacks().check(ctx)
    assert findings and "pure_callback" in findings[0]
    assert not NoHostCallbacks(allow=("pure_callback",)).check(ctx)


def test_collective_budget_fires_on_unaccounted_psum():
    devs = jax.devices()
    mesh = make_mesh((len(devs),), ("m",))
    bad = shard_map(lambda x: jax.lax.psum(x, "m") + jax.lax.pmax(x, "m"),
                    mesh=mesh, in_specs=P("m"), out_specs=P("m"))
    ctx = _ctx(bad, jnp.ones(len(devs)))
    # psum + pmax (+ any rewrite-inserted pbroadcast) against a budget of
    # one: the unaccounted channel fires, naming every collective
    findings = CollectiveBudget(max_count=1).check(ctx)
    assert findings and "> budget 1" in findings[0]
    n_coll = sum(v["count"] for v in collective_stats(ctx.jaxpr).values())
    assert n_coll >= 2
    # a byte ceiling catches a payload regression even under the count budget
    assert CollectiveBudget(max_count=n_coll, max_bytes=1).check(ctx)
    assert not CollectiveBudget(max_count=n_coll).check(ctx)


# --------------------------------------------------------------------------
# sharding plane: the PR-8 committed-replicated leak
# --------------------------------------------------------------------------


def _replicated_sharding():
    devs = jax.devices()
    assert len(devs) >= 2, "conftest forces 8 host devices"
    mesh = make_mesh((len(devs),), ("m",))
    return jax.sharding.NamedSharding(mesh, P())


def test_find_sharding_leaks_flags_committed_replication():
    rep = _replicated_sharding()
    tree = {"good": jnp.ones(3), "bad": jax.device_put(jnp.ones(3), rep)}
    leaks = find_sharding_leaks(tree)
    assert [p for p, _ in leaks] == ["bad"]
    assert leaks[0][1] == len(jax.devices())
    # the allow predicate admits deliberately-sharded leaves by path
    assert not find_sharding_leaks(tree, allow=lambda p: p.startswith("bad"))


def test_shard_map_identity_output_is_committed_and_detected():
    """The PR-8 mechanism itself: out_specs=P() commits the output to a
    replicated NamedSharding over the whole mesh, and the leak scan sees it."""
    devs = jax.devices()
    mesh = make_mesh((len(devs),), ("m",))
    f = shard_map(lambda x: jax.lax.psum(x, "m"),
                  mesh=mesh, in_specs=P("m"), out_specs=P())
    out = jax.jit(f)(jnp.ones(len(devs)))
    leaks = find_sharding_leaks({"out": out})
    assert leaks == [("out", len(devs))]


def test_check_contracts_catches_pr8_regression(art_center, Xq):
    """Regression for the PR-8 qps collapse: a serving artifact whose leaves
    escaped fit committed-replicated over the mesh violates its contract."""
    rep = _replicated_sharding()
    bad = jax.tree_util.tree_map(lambda x: jax.device_put(x, rep), art_center)
    with pytest.raises(ContractViolation) as exc:
        check_contracts(bad, Xq)
    assert "no-sharding-leak" in str(exc.value)
    report = check_contracts(bad, Xq, raise_on_violation=False)
    assert not report.ok and report.leaks


# --------------------------------------------------------------------------
# ledger plane
# --------------------------------------------------------------------------


def test_ledger_accounting_fires_on_doctored_wire(art_center):
    stream = dataclasses.replace(
        art_center.stream,
        wire_bits=art_center.stream.payload_bits + jnp.int64(1)
        if art_center.stream.wire_bits.dtype == jnp.int64
        else art_center.stream.payload_bits + jnp.int32(1),
    )
    bad = dataclasses.replace(art_center, stream=stream)
    findings = LedgerAccounting().check(_CheckContext(artifact=bad))
    assert findings and "payload_bits" in findings[0]
    with pytest.raises(ContractViolation):
        check_contracts(bad, phase="update")


# --------------------------------------------------------------------------
# contracts: registry, enforcement, trace plane
# --------------------------------------------------------------------------


def test_registered_contracts_pass_on_real_artifacts(Xq):
    rng = np.random.default_rng(2)
    X = rng.normal(size=(64, 3)).astype(np.float32)
    y = rng.normal(size=64).astype(np.float32)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(2))
    for proto, bits, kw in [("center", 16, {}), ("broadcast", 16, {}),
                            ("poe", 0, {"method": "rbcm"})]:
        art = fit(parts, bits, proto, steps=1, **kw)
        report = check_contracts(art, Xq)
        assert report.ok
        assert report.contract == f"{proto}-serve"
        assert sum(report.op_counts.values()) == 0
        assert not report.collectives and not report.leaks
        assert check_contracts(art, phase="update").ok


def test_contract_lookup_precedence_and_duplicates():
    c = contract_for("broadcast", "mesh", "predict")
    assert c.name == "mesh-serve"
    assert contract_for("broadcast", "batched", "predict").name == "broadcast-serve"
    with pytest.raises(KeyError):
        contract_for("nonesuch", "batched", "predict")
    with pytest.raises(ValueError):
        register_contract("center", "predict", Contract("dup", rules=()))


def test_check_contracts_is_trace_neutral(art_center, Xq):
    c0 = serve_trace_count("center")
    for _ in range(3):
        check_contracts(art_center, Xq)
    assert serve_trace_count("center") == c0


def test_retrace_budget_raises_on_violation(art_center):
    # a fresh query shape forces one serve trace — over a budget of zero
    Xodd = np.zeros((11, 3), np.float32)
    with pytest.raises(ContractViolation) as exc:
        with retrace_budget("center", serve=0):
            predict(art_center, Xodd)
    assert "serve-retraces" in str(exc.value)


# --------------------------------------------------------------------------
# source plane: every lint rule on a synthetic source, the real tree clean
# --------------------------------------------------------------------------


def _rules(src, path):
    return sorted({v.rule for v in lint_source(src, path)})


def test_lint_raw_cholesky():
    src = "import jax.numpy as jnp\ndef f(x):\n    return jnp.linalg.cholesky(x)\n"
    assert _rules(src, "src/repro/core/foo.py") == ["raw-cholesky"]
    assert _rules(src, "src/repro/core/linalg_safe.py") == []
    # host numerics are exempt: numpy/scipy carry no jitter policy
    host = "import numpy as np\ndef f(x):\n    return np.linalg.cholesky(x)\n"
    assert _rules(host, "src/repro/core/foo.py") == []


def test_lint_raw_eigh():
    src = "import jax.numpy as jnp\ndef f(x):\n    return jnp.linalg.eigh(x)\n"
    assert _rules(src, "src/repro/core/foo.py") == ["raw-eigh"]
    imp = "from jax.numpy.linalg import eigh\n"
    assert _rules(imp, "src/repro/core/foo.py") == ["raw-eigh"]


def test_lint_local_jitter():
    assert _rules("_JITTER = 1e-6\n", "src/repro/core/foo.py") == ["local-jitter"]
    assert _rules("DEFAULT_JITTER = 1e-5\n", "src/repro/core/foo.py") == ["local-jitter"]
    assert _rules("from .nystrom import _JITTER\n", "src/repro/core/foo.py") == ["local-jitter"]
    assert _rules("DEFAULT_JITTER = 1e-6\n", "src/repro/core/linalg_safe.py") == []


def test_lint_xla_env_mutation():
    src = 'import os\nos.environ["XLA_FLAGS"] = "--x"\n'
    assert _rules(src, "src/repro/launch/foo.py") == ["xla-env-mutation"]
    assert _rules(src, "src/repro/compat.py") == []
    sd = 'import os\nos.environ.setdefault("XLA_FLAGS", "--x")\n'
    assert _rules(sd, "src/repro/launch/foo.py") == ["xla-env-mutation"]


def test_lint_device_get_hot_path():
    src = "import jax\ndef f(x):\n    return jax.device_get(x)\n"
    assert _rules(src, "src/repro/kernels/foo.py") == ["device-get-hot-path"]
    assert _rules(src, "src/repro/core/protocols/foo.py") == ["device-get-hot-path"]
    # the named host-sync boundaries are sanctioned
    boundary = ("import jax\ndef ensure_capacity(x):\n"
                "    return jax.device_get(x)\n")
    assert _rules(boundary, "src/repro/core/protocols/streaming.py") == []
    # outside hot modules device_get is fine (launch scripts, tests)
    assert _rules(src, "src/repro/launch/foo.py") == []


def test_lint_registry_top_level():
    src = "def f():\n    register_kernel('k', object())\n"
    assert _rules(src, "src/repro/kernels/foo.py") == ["registry-top-level"]
    assert _rules("register_kernel('k', object())\n", "src/repro/kernels/foo.py") == []


def test_lint_trace_counter_encapsulation():
    src = "from repro.core.protocols import base\nn = base._SERVE_TRACES['c']\n"
    assert _rules(src, "src/repro/launch/foo.py") == ["trace-counter-encapsulation"]
    assert _rules(src, "src/repro/core/protocols/foo.py") == []
    assert _rules(src, "src/repro/analysis/foo.py") == []


def test_lint_rule_table_is_live():
    assert len(RULES) >= 6  # the acceptance floor: at least 6 active rules


def test_repo_tree_is_lint_clean():
    violations = lint_paths(["src"])
    assert not violations, "\n".join(str(v) for v in violations)
