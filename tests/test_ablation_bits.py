"""Algorithm-1 optimality, quantified: greedy must beat uniform and match or
beat rounded reverse-water-filling at equal total rate."""
import numpy as np
import jax.numpy as jnp

from repro.core import quantizers as Q
from repro.core.transforms import make_decorrelating_transform
from repro.core.distortion import distortion_quadratic
from benchmarks.ablation_bits import _alloc_uniform, _alloc_waterfill_rounded, _distortion


def test_greedy_beats_uniform_and_matches_waterfill():
    rng = np.random.default_rng(0)
    d, n = 16, 3000
    A = rng.normal(size=(d, d)); Qx = A @ A.T / d
    B = rng.normal(size=(d, d)); Qy = B @ B.T / d
    X = rng.multivariate_normal(np.zeros(d), Qx, size=n).astype(np.float32)
    tr = make_decorrelating_transform(Qx, Qy)
    lam = np.maximum(tr.variances, 0)
    for R in (16, 48):
        g = Q.allocate_bits_greedy(lam, R, 10)
        u = _alloc_uniform(lam, R, 10)
        w = _alloc_waterfill_rounded(lam, R, 10)
        assert g.sum() == R and u.sum() == R
        e_g = _distortion(X, tr, g, Qy)
        e_u = _distortion(X, tr, np.asarray(u), Qy)
        e_w = _distortion(X, tr, np.asarray(w), Qy)
        assert e_g <= e_u * 1.02
        assert e_g <= e_w * 1.02  # greedy is optimal among integer allocations
