"""Extra hypothesis property tests on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import quantizers as Q
from repro.core.schemes import PerSymbolScheme
from repro.core.rate_distortion import reverse_waterfill
from repro.core.fusion import kl_fuse_diag
from repro.core.poe import poe, bcm


@given(st.integers(1, 6), st.integers(0, 10000))
@settings(max_examples=25, deadline=None)
def test_quantizer_idempotent(rate, seed):
    """Quantizing an already-quantized value is the identity (codes are fixed
    points of encode∘decode)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(50, 1)).astype(np.float32)
    rates = np.array([rate], np.int32)
    sigma = jnp.asarray([1.0], jnp.float32)
    edges, cents = Q.build_codebook_tables(rate)
    c1 = Q.quantize(jnp.asarray(x), sigma, jnp.asarray(rates), edges)
    xh = Q.dequantize(c1, sigma, jnp.asarray(rates), cents)
    c2 = Q.quantize(xh, sigma, jnp.asarray(rates), edges)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_scheme_deterministic(seed):
    rng = np.random.default_rng(seed)
    d = 6
    A = rng.normal(size=(d, d)); Qx = A @ A.T / d
    B = rng.normal(size=(d, d)); Qy = B @ B.T / d
    X = rng.normal(size=(40, d)).astype(np.float32)
    s1 = PerSymbolScheme(18).fit(Qx, Qy)
    s2 = PerSymbolScheme(18).fit(Qx, Qy)
    np.testing.assert_array_equal(np.asarray(s1.encode(X)), np.asarray(s2.encode(X)))


@given(st.lists(st.floats(0.01, 10.0), min_size=2, max_size=12), st.floats(0.01, 1.0))
@settings(max_examples=30, deadline=None)
def test_waterfill_monotone_in_D(eigs, frac):
    eigs = np.asarray(eigs)
    D1 = frac * eigs.sum() * 0.5
    D2 = frac * eigs.sum()
    q1 = reverse_waterfill(eigs, D1)
    q2 = reverse_waterfill(eigs, D2)
    assert np.all(q1 <= q2 + 1e-9)  # more budget -> (weakly) more distortion per dim


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_fusion_mean_within_expert_range(seed):
    rng = np.random.default_rng(seed)
    mus = rng.normal(size=(5, 3)).astype(np.float32)
    s2s = rng.uniform(0.1, 2.0, size=(5, 3)).astype(np.float32)
    mu, s2 = kl_fuse_diag(jnp.asarray(mus), jnp.asarray(s2s))
    assert np.all(np.asarray(mu) <= mus.max(0) + 1e-6)
    assert np.all(np.asarray(mu) >= mus.min(0) - 1e-6)
    assert np.all(np.asarray(s2) > 0)


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_poe_variance_never_exceeds_best_expert(seed):
    rng = np.random.default_rng(seed)
    mus = jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)
    s2s = jnp.asarray(rng.uniform(0.1, 3.0, size=(4, 6)), jnp.float32)
    _, s2 = poe(mus, s2s)
    assert np.all(np.asarray(s2) <= np.asarray(s2s).min(0) + 1e-6)
