"""Chaos suite: the fault-injection plane (repro.faults), CRC-guarded wire
demotion, degraded-mode serving, and the numerical guardrails.

Locked invariants:
  * a :class:`~repro.faults.FaultPlan` is a frozen, hashable, mergeable value
    that round-trips through ``DGPConfig`` json metadata;
  * the CRC-16 framing detects EVERY single-bit flip and (empirically) all
    1%-rate random corruption — corrupted rows are demoted to the masked-row
    path IDENTICALLY on the batched and mesh impls, and the integrity ledger
    still charges the original (pre-demotion) row counts;
  * losing machines at fit or serve time degrades accuracy, never finiteness:
    predictions stay finite, KL-fused variance inflates (losing experts must
    never shrink uncertainty), and ``health()`` reports the loss instead of
    the caller discovering NaNs;
  * ``chol_safe`` recovers rank-deficient Grams by geometric jitter
    escalation while the well-conditioned path stays bit-identical, and the
    warm predict program still contains zero factorizations;
  * hostile inputs (NaN/Inf queries, NaN update batches, all-masked shards,
    absurd pack widths, bit-rotted checkpoints) fail loud or degrade soft —
    never propagate garbage silently.

The mesh halves run IN-PROCESS on the conftest's 8 forced host devices.
"""
import dataclasses
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import DGPConfig, DistributedGP, jax_scheme
from repro.core.linalg_safe import DEFAULT_JITTER, chol_jittered, chol_safe
from repro.core.distributed_gp import predict_op_counts
from repro.faults import (
    FaultPlan,
    apply_to_parts,
    corrupt_words,
    drop_machine,
    flip_words,
    nan_shard,
    straggler,
)


# --------------------------------------------------------------------------
# shared fixtures
# --------------------------------------------------------------------------

M, N, D = 8, 160, 4


def _data(seed=0, n=N, d=D, n_test=16):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (np.sin(X @ w) + 0.05 * rng.normal(size=n)).astype(np.float32)
    Xt = rng.normal(size=(n_test, d)).astype(np.float32)
    return X, y, Xt


def _cfg(impl="batched", protocol="broadcast", **kw):
    base = dict(protocol=protocol, impl=impl, steps=4, bits_per_sample=12)
    if protocol == "poe":
        base.update(bits_per_sample=0, gram_mode="dense", fusion="rbcm")
    base.update(kw)
    return DGPConfig(**base)


def _finite(*arrays):
    return all(np.isfinite(np.asarray(a)).all() for a in arrays)


# --------------------------------------------------------------------------
# the fault plan: a frozen, mergeable, serializable value
# --------------------------------------------------------------------------


def test_fault_plan_merge_and_roundtrip():
    plan = (drop_machine(3) | corrupt_words(0.01, seed=7)
            | nan_shard(5) | straggler(1, delay=0.2))
    assert plan.drop == (3,) and plan.nan == (5,)
    assert plan.flip_rate == pytest.approx(0.01) and plan.seed == 7
    assert plan.straggle == ((1, 0.2),)
    assert plan.active
    # frozen + hashable: usable as static jit metadata
    hash(plan)
    with pytest.raises(dataclasses.FrozenInstanceError):
        plan.flip_rate = 0.5
    # dict round-trip is exact (this is what DGPConfig persists)
    assert FaultPlan.from_dict(plan.asdict()) == plan
    assert not FaultPlan().active


def test_fault_plan_through_config_roundtrip():
    cfg = _cfg(faults=drop_machine(2) | corrupt_words(0.005))
    cfg2 = DGPConfig.from_dict(json.loads(json.dumps(cfg.asdict())))
    assert cfg2.faults == cfg.faults
    # a healthy config carries no plan at all
    assert _cfg().faults is None


def test_apply_to_parts_drop_and_nan():
    X, y, _ = _data()
    parts = [(X[i * 20:(i + 1) * 20], y[i * 20:(i + 1) * 20]) for i in range(M)]
    new, removed = apply_to_parts(parts, drop_machine(3) | nan_shard(5))
    assert new[3][0].shape[0] == 0 and removed > 0
    assert new[5][0].shape[0] < 20  # NaN-poisoned rows filtered out
    for j in (0, 1, 2, 4, 6, 7):
        np.testing.assert_array_equal(np.asarray(new[j][0]), np.asarray(parts[j][0]))


# --------------------------------------------------------------------------
# the bit-flip channel and the CRC that catches it
# --------------------------------------------------------------------------


def test_flip_words_deterministic_and_rate():
    rng = np.random.default_rng(0)
    words = jnp.asarray(rng.integers(0, 2**32, (64, 3), dtype=np.uint32))
    key = jax.random.PRNGKey(11)
    a = flip_words(words, 0.02, key)
    b = flip_words(words, 0.02, key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))  # seeded channel
    assert flip_words(words, 0.0, key) is words  # rate 0 is the identity
    flips = bin(int(np.bitwise_xor(np.asarray(a), np.asarray(words))
                    .astype(np.uint64).sum() % 1))  # noqa: F841 (popcount below)
    xor = np.bitwise_xor(np.asarray(a), np.asarray(words))
    n_flipped = int(np.unpackbits(xor.view(np.uint8)).sum())
    n_bits = words.size * 32
    assert 0.5 * 0.02 * n_bits < n_flipped < 2.0 * 0.02 * n_bits


def test_crc_detects_every_single_bit_flip():
    rng = np.random.default_rng(1)
    words = jnp.asarray(rng.integers(0, 2**32, (2,), dtype=np.uint32))[None, :]
    crc0 = int(jax_scheme.crc_words(words)[0])
    crc_jit = jax.jit(jax_scheme.crc_words)
    for w in range(2):
        for b in range(32):
            flipped = np.asarray(words).copy()
            flipped[0, w] ^= np.uint32(1) << np.uint32(b)
            assert int(crc_jit(jnp.asarray(flipped))[0]) != crc0, (w, b)


def test_crc_detection_rate_at_one_percent():
    """The acceptance bound: >= 1 - 2^-16 detection at a 1% flip rate.  With
    ~500 corrupted rows the expected number of misses is ~0.008, so a fixed
    seed should see zero — we assert the bound, not perfection."""
    rng = np.random.default_rng(2)
    n_rows, W = 600, 4
    words = jnp.asarray(rng.integers(0, 2**32, (n_rows, W), dtype=np.uint32))
    clean = jax_scheme.crc_words(words)
    rx = flip_words(words, 0.01, jax.random.PRNGKey(3))
    dirty = jax_scheme.crc_words(rx)
    corrupted = np.any(np.asarray(rx) != np.asarray(words), axis=-1)
    # P(row corrupted) = 1 - 0.99^128 ~ 0.72 at 1% over 4 words
    assert corrupted.sum() > 0.6 * n_rows
    detected = (np.asarray(dirty) != np.asarray(clean)) & corrupted
    rate = detected.sum() / corrupted.sum()
    assert rate >= 1.0 - 2.0**-16


# --------------------------------------------------------------------------
# fit-time faults: drop / NaN / corruption through every impl
# --------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", ["broadcast", "poe"])
@pytest.mark.parametrize("impl", ["batched", "mesh"])
def test_drop_machine_fit_survives(protocol, impl):
    X, y, Xt = _data()
    est = DistributedGP(_cfg(impl, protocol, faults=drop_machine(3)))
    art = est.fit(X, y, M)
    assert art.lengths[3] == 0
    mu, var = est.predict(art, Xt)
    assert _finite(mu, var) and np.all(np.asarray(var) > 0)
    h = est.health(art)
    assert h.status == "degraded" and h.machines_lost == (3,)
    if protocol == "broadcast":  # kl fusion inflates by m / m_alive
        assert h.variance_inflation == pytest.approx(M / (M - 1))


def test_drop_guards_fail_loud():
    X, y, _ = _data()
    with pytest.raises(ValueError, match="machine 0"):
        DistributedGP(_cfg(faults=drop_machine(0))).fit(X, y, M)
    with pytest.raises(ValueError, match="center"):
        DistributedGP(
            _cfg(protocol="center", faults=drop_machine(0))
        ).fit(X, y, M)
    with pytest.raises(ValueError, match="every row"):
        DistributedGP(
            _cfg(faults=FaultPlan(drop=tuple(range(M))))
        ).fit(X, y, M)


def test_nan_shard_fit_filters_rows():
    X, y, Xt = _data()
    est = DistributedGP(_cfg(protocol="center", faults=nan_shard(2)))
    art = est.fit(X, y, M)
    assert 0 < art.lengths[2] < N // M  # poisoned rows filtered, shard kept
    mu, var = est.predict(art, Xt)
    assert _finite(mu, var)


def test_corruption_demotes_identically_batched_vs_mesh():
    """The CRC demotion contract: the same seeded channel corrupts the same
    packed words on both impls, so the surviving row sets — and therefore the
    fitted artifacts — are identical by construction."""
    X, y, Xt = _data()
    arts = {}
    for impl in ("batched", "mesh"):
        est = DistributedGP(_cfg(impl, faults=corrupt_words(0.01, seed=3)))
        arts[impl] = est.fit(X, y, M)
    ab, am = arts["batched"], arts["mesh"]
    assert ab.rows_demoted == am.rows_demoted > 0
    assert ab.lengths == am.lengths
    # integrity is charged on what was TRANSMITTED (original rows), so the
    # ledger matches the clean fit even though rows were demoted on receive
    clean = DistributedGP(_cfg()).fit(X, y, M)
    assert ab.integrity_bits == am.integrity_bits == clean.integrity_bits
    mu_b, s2_b = DistributedGP(_cfg()).predict(ab, Xt)
    mu_m, s2_m = DistributedGP(_cfg("mesh")).predict(am, Xt)
    assert _finite(mu_b, s2_b, mu_m, s2_m)
    np.testing.assert_allclose(np.asarray(mu_m), np.asarray(mu_b), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2_m), np.asarray(s2_b), atol=1e-4)


def test_corruption_health_reports_demotion():
    X, y, _ = _data()
    est = DistributedGP(_cfg(faults=corrupt_words(0.02, seed=5)))
    art = est.fit(X, y, M)
    h = est.health(art)
    assert h.rows_demoted == art.rows_demoted > 0
    assert h.status == "degraded"


# --------------------------------------------------------------------------
# serve-time degradation: availability masks through fusion
# --------------------------------------------------------------------------


def test_degraded_predict_batched_matches_mesh():
    X, y, Xt = _data(seed=4)
    ab = DistributedGP(_cfg()).fit(X, y, M)
    am = DistributedGP(_cfg("mesh")).fit(X, y, M)
    av = np.ones(M, np.float32)
    av[[2, 6]] = 0.0
    mu_b, s2_b = DistributedGP(_cfg()).predict(ab, Xt, available=av)
    mu_m, s2_m = DistributedGP(_cfg("mesh")).predict(am, Xt, available=av)
    assert _finite(mu_b, s2_b, mu_m, s2_m)
    np.testing.assert_allclose(np.asarray(mu_m), np.asarray(mu_b), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2_m), np.asarray(s2_b), atol=1e-4)


def test_kl_variance_never_shrinks_under_loss():
    X, y, Xt = _data(seed=5)
    est = DistributedGP(_cfg())
    art = est.fit(X, y, M)
    _, s2_full = est.predict(art, Xt)
    for lost in ([7], [1, 4], [1, 3, 5, 7]):
        av = np.ones(M, np.float32)
        av[lost] = 0.0
        mu, s2 = est.predict(art, Xt, available=av)
        assert _finite(mu, s2)
        assert np.all(np.asarray(s2) >= np.asarray(s2_full) - 1e-6), lost
        h = est.health(art, av)
        assert h.machines_lost == tuple(lost)
        assert h.variance_inflation == pytest.approx(M / (M - len(lost)))


@pytest.mark.parametrize("fusion", ["poe", "gpoe", "bcm", "rbcm"])
def test_poe_family_degraded_serving(fusion):
    X, y, Xt = _data(seed=6)
    est = DistributedGP(_cfg(protocol="poe", fusion=fusion))
    art = est.fit(X, y, M)
    av = np.ones(M, np.float32)
    av[0] = 0.0
    mu, s2 = est.predict(art, Xt, available=av)
    assert _finite(mu, s2) and np.all(np.asarray(s2) > 0)
    # all-alive mask serves (numerically) the healthy program
    mu1, s21 = est.predict(art, Xt, available=np.ones(M, np.float32))
    mu0, s20 = est.predict(art, Xt)
    np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s21), np.asarray(s20), atol=1e-4)


def test_center_ignores_availability():
    """The §5.1 center holds every decoded shard locally — machine loss after
    fit cannot change its predictive (the mask is surface parity only)."""
    X, y, Xt = _data(seed=7)
    est = DistributedGP(_cfg(protocol="center"))
    art = est.fit(X, y, M)
    av = np.ones(M, np.float32)
    av[4] = 0.0
    mu0, s20 = est.predict(art, Xt)
    mu1, s21 = est.predict(art, Xt, available=av)
    np.testing.assert_array_equal(np.asarray(mu1), np.asarray(mu0))
    np.testing.assert_array_equal(np.asarray(s21), np.asarray(s20))


def test_availability_mask_validated():
    X, y, Xt = _data(seed=8)
    est = DistributedGP(_cfg())
    art = est.fit(X, y, M)
    with pytest.raises(ValueError, match="available"):
        est.predict(art, Xt, available=np.ones(M - 1, np.float32))
    with pytest.raises(TypeError, match="health"):
        est.health(object())


# --------------------------------------------------------------------------
# numerical guardrails: chol_safe + hostile inputs
# --------------------------------------------------------------------------


def test_chol_safe_bit_identical_when_well_conditioned():
    rng = np.random.default_rng(9)
    A = rng.normal(size=(12, 12))
    Mx = jnp.asarray(A @ A.T + 12 * np.eye(12), jnp.float32)
    L_ref = jnp.linalg.cholesky(Mx + DEFAULT_JITTER * jnp.eye(12, dtype=jnp.float32))
    L = chol_safe(Mx, DEFAULT_JITTER)
    np.testing.assert_array_equal(np.asarray(L), np.asarray(L_ref))
    np.testing.assert_array_equal(
        np.asarray(chol_jittered(Mx, DEFAULT_JITTER)), np.asarray(L_ref)
    )


def test_chol_safe_recovers_rank_deficient():
    rng = np.random.default_rng(10)
    U = rng.normal(size=(16, 3)).astype(np.float32)
    Mx = jnp.asarray(U @ U.T)  # rank 3 of 16: plain cholesky returns NaN
    assert not np.isfinite(np.asarray(jnp.linalg.cholesky(Mx))).all()
    L = chol_safe(Mx)
    assert np.isfinite(np.asarray(L)).all()
    err = np.abs(np.asarray(L @ L.T) - np.asarray(Mx)).max()
    assert err < 1e-2  # reconstruction within the escalated jitter


def test_chol_safe_vmap_mixed_batch():
    """Per-element escalation: a healthy batch element keeps its original
    factor bit-identically even while a rank-deficient sibling escalates."""
    rng = np.random.default_rng(11)
    A = rng.normal(size=(8, 8))
    good = (A @ A.T + 8 * np.eye(8)).astype(np.float32)
    U = rng.normal(size=(8, 2)).astype(np.float32)
    bad = U @ U.T
    batch = jnp.stack([jnp.asarray(good), jnp.asarray(bad)])
    L = jax.vmap(lambda m: chol_safe(m, DEFAULT_JITTER))(batch)
    assert np.isfinite(np.asarray(L)).all()
    L_good = chol_safe(jnp.asarray(good), DEFAULT_JITTER)
    np.testing.assert_array_equal(np.asarray(L[0]), np.asarray(L_good))


def test_warm_predict_has_zero_factorizations():
    """chol_safe lives at fit time only: the warm serve program still contains
    zero cholesky/eigh equations — jitter escalation costs nothing per query."""
    X, y, Xt = _data(seed=12)
    art = DistributedGP(_cfg()).fit(X, y, M)
    assert predict_op_counts(art, Xt) == {"cholesky": 0, "eigh": 0}


def test_hostile_query_rows_degrade_to_prior():
    X, y, Xt = _data(seed=13)
    est = DistributedGP(_cfg())
    art = est.fit(X, y, M)
    Xbad = Xt.copy()
    Xbad[3] = np.nan
    Xbad[7] = np.inf
    mu, var = est.predict(art, Xbad)
    assert _finite(mu, var)
    mu0, var0 = est.predict(art, Xt)
    # healthy rows unaffected; poisoned rows report zero mean + prior variance
    keep = np.ones(len(Xt), bool)
    keep[[3, 7]] = False
    np.testing.assert_allclose(np.asarray(mu)[keep], np.asarray(mu0)[keep],
                               atol=1e-6)
    assert np.asarray(mu)[3] == 0.0 and np.asarray(mu)[7] == 0.0
    assert np.asarray(var)[3] > np.median(np.asarray(var0))  # prior, not 0


def test_hostile_update_batch_filters_and_warns():
    X, y, Xt = _data(seed=14)
    est = DistributedGP(_cfg())
    art = est.fit(X, y, M)
    Xn = np.random.default_rng(0).normal(size=(6, D)).astype(np.float32)
    yn = np.zeros(6, np.float32)
    Xn[2] = np.nan
    yn[4] = np.inf
    with pytest.warns(UserWarning, match="non-finite"):
        art2 = est.update(art, Xn, yn, machine=1)
    assert art2.lengths[1] == art.lengths[1] + 4  # 2 poisoned rows dropped
    mu, var = est.predict(art2, Xt)
    assert _finite(mu, var)


def test_pack_codes_width_overflow_fails_loud():
    with pytest.raises(ValueError, match="overflow"):
        jax_scheme.pack_codes(
            jnp.zeros((1, 2**27), jnp.uint32), 32
        )


def test_all_masked_shard_transmits_nothing():
    """An all-masked (zero-row) shard in q_all_gather: finite outputs, zero
    words, zero charge on all three ledgers for that machine."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.comm import q_all_gather
    from repro.comm.accounting import side_info_bits, CRC_BITS
    from repro.compat import shard_map

    m, n_loc, d, bits = 4, 10, 5, 15
    rng = np.random.default_rng(15)
    X = rng.normal(size=(m * n_loc, d)).astype(np.float32)
    mask = np.ones((m, n_loc), np.float32)
    mask[2, :] = 0.0  # machine 2 has nothing to say
    mesh = Mesh(np.asarray(jax.devices()[:m]), ("m",))
    fn = shard_map(
        lambda x, mk: q_all_gather(x, "m", bits, mask=mk[0], return_state=True)[1],
        mesh=mesh, in_specs=(P("m", None), P("m", None)), out_specs=P(),
        check_vma=False,
    )
    st = jax.jit(fn)(X, mask)
    assert np.isfinite(np.asarray(st["decoded"])).all()
    assert np.all(np.asarray(st["codes"])[2] == 0)
    rates = np.asarray(st["rates"])
    n_valid = mask.sum(axis=1).astype(int)
    live = [j for j in range(m) if n_valid[j] > 0]
    assert int(st["wire_bits"]) == sum(
        int(rates[j].sum()) * int(n_valid[j]) + side_info_bits(d) for j in live
    )
    assert int(st["integrity_bits"]) == CRC_BITS * int(n_valid[live].sum())


def test_q_all_gather_flip_fault_demotes_peers_not_self():
    """Collective-level corruption: flipped peer rows fail their CRC and are
    demoted in the gathered mask, while each machine's own block stays valid
    (it never crossed the wire)."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.comm import q_all_gather
    from repro.compat import shard_map

    m, n_loc, d, bits = 4, 12, 5, 15
    rng = np.random.default_rng(16)
    X = rng.normal(size=(m * n_loc, d)).astype(np.float32)
    mesh = Mesh(np.asarray(jax.devices()[:m]), ("m",))
    plan = corrupt_words(0.05, seed=9)
    fn = shard_map(
        lambda x: q_all_gather(x, "m", bits, return_state=True,
                               faults=plan)[1],
        mesh=mesh, in_specs=P("m", None), out_specs=P(), check_vma=False,
    )
    st = jax.jit(fn)(X)
    gmask = np.asarray(st["mask"])  # (m, n_loc) as seen by machine 0
    assert np.isfinite(np.asarray(st["decoded"])).all()
    assert np.all(gmask[0] == 1.0)  # own rows exempt from channel noise
    assert gmask[1:].sum() < (m - 1) * n_loc  # some peer rows demoted


def test_vq_scheme_rejects_flip_faults():
    X, y, _ = _data(seed=17)
    cfg = _cfg(scheme="vq", bits_per_sample=8, faults=corrupt_words(0.01))
    with pytest.raises(NotImplementedError, match="vq"):
        DistributedGP(cfg).fit(X, y, M)


# --------------------------------------------------------------------------
# checkpoint integrity (format v4)
# --------------------------------------------------------------------------


def _corrupt_npz_array(directory, key):
    path = os.path.join(directory, "ckpt_00000000.npz")
    arrays = dict(np.load(path))
    arr = arrays[key]
    flat = arr.reshape(-1).copy()
    flat[0] = flat[0] + 1 if np.issubdtype(arr.dtype, np.integer) else flat[0] + 0.5
    arrays[key] = flat.reshape(arr.shape)
    np.savez(path, **arrays)


def test_checkpoint_checksum_catches_bitrot(tmp_path):
    X, y, Xt = _data(seed=18)
    est = DistributedGP(_cfg())
    art = est.fit(X, y, M)
    d = str(tmp_path)
    est.save(art, d)
    meta = json.load(open(os.path.join(d, "meta_00000000.json")))
    assert meta["format_version"] >= 4 and meta["array_checksums"]
    # clean round trip first
    art2 = DistributedGP.load(d)
    mu, s2 = est.predict(art2, Xt)
    assert _finite(mu, s2)
    # now rot one array: load must name the bad array, not serve garbage
    bad_key = sorted(meta["array_checksums"])[0]
    _corrupt_npz_array(d, bad_key)
    from repro.checkpoint import CorruptCheckpointError

    with pytest.raises(CorruptCheckpointError, match=bad_key.split("/")[0]):
        DistributedGP.load(d)


def test_checkpoint_missing_array_named(tmp_path):
    X, y, _ = _data(seed=19)
    est = DistributedGP(_cfg())
    est.save(est.fit(X, y, M), str(tmp_path))
    path = os.path.join(str(tmp_path), "ckpt_00000000.npz")
    arrays = dict(np.load(path))
    victim = sorted(arrays)[-1]
    del arrays[victim]
    np.savez(path, **arrays)
    from repro.checkpoint import CorruptCheckpointError

    with pytest.raises(CorruptCheckpointError, match="missing array"):
        DistributedGP.load(str(tmp_path))


def test_legacy_checkpoint_without_checksums_loads(tmp_path):
    """v1-v3 artifacts carry no checksum table: they load unverified (and
    un-rotted v4 data with the table stripped behaves exactly like v3)."""
    X, y, Xt = _data(seed=20)
    est = DistributedGP(_cfg())
    art = est.fit(X, y, M)
    d = str(tmp_path)
    est.save(art, d)
    mp = os.path.join(d, "meta_00000000.json")
    meta = json.load(open(mp))
    del meta["array_checksums"]
    meta["format_version"] = 3
    json.dump(meta, open(mp, "w"))
    art2 = DistributedGP.load(d)
    mu, s2 = est.predict(art2, Xt)
    mu0, s20 = est.predict(art, Xt)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu0), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s20), atol=1e-5)


# --------------------------------------------------------------------------
# faults x streaming: updates against a degraded fleet, corrupted batches
# --------------------------------------------------------------------------


def test_update_to_dropped_machine_is_refused():
    """A machine that transmitted nothing at fit time has no frozen codebooks
    to stream under: update() targeting it fails loud, and routing the batch
    to a survivor works."""
    X, y, Xt = _data(seed=20)
    est = DistributedGP(_cfg(faults=drop_machine(3)))
    art = est.fit(X, y, M)
    assert art.lengths[3] == 0
    rng = np.random.default_rng(20)
    Xn = rng.normal(size=(5, D)).astype(np.float32)
    yn = np.zeros(5, np.float32)
    with pytest.raises(ValueError, match="no rows at fit time"):
        est.update(art, Xn, yn, machine=3)
    art2 = est.update(art, Xn, yn, machine=1)  # survivors still stream
    assert art2.lengths[1] == art.lengths[1] + 5
    assert art2.lengths[3] == 0
    mu, var = est.predict(art2, Xt)
    assert _finite(mu, var) and np.all(np.asarray(var) > 0)


def test_corrupt_update_batch_demotes_only_new_rows():
    """Under a flip-rate plan a streamed batch crosses the physical wire:
    CRC-failing NEW rows are demoted (fit-time rows are untouchable), the
    FULL transmission is still charged to all three ledgers, and the
    artifact keeps serving."""
    from repro.comm.accounting import CRC_BITS

    X, y, Xt = _data(seed=21)
    est = DistributedGP(_cfg(faults=corrupt_words(0.05, seed=7)))
    art = est.fit(X, y, M)
    n_new = 40
    rng = np.random.default_rng(21)
    Xn = rng.normal(size=(n_new, D)).astype(np.float32)
    yn = np.zeros(n_new, np.float32)
    art2 = est.update(art, Xn, yn, machine=1)
    demoted_new = art2.rows_demoted - art.rows_demoted
    survived = art2.lengths[1] - art.lengths[1]
    # every transmitted row is accounted for: kept or demoted, nothing lost
    assert survived + demoted_new == n_new
    assert demoted_new > 0  # 5%/bit over 32-bit words: corruption is certain
    assert survived > 0
    # only machine 1's count moved
    for j in range(M):
        if j != 1:
            assert art2.lengths[j] == art.lengths[j]
    # the ledgers charge what was TRANSMITTED, not what survived
    rate1 = int(np.asarray(art.wire.rates[1]).sum())
    W = art.wire.codes.shape[-1]
    assert art2.wire_bits == art.wire_bits + n_new * rate1
    assert art2.payload_bits == art.payload_bits + n_new * 32 * W
    assert art2.integrity_bits == art.integrity_bits + n_new * CRC_BITS
    h = est.health(art2)
    assert h.status == "degraded" and h.rows_demoted == art2.rows_demoted
    mu, var = est.predict(art2, Xt)
    assert _finite(mu, var) and np.all(np.asarray(var) > 0)


def test_degraded_mask_predict_correct_after_updates():
    """Availability-masked serving stays correct on a streamed (bucketed)
    artifact: the KL-fused variance still never shrinks under machine loss,
    and batched == mesh on identically streamed artifacts."""
    X, y, Xt = _data(seed=22)
    ab = DistributedGP(_cfg()).fit(X, y, M)
    am = DistributedGP(_cfg("mesh")).fit(X, y, M)
    rng = np.random.default_rng(22)
    for j, n_new in [(1, 6), (4, 9)]:
        Xn = rng.normal(size=(n_new, D)).astype(np.float32)
        yn = np.zeros(n_new, np.float32)
        ab = DistributedGP(_cfg()).update(ab, Xn, yn, machine=j)
        am = DistributedGP(_cfg("mesh")).update(am, Xn, yn, machine=j)
    av = np.ones(M, np.float32)
    av[[2, 6]] = 0.0
    mu_b, s2_b = DistributedGP(_cfg()).predict(ab, Xt, available=av)
    mu_m, s2_m = DistributedGP(_cfg("mesh")).predict(am, Xt, available=av)
    assert _finite(mu_b, s2_b, mu_m, s2_m)
    np.testing.assert_allclose(np.asarray(mu_m), np.asarray(mu_b), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2_m), np.asarray(s2_b), atol=1e-4)
    _, s2_full = DistributedGP(_cfg()).predict(ab, Xt)
    assert np.all(np.asarray(s2_b) >= np.asarray(s2_full) - 1e-6)
