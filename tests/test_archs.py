"""Per-architecture smoke tests: REDUCED variant of each assigned config runs
one forward + one train step + one decode step on CPU with finite outputs of
the right shape."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (
    init_model, forward, init_decode_state, decode_step, make_train_step,
)
from repro.models.steps import init_train_state

ARCHS = list_archs()


def _batch(cfg, B=2, S=32):
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["enc_embed"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embed"] = jnp.ones((B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    return batch


def test_all_ten_archs_assigned():
    assert len(ARCHS) == 10
    assert set(ARCHS) == {
        "gemma-7b", "whisper-medium", "internvl2-2b", "mistral-large-123b",
        "arctic-480b", "stablelm-12b", "gemma2-2b", "xlstm-125m",
        "qwen2-moe-a2.7b", "zamba2-2.7b",
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_values(arch):
    cfg = get_config(arch)
    assert cfg.source, "every config must cite its source"
    assert cfg.vocab_size > 0 and cfg.num_layers > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b))(params, _batch(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), "NaN/Inf in logits"
    if cfg.family == "moe":
        assert "load_balance" in aux


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step_decreases_loss(arch):
    cfg = get_config(arch).reduced()
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg))
    batch = _batch(cfg)
    losses = []
    for _ in range(6):  # a couple of Adam steps of slack before asserting
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert min(losses[1:]) < losses[0], f"{arch}: loss did not decrease {losses}"


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    B = 2
    state = init_decode_state(cfg, B, 64)
    step = jax.jit(lambda p, s, t, pos: decode_step(p, cfg, s, t, pos))
    tok = jnp.ones((B, 1), jnp.int32)
    for pos in range(3):
        logits, state = step(params, state, tok, jnp.int32(pos))
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None].astype(jnp.int32)


def test_microbatched_train_step_matches_unbatched():
    cfg = get_config("xlstm-125m").reduced()
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=4, S=32)
    s1 = jax.jit(make_train_step(cfg))
    s2 = jax.jit(make_train_step(cfg, microbatches=2))
    p1, o1, m1 = s1(params, opt, batch)
    p2, o2, m2 = s2(params, opt, batch)
    # same gradients (up to accumulation order) -> nearly identical params
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
    )
    assert d < 5e-3, f"microbatched step diverged from reference: {d}"
