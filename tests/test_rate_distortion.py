"""Theorem 1 / Theorem 2 tests."""
import numpy as np
import jax
import pytest

from repro.core import rate_distortion as rd
from repro.core.distortion import distortion_quadratic
from repro.core.schemes import PerSymbolScheme


def _cov(rng, d):
    A = rng.normal(size=(d, d))
    return A @ A.T / d


def test_waterfill_sums_to_D():
    rng = np.random.default_rng(0)
    eigs = rng.uniform(0.1, 4.0, size=10)
    for D in [0.1, 1.0, eigs.sum() * 0.5]:
        q = rd.reverse_waterfill(eigs, D)
        assert np.all(q <= eigs + 1e-12)
        assert q.sum() == pytest.approx(D, rel=1e-4)


def test_waterfill_saturates_at_total():
    eigs = np.array([1.0, 2.0])
    q = rd.reverse_waterfill(eigs, 10.0)
    np.testing.assert_allclose(q, eigs)


def test_rd_curve_monotone_decreasing():
    rng = np.random.default_rng(1)
    Qx, Qy = _cov(rng, 8), _cov(rng, 8)
    rates, dists = rd.rd_lower_bound_curve(Qx, Qy)
    assert np.all(np.diff(rates) >= -1e-9)
    assert np.all(np.diff(dists) <= 1e-9)
    # zero rate -> full distortion = sum of eigenvalues = tr(QxQy)
    assert dists[0] == pytest.approx(np.trace(Qx @ Qy), rel=1e-6)


def test_test_channel_achieves_target_distortion():
    rng = np.random.default_rng(2)
    d = 10
    Qx, Qy = _cov(rng, d), _cov(rng, d)
    D_target = 0.25 * np.trace(Qx @ Qy)
    ch = rd.make_test_channel(Qx, Qy, D_target)
    assert ch.distortion == pytest.approx(D_target, rel=1e-3)
    X = rng.multivariate_normal(np.zeros(d), Qx, size=4000).astype(np.float32)
    Xh = rd.sample_test_channel(ch, X, jax.random.PRNGKey(0))
    emp = float(distortion_quadratic(X, Xh, Qy))
    assert emp == pytest.approx(D_target, rel=0.08)


def test_per_symbol_respects_lower_bound():
    """No practical scheme may beat the Theorem-1 bound (paper Fig. 2)."""
    rng = np.random.default_rng(3)
    d = 10
    Qx, Qy = _cov(rng, d), _cov(rng, d)
    X = rng.multivariate_normal(np.zeros(d), Qx, size=3000).astype(np.float32)
    for R in [5, 15, 30]:
        ps = PerSymbolScheme(R).fit(Qx, Qy)
        emp = float(distortion_quadratic(X, ps.roundtrip(X), Qy))
        lb = rd.distortion_for_rate(Qx, Qy, R)
        assert emp >= 0.95 * lb  # small slack for sampling noise
        # and within a constant factor of optimal (the paper's 'near optimal')
        assert emp <= 16.0 * lb + 1e-3
