"""Property tests for the packed code plane (jax_scheme.pack_codes/unpack_codes).

The packed representation is what the collectives move, the qgram kernels
consume, and checkpoints store — so its roundtrip identity is load-bearing
for the whole wire.  Hypothesis sweeps: uniform widths over the full 1..32
range, per-dimension variable widths whose rows straddle word boundaries,
ragged masks, -1 sentinels, odd lengths, and dtype stability under vmap/jit.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import jax_scheme as js


@given(
    bits=st.integers(1, 31),
    n=st.integers(1, 65),
    d=st.integers(1, 9),
    seed=st.integers(0, 2**20),
)
@settings(max_examples=40, deadline=None)
def test_uniform_roundtrip_identity(bits, n, d, seed):
    """pack∘unpack is the identity for every uniform width 1..31 and any
    (possibly odd, word-straddling) row length."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(
        rng.integers(0, 1 << bits, size=(n, d)).astype(np.int64).astype(np.int32)
    )
    words = js.pack_codes(codes, bits)
    assert words.dtype == jnp.uint32
    assert words.shape == (n, js.row_words(d * bits))
    back = js.unpack_codes(words, bits, num=d)
    assert back.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


@given(n=st.integers(1, 33), d=st.integers(1, 5), seed=st.integers(0, 2**20))
@settings(max_examples=15, deadline=None)
def test_full_width_32_roundtrip(n, d, seed):
    """bits=32: whole uint32 values pass through untouched (one word per
    code, no sentinel interpretation on the unsigned dtype)."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 1 << 32, size=(n, d), dtype=np.uint32))
    words = js.pack_codes(codes, 32)
    assert words.shape == (n, d)
    back = js.unpack_codes(words, 32, num=d, dtype=jnp.uint32)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


@given(
    widths=st.lists(st.integers(0, 13), min_size=1, max_size=12),
    n=st.integers(1, 40),
    slack=st.integers(0, 9),
    seed=st.integers(0, 2**20),
)
@settings(max_examples=40, deadline=None)
def test_variable_width_roundtrip(widths, n, slack, seed):
    """Per-dimension widths (the scheme's rates, zeros included) roundtrip
    exactly, including rows that straddle uint32 boundaries and layouts whose
    static total_bits bound exceeds the actual widths sum."""
    rng = np.random.default_rng(seed)
    w = np.asarray(widths, np.int32)
    total = int(w.sum()) + slack
    codes = jnp.asarray(np.stack(
        [rng.integers(0, 1 << int(b), size=(n,)) for b in w], axis=-1
    ).astype(np.int32))
    words = js.pack_codes(codes, jnp.asarray(w), total_bits=total)
    assert words.shape == (n, js.row_words(total))
    back = js.unpack_codes(words, jnp.asarray(w), total_bits=total)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


@given(
    widths=st.lists(st.integers(0, 11), min_size=1, max_size=8),
    n=st.integers(2, 30),
    n_valid=st.integers(0, 30),
    seed=st.integers(0, 2**20),
)
@settings(max_examples=40, deadline=None)
def test_ragged_mask_and_sentinels(widths, n, n_valid, seed):
    """Masked rows — equivalently rows carrying the -1 sentinel — pack to
    all-zero words and unpack back to -1 under the same mask; valid rows are
    untouched."""
    rng = np.random.default_rng(seed)
    w = np.asarray(widths, np.int32)
    total = int(w.sum())
    n_valid = min(n_valid, n)
    mask = jnp.asarray((np.arange(n) < n_valid).astype(np.float32))
    codes = np.stack(
        [rng.integers(0, 1 << int(b), size=(n,)) for b in w], axis=-1
    ).astype(np.int32)
    codes_s = jnp.where(mask[:, None] > 0, jnp.asarray(codes), -1)
    # mask argument and -1 sentinels are two spellings of the same validity
    via_mask = js.pack_codes(jnp.asarray(codes), jnp.asarray(w),
                             total_bits=total, mask=mask)
    via_sentinel = js.pack_codes(codes_s, jnp.asarray(w), total_bits=total)
    np.testing.assert_array_equal(np.asarray(via_mask), np.asarray(via_sentinel))
    assert np.all(np.asarray(via_mask)[n_valid:] == 0)
    back = js.unpack_codes(via_mask, jnp.asarray(w), total_bits=total, mask=mask)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes_s))


@given(
    bits=st.integers(1, 16),
    m=st.integers(1, 4),
    n=st.integers(1, 17),
    d=st.integers(1, 6),
    seed=st.integers(0, 2**20),
)
@settings(max_examples=25, deadline=None)
def test_dtype_and_value_stability_under_vmap_jit(bits, m, n, d, seed):
    """vmapping/jitting the pack does not change dtype, shape, or values vs
    the per-row eager path (the collectives run exactly this composition)."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(
        rng.integers(0, 1 << bits, size=(m, n, d)).astype(np.int32)
    )
    pack = lambda c: js.pack_codes(c, bits)
    batched = jax.jit(jax.vmap(pack))(codes)
    assert batched.dtype == jnp.uint32
    for j in range(m):
        np.testing.assert_array_equal(
            np.asarray(batched[j]), np.asarray(pack(codes[j]))
        )
    unpack = jax.jit(jax.vmap(lambda w: js.unpack_codes(w, bits, num=d)))
    back = unpack(batched)
    assert back.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
