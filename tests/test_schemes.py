"""Scheme-level API tests + eq.(6)==eq.(7) property."""
import numpy as np
import jax
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.schemes import PerSymbolScheme, OptimalScheme, DimReductionScheme, PCAScheme
from repro.core.distortion import distortion_pairwise, distortion_quadratic, second_moment


def _data(seed, d=10, n=2000):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(d, d)); Qx = A @ A.T / d
    B = rng.normal(size=(d, d)); Qy = B @ B.T / d
    X = rng.multivariate_normal(np.zeros(d), Qx, size=n).astype(np.float32)
    Y = rng.multivariate_normal(np.zeros(d), Qy, size=n).astype(np.float32)
    return Qx, Qy, X, Y


@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_eq6_equals_eq7(seed):
    rng = np.random.default_rng(seed)
    n, d = 50, 6
    X = rng.normal(size=(n, d)).astype(np.float32)
    Xh = rng.normal(size=(n, d)).astype(np.float32)
    Y = rng.normal(size=(n, d)).astype(np.float32)
    Sy = second_moment(Y)
    a = float(distortion_pairwise(X, Xh, Y))
    b = float(distortion_quadratic(X, Xh, Sy))
    assert a == pytest.approx(b, rel=1e-4)


def test_per_symbol_empirical_matches_expected():
    Qx, Qy, X, Y = _data(0)
    # finite-sample variance of the empirical distortion grows with rate
    # (fewer effective samples per bin), hence the rate-dependent tolerance
    for R, rel in [(8, 0.15), (30, 0.2), (60, 0.35)]:
        ps = PerSymbolScheme(R).fit(Qx, Qy)
        emp = float(distortion_quadratic(X, ps.roundtrip(X), Qy))
        assert emp == pytest.approx(ps.expected_distortion, rel=rel)


def test_distortion_decreases_with_rate():
    Qx, Qy, X, _ = _data(1)
    errs = []
    for R in [5, 10, 20, 40, 80]:
        ps = PerSymbolScheme(R).fit(Qx, Qy)
        errs.append(float(distortion_quadratic(X, ps.roundtrip(X), Qy)))
    assert all(a > b for a, b in zip(errs, errs[1:]))


def test_scheme_ordering_optimal_persym_dr():
    """Paper Fig. 2 ordering: optimal <= per-symbol << dim-reduction (at equal
    wire budget, DR coefficients cost 16 bits each)."""
    Qx, Qy, X, _ = _data(2)
    R = 48
    ps = PerSymbolScheme(R).fit(Qx, Qy)
    e_ps = float(distortion_quadratic(X, ps.roundtrip(X), Qy))
    opt = OptimalScheme(R).fit(Qx, Qy)
    e_opt = float(distortion_quadratic(X, opt.roundtrip(X, jax.random.PRNGKey(0)), Qy))
    dr = DimReductionScheme(R // 16).fit(Qx, Qy)  # same bits on the wire
    e_dr = float(distortion_quadratic(X, dr.roundtrip(X), Qy))
    assert e_opt <= e_ps * 1.05
    assert e_ps < e_dr


def test_wire_accounting():
    Qx, Qy, X, _ = _data(3)
    n, d = X.shape
    ps = PerSymbolScheme(24).fit(Qx, Qy)
    assert ps.wire_bits(n) == 24 * n
    assert ps.side_info_bits(d) == 2 * d * d * 32
    dr = DimReductionScheme(4).fit(Qx, Qy)
    assert dr.wire_bits(n) == 16 * (4 * n + 4 * d)
    pc = PCAScheme(4).fit(Qx)
    assert pc.side_info_bits(d) == 0


def test_codes_are_small_ints():
    Qx, Qy, X, _ = _data(4)
    ps = PerSymbolScheme(30, max_bits_per_dim=6).fit(Qx, Qy)
    codes = np.asarray(ps.encode(X))
    assert codes.dtype == np.int32
    assert codes.min() >= 0 and codes.max() < 2**6
