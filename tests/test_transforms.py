"""Decorrelating transform (§4.2) and Theorem-3 dimension reduction tests."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.transforms import (
    make_decorrelating_transform,
    make_dim_reduction,
    make_pca,
    dr_encode,
    dr_decode,
)
from repro.core.distortion import distortion_quadratic, second_moment


def _cov(rng, d, scale=1.0):
    A = rng.normal(size=(d, d))
    return scale * A @ A.T / d


def test_decorrelating_transform_diagonalizes():
    rng = np.random.default_rng(0)
    d = 8
    Qx, Qy = _cov(rng, d), _cov(rng, d)
    tr = make_decorrelating_transform(Qx, Qy)
    cov_xp = tr.T @ Qx @ tr.T.T
    np.testing.assert_allclose(cov_xp, np.diag(tr.variances), atol=1e-8)
    # inverse really inverts
    np.testing.assert_allclose(tr.T_inv @ tr.T, np.eye(d), atol=1e-8)


def test_dim_reduction_distortion_equals_leftout_eigs():
    rng = np.random.default_rng(1)
    d, n = 10, 20000
    Qx, Qy = _cov(rng, d), _cov(rng, d)
    X = rng.multivariate_normal(np.zeros(d), Qx, size=n).astype(np.float32)
    Sx = np.asarray(second_moment(X), np.float64)
    for m in [2, 5, 9]:
        dr = make_dim_reduction(Sx, Qy, m)
        Xh = dr_decode(dr, dr_encode(dr, X))
        emp = float(distortion_quadratic(X, Xh, Qy))
        assert emp == pytest.approx(dr.left_out, rel=5e-3)


def test_dim_reduction_full_rank_is_exact():
    rng = np.random.default_rng(2)
    d = 6
    Qx, Qy = _cov(rng, d), _cov(rng, d)
    X = rng.multivariate_normal(np.zeros(d), Qx, size=200).astype(np.float32)
    dr = make_dim_reduction(Qx, Qy, d)
    Xh = dr_decode(dr, dr_encode(dr, X))
    np.testing.assert_allclose(np.asarray(Xh), X, atol=1e-3)


@given(st.integers(1, 9))
@settings(max_examples=10, deadline=None)
def test_dr_never_worse_than_pca_in_objective(m):
    """Theorem 3 optimality: the proposed basis minimizes (7), so it must beat
    (or tie) PCA under that metric."""
    rng = np.random.default_rng(m)
    d, n = 10, 4000
    Qx, Qy = _cov(rng, d), _cov(rng, d, scale=3.0)
    X = rng.multivariate_normal(np.zeros(d), Qx, size=n).astype(np.float32)
    Sx = np.asarray(second_moment(X), np.float64)
    dr = make_dim_reduction(Sx, Qy, m)
    pc = make_pca(Sx, m)
    e_dr = float(distortion_quadratic(X, dr_decode(dr, dr_encode(dr, X)), Qy))
    e_pc = float(distortion_quadratic(X, dr_decode(pc, dr_encode(pc, X)), Qy))
    assert e_dr <= e_pc * 1.01  # tie allowed (identical covariances case)


def test_dr_equals_pca_when_sy_identity():
    rng = np.random.default_rng(5)
    d = 8
    Qx = _cov(rng, d)
    X = rng.multivariate_normal(np.zeros(d), Qx, size=1000).astype(np.float32)
    dr = make_dim_reduction(Qx, np.eye(d), 4)
    pc = make_pca(Qx, 4)
    e_dr = float(distortion_quadratic(X, dr_decode(dr, dr_encode(dr, X)), np.eye(d)))
    e_pc = float(distortion_quadratic(X, dr_decode(pc, dr_encode(pc, X)), np.eye(d)))
    assert e_dr == pytest.approx(e_pc, rel=1e-5)
