"""Traceable scheme (core.jax_scheme) must match the host-side scheme."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import quantizers as Q
from repro.core import jax_scheme
from repro.core.schemes import PerSymbolScheme
from repro.core.distortion import distortion_quadratic


def _cov(rng, d):
    A = rng.normal(size=(d, d))
    return (A @ A.T / d).astype(np.float32)


def test_traceable_greedy_equals_heap_greedy():
    rng = np.random.default_rng(0)
    d = 14
    Qx, Qy = _cov(rng, d), _cov(rng, d)
    for bits in [0, 7, 30, 64]:
        st = jax_scheme.fit_scheme(jnp.asarray(Qx), jnp.asarray(Qy), bits, 8)
        host = PerSymbolScheme(bits, max_bits_per_dim=8).fit(Qx, Qy)
        # same multiset of rates against matching variances (eigh order may
        # differ on degenerate eigenvalues; compare sorted-by-variance)
        v_j = np.asarray(st["sigma"]) ** 2
        v_h = host._tr.variances
        np.testing.assert_allclose(np.sort(v_j), np.sort(v_h), rtol=5e-3)  # fp32 eigh vs fp64
        r_j = np.asarray(st["rates"])[np.argsort(v_j)]
        r_h = np.asarray(host.rates)[np.argsort(v_h)]
        assert r_j.sum() == r_h.sum()
        exp_j = float(np.sum(np.sort(v_j) * [Q.unit_distortion(int(r)) for r in r_j]))
        assert exp_j == jax.numpy.allclose(exp_j, host.expected_distortion, rtol=1e-3) or True
        np.testing.assert_allclose(exp_j, host.expected_distortion, rtol=1e-3)


def test_traceable_roundtrip_distortion():
    rng = np.random.default_rng(1)
    d, n, bits = 10, 3000, 40
    Qx, Qy = _cov(rng, d), _cov(rng, d)
    X = rng.multivariate_normal(np.zeros(d), Qx, size=n).astype(np.float32)
    st = jax_scheme.fit_scheme(jnp.asarray(Qx), jnp.asarray(Qy), bits, 8)
    tables = Q.build_codebook_tables(8)
    codes = jax_scheme.encode(st, jnp.asarray(X), tables)
    Xh = jax_scheme.decode(st, codes, tables)
    emp = float(distortion_quadratic(X, Xh, Qy))
    host = PerSymbolScheme(bits, max_bits_per_dim=8).fit(Qx, Qy)
    assert abs(emp - host.expected_distortion) / host.expected_distortion < 0.2


def test_fit_scheme_is_jittable_and_shardmap_safe():
    rng = np.random.default_rng(2)
    d = 6
    Qx, Qy = _cov(rng, d), _cov(rng, d)
    out = jax.jit(lambda a, b: jax_scheme.fit_scheme(a, b, 12, 6))(
        jnp.asarray(Qx), jnp.asarray(Qy))
    assert int(out["rates"].sum()) == 12
