"""The paper's quantized cross-pod gradient reduction (§Perf C): convergence
parity with the exact fp32 reduce, on an 8-device (2 pods x 2 data x 2 model)
host mesh in a subprocess."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import make_train_step
from repro.models.steps import init_train_state
from repro.models.sharding import logical_rules, rules_multi_pod
from repro.compat import make_mesh, set_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("gemma2-2b").reduced()
with set_mesh(mesh), logical_rules(rules_multi_pod()):
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
    batch["labels"] = batch["tokens"]
    batch = jax.device_put(batch, NamedSharding(mesh, P(("pod", "data"), None)))
    out = {}
    for qbits in (0, 8):
        step = jax.jit(make_train_step(cfg, qcomm_bits=qbits, peak_lr=1e-3,
                                       warmup=2, total_steps=12))
        p, o = params, opt
        losses = []
        for _ in range(8):
            p, o, m = step(p, o, batch)
            losses.append(float(m["loss"]))
        out[str(qbits)] = losses
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def traces():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_exact_reduction_trains(traces):
    exact = traces["0"]
    assert exact[-1] < exact[0] - 0.5


def test_q8_matches_exact_training(traces):
    exact, q8 = traces["0"], traces["8"]
    assert q8[0] == pytest.approx(exact[0], rel=1e-3)  # same init/first loss
    assert abs(q8[-1] - exact[-1]) < 0.15  # indistinguishable convergence
