"""The streaming-invariant harness: retrace-free device-resident update().

Locks the bucketed-buffer streaming contract (core.protocols.streaming):
  * capacity buckets — a fresh fit is EXACT-size (bitwise pre-streaming
    artifacts); the first update grows to the next power of two; in-bucket
    updates never change array shapes and bucket crossings are the only
    growth events;
  * exactness at every capacity edge — padded factor growth equals a
    from-scratch factor build on the concatenated decodes, and splitting a
    batch across a bucket boundary equals streaming it whole;
  * update()-then-predict tracks a full protocol refit within tolerance for
    every protocol x wire scheme (per_symbol AND vq);
  * ledger increments match the repro.comm.accounting formulas
    INTEGER-EXACTLY (frozen rate per row, whole-word payload, CRC framing —
    and no new side info: the codebooks are frozen);
  * the retrace regression: N consecutive in-bucket update() calls leave
    ``update_trace_count`` flat, the first predict after an in-bucket update
    adds ZERO serve traces, and the warm predict program on bucketed buffers
    still contains zero cholesky/eigh equations;
  * checkpoint v5: a streamed artifact round-trips BITWISE, stream state
    (counts / capacity / ledgers) included.

Hypothesis fuzz sweeps run when the optional dev dep is installed
(requirements-dev.txt) and skip cleanly otherwise.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import jax_scheme
from repro.core.gp import gram_fn
from repro.core.nystrom import nystrom_posterior
from repro.core.protocols import (
    fit,
    load_artifact,
    predict,
    predict_op_counts,
    save_artifact,
    serve_trace_count,
    split_machines,
    update,
    update_trace_count,
)
from repro.core.protocols.streaming import next_pow2
from repro.analysis import check_contracts
from repro.comm.accounting import (
    CRC_BITS,
    integrity_bits_formula,
    payload_bits_formula,
    side_info_bits,
    wire_bits_formula,
)

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    hypothesis = None

    def given(*a, **k):
        def deco(f):
            return pytest.mark.skip(
                reason="hypothesis not installed (requirements-dev.txt)"
            )(f)
        return deco

    def settings(*a, **k):
        return lambda f: f

    class st:  # placeholder strategies, never drawn when skipped
        integers = sampled_from = lists = staticmethod(lambda *a, **k: None)


# --------------------------------------------------------------------------
# shared fixtures
# --------------------------------------------------------------------------


def _problem(seed=0, n=120, d=4, m=4, n_test=24):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    Xt = rng.normal(size=(n_test, d)).astype(np.float32)
    parts = split_machines(X, y, m, jax.random.PRNGKey(seed))
    return parts, jnp.asarray(Xt), f


def _batch(f, n, d, seed):
    rng = np.random.default_rng(seed)
    Xn = rng.normal(size=(n, d)).astype(np.float32)
    yn = (f(Xn) + 0.05 * rng.normal(size=n)).astype(np.float32)
    return Xn, yn


def _fit_any(protocol, parts, bits, scheme="per_symbol", steps=4, **kw):
    if protocol == "poe":
        return fit(parts, 0, "poe", steps=steps, method="rbcm", **kw)
    return fit(parts, bits, protocol, steps=steps, scheme=scheme, **kw)


def _capacity(art):
    return int(art.y.shape[-1])


PROTOCOLS = ["center", "broadcast", "poe"]


# --------------------------------------------------------------------------
# capacity buckets: exact fresh fit, geometric growth, in-bucket stability
# --------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", PROTOCOLS)
def test_fresh_fit_capacity_is_exact(protocol):
    """A fresh fit carries NO padding — its buffers are bitwise the
    pre-streaming artifacts (capacity == occupied columns)."""
    parts, _, _ = _problem(0)
    art = _fit_any(protocol, parts, 16)
    expect = max(art.lengths) if protocol == "poe" else sum(art.lengths)
    assert _capacity(art) == expect


@pytest.mark.parametrize("protocol", PROTOCOLS)
def test_capacity_grows_geometrically(protocol):
    """First update overflows the exact-size bucket and grows to next_pow2;
    in-bucket updates keep every shape; the next crossing doubles again."""
    parts, _, f = _problem(1)
    d = parts[0][0].shape[1]
    art = _fit_any(protocol, parts, 16)
    cols = _capacity(art)  # fresh: fully occupied
    occupied = cols

    Xn, yn = _batch(f, 5, d, 1)
    art = update(art, Xn, yn, machine=1)
    occupied += 5
    assert _capacity(art) == next_pow2(occupied)

    cap = _capacity(art)
    while occupied + 3 <= cap:  # in-bucket: capacity pinned
        Xn, yn = _batch(f, 3, d, occupied)
        art = update(art, Xn, yn, machine=2)
        occupied += 3
        assert _capacity(art) == cap
    Xn, yn = _batch(f, 3, d, occupied)  # straddles the bucket edge
    art = update(art, Xn, yn, machine=2)
    occupied += 3
    assert _capacity(art) == next_pow2(occupied) > cap
    mu, s2 = predict(art, jnp.asarray(_batch(f, 8, d, 99)[0]))
    assert np.isfinite(np.asarray(mu)).all() and np.all(np.asarray(s2) > 0)


@pytest.mark.parametrize("protocol", PROTOCOLS)
@pytest.mark.parametrize("split", [(1, 7), (4, 4), (7, 1)])
def test_chunk_split_equals_single_batch_across_bucket_edge(protocol, split):
    """Streaming a batch in two chunks — including splits that straddle the
    first bucket boundary — serves the same predictive as streaming it whole
    (per-symbol encode is deterministic; rank-k growth is exact algebra)."""
    parts, Xt, f = _problem(2)
    d = parts[0][0].shape[1]
    art = _fit_any(protocol, parts, 16)
    Xn, yn = _batch(f, sum(split), d, 2)
    k = split[0]

    art_whole = update(art, Xn, yn, machine=1)
    art_chunks = update(
        update(art, Xn[:k], yn[:k], machine=1), Xn[k:], yn[k:], machine=1
    )
    assert art_chunks.lengths == art_whole.lengths
    assert art_chunks.wire_bits == art_whole.wire_bits
    assert art_chunks.payload_bits == art_whole.payload_bits
    assert art_chunks.integrity_bits == art_whole.integrity_bits
    mu_w, v_w = predict(art_whole, Xt)
    mu_c, v_c = predict(art_chunks, Xt)
    np.testing.assert_allclose(np.asarray(mu_c), np.asarray(mu_w), atol=1e-4)
    np.testing.assert_allclose(np.asarray(v_c), np.asarray(v_w), atol=1e-4)


def test_growth_exact_vs_scratch_build_at_every_capacity_edge():
    """The padded factor growth is EXACT at every step of a stream that
    crosses a capacity edge: after each update the served predictive equals a
    full nystrom_posterior built from scratch on [fit-time reconstruction;
    streamed decodes] (padding contributes nothing)."""
    parts, Xt, f = _problem(3, n=60, m=3)
    d = parts[0][0].shape[1]
    art0 = fit(parts, 16, "center", steps=6)
    X_fit = art0.data["X_recon"]  # fresh fit: exact-size, no padding
    tables = jax_scheme.scheme_tables(art0.bits_per_sample, art0.max_bits)
    k = gram_fn("se")
    p = art0.params
    Xc = art0.data["Xc"]
    g_ss = jnp.full(Xt.shape[0], jnp.exp(p.log_a))

    art = art0
    decs, ys = [], []
    for step, n_new in enumerate([3, 4, 5, 6]):  # 60 -> cap 64 -> cap 128
        Xn, yn = _batch(f, n_new, d, 30 + step)
        art = update(art, Xn, yn, machine=1)
        w = art0.wire
        state = {"T": w.T[1], "T_inv": w.T_inv[1], "sigma": w.sigma[1],
                 "rates": w.rates[1]}
        _, dec = jax_scheme.roundtrip(state, jnp.asarray(Xn), tables)
        decs.append(dec)
        ys.append(jnp.asarray(yn))

        X2 = jnp.concatenate([X_fit] + decs)
        y2 = jnp.concatenate([art0.y] + ys)
        mu_s, v_s = nystrom_posterior(
            k(p, Xc), k(p, Xc, X2), y2, jnp.exp(p.log_noise), k(p, Xt, Xc),
            g_ss,
        )
        mu_u, v_u = predict(art, Xt)
        np.testing.assert_allclose(np.asarray(mu_u), np.asarray(mu_s),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(v_u), np.asarray(v_s),
                                   atol=1e-4)


# --------------------------------------------------------------------------
# protocol x scheme: streamed artifact tracks a full refit
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "protocol,scheme",
    [
        ("center", "per_symbol"),
        ("center", "vq"),
        ("broadcast", "per_symbol"),
        ("broadcast", "vq"),
        ("poe", "per_symbol"),
    ],
)
def test_update_then_predict_tracks_full_refit(protocol, scheme):
    """Frozen-codebook streaming vs refitting the whole protocol (steps=0,
    same hypers) on the concatenated shards: at a healthy rate the served
    predictions agree closely for every protocol x scheme pairing."""
    parts, Xt, f = _problem(4, n=160, m=4)
    d = parts[0][0].shape[1]
    bits = 48
    art = _fit_any(protocol, parts, bits, scheme=scheme, steps=12)
    Xn, yn = _batch(f, 12, d, 40)
    art_u = update(art, Xn, yn, machine=1)
    assert art_u.lengths[1] == art.lengths[1] + 12
    mu_u, v_u = predict(art_u, Xt)

    parts2 = list(parts)
    parts2[1] = (
        jnp.concatenate([parts[1][0], jnp.asarray(Xn)]),
        jnp.concatenate([parts[1][1], jnp.asarray(yn)]),
    )
    art_r = _fit_any(protocol, parts2, bits, scheme=scheme, steps=0,
                     params=art.params)
    mu_r, _ = predict(art_r, Xt)
    # the refit re-fits schemes AND (broadcast/poe) re-seats the per-machine
    # Nyström bases on the grown shards, so exact agreement is not the
    # contract — tracking it is: the streamed artifact's error against the
    # true function must not drift from the refit's, and the two predictive
    # surfaces must stay close relative to the target spread
    yt = np.asarray(f(np.asarray(Xt)))
    e_u = float(np.mean((yt - np.asarray(mu_u)) ** 2) / np.var(yt))
    e_r = float(np.mean((yt - np.asarray(mu_r)) ** 2) / np.var(yt))
    assert e_u < e_r * 1.3 + 0.03
    spread = float(np.std(yt))
    assert float(jnp.max(jnp.abs(mu_u - mu_r))) < 0.3 * max(spread, 1.0)
    assert np.all(np.asarray(v_u) > 0)


# --------------------------------------------------------------------------
# ledgers: increments match the accounting formulas integer-exactly
# --------------------------------------------------------------------------


def test_per_symbol_ledger_increments_match_accounting_formulas():
    """Every per-symbol streamed batch charges EXACTLY the accounting
    formulas for a one-machine lengths vector, minus the side info (codebooks
    are frozen — no new transform crosses the wire)."""
    parts, _, f = _problem(5, m=4)
    d = parts[0][0].shape[1]
    for protocol in ("center", "broadcast"):
        art = _fit_any(protocol, parts, 19)
        rates = np.asarray(art.wire.rates)
        center = art.block_order[0] if protocol == "center" else None
        exp_w, exp_p, exp_i = art.wire_bits, art.payload_bits, art.integrity_bits
        counts = list(art.lengths)
        for j, n_new in [(1, 6), (2, 3), (0, 5), (1, 4)]:
            Xn, yn = _batch(f, n_new, d, 50 + j * 10 + n_new)
            art = update(art, Xn, yn, machine=j)
            L = [n_new if q == j else 0 for q in range(len(counts))]
            exp_w += wire_bits_formula(rates, L, d, skip=center) - (
                0 if j == center else side_info_bits(d)
            )
            exp_p += payload_bits_formula(
                L, d, art.bits_per_sample, art.max_bits, skip=center
            ) - (0 if j == center else side_info_bits(d))
            exp_i += integrity_bits_formula(L, skip=center)
            counts[j] += n_new
            assert art.wire_bits == exp_w
            assert art.payload_bits == exp_p
            assert art.integrity_bits == exp_i
            assert art.lengths == tuple(counts)


def test_vq_ledger_increments_match_achieved_rate():
    """The vq test channel charges ceil(n * achieved_rate) to BOTH ledgers
    (simulated block code: payload == ledger, no word padding) and nothing to
    the integrity ledger (no packed rows, no CRC framing)."""
    import math

    from repro.core import DGPConfig, DistributedGP

    parts, _, f = _problem(6, m=3)
    d = parts[0][0].shape[1]
    est = DistributedGP(DGPConfig(protocol="broadcast", bits_per_sample=20,
                                  steps=3, scheme="vq"))
    art = est.fit(parts=parts)
    for j, n_new in [(1, 7), (2, 4), (1, 2)]:
        Xn, yn = _batch(f, n_new, d, 60 + n_new)
        art2 = est.update(art, Xn, yn, machine=j)
        rate = float(np.asarray(art.data["vq_rate_bits"][j]))
        bits = math.ceil(n_new * rate)
        assert art2.wire_bits == art.wire_bits + bits
        assert art2.payload_bits == art.payload_bits + bits
        assert art2.integrity_bits == art.integrity_bits == 0
        art = art2


def test_poe_streaming_stays_zero_rate():
    parts, _, f = _problem(7)
    d = parts[0][0].shape[1]
    art = _fit_any("poe", parts, 0)
    for j in range(4):
        Xn, yn = _batch(f, 3, d, 70 + j)
        art = update(art, Xn, yn, machine=j)
    assert art.wire_bits == art.payload_bits == art.integrity_bits == 0


# --------------------------------------------------------------------------
# the retrace regression: in-bucket streaming is ONE cached program
# --------------------------------------------------------------------------


def _warm_and_stream(protocol, impl="batched", scheme="per_symbol", m=4,
                     n_updates=5, batch=4):
    """Fit, grow into a roomy bucket, warm every (machine-class) cache entry,
    then stream ``n_updates`` fixed-size in-bucket batches; returns the trace
    counters observed around the in-bucket window and the final artifact."""
    parts, Xt, f = _problem(8, n=120, m=m)
    d = parts[0][0].shape[1]
    art = _fit_any(protocol, parts, 16, scheme=scheme, steps=3, impl=impl)
    # first update: one growth into a bucket with enough slack for the whole
    # warm + measurement window on every layout (the expert layouts bucket at
    # next_pow2(n_pad): 30 + 40 -> 128 leaves 58 free columns)
    Xn, yn = _batch(f, 40, d, 80)
    art = update(art, Xn, yn, machine=1)
    predict(art, Xt)  # warm the serve program on the bucketed buffers
    # warm one update per machine-treedef class: the center's own batch takes
    # the precomputed-exact path (a second jit cache entry, by design)
    for j in range(m):
        Xn, yn = _batch(f, batch, d, 81 + j)
        art = update(art, Xn, yn, machine=j)
    predict(art, Xt)
    u0 = update_trace_count(protocol)
    c0 = serve_trace_count(protocol)
    for i in range(n_updates):
        Xn, yn = _batch(f, batch, d, 90 + i)
        art = update(art, Xn, yn, machine=(i % m))
        mu, s2 = predict(art, Xt)
        assert np.isfinite(np.asarray(mu)).all()
        assert np.all(np.asarray(s2) > 0)
    return u0, update_trace_count(protocol), c0, serve_trace_count(protocol), \
        art, Xt


@pytest.mark.parametrize("protocol", PROTOCOLS)
def test_in_bucket_updates_do_not_retrace(protocol):
    """N consecutive in-bucket fixed-size update() calls: ZERO retraces of
    the update program — the device-resident streaming contract."""
    u0, u1, _, _, _, _ = _warm_and_stream(protocol)
    assert u1 == u0


@pytest.mark.parametrize("protocol", PROTOCOLS)
def test_first_predict_after_in_bucket_update_does_not_recompile(protocol):
    """The warm predict program reads the same bucketed buffers the update
    wrote: the first predict after every in-bucket update adds ZERO serve
    traces (the pre-streaming behavior was one recompile per update)."""
    _, _, c0, c1, _, _ = _warm_and_stream(protocol)
    assert c1 == c0


@pytest.mark.parametrize("protocol", PROTOCOLS)
def test_warm_predict_on_bucketed_buffers_is_factorization_free(protocol):
    """Padding does not smuggle factorizations into the serve path: the warm
    predict jaxpr on a streamed (padded) artifact still contains zero
    cholesky/eigh equations."""
    _, _, _, _, art, Xt = _warm_and_stream(protocol)
    report = check_contracts(art, Xt)  # full registered contract, incl. budgets
    assert report.op_counts["cholesky"] == 0
    assert report.op_counts["eigh"] == 0
    assert predict_op_counts(art, Xt) == {"cholesky": 0, "eigh": 0}


@pytest.mark.parametrize("protocol", ["broadcast", "poe"])
def test_mesh_in_bucket_updates_do_not_retrace(protocol):
    """The mesh substrate honors the same contract: in-bucket shard_map
    updates are one cached program and the sharded serve program does not
    recompile after them."""
    u0, u1, c0, c1, art, Xt = _warm_and_stream(protocol, impl="mesh")
    assert u1 == u0
    assert c1 == c0
    # the mesh-serve contract additionally budgets the fused epilogue to ONE
    # stacked psum and allows only the machine-axis factor/data shardings
    check_contracts(art, Xt)


def test_in_bucket_update_under_strict_device_guard(strict_device_guard):
    """A warm in-bucket update survives jax.transfer_guard("disallow") +
    strict dtype promotion: the streamed batch is device_put explicitly, the
    machine index crosses via the explicit _machine_index transfer, and
    nothing else moves — the runtime complement of the update contract."""
    import jax.numpy as jnp

    with jax.transfer_guard("allow"), jax.numpy_dtype_promotion("standard"):
        parts, Xt, f = _problem(23, n=96, d=4)
        art = fit(parts, 16, "center", steps=2)
        Xn, yn = _batch(f, 6, 4, 0)
        art = update(art, Xn, yn, machine=1)   # warm the update program
        predict(art, Xt)                        # and the serve program
        Xn_dev = jax.device_put(jnp.asarray(Xn))
        yn_dev = jax.device_put(jnp.asarray(yn))
        Xt_dev = jax.device_put(jnp.asarray(Xt))
    art = update(art, Xn_dev, yn_dev, machine=1)
    mu, s2 = predict(art, Xt_dev)
    assert np.isfinite(np.asarray(jax.block_until_ready(mu))).all()
    assert np.all(np.asarray(s2) > 0)


def test_vq_in_bucket_updates_do_not_retrace():
    """The vq host-side channel precomputes its decode eagerly, but the
    factor growth still runs as the one cached device program."""
    u0, u1, c0, c1, _, _ = _warm_and_stream("broadcast", scheme="vq")
    assert u1 == u0
    assert c1 == c0


def test_bucket_crossing_costs_exactly_one_retrace():
    # d=5 / batch=6 give this test its own jit-cache shape signature: the
    # counters are global, so shapes shared with other tests would be warm
    parts, Xt, f = _problem(9, n=100, d=5)
    art = fit(parts, 16, "center", steps=3)
    art = update(art, *_batch(f, 6, 5, 0), machine=1)  # 100 -> cap 128
    art = update(art, *_batch(f, 6, 5, 1), machine=1)  # in-bucket, warm
    u0 = update_trace_count("center")
    art = update(art, *_batch(f, 6, 5, 2), machine=1)  # in-bucket: cached
    art = update(art, *_batch(f, 6, 5, 3), machine=1)  # 124 occupied
    assert update_trace_count("center") == u0
    # stream past 128: one growth to cap 256, exactly one retrace
    art = update(art, *_batch(f, 6, 5, 4), machine=1)
    assert update_trace_count("center") == u0 + 1
    mu, _ = predict(art, Xt)
    assert np.isfinite(np.asarray(mu)).all()


# --------------------------------------------------------------------------
# checkpoint v5: stream state round-trips bitwise
# --------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", PROTOCOLS)
def test_v5_roundtrip_after_streaming_is_bitwise(tmp_path, protocol):
    parts, Xt, f = _problem(10)
    d = parts[0][0].shape[1]
    art = _fit_any(protocol, parts, 16)
    for j, n_new in [(1, 6), (2, 3)]:
        art = update(art, *_batch(f, n_new, d, j), machine=j)
    save_artifact(art, str(tmp_path))
    art2 = load_artifact(str(tmp_path))
    assert art2.lengths == art.lengths
    assert art2.wire_bits == art.wire_bits
    assert art2.payload_bits == art.payload_bits
    assert art2.integrity_bits == art.integrity_bits
    assert _capacity(art2) == _capacity(art)  # the bucket itself persists
    mu0, v0 = predict(art, Xt)
    mu1, v1 = predict(art2, Xt)
    np.testing.assert_array_equal(np.asarray(mu0), np.asarray(mu1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    # and the restored artifact keeps streaming where the original left off
    Xn, yn = _batch(f, 5, d, 20)
    a_cont = update(art, Xn, yn, machine=1)
    b_cont = update(art2, Xn, yn, machine=1)
    assert a_cont.wire_bits == b_cont.wire_bits
    np.testing.assert_allclose(
        np.asarray(predict(a_cont, Xt)[0]),
        np.asarray(predict(b_cont, Xt)[0]), atol=1e-5,
    )


# --------------------------------------------------------------------------
# hypothesis sweeps: batch-size sequences straddling bucket edges
# --------------------------------------------------------------------------


@given(
    sizes=st.lists(st.integers(1, 9), min_size=1, max_size=4),
    machines=st.lists(st.integers(0, 2), min_size=4, max_size=4),
    protocol=st.sampled_from(["center", "broadcast", "poe"]),
    seed=st.integers(0, 2**20),
)
@settings(max_examples=8, deadline=None)
def test_hyp_streamed_sequences_keep_invariants(sizes, machines, protocol,
                                                seed):
    """Random batch-size sequences (freely straddling capacity edges) x
    random target machines: counts, capacity, and the wire ledger stay
    mutually consistent and the artifact keeps serving finite predictions."""
    parts, Xt, f = _problem(seed % 97, n=48, d=3, m=3, n_test=8)
    d = 3
    art = _fit_any(protocol, parts, 9, steps=0)
    rates = np.asarray(art.wire.rates) if art.wire is not None else None
    center = art.block_order[0] if protocol == "center" else None
    counts = list(art.lengths)
    occupied = _capacity(art)
    exp_wire = art.wire_bits
    for n_new, j in zip(sizes, machines):
        cap_before = _capacity(art)
        Xn, yn = _batch(f, n_new, d, seed + n_new + j)
        art = update(art, Xn, yn, machine=j)
        counts[j] += n_new
        occupied += n_new
        if protocol != "poe" and j != center:
            exp_wire += n_new * int(rates[j].sum())
        assert art.lengths == tuple(counts)
        assert art.wire_bits == exp_wire
        assert _capacity(art) == (
            cap_before if occupied <= cap_before else next_pow2(occupied)
        )
    mu, s2 = predict(art, Xt)
    assert np.isfinite(np.asarray(mu)).all() and np.all(np.asarray(s2) > 0)


@given(
    k=st.integers(1, 11),
    machine=st.integers(0, 2),
    seed=st.integers(0, 2**20),
)
@settings(max_examples=8, deadline=None)
def test_hyp_chunk_split_invariance(k, machine, seed):
    """For every split point of a 12-row batch and every target machine, the
    two-chunk stream equals the whole-batch stream (per-symbol wire)."""
    parts, Xt, f = _problem(seed % 89, n=48, d=3, m=3, n_test=8)
    art = fit(parts, 12, "broadcast", steps=0)
    Xn, yn = _batch(f, 12, 3, seed)
    a = update(art, Xn, yn, machine=machine)
    b = update(update(art, Xn[:k], yn[:k], machine=machine),
               Xn[k:], yn[k:], machine=machine)
    assert a.lengths == b.lengths and a.wire_bits == b.wire_bits
    np.testing.assert_allclose(np.asarray(predict(b, Xt)[0]),
                               np.asarray(predict(a, Xt)[0]), atol=1e-4)
