"""Decode-attention Pallas kernel vs oracle: shape/window/ring sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attn.ops import decode_attn
from repro.kernels.decode_attn.ref import decode_attn_ref


CASES = [
    # B, S, KV, G, hd, window, pos
    (2, 100, 2, 3, 16, None, 80),
    (1, 512, 4, 1, 32, None, 511),
    (2, 300, 1, 4, 8, 64, 250),
    (3, 64, 2, 2, 16, 16, 10),
    (1, 7, 1, 1, 4, None, 3),  # tiny, heavy padding
]


@pytest.mark.parametrize("B,S,KV,G,hd,window,pos", CASES)
def test_matches_ref(B, S, KV, G, hd, window, pos):
    rng = np.random.default_rng(S)
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    kpos = np.broadcast_to(np.arange(S), (B, S)).copy()
    kpos[:, pos + 1:] = -1
    kpos = jnp.asarray(kpos)
    out = decode_attn(q, K, V, kpos, pos, window=window, chunk=64, interpret=True)
    ref = decode_attn_ref(q, K, V, kpos, pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_ring_cache_order_invariance():
    """A ring cache stores entries in slot order != position order; the kernel
    must only care about kpos values."""
    rng = np.random.default_rng(0)
    B, S, KV, G, hd = 1, 32, 1, 2, 8
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    kpos = jnp.asarray(np.arange(S)[None], jnp.int32)
    out1 = decode_attn(q, K, V, kpos, 31, interpret=True)
    perm = np.random.default_rng(1).permutation(S)
    out2 = decode_attn(q, K[:, perm], V[:, perm], kpos[:, perm], 31, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-4, atol=1e-5)


def test_bf16_inputs():
    rng = np.random.default_rng(2)
    B, S, KV, G, hd = 2, 128, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.bfloat16)
    K = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
    V = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
    kpos = jnp.asarray(np.arange(S)[None].repeat(B, 0), jnp.int32)
    out = decode_attn(q, K, V, kpos, S - 1, interpret=True)
    ref = decode_attn_ref(q, K, V, kpos, S - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2, atol=2e-2)
