"""Titsias SGPR tests (paper Fig. 7 substrate)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.gp import init_params, se_gram, nlml_from_gram, train_gp
from repro.core.sparse_gp import elbo, train_sgpr
from repro.core.schemes import PerSymbolScheme
from repro.core.distortion import second_moment


def _problem(seed=0, n=200, d=3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (np.sin(X @ np.ones(d)) + 0.1 * rng.normal(size=n)).astype(np.float32)
    return X, y


def test_elbo_lower_bounds_exact_marginal_likelihood():
    X, y = _problem()
    p = init_params(a=1.0, b=2.0, noise=0.1)
    G = se_gram(p, jnp.asarray(X))
    exact_lml = -float(nlml_from_gram(G, jnp.asarray(y), float(jnp.exp(p.log_noise))))
    for m in [5, 20, 80]:
        Z = jnp.asarray(X[:m])
        bound = float(elbo(p, Z, jnp.asarray(X), jnp.asarray(y), "se"))
        assert bound <= exact_lml + 1e-2
    # bound tightens as m grows to n (Z == X makes Qnn == Knn)
    b_all = float(elbo(p, jnp.asarray(X), jnp.asarray(X), jnp.asarray(y), "se"))
    assert b_all == pytest.approx(exact_lml, abs=0.5)


def test_sgpr_training_improves_elbo_and_predicts():
    X, y = _problem(1)
    sg0 = train_sgpr(X, y, 15, steps=0)
    sg = train_sgpr(X, y, 15, steps=150)
    e0 = float(elbo(sg0.params, sg0.Z, jnp.asarray(X), jnp.asarray(y), "se"))
    e1 = float(elbo(sg.params, sg.Z, jnp.asarray(X), jnp.asarray(y), "se"))
    assert e1 > e0
    mu, var = sg.predict(X[:30])
    assert np.mean((np.asarray(mu) - y[:30]) ** 2) < 0.2 * np.var(y)
    assert np.all(np.asarray(var) > 0)


def test_quantized_inducing_points_degrade_gracefully():
    """Fig.-7 mechanism: quantizing Z at a few bits/dim should barely move the
    predictions (inducing sets are small, so bits are cheap)."""
    X, y = _problem(2)
    sg = train_sgpr(X, y, 12, steps=120)
    Z = np.asarray(sg.Z)
    Q = np.cov(Z.T) + 1e-3 * np.eye(Z.shape[1])
    S = np.asarray(second_moment(jnp.asarray(X)))
    sch = PerSymbolScheme(8 * Z.shape[1]).fit(Q, S)  # 8 bits/dim
    Zq = np.asarray(sch.roundtrip(Z))
    mu0, _ = sg.predict(X[:50])
    import dataclasses
    sgq = dataclasses.replace(sg, Z=jnp.asarray(Zq))
    mu1, _ = sgq.predict(X[:50])
    base = float(np.mean((np.asarray(mu0) - y[:50]) ** 2))
    quant = float(np.mean((np.asarray(mu1) - y[:50]) ** 2))
    assert quant < 2.5 * base + 0.05
