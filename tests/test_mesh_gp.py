"""Mesh-native broadcast GP (core.protocols.mesh): the §5.2 protocol with devices as
machines and repro.comm as the wire — 8-device subprocess."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax
from repro.core.protocols.mesh import broadcast_gp_mesh
from repro.compat import make_mesh
from repro.core.gp import train_gp

mesh = make_mesh((8,), ("m",))
rng = np.random.default_rng(0)
d, n, t = 8, 320, 100
W = rng.normal(size=(d, 2))
f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
X = rng.normal(size=(n, d)).astype(np.float32)
y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
Xt = rng.normal(size=(t, d)).astype(np.float32)
yt = f(Xt)
sm = lambda mu: float(np.mean((yt - np.asarray(mu)) ** 2) / np.var(yt))

full = train_gp(X, y, kernel="se", steps=100)
out = {"full": sm(full.predict(Xt)[0])}
for bits in (4, 32):
    mu, s2 = broadcast_gp_mesh(mesh, "m", X, y, Xt, full.params,
                               kernel="se", bits_per_sample=bits)
    out[str(bits)] = {"smse": sm(mu), "var_pos": bool(np.all(np.asarray(s2) > 0))}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_high_rate_matches_full_gp(results):
    assert results["32"]["smse"] < 1.15 * results["full"] + 0.02


def test_rate_monotone(results):
    assert results["32"]["smse"] <= results["4"]["smse"] * 1.05


def test_variances_positive(results):
    assert results["32"]["var_pos"] and results["4"]["var_pos"]
