"""PoE/BCM/rBCM combiners and KL-barycenter fusion (eqs. 62-64)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.poe import poe, gpoe, bcm, rbcm, combine
from repro.core.fusion import kl_fuse, kl_fuse_diag


def test_single_expert_identity():
    mus = jnp.asarray([[1.0, -2.0]])
    s2s = jnp.asarray([[0.5, 2.0]])
    for fn in (poe, gpoe):
        mu, s2 = fn(mus, s2s)
        np.testing.assert_allclose(mu, mus[0], rtol=1e-6)
        np.testing.assert_allclose(s2, s2s[0], rtol=1e-6)
    mu, s2 = bcm(mus, s2s, prior_var=jnp.asarray([4.0, 4.0]))
    np.testing.assert_allclose(mu, mus[0], rtol=1e-6)


def test_poe_precision_weighting():
    mus = jnp.asarray([[0.0], [2.0]])
    s2s = jnp.asarray([[1.0], [1.0]])
    mu, s2 = poe(mus, s2s)
    assert float(mu[0]) == pytest.approx(1.0)
    assert float(s2[0]) == pytest.approx(0.5)
    # tighter expert dominates
    s2s = jnp.asarray([[0.01], [1.0]])
    mu, _ = poe(mus, s2s)
    assert abs(float(mu[0])) < 0.1


def test_bcm_removes_prior_overcount():
    # two identical experts that know nothing (s2 == prior) must return prior
    prior = jnp.asarray([3.0])
    mus = jnp.asarray([[0.0], [0.0]])
    s2s = jnp.asarray([[3.0], [3.0]])
    _, s2 = bcm(mus, s2s, prior)
    assert float(s2[0]) == pytest.approx(3.0, rel=1e-5)
    # plain PoE would (wrongly) halve the variance
    _, s2p = poe(mus, s2s)
    assert float(s2p[0]) == pytest.approx(1.5, rel=1e-5)


def test_rbcm_uninformative_expert_is_ignored():
    prior = jnp.asarray([2.0])
    mus = jnp.asarray([[5.0], [0.0]])
    s2s = jnp.asarray([[2.0], [0.1]])  # expert 0 has prior variance: beta_0 = 0
    mu, _ = rbcm(mus, s2s, prior)
    assert abs(float(mu[0])) < 0.2


def test_combine_dispatch():
    mus = jnp.zeros((3, 4))
    s2s = jnp.ones((3, 4))
    for name in ["poe", "gpoe", "bcm", "rbcm"]:
        mu, s2 = combine(name, mus, s2s, prior_var=jnp.full((4,), 2.0))
        assert mu.shape == (4,) and s2.shape == (4,)


def test_kl_fusion_formulas():
    rng = np.random.default_rng(0)
    m, t = 5, 3
    mus = rng.normal(size=(m, t)).astype(np.float32)
    s2s = rng.uniform(0.5, 2.0, size=(m, t)).astype(np.float32)
    mu, s2 = kl_fuse_diag(jnp.asarray(mus), jnp.asarray(s2s))
    np.testing.assert_allclose(np.asarray(mu), mus.mean(0), rtol=1e-5)
    ref = s2s.mean(0) + ((mus.mean(0)[None] - mus) ** 2).mean(0)
    np.testing.assert_allclose(np.asarray(s2), ref, rtol=1e-5)
    # full-covariance version agrees on the diagonal
    Sig = np.stack([np.diag(s) for s in s2s]).astype(np.float32)
    mu2, Sig2 = kl_fuse(jnp.asarray(mus), jnp.asarray(Sig))
    np.testing.assert_allclose(np.asarray(mu2), mus.mean(0), rtol=1e-5)
    np.testing.assert_allclose(np.diagonal(np.asarray(Sig2)), ref, rtol=1e-5)


def test_kl_fusion_is_the_barycenter_optimum():
    """(63)-(64) minimize sum_i KL(N_i || N): check by perturbation."""
    rng = np.random.default_rng(1)
    mus = rng.normal(size=(4, 1)).astype(np.float64)
    s2s = rng.uniform(0.5, 1.5, size=(4, 1)).astype(np.float64)

    def obj(mu, s2):
        return sum(
            0.5 * (np.log(s2 / s) + (s + (m - mu) ** 2) / s2 - 1.0)
            for m, s in zip(mus[:, 0], s2s[:, 0])
        )

    mu_star, s2_star = kl_fuse_diag(jnp.asarray(mus), jnp.asarray(s2s))
    base = obj(float(mu_star[0]), float(s2_star[0]))
    for dm in [-0.05, 0.05]:
        assert obj(float(mu_star[0]) + dm, float(s2_star[0])) > base
        assert obj(float(mu_star[0]), float(s2_star[0]) * (1 + dm)) > base
