"""Multi-tenant fleet serving contract (core.fleet + launch.fleet).

Locks the tentpole invariants of the stacked vmapped serve path:
  * equivalence — one stacked dispatch over a mixed-tenant micro-batch
    matches the per-artifact serial predict loop, for the plain-vmap path
    (center) AND the tenant-batched fused epilogue path (broadcast +
    pallas-mode artifacts);
  * isolation — one tenant's hostile query rows (NaN) or degraded
    availability mask never perturbs a co-batched tenant: the neighbor's
    answers are BITWISE identical with and without the bad tenant present;
  * retrace-freedom — admitting tenants, swapping the batch mix, and LRU
    evictions leave ``fleet_trace_count`` flat (row writes + traced gather
    indices never change the jit key);
  * the cache plane — LRU eviction order, byte-capacity accounting, and
    checkpoint-backed load-on-miss serving BITWISE identically to a direct
    ``load_artifact``;
  * the request plane — MicroBatcher budget/size flush semantics under a
    fake clock (no sleeping), FleetServer end-to-end, and the injectable
    ``_retry`` backoff.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import split_machines
from repro.core.fleet import (
    ArtifactCache,
    ArtifactStore,
    FleetStack,
    artifact_nbytes,
    bucket_key,
    fleet_trace_count,
    pad_to_capacity,
    scale_targets,
    stack_artifacts,
)
from repro.core.protocols import fit, predict, update
from repro.launch.fleet import FleetServer, MicroBatcher, build_fleet, \
    serve_loop, zipf_tenants

M, N, D, STEPS, BITS = 4, 96, 4, 2, 8
T_Q = 8  # query points per tenant request


def _parts(seed=0):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(D, 2))
    X = rng.normal(size=(N, D)).astype(np.float32)
    y = (np.sin(X @ W[:, 0]) + 0.4 * (X @ W[:, 1])
         + 0.05 * rng.normal(size=N)).astype(np.float32)
    return split_machines(X, y, M, jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def base_fused():
    """One broadcast artifact on the fused serve path (pallas gram mode +
    cached Nyström serve factors) — the tenant-batched epilogue route."""
    art = fit(_parts(0), BITS, "broadcast", steps=STEPS,
              gram_backend="pallas")
    assert "Ainv" in art.factors  # precondition: fused epilogue applies
    return art


@pytest.fixture(scope="module")
def base_center():
    """One center-protocol artifact — the plain-vmap fleet route."""
    return fit(_parts(1), BITS, "center", steps=STEPS)


def _tenants(base, n, start=0.3, step=0.2):
    """n genuinely distinct same-bucket tenants via exact y-scaling."""
    return {i: scale_targets(base, start + step * i) for i in range(n)}


def _queries(S, seed=2):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(S, T_Q, D)).astype(np.float32)


# --------------------------------------------------------------------------
# equivalence: stacked dispatch == serial per-artifact loop
# --------------------------------------------------------------------------


@pytest.mark.parametrize("which", ["fused", "center"])
def test_stacked_predict_matches_serial(which, base_fused, base_center):
    base = base_fused if which == "fused" else base_center
    tenants = _tenants(base, 5)
    stack = FleetStack(tenants, slots=8)
    tids = [3, 0, 4, 1, 3]  # repeats allowed
    Xq = _queries(len(tids))
    mu_s, var_s = stack.predict(tids, Xq)
    for s, tid in enumerate(tids):
        mu_1, var_1 = predict(tenants[tid], Xq[s])
        np.testing.assert_allclose(np.asarray(mu_s[s]), np.asarray(mu_1),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(var_s[s]), np.asarray(var_1),
                                   rtol=2e-4, atol=2e-4)


def test_scale_targets_is_exact(base_fused, base_center):
    """scale_targets(art, c) == the posterior on c*y: the mean scales by c
    (linearity of alpha in y).  The center protocol's GP variance never
    depends on y, so it stays BITWISE unchanged; the broadcast KL fusion's
    moment-matched variance legitimately shifts with the expert means, so
    only the mean is checked there."""
    Xq = _queries(1)[0]
    mu0, var0 = predict(base_center, Xq)
    mu2, var2 = predict(scale_targets(base_center, -2.0), Xq)
    np.testing.assert_allclose(np.asarray(mu2), -2.0 * np.asarray(mu0),
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(var2), np.asarray(var0))
    mu0f, _ = predict(base_fused, Xq)
    mu2f, _ = predict(scale_targets(base_fused, -2.0), Xq)
    np.testing.assert_allclose(np.asarray(mu2f), -2.0 * np.asarray(mu0f),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# isolation: a bad tenant never perturbs its co-batched neighbors
# --------------------------------------------------------------------------


@pytest.mark.parametrize("which", ["fused", "center"])
def test_nan_query_tenant_is_isolated(which, base_fused, base_center):
    base = base_fused if which == "fused" else base_center
    tenants = _tenants(base, 3)
    stack = FleetStack(tenants, slots=4)
    tids = [0, 1, 2]
    Xq = _queries(3)
    mu_ref, var_ref = stack.predict(tids, Xq)
    hostile = Xq.copy()
    hostile[1] = np.nan  # tenant 1's whole request goes hostile
    mu_h, var_h = stack.predict(tids, hostile)
    # neighbors bitwise untouched
    for s in (0, 2):
        assert np.array_equal(np.asarray(mu_h[s]), np.asarray(mu_ref[s]))
        assert np.array_equal(np.asarray(var_h[s]), np.asarray(var_ref[s]))
    # the hostile tenant degrades to the prior (finite), not NaN
    assert np.isfinite(np.asarray(mu_h[1])).all()
    assert np.isfinite(np.asarray(var_h[1])).all()
    assert np.allclose(np.asarray(mu_h[1]), 0.0)


def test_degraded_mask_tenant_is_isolated(base_fused):
    tenants = _tenants(base_fused, 3)
    stack = FleetStack(tenants, slots=4)
    tids = [0, 1, 2]
    Xq = _queries(3)
    healthy = np.ones((3, M), np.float32)
    mu_ref, var_ref = stack.predict(tids, Xq, healthy)
    degraded = healthy.copy()
    degraded[1, 0] = 0.0  # tenant 1 loses machine 0
    mu_d, var_d = stack.predict(tids, Xq, degraded)
    for s in (0, 2):
        assert np.array_equal(np.asarray(mu_d[s]), np.asarray(mu_ref[s]))
        assert np.array_equal(np.asarray(var_d[s]), np.asarray(var_ref[s]))
    # the degraded tenant matches its own single-artifact degraded serve
    avail = degraded[1]
    mu_1, var_1 = predict(tenants[1], Xq[1], available=avail)
    np.testing.assert_allclose(np.asarray(mu_d[1]), np.asarray(mu_1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(var_d[1]), np.asarray(var_1),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# retrace-freedom: swaps and mix changes never recompile
# --------------------------------------------------------------------------


def test_tenant_swap_never_retraces(base_fused):
    tenants = _tenants(base_fused, 6)
    stack = FleetStack(dict(list(tenants.items())[:4]), slots=4)
    Xq = _queries(3)
    stack.predict([0, 1, 2], Xq)  # traces once
    c0 = fleet_trace_count("broadcast")
    stack.predict([2, 0, 3], Xq)          # new mix
    stack.admit(4, tenants[4])            # LRU eviction (stack is full)
    stack.admit(5, tenants[5])
    stack.predict([4, 5, 3], Xq)          # swapped-in tenants
    stack.admit(0, tenants[0])            # still resident: refresh, not swap
    stack.predict([0, 0, 0], Xq)
    assert fleet_trace_count("broadcast") == c0
    assert stack.swaps == 2  # admits of 4 and 5 evicted tenants 1 and 2


def test_stack_rejects_nonresident_and_heterogeneous(base_fused, base_center):
    stack = FleetStack(_tenants(base_fused, 2), slots=4)
    with pytest.raises(KeyError, match="not resident"):
        stack.predict([0, 99], _queries(2))
    with pytest.raises(ValueError, match="bucket-compatible"):
        stack.admit(7, base_center)
    with pytest.raises(ValueError, match="bucket-compatible"):
        stack_artifacts([base_fused, base_center])


def test_pad_to_capacity_cobuckets_streamed_artifacts(base_center):
    """A fresh fit (exact-size buffers) and a streamed artifact (grown
    buffers) land in different buckets until padded to a common capacity —
    and the padded artifact predicts identically."""
    rng = np.random.default_rng(3)
    Xn = rng.normal(size=(4, D)).astype(np.float32)
    yn = np.zeros(4, np.float32)
    streamed = update(base_center, Xn, yn, machine=0)
    assert bucket_key(streamed) != bucket_key(base_center)
    cap = int(streamed.y.shape[-1])
    fresh_padded = pad_to_capacity(base_center, cap)
    assert bucket_key(fresh_padded) == bucket_key(streamed)
    Xq = _queries(1)[0]
    mu0, var0 = predict(base_center, Xq)
    mu1, var1 = predict(fresh_padded, Xq)
    np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(var1), np.asarray(var0),
                               rtol=1e-5, atol=1e-5)
    stack = FleetStack({0: fresh_padded, 1: streamed})
    mu_s, _ = stack.predict([0, 1], _queries(2))
    assert np.isfinite(np.asarray(mu_s)).all()


# --------------------------------------------------------------------------
# cache plane: LRU, bytes, bitwise load-on-miss
# --------------------------------------------------------------------------


def test_cache_lru_eviction_and_load_on_miss(base_fused, tmp_path):
    from repro.core.protocols import load_artifact

    tenants = _tenants(base_fused, 4)
    store = ArtifactStore(str(tmp_path))
    for tid, art in tenants.items():
        store.save(tid, art)
    assert store.tenants() == sorted(str(t) for t in tenants)
    cache = ArtifactCache(store.load, capacity=2)
    cache.get(0), cache.get(1)
    cache.get(0)          # refresh 0: now 1 is LRU
    cache.get(2)          # evicts 1
    assert 1 not in cache and 0 in cache and 2 in cache
    assert (cache.hits, cache.misses, cache.evictions) == (1, 3, 1)
    # load-on-miss serves BITWISE identically to a direct checkpoint load
    art_c = cache.get(1)
    art_d = load_artifact(store.path(1))
    Xq = _queries(1)[0]
    mu_c, var_c = predict(art_c, Xq)
    mu_d, var_d = predict(art_d, Xq)
    assert np.array_equal(np.asarray(mu_c), np.asarray(mu_d))
    assert np.array_equal(np.asarray(var_c), np.asarray(var_d))
    # and the store's meta screen reads without touching arrays
    meta = store.meta(1)
    assert meta["protocol"] == "broadcast"


def test_cache_byte_capacity(base_fused):
    nb = artifact_nbytes(base_fused)
    tenants = _tenants(base_fused, 3)
    cache = ArtifactCache(lambda t: tenants[t], capacity_bytes=2 * nb)
    cache.get(0), cache.get(1)
    assert cache.total_bytes == 2 * nb
    cache.get(2)  # over budget -> evict LRU tenant 0
    assert 0 not in cache and cache.total_bytes == 2 * nb
    # a single artifact bigger than the budget is still kept (bounded, not
    # refused)
    tiny = ArtifactCache(lambda t: tenants[t], capacity_bytes=nb // 2)
    tiny.get(0)
    assert 0 in tiny and len(tiny) == 1


# --------------------------------------------------------------------------
# request plane: batcher, server, retry
# --------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_microbatcher_flushes_on_size_and_budget():
    clk = FakeClock()
    mb = MicroBatcher(slots=3, budget_ms=5.0, clock=clk)
    assert mb.add("a", 1) is None and mb.add("b", 2) is None
    batch = mb.add("c", 3)  # third request fills the slots
    assert [r.tenant for r in batch] == ["a", "b", "c"] and len(mb) == 0
    mb.add("d", 4)
    assert not mb.due()
    clk.t += 0.0049
    assert not mb.due()  # 4.9ms < 5ms budget
    clk.t += 0.0002
    assert mb.due()      # 5.1ms >= budget
    assert [r.tenant for r in mb.flush()] == ["d"]
    assert not mb.due()  # empty queue is never due


def test_fleet_server_end_to_end(base_fused, tmp_path):
    store, tids = build_fleet([base_fused], 10, str(tmp_path))
    clk = FakeClock()
    server = FleetServer(store, cache_artifacts=6, slots=3, budget_ms=5.0,
                         clock=clk)
    rng = np.random.default_rng(4)
    mk = lambda i: rng.normal(size=(T_Q, D)).astype(np.float32)
    stats = serve_loop(server, zipf_tenants(tids, 20, seed=1), mk)
    assert stats["completed"] == 20
    assert stats["cache"]["misses"] >= 6  # cold start + capacity pressure
    assert stats["requests"] == 20 and stats["stacks"] == 1
    # a ragged tail flush (padded to the fixed width) answers correctly
    out = server.submit(tids[0], mk(0))
    assert out == []
    server.batcher._queue[0].enqueued_at -= 1.0  # age it past the budget
    done = server.poll()
    assert len(done) == 1 and done[0][0] == tids[0]


def test_fleet_server_padded_tail_matches_direct(base_fused, tmp_path):
    """A partial flush is padded to the fixed width; the answer for the real
    request must match the tenant's direct single-artifact predict."""
    store, tids = build_fleet([base_fused], 4, str(tmp_path))
    server = FleetServer(store, cache_artifacts=4, slots=4, budget_ms=0.0)
    rng = np.random.default_rng(5)
    Xq = rng.normal(size=(T_Q, D)).astype(np.float32)
    server.submit(tids[2], Xq)
    (tid, mu, var, lat), = server.poll()  # budget 0 -> due immediately
    assert tid == tids[2] and lat >= 0.0
    mu_d, var_d = predict(store.load(tids[2]), Xq)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_d),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_d),
                               rtol=2e-4, atol=2e-4)


def test_retry_injectable_sleep():
    from repro.launch.serve_gp import _retry

    waits = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert _retry("t", flaky, attempts=4, backoff=0.5,
                  sleep=waits.append) == "ok"
    assert waits == [0.5, 1.0]  # exponential backoff, recorded not slept

    with pytest.raises(RuntimeError):
        _retry("t", lambda: (_ for _ in ()).throw(RuntimeError("hard")),
               attempts=2, backoff=0.25, sleep=waits.append)
    assert waits == [0.5, 1.0, 0.25]
