"""End-to-end behaviour tests for the full system."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import make_train_step, make_decode_step
from repro.models.steps import init_train_state
from repro.models.decode import init_decode_state
from repro.data import lm_batch_stream, regression_dataset, DATASET_SPECS
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step


def test_lm_training_loss_decreases_end_to_end():
    cfg = get_config("xlstm-125m").reduced()
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup=5, total_steps=40))
    stream = lm_batch_stream(cfg.vocab_size, batch=4, seq=64, seed=1)
    first = last = None
    for i in range(25):
        params, opt, m = step(params, opt, next(stream))
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.1, (first, last)


def test_decode_after_training_runs_greedy(tmp_path):
    cfg = get_config("gemma2-2b").reduced()
    params, _ = init_train_state(jax.random.PRNGKey(1), cfg)
    state = init_decode_state(cfg, 2, 32)
    step = jax.jit(make_decode_step(cfg))
    tok = jnp.zeros((2, 1), jnp.int32)
    toks = []
    for pos in range(8):
        tok, state = step(params, state, tok, jnp.int32(pos))
        toks.append(np.asarray(tok))
    toks = np.concatenate(toks, 1)
    assert toks.shape == (2, 8)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("xlstm-125m").reduced()
    params, opt = init_train_state(jax.random.PRNGKey(2), cfg)
    d = str(tmp_path)
    save_checkpoint(d, 7, params)
    assert latest_step(d) == 7
    restored = restore_checkpoint(d, 7, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_regression_data_matches_paper_specs():
    for name, (n_tr, n_te, d) in DATASET_SPECS.items():
        X_tr, y_tr, X_te, y_te = regression_dataset(name)
        assert X_tr.shape == (n_tr, d) and X_te.shape == (n_te, d)
        # normalized as in the paper: zero-mean unit-variance inputs
        np.testing.assert_allclose(X_tr.mean(0), 0.0, atol=1e-4)
        np.testing.assert_allclose(X_tr.std(0), 1.0, atol=1e-3)
        assert abs(float(y_tr.mean())) < 1e-4 * max(1.0, np.abs(y_tr).max())


def test_train_driver_cli(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "xlstm-125m",
         "--reduce", "--steps", "6", "--batch", "2", "--seq", "32",
         "--workdir", str(tmp_path), "--ckpt-every", "6", "--log-every", "2"],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss" in out.stdout
    assert latest_step(str(tmp_path)) == 6
    assert os.path.exists(os.path.join(str(tmp_path), "metrics.csv"))


def test_serve_driver_cli():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "xlstm-125m",
         "--reduce", "--batch", "2", "--prompt-len", "8", "--gen", "4"],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "generated token ids" in out.stdout


def test_dryrun_cli_single_combo():
    """The dry-run entry point itself, on the cheapest real combo."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
         "--shape", "long_500k"],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-2000:])
    assert "dom=" in out.stdout
