"""The §5 protocols end-to-end (simulated machines)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    split_machines, single_center_gp, broadcast_gp, poe_baseline, train_gp,
)


def _problem(seed=0, n=240, d=6, n_test=80):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda X: np.sin(X @ W[:, 0]) + 0.4 * (X @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    Xt = rng.normal(size=(n_test, d)).astype(np.float32)
    yt = f(Xt).astype(np.float32)
    return X, y, Xt, yt


def _smse(pred, yt):
    return float(np.mean((yt - np.asarray(pred)) ** 2) / np.var(yt))


def test_split_machines_partitions_everything():
    X, y, _, _ = _problem()
    parts = split_machines(X, y, 8, jax.random.PRNGKey(0))
    assert len(parts) == 8
    assert sum(p[0].shape[0] for p in parts) == X.shape[0]
    all_y = np.sort(np.concatenate([np.asarray(p[1]) for p in parts]))
    np.testing.assert_allclose(all_y, np.sort(y), rtol=1e-6)


def test_single_center_converges_to_full_gp_with_rate():
    X, y, Xt, yt = _problem(1)
    full = train_gp(X, y, kernel="se", steps=120)
    e_full = _smse(full.predict(Xt)[0], yt)
    parts = split_machines(X, y, 6, jax.random.PRNGKey(0))
    m_lo = single_center_gp(parts, 4, kernel="se", steps=120, gram_mode="direct")
    m_hi = single_center_gp(parts, 48, kernel="se", steps=120, gram_mode="direct")
    e_lo = _smse(m_lo.predict(Xt)[0], yt)
    e_hi = _smse(m_hi.predict(Xt)[0], yt)
    assert e_hi < e_lo  # more bits help
    assert e_hi < 1.35 * e_full + 0.02  # near full GP at ~8 bits/dim


def test_single_center_beats_zero_rate_baselines_at_moderate_rate():
    X, y, Xt, yt = _problem(2)
    parts = split_machines(X, y, 8, jax.random.PRNGKey(1))
    e_rbcm = _smse(poe_baseline(parts, Xt, kernel="se", method="rbcm", steps=120)[0], yt)
    m = single_center_gp(parts, 36, kernel="se", steps=120, gram_mode="direct")
    e_q = _smse(m.predict(Xt)[0], yt)
    assert e_q < e_rbcm  # the paper's headline claim (Figs. 5-6)


def test_wire_bits_accounting_scales_with_machines_and_rate():
    X, y, _, _ = _problem(3)
    parts = split_machines(X, y, 5, jax.random.PRNGKey(2))
    m8 = single_center_gp(parts, 8, kernel="linear", steps=5)
    m16 = single_center_gp(parts, 16, kernel="linear", steps=5)
    n_noncenter = sum(p[0].shape[0] for p in parts[1:])
    d = X.shape[1]
    assert m8.wire_bits == 8 * n_noncenter + 4 * 2 * d * d * 32
    assert m16.wire_bits == 16 * n_noncenter + 4 * 2 * d * d * 32


def test_broadcast_runs_and_fuses():
    X, y, Xt, yt = _problem(4, n=160)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(3))
    mu, s2, wire, p = broadcast_gp(parts, 24, Xt, kernel="se", steps=60)
    assert mu.shape == (Xt.shape[0],)
    assert np.all(np.asarray(s2) > 0)
    assert wire > 0
    assert _smse(mu, yt) < 1.0  # better than predicting the mean


def test_nystrom_vs_direct_gram_modes():
    X, y, Xt, yt = _problem(5)
    parts = split_machines(X, y, 6, jax.random.PRNGKey(4))
    m_nys = single_center_gp(parts, 64, kernel="se", steps=80, gram_mode="nystrom")
    m_dir = single_center_gp(parts, 64, kernel="se", steps=80, gram_mode="direct")
    e_n = _smse(m_nys.predict(Xt)[0], yt)
    e_d = _smse(m_dir.predict(Xt)[0], yt)
    # at high rate, direct should be at least as good (Nyström caps at rank K)
    assert e_d <= e_n * 1.1
