"""Quantized collectives on an 8-device host mesh (subprocess so the main
pytest process keeps 1 device, per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comm import q_all_gather, q_psum
from repro.compat import shard_map, make_mesh

mesh = make_mesh((8,), ("m",))
rng = np.random.default_rng(0)
d, n_loc = 12, 64
X = (rng.normal(size=(8 * n_loc, d)) @ (rng.normal(size=(d, d)) / np.sqrt(d))).astype(np.float32)

f = shard_map(lambda x: q_all_gather(x, "m", 36), mesh=mesh,
                  in_specs=P("m", None), out_specs=P("m", None))
out = np.asarray(jax.jit(f)(X))
view0 = out[:8]
own_exact = float(np.abs(view0[0] - X[:n_loc]).max())
others = float(np.mean((view0[1:].reshape(-1, d) - X[n_loc:8 * n_loc]) ** 2))
raw_var = float(np.mean(X ** 2))

errs = {}
g = rng.normal(size=(4096,)).astype(np.float32)
G = np.stack([g * (i + 1) for i in range(8)])
for bits in (4, 8):
    f2 = shard_map(lambda x, b=bits: q_psum(x[0], "m", b), mesh=mesh,
                       in_specs=P("m", None), out_specs=P(), check_vma=False)
    s = np.asarray(jax.jit(f2)(G))
    true = G.sum(0)
    errs[bits] = float(np.linalg.norm(s - true) / np.linalg.norm(true))

print(json.dumps({"own_exact": own_exact, "others_mse": others,
                  "raw_var": raw_var, "psum_err": errs}))
"""


@pytest.fixture(scope="module")
def comm_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_q_all_gather_own_block_exact(comm_results):
    assert comm_results["own_exact"] < 1e-5


def test_q_all_gather_peers_within_rate_distortion(comm_results):
    # 36 bits over 12 dims = 3 bits/dim: distortion well below signal power
    assert comm_results["others_mse"] < 0.5 * comm_results["raw_var"]
    assert comm_results["others_mse"] > 0  # actually quantized, not copied


def test_q_psum_error_decreases_with_bits(comm_results):
    errs = comm_results["psum_err"]
    assert errs["8"] < errs["4"] < 0.5
    assert errs["8"] < 0.1
