"""Quantized collectives on an 8-device host mesh (subprocess so the main
pytest process keeps 1 device, per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comm import q_all_gather, q_psum
from repro.compat import shard_map, make_mesh

mesh = make_mesh((8,), ("m",))
rng = np.random.default_rng(0)
d, n_loc = 12, 64
X = (rng.normal(size=(8 * n_loc, d)) @ (rng.normal(size=(d, d)) / np.sqrt(d))).astype(np.float32)

f = shard_map(lambda x: q_all_gather(x, "m", 36), mesh=mesh,
                  in_specs=P("m", None), out_specs=P("m", None))
out = np.asarray(jax.jit(f)(X))
view0 = out[:8]
own_exact = float(np.abs(view0[0] - X[:n_loc]).max())
others = float(np.mean((view0[1:].reshape(-1, d) - X[n_loc:8 * n_loc]) ** 2))
raw_var = float(np.mean(X ** 2))

errs = {}
g = rng.normal(size=(4096,)).astype(np.float32)
G = np.stack([g * (i + 1) for i in range(8)])
for bits in (4, 8):
    f2 = shard_map(lambda x, b=bits: q_psum(x[0], "m", b), mesh=mesh,
                       in_specs=P("m", None), out_specs=P(), check_vma=False)
    s = np.asarray(jax.jit(f2)(G))
    true = G.sum(0)
    errs[bits] = float(np.linalg.norm(s - true) / np.linalg.norm(true))

print(json.dumps({"own_exact": own_exact, "others_mse": others,
                  "raw_var": raw_var, "psum_err": errs}))
"""


@pytest.fixture(scope="module")
def comm_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_q_all_gather_own_block_exact(comm_results):
    assert comm_results["own_exact"] < 1e-5


def test_q_all_gather_peers_within_rate_distortion(comm_results):
    # 36 bits over 12 dims = 3 bits/dim: distortion well below signal power
    assert comm_results["others_mse"] < 0.5 * comm_results["raw_var"]
    assert comm_results["others_mse"] > 0  # actually quantized, not copied


def test_q_psum_error_decreases_with_bits(comm_results):
    errs = comm_results["psum_err"]
    assert errs["8"] < errs["4"] < 0.5
    assert errs["8"] < 0.1


# --------------------------------------------------------------------------
# in-process coverage (conftest's 8 forced host devices): bits edge cases,
# shard counts, ledger accounting, gradients
# --------------------------------------------------------------------------


def _mesh(m):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:m]), ("m",))


def _run_q_all_gather(m, n_loc, d, bits, seed=0):
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.comm import q_all_gather
    from repro.compat import shard_map

    rng = np.random.default_rng(seed)
    X = (rng.normal(size=(m * n_loc, d))
         @ (rng.normal(size=(d, d)) / np.sqrt(d))).astype(np.float32)
    fn = shard_map(lambda x: q_all_gather(x, "m", bits), mesh=_mesh(m),
                   in_specs=P("m", None), out_specs=P("m", None),
                   check_vma=False)
    return X, np.asarray(jax.jit(fn)(X)).reshape(m, m, n_loc, d)


@pytest.mark.parametrize("m", [2, 4, 8])
def test_q_all_gather_shard_counts(m):
    """Own block exact and peers genuinely quantized for 2/4/8 shards."""
    import numpy as np

    n_loc, d = 16, 6
    X, out = _run_q_all_gather(m, n_loc, d, bits=18)
    blocks = X.reshape(m, n_loc, d)
    for i in range(m):
        np.testing.assert_array_equal(out[i, i], blocks[i])  # own block exact
    if m > 1:
        peer_mse = np.mean((out[0, 1:] - blocks[1:]) ** 2)
        assert 0 < peer_mse < np.mean(X**2)


@pytest.mark.parametrize("bits", [1, 8, 32])
def test_q_all_gather_bits_edges(bits):
    """1 bit/sample (minimum rate), 8, and a 32-bit budget all decode to
    finite blocks whose distortion decreases with rate."""
    import numpy as np

    X, out = _run_q_all_gather(4, 16, 6, bits=bits)
    assert np.all(np.isfinite(out))
    blocks = X.reshape(4, 16, 6)
    mse = np.mean((out[0, 1:] - blocks[1:]) ** 2)
    if bits == 1:
        assert mse > 0
    if bits == 32:
        assert mse < 0.5 * np.mean(X**2)


def test_q_all_gather_state_ledger_matches_formula():
    """The return_state ledgers: ``wire_bits`` equals rates.sum() * n_valid +
    side_info_bits(d) per transmitting shard, ``payload_bits`` — measured
    from the packed word buffer the collective moved — equals the shared
    payload formula EXACTLY (whole uint32 words per valid row), and masked
    rows pack to all-zero words, unpack to -1 sentinels, and are neither
    decoded nor charged."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.comm import q_all_gather
    from repro.comm.accounting import payload_bits_formula, side_info_bits
    from repro.compat import shard_map
    from repro.core import jax_scheme

    m, n_loc, d = 4, 12, 5
    bits = 15
    rng = np.random.default_rng(1)
    X = rng.normal(size=(m * n_loc, d)).astype(np.float32)
    mask = np.ones((m, n_loc), np.float32)
    mask[1, 9:] = 0.0  # machine 1 is ragged: 9 valid rows
    mask[3, 6:] = 0.0

    fn = shard_map(
        lambda x, mk: q_all_gather(x, "m", bits, mask=mk[0], return_state=True)[1],
        mesh=_mesh(m), in_specs=(P("m", None), P("m", None)), out_specs=P(),
        check_vma=False,
    )
    st = jax.jit(fn)(X, mask)
    rates = np.asarray(st["rates"])
    n_valid = mask.sum(axis=1).astype(int)
    expect = sum(int(rates[j].sum()) * int(n_valid[j]) + side_info_bits(d)
                 for j in range(m))
    assert int(st["wire_bits"]) == expect
    # physical payload: measured == formula, and == ledger + per-word padding
    lengths = [int(v) for v in n_valid]
    assert int(st["payload_bits"]) == payload_bits_formula(lengths, d, bits, 8)
    words = np.asarray(st["codes"])
    W = words.shape[-1]
    pad = sum((32 * W - int(rates[j].sum())) * lengths[j] for j in range(m))
    assert int(st["payload_bits"]) == int(st["wire_bits"]) + pad
    # the wire is packed uint32 words; masked rows are all-zero words that
    # unpack to -1 sentinels and decode to zero
    assert words.dtype == np.uint32 and W == (bits + 31) // 32
    dec = np.asarray(st["decoded"])
    assert np.all(words[1, 9:] == 0) and np.all(dec[1, 9:] == 0.0)
    assert np.all(words[3, 6:] == 0) and np.all(dec[3, 6:] == 0.0)
    codes = np.asarray(jax.vmap(
        lambda w, r, mk: jax_scheme.unpack_codes(w, r, total_bits=bits, mask=mk)
    )(st["codes"], st["rates"], st["mask"]))
    assert np.all(codes[1, 9:] == -1) and np.all(codes[3, 6:] == -1)
    assert np.all(codes[:, :6] >= 0)  # valid rows carry real codes


def test_wire_bits_all_gather_accounting():
    """Both comm ledger call sites charge the ONE shared side-info formula."""
    from repro.comm import wire_bits_all_gather
    from repro.comm.accounting import side_info_bits

    q, base = wire_bits_all_gather(n_per_shard=100, d=8, bits=24, n_shards=4)
    assert q == 100 * 24 + side_info_bits(8)
    assert q == 100 * 24 + 2 * 8 * 8 * 32  # the paper's O(2 d^2) exchange
    assert base == 100 * 8 * 32
    assert q < base  # the point of the paper


def test_ledger_call_sites_integer_equal():
    """The q_all_gather return_state ledger and the wire_bits_all_gather
    formula are the same accounting: summed over shards they agree exactly
    (uniform shards, no mask)."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.comm import q_all_gather, wire_bits_all_gather
    from repro.compat import shard_map

    m, n_loc, d, bits = 4, 16, 6, 21
    rng = np.random.default_rng(3)
    X = rng.normal(size=(m * n_loc, d)).astype(np.float32)
    fn = shard_map(
        lambda x: q_all_gather(x, "m", bits, return_state=True)[1],
        mesh=_mesh(m), in_specs=P("m", None), out_specs=P(), check_vma=False,
    )
    st = jax.jit(fn)(X)
    # wire_bits_all_gather charges bits/sample * n + side info per shard; the
    # collective's ledger is that same number summed over all m shards
    # (greedy allocation hands out exactly `bits` per sample here, and
    # wire_bits_all_gather's n_per_shard counts samples * bits-per-sample as
    # its per-shard code payload via n * bits)
    rates = np.asarray(st["rates"])
    assert (rates.sum(axis=1) == bits).all()
    per_shard, _ = wire_bits_all_gather(n_per_shard=n_loc, d=d, bits=bits,
                                        n_shards=m)
    assert int(st["wire_bits"]) == m * per_shard


def test_q_psum_fp_fallback_is_exact():
    """bits >= 32 is the fp fallback: an exact lax.psum."""
    import jax
    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.comm import q_psum
    from repro.compat import shard_map

    m = 4
    G = np.stack([np.linspace(-1, 1, 128).astype(np.float32) * (i + 1)
                  for i in range(m)])
    fn = shard_map(lambda x: q_psum(x[0], "m", 32), mesh=_mesh(m),
                   in_specs=P("m", None), out_specs=P(), check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(fn)(jnp.asarray(G))),
                               G.sum(0), rtol=1e-6)


@pytest.mark.parametrize("m", [2, 4, 8])
def test_q_psum_gradient_straight_through(m):
    """jax.grad flows through q_psum: at bits=32 (exact fallback) gradients
    match the exact-psum gradients; at bits=8 the straight-through VJP gives
    finite gradients aligned with the exact ones (the quantizer's
    zero-derivative staircase must not zero them out)."""
    import jax
    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.comm import q_psum
    from repro.compat import shard_map

    rng = np.random.default_rng(m)
    G = jnp.asarray(rng.normal(size=(m, 256)).astype(np.float32))

    def loss(bits):
        body = lambda x: jnp.sum(q_psum(x[0], "m", bits) ** 2)[None]
        fn = shard_map(body, mesh=_mesh(m), in_specs=P("m", None),
                       out_specs=P("m"), check_vma=False)
        return lambda x: jnp.sum(fn(x)) / m

    g_exact = jax.grad(lambda x: jnp.sum(jnp.sum(x, 0) ** 2))(G)
    g32 = jax.grad(jax.jit(loss(32)))(G)
    np.testing.assert_allclose(np.asarray(g32), np.asarray(g_exact),
                               rtol=1e-4, atol=1e-4)
    g8 = jax.grad(jax.jit(loss(8)))(G)
    g8, ge = np.asarray(g8), np.asarray(g_exact)
    assert np.all(np.isfinite(g8)) and np.linalg.norm(g8) > 0
    cos = float((g8 * ge).sum() / (np.linalg.norm(g8) * np.linalg.norm(ge)))
    assert cos > 0.95
    # and the MAGNITUDE matches too — the bwd must psum the cotangent, else
    # gradients come out 1/m of the exact reduce (scale-blind cosine passes)
    ratio = float(np.linalg.norm(g8) / np.linalg.norm(ge))
    assert 0.8 < ratio < 1.2
