"""Checkpoint back-compat: pre-redesign artifacts keep loading.

``tests/fixtures/legacy_artifact/`` is a committed format-version-1
checkpoint (PR-3 era ``meta.json``: no ``config``, ``scheme`` or
``format_version`` keys) of a tiny §5.1 center fit, plus the predictions the
original artifact produced (``expected.npz``).  Locked here:

  * ``load_artifact`` reads it, defaults the scheme to ``per_symbol``, and
    reconstructs a ``DGPConfig`` from the legacy metadata;
  * predictions from the restored artifact match the recorded ones bitwise
    (the serve path is unchanged by the metadata upgrade);
  * re-saving writes a format-version-2 checkpoint (config recorded) that
    round-trips bitwise.
"""
import json
import os

import numpy as np
import pytest

from repro.core import DGPConfig, DistributedGP
from repro.core.config import ARTIFACT_FORMAT_VERSION
from repro.core.protocols import load_artifact, predict, save_artifact, update

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "legacy_artifact")


def _expected():
    z = np.load(os.path.join(FIXTURE, "expected.npz"))
    return z["Xt"], z["mu"], z["s2"]


def test_fixture_is_actually_legacy_format():
    with open(os.path.join(FIXTURE, "meta_00000000.json")) as f:
        meta = json.load(f)
    for key in ("format_version", "scheme", "config"):
        assert key not in meta


def test_legacy_artifact_loads_with_reconstructed_config():
    art = load_artifact(FIXTURE)
    assert art.scheme == "per_symbol"
    assert isinstance(art.config, DGPConfig)
    assert art.config.protocol == art.protocol == "center"
    assert art.config.bits_per_sample == art.bits_per_sample == 8
    assert art.config.kernel == art.kernel
    assert art.config.impl == "batched"
    # training knobs were never recorded pre-redesign: defaults
    assert art.config.steps == DGPConfig().steps
    # the legacy int32 code plane is packed on load — every restored
    # artifact carries the one shared wire representation; the payload was
    # never measured pre-v3, so its ledger stays 0
    assert art.wire.codes.dtype == np.uint32
    assert art.payload_bits == 0
    Xt, mu_exp, s2_exp = _expected()
    mu, s2 = predict(art, Xt)
    np.testing.assert_array_equal(np.asarray(mu), mu_exp)
    np.testing.assert_array_equal(np.asarray(s2), s2_exp)


def test_legacy_artifact_roundtrips_to_current_format(tmp_path):
    art = load_artifact(FIXTURE)
    save_artifact(art, str(tmp_path))
    with open(os.path.join(str(tmp_path), "meta_00000000.json")) as f:
        meta = json.load(f)
    assert meta["format_version"] == ARTIFACT_FORMAT_VERSION
    assert meta["scheme"] == "per_symbol"
    assert meta["config"]["protocol"] == "center"
    art2 = load_artifact(str(tmp_path))
    assert art2.config == art.config
    Xt, mu_exp, s2_exp = _expected()
    mu, s2 = predict(art2, Xt)
    np.testing.assert_array_equal(np.asarray(mu), mu_exp)
    np.testing.assert_array_equal(np.asarray(s2), s2_exp)


def test_legacy_artifact_supports_streaming_and_facade():
    """The restored artifact is a full citizen: the facade serves it and
    update() keeps charging the frozen per-machine rate to the ledger."""
    art = load_artifact(FIXTURE)
    est = DistributedGP(art.config)
    Xt, mu_exp, _ = _expected()
    mu, _ = est.predict(art, Xt)
    np.testing.assert_array_equal(np.asarray(mu), mu_exp)
    rng = np.random.default_rng(0)
    Xn = rng.normal(size=(4, Xt.shape[1])).astype(np.float32)
    art2 = update(art, Xn, np.zeros(4, np.float32), machine=1)
    rate = int(np.asarray(art.wire.rates[1]).sum())
    assert art2.wire_bits == art.wire_bits + 4 * rate
    mu2, s22 = predict(art2, Xt)
    assert np.all(np.isfinite(np.asarray(mu2))) and np.all(np.asarray(s22) > 0)
