"""Unit + property tests for the per-symbol quantizer (paper §4.2)."""
import itertools

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import quantizers as Q


def test_bin_edges_are_quantiles():
    for r in [1, 2, 3, 6]:
        e = Q.gauss_bin_edges(r)
        assert e.shape == (2**r - 1,)
        assert np.all(np.diff(e) > 0)
        # symmetric
        np.testing.assert_allclose(e, -e[::-1], atol=1e-12)


def test_centroids_zero_mean_and_symmetric():
    for r in [1, 2, 5]:
        c = Q.gauss_centroids(r)
        assert c.shape == (2**r,)
        np.testing.assert_allclose(c.mean(), 0.0, atol=1e-12)
        np.testing.assert_allclose(c, -c[::-1], atol=1e-10)


def test_r1_centroids_match_half_normal():
    # 1-bit quantizer of N(0,1): centroids +- sqrt(2/pi)
    c = Q.gauss_centroids(1)
    np.testing.assert_allclose(sorted(c), [-np.sqrt(2 / np.pi), np.sqrt(2 / np.pi)], rtol=1e-9)


def test_unit_distortion_decreasing():
    es = [Q.unit_distortion(r) for r in range(11)]
    assert es[0] == pytest.approx(1.0)
    assert all(a > b for a, b in zip(es, es[1:]))


def test_distortion_matches_empirical():
    rng = np.random.default_rng(3)
    u = rng.normal(size=400_000)
    for r in [1, 2, 4]:
        edges = Q.gauss_bin_edges(r)
        cents = Q.gauss_centroids(r)
        codes = np.searchsorted(edges, u)
        emp = np.mean((u - cents[codes]) ** 2)
        assert emp == pytest.approx(Q.unit_distortion(r), rel=0.02)


def test_greedy_matches_bruteforce_small():
    rng = np.random.default_rng(1)
    var = rng.uniform(0.1, 3.0, size=3)
    R = 6

    def total_e(alloc):
        return sum(Q.expected_distortion(v, r) for v, r in zip(var, alloc))

    best = min(
        (a for a in itertools.product(range(R + 1), repeat=3) if sum(a) == R),
        key=total_e,
    )
    greedy = Q.allocate_bits_greedy(var, R)
    assert sum(greedy) == R
    assert total_e(greedy) == pytest.approx(total_e(best), rel=1e-9)


@given(
    st.lists(st.floats(0.01, 10.0), min_size=2, max_size=8),
    st.integers(0, 32),
)
@settings(max_examples=30, deadline=None)
def test_greedy_allocates_all_bits_to_larger_variances_first(vars_, R):
    var = np.asarray(vars_)
    rates = Q.allocate_bits_greedy(var, R, max_bits=12)
    assert rates.sum() == min(R, 12 * len(var))
    # monotone: a dimension with strictly larger variance never gets fewer bits
    order = np.argsort(-var)
    sorted_rates = rates[order]
    sorted_vars = var[order]
    for i in range(len(var) - 1):
        if sorted_vars[i] > sorted_vars[i + 1] + 1e-12:
            assert sorted_rates[i] >= sorted_rates[i + 1]


@given(st.integers(0, 6), st.floats(0.1, 5.0))
@settings(max_examples=20, deadline=None)
def test_quantize_dequantize_roundtrip_bounded(rate, sigma):
    rng = np.random.default_rng(rate)
    x = (rng.normal(size=(200, 1)) * sigma).astype(np.float32)
    rates = np.array([rate], dtype=np.int32)
    edges, cents = Q.build_codebook_tables(max(rate, 1))
    codes = Q.quantize(jnp.asarray(x), jnp.asarray([sigma], jnp.float32), jnp.asarray(rates), edges)
    assert int(codes.max()) <= 2**rate - 1 and int(codes.min()) >= 0
    xh = Q.dequantize(codes, jnp.asarray([sigma], jnp.float32), jnp.asarray(rates), cents)
    emp = float(np.mean((x - np.asarray(xh)) ** 2))
    # within 4x of the theoretical distortion (finite sample) and never worse
    # than the zero-rate distortion by a wide margin
    assert emp <= 4.0 * max(Q.expected_distortion(sigma**2, rate), 1e-6) + 0.05 * sigma**2
