import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def rand_cov(rng, d, scale=1.0):
    A = rng.normal(size=(d, d))
    return scale * (A @ A.T) / d


@pytest.fixture
def cov_pair(rng):
    d = 12
    return rand_cov(rng, d), rand_cov(rng, d), d
