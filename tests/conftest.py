import os

# Give in-process tests a multi-device CPU platform.  This must run before the
# first jax import (conftest is imported before any test module).  Subprocess
# tests (test_comm / test_mesh_gp / test_qcomm) overwrite XLA_FLAGS themselves,
# and repro.launch.dryrun only forces its 512 placeholder devices under
# __main__ (force_placeholder_devices), so importing it never stomps this
# setting.  In-process mesh tests (test_conformance, the in-process halves of
# test_comm) rely on these 8 devices.
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def rand_cov(rng, d, scale=1.0):
    A = rng.normal(size=(d, d))
    return scale * (A @ A.T) / d


@pytest.fixture
def cov_pair(rng):
    d = 12
    return rand_cov(rng, d), rand_cov(rng, d), d


@pytest.fixture
def strict_device_guard():
    """Run the guarded block under jax's strictest runtime modes: any IMPLICIT
    host<->device transfer (a numpy array silently crossing into a jitted
    program, a traced value concretized on host) and any implicit dtype
    promotion raise instead of silently costing a sync / widening to f64.

    The warm-serve and streaming-update paths must pass under both — they are
    the runtime complement of the jaxpr-level contracts in repro.analysis
    (``check_contracts`` proves no callback primitive is IN the program; this
    proves the dispatch loop AROUND the program moves nothing by accident).
    Explicit jax.device_put/device_get remain allowed.
    """
    import jax

    with jax.transfer_guard("disallow"), \
            jax.numpy_dtype_promotion("strict"):
        yield
