"""Cross-implementation conformance: the three wire-protocol execution paths
— ``impl="host"`` (serial scipy oracle), ``impl="batched"`` (one vmapped jit),
``impl="mesh"`` (machines are devices; the wire is ``repro.comm`` collectives
inside shard_map programs) — driven through shared fixtures.

Locked invariants:
  * wire-bit ledgers are INTEGER-IDENTICAL across all three impls for all
    three protocols (the mesh ledger is computed from what the collective
    actually moves, the host ledger from the paper's §4 formula);
  * reconstructions and predictions match across impls within float
    tolerance (mesh vs batched is the same f32 math, so tight; vs the
    float64 scipy oracle, looser);
  * ``fit(impl="mesh")`` artifacts: factors live SHARDED along the machine
    mesh axis, predict() is structurally factorization-free and retrace-free
    warm, predictions match the single-host artifact, and the checkpoint
    round-trips to a single-host artifact that serves identically;
  * hypothesis sweeps over m, ragged shard sizes, d, bits ∈ {1..8, 32} and
    kernel ∈ {se, linear} (skipped cleanly without the optional dev dep).

The mesh paths run IN-PROCESS on the conftest's 8 forced host devices.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    split_machines, single_center_gp, broadcast_gp, poe_baseline,
    fit, predict, update, save_artifact, load_artifact,
)
from repro.core.distributed_gp import (
    quantize_to_center,
    predict_op_counts,
    serve_trace_count,
    MESH_AXIS,
)
from repro.analysis import check_contracts, retrace_budget

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    hypothesis = None

    def given(*a, **k):
        def deco(f):
            return pytest.mark.skip(
                reason="hypothesis not installed (requirements-dev.txt)"
            )(f)
        return deco

    def settings(*a, **k):
        return lambda f: f

    class st:  # placeholder strategies, never drawn when skipped
        integers = sampled_from = lists = staticmethod(lambda *a, **k: None)


# --------------------------------------------------------------------------
# shared fixtures
# --------------------------------------------------------------------------


def _ragged_parts(lengths, d, seed=0, n_test=24):
    """Machine shards with EXPLICIT ragged sizes (exercises the padded-shard
    masks / -1 sentinels / per-machine ledger slices on every impl)."""
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    parts = []
    for n_j in lengths:
        Xj = rng.normal(size=(n_j, d)).astype(np.float32)
        yj = (f(Xj) + 0.05 * rng.normal(size=n_j)).astype(np.float32)
        parts.append((jnp.asarray(Xj), jnp.asarray(yj)))
    Xt = rng.normal(size=(n_test, d)).astype(np.float32)
    return parts, jnp.asarray(Xt)


def _problem(seed=0, n=180, d=6, m=4, n_test=30):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    Xt = rng.normal(size=(n_test, d)).astype(np.float32)
    parts = split_machines(X, y, m, jax.random.PRNGKey(seed))
    return parts, jnp.asarray(Xt)


def _max_abs(a, b):
    return float(jnp.max(jnp.abs(jnp.asarray(a) - jnp.asarray(b)))) if np.size(np.asarray(a)) else 0.0


# --------------------------------------------------------------------------
# wire level: quantize_to_center across all three impls
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "lengths,d,bits",
    [
        ((37, 41, 29, 43), 6, 16),
        ((12, 30, 18), 4, 1),       # 1 bit/sample: the minimum-rate edge
        ((25, 25, 25, 25, 20), 5, 32),  # high rate, 5 machines
    ],
)
def test_quantize_to_center_three_impls(lengths, d, bits):
    parts, _ = _ragged_parts(lengths, d, seed=hash((lengths, d, bits)) % 2**31)
    Xh, yh, wh, nch, sqh = quantize_to_center(parts, bits, impl="host")
    Xb, yb, wb, ncb, sqb = quantize_to_center(parts, bits, impl="batched")
    Xm, ym, wm, ncm, sqm = quantize_to_center(parts, bits, impl="mesh")
    # ledger: exact integer equality, all three impls
    assert wh == wb == wm
    assert nch == ncb == ncm
    np.testing.assert_array_equal(np.asarray(yb), np.asarray(yh))
    np.testing.assert_array_equal(np.asarray(ym), np.asarray(yh))
    # mesh and batched run the same f32 program (collectives vs vmap)
    assert _max_abs(Xm, Xb) <= 1e-6
    np.testing.assert_allclose(np.asarray(sqm), np.asarray(sqb), rtol=1e-6)
    # both match the float64 scipy oracle within decode tolerance
    np.testing.assert_allclose(np.asarray(Xb), np.asarray(Xh), atol=5e-4)
    np.testing.assert_allclose(np.asarray(Xm), np.asarray(Xh), atol=5e-4)


# --------------------------------------------------------------------------
# protocol level: fit + predict across all three impls
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kernel", ["se", "linear"])
def test_center_protocol_three_impls(kernel):
    parts, Xt = _ragged_parts((31, 44, 27, 38), 6, seed=1)
    m_h = single_center_gp(parts, 16, kernel=kernel, steps=10, impl="host",
                           train_impl="loop")
    m_b = single_center_gp(parts, 16, kernel=kernel, steps=10)
    m_m = single_center_gp(parts, 16, kernel=kernel, steps=10, impl="mesh")
    assert m_h.wire_bits == m_b.wire_bits == m_m.wire_bits
    mu_h, v_h = m_h.predict(Xt)
    mu_b, v_b = m_b.predict(Xt)
    mu_m, v_m = m_m.predict(Xt)
    assert _max_abs(mu_m, mu_b) <= 5e-4  # same f32 protocol, two substrates
    assert _max_abs(v_m, v_b) <= 5e-4
    np.testing.assert_allclose(np.asarray(mu_m), np.asarray(mu_h), atol=3e-3)
    np.testing.assert_allclose(np.asarray(v_m), np.asarray(v_h), atol=3e-3)


@pytest.mark.parametrize("kernel,fuse", [("se", "kl"), ("linear", "kl"), ("se", "rbcm")])
def test_broadcast_protocol_three_impls(kernel, fuse):
    parts, Xt = _ragged_parts((33, 41, 28, 36), 6, seed=2)
    mu_h, s2_h, w_h, _ = broadcast_gp(parts, 24, Xt, kernel=kernel, steps=10,
                                      fuse=fuse, impl="host", train_impl="loop")
    mu_b, s2_b, w_b, _ = broadcast_gp(parts, 24, Xt, kernel=kernel, steps=10,
                                      fuse=fuse)
    mu_m, s2_m, w_m, _ = broadcast_gp(parts, 24, Xt, kernel=kernel, steps=10,
                                      fuse=fuse, impl="mesh")
    assert w_h == w_b == w_m
    assert _max_abs(mu_m, mu_b) <= 1e-3
    assert _max_abs(s2_m, s2_b) <= 1e-3
    np.testing.assert_allclose(np.asarray(mu_m), np.asarray(mu_h), atol=5e-3)
    np.testing.assert_allclose(np.asarray(s2_m), np.asarray(s2_h), atol=5e-3)
    assert np.all(np.asarray(s2_m) > 0)


@pytest.mark.parametrize("method", ["rbcm", "poe"])
def test_poe_three_impls(method):
    parts, Xt = _ragged_parts((26, 35, 30, 24), 5, seed=3)
    mu_h, s2_h, _ = poe_baseline(parts, Xt, method=method, steps=10,
                                 impl="host", train_impl="loop")
    mu_b, s2_b, _ = poe_baseline(parts, Xt, method=method, steps=10)
    mu_m, s2_m, _ = poe_baseline(parts, Xt, method=method, steps=10, impl="mesh")
    assert _max_abs(mu_m, mu_b) <= 1e-3
    assert _max_abs(s2_m, s2_b) <= 1e-3
    np.testing.assert_allclose(np.asarray(mu_m), np.asarray(mu_h), atol=5e-3)
    np.testing.assert_allclose(np.asarray(s2_m), np.asarray(s2_h), atol=5e-3)


# --------------------------------------------------------------------------
# physical-equals-ledger: the packed payload vs the Theorem-1 formula
# --------------------------------------------------------------------------


def _exact_padding(art):
    """The only admissible payload-vs-ledger slack: per-word padding —
    sum_j n_j * (32 W - rates_j.sum()) over transmitting machines."""
    W = art.wire.codes.shape[-1]
    rates = np.asarray(art.wire.rates)
    skip = art.block_order[0] if art.protocol == "center" else None
    return sum(
        (32 * W - int(rates[j].sum())) * n_j
        for j, n_j in enumerate(art.lengths) if j != skip
    )


@pytest.mark.parametrize("protocol", ["center", "broadcast", "poe"])
def test_payload_equals_ledger_three_impls(protocol):
    """The acceptance contract of the packed wire: for every protocol x
    {host, batched, mesh}, the measured bits of the packed collective payload
    are integer-identical across impls and equal the Theorem-1 ledger up to
    EXACTLY the per-word padding (no other slack)."""
    from repro.core.config import DGPConfig
    from repro.core.registry import PROTOCOLS

    parts, _ = _ragged_parts((29, 37, 23, 31), 6, seed=11)
    bits = 0 if protocol == "poe" else 19
    art_b = fit(parts, bits, protocol, steps=2)
    art_m = fit(parts, bits, protocol, steps=2, impl="mesh")
    cfg_h = DGPConfig(
        protocol=protocol, bits_per_sample=bits, steps=2, impl="host",
        train_impl="loop",
        gram_mode="dense" if protocol == "poe" else "nystrom",
        fusion="rbcm" if protocol == "poe" else "kl",
    )
    host = PROTOCOLS.get(protocol).fit_host(parts, cfg_h)
    host_payload = getattr(host, "payload_bits", 0)
    assert art_b.payload_bits == art_m.payload_bits == host_payload
    assert art_b.wire_bits == art_m.wire_bits
    # the CRC framing ledger: integer-identical across impls and equal to the
    # accounting formula (CRC_BITS per transmitted row, n_j == 0 skipped)
    from repro.comm.accounting import integrity_bits_formula

    host_integrity = getattr(host, "integrity_bits", 0)
    assert art_b.integrity_bits == art_m.integrity_bits == host_integrity
    if protocol == "poe":  # zero-rate: no wire, no payload, no framing
        assert art_b.payload_bits == art_b.wire_bits == 0
        assert art_b.integrity_bits == 0
        return
    skip = art_b.block_order[0] if protocol == "center" else None
    assert art_b.integrity_bits == integrity_bits_formula(
        art_b.lengths, skip=skip
    )
    assert art_b.payload_bits == art_b.wire_bits + _exact_padding(art_b)
    # the wire state all three consumers share really is the packed plane
    assert art_b.wire.codes.dtype == jnp.uint32
    assert art_m.wire.codes.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(art_m.wire.codes), np.asarray(art_b.wire.codes)
    )


def test_payload_streams_through_update():
    """update() extends BOTH ledgers: the Theorem-1 charge at the frozen rate
    and the physical charge in whole packed words."""
    parts, Xt = _ragged_parts((24, 31, 27), 5, seed=12)
    art = fit(parts, 17, "broadcast", steps=2)
    W = art.wire.codes.shape[-1]
    rng = np.random.default_rng(0)
    Xn = rng.normal(size=(9, 5)).astype(np.float32)
    art2 = update(art, Xn, np.zeros(9, np.float32), machine=1)
    rate1 = int(np.asarray(art.wire.rates[1]).sum())
    assert art2.wire_bits == art.wire_bits + 9 * rate1
    assert art2.payload_bits == art.payload_bits + 9 * 32 * W
    mu, s2 = predict(art2, Xt)
    assert np.all(np.isfinite(np.asarray(mu))) and np.all(np.asarray(s2) > 0)


def test_packed_artifact_bitwise_equals_unpacked_v2(tmp_path):
    """A format-v2 checkpoint (unpacked int32 codes) restores to the SAME
    artifact as its packed v3 twin: bitwise-identical predictions and an
    identical in-memory packed wire plane."""
    import json
    import os

    from repro.core import jax_scheme

    parts, Xt = _problem(seed=13, m=3, n=120, d=5)
    art = fit(parts, 18, "center", steps=3)
    d3 = str(tmp_path / "v3")
    save_artifact(art, d3)

    # rewrite the checkpoint as a v2 artifact: unpack the code plane back to
    # the legacy int32 (-1-sentinel) layout and stamp format_version 2
    d2 = str(tmp_path / "v2")
    os.makedirs(d2)
    arrays = dict(np.load(os.path.join(d3, "ckpt_00000000.npz")))
    with open(os.path.join(d3, "meta_00000000.json")) as f:
        meta = json.load(f)
    n_pad = arrays["wire/decoded"].shape[1]
    mask = jnp.asarray(
        np.arange(n_pad)[None, :] < np.asarray(art.lengths)[:, None], jnp.float32
    )
    arrays["wire/codes"] = np.asarray(jax.vmap(
        lambda w, r, mk: jax_scheme.unpack_codes(
            w, r, total_bits=18, mask=mk
        )
    )(jnp.asarray(arrays["wire/codes"]), jnp.asarray(arrays["wire/rates"]), mask))
    meta["format_version"] = 2
    del meta["payload_bits"]
    del meta["array_checksums"]  # v4-only: a real v2 artifact has no table
    np.savez(os.path.join(d2, "ckpt_00000000.npz"), **arrays)
    with open(os.path.join(d2, "meta_00000000.json"), "w") as f:
        json.dump(meta, f)

    art3 = load_artifact(d3)
    art2 = load_artifact(d2)
    # v2 codes are packed on load: identical plane, bitwise-identical serving
    assert art2.wire.codes.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(art2.wire.codes), np.asarray(art3.wire.codes)
    )
    mu3, s3 = predict(art3, Xt)
    mu2, s2 = predict(art2, Xt)
    np.testing.assert_array_equal(np.asarray(mu2), np.asarray(mu3))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s3))


# --------------------------------------------------------------------------
# the mesh serving artifact: sharded factors, shard_map serve, checkpointing
# --------------------------------------------------------------------------


def test_mesh_factors_sharded_along_machine_axis():
    parts, _ = _problem(seed=4, m=4)
    art = fit(parts, 24, "broadcast", steps=4, impl="mesh")
    for leaf in jax.tree_util.tree_leaves(art.factors):
        assert leaf.sharding.spec[0] == MESH_AXIS
    assert art.data["Xs"].sharding.spec[0] == MESH_AXIS
    art_p = fit(parts, 0, "poe", steps=4, impl="mesh")
    for leaf in jax.tree_util.tree_leaves(art_p.factors):
        assert leaf.sharding.spec[0] == MESH_AXIS


@pytest.mark.parametrize("protocol", ["center", "broadcast", "poe"])
def test_mesh_artifact_matches_single_host_and_roundtrips(tmp_path, protocol):
    """The acceptance contract: fit(impl="mesh") serves within tolerance of
    the single-host artifact, and its checkpoint round-trips to a single-host
    artifact with identical ledger and matching predictions."""
    parts, Xt = _problem(seed=5, m=4)
    bits = 0 if protocol == "poe" else 20
    art_b = fit(parts, bits, protocol, steps=6)
    art_m = fit(parts, bits, protocol, steps=6, impl="mesh")
    assert art_m.impl == "mesh"
    assert art_m.wire_bits == art_b.wire_bits
    mu_b, s2_b = predict(art_b, Xt)
    mu_m, s2_m = predict(art_m, Xt)
    assert _max_abs(mu_m, mu_b) <= 1e-3
    assert _max_abs(s2_m, s2_b) <= 1e-3

    d = str(tmp_path)
    save_artifact(art_m, d)
    art_l = load_artifact(d)
    assert art_l.impl == "batched"  # checkpoints restore single-host
    assert art_l.wire_bits == art_m.wire_bits
    assert art_l.lengths == art_m.lengths
    mu_l, s2_l = predict(art_l, Xt)
    np.testing.assert_allclose(np.asarray(mu_l), np.asarray(mu_m), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2_l), np.asarray(s2_m), atol=1e-5)


def test_mesh_predict_structure_and_streaming():
    """Warm mesh serving: zero cholesky/eigh equations in the shard_map serve
    program, no retrace on a warm loop, exactly one after a streamed growth;
    update() charges the frozen per-machine rate to the ledger."""
    parts, Xt = _problem(seed=6, m=4)
    art = fit(parts, 24, "broadcast", steps=4, impl="mesh")
    # the registered mesh-serve contract: zero factorizations, ONE stacked
    # psum, machine-axis shardings only (check is trace-neutral, so its
    # placement relative to the retrace budget below is free)
    report = check_contracts(art, Xt)
    assert report.op_counts["cholesky"] == 0
    assert report.op_counts["eigh"] == 0
    assert sum(v["count"] for v in report.collectives.values()) == 1
    predict(art, Xt)  # trace once
    c0 = serve_trace_count("broadcast")
    with retrace_budget("broadcast", serve=0):
        for _ in range(3):
            predict(art, Xt)
        check_contracts(art, Xt)
    rng = np.random.default_rng(0)
    Xn = rng.normal(size=(7, parts[0][0].shape[1])).astype(np.float32)
    art2 = update(art, Xn, np.zeros(7, np.float32), machine=2)
    rate2 = int(np.asarray(art.wire.rates[2]).sum())
    assert art2.wire_bits == art.wire_bits + 7 * rate2
    mu2, s22 = predict(art2, Xt)
    assert serve_trace_count("broadcast") == c0 + 1
    assert np.all(np.isfinite(np.asarray(mu2))) and np.all(np.asarray(s22) > 0)


# --------------------------------------------------------------------------
# hypothesis sweeps: m, ragged shard sizes, d, bits, kernel
# --------------------------------------------------------------------------

_BITS = st.sampled_from([1, 2, 3, 4, 5, 6, 7, 8, 32])


@given(
    lengths=st.lists(st.integers(8, 24), min_size=2, max_size=6),
    d=st.integers(2, 6),
    bits=_BITS,
    seed=st.integers(0, 2**20),
)
@settings(max_examples=10, deadline=None)
def test_hyp_wire_ledger_host_vs_batched(lengths, d, bits, seed):
    """Sweep m (=len(lengths)), ragged shard sizes, d, bits: the batched wire
    must reproduce the scipy oracle's ledger exactly and its reconstructions
    within f32-vs-f64 decode tolerance."""
    parts, _ = _ragged_parts(tuple(lengths), d, seed=seed)
    Xh, yh, wh, nch, _ = quantize_to_center(parts, bits, impl="host")
    Xb, yb, wb, ncb, _ = quantize_to_center(parts, bits, impl="batched")
    assert wh == wb and nch == ncb
    np.testing.assert_array_equal(np.asarray(yb), np.asarray(yh))
    np.testing.assert_allclose(np.asarray(Xb), np.asarray(Xh), atol=5e-3)


@given(
    lengths=st.lists(st.integers(8, 16), min_size=2, max_size=4),
    d=st.integers(2, 4),
    bits=st.sampled_from([1, 4, 8, 32]),
    seed=st.integers(0, 2**20),
)
@settings(max_examples=5, deadline=None)
def test_hyp_wire_ledger_mesh(lengths, d, bits, seed):
    """The mesh wire (real collectives) against both other impls: the ledger
    computed from the collective's actual payload is integer-equal to the §4
    formula, and the reconstructions are the batched ones."""
    parts, _ = _ragged_parts(tuple(lengths), d, seed=seed)
    _, _, wh, _, _ = quantize_to_center(parts, bits, impl="host")
    Xb, _, wb, _, _ = quantize_to_center(parts, bits, impl="batched")
    Xm, _, wm, _, _ = quantize_to_center(parts, bits, impl="mesh")
    assert wm == wh == wb
    assert _max_abs(Xm, Xb) <= 1e-6


@given(
    kernel=st.sampled_from(["se", "linear"]),
    bits=st.sampled_from([4, 8, 32]),
    m=st.integers(2, 4),
    seed=st.integers(0, 2**20),
)
@settings(max_examples=6, deadline=None)
def test_hyp_protocol_kernels_host_vs_batched(kernel, bits, m, seed):
    """Kernel sweep at fixed hypers (steps=0): the full center protocol
    (wire -> Nyström completion -> predictive) agrees across impls."""
    parts, Xt = _problem(seed=seed, n=90, d=4, m=m, n_test=16)
    m_h = single_center_gp(parts, bits, kernel=kernel, steps=0, impl="host",
                           train_impl="loop")
    art_b = single_center_gp(parts, bits, kernel=kernel, steps=0)
    assert m_h.wire_bits == art_b.wire_bits
    mu_h, v_h = m_h.predict(Xt)
    mu_b, v_b = art_b.predict(Xt)
    np.testing.assert_allclose(np.asarray(mu_b), np.asarray(mu_h), atol=5e-3)
    np.testing.assert_allclose(np.asarray(v_b), np.asarray(v_h), atol=5e-3)


# --------------------------------------------------------------------------
# streamed updates: cross-impl ledgers, codes, and sharding
# --------------------------------------------------------------------------


def test_streamed_ledgers_integer_equal_formula_batched_mesh():
    """After an identical streamed sequence, all three ledgers are
    INTEGER-equal between the batched and mesh impls and equal to the
    accounting formulas (the host expectation: frozen rate per row, whole
    packed words, CRC framing — and no new side info, the codebooks are
    frozen)."""
    from repro.comm.accounting import (
        integrity_bits_formula, payload_bits_formula, side_info_bits,
        wire_bits_formula,
    )

    parts, Xt = _problem(seed=21, m=4)
    d = parts[0][0].shape[1]
    ab = fit(parts, 20, "broadcast", steps=3)
    am = fit(parts, 20, "broadcast", steps=3, impl="mesh")
    rates = np.asarray(ab.wire.rates)
    exp_w, exp_p, exp_i = ab.wire_bits, ab.payload_bits, ab.integrity_bits
    rng = np.random.default_rng(1)
    for j, n_new in [(1, 6), (3, 4), (1, 5), (2, 7)]:
        Xn = rng.normal(size=(n_new, d)).astype(np.float32)
        yn = np.zeros(n_new, np.float32)
        ab = update(ab, Xn, yn, machine=j)
        am = update(am, Xn, yn, machine=j)
        L = [n_new if q == j else 0 for q in range(4)]
        exp_w += wire_bits_formula(rates, L, d) - side_info_bits(d)
        exp_p += payload_bits_formula(
            L, d, ab.bits_per_sample, ab.max_bits
        ) - side_info_bits(d)
        exp_i += integrity_bits_formula(L)
    assert ab.wire_bits == am.wire_bits == exp_w
    assert ab.payload_bits == am.payload_bits == exp_p
    assert ab.integrity_bits == am.integrity_bits == exp_i
    assert ab.lengths == am.lengths
    # the packed code plane both consumers carry is still identical word for
    # word (streaming must not disturb the fit-frozen wire state)
    np.testing.assert_array_equal(
        np.asarray(am.wire.codes), np.asarray(ab.wire.codes)
    )
    mu_b, s2_b = predict(ab, Xt)
    mu_m, s2_m = predict(am, Xt)
    assert _max_abs(mu_m, mu_b) <= 1e-3
    assert _max_abs(s2_m, s2_b) <= 1e-3


@pytest.mark.parametrize("protocol", ["broadcast", "poe"])
def test_mesh_update_keeps_factors_sharded(protocol):
    """The mesh update program grows the factors IN PLACE on their devices:
    after a streamed sequence (including a bucket growth) every factor leaf
    is still sharded along the machine mesh axis — no host pull."""
    parts, Xt = _problem(seed=22, m=4)
    d = parts[0][0].shape[1]
    bits = 0 if protocol == "poe" else 24
    art = fit(parts, bits, protocol, steps=3, impl="mesh")
    rng = np.random.default_rng(2)
    for j, n_new in [(1, 8), (2, 5)]:  # first update grows the bucket
        Xn = rng.normal(size=(n_new, d)).astype(np.float32)
        art = update(art, Xn, np.zeros(n_new, np.float32), machine=j)
    for leaf in jax.tree_util.tree_leaves(art.factors):
        assert leaf.sharding.spec[0] == MESH_AXIS
    assert art.data["Xs"].sharding.spec[0] == MESH_AXIS
    mu, s2 = predict(art, Xt)
    assert np.all(np.isfinite(np.asarray(mu))) and np.all(np.asarray(s2) > 0)
