"""Known-answer tests for the trip-count-aware HLO cost parser."""
import jax
import jax.numpy as jnp
import pytest

from repro.compat import cost_analysis_dict
from repro.roofline import analyze_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    c = analyze_hlo(_compile_text(lambda a, b: a @ b, x, w))
    assert c.flops == pytest.approx(2 * 64 * 32 * 16, rel=0.01)


def test_scan_multiplies_by_trip_count():
    """XLA cost_analysis counts while bodies once; our parser must not."""
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    parsed = analyze_hlo(compiled.as_text())
    expected = 10 * 2 * 128**3
    assert parsed.flops == pytest.approx(expected, rel=0.02)
    # and confirm the builtin undercounts (the reason this module exists)
    xla = cost_analysis_dict(compiled).get("flops", 0)
    assert xla < 0.2 * expected


def test_nested_scan():
    def g(x, ws):
        def outer(c, wouter):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, wouter)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 10, 128, 128), jnp.float32)
    c = analyze_hlo(_compile_text(g, x, ws))
    assert c.flops == pytest.approx(50 * 2 * 128**3, rel=0.02)


def test_bytes_nonzero_and_scale_with_loop():
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = analyze_hlo(_compile_text(f, x))
    # at least 7 x (read + write) of the 4 MB buffer
    assert c.bytes >= 7 * 2 * 4 * 1024 * 1024 * 0.9
