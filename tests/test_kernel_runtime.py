"""The unified kernel runtime: one dispatch policy, the kernel-op registry,
the persistent autotune cache, and the fused serve epilogue.

Covers the PR's acceptance contract: off-TPU ``interpret=None`` routes to the
XLA fallback for EVERY family; forced-Pallas interpret mode agrees with each
family's ``ref.py`` oracle; the autotune cache is demonstrably persistent
across processes (second process performs ZERO sweeps) and tolerates corrupt
files; and the fused serve epilogue is numerically equal to the unfused path
for every registered fusion method, with the warm-serve invariants (0
cholesky / 0 eigh / 0 retraces) intact.
"""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import runtime

FAMILIES = (
    "gram", "quant_encode", "quant_decode", "qgram", "qgram_packed",
    "decode_attn", "epilogue",
)


# --------------------------------------------------------------------------
# the one fallback policy
# --------------------------------------------------------------------------


def test_choose_policy_off_tpu(monkeypatch):
    assert jax.default_backend() != "tpu"  # CI/dev hosts
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    assert runtime.choose(None) == runtime.Decision("xla")
    assert runtime.choose(True) == runtime.Decision("pallas", True)
    assert runtime.choose(False) == runtime.Decision("pallas", False)
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    assert runtime.choose(None) == runtime.Decision("pallas", True)
    # explicit interpret always wins over the env override
    assert runtime.choose(False) == runtime.Decision("pallas", False)


def test_registry_has_every_family():
    for name in FAMILIES:
        spec = runtime.kernel_op(name)
        assert spec.name == name
        assert callable(spec.pallas) and callable(spec.xla)
        assert spec.ref is not None


def test_registry_unknown_op_lists_menu():
    with pytest.raises(ValueError, match="known kernel ops are .*gram"):
        runtime.kernel_op("no_such_kernel")


def test_dispatch_binds_backend(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    spec = runtime.kernel_op("gram")
    d, fn = runtime.dispatch("gram")
    assert d.kind == "xla" and fn is spec.xla
    d, fn = runtime.dispatch("gram", interpret=True)
    assert d == runtime.Decision("pallas", True)


# --------------------------------------------------------------------------
# dispatch-table parity: pallas(interpret) vs ref, xla vs ref, per family
# --------------------------------------------------------------------------


def _family_args(name, rng):
    """(args, kwargs) over each op's public unpadded signature."""
    from repro.core import quantizers as Q
    from repro.core import jax_scheme as js
    from repro.kernels.quant.ops import build_scaled_tables, encode

    if name == "gram":
        return (rng.normal(size=(33, 7)).astype(np.float32),
                rng.normal(size=(20, 7)).astype(np.float32)), {}
    d, bits = 10, 30
    var = rng.uniform(0.05, 4.0, size=d)
    rates = Q.allocate_bits_greedy(var, bits, 8)
    sigma = np.sqrt(var).astype(np.float32)
    edges, cents = build_scaled_tables(sigma, rates)
    x = (rng.normal(size=(40, d)) * sigma).astype(np.float32)
    if name == "quant_encode":
        return (x, edges), {}
    codes = encode(x, edges, interpret=True)
    if name == "quant_decode":
        return (codes, cents), {}
    y = rng.normal(size=(22, d)).astype(np.float32)
    if name == "qgram":
        return (codes, cents, y), {}
    if name == "qgram_packed":
        words = js.pack_codes(codes, jnp.asarray(rates), total_bits=bits)
        return (words, jnp.asarray(rates), cents, y), {"total_bits": bits}
    if name == "decode_attn":
        B, S, KV, G, hd = 2, 24, 2, 2, 16
        q = rng.normal(size=(B, KV, G, hd)).astype(np.float32)
        K = rng.normal(size=(B, S, KV, hd)).astype(np.float32)
        V = rng.normal(size=(B, S, KV, hd)).astype(np.float32)
        kpos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
        return (q, K, V, kpos, S - 1), {}
    if name == "epilogue":
        m, t, K = 3, 17, 11
        G = rng.normal(size=(m, t, K)).astype(np.float32)
        Ainv = np.stack([
            np.linalg.inv(np.tril(rng.normal(size=(K, K))) * 0.1 + np.eye(K))
            for _ in range(m)
        ]).astype(np.float32)
        P = np.stack([0.01 * A @ A.T for A in Ainv]).astype(np.float32)
        walpha = rng.normal(size=(m, K)).astype(np.float32)
        gss = rng.uniform(1.0, 2.0, size=(t,)).astype(np.float32)
        w = np.ones((m,), np.float32)
        return (G, Ainv, P, walpha, gss, gss + 0.1, w), {"fuse": "kl"}
    raise AssertionError(name)


@pytest.mark.parametrize("name", FAMILIES)
def test_family_backends_match_ref(name):
    """Forced-Pallas interpret mode AND the XLA fallback against the family's
    pure-jnp oracle, through the registry's uniform public signature."""
    rng = np.random.default_rng(hash(name) % 2**31)
    spec = runtime.kernel_op(name)
    args, kw = _family_args(name, rng)
    ref = spec.ref(*args, **kw)
    pal = spec.pallas(*args, interpret=True, **kw)
    xla = spec.xla(*args, **kw)
    for got in (pal, xla):
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-3
            ),
            got, ref,
        )


def test_decode_attn_xla_fallback_serves_off_tpu(monkeypatch):
    """decode_attn historically had NO fallback: interpret=None off-TPU now
    runs the jitted reference instead of raising/interpreting."""
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    from repro.kernels.decode_attn.ops import decode_attn

    rng = np.random.default_rng(3)
    (q, K, V, kpos, pos), _ = _family_args("decode_attn", rng)
    out = decode_attn(q, K, V, kpos, pos)  # interpret=None -> xla
    ref = runtime.kernel_op("decode_attn").ref(q, K, V, kpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# persistent autotune cache
# --------------------------------------------------------------------------


def _with_cache(monkeypatch, tmp_path):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    runtime.clear_cache_memory()
    return path


def test_autotune_sweeps_once_then_warm_hits(monkeypatch, tmp_path):
    path = _with_cache(monkeypatch, tmp_path)
    key = runtime.cache_key("op", [(8, 8)], "float32", bits=4)
    seen = []
    measure = lambda c: (seen.append(c), float(c[0]))[1]
    before = runtime.sweep_count()
    win = runtime.autotune(key, [(2, 2), (1, 1)], measure, (2, 2))
    assert win == (1, 1) and runtime.sweep_count() == before + 1
    assert seen == [(2, 2), (1, 1)]
    # warm hit: straight from disk image, zero sweeps, measure never called
    runtime.clear_cache_memory()
    win2 = runtime.autotune(key, [(2, 2), (1, 1)], lambda c: 1 / 0, (2, 2))
    assert win2 == (1, 1) and runtime.sweep_count() == before + 1
    blob = json.load(open(path))
    assert blob["version"] == runtime.CACHE_VERSION
    assert blob["entries"][key] == [1, 1]


def test_autotune_infeasible_and_failing_candidates(monkeypatch, tmp_path):
    _with_cache(monkeypatch, tmp_path)
    key = runtime.cache_key("op2", [(4,)], "int8")

    def measure(c):
        if c == (1,):
            return None  # infeasible for this shape
        if c == (2,):
            raise RuntimeError("compile blew up")
        return 5.0

    assert runtime.autotune(key, [(1,), (2,), (3,)], measure, (1,)) == (3,)


def test_corrupt_or_stale_cache_falls_back(monkeypatch, tmp_path):
    path = _with_cache(monkeypatch, tmp_path)
    key = runtime.cache_key("op3", [(2, 2)], "float32")
    for garbage in ("{not json", json.dumps({"version": 99, "entries": {key: [9]}}),
                    json.dumps([1, 2, 3])):
        with open(path, "w") as f:
            f.write(garbage)
        runtime.clear_cache_memory()
        before = runtime.sweep_count()
        win = runtime.autotune(key, [(7,)], lambda c: 1.0, (7,))
        assert win == (7,) and runtime.sweep_count() == before + 1
        runtime.clear_cache_memory()  # the sweep rewrote a valid file


def test_stale_winner_not_in_candidates_resweeps(monkeypatch, tmp_path):
    path = _with_cache(monkeypatch, tmp_path)
    key = runtime.cache_key("op4", [(2,)], "float32")
    with open(path, "w") as f:
        json.dump({"version": runtime.CACHE_VERSION,
                   "entries": {key: [999, 999]}}, f)
    runtime.clear_cache_memory()
    before = runtime.sweep_count()
    win = runtime.autotune(key, [(4, 4)], lambda c: 1.0, (4, 4))
    assert win == (4, 4) and runtime.sweep_count() == before + 1


_SUBPROC = r"""
import os, sys
import numpy as np, jax.numpy as jnp
sys.path.insert(0, {src!r})
from repro.core import quantizers as Q, jax_scheme as js
from repro.kernels import runtime
from repro.kernels.quant.ops import build_scaled_tables, encode
from repro.kernels.qgram.ops import qgram_packed

rng = np.random.default_rng(0)
d, bits = 10, 30
var = rng.uniform(0.05, 4.0, size=d)
rates = Q.allocate_bits_greedy(var, bits, 8)
sigma = np.sqrt(var).astype(np.float32)
edges, cents = build_scaled_tables(sigma, rates)
x = (rng.normal(size=(40, d)) * sigma).astype(np.float32)
y = rng.normal(size=(22, d)).astype(np.float32)
codes = encode(x, edges, interpret=True)
words = js.pack_codes(codes, jnp.asarray(rates), total_bits=bits)
out = qgram_packed(words, jnp.asarray(rates), cents, y, total_bits=bits,
                   interpret=True)
np.asarray(out)
print("SWEEPS", runtime.sweep_count())
"""


def test_cache_persists_across_processes(tmp_path):
    """The acceptance criterion verbatim: a second process serving the same
    shapes performs ZERO autotune sweeps (warm disk hit)."""
    env = dict(
        os.environ,
        REPRO_TUNE_CACHE=str(tmp_path / "autotune.json"),
        REPRO_AUTOTUNE_INTERPRET="1",  # let the interpret path tune on CPU
        JAX_PLATFORMS="cpu",
    )
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SUBPROC.format(src=os.path.abspath(src))

    def run():
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=600)
        assert r.returncode == 0, r.stderr
        return int(r.stdout.strip().split()[-1])

    assert run() >= 1  # cold: at least one sweep, winner persisted
    assert run() == 0  # warm: second process sweeps ZERO times


# --------------------------------------------------------------------------
# fused serve epilogue: equality with the unfused path, serve invariants
# --------------------------------------------------------------------------


def _parts(rng, m=3, n=20, d=3):
    return [(rng.normal(size=(n, d)).astype(np.float32),
             rng.normal(size=(n,)).astype(np.float32)) for _ in range(m)]


@pytest.mark.parametrize("fuse", ["kl", "poe", "gpoe", "bcm", "rbcm"])
def test_fused_epilogue_equals_unfused_all_fusions(fuse):
    import dataclasses
    from repro.core.api import DistributedGP
    from repro.core.config import DGPConfig

    rng = np.random.default_rng(7)
    parts = _parts(rng)
    Xst = rng.normal(size=(12, 3)).astype(np.float32)
    cfg = DGPConfig(protocol="broadcast", fusion=fuse, steps=4,
                    bits_per_sample=8, serve_epilogue="fused")
    art_f = DistributedGP(cfg).fit(parts=parts)
    assert "Ainv" in art_f.factors and "U" in art_f.factors
    cfg_u = dataclasses.replace(cfg, serve_epilogue="unfused")
    art_u = DistributedGP(cfg_u).fit(parts=parts)
    assert "Ainv" not in art_u.factors
    mu_f, s2_f = DistributedGP(cfg).predict(art_f, Xst)
    mu_u, s2_u = DistributedGP(cfg_u).predict(art_u, Xst)
    np.testing.assert_allclose(np.asarray(mu_f), np.asarray(mu_u), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2_f), np.asarray(s2_u), atol=2e-4)
    # degraded serving goes through the same fused moments
    avail = np.array([1.0, 0.0, 1.0], np.float32)
    mu_f, s2_f = DistributedGP(cfg).predict(art_f, Xst, available=avail)
    mu_u, s2_u = DistributedGP(cfg_u).predict(art_u, Xst, available=avail)
    np.testing.assert_allclose(np.asarray(mu_f), np.asarray(mu_u), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2_f), np.asarray(s2_u), atol=2e-4)


def test_fused_pallas_backend_matches_xla_backend():
    """gram_backend="pallas" + fused cache routes the whole epilogue through
    the one-launch kernels.epilogue op — same answer as the xla route."""
    from repro.core.protocols import base

    rng = np.random.default_rng(11)
    parts = _parts(rng)
    Xst = rng.normal(size=(10, 3)).astype(np.float32)
    kw = dict(protocol="broadcast", kernel="se", steps=4, fuse="kl")
    art_x = base.fit(parts, 8, gram_backend="xla", **kw)
    art_p = base.fit(parts, 8, gram_backend="pallas", **kw)
    assert "Ainv" in art_p.factors
    mu_x, s2_x = base.predict(art_x, Xst)
    mu_p, s2_p = base.predict(art_p, Xst)
    np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_x), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2_p), np.asarray(s2_x), atol=1e-4)


def test_fused_update_maintains_cache():
    """Streaming update keeps the serve-cache keys consistent: an updated
    fused artifact predicts the same as an updated unfused one."""
    import dataclasses
    from repro.core.api import DistributedGP
    from repro.core.config import DGPConfig

    rng = np.random.default_rng(13)
    parts = _parts(rng)
    Xst = rng.normal(size=(10, 3)).astype(np.float32)
    Xn = rng.normal(size=(4, 3)).astype(np.float32)
    yn = rng.normal(size=(4,)).astype(np.float32)
    cfg = DGPConfig(protocol="broadcast", fusion="kl", steps=4,
                    bits_per_sample=8, serve_epilogue="fused")
    cfg_u = dataclasses.replace(cfg, serve_epilogue="unfused")
    from repro.core.protocols import base

    art_f = base.update(DistributedGP(cfg).fit(parts=parts), Xn, yn, machine=1)
    art_u = base.update(DistributedGP(cfg_u).fit(parts=parts), Xn, yn, machine=1)
    assert "U" in art_f.factors and "walpha" in art_f.factors
    mu_f, s2_f = base.predict(art_f, Xst)
    mu_u, s2_u = base.predict(art_u, Xst)
    np.testing.assert_allclose(np.asarray(mu_f), np.asarray(mu_u), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2_f), np.asarray(s2_u), atol=2e-4)


def test_fused_serve_keeps_warm_invariants():
    """The fused predict program still contains ZERO fit-time factorizations,
    and repeated serving does not retrace."""
    from repro.core.protocols import base

    rng = np.random.default_rng(17)
    parts = _parts(rng)
    Xst = rng.normal(size=(8, 3)).astype(np.float32)
    for protocol in ("center", "broadcast"):
        art = base.fit(parts, 8, protocol=protocol, steps=4)
        assert "Ainv" in art.factors
        counts = base.predict_op_counts(art, Xst)
        assert counts["cholesky"] == 0 and counts["eigh"] == 0
        base.predict(art, Xst)
        traces = dict(base._SERVE_TRACES)
        for _ in range(3):
            base.predict(art, Xst)
        assert dict(base._SERVE_TRACES) == traces  # warm: zero retraces
