"""GP substrate tests: posterior correctness, training, Nyström."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.gp import (
    init_params, linear_gram, se_gram, posterior_from_gram, nlml_from_gram, train_gp,
)
from repro.core.nystrom import nystrom_complete, nystrom_posterior


def test_posterior_matches_naive_formula():
    rng = np.random.default_rng(0)
    n, t, d = 30, 7, 3
    X = rng.normal(size=(n, d)).astype(np.float32)
    Xs = rng.normal(size=(t, d)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    p = init_params(a=0.7, b=0.2, noise=0.3)
    G = np.asarray(se_gram(p, jnp.asarray(X)), np.float64)
    Gsn = np.asarray(se_gram(p, jnp.asarray(Xs), jnp.asarray(X)), np.float64)
    gss = np.asarray(se_gram(p, jnp.asarray(Xs)), np.float64).diagonal()
    K = G + 0.3 * np.eye(n)
    mean_ref = Gsn @ np.linalg.solve(K, y)
    var_ref = gss - np.einsum("tn,nm,tm->t", Gsn, np.linalg.inv(K), Gsn)
    mean, var = posterior_from_gram(
        jnp.asarray(G, jnp.float32), jnp.asarray(Gsn, jnp.float32),
        jnp.asarray(gss, jnp.float32), jnp.asarray(y), 0.3,
    )
    np.testing.assert_allclose(np.asarray(mean), mean_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(var), var_ref, rtol=2e-2, atol=2e-3)


def test_nlml_matches_gaussian_logpdf():
    rng = np.random.default_rng(1)
    n = 20
    X = rng.normal(size=(n, 2)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    p = init_params()
    G = np.asarray(linear_gram(p, jnp.asarray(X)), np.float64)
    K = G + np.exp(float(p.log_noise)) * np.eye(n)
    sign, logdet = np.linalg.slogdet(K)
    ref = 0.5 * (y @ np.linalg.solve(K, y) + logdet + n * np.log(2 * np.pi))
    val = float(nlml_from_gram(jnp.asarray(G, jnp.float32), jnp.asarray(y), np.exp(float(p.log_noise))))
    assert val == pytest.approx(ref, rel=1e-3)


def test_training_reduces_nlml_and_fits():
    rng = np.random.default_rng(2)
    n, d = 150, 4
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (np.sin(X @ np.ones(d)) + 0.05 * rng.normal(size=n)).astype(np.float32)
    m0 = train_gp(X, y, kernel="se", steps=0)
    m1 = train_gp(X, y, kernel="se", steps=150)
    assert float(m1.nlml()) < float(m0.nlml())
    mu, var = m1.predict(X[:20])
    assert np.mean((np.asarray(mu) - y[:20]) ** 2) < 0.1 * np.var(y)
    assert np.all(np.asarray(var) > 0)


def test_nystrom_exact_on_first_block_and_lowrank():
    rng = np.random.default_rng(3)
    n, K_, d = 40, 20, 10  # linear gram rank <= d+1 = 11 < K: Nyström ~exact
    X = rng.normal(size=(n, d)).astype(np.float32)
    p = init_params(a=1.0, b=0.1, noise=0.1)
    G = np.asarray(linear_gram(p, jnp.asarray(X)), np.float64)  # rank <= d+1
    Gh = np.asarray(nystrom_complete(
        jnp.asarray(G[:K_, :K_], jnp.float32), jnp.asarray(G[:K_, :], jnp.float32)))
    np.testing.assert_allclose(Gh[:K_, :], G[:K_, :], rtol=2e-3, atol=2e-3)
    # linear-kernel gram has rank <= d+1 <= K: Nyström is (nearly) exact
    np.testing.assert_allclose(Gh, G, rtol=3e-2, atol=3e-2)


def test_nystrom_posterior_equals_dense_path():
    rng = np.random.default_rng(4)
    n, K_, t, d = 50, 20, 6, 4
    X = rng.normal(size=(n, d)).astype(np.float32)
    Xs = rng.normal(size=(t, d)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    p = init_params(noise=0.2)
    k = lambda A, B=None: se_gram(p, jnp.asarray(A), None if B is None else jnp.asarray(B))
    G_KK = k(X[:K_])
    G_KN = k(X[:K_], X)
    Ghat = nystrom_complete(G_KK, G_KN)
    from repro.core.gp import posterior_from_gram
    G_sK = k(Xs, X[:K_])
    # dense reference: G_*N from the same Nyström map
    L = np.linalg.cholesky(np.asarray(G_KK, np.float64) + 1e-6 * np.trace(np.asarray(G_KK)) / K_ * np.eye(K_))
    W = np.linalg.solve(L, np.asarray(G_KN, np.float64))
    GsN = np.linalg.solve(L, np.asarray(G_sK, np.float64).T).T @ W
    gss = np.asarray(k(Xs)).diagonal()
    mu_ref, var_ref = posterior_from_gram(
        jnp.asarray(Ghat), jnp.asarray(GsN, jnp.float32), jnp.asarray(gss), jnp.asarray(y), 0.2)
    mu, var = nystrom_posterior(G_KK, G_KN, jnp.asarray(y), 0.2, G_sK, jnp.asarray(gss))
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_ref), rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_ref), rtol=5e-2, atol=1e-2)
