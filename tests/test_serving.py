"""The fit-once/serve-many artifact (core.distributed_gp.FittedProtocol).

Locks the serving contract:
  * checkpoint save/load reproduces predict() outputs BITWISE;
  * warm predict() is structurally factorization-free (zero cholesky/eigh
    equations in its jaxpr) and never retraces on a warm loop;
  * streaming update() equals a from-scratch factor build on the concatenated
    data exactly (rank-k updates are algebra, not approximation), and tracks a
    full protocol refit within tolerance;
  * the wire-bit ledger charges only the new symbols at the frozen codebook's
    rate (zero for points landing on the center / a PoE expert's own data).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    split_machines, fit, predict, update, save_artifact, load_artifact,
)
from repro.core import jax_scheme
from repro.core.gp import gram_fn
from repro.core.nystrom import (
    nystrom_posterior, chol_update_rank, chol_append,
)
from repro.core.distributed_gp import predict_op_counts, serve_trace_count
from repro.analysis import check_contracts, retrace_budget


def _problem(seed=0, n=160, d=5, n_test=40):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    Xt = rng.normal(size=(n_test, d)).astype(np.float32)
    return X, y, jnp.asarray(Xt), f


def _fit_any(protocol, gram_mode, parts, bits, steps=8):
    if protocol == "poe":
        return fit(parts, 0, "poe", steps=steps, method="rbcm")
    return fit(parts, bits, protocol, steps=steps, gram_mode=gram_mode)


# --------------------------------------------------------------------------
# persistence
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "protocol,gram_mode",
    [
        ("center", "nystrom"),
        ("center", "nystrom_fitc"),
        ("center", "direct"),
        ("broadcast", "nystrom"),
        ("broadcast", "direct"),
        ("poe", "dense"),
    ],
)
def test_artifact_roundtrip_is_bitwise(tmp_path, protocol, gram_mode):
    X, y, Xt, _ = _problem(0)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(0))
    art = _fit_any(protocol, gram_mode, parts, 16)
    mu0, v0 = predict(art, Xt)
    save_artifact(art, str(tmp_path))
    art2 = load_artifact(str(tmp_path))
    assert art2.wire_bits == art.wire_bits
    assert art2.lengths == art.lengths
    mu1, v1 = predict(art2, Xt)
    np.testing.assert_array_equal(np.asarray(mu0), np.asarray(mu1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))


def test_artifact_roundtrip_after_update_is_bitwise(tmp_path):
    X, y, Xt, f = _problem(1)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(1))
    rng = np.random.default_rng(9)
    Xn = rng.normal(size=(10, X.shape[1])).astype(np.float32)
    yn = f(Xn).astype(np.float32)
    art = update(fit(parts, 16, "center", steps=6), Xn, yn, machine=2)
    mu0, v0 = predict(art, Xt)
    save_artifact(art, str(tmp_path), step=3)
    art2 = load_artifact(str(tmp_path))  # latest-step discovery
    np.testing.assert_array_equal(np.asarray(mu0), np.asarray(predict(art2, Xt)[0]))


def test_load_artifact_respects_shardings(tmp_path):
    X, y, Xt, _ = _problem(2)
    parts = split_machines(X, y, 3, jax.random.PRNGKey(2))
    art = fit(parts, 8, "center", steps=4)
    mu0, _ = predict(art, Xt)
    save_artifact(art, str(tmp_path))
    dev = jax.devices()[0]
    art2 = load_artifact(str(tmp_path), shardings=dev)
    for leaf in jax.tree_util.tree_leaves(art2):
        assert dev in leaf.devices()
    np.testing.assert_array_equal(np.asarray(mu0), np.asarray(predict(art2, Xt)[0]))


# --------------------------------------------------------------------------
# warm-serve structure: no refit, no refactorization, no retrace
# --------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", ["center", "broadcast", "poe"])
def test_warm_predict_is_factorization_free(protocol):
    X, y, Xt, _ = _problem(3)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(3))
    art = _fit_any(protocol, "nystrom", parts, 16)
    # the full registered contract: zero factorizations, zero host callbacks,
    # zero collectives, no sharding leak, consistent ledgers
    report = check_contracts(art, Xt)
    assert report.op_counts["cholesky"] == 0
    assert report.op_counts["eigh"] == 0
    # the legacy wrapper agrees (kept for BENCH_serve.json and old callers)
    assert predict_op_counts(art, Xt) == {"cholesky": 0, "eigh": 0}


def test_warm_predict_does_not_retrace():
    X, y, Xt, _ = _problem(4)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(4))
    art = fit(parts, 16, "center", steps=4)
    predict(art, Xt)  # trace once
    check_contracts(art, Xt)  # trace-neutral: must not perturb the budget
    with retrace_budget("center", serve=0):
        for _ in range(3):
            predict(art, Xt)
        check_contracts(art, Xt)
    # a grown artifact retraces exactly once, then is warm again
    rng = np.random.default_rng(0)
    Xn = rng.normal(size=(6, X.shape[1])).astype(np.float32)
    art2 = update(art, Xn, np.zeros(6, np.float32), machine=1)
    c0 = serve_trace_count("center")
    predict(art2, Xt)
    c1 = serve_trace_count("center")
    assert c1 == c0 + 1
    with retrace_budget("center", serve=0):
        predict(art2, Xt)


def test_warm_predict_under_strict_device_guard(strict_device_guard):
    """The warm serve loop survives jax.transfer_guard("disallow") +
    strict dtype promotion: no implicit host<->device transfer and no silent
    widening anywhere in the dispatch path (the runtime complement of the
    jaxpr-level contract)."""
    with jax.transfer_guard("allow"), jax.numpy_dtype_promotion("standard"):
        # problem setup + fit + first trace outside the guard: fitting
        # legitimately moves the numpy problem data onto the device
        X, y, Xt, _ = _problem(13)
        parts = split_machines(X, y, 4, jax.random.PRNGKey(13))
        art = fit(parts, 16, "center", steps=2)
        Xt_dev = jax.device_put(jnp.asarray(Xt))
        predict(art, Xt_dev)
    for _ in range(3):
        mu, s2 = predict(art, Xt_dev)
    assert np.isfinite(np.asarray(jax.block_until_ready(mu))).all()


# --------------------------------------------------------------------------
# streaming update
# --------------------------------------------------------------------------


def test_update_center_matches_scratch_factor_build_exactly():
    """The rank-k factor updates are exact algebra: an updated artifact must
    match a posterior built from scratch on [old reconstruction; new decode]
    to float tolerance."""
    X, y, Xt, f = _problem(5)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(5))
    art = fit(parts, 16, "center", steps=8)
    rng = np.random.default_rng(1)
    Xn = rng.normal(size=(12, X.shape[1])).astype(np.float32)
    yn = (f(Xn) + 0.05 * rng.normal(size=12)).astype(np.float32)
    art_u = update(art, Xn, yn, machine=1)
    mu_u, v_u = predict(art_u, Xt)

    # scratch: re-encode with the SAME frozen scheme, full nystrom_posterior
    w = art.wire
    state = {"T": w.T[1], "T_inv": w.T_inv[1], "sigma": w.sigma[1],
             "rates": w.rates[1]}
    tables = jax_scheme.scheme_tables(art.bits_per_sample, art.max_bits)
    _, dec = jax_scheme.roundtrip(state, jnp.asarray(Xn), tables)
    X2 = jnp.concatenate([art.data["X_recon"], dec])
    y2 = jnp.concatenate([art.y, jnp.asarray(yn)])
    k = gram_fn("se")
    p = art.params
    Xc = art.data["Xc"]
    g_ss = jnp.full(Xt.shape[0], jnp.exp(p.log_a))
    mu_s, v_s = nystrom_posterior(
        k(p, Xc), k(p, Xc, X2), y2, jnp.exp(p.log_noise), k(p, Xt, Xc), g_ss
    )
    np.testing.assert_allclose(np.asarray(mu_u), np.asarray(mu_s), atol=1e-4)
    np.testing.assert_allclose(np.asarray(v_u), np.asarray(v_s), atol=1e-4)


def test_update_tracks_full_refit_within_tolerance():
    """Frozen-codebook streaming vs a full protocol refit on the concatenated
    data (scheme refit + everything): at a healthy rate the two predictions
    must agree closely — the artifact does not drift from the protocol."""
    X, y, Xt, f = _problem(6, n=200)
    d = X.shape[1]
    parts = split_machines(X, y, 4, jax.random.PRNGKey(6))
    art = fit(parts, 48, "center", steps=20)
    rng = np.random.default_rng(2)
    Xn = rng.normal(size=(15, d)).astype(np.float32)
    yn = (f(Xn) + 0.05 * rng.normal(size=15)).astype(np.float32)
    art_u = update(art, Xn, yn, machine=1)
    mu_u, _ = predict(art_u, Xt)

    parts2 = list(parts)
    parts2[1] = (
        jnp.concatenate([parts[1][0], jnp.asarray(Xn)]),
        jnp.concatenate([parts[1][1], jnp.asarray(yn)]),
    )
    art_refit = fit(parts2, 48, "center", steps=0, params=art.params)
    mu_r, _ = predict(art_refit, Xt)
    err = float(jnp.max(jnp.abs(mu_u - mu_r)))
    spread = float(jnp.std(jnp.asarray(y)))
    assert err < 0.05 * max(spread, 1.0)


@pytest.mark.parametrize("protocol", ["broadcast", "poe"])
def test_update_improves_or_holds_other_protocols(protocol):
    X, y, Xt, f = _problem(7, n=180)
    yt = f(np.asarray(Xt))
    parts = split_machines(X, y, 4, jax.random.PRNGKey(7))
    art = _fit_any(protocol, "nystrom", parts, 24, steps=15)
    rng = np.random.default_rng(3)
    Xn = rng.normal(size=(30, X.shape[1])).astype(np.float32)
    yn = (f(Xn) + 0.05 * rng.normal(size=30)).astype(np.float32)
    art_u = update(art, Xn, yn, machine=1)
    mu0, v0 = predict(art, Xt)
    mu1, v1 = predict(art_u, Xt)
    assert np.all(np.isfinite(np.asarray(mu1))) and np.all(np.asarray(v1) > 0)
    e0 = float(np.mean((yt - np.asarray(mu0)) ** 2) / np.var(yt))
    e1 = float(np.mean((yt - np.asarray(mu1)) ** 2) / np.var(yt))
    assert e1 < e0 * 1.25 + 0.02  # more data must not meaningfully hurt


def test_update_wire_ledger_accounting():
    X, y, _, f = _problem(8)
    parts = split_machines(X, y, 5, jax.random.PRNGKey(8))
    rng = np.random.default_rng(4)
    Xn = rng.normal(size=(9, X.shape[1])).astype(np.float32)
    yn = np.zeros(9, np.float32)
    art = fit(parts, 16, "center", steps=2)
    # machine j pays its frozen allocation per point; the center pays nothing
    rate_j = int(np.asarray(art.wire.rates[2]).sum())
    assert update(art, Xn, yn, machine=2).wire_bits == art.wire_bits + 9 * rate_j
    assert update(art, Xn, yn, machine=0).wire_bits == art.wire_bits
    # FITC additionally ships 32 bits/point of exact |x|^2
    art_f = fit(parts, 16, "center", steps=2, gram_mode="nystrom_fitc")
    rate_f = int(np.asarray(art_f.wire.rates[2]).sum())
    assert (
        update(art_f, Xn, yn, machine=2).wire_bits
        == art_f.wire_bits + 9 * (rate_f + 32)
    )
    # PoE stays a zero-rate baseline under streaming
    art_p = fit(parts, 0, "poe", steps=2)
    assert update(art_p, Xn, yn, machine=3).wire_bits == 0


# --------------------------------------------------------------------------
# update edge cases: empty batches, bad machine indices, zero-cost locality
# --------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", ["center", "broadcast", "poe"])
def test_update_zero_point_batch_is_identity(protocol):
    """A zero-row update must be a no-op: same predictions, same ledger,
    same lengths (the rank-0 factor growth is degenerate but well-defined)."""
    X, y, Xt, _ = _problem(10)
    d = X.shape[1]
    parts = split_machines(X, y, 4, jax.random.PRNGKey(10))
    art = _fit_any(protocol, "nystrom", parts, 16, steps=4)
    mu0, v0 = predict(art, Xt)
    art_u = update(art, np.zeros((0, d), np.float32), np.zeros(0, np.float32),
                   machine=1)
    assert art_u.wire_bits == art.wire_bits
    assert art_u.lengths == art.lengths
    mu1, v1 = predict(art_u, Xt)
    np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu0), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0), atol=1e-6)


@pytest.mark.parametrize("protocol", ["center", "broadcast", "poe"])
@pytest.mark.parametrize("machine", [-1, 4, 100])
def test_update_out_of_range_machine_raises(protocol, machine):
    X, y, _, _ = _problem(11)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(11))
    art = _fit_any(protocol, "nystrom", parts, 16, steps=2)
    Xn = np.zeros((3, X.shape[1]), np.float32)
    with pytest.raises(ValueError, match="out of range"):
        update(art, Xn, np.zeros(3, np.float32), machine=machine)


def test_update_malformed_batch_raises():
    X, y, _, _ = _problem(12)
    parts = split_machines(X, y, 3, jax.random.PRNGKey(12))
    art = fit(parts, 16, "center", steps=2)
    d = X.shape[1]
    with pytest.raises(ValueError, match="update expects"):
        update(art, np.zeros((3, d), np.float32), np.zeros((4,), np.float32))
    with pytest.raises(ValueError, match="update expects"):
        update(art, np.zeros((d,), np.float32), np.zeros((1,), np.float32))


def test_update_ledger_zero_for_locally_owned_data():
    """Data that never crosses the wire costs nothing, for all three
    protocols: the center's own points (§5.1), a PoE expert's own points
    (zero-rate by construction), and a zero-rate broadcast artifact (frozen
    rates are all zero, so streamed symbols carry no bits either)."""
    X, y, _, f = _problem(13)
    d = X.shape[1]
    parts = split_machines(X, y, 4, jax.random.PRNGKey(13))
    rng = np.random.default_rng(5)
    Xn = rng.normal(size=(6, d)).astype(np.float32)
    yn = f(Xn).astype(np.float32)

    art_c = fit(parts, 16, "center", steps=2)
    assert update(art_c, Xn, yn, machine=0).wire_bits == art_c.wire_bits

    art_p = fit(parts, 0, "poe", steps=2)
    for j in range(4):
        assert update(art_p, Xn, yn, machine=j).wire_bits == 0

    art_b = fit(parts, 0, "broadcast", steps=2)
    assert int(np.asarray(art_b.wire.rates).sum()) == 0
    assert (
        update(art_b, Xn, yn, machine=2).wire_bits == art_b.wire_bits
    )
    # and a non-zero-rate broadcast DOES charge the frozen per-machine rate
    art_b24 = fit(parts, 24, "broadcast", steps=2)
    rate2 = int(np.asarray(art_b24.wire.rates[2]).sum())
    assert (
        update(art_b24, Xn, yn, machine=2).wire_bits
        == art_b24.wire_bits + 6 * rate2
    )


# --------------------------------------------------------------------------
# the rank-k cholesky primitives themselves
# --------------------------------------------------------------------------


def test_chol_update_rank_matches_refactorization():
    rng = np.random.default_rng(0)
    A = rng.normal(size=(6, 6)).astype(np.float32)
    A = A @ A.T + 6 * np.eye(6, dtype=np.float32)
    V = rng.normal(size=(6, 3)).astype(np.float32)
    L = jnp.linalg.cholesky(jnp.asarray(A))
    L_up = chol_update_rank(L, jnp.asarray(V))
    L_ref = jnp.linalg.cholesky(jnp.asarray(A + V @ V.T))
    np.testing.assert_allclose(np.asarray(L_up), np.asarray(L_ref), atol=1e-4)


def test_chol_append_matches_refactorization():
    rng = np.random.default_rng(1)
    M = rng.normal(size=(9, 9)).astype(np.float32)
    M = M @ M.T + 9 * np.eye(9, dtype=np.float32)
    A, C_on, C_nn = M[:6, :6], M[:6, 6:], M[6:, 6:]
    L = jnp.linalg.cholesky(jnp.asarray(A))
    L_app = chol_append(L, jnp.asarray(C_on), jnp.asarray(C_nn))
    L_ref = jnp.linalg.cholesky(jnp.asarray(M))
    np.testing.assert_allclose(np.asarray(L_app), np.asarray(L_ref), atol=1e-4)
