"""Locks the vmapped padded-shard protocol to the serial seed protocol's
semantics, and the Pallas gram backend to the reference path.

Semantics locked:
  * own / center block is EXACT (bit-identical to the local data);
  * wire-bit accounting identical to the host scipy PerSymbolScheme path;
  * decoded reconstructions, trained predictors, and fused predictives match
    the serial implementation within float tolerance;
  * gram_backend="pallas" (interpret mode on CPU) matches the reference path
    to <= 1e-4 max-abs on the paper-scale smoke problem.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import split_machines, single_center_gp, broadcast_gp, poe_baseline
from repro.core.distributed_gp import (
    quantize_to_center,
    pad_parts,
    _run_wire_protocol,
)


def _problem(seed=0, n=234, d=6, n_test=60):
    # n is deliberately NOT divisible by the machine counts used below, so the
    # padded-shard machinery (masks, -1 code sentinels, pinned diagonals) is
    # genuinely exercised by every equivalence check
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    Xt = rng.normal(size=(n_test, d)).astype(np.float32)
    return X, y, jnp.asarray(Xt)


def test_center_protocol_matches_serial():
    X, y, _ = _problem(0)
    parts = split_machines(X, y, 5, jax.random.PRNGKey(0))
    Xb, yb, wire_b, nc_b, sq_b = quantize_to_center(parts, 16, impl="batched")
    Xh, yh, wire_h, nc_h, sq_h = quantize_to_center(parts, 16, impl="host")
    # identical wire-bit accounting
    assert wire_b == wire_h
    assert nc_b == nc_h
    # own (center) block exact
    np.testing.assert_array_equal(np.asarray(Xb[:nc_b]), np.asarray(parts[0][0]))
    # targets unquantized and identically ordered; exact |x|^2 side channel
    np.testing.assert_array_equal(np.asarray(yb), np.asarray(yh))
    np.testing.assert_allclose(np.asarray(sq_b), np.asarray(sq_h), rtol=1e-6)
    # reconstructions match the serial scipy scheme within float tolerance
    np.testing.assert_allclose(np.asarray(Xb), np.asarray(Xh), atol=5e-4)


def test_broadcast_wire_accounting_and_own_blocks():
    X, y, _ = _problem(1)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(1))
    shards = pad_parts(parts)
    ws = _run_wire_protocol(shards.X, shards.mask, 24, 12, "broadcast", 0)
    # greedy allocation hands out exactly R bits per sample on every machine
    rates = np.asarray(ws.rates)
    assert (rates.sum(axis=1) == 24).all()
    # the wire is the packed code plane: R=24 bits/row in one uint32 word
    words = np.asarray(ws.codes)
    assert words.dtype == np.uint32 and words.shape[-1] == 1
    # padded rows decode to exactly zero, pack to all-zero words, and unpack
    # back to the -1 sentinel under the shard mask
    from repro.core import jax_scheme

    codes = np.asarray(jax.vmap(
        lambda w, r, mk: jax_scheme.unpack_codes(w, r, total_bits=24, mask=mk)
    )(ws.codes, ws.rates, shards.mask))
    for j, n_j in enumerate(shards.lengths):
        assert np.all(words[j, n_j:] == 0)
        assert np.all(codes[j, n_j:] == -1)
        assert np.all(codes[j, :n_j] >= 0)
        assert np.all(np.asarray(ws.decoded[j, n_j:]) == 0.0)


def test_batched_end_to_end_matches_serial():
    X, y, Xt = _problem(2)
    parts = split_machines(X, y, 5, jax.random.PRNGKey(2))
    m_b = single_center_gp(parts, 16, kernel="se", steps=15)
    m_h = single_center_gp(parts, 16, kernel="se", steps=15, impl="host",
                           train_impl="loop")
    assert m_b.wire_bits == m_h.wire_bits
    mu_b, v_b = m_b.predict(Xt)
    mu_h, v_h = m_h.predict(Xt)
    np.testing.assert_allclose(np.asarray(mu_b), np.asarray(mu_h), atol=2e-3)
    np.testing.assert_allclose(np.asarray(v_b), np.asarray(v_h), atol=2e-3)

    mu_b, s2_b, w_b, _ = broadcast_gp(parts, 24, Xt, kernel="se", steps=15)
    mu_h, s2_h, w_h, _ = broadcast_gp(parts, 24, Xt, kernel="se", steps=15,
                                      impl="host", train_impl="loop")
    assert w_b == w_h
    np.testing.assert_allclose(np.asarray(mu_b), np.asarray(mu_h), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s2_b), np.asarray(s2_h), atol=2e-3)

    mu_b, s2_b, _ = poe_baseline(parts, Xt, kernel="se", steps=15)
    mu_h, s2_h, _ = poe_baseline(parts, Xt, kernel="se", steps=15, impl="host",
                                 train_impl="loop")
    np.testing.assert_allclose(np.asarray(mu_b), np.asarray(mu_h), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s2_b), np.asarray(s2_h), atol=2e-3)


@pytest.mark.parametrize("gram_mode", ["nystrom", "nystrom_fitc", "direct"])
def test_pallas_backend_matches_reference_center(gram_mode):
    X, y, Xt = _problem(3, n=201, d=6)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(3))
    m_p = single_center_gp(parts, 16, kernel="se", steps=5, gram_mode=gram_mode,
                           gram_backend="pallas")
    m_x = single_center_gp(parts, 16, kernel="se", steps=5, gram_mode=gram_mode)
    mu_p, v_p = m_p.predict(Xt)
    mu_x, v_x = m_x.predict(Xt)
    assert float(jnp.max(jnp.abs(mu_p - mu_x))) <= 1e-4
    assert float(jnp.max(jnp.abs(v_p - v_x))) <= 1e-4


@pytest.mark.parametrize("gram_mode", ["nystrom", "direct"])
def test_pallas_backend_matches_reference_broadcast(gram_mode):
    X, y, Xt = _problem(4, n=158, d=6)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(4))
    mu_p, s2_p, w_p, _ = broadcast_gp(parts, 24, Xt, kernel="se", steps=5,
                                      gram_mode=gram_mode, gram_backend="pallas")
    mu_x, s2_x, w_x, _ = broadcast_gp(parts, 24, Xt, kernel="se", steps=5,
                                      gram_mode=gram_mode)
    assert w_p == w_x
    assert float(jnp.max(jnp.abs(mu_p - mu_x))) <= 1e-4
    assert float(jnp.max(jnp.abs(s2_p - s2_x))) <= 1e-4


def test_bit_allocation_parity_with_zero_variance_dims():
    """The fori_loop allocator must stop exactly where the host heap stops:
    once every positive-variance dim is capped, zero-variance dims get NO
    bits (their gain is 0), keeping wire-bit accounting identical."""
    from repro.core import jax_scheme
    from repro.core import quantizers as Q

    d = 3
    lam = np.array([4.0, 1.0, 0.0])
    Qx = np.diag(lam).astype(np.float32)
    Qy = np.eye(d, dtype=np.float32)
    state = jax_scheme.fit_scheme(jnp.asarray(Qx), jnp.asarray(Qy), 30, 12)
    host = Q.allocate_bits_greedy(lam, 30, 12)
    np.testing.assert_array_equal(np.sort(np.asarray(state["rates"])), np.sort(host))
    assert int(np.asarray(state["rates"]).sum()) == int(host.sum()) == 24


def test_train_gp_pallas_backend_matches_xla():
    """Full training through the Pallas gram (exercises the kernel's custom
    VJP: grads of the NLML flow through the tiled gram product)."""
    X, y, Xt = _problem(6, n=96, d=4)
    from repro.core import train_gp

    m_p = train_gp(X, y, kernel="se", steps=5, gram_backend="pallas")
    m_x = train_gp(X, y, kernel="se", steps=5)
    for a, b in zip(m_p.params, m_x.params):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    mu_p, _ = m_p.predict(Xt)
    mu_x, _ = m_x.predict(Xt)
    assert float(jnp.max(jnp.abs(mu_p - mu_x))) <= 1e-4


def test_scan_training_matches_loop():
    X, y, Xt = _problem(5, n=120, d=4)
    from repro.core import train_gp

    m_s = train_gp(X, y, kernel="se", steps=25, impl="scan")
    m_l = train_gp(X, y, kernel="se", steps=25, impl="loop")
    for a, b in zip(m_s.params, m_l.params):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    mu_s, _ = m_s.predict(Xt)
    mu_l, _ = m_l.predict(Xt)
    np.testing.assert_allclose(np.asarray(mu_s), np.asarray(mu_l), atol=1e-4)
