"""The Snelson–Ghahramani exact-diagonal (FITC) gram mode the paper cites."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import split_machines, single_center_gp
from repro.core.gp import gram_fn


def _problem(seed=0, n=200, d=5):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (np.sin(X @ np.ones(d)) + 0.05 * rng.normal(size=n)).astype(np.float32)
    return X, y


def test_fitc_gram_diagonal_is_exact():
    X, y = _problem()
    parts = split_machines(X, y, 5, jax.random.PRNGKey(0))
    m = single_center_gp(parts, 16, kernel="se", steps=10, gram_mode="nystrom_fitc")
    G = np.asarray(m._gram(m.params))
    k = gram_fn("se")
    # SE prior variance is constant = exp(log_a)
    expected = float(np.exp(np.asarray(m.params.log_a)))
    np.testing.assert_allclose(np.diagonal(G), expected, rtol=1e-4)


def test_fitc_wire_accounts_for_sq_norms():
    X, y = _problem(1)
    parts = split_machines(X, y, 5, jax.random.PRNGKey(1))
    m_plain = single_center_gp(parts, 16, kernel="se", steps=2, gram_mode="nystrom")
    m_fitc = single_center_gp(parts, 16, kernel="se", steps=2, gram_mode="nystrom_fitc")
    n_noncenter = X.shape[0] - parts[0][0].shape[0]
    assert m_fitc.wire_bits == m_plain.wire_bits + 32 * n_noncenter


def test_fitc_predicts_finite_and_sane():
    X, y = _problem(2)
    parts = split_machines(X, y, 5, jax.random.PRNGKey(2))
    m = single_center_gp(parts, 48, kernel="se", steps=60, gram_mode="nystrom_fitc")
    mu, var = m.predict(jnp.asarray(X[:40]))
    assert np.all(np.isfinite(np.asarray(mu)))
    assert np.all(np.asarray(var) > 0)
    # better than predicting the mean
    assert float(np.mean((np.asarray(mu) - y[:40]) ** 2)) < np.var(y)
