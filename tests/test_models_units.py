"""Model building-block unit tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.config import ModelConfig
from repro.models.layers import (
    rmsnorm, init_rmsnorm, rope, attention_apply, init_attention, _attn_chunked,
    _group_q,
)
from repro.models.ssm import chunked_gla, gla_step
from repro.models.moe import init_moe, moe_apply


def _cfg(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=97, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base)


def test_rmsnorm_unit_scale():
    p = init_rmsnorm(8)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 8)) * 10, jnp.float32)
    y = rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relative_phase():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 6, 2, 8)), jnp.float32)
    pos = jnp.arange(6)[None]
    y = rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m - n
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 8)), jnp.float32)
    def dot_at(m, n):
        qm = rope(q, jnp.asarray([[m]]), 10000.0)
        kn = rope(k, jnp.asarray([[n]]), 10000.0)
        return float(jnp.sum(qm * kn))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)
    assert dot_at(4, 0) == pytest.approx(dot_at(9, 5), rel=1e-4)


def test_causal_mask_blocks_future():
    cfg = _cfg()
    params = init_attention(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)), jnp.float32)
    pos = jnp.arange(8)[None]
    out1 = attention_apply(params, x, cfg, positions=pos)
    x2 = x.at[0, -1].set(99.0)  # perturb the LAST position only
    out2 = attention_apply(params, x2, cfg, positions=pos)
    np.testing.assert_allclose(np.asarray(out1[0, :-1]), np.asarray(out2[0, :-1]), atol=1e-5)
    assert not np.allclose(np.asarray(out1[0, -1]), np.asarray(out2[0, -1]))


def test_sliding_window_restricts_attention():
    cfg = _cfg()
    params = init_attention(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(2)
    S = 16
    x = jnp.asarray(rng.normal(size=(1, S, cfg.d_model)), jnp.float32)
    pos = jnp.arange(S)[None]
    out_w = attention_apply(params, x, cfg, positions=pos, layer_window=4)
    # perturbing a token >= window away must not change the output
    x2 = x.at[0, 0].set(50.0)
    out_w2 = attention_apply(params, x2, cfg, positions=pos, layer_window=4)
    np.testing.assert_allclose(np.asarray(out_w[0, 8:]), np.asarray(out_w2[0, 8:]), atol=1e-5)


def test_chunked_attention_matches_dense():
    cfg = _cfg()
    params = init_attention(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(3)
    S = 100  # not a chunk multiple: exercises padding
    x = jnp.asarray(rng.normal(size=(2, S, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (2, S))
    q = (x @ params["wq"]).reshape(2, S, 4, 16)
    k = (x @ params["wk"]).reshape(2, S, 2, 16)
    v = (x @ params["wv"]).reshape(2, S, 2, 16)
    qg = _group_q(q, 2)
    import repro.models.layers as L
    dense = L._attn_dense(qg, k, v,
                          pos[:, None, None, :, None] >= pos[:, None, None, None, :], None)
    old = L.ATTN_CHUNK
    L.ATTN_CHUNK = 32
    try:
        chunked = _attn_chunked(qg, k, v, pos, pos, None, None, True)
    finally:
        L.ATTN_CHUNK = old
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=2e-3, atol=2e-3)


def test_gla_chunked_equals_stepwise():
    rng = np.random.default_rng(4)
    B, S, H, dk, dv = 1, 64, 2, 8, 4
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32)
    log_a = jnp.asarray(-rng.uniform(0.05, 1.0, size=(B, S, H)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=(B, S, H)), jnp.float32)
    y, st = chunked_gla(q, k, v, log_a, w, chunk=16)
    st2 = jnp.zeros((B, H, dk, dv))
    ys = []
    for t in range(S):
        yt, st2 = gla_step(q[:, t], k[:, t], v[:, t], log_a[:, t], w[:, t], st2)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2), rtol=1e-4, atol=1e-4)


def test_gla_state_continuity_across_calls():
    """Splitting a sequence across two chunked_gla calls == one call."""
    rng = np.random.default_rng(5)
    B, S, H, dk, dv = 1, 32, 1, 4, 4
    mk = lambda *sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    q, k, v = mk(B, S, H, dk), mk(B, S, H, dk), mk(B, S, H, dv)
    log_a = -jnp.abs(mk(B, S, H)) * 0.2
    w = jnp.abs(mk(B, S, H))
    y_full, st_full = chunked_gla(q, k, v, log_a, w, chunk=8)
    y1, st1 = chunked_gla(q[:, :16], k[:, :16], v[:, :16], log_a[:, :16], w[:, :16], chunk=8)
    y2, st2 = chunked_gla(q[:, 16:], k[:, 16:], v[:, 16:], log_a[:, 16:], w[:, 16:], state=st1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), rtol=1e-4, atol=1e-4)


def test_moe_routing_conservation():
    cfg = _cfg(family="moe", num_experts=4, top_k=2, moe_d_ff=32)
    params = init_moe(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    out, aux = moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux["drop_frac"]) <= 0.5  # generous capacity at this size
    assert float(aux["load_balance"]) >= 0.99  # >= 1 in expectation (E * sum(me*ce))


def test_moe_zero_router_uniform_dispatch():
    """With identical expert weights, MoE output must not depend on routing."""
    cfg = _cfg(family="moe", num_experts=4, top_k=2, moe_d_ff=32)
    params = init_moe(jax.random.PRNGKey(4), cfg)
    # make all experts identical
    w_in = params["w_in_e"][0]
    w_out = params["w_out_e"][0]
    params["w_in_e"] = jnp.broadcast_to(w_in[None], params["w_in_e"].shape)
    params["w_out_e"] = jnp.broadcast_to(w_out[None], params["w_out_e"].shape)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(1, 16, cfg.d_model)), jnp.float32)
    out, aux = moe_apply(params, x, cfg)
    # reference: single dense expert (gates sum to 1, no drops at this size)
    from repro.models.layers import mlp_apply
    ref = mlp_apply({"wi": w_in, "wo_mlp": w_out}, x.reshape(16, -1), cfg.activation)
    np.testing.assert_allclose(np.asarray(out.reshape(16, -1)), np.asarray(ref), rtol=2e-3, atol=2e-3)
