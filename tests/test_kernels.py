"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import quantizers as Q
from repro.kernels.gram.ops import gram
from repro.kernels.gram.ref import gram_ref
from repro.kernels.quant.ops import encode, decode, build_scaled_tables
from repro.kernels.quant.ref import encode_ref, decode_ref
from repro.kernels.qgram.ops import qgram
from repro.kernels.qgram.ref import qgram_ref


GRAM_SHAPES = [
    (8, 4, 8),        # tiny, all padding
    (128, 128, 128),  # exact single tile
    (130, 20, 50),    # ragged every axis
    (256, 384, 128),  # multi-tile
    (1, 1, 1),        # degenerate
]


@pytest.mark.parametrize("n,d,p", GRAM_SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_gram_matches_ref(n, d, p, dtype):
    rng = np.random.default_rng(n * 1000 + d)
    x = rng.normal(size=(n, d)).astype(dtype)
    y = rng.normal(size=(p, d)).astype(dtype)
    out = np.asarray(gram(x, y, interpret=True))
    ref = np.asarray(gram_ref(x, y))
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
    assert out.shape == (n, p) and out.dtype == np.float32


@pytest.mark.parametrize("block", [(128, 128, 128), (256, 128, 128)])
def test_gram_block_shapes(block):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(100, 40)).astype(np.float32)
    y = rng.normal(size=(60, 40)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(gram(x, y, block=block, interpret=True)),
        np.asarray(gram_ref(x, y)), rtol=1e-5, atol=1e-4,
    )


def _tables(rng, d, total_bits, max_bits=8):
    var = rng.uniform(0.05, 4.0, size=d)
    rates = Q.allocate_bits_greedy(var, total_bits, max_bits)
    sigma = np.sqrt(var).astype(np.float32)
    return sigma, rates, build_scaled_tables(sigma, rates)


@pytest.mark.parametrize("n,d,bits", [(64, 8, 24), (200, 20, 60), (128, 128, 200), (3, 5, 0)])
def test_quant_encode_decode_match_ref(n, d, bits):
    rng = np.random.default_rng(d)
    sigma, rates, (edges, cents) = _tables(rng, d, bits)
    x = (rng.normal(size=(n, d)) * sigma).astype(np.float32)
    ce = np.asarray(encode(x, edges, interpret=True))
    cr = np.asarray(encode_ref(jnp.asarray(x), edges))
    np.testing.assert_array_equal(ce, cr)
    xe = np.asarray(decode(jnp.asarray(ce), cents, interpret=True))
    xr = np.asarray(decode_ref(jnp.asarray(cr), cents))
    np.testing.assert_allclose(xe, xr, rtol=1e-6)


def test_quant_kernel_agrees_with_core_quantizers():
    rng = np.random.default_rng(7)
    d = 16
    sigma, rates, (edges, cents) = _tables(rng, d, 48)
    x = (rng.normal(size=(100, d)) * sigma).astype(np.float32)
    et, ct = Q.build_codebook_tables(int(max(rates.max(), 1)))
    c_core = Q.quantize(jnp.asarray(x), jnp.asarray(sigma), jnp.asarray(rates), et)
    c_kern = encode(x, edges, interpret=True)
    np.testing.assert_array_equal(np.asarray(c_core), np.asarray(c_kern))
    x_core = Q.dequantize(c_core, jnp.asarray(sigma), jnp.asarray(rates), ct)
    x_kern = decode(c_kern, cents, interpret=True)
    np.testing.assert_allclose(np.asarray(x_core), np.asarray(x_kern), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,d,p,bits", [(64, 8, 32, 24), (130, 20, 33, 60), (128, 128, 128, 256)])
def test_qgram_fused_matches_ref(n, d, p, bits):
    rng = np.random.default_rng(n + d)
    sigma, rates, (edges, cents) = _tables(rng, d, bits)
    x = (rng.normal(size=(n, d)) * sigma).astype(np.float32)
    y = rng.normal(size=(p, d)).astype(np.float32)
    codes = encode(x, edges, interpret=True)
    out = np.asarray(qgram(codes, cents, y, interpret=True))
    ref = np.asarray(qgram_ref(jnp.asarray(codes), cents, jnp.asarray(y)))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n,d,p,bits", [(64, 8, 32, 24), (130, 20, 33, 60), (50, 6, 20, 0)])
def test_qgram_packed_matches_ref(n, d, p, bits):
    """The packed-word kernel (unpack in-block, shift/mask, one-hot decode)
    against the three-step oracle — Pallas interpret AND the XLA fallback."""
    from repro.core import jax_scheme as js
    from repro.kernels.qgram.ops import qgram_packed
    from repro.kernels.qgram.ref import qgram_packed_ref

    rng = np.random.default_rng(n + d)
    sigma, rates, (edges, cents) = _tables(rng, d, bits)
    x = (rng.normal(size=(n, d)) * sigma).astype(np.float32)
    y = rng.normal(size=(p, d)).astype(np.float32)
    codes = encode(x, edges, interpret=True)
    mask = (np.arange(n) < n - 5).astype(np.float32)
    words = js.pack_codes(codes, jnp.asarray(rates), total_bits=bits,
                          mask=jnp.asarray(mask))
    kw = dict(total_bits=bits, mask=jnp.asarray(mask))
    ref = np.asarray(qgram_packed_ref(words, jnp.asarray(rates), cents, y, **kw))
    out_xla = np.asarray(qgram_packed(words, jnp.asarray(rates), cents, y, **kw))
    np.testing.assert_allclose(out_xla, ref, rtol=1e-5, atol=1e-5)
    if bits > 0:  # zero-rate rows have no words for a kernel block to load
        out_pal = np.asarray(
            qgram_packed(words, jnp.asarray(rates), cents, y, interpret=True, **kw)
        )
        np.testing.assert_allclose(out_pal, ref, rtol=1e-4, atol=1e-3)


def test_qgram_packed_equals_unpacked_qgram():
    """The packed kernel and the legacy int-code kernel are the same math:
    identical grams from the same scheme output."""
    from repro.core import jax_scheme as js
    from repro.kernels.qgram.ops import qgram_packed

    rng = np.random.default_rng(17)
    n, d, p, bits = 70, 12, 40, 36
    sigma, rates, (edges, cents) = _tables(rng, d, bits)
    x = (rng.normal(size=(n, d)) * sigma).astype(np.float32)
    y = rng.normal(size=(p, d)).astype(np.float32)
    codes = encode(x, edges, interpret=True)
    words = js.pack_codes(codes, jnp.asarray(rates), total_bits=bits)
    packed = np.asarray(
        qgram_packed(words, jnp.asarray(rates), cents, y, total_bits=bits,
                     interpret=True)
    )
    unpacked = np.asarray(qgram(codes, cents, y, interpret=True))
    np.testing.assert_allclose(packed, unpacked, rtol=1e-4, atol=1e-3)


def test_qgram_equals_decode_then_gram():
    """The fusion must be exactly decode∘gram."""
    rng = np.random.default_rng(9)
    d = 12
    sigma, rates, (edges, cents) = _tables(rng, d, 36)
    x = (rng.normal(size=(70, d)) * sigma).astype(np.float32)
    y = rng.normal(size=(40, d)).astype(np.float32)
    codes = encode(x, edges, interpret=True)
    xhat = decode(codes, cents, interpret=True)
    fused = np.asarray(qgram(codes, cents, y, interpret=True))
    twostep = np.asarray(gram(xhat, y, interpret=True))
    np.testing.assert_allclose(fused, twostep, rtol=1e-4, atol=1e-3)
