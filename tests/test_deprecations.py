"""The legacy entry points live on as DeprecationWarning wrappers.

Locks the migration contract:
  * each of the seven deprecated entry points (quantize_to_center,
    single_center_gp, broadcast_gp, poe_baseline, fit, predict, update)
    warns EXACTLY ONCE per process — the first call emits one
    DeprecationWarning naming the replacement, repeat calls are silent;
  * delegation is faithful: the wrappers return the same objects as the new
    implementations;
  * the old core.mesh_gp shim (deprecated two PRs ago) is gone for real.
"""
import warnings

import numpy as np
import jax
import pytest

from repro.core import distributed_gp as dgp
from repro.core.protocols import split_machines

DEPRECATED = (
    "quantize_to_center", "single_center_gp", "broadcast_gp", "poe_baseline",
    "fit", "predict", "update",
)


def _tiny_problem():
    rng = np.random.default_rng(0)
    d = 3
    X = rng.normal(size=(60, d)).astype(np.float32)
    y = rng.normal(size=60).astype(np.float32)
    Xt = rng.normal(size=(8, d)).astype(np.float32)
    parts = split_machines(X, y, 3, jax.random.PRNGKey(0))
    return parts, Xt


def test_deprecated_wrappers_warn_exactly_once_each():
    parts, Xt = _tiny_problem()
    dgp._WARNED.clear()  # make the test independent of suite ordering
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        art = dgp.fit(parts, 8, "center", steps=0)
        dgp.fit(parts, 8, "center", steps=0)
        dgp.predict(art, Xt)
        dgp.predict(art, Xt)
        Xn = np.zeros((2, 3), np.float32)
        dgp.update(art, Xn, np.zeros(2, np.float32), machine=0)
        dgp.update(art, Xn, np.zeros(2, np.float32), machine=1)
        dgp.quantize_to_center(parts, 8)
        dgp.quantize_to_center(parts, 8)
        dgp.single_center_gp(parts, 8, steps=0)
        dgp.single_center_gp(parts, 8, steps=0)
        dgp.broadcast_gp(parts, 8, Xt, steps=0)
        dgp.broadcast_gp(parts, 8, Xt, steps=0)
        dgp.poe_baseline(parts, Xt, steps=0)
        dgp.poe_baseline(parts, Xt, steps=0)
    ours = [
        str(w.message) for w in rec
        if issubclass(w.category, DeprecationWarning)
        and str(w.message).startswith("repro.core.distributed_gp.")
    ]
    for name in DEPRECATED:
        hits = [m for m in ours
                if m.startswith(f"repro.core.distributed_gp.{name} is deprecated")]
        assert len(hits) == 1, f"{name}: expected exactly 1 warning, got {hits}"
    assert len(ours) == len(DEPRECATED)


def test_wrappers_delegate_faithfully():
    from repro.core import protocols

    parts, Xt = _tiny_problem()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        art_old = dgp.fit(parts, 8, "center", steps=2)
        mu_old, s2_old = dgp.predict(art_old, Xt)
    art_new = protocols.fit(parts, 8, "center", steps=2)
    mu_new, s2_new = protocols.predict(art_new, Xt)
    np.testing.assert_array_equal(np.asarray(mu_old), np.asarray(mu_new))
    np.testing.assert_array_equal(np.asarray(s2_old), np.asarray(s2_new))
    assert type(art_old) is type(art_new)
    assert art_old.wire_bits == art_new.wire_bits


def test_mesh_gp_shim_is_gone():
    with pytest.raises(ModuleNotFoundError):
        import repro.core.mesh_gp  # noqa: F401
    # its survivor lives in the protocols package
    from repro.core.protocols.mesh import broadcast_gp_mesh  # noqa: F401
