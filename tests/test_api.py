"""The registry-backed estimator API (core.config.DGPConfig /
core.api.DistributedGP / core.registry).

Locks the redesign's contract:
  * DGPConfig validates at CONSTRUCTION: bad protocol/scheme/impl/fusion/
    kernel names raise ValueError with the registry's known names in the
    message; cross-field vq constraints are enforced there too;
  * registering a duplicate name in any registry raises;
  * all 3 protocols x all 3 impls (host/batched/mesh) are reachable through
    DistributedGP(DGPConfig(...)) and agree with the legacy entry points;
  * scheme="vq" (the §4.1 Theorem-2 optimal test channel) runs end-to-end on
    the wire for the batched impl, with the ledger charged at the channel's
    achieved rate (matched to the per-symbol budget) and streaming update()
    re-encoding under the FROZEN channel;
  * the fitted artifact carries its config, and save_artifact records it
    (plus a format version) in meta.json.
"""
import dataclasses
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    DGPConfig,
    DistributedGP,
    FittedProtocol,
    KERNELS,
    FUSIONS,
    PROTOCOLS,
    SCHEMES,
    FusionSpec,
    SchemeSpec,
    register_fusion,
    register_scheme,
)
from repro.core.protocols import split_machines
from repro.core.protocols.center import CenterGP


def _problem(seed=0, n=140, d=4, m=4, n_test=20):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    Xt = rng.normal(size=(n_test, d)).astype(np.float32)
    return X, y, jnp.asarray(Xt)


# --------------------------------------------------------------------------
# DGPConfig validation
# --------------------------------------------------------------------------


def test_default_config_is_valid():
    cfg = DGPConfig()
    assert cfg.protocol == "center" and cfg.scheme == "per_symbol"
    # frozen: field assignment is an error
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.protocol = "broadcast"


@pytest.mark.parametrize(
    "field,value,registry",
    [
        ("protocol", "centre", PROTOCOLS),
        ("scheme", "vector-q", SCHEMES),
        ("kernel", "matern", KERNELS),
        ("fusion", "klqb", FUSIONS),
    ],
)
def test_bad_registry_names_raise_with_known_names(field, value, registry):
    with pytest.raises(ValueError) as ei:
        DGPConfig(**{field: value})
    msg = str(ei.value)
    assert value in msg
    for known in registry.names():
        assert known in msg  # the menu is in the error


@pytest.mark.parametrize(
    "field,value",
    [("impl", "tpu"), ("gram_backend", "triton"), ("gram_mode", "fitc"),
     ("train_impl", "while")],
)
def test_bad_enum_fields_raise(field, value):
    with pytest.raises(ValueError, match=field):
        DGPConfig(**{field: value})


@pytest.mark.parametrize("impl", ["host", "mesh"])
def test_pallas_requires_batched_at_construction(impl):
    with pytest.raises(ValueError, match="pallas"):
        DGPConfig(gram_backend="pallas", impl=impl)


def test_numeric_field_validation():
    with pytest.raises(ValueError, match="bits_per_sample"):
        DGPConfig(bits_per_sample=-1)
    with pytest.raises(ValueError, match="steps"):
        DGPConfig(steps=-5)


@pytest.mark.parametrize(
    "kw",
    [
        dict(scheme="vq", impl="mesh"),
        dict(scheme="vq", impl="host"),
        dict(scheme="vq", gram_backend="pallas"),
        dict(scheme="vq", protocol="poe"),
    ],
)
def test_vq_cross_constraints(kw):
    with pytest.raises(ValueError, match="vq"):
        DGPConfig(**kw)


def test_duplicate_registration_raises():
    name = "test_dup_entry_xyzzy"
    register_fusion(FusionSpec(name=name, fuse=lambda m, s, p: (m, s)))
    with pytest.raises(ValueError, match="duplicate"):
        register_fusion(FusionSpec(name=name, fuse=lambda m, s, p: (m, s)))
    with pytest.raises(ValueError, match="duplicate"):
        register_scheme(SchemeSpec(
            name="per_symbol", run=lambda *a: None, reencode=lambda *a: None,
        ))


def test_registered_fusion_is_selectable():
    # a brand-new fusion rule plugs into the batched serve path by name only
    name = "test_mean_fusion_xyzzy"
    if name not in FUSIONS:
        register_fusion(FusionSpec(
            name=name,
            fuse=lambda mus, s2s, prior: (jnp.mean(mus, 0), jnp.mean(s2s, 0)),
        ))
    X, y, Xt = _problem()
    est = DistributedGP(DGPConfig(protocol="broadcast", fusion=name,
                                  bits_per_sample=16, steps=2))
    art = est.fit(X, y, 3)
    mu, s2 = est.predict(art, Xt)
    assert np.all(np.isfinite(np.asarray(mu))) and np.all(np.asarray(s2) > 0)


# --------------------------------------------------------------------------
# the facade reaches every protocol x impl
# --------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", ["center", "broadcast", "poe"])
@pytest.mark.parametrize("impl", ["host", "batched", "mesh"])
def test_facade_reaches_all_protocols_and_impls(protocol, impl):
    X, y, Xt = _problem(seed=3)
    cfg = DGPConfig(
        protocol=protocol,
        impl=impl,
        bits_per_sample=0 if protocol == "poe" else 16,
        fusion="rbcm" if protocol == "poe" else "kl",
        steps=2,
    )
    est = DistributedGP(cfg)
    art = est.fit(X, y, 4, key=jax.random.PRNGKey(3))
    if impl == "host":
        assert not isinstance(art, FittedProtocol)  # oracle model
        if protocol == "center":
            assert isinstance(art, CenterGP)
    else:
        assert isinstance(art, FittedProtocol)
        assert art.impl == impl and art.config == cfg
    mu, s2 = est.predict(art, Xt)
    assert mu.shape == (Xt.shape[0],)
    assert np.all(np.isfinite(np.asarray(mu))) and np.all(np.asarray(s2) > 0)


def test_facade_matches_legacy_entry_point():
    X, y, Xt = _problem(seed=4)
    parts = split_machines(X, y, 4, jax.random.PRNGKey(4))
    est = DistributedGP(DGPConfig(bits_per_sample=16, steps=5))
    art = est.fit(parts=parts)
    from repro.core.protocols import fit as new_fit, predict as new_predict

    art_legacy = new_fit(parts, 16, "center", steps=5)
    mu_a, s2_a = est.predict(art, Xt)
    mu_b, s2_b = new_predict(art_legacy, Xt)
    np.testing.assert_array_equal(np.asarray(mu_a), np.asarray(mu_b))
    np.testing.assert_array_equal(np.asarray(s2_a), np.asarray(s2_b))
    assert art.wire_bits == art_legacy.wire_bits


def test_facade_fit_argument_errors():
    X, y, _ = _problem()
    est = DistributedGP()
    with pytest.raises(ValueError, match="either"):
        est.fit(X, y)  # m missing
    parts = split_machines(X, y, 2, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="not both"):
        est.fit(X, y, 2, parts=parts)
    with pytest.raises(ValueError, match="not both"):
        est.fit(parts=parts, key=jax.random.PRNGKey(7))  # key would be unused
    with pytest.raises(TypeError):
        DistributedGP(config="center")


@pytest.mark.parametrize("impl", ["host", "batched"])
def test_center_out_of_range_raises(impl):
    X, y, _ = _problem()
    est = DistributedGP(DGPConfig(protocol="center", center=7, impl=impl,
                                  bits_per_sample=8, steps=0))
    with pytest.raises(ValueError, match="center=7 out of range"):
        est.fit(X, y, 4)


@pytest.mark.parametrize("protocol", ["broadcast", "poe"])
def test_host_oracles_honor_warm_start_params(protocol):
    from repro.core import init_params

    X, y, _ = _problem()
    cfg = DGPConfig(protocol=protocol, impl="host",
                    bits_per_sample=0 if protocol == "poe" else 8,
                    fusion="rbcm" if protocol == "poe" else "kl", steps=0)
    est = DistributedGP(cfg)
    warm = init_params(a=3.0, b=2.0, noise=0.3)
    model = est.fit(X, y, 3, params=warm)
    # steps=0: training is a no-op, so fit must return exactly the warm start
    np.testing.assert_allclose(float(model.params.log_a), float(warm.log_a))
    np.testing.assert_allclose(float(model.params.log_noise), float(warm.log_noise))


# --------------------------------------------------------------------------
# scheme="vq": the optimal test channel on the wire
# --------------------------------------------------------------------------


@pytest.mark.parametrize("protocol", ["center", "broadcast"])
def test_vq_end_to_end_with_matched_ledger(protocol):
    X, y, Xt = _problem(seed=5, n=160, d=4, m=4)
    bits = 16
    vq = DistributedGP(DGPConfig(protocol=protocol, scheme="vq",
                                 bits_per_sample=bits, steps=3))
    ps = DistributedGP(DGPConfig(protocol=protocol, scheme="per_symbol",
                                 bits_per_sample=bits, steps=3))
    key = jax.random.PRNGKey(5)
    art_vq = vq.fit(X, y, 4, key=key)
    art_ps = ps.fit(X, y, 4, key=key)
    assert art_vq.scheme == "vq" and art_ps.scheme == "per_symbol"
    mu, s2 = vq.predict(art_vq, Xt)
    assert np.all(np.isfinite(np.asarray(mu))) and np.all(np.asarray(s2) > 0)
    # matched budgets: the channel's achieved Theorem-1 rate is ~R, so the
    # ledgers (same side-info accounting) agree within a few percent
    assert art_vq.wire_bits > 0
    assert abs(art_vq.wire_bits - art_ps.wire_bits) <= 0.05 * art_ps.wire_bits
    # the channel state rides in the artifact for streaming re-encode
    for k in ("vq_A", "vq_W_half", "vq_rate_bits"):
        assert k in art_vq.data


def test_vq_update_charges_frozen_channel_rate():
    X, y, Xt = _problem(seed=6, n=120, d=3, m=3)
    est = DistributedGP(DGPConfig(protocol="center", scheme="vq",
                                  bits_per_sample=12, steps=2))
    art = est.fit(X, y, 3)
    rng = np.random.default_rng(0)
    n_new = 9
    Xn = rng.normal(size=(n_new, 3)).astype(np.float32)
    art2 = est.update(art, Xn, np.zeros(n_new, np.float32), machine=1)
    rate = float(np.asarray(art.data["vq_rate_bits"][1]))
    assert art2.wire_bits == art.wire_bits + int(np.ceil(n_new * rate))
    # center-local points stay free, as with per-symbol
    art3 = est.update(art, Xn, np.zeros(n_new, np.float32), machine=0)
    assert art3.wire_bits == art.wire_bits
    mu, s2 = est.predict(art2, Xt)
    assert np.all(np.isfinite(np.asarray(mu))) and np.all(np.asarray(s2) > 0)


def test_vq_checkpoint_roundtrip(tmp_path):
    X, y, Xt = _problem(seed=7, n=100, d=3, m=3)
    est = DistributedGP(DGPConfig(protocol="broadcast", scheme="vq",
                                  bits_per_sample=10, steps=2))
    art = est.fit(X, y, 3)
    est.save(art, str(tmp_path))
    loaded = est.load(str(tmp_path))
    assert loaded.scheme == "vq" and loaded.config.scheme == "vq"
    mu_a, s2_a = est.predict(art, Xt)
    mu_b, s2_b = est.predict(loaded, Xt)
    np.testing.assert_array_equal(np.asarray(mu_a), np.asarray(mu_b))
    np.testing.assert_array_equal(np.asarray(s2_a), np.asarray(s2_b))


# --------------------------------------------------------------------------
# the config rides on the artifact and into meta.json
# --------------------------------------------------------------------------


def test_artifact_and_checkpoint_carry_config(tmp_path):
    from repro.core.config import ARTIFACT_FORMAT_VERSION

    X, y, Xt = _problem(seed=8)
    cfg = DGPConfig(protocol="center", bits_per_sample=12, steps=2,
                    gram_mode="nystrom_fitc")
    est = DistributedGP(cfg)
    art = est.fit(X, y, 3)
    assert art.config == cfg
    est.save(art, str(tmp_path))
    with open(os.path.join(str(tmp_path), "meta_00000000.json")) as f:
        meta = json.load(f)
    assert meta["format_version"] == ARTIFACT_FORMAT_VERSION
    assert meta["scheme"] == "per_symbol"
    assert meta["config"]["protocol"] == "center"
    assert meta["config"]["gram_mode"] == "nystrom_fitc"
    assert meta["config"]["steps"] == 2
    loaded = est.load(str(tmp_path))
    assert loaded.config == cfg


def test_future_format_version_refuses_to_load(tmp_path):
    X, y, _ = _problem(seed=9, n=60, m=2)
    est = DistributedGP(DGPConfig(bits_per_sample=8, steps=0))
    est.save(est.fit(X, y, 2), str(tmp_path))
    mp = os.path.join(str(tmp_path), "meta_00000000.json")
    with open(mp) as f:
        meta = json.load(f)
    meta["format_version"] = 99
    with open(mp, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="format version 99"):
        est.load(str(tmp_path))


def test_vq_respects_max_bits_cap():
    """When the per-dimension cap binds (d*max_bits < R), the vq target rate
    clamps to the same ceiling the per-symbol allocator has, keeping the two
    ledgers matched."""
    X, y, _ = _problem(seed=10, n=90, d=3, m=3)
    key = jax.random.PRNGKey(10)
    arts = {}
    for scheme in ("per_symbol", "vq"):
        est = DistributedGP(DGPConfig(protocol="center", scheme=scheme,
                                      bits_per_sample=24, max_bits=2, steps=0))
        arts[scheme] = est.fit(X, y, 3, key=key)
    lo, hi = sorted(a.wire_bits for a in arts.values())
    assert hi - lo <= 0.05 * hi, arts
