"""Paper Fig. 7: sparse GP with QUANTIZED INDUCING variables (single-center)
on the KIN40K-scale dataset — the paper's remedy for the very-low-rate regime
('transmit fewer samples at acceptable quality').

Protocol: each machine trains Titsias inducing points locally (method of
[27]), quantizes the inducing INPUTS Z_j with the per-symbol scheme, and ships
them with its variational summary q(u_j) = N(m_j, diag S_j) (a handful of
floats).  The center treats the pooled pseudo-points as heteroscedastic
observations (noise_i = S_i) of one GP and serves the posterior.

Validates: at low bits/sample this beats the non-sparse quantized model
(Fig. 6) and the PoE baselines.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import split_machines, train_sgpr, poe_baseline
from repro.core.gp import gram_fn, posterior_from_gram
from repro.core.schemes import PerSymbolScheme
from repro.core.distortion import second_moment
from repro.data import regression_dataset
from .common import timed, emit, smse


def main(quick: bool = True, data_dir: str | None = None, seed: int = 0):
    X, y, Xt, yt = regression_dataset("kin40k", data_dir=data_dir)
    n_test = 300 if quick else 2000
    Xt, yt = jnp.asarray(Xt[:n_test]), yt[:n_test]
    m_machines = 10 if quick else 40
    n_inducing = 10 if quick else 15
    steps = 120 if quick else 250
    d = X.shape[1]

    parts = split_machines(X, y, m_machines, jax.random.PRNGKey(seed))
    mu, _, _ = poe_baseline(parts, Xt, kernel="se", method="rbcm", steps=steps)
    emit("fig7", 0.0, model="rbcm", R=0, smse=smse(yt, mu))

    # per-machine local sparse GPs (the expensive, communication-free part)
    locals_ = []
    for j, (Xj, yj) in enumerate(parts):
        sg = train_sgpr(np.asarray(Xj), np.asarray(yj), n_inducing, steps=steps,
                        key=jax.random.PRNGKey(100 + j))
        locals_.append((sg, *sg.qu()))

    S_c = np.asarray(second_moment(parts[0][0]), np.float64)
    p0 = locals_[0][0].params
    k = gram_fn("se")

    for R in ([2, 4, 8, 16, 32] if quick else [1, 2, 4, 8, 16, 32, 64]):
        def build():
            # center's own raw block enters exactly (noise sigma_eps^2);
            # peers contribute quantized pseudo-points with q(u) variances
            s2_center = float(np.exp(np.asarray(p0.log_noise)))
            X0, y0 = np.asarray(parts[0][0]), np.asarray(parts[0][1])
            Zs, mus, vars_ = [X0], [y0], [np.full(X0.shape[0], s2_center)]
            wire = 0
            for j, (sg, m_u, s_u) in enumerate(locals_):
                if j == 0:
                    continue
                Z = np.asarray(sg.Z)
                Qz = np.cov(Z.T) + 1e-4 * np.eye(d)
                sch = PerSymbolScheme(R).fit(Qz, S_c)
                Zs.append(np.asarray(sch.roundtrip(Z)))
                wire += sch.wire_bits(Z.shape[0]) + sch.side_info_bits(d)
                wire += 2 * Z.shape[0] * 16  # m_u + S_u at 16 bits each
                mus.append(np.asarray(m_u))
                vars_.append(np.asarray(s_u))
            Z_all = jnp.asarray(np.concatenate(Zs), jnp.float32)
            y_ps = jnp.asarray(np.concatenate(mus), jnp.float32)
            noise = jnp.asarray(np.concatenate(vars_), jnp.float32)
            return Z_all, y_ps, noise, wire

        (Z_all, y_ps, noise, wire), us = timed(build, repeats=1)
        G = k(p0, Z_all)
        G_sn = k(p0, Xt, Z_all)
        g_ss = jnp.diagonal(k(p0, Xt, Xt))
        mu, _ = posterior_from_gram(G, G_sn, g_ss, y_ps, noise)
        emit("fig7", us, model="sparse_quantized", R=R, smse=smse(yt, mu),
             wire_kbits=wire / 1e3)


if __name__ == "__main__":
    main()
