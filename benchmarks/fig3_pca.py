"""Paper Fig. 3: proposed dimension reduction vs PCA in four settings:
(a) Gaussian, different covariances per machine
(b) Gaussian, identical covariance
(c) MNIST-like: digit 6 on machine 1, digit 7 on machine 2
(d) MNIST-like: both digits split uniformly

Validates: proposed < PCA exactly when the two machines' covariances differ
(a, c); ties when they match (b, d).
"""
from __future__ import annotations

import numpy as np

from repro.core.schemes import DimReductionScheme, PCAScheme
from repro.core.distortion import distortion_quadratic, second_moment
from repro.data import mnist_like_two_digits
from .common import timed, emit


def _gauss(rng, d, n, same_cov):
    A = rng.normal(size=(d, d)); Qx = A @ A.T / d
    if same_cov:
        Qy = Qx
    else:
        B = rng.normal(size=(d, d)); Qy = B @ B.T / d
    X = rng.multivariate_normal(np.zeros(d), Qx, size=n).astype(np.float32)
    Y = rng.multivariate_normal(np.zeros(d), Qy, size=n).astype(np.float32)
    return X, Y


def _compare(tag, X, Y, ms):
    Sx = np.asarray(second_moment(X), np.float64)
    Sy = np.asarray(second_moment(Y), np.float64)
    out = {}
    for m in ms:
        dr = DimReductionScheme(m).fit(Sx, Sy)
        pc = PCAScheme(m).fit(Sx)
        (e_dr, us) = timed(lambda: float(distortion_quadratic(X, dr.roundtrip(X), Sy)))
        e_pc = float(distortion_quadratic(X, pc.roundtrip(X), Sy))
        emit(f"fig3{tag}", us, m=m, proposed=e_dr, pca=e_pc,
             ratio=e_dr / max(e_pc, 1e-12))
        out[m] = (e_dr, e_pc)
    return out


def main(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    d, n = 20, 3000
    ms = [2, 4, 8, 12, 16] if quick else list(range(1, d))
    res = {}
    X, Y = _gauss(rng, d, n, same_cov=False)
    res["a"] = _compare("a_diff_cov", X, Y, ms)
    X, Y = _gauss(rng, d, n, same_cov=True)
    res["b"] = _compare("b_same_cov", X, Y, ms)

    six, seven = mnist_like_two_digits(n_per_digit=600 if quick else 1000, seed=seed)
    ms_img = [5, 10, 20, 40] if quick else [2, 5, 10, 20, 40, 80]
    res["c"] = _compare("c_mnist_split_by_digit", six, seven, ms_img)
    both = np.concatenate([six, seven])
    rng.shuffle(both)
    half = both.shape[0] // 2
    res["d"] = _compare("d_mnist_uniform", both[:half], both[half:], ms_img)
    return res


if __name__ == "__main__":
    main()
