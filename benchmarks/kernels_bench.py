"""Pallas kernel microbenchmarks (interpret mode on CPU; on-TPU the same
entry points compile natively).  Reports us/call and achieved element rates,
plus the fused-vs-unfused HBM-traffic ratio that motivates kernels/qgram,
and a FlagGems-style shape sweep of every registered backend of the key ops
through the unified kernel runtime (``kernel_sweep/<op>/<case>/<backend>``
rows — the honest table of when the XLA fallback beats the interpreter).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import quantizers as Q
from repro.kernels import runtime
from repro.kernels.gram.ops import gram
from repro.kernels.gram.ref import gram_ref
from repro.kernels.quant.ops import encode, decode, build_scaled_tables
from repro.kernels.qgram.ops import qgram
from repro.kernels.decode_attn.ops import decode_attn
from repro.kernels.epilogue.ops import epilogue_moments
from .common import timed, emit


def main(quick: bool = True):
    rng = np.random.default_rng(0)
    n, d, p = (256, 64, 256) if quick else (1024, 128, 1024)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(p, d)).astype(np.float32)

    _, us = timed(lambda: jax.block_until_ready(gram(x, y, interpret=True)), repeats=2)
    _, us_ref = timed(lambda: jax.block_until_ready(gram_ref(x, y)), repeats=2)
    emit("kernel_gram", us, flops=2 * n * d * p, ref_us=us_ref)

    var = rng.uniform(0.1, 2.0, size=d)
    rates = Q.allocate_bits_greedy(var, 4 * d, 8)
    sigma = np.sqrt(var).astype(np.float32)
    edges, cents = build_scaled_tables(sigma, rates)
    xs = (rng.normal(size=(n, d)) * sigma).astype(np.float32)
    codes, us = timed(lambda: jax.block_until_ready(encode(xs, edges, interpret=True)), repeats=2)
    emit("kernel_quant_encode", us, elems=n * d)
    _, us = timed(lambda: jax.block_until_ready(decode(codes, cents, interpret=True)), repeats=2)
    emit("kernel_quant_decode", us, elems=n * d)

    _, us = timed(lambda: jax.block_until_ready(qgram(codes, cents, y, interpret=True)), repeats=2)
    # HBM traffic: unfused writes+reads the (n, d) fp32 reconstruction
    unfused_bytes = n * d * 4 * 2 + (n * d * 1 + p * d * 4 + n * p * 4)
    fused_bytes = n * d * 1 + p * d * 4 + n * p * 4
    emit("kernel_qgram_fused", us, traffic_ratio=unfused_bytes / fused_bytes)

    # decode attention: one token vs a 4k KV cache
    import jax.numpy as jnp
    B, S, KV, G, hd = (2, 2048, 2, 4, 64) if quick else (8, 8192, 4, 8, 128)
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
    V = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
    kpos = jnp.asarray(np.arange(S)[None].repeat(B, 0), jnp.int32)
    _, us = timed(lambda: jax.block_until_ready(
        decode_attn(q, K, V, kpos, S - 1, interpret=True)), repeats=2)
    emit("kernel_decode_attn", us, kv_bytes=B * S * KV * hd * 2 * 2)

    # fused serve epilogue: m experts' cached apply + fusion moments, 1 launch
    m_e, t_e, K_e = (8, 128, 64) if quick else (16, 512, 128)
    ep_args = _epilogue_args(rng, m_e, t_e, K_e)
    _, us = timed(lambda: jax.block_until_ready(
        epilogue_moments(*ep_args, fuse="kl", interpret=True)), repeats=2)
    _, us_x = timed(lambda: jax.block_until_ready(
        epilogue_moments(*ep_args, fuse="kl")), repeats=2)
    emit("kernel_epilogue", us, experts=m_e, t=t_e, K=K_e, xla_us=us_x)

    # ---- unified-runtime shape sweep: every backend of every swept op ----
    sweeps = {
        "gram": [
            (f"{n_}x{d_}x{p_}",
             (lambda n_=n_, d_=d_, p_=p_: (
                 rng.normal(size=(n_, d_)).astype(np.float32),
                 rng.normal(size=(p_, d_)).astype(np.float32))),
             None)
            for n_, d_, p_ in ([(64, 16, 64), (256, 64, 256)] if quick
                               else [(64, 16, 64), (256, 64, 256),
                                     (1024, 128, 1024)])
        ],
        "epilogue": [
            (f"m{mm}t{tt}K{kk}",
             (lambda mm=mm, tt=tt, kk=kk: _epilogue_args(rng, mm, tt, kk)),
             {"fuse": "kl"})
            for mm, tt, kk in ([(4, 128, 64)] if quick
                               else [(4, 128, 64), (16, 512, 128)])
        ],
    }
    for op, cases in sweeps.items():
        for label, backend, us in runtime.shape_sweep(op, cases, reps=2):
            emit(f"kernel_sweep/{op}/{label}/{backend}", us,
                 sweeps_run=runtime.sweep_count())


def _epilogue_args(rng, m, t, K):
    G = rng.normal(size=(m, t, K)).astype(np.float32)
    Ainv = np.broadcast_to(np.eye(K, dtype=np.float32), (m, K, K)).copy()
    P = 0.01 * np.broadcast_to(np.eye(K, dtype=np.float32), (m, K, K)).copy()
    walpha = rng.normal(size=(m, K)).astype(np.float32)
    gss = rng.uniform(1.0, 2.0, size=(t,)).astype(np.float32)
    w = np.ones((m,), np.float32)
    return G, Ainv, P, walpha, gss, gss + 0.1, w


if __name__ == "__main__":
    main()
