"""Pallas kernel microbenchmarks (interpret mode on CPU; on-TPU the same
entry points compile natively).  Reports us/call and achieved element rates,
plus the fused-vs-unfused HBM-traffic ratio that motivates kernels/qgram.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import quantizers as Q
from repro.kernels.gram.ops import gram
from repro.kernels.gram.ref import gram_ref
from repro.kernels.quant.ops import encode, decode, build_scaled_tables
from repro.kernels.qgram.ops import qgram
from repro.kernels.decode_attn.ops import decode_attn
from .common import timed, emit


def main(quick: bool = True):
    rng = np.random.default_rng(0)
    n, d, p = (256, 64, 256) if quick else (1024, 128, 1024)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(p, d)).astype(np.float32)

    _, us = timed(lambda: jax.block_until_ready(gram(x, y, interpret=True)), repeats=2)
    _, us_ref = timed(lambda: jax.block_until_ready(gram_ref(x, y)), repeats=2)
    emit("kernel_gram", us, flops=2 * n * d * p, ref_us=us_ref)

    var = rng.uniform(0.1, 2.0, size=d)
    rates = Q.allocate_bits_greedy(var, 4 * d, 8)
    sigma = np.sqrt(var).astype(np.float32)
    edges, cents = build_scaled_tables(sigma, rates)
    xs = (rng.normal(size=(n, d)) * sigma).astype(np.float32)
    codes, us = timed(lambda: jax.block_until_ready(encode(xs, edges, interpret=True)), repeats=2)
    emit("kernel_quant_encode", us, elems=n * d)
    _, us = timed(lambda: jax.block_until_ready(decode(codes, cents, interpret=True)), repeats=2)
    emit("kernel_quant_decode", us, elems=n * d)

    _, us = timed(lambda: jax.block_until_ready(qgram(codes, cents, y, interpret=True)), repeats=2)
    # HBM traffic: unfused writes+reads the (n, d) fp32 reconstruction
    unfused_bytes = n * d * 4 * 2 + (n * d * 1 + p * d * 4 + n * p * 4)
    fused_bytes = n * d * 1 + p * d * 4 + n * p * 4
    emit("kernel_qgram_fused", us, traffic_ratio=unfused_bytes / fused_bytes)

    # decode attention: one token vs a 4k KV cache
    import jax.numpy as jnp
    B, S, KV, G, hd = (2, 2048, 2, 4, 64) if quick else (8, 8192, 4, 8, 128)
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    K = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
    V = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.bfloat16)
    kpos = jnp.asarray(np.arange(S)[None].repeat(B, 0), jnp.int32)
    _, us = timed(lambda: jax.block_until_ready(
        decode_attn(q, K, V, kpos, S - 1, interpret=True)), repeats=2)
    emit("kernel_decode_attn", us, kv_bytes=B * S * KV * hd * 2 * 2)


if __name__ == "__main__":
    main()
