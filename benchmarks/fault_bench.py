"""Degraded-mode benchmark (EXPERIMENTS.md §Robustness): what machine loss,
channel corruption, and jitter escalation actually COST.

Rows (written to BENCH_fault.json via benchmarks/run.py --json, or standalone):

* ``fault/degraded_lost{k}_m8`` — broadcast/KL serving with k of 8 machines
  masked out at predict time: SMSE against the ground-truth function, and
  95% coverage (|y - mu| <= 1.96 sqrt(var)).  The contract is GRACEFUL
  degradation — SMSE drifts up with k, coverage stays near nominal because
  the KL fusion inflates variance by m/m_alive instead of overclaiming;
* ``fault/crc_detect_rate{r}`` — empirical CRC-16 detection rate on packed
  wire rows under a Bernoulli(r) bit-flip channel, plus the fraction of rows
  the channel actually corrupted (the 16-bit check misses a corrupted row
  with probability ~2^-16, so detect should print 1 at bench scale);
* ``fault/chol_safe_overhead`` — chol_safe vs the bare jnp.linalg.cholesky it
  wraps, on a well-conditioned Gram (the steady-state cost of the guardrail:
  one isfinite reduction; the escalation loop never runs), and the
  escalations needed to recover a rank-deficient Gram;
* ``fault/predict_warm_degraded`` — warm degraded-mode predict latency vs the
  healthy fast path, with the structural check that BOTH programs contain
  zero factorizations.

Run standalone to write BENCH_fault.json:
  PYTHONPATH=src python -m benchmarks.fault_bench [--full]
or through the driver: PYTHONPATH=src python -m benchmarks.run --json --only fault
"""
from __future__ import annotations

import json

import numpy as np
import jax
import jax.numpy as jnp

from .common import timed, emit, smse


def _problem(n, d, seed=0):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    return X, y, f


def main(quick: bool = True) -> None:
    from repro.core import DGPConfig, DistributedGP, jax_scheme
    from repro.core.distributed_gp import predict_op_counts
    from repro.core.linalg_safe import DEFAULT_JITTER, chol_safe
    from repro.faults import flip_words

    m = 8
    n, d, steps = (640, 6, 20) if quick else (2400, 8, 80)
    n_test = 256
    X, y, f = _problem(n, d)
    rng = np.random.default_rng(1)
    Xq = rng.normal(size=(n_test, d)).astype(np.float32)
    yq = f(Xq)

    est = DistributedGP(DGPConfig(protocol="broadcast", impl="batched",
                                  bits_per_sample=16, steps=steps))
    art = est.fit(X, y, m)

    # ---- SMSE + coverage vs machines lost at serve time ----
    for k in (0, 1, 2, 4):
        av = np.ones(m, np.float32)
        av[m - k:] = 0.0  # lose the last k machines
        avail = None if k == 0 else av
        (mu, var), us = timed(
            lambda a=avail: jax.block_until_ready(est.predict(art, Xq, available=a))
        )
        mu, var = np.asarray(mu), np.asarray(var)
        cov = float(np.mean(np.abs(yq - mu) <= 1.96 * np.sqrt(var)))
        h = est.health(art, avail)
        emit(f"fault/degraded_lost{k}_m8", us,
             smse=smse(yq, mu), coverage=cov, finite=int(np.isfinite(mu).all()),
             var_inflation=float(h.variance_inflation))

    # ---- CRC detection rate vs flip rate on the packed plane ----
    n_rows, W = (2000, 4) if quick else (20000, 4)
    words = jnp.asarray(
        np.random.default_rng(2).integers(0, 2**32, (n_rows, W), dtype=np.uint32)
    )
    crc_jit = jax.jit(jax_scheme.crc_words)
    clean = crc_jit(words)
    for rate in (0.001, 0.01, 0.05):
        def channel(r=rate):
            rx = flip_words(words, r, jax.random.PRNGKey(3))
            return rx, crc_jit(rx)
        (rx, dirty), us = timed(lambda: jax.block_until_ready(channel()))
        corrupted = np.any(np.asarray(rx) != np.asarray(words), axis=-1)
        caught = (np.asarray(dirty) != np.asarray(clean)) & corrupted
        n_c = max(int(corrupted.sum()), 1)
        emit(f"fault/crc_detect_rate{rate}", us,
             detect=float(caught.sum() / n_c),
             corrupted_frac=float(corrupted.sum() / n_rows))

    # ---- chol_safe: steady-state overhead + escalation recovery ----
    dim = 64 if quick else 256
    A = np.random.default_rng(3).normal(size=(dim, dim))
    good = jnp.asarray(A @ A.T + dim * np.eye(dim), jnp.float32)
    bare = jax.jit(lambda M: jnp.linalg.cholesky(
        M + DEFAULT_JITTER * jnp.eye(dim, dtype=M.dtype)))
    safe = jax.jit(lambda M: chol_safe(M, DEFAULT_JITTER))
    _, us_bare = timed(lambda: jax.block_until_ready(bare(good)), repeats=10)
    _, us_safe = timed(lambda: jax.block_until_ready(safe(good)), repeats=10)
    U = np.random.default_rng(4).normal(size=(dim, dim // 8)).astype(np.float32)
    bad = jnp.asarray(U @ U.T)  # rank dim/8: bare cholesky returns NaN
    L_bad = safe(bad)
    recovered = int(np.isfinite(np.asarray(L_bad)).all())
    emit("fault/chol_safe_overhead", us_safe,
         us_bare=us_bare, overhead_pct=100.0 * (us_safe - us_bare) / us_bare,
         rank_deficient_recovered=recovered)

    # ---- warm degraded predict vs healthy fast path ----
    av = np.ones(m, np.float32)
    av[m - 1] = 0.0
    est.predict(art, Xq)                    # trace healthy program
    est.predict(art, Xq, available=av)      # trace degraded program
    _, us_h = timed(lambda: jax.block_until_ready(est.predict(art, Xq)),
                    repeats=10)
    _, us_d = timed(
        lambda: jax.block_until_ready(est.predict(art, Xq, available=av)),
        repeats=10)
    ops = predict_op_counts(art, Xq)
    emit("fault/predict_warm_degraded", us_d,
         us_healthy=us_h, cholesky_eqns=ops["cholesky"], eigh_eqns=ops["eigh"])


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    from . import common

    print("name,us_per_call,derived")
    main(quick=not args.full)
    with open("BENCH_fault.json", "w") as fh:
        json.dump(common.RESULTS, fh, indent=1)
    print(f"# wrote BENCH_fault.json ({len(common.RESULTS)} rows)")
