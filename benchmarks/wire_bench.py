"""Packed-wire benchmark: physical payload and checkpoint size (BENCH_wire.json).

What the packed code plane (``jax_scheme.pack_codes``) buys over the old
uint8/int32 wire, measured — not computed from a formula:

* **payload**: bytes of the per-machine wire buffer at paper scale (d=21,
  SARCOS) for bits/sample in {2, 4, 8} — packed uint32 words vs the uint8
  codes the old mesh collective gathered vs the int32 plane the old
  WireState/checkpoints carried.  The quick pass ASSERTS >= 4x reduction vs
  the uint8 wire at bits <= 8 (the acceptance bar; vs int32 it is ~21x).
* **roundtrip**: pack+unpack identity cost of the full (m, n, d) code tensor
  (the wire's CPU-side overhead; it is noise next to one collective).
* **ckpt**: on-disk bytes of a format-v3 artifact checkpoint (packed codes)
  vs the same checkpoint re-written with the v2 unpacked int32 plane, plus a
  bitwise predict check across save/load.
* **qgram**: packed-fused unpack+dequantize+gram vs the unfused
  decode->HBM->matmul pipeline (same number as BENCH_hotpath, recorded here
  so the wire artifact is self-contained; >= 1.0x is the bar).

Run standalone:  PYTHONPATH=src python -m benchmarks.wire_bench
or through the driver: python -m benchmarks.run --json --only wire
"""
from __future__ import annotations

import json
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from .common import timed, emit


def _problem(n, d, m, seed=0):
    from repro.core import split_machines

    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    X = (rng.normal(size=(n, d)) @ (rng.normal(size=(d, d)) / np.sqrt(d))).astype(
        np.float32
    )
    y = (np.sin(X @ W[:, 0]) + 0.4 * (X @ W[:, 1]) + 0.05 * rng.normal(size=n)).astype(
        np.float32
    )
    return split_machines(X, y, m, jax.random.PRNGKey(seed))


def main(quick: bool = True):
    from repro.core import fit, predict, save_artifact, load_artifact
    from repro.core import jax_scheme
    from repro.core.protocols.base import pad_parts
    from repro.core.protocols.wire import _run_wire_protocol
    from repro.kernels.gram.ops import gram as gram_kernel
    from repro.kernels.qgram.ops import qgram_packed
    from repro.kernels.quant.ops import decode as quant_decode

    n, d, m = (504, 21, 8) if quick else (2000, 21, 40)
    max_bits = 8
    parts = _problem(n, d, m)
    shards = pad_parts(parts)
    n_pad = shards.X.shape[1]

    from repro.comm.accounting import row_bits

    # ---- payload: packed words vs the old uint8/int32 planes ----
    for bits in (2, 4, 8):
        ws, us_wire = timed(
            lambda: jax.block_until_ready(
                _run_wire_protocol(shards.X, shards.mask, bits, max_bits,
                                   "broadcast", 0)
            ),
            repeats=1,
        )
        words = np.asarray(ws.codes)
        packed_bytes = words.size * words.dtype.itemsize  # measured buffer
        uint8_bytes = m * n_pad * d  # the old mesh wire (one byte per symbol)
        int32_bytes = m * n_pad * d * 4  # the old WireState/ckpt plane
        fp32_bytes = m * n_pad * d * 4  # unquantized baseline
        red_u8 = uint8_bytes / packed_bytes
        red_i32 = int32_bytes / packed_bytes
        if quick and bits <= 8:
            assert red_u8 >= 4.0, (
                f"packed wire must be >=4x smaller than the uint8 wire at "
                f"bits={bits} (got {red_u8:.2f}x)"
            )
        # roundtrip identity cost of the full code tensor through the plane
        rbits = row_bits(bits, d, max_bits)
        pack = jax.jit(jax.vmap(
            lambda c, r, mk: jax_scheme.pack_codes(
                c, r, total_bits=rbits, mask=mk
            )
        ))
        unpack = jax.jit(jax.vmap(
            lambda w, r, mk: jax_scheme.unpack_codes(
                w, r, total_bits=rbits, mask=mk
            )
        ))
        codes = unpack(ws.codes, ws.rates, shards.mask)
        w2, us_pack = timed(
            lambda: jax.block_until_ready(pack(codes, ws.rates, shards.mask))
        )
        np.testing.assert_array_equal(np.asarray(w2), words)
        emit(
            f"wire/payload_b{bits}",
            us_wire,
            packed_bytes=packed_bytes,
            uint8_bytes=uint8_bytes,
            int32_bytes=int32_bytes,
            fp32_bytes=fp32_bytes,
            reduction_vs_uint8=red_u8,
            reduction_vs_int32=red_i32,
            pack_roundtrip_us=us_pack,
        )

    # ---- ckpt: format-v3 packed artifact vs the v2 unpacked plane ----
    bits = 8
    art = fit(parts, bits, "center", steps=2 if quick else 50)
    Xt = jnp.asarray(np.random.default_rng(1).normal(size=(32, d)).astype(np.float32))
    mu0, s0 = predict(art, Xt)
    with tempfile.TemporaryDirectory() as td:
        _, us_save = timed(lambda: save_artifact(art, td), repeats=1)
        ckpt = os.path.join(td, "ckpt_00000000.npz")
        v3_bytes = os.path.getsize(ckpt)
        arrays = dict(np.load(ckpt))
        codes_bytes_v3 = arrays["wire/codes"].nbytes
        # the same checkpoint with the pre-v3 unpacked int32 code plane
        arrays["wire/codes"] = np.asarray(jax.vmap(
            lambda w, r: jax_scheme.unpack_codes(
                w, r, total_bits=row_bits(bits, d, art.max_bits)
            )
        )(jnp.asarray(arrays["wire/codes"]), jnp.asarray(arrays["wire/rates"])))
        v2_path = os.path.join(td, "v2.npz")
        np.savez(v2_path, **arrays)
        v2_bytes = os.path.getsize(v2_path)
        codes_bytes_v2 = arrays["wire/codes"].nbytes
        art_l = load_artifact(td)
        mu1, s1 = predict(art_l, Xt)
        assert np.array_equal(np.asarray(mu1), np.asarray(mu0))
        assert np.array_equal(np.asarray(s1), np.asarray(s0))
    emit(
        "wire/ckpt_v3_vs_v2",
        us_save,
        v3_bytes=v3_bytes,
        v2_bytes=v2_bytes,
        ckpt_reduction=v2_bytes / v3_bytes,
        codes_bytes_v3=codes_bytes_v3,
        codes_bytes_v2=codes_bytes_v2,
        codes_reduction=codes_bytes_v2 / codes_bytes_v3,
        bitwise_predict=1,
    )

    # ---- qgram: packed-fused vs unfused (the wire artifact's own copy) ----
    bits = 24
    ws = _run_wire_protocol(shards.X, shards.mask, bits, 12, "broadcast", 0)
    words, rates, cents = ws.codes[1], ws.rates[1], ws.scaled_cents[1]
    codes = jax_scheme.unpack_codes(words, rates, total_bits=bits)
    Y = jnp.asarray(np.random.default_rng(2).normal(size=(n_pad, d)).astype(np.float32))

    def unfused():
        xhat = quant_decode(codes, cents)
        return gram_kernel(xhat, Y)

    def fused():
        return qgram_packed(words, rates, cents, Y, total_bits=bits)

    ref, us_unfused = timed(lambda: jax.block_until_ready(unfused()))
    out, us_fused = timed(lambda: jax.block_until_ready(fused()))
    speedup = us_unfused / us_fused
    derived = dict(
        speedup=speedup, max_abs_err=float(jnp.max(jnp.abs(ref - out)))
    )
    if speedup < 1.0:
        derived["note"] = (
            f"REGRESSION: packed-fused qgram {speedup:.2f}x vs unfused"
        )
    emit("wire/qgram_packed_fused", us_fused, **derived)
    emit("wire/qgram_unfused", us_unfused)


if __name__ == "__main__":
    import argparse

    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_wire.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=not args.full)
    with open(args.out, "w") as f:
        json.dump(common.RESULTS, f, indent=1)
    print(f"# wrote {args.out}")
