"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints rows:  name,us_per_call,derived
where ``derived`` is the figure's own metric (distortion, SMSE, ...) encoded
as key=value pairs joined by '|'.
"""
from __future__ import annotations

import time

import numpy as np

# every emit() lands here so benchmarks/run.py --json can write BENCH_*.json
RESULTS: list = []


def timed(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def emit(name: str, us_per_call: float, **derived):
    RESULTS.append({"name": name, "us_per_call": float(us_per_call), "derived": derived})
    kv = "|".join(f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{kv}", flush=True)


def smse(y_true, y_pred):
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    return float(np.mean((y_true - y_pred) ** 2) / np.var(y_true))
