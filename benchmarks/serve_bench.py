"""Fit-once/serve-many benchmark (EXPERIMENTS.md §Serve): cold fit vs warm
predict at paper scale (m=40 machines), query throughput, streaming update
cost, and the structural serve-path checks.

Rows (written to BENCH_serve.json via benchmarks/run.py --json, or standalone):

* ``serve/cold_fit_predict_m40`` — one full fit() (wire protocol + training +
  factorization, includes trace/compile) plus a first predict(): what a fresh
  experiment pays, and what the legacy pipeline re-paid on EVERY call;
* ``serve/predict_warm_m40`` — the cached-program serve loop: per-query-batch
  latency and queries/sec against the fitted artifact.  ``retraces_warm_loop``
  and ``cholesky_eqns``/``eigh_eqns`` are the structural proof that warm
  serving does no scheme refit and no Cholesky refactorization;
* ``serve/update_stream_m40`` — streaming n_new points through the frozen
  codebooks (rank-k factor growth) + the one retrace the next predict pays;
* ``serve/save_load_roundtrip`` — artifact checkpoint round-trip wall clock;
  ``bitwise_equal=1`` is asserted, not just recorded.

Run standalone to write BENCH_serve.json:
  PYTHONPATH=src python -m benchmarks.serve_bench [--full]
or through the driver: PYTHONPATH=src python -m benchmarks.run --json --only serve
"""
from __future__ import annotations

import json
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from .common import timed, emit


def _problem(n, d, m, seed=0):
    from repro.core import split_machines

    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    parts = split_machines(X, y, m, jax.random.PRNGKey(seed))
    return parts, f


def main(quick: bool = True) -> None:
    from repro.core import fit, predict, update, save_artifact, load_artifact
    from repro.core.distributed_gp import predict_op_counts, serve_trace_count

    # paper scale is 40 machines (§6); quick mode shrinks n/steps, not m
    m = 40
    n, d, steps = (1200, 8, 30) if quick else (4000, 12, 100)
    t_batch, bits = 128, 24
    parts, _ = _problem(n, d, m)
    rng = np.random.default_rng(1)
    Xq = rng.normal(size=(t_batch, d)).astype(np.float32)

    # ---- cold: full protocol + first query (includes trace+compile) ----
    t0 = time.perf_counter()
    art = fit(parts, bits, "center", steps=steps)
    jax.block_until_ready(predict(art, Xq))
    us_cold = (time.perf_counter() - t0) * 1e6
    emit("serve/cold_fit_predict_m40", us_cold, n=n, d=d, m=m,
         wire_kbits=art.wire_bits / 1e3, includes_compile=1)

    # ---- warm serve loop: cached program, cached factors ----
    c0 = serve_trace_count("center")
    _, us_warm = timed(lambda: jax.block_until_ready(predict(art, Xq)), repeats=20)
    retraces = serve_trace_count("center") - c0
    ops = predict_op_counts(art, Xq)
    assert retraces == 0, f"warm predict retraced {retraces}x"
    assert ops == {"cholesky": 0, "eigh": 0}, f"warm predict refactorizes: {ops}"
    assert us_warm < us_cold, "warm predict must beat cold fit+predict"
    WARM_GATE_US = 6400.0  # PR-8 acceptance: fused-epilogue warm serve p50
    assert us_warm < WARM_GATE_US, (
        f"warm predict p50 {us_warm:.0f}us blew the {WARM_GATE_US:.0f}us gate"
    )
    emit("serve/predict_warm_m40", us_warm, qps=t_batch / (us_warm / 1e6),
         batch=t_batch, speedup_vs_cold=us_cold / us_warm,
         retraces_warm_loop=retraces,
         cholesky_eqns=ops["cholesky"], eigh_eqns=ops["eigh"],
         p50_gate_us=WARM_GATE_US, gate_ok=1)

    # ---- fused vs unfused serve epilogue on the same problem ----
    # (the fused path serves on the K-sized nystrom_serve_cache operands:
    # matmuls only, no O(t N K) triangular solve in the hot loop)
    import dataclasses
    from repro.core.api import DistributedGP
    from repro.core.config import DGPConfig

    cfg_u = dataclasses.replace(
        art.config if isinstance(art.config, DGPConfig)
        else DGPConfig.from_dict(dict(art.config)),
        serve_epilogue="unfused",
    )
    art_unf = DistributedGP(cfg_u).fit(parts=parts)
    assert "Ainv" not in art_unf.factors
    _, us_unf = timed(lambda: jax.block_until_ready(predict(art_unf, Xq)),
                      repeats=20)
    mu_f, v_f = predict(art, Xq)
    mu_u, v_u = predict(art_unf, Xq)
    dev = float(max(np.max(np.abs(np.asarray(mu_f) - np.asarray(mu_u))),
                    np.max(np.abs(np.asarray(v_f) - np.asarray(v_u)))))
    assert dev < 1e-3, f"fused/unfused serve divergence {dev}"
    emit("serve/predict_warm_unfused_m40", us_unf,
         fused_us=us_warm, unfused_over_fused=us_unf / us_warm,
         max_abs_dev=dev)

    # ---- streaming update: frozen codebooks, rank-k factor growth ----
    n_new = 16
    Xn = rng.normal(size=(n_new, d)).astype(np.float32)
    yn = np.zeros(n_new, np.float32)
    t0 = time.perf_counter()
    art_u = update(art, Xn, yn, machine=1)
    jax.block_until_ready(art_u.factors["alpha"])
    us_update = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    jax.block_until_ready(predict(art_u, Xq))  # the one retrace growth pays
    us_regrow = (time.perf_counter() - t0) * 1e6
    # first_predict_after_us here INCLUDES the capacity-growth retrace (the
    # first update after a fresh fit always crosses into a bigger bucket) —
    # it is a compile-cost row, not a steady-state serving row
    emit("serve/update_stream_m40", us_update, n_new=n_new,
         wire_bits_added=art_u.wire_bits - art.wire_bits,
         first_predict_after_us=us_regrow, includes_growth_retrace=1)

    # ---- in-bucket streaming update: NO retrace allowed ----
    # the growth above padded the buffers to a power-of-two capacity, so the
    # next small update stays inside the bucket: shapes are unchanged and the
    # first predict after it must reuse the cached program.  The gate is
    # asserted — a post-update recompile regression FAILS the bench instead
    # of silently inflating first_predict_after_us (the pre-PR7 behavior,
    # ~276ms, re-paid compile on every update).
    Xn2 = rng.normal(size=(n_new, d)).astype(np.float32)
    t0 = time.perf_counter()
    art_u2 = update(art_u, Xn2, yn, machine=2)
    jax.block_until_ready(art_u2.factors["alpha"])
    us_update2 = (time.perf_counter() - t0) * 1e6
    c1 = serve_trace_count("center")
    t0 = time.perf_counter()
    jax.block_until_ready(predict(art_u2, Xq))
    us_after2 = (time.perf_counter() - t0) * 1e6
    retraces_after = serve_trace_count("center") - c1
    gate_ok = retraces_after == 0 and us_after2 < WARM_GATE_US
    emit("serve/update_stream_inbucket_m40", us_update2, n_new=n_new,
         first_predict_after_us=us_after2, retraces_after_update=retraces_after,
         p50_gate_us=WARM_GATE_US, gate_ok=int(gate_ok))
    assert retraces_after == 0, (
        f"in-bucket streaming update retraced the serve program "
        f"{retraces_after}x (capacity unchanged — the predict must reuse "
        "the cached trace)"
    )
    assert us_after2 < WARM_GATE_US, (
        f"first predict after an in-bucket update took {us_after2:.0f}us "
        f"(> {WARM_GATE_US:.0f}us warm gate) — post-update recompile "
        "regression"
    )

    # ---- checkpoint round-trip: bitwise-identical serving ----
    mu0, v0 = predict(art, Xq)
    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        save_artifact(art, td)
        art2 = load_artifact(td)
        us_ckpt = (time.perf_counter() - t0) * 1e6
        mu1, v1 = predict(art2, Xq)
    bitwise = bool(
        np.array_equal(np.asarray(mu0), np.asarray(mu1))
        and np.array_equal(np.asarray(v0), np.asarray(v1))
    )
    assert bitwise, "loaded artifact must predict bitwise-identically"
    emit("serve/save_load_roundtrip", us_ckpt, bitwise_equal=int(bitwise))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    from .common import RESULTS

    main(quick=not args.full)
    with open("BENCH_serve.json", "w") as fjson:
        json.dump(RESULTS, fjson, indent=1)
    print(f"# wrote BENCH_serve.json ({len(RESULTS)} rows)")
