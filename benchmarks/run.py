"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  PYTHONPATH=src python -m benchmarks.run          # quick pass (CI scale)
  PYTHONPATH=src python -m benchmarks.run --full   # paper-scale settings
  PYTHONPATH=src python -m benchmarks.run --json   # + write BENCH_<name>.json
                                                   # (us/call per benchmark row;
                                                   #  see EXPERIMENTS.md §Perf)
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<name>.json per selected benchmark")
    ap.add_argument("--only", default=None,
                    help="comma list of: fig2,fig3,fig4,fig56,fig7,kernels,"
                         "ablation_bits,roofline,hotpath,serve,mesh,vq,wire,"
                         "fault,stream,fleet")
    args = ap.parse_args()
    quick = not args.full

    from . import fig2_distortion, fig3_pca, fig4_gp1d, fig56_regression, fig7_sparse
    from . import kernels_bench, roofline, ablation_bits, hotpath_bench, serve_bench
    from . import mesh_bench, vq_bench, wire_bench, fault_bench, stream_bench
    from . import fleet_bench
    from . import common

    benches = {
        "fig2": lambda: fig2_distortion.main(quick=quick),
        "fig3": lambda: fig3_pca.main(quick=quick),
        "fig4": lambda: fig4_gp1d.main(quick=quick),
        "fig56": lambda: fig56_regression.main(quick=quick),
        "fig7": lambda: fig7_sparse.main(quick=quick),
        "kernels": lambda: kernels_bench.main(quick=quick),
        "ablation_bits": lambda: ablation_bits.main(quick=quick),
        "roofline": lambda: roofline.main(),
        "hotpath": lambda: hotpath_bench.main(quick=quick),
        "serve": lambda: serve_bench.main(quick=quick),
        "mesh": lambda: mesh_bench.main(quick=quick),
        "vq": lambda: vq_bench.main(quick=quick),
        "wire": lambda: wire_bench.main(quick=quick),
        "fault": lambda: fault_bench.main(quick=quick),
        "stream": lambda: stream_bench.main(quick=quick),
        "fleet": lambda: fleet_bench.main(quick=quick),
    }
    selected = args.only.split(",") if args.only else list(benches)
    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        start = len(common.RESULTS)
        benches[name]()
        if args.json:
            rows = common.RESULTS[start:]
            with open(f"BENCH_{name}.json", "w") as f:
                json.dump(rows, f, indent=1)
            print(f"# wrote BENCH_{name}.json ({len(rows)} rows)", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
