"""Wire-scheme comparison at matched bit budgets: per-symbol (§4.2) vs the
Theorem-2 optimal vector-quantization test channel (§4.1), m=8 machines.

The paper's Fig. 2 compares the schemes on *distortion*; this benchmark
compares them where it matters for the application — end-to-end distributed-GP
regression error at the SAME wire-bit ledger — now that ``scheme="vq"`` is a
runnable wire scheme behind ``DistributedGP`` rather than an offline curve.
Expectation (paper §4): vq tracks the rate-distortion optimum, per-symbol
pays a small near-optimality gap that shrinks as R grows.

Rows: ``vq_<protocol>_R<bits>_<scheme>``, derived = smse | wire_kbits.
Registered in benchmarks/run.py (``--only vq`` -> BENCH_vq.json).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import DGPConfig, DistributedGP

from .common import emit, smse, timed


def main(quick: bool = True):
    rng = np.random.default_rng(0)
    n, d, m = (360, 6, 8) if quick else (2000, 8, 8)
    steps = 20 if quick else 100
    rates = (8, 16) if quick else (8, 16, 32, 64)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    Xt = rng.normal(size=(300, d)).astype(np.float32)
    yt = f(Xt)
    key = jax.random.PRNGKey(0)

    for protocol in ("center", "broadcast"):
        for bits in rates:
            ledgers = {}
            for scheme in ("per_symbol", "vq"):
                est = DistributedGP(DGPConfig(
                    protocol=protocol, scheme=scheme, bits_per_sample=bits,
                    steps=steps,
                ))

                def run():
                    art = est.fit(X, y, m, key=key)
                    mu, _ = est.predict(art, Xt)
                    return art, np.asarray(jax.block_until_ready(mu))

                (art, mu), us = timed(run, repeats=1)
                ledgers[scheme] = art.wire_bits
                emit(
                    f"vq_{protocol}_R{bits}_{scheme}", us,
                    smse=smse(yt, mu), wire_kbits=art.wire_bits / 1e3,
                )
            # matched budgets are the point of the comparison: the vq ledger
            # (charged at the channel's achieved Theorem-1 rate) must sit
            # within a few percent of per-symbol's at the same R
            lo, hi = sorted(ledgers.values())
            assert hi - lo <= 0.05 * hi, (
                f"{protocol} R={bits}: ledgers not matched {ledgers}"
            )


if __name__ == "__main__":
    main()
