"""Paper Figs. 5-6: distributed GP regression SMSE vs bits/sample on
SARCOS / KIN40K / ABALONE-scale datasets (matched-moment synthetic by default,
real files via --data-dir), 1000 training points across 40 machines.

Models: full GP (SD reference), BCM, rBCM (zero rate), single-center and
broadcast quantized GPs.  Kernels: linear (Fig. 5) and SE (Fig. 6).

Validates: broadcast/single-center cross the rBCM line at a few bits/dim and
approach the full GP; at very low rate quantized models are WORSE than rBCM
(the paper's own observation motivating Fig. 7).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import (
    split_machines, single_center_gp, broadcast_gp, poe_baseline, train_gp,
)
from repro.data import regression_dataset
from .common import timed, emit, smse


def run_dataset(name, kernel, rates, m_machines, steps, n_test_cap, data_dir=None,
                gram_mode="nystrom", n_train_cap=None):
    X, y, Xt, yt = regression_dataset(name, data_dir=data_dir)
    if n_train_cap:
        X, y = X[:n_train_cap], y[:n_train_cap]
    Xt, yt = Xt[:n_test_cap], yt[:n_test_cap]
    results = {}

    full = train_gp(X, y, kernel=kernel, steps=steps)
    mu, _ = full.predict(Xt)
    results["full"] = smse(yt, mu)
    emit(f"fig56_{name}_{kernel}", 0.0, model="full", R=0, smse=results["full"])

    parts = split_machines(X, y, m_machines, jax.random.PRNGKey(0))
    for method in ("bcm", "rbcm"):
        mu, _, _ = poe_baseline(parts, Xt, kernel=kernel, method=method, steps=steps)
        results[method] = smse(yt, mu)
        emit(f"fig56_{name}_{kernel}", 0.0, model=method, R=0, smse=results[method])

    # 'nystrom' is the paper's §5 protocol (rank capped at the center block);
    # 'direct' is the beyond-paper variant that rebuilds every gram block from
    # the reconstructed points and converges to the full GP as R -> inf
    for R in rates:
        for mode in ("nystrom", "direct"):
            m, us = timed(lambda: single_center_gp(parts, R, kernel=kernel, steps=steps,
                                                   gram_mode=mode), repeats=1)
            mu, _ = m.predict(Xt)
            e = smse(yt, mu)
            results[("center", mode, R)] = e
            emit(f"fig56_{name}_{kernel}", us, model=f"single_center_{mode}", R=R,
                 smse=e, wire_kbits=m.wire_bits / 1e3)
        mu, s2, wire, _ = broadcast_gp(parts, R, Xt, kernel=kernel, steps=steps,
                                       gram_mode=gram_mode)
        e = smse(yt, mu)
        results[("broadcast", R)] = e
        emit(f"fig56_{name}_{kernel}", 0.0, model="broadcast", R=R, smse=e,
             wire_kbits=wire / 1e3)
    return results


def main(quick: bool = True, data_dir: str | None = None, gram_mode: str = "nystrom"):
    # quick: 500-sample subsets / 10 machines so the whole figure runs in a
    # few minutes on 1 CPU; --full is the paper's 1000 samples / 40 machines
    rates = [4, 16, 48] if quick else [2, 5, 8, 12, 16, 25, 40, 64, 100]
    m_machines = 10 if quick else 40
    steps = 60 if quick else 150
    n_test_cap = 200 if quick else 1000
    n_train_cap = 500 if quick else None
    out = {}
    for kernel, datasets in (("linear", ["sarcos", "abalone"]),
                             ("se", ["sarcos", "kin40k", "abalone"])):
        for name in datasets:
            out[(name, kernel)] = run_dataset(
                name, kernel, rates, m_machines, steps, n_test_cap, data_dir,
                gram_mode, n_train_cap)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--data-dir", default=None)
    ap.add_argument("--gram-mode", default="nystrom", choices=["nystrom", "direct"])
    a = ap.parse_args()
    main(quick=not a.full, data_dir=a.data_dir, gram_mode=a.gram_mode)
