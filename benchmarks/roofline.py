"""Roofline table from the dry-run JSON artifacts (results/dryrun_*.json).

Emits one CSV row per (arch, shape, mesh) with the three roofline terms in
seconds, the dominant term, MODEL_FLOPS/HLO_FLOPs, and peak bytes/device.
Also renders the markdown table for EXPERIMENTS.md §Roofline with --markdown.
"""
from __future__ import annotations

import argparse
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(path):
    with open(path) as f:
        return json.load(f)


def rows(results):
    for r in results:
        if "skipped" in r or "error" in r:
            continue
        rl = r["roofline"]
        yield {
            "arch": r["arch"],
            "shape": r["shape"],
            "pods": 2 if r["multi_pod"] else 1,
            "compute_s": rl["compute_s"],
            "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"],
            "dominant": rl["dominant"],
            "useful_ratio": rl.get("useful_flops_ratio"),
            "peak_gb": (r["memory"]["peak_bytes"] or 0) / 1e9,
        }


def main(markdown: bool = False, paths=None):
    paths = paths or [
        os.path.join(RESULTS, "dryrun_single.json"),
        os.path.join(RESULTS, "dryrun_multi.json"),
    ]
    all_rows = []
    for p in paths:
        if os.path.exists(p):
            all_rows.extend(rows(load(p)))
    if markdown:
        print("| arch | shape | pods | compute s | memory s | collective s | dominant | useful FLOPs | peak GB/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in all_rows:
            ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
            print(f"| {r['arch']} | {r['shape']} | {r['pods']} | "
                  f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                  f"{r['dominant']} | {ur} | {r['peak_gb']:.2f} |")
    else:
        for r in all_rows:
            emit("roofline", 0.0, **{k: v for k, v in r.items() if v is not None})
    return all_rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    a = ap.parse_args()
    main(markdown=a.markdown)
