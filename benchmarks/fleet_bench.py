"""Multi-tenant fleet serving benchmark (docs/fleet_serving.md).

Two measurements, both GATED (an assertion failure fails the bench run):

* ``fleet/predict_stacked_S{S}`` — the core tentpole claim: ONE stacked
  vmapped dispatch answering S resident tenants vs a serial python loop
  making S per-tenant predict calls at the SAME per-request batch.  Gate:
  aggregate qps of the stacked path >= 5x the serial loop.
* ``fleet/traffic_zipf_T{T}`` — the serving story end to end: >=256 tenants
  (quick mode shrinks the REQUEST count, never the tenant count), zipf-mixed
  traffic through the FleetServer (LRU artifact cache with checkpoint-backed
  load-on-miss, latency-budgeted micro-batching).  Reports aggregate qps,
  p50/p99 request latency, cache hit rate, tenant swaps.  Gate: the
  steady-state loop retraces NOTHING (fleet + serve trace counters flat) —
  tenant swaps, cache misses, and ragged tail flushes included.

The 256-tenant fleet is built from ONE base fit via exact y-scaling
(:func:`repro.core.fleet.scale_targets`): genuinely distinct posteriors,
same homogeneity bucket, no per-tenant fit cost.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from .common import emit, timed

N_TENANTS = 256  # the >=256-tenant floor holds in quick mode too
QPS_SPEEDUP_GATE = 5.0


def main(quick: bool = True):
    import jax
    from repro.core import DGPConfig, DistributedGP
    from repro.core.fleet import FleetStack, fleet_trace_count
    from repro.core.protocols import serve_trace_count
    from repro.launch.fleet import (
        FleetServer,
        build_fleet,
        serve_loop,
        zipf_tenants,
    )

    m, n, d, steps = 4, 256, 6, 5
    batch = 16  # query points per request
    slots = 32  # micro-batch flush width == stacked dispatch size
    cache_cap = 64
    n_requests = 256 if quick else 2048

    cfg = DGPConfig(
        protocol="broadcast",
        gram_backend="pallas",  # fused fleet epilogue path
        gram_mode="nystrom",
        bits_per_sample=8,
        steps=steps,
    )
    est = DistributedGP(cfg)
    rng = np.random.default_rng(0)
    W = rng.normal(size=(d, 2))
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (np.sin(X @ W[:, 0]) + 0.4 * (X @ W[:, 1])
         + 0.05 * rng.normal(size=n)).astype(np.float32)
    base_art = est.fit(X, y, m, key=jax.random.PRNGKey(0))

    with tempfile.TemporaryDirectory() as td:
        store, tids = build_fleet([base_art], N_TENANTS, td)

        # ---- gate 1: stacked dispatch vs serial per-tenant loop ----------
        sub = tids[:slots]
        arts = [store.load(t) for t in sub]  # resident for BOTH paths
        stack = FleetStack(dict(zip(sub, arts)), slots=slots)
        Xq = rng.normal(size=(slots, batch, d)).astype(np.float32)

        def fleet_call():
            mu, var = stack.predict(sub, Xq)
            jax.block_until_ready(mu)
            return mu

        def serial_call():
            out = []
            for art, Xi in zip(arts, Xq):
                mu, var = est.predict(art, Xi)
                jax.block_until_ready(mu)
                out.append(mu)
            return out

        _, us_fleet = timed(fleet_call, repeats=10)
        _, us_serial = timed(serial_call, repeats=10)
        qps_fleet = slots * batch / (us_fleet / 1e6)
        qps_serial = slots * batch / (us_serial / 1e6)
        speedup = qps_fleet / qps_serial
        # parity spot-check rides along: gates are only meaningful if the
        # stacked path computes the same posterior
        mu_f = np.asarray(fleet_call())
        mu_s = np.asarray(serial_call())
        dmu = float(np.max(np.abs(mu_f - mu_s)))
        emit(
            f"fleet/predict_stacked_S{slots}",
            us_fleet,
            qps_fleet=qps_fleet,
            qps_serial=qps_serial,
            speedup=speedup,
            max_dmu_vs_serial=dmu,
            gate_ok=int(speedup >= QPS_SPEEDUP_GATE and dmu < 1e-3),
        )
        assert dmu < 1e-3, (
            f"stacked fleet predict diverges from the serial per-tenant "
            f"loop: max |dmu| = {dmu:.3e}"
        )
        assert speedup >= QPS_SPEEDUP_GATE, (
            f"fleet stacked predict speedup gate FAILED: {speedup:.2f}x < "
            f"{QPS_SPEEDUP_GATE}x (qps_fleet={qps_fleet:.0f}, "
            f"qps_serial={qps_serial:.0f})"
        )

        # ---- gate 2: zipf traffic, steady state never retraces -----------
        server = FleetServer(
            store, cache_artifacts=cache_cap, slots=slots, budget_ms=2.0
        )
        stream = zipf_tenants(tids, n_requests, a=1.1)
        make_query = lambda i: rng.normal(size=(batch, d)).astype(np.float32)
        # warm pass traces the healthy-shape program; the measured loop
        # (swaps, misses, ragged tail flush included) must hold the
        # counters flat
        serve_loop(server, stream[: 4 * slots], make_query)
        server.reset_stats()
        c0 = fleet_trace_count("broadcast")
        s0 = serve_trace_count("broadcast")
        t0 = time.perf_counter()
        stats = serve_loop(server, stream, make_query)
        wall = time.perf_counter() - t0
        retraces = (fleet_trace_count("broadcast") - c0) + \
            (serve_trace_count("broadcast") - s0)
        qps = stats["completed"] * batch / wall
        cache = stats["cache"]
        emit(
            f"fleet/traffic_zipf_T{N_TENANTS}",
            wall / max(stats["completed"], 1) * 1e6,
            tenants=N_TENANTS,
            requests=stats["completed"],
            qps=qps,
            p50_ms=stats["p50_ms"],
            p99_ms=stats["p99_ms"],
            hit_rate=cache["hit_rate"],
            evictions=cache["evictions"],
            stack_swaps=stats["stack_swaps"],
            retraces=retraces,
            gate_ok=int(retraces == 0),
        )
        assert stats["completed"] == n_requests, (
            f"fleet server dropped requests: {stats['completed']} of "
            f"{n_requests} completed"
        )
        assert retraces == 0, (
            f"steady-state retrace gate FAILED: {retraces} retrace(s) during "
            "the measured zipf traffic loop (tenant swaps and cache misses "
            "must not retrace)"
        )


if __name__ == "__main__":
    main()
