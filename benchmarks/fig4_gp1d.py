"""Paper Fig. 4: GP regression on a 1-d dataset (N=200) trained on per-symbol
quantized inputs at R = 1..8 bits/sample; compare posterior mean/std against
the unquantized (true) GP on a dense grid.

Validates: R=1 badly distorted (possible inverted peaks), R>=6 ~ true GP.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.gp import train_gp
from repro.core.schemes import PerSymbolScheme
from .common import timed, emit


def main(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = 200
    X = rng.uniform(-8, 8, size=(n, 1)).astype(np.float32)
    f = lambda x: np.sin(x[:, 0]) + 0.5 * np.cos(2.3 * x[:, 0]) + 0.1 * x[:, 0]
    y = (f(X) + 0.1 * rng.normal(size=n)).astype(np.float32)
    grid = np.linspace(-8, 8, 200).astype(np.float32)[:, None]

    steps = 120 if quick else 300
    true_gp = train_gp(X, y, kernel="se", steps=steps)
    mu_t, var_t = true_gp.predict(jnp.asarray(grid))
    mu_t, sd_t = np.asarray(mu_t), np.sqrt(np.asarray(var_t))

    Qx = np.cov(X.T).reshape(1, 1) + 1e-6
    out = {}
    rates = range(1, 9)
    for R in rates:
        sch = PerSymbolScheme(R, max_bits_per_dim=R).fit(Qx, Qx)
        Xq = np.asarray(sch.roundtrip(X))
        (gp_q, us) = timed(lambda: train_gp(Xq, y, kernel="se", steps=steps), repeats=1)
        mu_q, var_q = gp_q.predict(jnp.asarray(grid))
        mu_q, sd_q = np.asarray(mu_q), np.sqrt(np.asarray(var_q))
        mean_mse = float(np.mean((mu_q - mu_t) ** 2))
        sd_mse = float(np.mean((sd_q - sd_t) ** 2))
        # sign-flip detector for the paper's 'reverse peaks' phenomenon
        corr = float(np.corrcoef(mu_q, mu_t)[0, 1])
        emit("fig4", us, R=R, mean_mse=mean_mse, sd_mse=sd_mse, corr_with_true=corr)
        out[R] = (mean_mse, sd_mse, corr)
    return out


if __name__ == "__main__":
    main()
