"""Streaming-ingest benchmark (EXPERIMENTS.md §Streaming): sustained
update() throughput while serving, against the pre-bucketing baseline.

Before the capacity-bucketed buffers, every update() re-dispatched a fresh
program over grown (exact-size) arrays: ~2.9e6 us per 16-point batch at
m=40 paper scale, plus a ~415e3 us predict recompile before the first query
against the grown artifact (BENCH_serve.json, serve/update_stream_m40).
With device-resident bucketed streaming, consecutive in-bucket updates are
ONE cached jit program and the warm predict program reads the same buffers.

Rows (written to BENCH_stream.json via benchmarks/run.py --json):

* ``stream/update_in_bucket_m40`` — p50/p90 latency of a 16-point in-bucket
  update at paper scale.  ``update_retraces`` and
  ``first_predict_new_traces`` are ASSERTED zero over the measured window
  (the retrace-free contract, same counters tests/test_streaming.py pins);
  ``speedup_vs_baseline`` is p50 against the 2.9s pre-bucketing baseline
  and is asserted >= 20x;
* ``stream/ingest_while_serving_m40`` — sustained points/sec through an
  update+predict serving loop (every batch is queried right after it lands).

Run standalone to write BENCH_stream.json:
  PYTHONPATH=src python -m benchmarks.stream_bench [--full]
or through the driver: PYTHONPATH=src python -m benchmarks.run --json --only stream
"""
from __future__ import annotations

import time

import numpy as np
import jax

from .common import emit

# the pre-bucketing cost of streaming at paper scale, measured by
# serve_bench on this repo before the bucketed-buffer refactor: one
# 16-point update re-dispatched over exact-size grown arrays (~2.9s), and
# the first predict against the grown artifact recompiled (~415 ms)
BASELINE_UPDATE_US = 2.9e6
BASELINE_FIRST_PREDICT_US = 415e3

# gates (quick CI scale, generous vs. observed): the acceptance contract
MAX_P50_UPDATE_US = 145e3  # >= 20x the 2.9s baseline
MIN_SPEEDUP = 20.0


def _problem(n, d, m, seed=0):
    from repro.core import split_machines

    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    parts = split_machines(X, y, m, jax.random.PRNGKey(seed))
    return parts, f


def main(quick: bool = True) -> None:
    from repro.core.protocols import fit, predict, update
    from repro.core.protocols import serve_trace_count, update_trace_count

    m, n, d, bits = 40, 1200, 8, 24  # paper scale (§6): 40 machines
    batch, iters = 16, (20 if quick else 50)
    parts, f = _problem(n, d, m)
    rng = np.random.default_rng(1)
    Xq = rng.normal(size=(128, d)).astype(np.float32)

    art = fit(parts, bits, "center", steps=10 if quick else 30)
    predict(art, Xq)
    center = art.block_order[0]
    machines = [j for j in range(m) if j != center]

    def batch_at(i):
        Xn = rng.normal(size=(batch, d)).astype(np.float32)
        yn = f(Xn).astype(np.float32)
        return Xn, yn, machines[i % len(machines)]

    # one growth into the 2048 bucket (next_pow2(1216)), then warm the
    # in-bucket update program and the bucketed serve program
    Xn, yn, j = batch_at(0)
    art = update(art, Xn, yn, machine=j)
    predict(art, Xq)
    Xn, yn, j = batch_at(1)
    art = update(art, Xn, yn, machine=j)
    predict(art, Xq)

    # ---- measured window: in-bucket updates, each followed by a query ----
    u0 = update_trace_count("center")
    c0 = serve_trace_count("center")
    upd_lat, points = [], 0
    t_loop = time.perf_counter()
    for i in range(iters):
        Xn, yn, j = batch_at(2 + i)
        t0 = time.perf_counter()
        art = update(art, Xn, yn, machine=j)
        jax.block_until_ready(art.factors)
        upd_lat.append((time.perf_counter() - t0) * 1e6)
        mu, s2 = predict(art, Xq)
        jax.block_until_ready((mu, s2))
        points += batch
    loop_s = time.perf_counter() - t_loop
    retraces = update_trace_count("center") - u0
    first_predict_traces = serve_trace_count("center") - c0

    p50 = float(np.percentile(upd_lat, 50))
    p90 = float(np.percentile(upd_lat, 90))
    speedup = BASELINE_UPDATE_US / p50
    pts_per_sec = points / loop_s

    # the acceptance gates: asserted, not just recorded
    assert retraces == 0, (
        f"in-bucket update retraced {retraces}x over {iters} iterations"
    )
    assert first_predict_traces == 0, (
        f"predict recompiled {first_predict_traces}x after in-bucket updates"
    )
    assert p50 <= MAX_P50_UPDATE_US, (
        f"p50 in-bucket update {p50:.0f}us exceeds gate {MAX_P50_UPDATE_US:.0f}us"
    )
    assert speedup >= MIN_SPEEDUP, (
        f"speedup {speedup:.1f}x vs {BASELINE_UPDATE_US:.2g}us baseline "
        f"below the {MIN_SPEEDUP}x gate"
    )

    emit(
        "stream/update_in_bucket_m40",
        p50,
        p50_update_us=p50,
        p90_update_us=p90,
        update_retraces=retraces,
        first_predict_new_traces=first_predict_traces,
        speedup_vs_baseline=speedup,
        baseline_update_us=BASELINE_UPDATE_US,
        baseline_first_predict_us=BASELINE_FIRST_PREDICT_US,
        batch=batch,
        iters=iters,
    )
    emit(
        "stream/ingest_while_serving_m40",
        loop_s * 1e6 / iters,
        ingest_points_per_sec=pts_per_sec,
        points_total=points,
        capacity=int(art.y.shape[-1]),
        lengths_total=sum(art.lengths),
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    import json

    from . import common

    print("name,us_per_call,derived")
    main(quick=not args.full)
    with open("BENCH_stream.json", "w") as fh:
        json.dump(common.RESULTS, fh, indent=1)
    print(f"# wrote BENCH_stream.json ({len(common.RESULTS)} rows)", flush=True)
