"""Paper Fig. 2: distortion vs bits/sample for the three schemes on a
20-dimensional Gaussian with a random covariance matrix.

Validates: per-symbol ~ optimal lower bound << dimension reduction; optimal
curve ~0 distortion around 3.5 bits/dim, per-symbol around 5 bits/dim.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core.schemes import PerSymbolScheme, OptimalScheme, DimReductionScheme
from repro.core.rate_distortion import rd_lower_bound_curve
from repro.core.distortion import distortion_quadratic
from .common import timed, emit


def main(quick: bool = True, d: int = 20, n: int = 4000, seed: int = 0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(d, d)); Qx = A @ A.T / d
    B = rng.normal(size=(d, d)); Qy = B @ B.T / d
    X = rng.multivariate_normal(np.zeros(d), Qx, size=n).astype(np.float32)
    D0 = float(np.trace(Qx @ Qy))  # zero-rate distortion

    rates = [5, 10, 20, 40, 70, 100] if quick else list(range(5, 121, 5))
    rows = {}
    for R in rates:
        ps = PerSymbolScheme(R).fit(Qx, Qy)
        Xh, us = timed(lambda: jax.block_until_ready(ps.roundtrip(X)))
        e_ps = float(distortion_quadratic(X, Xh, Qy))
        opt = OptimalScheme(R).fit(Qx, Qy)
        Xo = opt.roundtrip(X, jax.random.PRNGKey(R))
        e_opt = float(distortion_quadratic(X, Xo, Qy))
        m = max(1, R // 16)  # DR at the same wire budget, 16 bits/coefficient
        dr = DimReductionScheme(m).fit(Qx, Qy)
        e_dr = float(distortion_quadratic(X, dr.roundtrip(X), Qy))
        emit("fig2", us, bits=R, bits_per_dim=R / d, lb=opt.expected_distortion,
             opt=e_opt, per_symbol=e_ps, dim_red=e_dr, zero_rate=D0)
        rows[R] = (e_opt, e_ps, e_dr)
    # paper-claim checks (soft; printed, asserted in tests)
    hi = rates[-1]
    emit("fig2_check", 0.0,
         per_symbol_near_opt=rows[rates[2]][1] / max(rows[rates[2]][0], 1e-12),
         hi_rate_frac_of_zero=rows[hi][1] / D0)
    return rows


if __name__ == "__main__":
    main()
