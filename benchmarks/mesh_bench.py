"""Machines-as-devices scaling benchmark (EXPERIMENTS.md §Mesh): the
impl="mesh" execution path on 2 -> 8 forced host devices.

Rows (written to BENCH_mesh.json via benchmarks/run.py --json, or standalone):

* ``mesh/fit_<protocol>_m<k>`` — one full fit(impl="mesh") wall clock
  (wire collectives + training + sharded factor build, includes
  trace/compile) with the wire-bit ledger and its fp32 all-gather baseline;
* ``mesh/predict_<protocol>_m<k>`` — the warm shard_map serve loop
  (per-query-batch latency; psum/KL fusion epilogue on the mesh);
* ``mesh/conformance_m<k>`` — max |mesh - batched| prediction deviation on
  the shared problem, asserted small (the in-benchmark cross-impl check).

The machine mesh needs one device per machine, so the measurement runs in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` —
exactly how tests/test_conformance.py gets its devices in-process, and how a
real deployment would see one process per accelerator.

Run standalone:  PYTHONPATH=src python -m benchmarks.mesh_bench [--full]
or through the driver: PYTHONPATH=src python -m benchmarks.run --json --only mesh
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_SCRIPT = r"""
import json, os, time
import numpy as np
import jax, jax.numpy as jnp

quick = os.environ.get("MESH_BENCH_QUICK", "1") == "1"
from repro.core import split_machines, fit, predict

rng = np.random.default_rng(0)
d = 8
n_per = 40 if quick else 250
rows = []
qps = {}
for m in (2, 4, 8):
    n = m * n_per
    W = rng.normal(size=(d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=n)).astype(np.float32)
    Xt = rng.normal(size=(64, d)).astype(np.float32)
    parts = split_machines(X, y, m, jax.random.PRNGKey(0))
    steps = 10 if quick else 60
    for protocol, bits in (("broadcast", 24), ("center", 24)):
        t0 = time.perf_counter()
        art = fit(parts, bits, protocol, steps=steps, impl="mesh")
        mu, _ = predict(art, Xt)
        jax.block_until_ready(mu)
        t_fit = time.perf_counter() - t0
        # fp32 baseline: every transmitting machine ships raw floats
        tx = art.lengths[1:] if protocol == "center" else art.lengths
        fp32_bits = sum(32 * d * n_j for n_j in tx)
        rows.append({
            "name": f"mesh/fit_{protocol}_m{m}",
            "us_per_call": t_fit * 1e6,
            "derived": {"m": m, "n": n, "d": d, "bits": bits,
                        "wire_kbits": art.wire_bits / 1e3,
                        "payload_kbits": art.payload_bits / 1e3,
                        "fp32_baseline_kbits": fp32_bits / 1e3,
                        "wire_vs_fp32": art.wire_bits / fp32_bits},
        })
        # warm serve loop (trace once, then measure)
        predict(art, Xt)
        reps = 5 if quick else 20
        t0 = time.perf_counter()
        for _ in range(reps):
            mu, s2 = predict(art, Xt)
        jax.block_until_ready(mu)
        t_warm = (time.perf_counter() - t0) / reps
        qps[(protocol, m)] = 64 / t_warm
        rows.append({
            "name": f"mesh/predict_{protocol}_m{m}",
            "us_per_call": t_warm * 1e6,
            "derived": {"m": m, "batch": 64,
                        "qps": 64 / t_warm},
        })
    # cross-impl conformance on the shared problem
    art_b = fit(parts, 24, "broadcast", steps=steps)
    art_m = fit(parts, 24, "broadcast", steps=steps, impl="mesh")
    mu_b, _ = predict(art_b, Xt)
    mu_m, _ = predict(art_m, Xt)
    dev = float(jnp.max(jnp.abs(mu_b - mu_m)))
    assert dev < 1e-2, f"mesh/batched divergence {dev}"
    assert art_b.wire_bits == art_m.wire_bits
    rows.append({
        "name": f"mesh/conformance_m{m}",
        "us_per_call": 0.0,
        "derived": {"m": m, "max_abs_mu_dev": dev,
                    "wire_bits_equal": 1},
    })

# ---- the scaling gate: predict throughput must stay near-constant in m ----
# (the PR-8 regression was a 12x center-protocol collapse from m=2 to m=8,
# caused by the wire program's committed replicated sharding leaking into the
# serve-time jit; the gate keeps it from coming back)
# center gets the strict 2x gate (that's where the collapse lived); broadcast
# runs one more collective per call and, with 8 forced host devices
# oversubscribing this container's cores, measures ~2.2x — gate at the
# measured threshold + headroom, still far below the 12x failure mode.
GATE_MAX_RATIO = {"center": 2.0, "broadcast": 3.0}
for protocol in ("broadcast", "center"):
    q2, q8 = qps[(protocol, 2)], qps[(protocol, 8)]
    ratio = q2 / q8
    gate = GATE_MAX_RATIO[protocol]
    assert ratio < gate, (
        f"mesh predict scaling collapse ({protocol}): m=2 {q2:.0f} qps vs "
        f"m=8 {q8:.0f} qps ({ratio:.2f}x > {gate}x gate)"
    )
    rows.append({
        "name": f"mesh/predict_scaling_{protocol}",
        "us_per_call": 0.0,
        "derived": {"qps_m2": q2, "qps_m8": q8, "m2_over_m8": ratio,
                    "gate_max_ratio": gate, "gate_ok": 1},
    })
print("MESH_BENCH_JSON " + json.dumps(rows))
"""


def main(quick: bool = True) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    from repro.compat import host_device_count_flags

    env["XLA_FLAGS"] = host_device_count_flags(8, env.get("XLA_FLAGS", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["MESH_BENCH_QUICK"] = "1" if quick else "0"
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env, timeout=3600,
    )
    if out.returncode != 0:
        raise RuntimeError(f"mesh_bench subprocess failed:\n{out.stderr[-3000:]}")
    line = [l for l in out.stdout.splitlines() if l.startswith("MESH_BENCH_JSON ")][-1]
    for row in json.loads(line[len("MESH_BENCH_JSON "):]):
        emit(row["name"], row["us_per_call"], **row["derived"])


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    main(quick=not args.full)
    from .common import RESULTS

    with open("BENCH_mesh.json", "w") as f:
        json.dump(RESULTS, f, indent=1)
    print("# wrote BENCH_mesh.json")
