"""Perf-regression harness for the distributed-GP hot path (EXPERIMENTS.md §Perf).

Times old-vs-new on three axes so the speedups are recorded numbers:

* ``train_gp``: legacy per-step jit dispatch loop vs the single lax.scan
  program (dispatch counts are structural: ``steps`` host dispatches vs 1);
* ``broadcast_gp`` with m=8: serial host protocol (scipy scheme fit + one
  dense solve per machine) vs the vmapped padded-shard protocol;
* quantized gram assembly: unfused (decode X̂ to HBM, then matmul — two
  dispatches) vs the fused unpack+dequantize+gram path consuming the PACKED
  wire words (``kernels.qgram.qgram_packed``: the Pallas kernel on TPU, the
  single-jit XLA program elsewhere).  A fused speedup below 1.0x is a
  regression: the row gets a nonzero ``note`` in BENCH_hotpath.json so CI
  artifacts surface it.

Run standalone to write BENCH_hotpath.json:
  PYTHONPATH=src python -m benchmarks.hotpath_bench [--full]
or through the driver: PYTHONPATH=src python -m benchmarks.run --json --only hotpath
"""
from __future__ import annotations

import json

import numpy as np
import jax
import jax.numpy as jnp

from .common import timed, emit


def _problem(n, d, m, seed=0):
    from repro.core import split_machines

    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, 2))
    X = rng.normal(size=(n, d)).astype(np.float32)
    y = (np.sin(X @ W[:, 0]) + 0.4 * (X @ W[:, 1]) + 0.05 * rng.normal(size=n)).astype(
        np.float32
    )
    Xt = rng.normal(size=(max(n // 6, 16), d)).astype(np.float32)
    parts = split_machines(X, y, m, jax.random.PRNGKey(seed))
    return X, y, jnp.asarray(Xt), parts


def _warm_train_dispatch(X, y, steps: int, lr: float = 0.05):
    """Warm-cache dispatch-overhead measurement: train_gp's OWN Adam step
    (via gp.make_adam_step, so the benchmark always times the shipped update
    rule), but with the jitted programs built ONCE and reused across timed
    calls — train_gp builds fresh closures per call, so timing it always
    includes trace+compile.  Loop issues ``steps`` cached dispatches; scan
    issues one."""
    from repro.core.gp import gram_fn, init_params, make_adam_step, nlml_from_gram

    X, y = jnp.asarray(X), jnp.asarray(y)
    k = gram_fn("se")

    def loss(p):
        return nlml_from_gram(k(p, X), y, jnp.exp(p.log_noise))

    step = make_adam_step(loss, lr)
    jstep = jax.jit(step)

    @jax.jit
    def scan_run(p, m, v):
        def body(carry, i):
            return step(i, *carry), None

        (p, m, v), _ = jax.lax.scan(body, (p, m, v), jnp.arange(steps, dtype=jnp.float32))
        return p

    p0 = init_params()
    m0 = jax.tree.map(jnp.zeros_like, p0)
    v0 = jax.tree.map(jnp.zeros_like, p0)

    def run_loop():
        p, m, v = p0, m0, v0
        for i in range(steps):
            p, m, v = jstep(jnp.float32(i), p, m, v)
        return jax.block_until_ready(p)

    def run_scan():
        return jax.block_until_ready(scan_run(p0, m0, v0))

    _, us_loop = timed(run_loop)  # timed() warms once -> repeats hit the cache
    _, us_scan = timed(run_scan)
    return us_loop, us_scan


def main(quick: bool = True):
    from repro.core import train_gp, broadcast_gp
    from repro.core.distributed_gp import pad_parts, _run_wire_protocol
    from repro.kernels.gram.ops import gram as gram_kernel
    from repro.kernels.qgram.ops import qgram_packed
    from repro.kernels.quant.ops import decode as quant_decode

    n, d, m = (240, 6, 8) if quick else (1000, 21, 40)
    steps = 30 if quick else 150
    X, y, Xt, parts = _problem(n, d, m)

    # ---- train_gp: per-step dispatch loop vs one scanned program ----
    # Cold rows: a fresh train_gp call re-traces + re-compiles (what a fresh
    # experiment pays).  Block on the returned params so async device
    # execution is inside the measured window.
    _, us_loop = timed(
        lambda: jax.block_until_ready(train_gp(X, y, steps=steps, impl="loop").params),
        repeats=1,
    )
    _, us_scan = timed(
        lambda: jax.block_until_ready(train_gp(X, y, steps=steps, impl="scan").params),
        repeats=1,
    )
    emit("hotpath/train_gp_loop", us_loop, host_dispatches=steps, includes_compile=1)
    emit(
        "hotpath/train_gp_scan",
        us_scan,
        host_dispatches=1,
        dispatch_ratio=steps,  # structural: loop issues `steps` jit calls, scan 1
        speedup=us_loop / us_scan,
        includes_compile=1,
    )
    us_loop_w, us_scan_w = _warm_train_dispatch(X, y, steps)
    emit("hotpath/train_gp_loop_warm", us_loop_w, host_dispatches=steps)
    emit(
        "hotpath/train_gp_scan_warm",
        us_scan_w,
        host_dispatches=1,
        speedup=us_loop_w / us_scan_w,
    )

    # ---- broadcast_gp m=8: serial host protocol vs vmapped shards ----
    _, us_host = timed(
        lambda: jax.block_until_ready(
            broadcast_gp(parts, 24, Xt, steps=steps, impl="host", train_impl="loop")[0]
        ),
        repeats=1,
    )
    _, us_bat = timed(
        lambda: jax.block_until_ready(broadcast_gp(parts, 24, Xt, steps=steps)[0]),
        repeats=1,
    )
    emit(f"hotpath/broadcast_gp_m{m}_host", us_host)
    emit(f"hotpath/broadcast_gp_m{m}_batched", us_bat, speedup=us_host / us_bat)

    # ---- quantized gram: unfused decode->HBM->matmul vs fused packed qgram ----
    from repro.core import jax_scheme

    bits = 24
    shards = pad_parts(parts)
    ws = _run_wire_protocol(shards.X, shards.mask, bits, 12, "broadcast", 0)
    words = ws.codes[1]  # the packed wire plane, straight off the protocol
    rates = ws.rates[1]
    cents = ws.scaled_cents[1]
    codes = jax_scheme.unpack_codes(words, rates, total_bits=bits)
    Y = jnp.asarray(np.random.default_rng(1).normal(size=(n, d)).astype(np.float32))

    def unfused():
        xhat = quant_decode(codes, cents)  # X̂ materialized (the HBM round-trip)
        return gram_kernel(xhat, Y)

    def fused():
        return qgram_packed(words, rates, cents, Y, total_bits=bits)

    ref, us_unfused = timed(lambda: jax.block_until_ready(unfused()))
    out, us_fused = timed(lambda: jax.block_until_ready(fused()))
    err = float(jnp.max(jnp.abs(ref - out)))
    speedup = us_unfused / us_fused
    derived = dict(speedup=speedup, max_abs_err=err)
    if speedup < 1.0:
        # visible in the uploaded BENCH artifact: the fusion is LOSING
        derived["note"] = (
            f"REGRESSION: fused qgram {speedup:.2f}x slower than unfused"
        )
    emit("hotpath/qgram_unfused", us_unfused)
    emit("hotpath/qgram_fused", us_fused, **derived)


if __name__ == "__main__":
    import argparse

    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_hotpath.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(quick=not args.full)
    with open(args.out, "w") as f:
        json.dump(common.RESULTS, f, indent=1)
    print(f"# wrote {args.out}")
