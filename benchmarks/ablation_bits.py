"""Ablation: bit-allocation strategies for the per-symbol scheme.

The paper proves the greedy Algorithm-1 allocation optimal among integer
allocations.  This ablation quantifies what that optimality is worth against
(a) uniform allocation (R/d bits everywhere) and (b) rounded reverse-water-
filling (the real-valued optimum rounded to integers), at equal total rate.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import quantizers as Q
from repro.core.transforms import make_decorrelating_transform
from repro.core.rate_distortion import reverse_waterfill
from repro.core.distortion import distortion_quadratic
from .common import emit


def _alloc_uniform(lam, R, max_bits):
    d = lam.shape[0]
    base = R // d
    extra = R - base * d
    rates = np.full(d, base, dtype=np.int32)
    rates[:extra] += 1  # spill to the largest-variance dims
    return np.minimum(rates, max_bits)


def _alloc_waterfill_rounded(lam, R, max_bits):
    """Real-valued rates r_i = 0.5 log2(lam_i / q_i), floor+greedy-topoff."""
    lam = np.maximum(lam, 1e-12)
    lo, hi = 0.0, float(lam.max())
    for _ in range(100):  # bisect water level so total bits ~ R
        mid = 0.5 * (lo + hi)
        q = np.minimum(mid, lam)
        bits = 0.5 * np.log2(lam / q).sum()
        if bits > R:
            lo = mid
        else:
            hi = mid
    q = np.minimum(0.5 * (lo + hi), lam)
    real = 0.5 * np.log2(lam / np.maximum(q, 1e-12))
    rates = np.minimum(np.floor(real).astype(np.int32), max_bits)
    # distribute the leftover greedily by fractional part
    left = int(R - rates.sum())
    order = np.argsort(-(real - np.floor(real)))
    for i in order[:max(left, 0)]:
        if rates[i] < max_bits:
            rates[i] += 1
    return rates


def _distortion(X, tr, rates, Qy):
    sigma = np.sqrt(np.maximum(tr.variances, 0)).astype(np.float32)
    edges, cents = Q.build_codebook_tables(int(max(rates.max(), 1)))
    Xp = X @ tr.T.T.astype(np.float32)
    codes = Q.quantize(jnp.asarray(Xp), jnp.asarray(sigma), jnp.asarray(rates), edges)
    Xh = np.asarray(Q.dequantize(codes, jnp.asarray(sigma), jnp.asarray(rates), cents)) @ tr.T_inv.T.astype(np.float32)
    return float(distortion_quadratic(X, Xh, Qy))


def main(quick: bool = True, d: int = 20, n: int = 4000, seed: int = 0, max_bits: int = 10):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(d, d)); Qx = A @ A.T / d
    B = rng.normal(size=(d, d)); Qy = B @ B.T / d
    X = rng.multivariate_normal(np.zeros(d), Qx, size=n).astype(np.float32)
    tr = make_decorrelating_transform(Qx, Qy)
    lam = np.maximum(tr.variances, 0)

    for R in ([10, 20, 40, 80] if quick else [5, 10, 20, 40, 60, 80, 100, 120]):
        greedy = Q.allocate_bits_greedy(lam, R, max_bits)
        uni = _alloc_uniform(lam, R, max_bits)
        wf = _alloc_waterfill_rounded(lam, R, max_bits)
        e_g = _distortion(X, tr, greedy, Qy)
        e_u = _distortion(X, tr, np.asarray(uni), Qy)
        e_w = _distortion(X, tr, np.asarray(wf), Qy)
        emit("ablation_bits", 0.0, R=R, greedy=e_g, uniform=e_u,
             waterfill_rounded=e_w, uniform_penalty=e_u / max(e_g, 1e-12),
             wf_penalty=e_w / max(e_g, 1e-12))


if __name__ == "__main__":
    main()
