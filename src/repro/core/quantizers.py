"""Per-symbol scalar quantization (paper §4.2).

Equiprobable-bin quantizer for zero-mean Gaussian symbols:

* bin boundaries for the *standard* normal are ``alpha_i = Phi^{-1}(i / 2^R)``,
* centroids (eq. 39) ``c_i = 2^R/sqrt(2*pi) * (exp(-a_i^2/2) - exp(-a_{i+1}^2/2))``,
* for a symbol with std ``sigma`` boundaries/centroids simply scale by ``sigma``,
* expected reconstruction error (eq. 40) ``e(sigma^2, R) = sigma^2 - sigma_c^2
  = sigma^2 * e(1, R)``.

Bit allocation across dimensions follows the paper's greedy Algorithm 1, which is
optimal because ``Delta sigma(R)`` is decreasing in R (proved in §4.2).

Tables are precomputed in numpy (host side, static); encode/decode are pure-jnp
and jit/vmap friendly: heterogeneous per-dimension rates are handled with padded
edge/centroid tables indexed by the per-dimension rate.
"""
from __future__ import annotations

import heapq
from functools import lru_cache

import numpy as np
import jax.numpy as jnp
from scipy.special import ndtri  # Phi^{-1}

__all__ = [
    "gauss_bin_edges",
    "gauss_centroids",
    "unit_distortion",
    "expected_distortion",
    "allocate_bits_greedy",
    "build_codebook_tables",
    "quantize",
    "dequantize",
]

DEFAULT_MAX_BITS = 12  # codebooks up to 4096 levels


@lru_cache(maxsize=None)
def gauss_bin_edges(rate: int) -> np.ndarray:
    """Interior bin edges (2^R - 1 of them) for the standard normal."""
    if rate < 0:
        raise ValueError("rate must be >= 0")
    n = 1 << rate
    if n == 1:
        return np.zeros((0,), dtype=np.float64)
    p = np.arange(1, n) / n
    return ndtri(p)


@lru_cache(maxsize=None)
def gauss_centroids(rate: int) -> np.ndarray:
    """Centroids (2^R of them) of the equiprobable bins, standard normal (eq. 39)."""
    n = 1 << rate
    edges = np.concatenate([[-np.inf], gauss_bin_edges(rate), [np.inf]])
    # integral of u*phi(u) over (a_i, a_{i+1}) = phi(a_i) - phi(a_{i+1})
    pdf_vals = np.exp(-0.5 * edges**2) / np.sqrt(2.0 * np.pi)
    pdf_vals[~np.isfinite(edges)] = 0.0
    return n * (pdf_vals[:-1] - pdf_vals[1:])


@lru_cache(maxsize=None)
def unit_distortion(rate: int) -> float:
    """e(1, R) = 1 - 2^{-R} * sum(c_i^2): MSE of quantizing a standard normal."""
    c = gauss_centroids(rate)
    return float(1.0 - np.sum(c**2) / (1 << rate))


def expected_distortion(variance, rate: int):
    """e(sigma^2, R) (eq. 40) — scales linearly with the variance."""
    return variance * unit_distortion(rate)


def allocate_bits_greedy(
    variances: np.ndarray, total_bits: int, max_bits: int = DEFAULT_MAX_BITS
) -> np.ndarray:
    """Paper Algorithm 1: greedily give each of ``total_bits`` to the dimension
    whose distortion drops the most.  O(total_bits * log d) with a heap.

    Returns the per-dimension integer rates R_1..R_d (sum == total_bits, unless
    capped by ``max_bits`` on every dimension).
    """
    variances = np.asarray(variances, dtype=np.float64)
    d = variances.shape[0]
    rates = np.zeros(d, dtype=np.int32)

    def gain(var, r):
        return var * (unit_distortion(r) - unit_distortion(r + 1))

    heap = [(-gain(variances[i], 0), i) for i in range(d)]
    heapq.heapify(heap)
    remaining = int(total_bits)
    while remaining > 0 and heap:
        neg_g, i = heapq.heappop(heap)
        if neg_g >= 0.0:  # no dimension gains anything (all variances 0)
            break
        rates[i] += 1
        remaining -= 1
        if rates[i] < max_bits:
            heapq.heappush(heap, (-gain(variances[i], int(rates[i])), i))
    return rates


def build_codebook_tables(max_bits: int = DEFAULT_MAX_BITS):
    """Padded tables indexed by rate: edges[r, :] has 2^r - 1 real edges then +inf
    padding; centroids[r, :] has 2^r real centroids then 0 padding.

    Shapes: edges (max_bits+1, 2^max_bits - 1), centroids (max_bits+1, 2^max_bits).
    """
    n_max = 1 << max_bits
    edges = np.full((max_bits + 1, n_max - 1), np.inf, dtype=np.float32)
    cents = np.zeros((max_bits + 1, n_max), dtype=np.float32)
    for r in range(max_bits + 1):
        e = gauss_bin_edges(r)
        c = gauss_centroids(r)
        edges[r, : e.shape[0]] = e
        cents[r, : c.shape[0]] = c
    return jnp.asarray(edges), jnp.asarray(cents)


def quantize(x, sigma, rates, edges_table):
    """Encode symbols to bin indices.

    x: (..., d) values; sigma: (d,) per-dim std; rates: (d,) int per-dim bits;
    edges_table: from build_codebook_tables.  Returns int32 codes in [0, 2^R_i).

    code = #(scaled edges below x); padded +inf edges never count, so one padded
    comparison handles every rate at once (this is also the Pallas kernel's form).
    """
    x = jnp.asarray(x)
    edges = edges_table[rates]  # (d, n_max-1)
    scaled = edges * sigma[:, None]  # sigma scales the standard-normal edges
    return jnp.sum(x[..., None] > scaled, axis=-1).astype(jnp.int32)


def dequantize(codes, sigma, rates, centroids_table):
    """Decode bin indices back to centroid values (eq. 39 scaled by sigma)."""
    cents = centroids_table[rates] * sigma[:, None]  # (d, n_max)
    d = cents.shape[0]
    return cents[jnp.arange(d), codes]  # broadcast gather over the last axis
