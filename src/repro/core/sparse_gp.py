"""Titsias (2009) variational sparse GP (SGPR) + the paper's Fig.-7 variant:
quantize the *inducing* points with the per-symbol scheme instead of the full
dataset — the paper's remedy for the very-low-rate regime where shipping many
low-quality samples loses to shipping few good ones.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .gp import GPParams, init_params, gram_fn
from .linalg_safe import DEFAULT_JITTER, chol_jittered

__all__ = ["SGPR", "train_sgpr", "elbo"]


def _chol(K):
    # the ELBO (and hence _chol) sits under jax.grad — one-shot jitter only
    return chol_jittered(K, DEFAULT_JITTER)


def elbo(params: GPParams, Z, X, y, kernel: str):
    """Titsias ELBO:  log N(y | 0, Qnn + s2 I) - tr(Knn - Qnn)/(2 s2),
    with Qnn = Knm Kmm^{-1} Kmn, computed in O(n m^2)."""
    k = gram_fn(kernel)
    s2 = jnp.exp(params.log_noise) + DEFAULT_JITTER
    n, m = X.shape[0], Z.shape[0]
    Kmm = k(params, Z)
    Kmn = k(params, Z, X)
    knn_diag = jnp.diagonal(k(params, X, X))  # O(n^2) but fine at paper scale
    L = _chol(Kmm)
    A = jax.scipy.linalg.solve_triangular(L, Kmn, lower=True) / jnp.sqrt(s2)  # (m, n)
    B = jnp.eye(m, dtype=A.dtype) + A @ A.T
    Lb = _chol(B)
    c = jax.scipy.linalg.solve_triangular(Lb, A @ y, lower=True) / jnp.sqrt(s2)
    log_det = jnp.sum(jnp.log(jnp.diagonal(Lb))) + 0.5 * n * jnp.log(2 * jnp.pi * s2)
    quad = 0.5 * (y @ y) / s2 - 0.5 * (c @ c)
    trace_term = 0.5 * (jnp.sum(knn_diag) / s2 - jnp.sum(A * A))
    return -(log_det + quad + trace_term)


@dataclasses.dataclass
class SGPR:
    kernel: str
    params: GPParams
    Z: jnp.ndarray  # (m, d) inducing inputs
    X: jnp.ndarray
    y: jnp.ndarray

    def predict(self, X_star):
        """Standard SGPR predictive (Titsias eq. 6)."""
        k = gram_fn(self.kernel)
        s2 = jnp.exp(self.params.log_noise) + DEFAULT_JITTER
        m = self.Z.shape[0]
        Kmm = k(self.params, self.Z)
        Kmn = k(self.params, self.Z, self.X)
        Ksm = k(self.params, X_star, self.Z)
        kss = jnp.diagonal(k(self.params, X_star, X_star))
        L = _chol(Kmm)
        A = jax.scipy.linalg.solve_triangular(L, Kmn, lower=True) / jnp.sqrt(s2)
        B = jnp.eye(m, dtype=A.dtype) + A @ A.T
        Lb = _chol(B)
        c = jax.scipy.linalg.solve_triangular(Lb, A @ self.y, lower=True) / jnp.sqrt(s2)
        tmp1 = jax.scipy.linalg.solve_triangular(L, Ksm.T, lower=True)  # (m, t)
        tmp2 = jax.scipy.linalg.solve_triangular(Lb, tmp1, lower=True)
        mean = tmp2.T @ c
        var = kss - jnp.sum(tmp1**2, axis=0) + jnp.sum(tmp2**2, axis=0)
        return mean, jnp.maximum(var, 1e-12)

    def compact(self):
        """The transmit-side summary (inducing inputs + the data needed to
        rebuild the predictive): the paper quantizes exactly these Z."""
        return self.Z

    def qu(self):
        """Variational posterior q(u) = N(m_u, S_u) at the inducing points:
        the machine-local summary a distributed sparse GP ships (Fig. 7).
        Returns (m_u (m,), diag(S_u) (m,))."""
        k = gram_fn(self.kernel)
        s2 = jnp.exp(self.params.log_noise) + DEFAULT_JITTER
        m = self.Z.shape[0]
        Kmm = k(self.params, self.Z)
        Kmn = k(self.params, self.Z, self.X)
        L = _chol(Kmm)
        A = jax.scipy.linalg.solve_triangular(L, Kmn, lower=True) / jnp.sqrt(s2)
        B = jnp.eye(m, dtype=A.dtype) + A @ A.T
        Lb = _chol(B)
        c = jax.scipy.linalg.solve_triangular(Lb, A @ self.y, lower=True) / jnp.sqrt(s2)
        # m_u = Kmm^{1/2-ish} path: m_u = L Lb^{-T} c ; S_u = L B^{-1} L^T
        m_u = L @ jax.scipy.linalg.solve_triangular(Lb.T, c, lower=False)
        V = jax.scipy.linalg.solve_triangular(Lb, L.T, lower=True)  # (m, m)
        S_diag = jnp.sum(V * V, axis=0)
        return m_u, jnp.maximum(S_diag, 1e-8)


def train_sgpr(
    X,
    y,
    num_inducing: int,
    kernel: str = "se",
    params: GPParams | None = None,
    steps: int = 300,
    lr: float = 0.02,
    key=None,
) -> SGPR:
    """Maximize the ELBO over hyperparameters AND inducing locations."""
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    key = key if key is not None else jax.random.PRNGKey(0)
    idx = jax.random.choice(key, X.shape[0], (num_inducing,), replace=False)
    Z0 = X[idx]
    params = params or init_params()
    state = (params, Z0)

    def loss(s):
        p, Z = s
        return -elbo(p, Z, X, y, kernel)

    m = jax.tree.map(jnp.zeros_like, state)
    v = jax.tree.map(jnp.zeros_like, state)
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(i, s, m, v):
        g = jax.grad(loss)(s)
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        t = i + 1.0
        s = jax.tree.map(
            lambda a, mm, vv: a - lr * (mm / (1 - b1**t)) / (jnp.sqrt(vv / (1 - b2**t)) + eps),
            s, m, v,
        )
        return s, m, v

    for i in range(steps):
        state, m, v = step(jnp.float32(i), state, m, v)
    params, Z = state
    return SGPR(kernel=kernel, params=params, Z=Z, X=X, y=y)
