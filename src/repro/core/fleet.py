"""Multi-tenant fleet serving: stacked vmapped predict over homogeneous
:class:`~.protocols.base.FittedProtocol` artifacts.

The fit-once/serve-from-cached-factors story (§4/§5) scales to a FLEET of
independent posteriors by exploiting that artifacts fitted under the same
:class:`~.config.DGPConfig` at the same capacity bucket are pytrees with
IDENTICAL treedefs and leaf shapes.  Stacking T of them leaf-wise produces a
single tenant-major pytree, and ONE vmapped/jitted program — the per-tenant
serve path batched over the leading axis — answers a whole mixed-tenant
micro-batch in one dispatch:

* :func:`bucket_key` — the homogeneity class: (treedef, leaf shapes/dtypes).
  Same key <=> stackable.  :func:`pad_to_capacity` co-buckets artifacts with
  different update histories by padding to a common power-of-two capacity
  (the exact-padding rules of :mod:`.protocols.streaming`).
* :class:`FleetStack` — a device-resident stack with FIXED slot count and an
  LRU tenant->row map.  Tenant swaps write one row in place
  (``leaf.at[row].set``) and queries gather rows by a TRACED index vector
  (``leaf[idx]`` inside the jit), so neither admitting a tenant nor changing
  the tenant mix of a batch ever retraces: the jit cache is keyed on
  (treedef, avals) and both stay fixed (:func:`fleet_trace_count` proves it).
* broadcast artifacts on the fused serve path get a TENANT-BATCHED epilogue:
  the operand build of :func:`~.protocols.broadcast._fused_epilogue_operands`
  is vmapped and the whole (T, m)-expert moment reduction runs as one
  ``kernels.epilogue`` fleet launch (per-tenant accumulators — tenants never
  share a moment row).  Everything else serves through a plain vmap of the
  single-tenant ``_predict_impl`` (center/PoE predicts are matmul-shaped and
  batch cleanly).
* :class:`ArtifactCache` — LRU over loaded artifacts, capacity in artifacts
  or bytes, loader-on-miss (checkpoint-backed via :class:`ArtifactStore`).
* :class:`ArtifactStore` — a directory of per-tenant v6 packed checkpoints
  (:func:`~.protocols.base.save_artifact` format); restores are bitwise
  (tests/test_fleet.py locks cache-mediated == direct load).

The request-coalescing half (micro-batching under a latency budget) lives in
:mod:`repro.launch.fleet`; docs/fleet_serving.md has the design notes and
benchmarks/fleet_bench.py the ≥256-tenant zipf-traffic gates.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import re

import numpy as np
import jax
import jax.numpy as jnp

from .registry import FUSIONS
from .protocols import base
from .protocols import broadcast as _broadcast
from .protocols import streaming
from .protocols.base import FittedProtocol

__all__ = [
    "bucket_key",
    "artifact_nbytes",
    "pad_to_capacity",
    "scale_targets",
    "stack_artifacts",
    "FleetStack",
    "ArtifactCache",
    "ArtifactStore",
    "fleet_trace_count",
]


# --------------------------------------------------------------------------
# homogeneity: when do artifacts co-batch?
# --------------------------------------------------------------------------


def bucket_key(art: FittedProtocol):
    """The stacking-compatibility class of an artifact: its pytree treedef
    (which carries ALL static metadata — protocol, kernel, fusion, config,
    fit_lengths ...) plus every leaf's (shape, dtype).  Two artifacts share
    a bucket iff their keys compare equal; then — and only then — their
    leaves stack into one tenant-major pytree that a single traced program
    serves.  Hashable, so it keys the server's stack table directly."""
    leaves, treedef = jax.tree_util.tree_flatten(art)
    sig = tuple(
        (tuple(np.shape(leaf)), jnp.asarray(leaf).dtype.name)
        for leaf in leaves
    )
    return (treedef, sig)


def artifact_nbytes(art: FittedProtocol) -> int:
    """Device bytes of an artifact's array leaves (the unit of the cache's
    byte-capacity accounting)."""
    return sum(
        int(np.prod(np.shape(leaf)) * jnp.asarray(leaf).dtype.itemsize)
        for leaf in jax.tree_util.tree_leaves(art)
    )


def pad_to_capacity(art: FittedProtocol, capacity: int | None = None
                    ) -> FittedProtocol:
    """Pad an artifact's column-growable buffers up to ``capacity`` (default:
    the next power of two of its occupied columns) using the EXACT padding
    rules of :mod:`.protocols.streaming` — zero columns, identity Cholesky
    slots, masked cross-columns — so the padded artifact predicts identically.

    This is the co-bucketing primitive: a freshly fitted artifact (exact-size
    buffers) and one that streamed a few updates (grown buffers) land in
    different buckets until both are padded to the same capacity.  Host-side
    by construction (one device round-trip per admitted artifact, never in
    the serve loop)."""
    cols = int(jax.device_get(art.stream.cols))
    cap_now = int(art.y.shape[-1])
    target = streaming.next_pow2(cols) if capacity is None else int(capacity)
    if target < cap_now:
        if cap_now == cols and streaming.next_pow2(cols) == cap_now:
            return art  # already exactly at a power-of-two capacity
        raise ValueError(
            f"pad_to_capacity: target {target} is below the artifact's "
            f"current capacity {cap_now} (buffers never shrink)"
        )
    if target == cap_now:
        return art
    return streaming._grow(art, target)


def scale_targets(art: FittedProtocol, c: float) -> FittedProtocol:
    """An EXACT artifact for the target vector ``c * y``: the posterior mean
    operands (``alpha = (G + s2 I)^{-1} y`` and the cached ``walpha``) are
    linear in y, so scaling those leaves yields exactly the artifact a
    protocol run on scaled targets (at the same hyperparameters) would
    produce — without paying the fit.  Per-expert GP variances are
    y-independent; a moment-matching fusion's combined variance shifts with
    the (scaled) expert means, as a real refit's would.  Benchmarks and
    tests use this to build large fleets of genuinely distinct posteriors
    from a handful of fits (same bucket by construction: only leaf VALUES
    change)."""
    c = float(c)
    factors = dict(art.factors)
    for k in ("alpha", "walpha"):
        if k in factors:
            factors[k] = c * factors[k]
    return dataclasses.replace(art, y=c * art.y, factors=factors)


def stack_artifacts(arts) -> FittedProtocol:
    """Stack homogeneous artifacts leaf-wise into one tenant-major pytree
    (every leaf gains a leading tenant axis; static metadata is shared).
    Raises ``ValueError`` naming the first mismatching tenant when the
    artifacts are not bucket-compatible."""
    arts = list(arts)
    if not arts:
        raise ValueError("stack_artifacts: need at least one artifact")
    key0 = bucket_key(arts[0])
    for i, a in enumerate(arts[1:], start=1):
        if bucket_key(a) != key0:
            raise ValueError(
                f"stack_artifacts: artifact {i} is not bucket-compatible "
                f"with artifact 0 (different config/protocol metadata or "
                f"leaf shapes — pad_to_capacity() aligns capacity buckets; "
                f"heterogeneous configs need separate stacks)"
            )
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *arts)


# --------------------------------------------------------------------------
# the one fleet predict program
# --------------------------------------------------------------------------

# Incremented INSIDE the traced fleet program (the serve-trace idiom of
# core/protocols/base.py): a steady-state fleet loop — tenants swapping
# in/out of stacks included — must leave it flat.  benchmarks/fleet_bench.py
# gates on exactly that.
_FLEET_TRACES: collections.Counter = collections.Counter()


def fleet_trace_count(protocol: str = "broadcast") -> int:
    """How many times the stacked fleet predict program has been (re)traced
    for a protocol — tenant swaps and batch-mix changes hold this constant
    (row writes and traced gather indices never change the jit key)."""
    return _FLEET_TRACES[protocol]


def _fleet_fused_operands(art, Xq, avail, proj):
    """Single-tenant slice of the fused-epilogue operand build (vmapped over
    the stacked tenant axis by :func:`_fleet_predict_fused`).  Mirrors the
    sanitize prologue of ``base._predict_impl`` term for term — the parity
    tests lock the two paths together.  ``proj`` is the tenant's
    PRECOMPUTED woodbury projector (built once at admit time and kept
    resident next to the stack), so the hot path skips the per-query
    ``cho_solve`` chain the single-tenant serve pays on every call."""
    from .gp import prior_diag

    p = art.params
    noise = jnp.exp(p.log_noise)
    finite_row = jnp.isfinite(Xq).all(axis=-1)
    Xqc = jnp.where(finite_row[:, None], Xq, 0.0)
    sq_star = jnp.sum(Xqc**2, -1)
    g_ss = prior_diag(art.kernel, p, sq_star)
    G, Ainv, P, walpha, prior, w = _broadcast._fused_epilogue_operands(
        art, Xqc, sq_star, g_ss, noise, avail, proj
    )
    return finite_row, noise, g_ss, G, Ainv, P, walpha, prior, w


def _fleet_predict_fused(art, Xq, avail, proj, block):
    """Tenant-batched fused serve: vmap the operand build, then ONE
    ``epilogue_moments_fleet`` launch reduces every tenant's experts into
    per-tenant moment rows, and a vmapped ``finalize`` finishes.  The
    non-finite tripwire of ``base._predict_impl`` is applied per tenant row
    (a hostile query row degrades ITS tenant's row to the prior and touches
    nothing else)."""
    from ..kernels.epilogue.ops import epilogue_moments_fleet

    spec = FUSIONS.get(art.fuse)
    m = len(art.fit_lengths)
    av_ax = None if avail is None else 0
    pr_ax = None if proj is None else 0
    finite, noise, g_ss, G, Ainv, P, walpha, prior, w = jax.vmap(
        _fleet_fused_operands, in_axes=(0, 0, av_ax, pr_ax)
    )(art, Xq, avail, proj)
    S = epilogue_moments_fleet(G, Ainv, P, walpha, g_ss, prior, w,
                               fuse=art.fuse, block=block)
    mu, var = jax.vmap(lambda Si, pri: spec.finalize(Si, m, pri))(S, prior)
    ok = finite & jnp.isfinite(mu) & jnp.isfinite(var)
    mu = jnp.where(ok, mu, 0.0)
    var = jnp.where(ok, var, g_ss + noise[:, None])
    return mu, var


def _fleet_predict_impl(stack, idx, Xq, avail=None, proj=None, *, block=None):
    """The fleet serve program: gather the batch's tenant rows from the
    resident stack BY TRACED INDEX (idx value changes never retrace), then
    answer every tenant in one batched pass.  ``stack`` is a stacked
    FittedProtocol (leading tenant axis on every leaf); ``Xq`` is
    (S, t, d); ``avail`` is None or (S, m); ``proj`` is the stack's
    slot-aligned precomputed projector buffer (or None off the fused path);
    ``block`` is the statically resolved fleet-epilogue t-tile."""
    _FLEET_TRACES[stack.protocol] += 1  # runs at trace time only
    art = jax.tree.map(lambda leaf: leaf[idx], stack)
    if art.protocol == "broadcast" and art.impl != "mesh" and \
            _broadcast._uses_fused_epilogue(art, FUSIONS.get(art.fuse)):
        P = None if proj is None else proj[idx]
        return _fleet_predict_fused(art, Xq, avail, P, block)
    av_ax = None if avail is None else 0
    return jax.vmap(base._predict_impl, in_axes=(0, 0, av_ax))(art, Xq, avail)


_fleet_predict_jit = jax.jit(_fleet_predict_impl, static_argnames=("block",))

# admit-time projector builds (one artifact / a whole stacked tree); jitted so
# repeated admits into the same bucket reuse one compiled program
_projector_jit = jax.jit(_broadcast._epilogue_projector)
_stack_projector_jit = jax.jit(jax.vmap(_broadcast._epilogue_projector))


# --------------------------------------------------------------------------
# FleetStack: fixed device-resident slots, LRU tenant->row map
# --------------------------------------------------------------------------


class FleetStack:
    """A device-resident capacity bucket of the fleet: ``slots`` stacked
    artifact rows, an LRU ``tenant -> row`` map, and the one jitted predict
    program over them.

    The slot count is FIXED at construction (padded up to a power of two),
    which is the whole retrace story: admitting a tenant writes one row in
    place (``leaf.at[row].set(...)`` — shapes unchanged), evicting is just
    forgetting a map entry, and a query batch gathers its rows through a
    traced index vector, so the steady-state loop compiles exactly once per
    (batch shape, availability pattern).  Admits run off the hot path (host
    work per CACHE miss, not per request)."""

    def __init__(self, tenants, slots: int | None = None):
        items = list(tenants.items()) if isinstance(tenants, dict) \
            else list(tenants)
        if not items:
            raise ValueError("FleetStack: need at least one tenant artifact")
        self.key = bucket_key(items[0][1])
        n_slots = streaming.next_pow2(len(items)) if slots is None \
            else int(slots)
        if n_slots < len(items):
            raise ValueError(
                f"FleetStack: {len(items)} tenants exceed {n_slots} slots"
            )
        # unoccupied slots hold a copy of the first artifact: every row must
        # be a VALID artifact (the vmapped program computes all S gathered
        # rows), and unaddressed rows are never returned to a caller
        padded = [a for _, a in items]
        padded += [items[0][1]] * (n_slots - len(items))
        self.tree = stack_artifacts(padded)
        self.slots = n_slots
        self.protocol = items[0][1].protocol
        self._rows: "collections.OrderedDict[object, int]" = \
            collections.OrderedDict()
        self._free = list(range(len(items), n_slots))[::-1]
        self.swaps = 0  # admits that evicted a resident tenant
        self._block = None
        self._block_t = None
        for row, (tid, art) in enumerate(items):
            if tid in self._rows:
                raise ValueError(f"FleetStack: duplicate tenant id {tid!r}")
            self._rows[tid] = row
        # fused-path stacks keep the query-independent woodbury projector
        # resident per slot: built ONCE per admit (off the hot path), so the
        # stacked dispatch skips the per-query cho_solve chain the
        # single-tenant serve pays on every predict
        a0 = items[0][1]
        self._proj = None
        if self.protocol == "broadcast" and a0.impl != "mesh" and \
                _broadcast._uses_fused_epilogue(a0, FUSIONS.get(a0.fuse)):
            self._proj = _stack_projector_jit(self.tree)

    def __contains__(self, tenant) -> bool:
        return tenant in self._rows

    def __len__(self) -> int:
        return len(self._rows)

    def tenants(self) -> tuple:
        """Resident tenant ids, least-recently-used first."""
        return tuple(self._rows)

    def admit(self, tenant, art: FittedProtocol) -> int:
        """Make ``tenant`` resident (write its leaves into one slot row) and
        return the row.  A re-admit refreshes the row in place; a full stack
        evicts the least-recently-used tenant.  Never retraces the predict
        program: only leaf VALUES change."""
        if bucket_key(art) != self.key:
            raise ValueError(
                f"FleetStack.admit({tenant!r}): artifact is not "
                "bucket-compatible with this stack (different config "
                "metadata or leaf shapes; pad_to_capacity() aligns capacity "
                "buckets, heterogeneous configs need their own stack)"
            )
        if tenant in self._rows:
            row = self._rows[tenant]
            self._rows.move_to_end(tenant)
        elif self._free:
            row = self._free.pop()
            self._rows[tenant] = row
        else:
            _, row = self._rows.popitem(last=False)  # evict the LRU tenant
            self._rows[tenant] = row
            self.swaps += 1
        self.tree = jax.tree.map(
            lambda leaf, new: leaf.at[row].set(new), self.tree, art
        )
        if self._proj is not None:
            self._proj = self._proj.at[row].set(_projector_jit(art))
        return row

    def touch(self, tenant) -> None:
        """Refresh a resident tenant's LRU recency without rewriting its row
        (raises ``KeyError`` when not resident).  The server touches every
        batch member during grouping so a same-batch admit can never evict a
        co-batched tenant."""
        self._rows.move_to_end(tenant)

    def rows(self, tenants) -> np.ndarray:
        """Slot rows for a tenant batch (touches their LRU recency).  Raises
        ``KeyError`` naming the non-resident tenants."""
        missing = [t for t in tenants if t not in self._rows]
        if missing:
            raise KeyError(
                f"FleetStack: tenants not resident: {missing!r} (admit() "
                "them first — FleetServer does this through its cache)"
            )
        for t in tenants:
            self._rows.move_to_end(t)
        return np.asarray([self._rows[t] for t in tenants], np.int32)

    def _epilogue_block(self, t: int):
        """Statically resolve (and memoize) the tuned fleet-epilogue t-tile
        for this stack's launch shape — outside the trace, so a cache miss
        can actually time candidates (satellite: the fleet shape family is
        swept and cached like the single-tenant ones)."""
        if self.protocol != "broadcast" or "Ainv" not in self.tree.factors:
            return None
        if self._block_t == t:
            return self._block
        from ..kernels.epilogue.ops import fleet_epilogue_block

        m = len(self.tree.fit_lengths)
        K = int(self.tree.factors["Ainv"].shape[-1])
        self._block = fleet_epilogue_block(self.slots, m, t, K,
                                           fuse=self.tree.fuse)
        self._block_t = t
        return self._block

    def predict(self, tenants, Xq, avail=None):
        """Serve one mixed-tenant micro-batch in ONE dispatch.

        ``tenants``: length-S sequence of resident tenant ids (repeats
        allowed); ``Xq``: (S, t, d) per-tenant query batches; ``avail``:
        optional (S, m) per-tenant availability masks (rows of ones = that
        tenant healthy).  Returns (mu, var), each (S, t)."""
        idx = self.rows(tenants)
        Xq = jnp.asarray(Xq, jnp.float32)
        if Xq.ndim != 3 or Xq.shape[0] != idx.shape[0]:
            raise ValueError(
                f"FleetStack.predict: Xq must be (S, t, d) with "
                f"S == len(tenants) == {idx.shape[0]}, got {Xq.shape}"
            )
        if avail is not None:
            avail = jnp.asarray(
                (np.asarray(avail, np.float32) > 0).astype(np.float32)
            )
            m = len(self.tree.fit_lengths)
            if avail.shape != (idx.shape[0], m):
                raise ValueError(
                    f"FleetStack.predict: avail must be (S, m) = "
                    f"({idx.shape[0]}, {m}), got {tuple(avail.shape)}"
                )
        block = self._epilogue_block(int(Xq.shape[1]))
        return _fleet_predict_jit(self.tree, jnp.asarray(idx), Xq, avail,
                                  self._proj, block=block)


# --------------------------------------------------------------------------
# ArtifactCache: LRU over loaded artifacts, loader-on-miss
# --------------------------------------------------------------------------


class ArtifactCache:
    """LRU cache of loaded serving artifacts with checkpoint-backed
    load-on-miss.

    ``loader(tenant) -> FittedProtocol`` supplies misses (typically
    :meth:`ArtifactStore.load`); capacity is bounded in ARTIFACTS
    (``capacity``), BYTES (``capacity_bytes``, leaf nbytes via
    :func:`artifact_nbytes`), or both — eviction drops least-recently-used
    entries until both bounds hold.  A single artifact larger than the byte
    budget is kept (capacity bounds the cache, it does not refuse service).
    Hit/miss/eviction counters feed the bench's reported hit rate."""

    def __init__(self, loader, capacity: int | None = None,
                 capacity_bytes: int | None = None):
        self._loader = loader
        self.capacity = None if capacity is None else int(capacity)
        self.capacity_bytes = None if capacity_bytes is None \
            else int(capacity_bytes)
        if self.capacity is not None and self.capacity < 1:
            raise ValueError("ArtifactCache: capacity must be >= 1")
        self._items: "collections.OrderedDict[object, FittedProtocol]" = \
            collections.OrderedDict()
        self._nbytes: dict = {}
        self.total_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __contains__(self, tenant) -> bool:
        return tenant in self._items

    def __len__(self) -> int:
        return len(self._items)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def get(self, tenant) -> FittedProtocol:
        """The cached artifact for ``tenant``; a miss pays one loader call
        (checkpoint restore) and may evict LRU entries."""
        art = self._items.get(tenant)
        if art is not None:
            self.hits += 1
            self._items.move_to_end(tenant)
            return art
        self.misses += 1
        art = self._loader(tenant)
        self.put(tenant, art)
        return art

    def put(self, tenant, art: FittedProtocol) -> None:
        """Insert/refresh an entry, then evict LRU entries until the
        artifact- and byte-capacity bounds both hold."""
        if tenant in self._items:
            self.total_bytes -= self._nbytes.pop(tenant)
            del self._items[tenant]
        nb = artifact_nbytes(art)
        self._items[tenant] = art
        self._nbytes[tenant] = nb
        self.total_bytes += nb
        while len(self._items) > 1 and (
            (self.capacity is not None and len(self._items) > self.capacity)
            or (self.capacity_bytes is not None
                and self.total_bytes > self.capacity_bytes)
        ):
            old, _ = self._items.popitem(last=False)
            self.total_bytes -= self._nbytes.pop(old)
            self.evictions += 1

    def stats(self) -> dict:
        return {
            "entries": len(self._items),
            "bytes": self.total_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }


# --------------------------------------------------------------------------
# ArtifactStore: per-tenant v6 packed checkpoints on disk
# --------------------------------------------------------------------------


def _tenant_dirname(tenant) -> str:
    safe = re.sub(r"[^A-Za-z0-9_.-]", "_", str(tenant))
    return f"tenant_{safe}"


class ArtifactStore:
    """A directory of per-tenant artifact checkpoints
    (``root/tenant_<id>/``), each in the v6 packed format of
    :func:`~.protocols.base.save_artifact` — CRC-checksummed npz + metadata
    sidecar, so a bit-rotted tenant fails loud at load instead of serving
    garbage.  ``store.load`` is the canonical :class:`ArtifactCache` loader;
    restores are bitwise-identical to serving the original artifact."""

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)

    def path(self, tenant) -> str:
        return os.path.join(self.root, _tenant_dirname(tenant))

    def save(self, tenant, art: FittedProtocol, step: int = 0) -> str:
        return base.save_artifact(art, self.path(tenant), step)

    def load(self, tenant, step: int | None = None) -> FittedProtocol:
        return base.load_artifact(self.path(tenant), step)

    def meta(self, tenant, step: int | None = None) -> dict:
        """The checkpoint's static metadata WITHOUT loading the arrays — a
        cheap bucket-compatibility screen (protocol/config/capacity) before
        paying a full restore."""
        from ..checkpoint import load_artifact_meta

        return load_artifact_meta(self.path(tenant), step)

    def tenants(self) -> list:
        pref = "tenant_"
        return sorted(
            d[len(pref):] for d in os.listdir(self.root)
            if d.startswith(pref)
            and os.path.isdir(os.path.join(self.root, d))
        )
