"""``DistributedGP`` — the one front door to the paper's protocols.

One validated :class:`~repro.core.config.DGPConfig` in, one estimator out::

    from repro.core import DGPConfig, DistributedGP

    cfg = DGPConfig(protocol="center", scheme="per_symbol", bits_per_sample=24)
    est = DistributedGP(cfg)
    art = est.fit(X, y, m=40)          # wire + train + factorize ONCE
    mu, var = est.predict(art, X_query)  # warm: triangular solves only
    art = est.update(art, X_new, y_new, machine=3)
    est.save(art, "ckpt/")             # est.load("ckpt/") serves identically

Every combination the legacy entry points exposed as loose kwargs is a config
field: 3 protocols × 3 impls × 2 wire schemes × kernels/fusions/backends, all
validated at ``DGPConfig`` construction against the registries
(:mod:`repro.core.registry`), so a typo fails with the known names in hand
rather than deep inside ``fit``.

``impl="host"`` returns the serial oracle models (:class:`~.protocols.center.
CenterGP`, ``HostBroadcastGP``, ``HostPoEGP``) — same ``.predict`` surface,
no artifact; the batched/mesh impls return a checkpointable
:class:`~repro.core.protocols.base.FittedProtocol`.
"""
from __future__ import annotations

import dataclasses

import jax

from .config import DGPConfig
from .gp import GPParams
from .registry import PROTOCOLS
from .protocols import base as _base
from .protocols.base import FittedProtocol, split_machines

__all__ = ["DistributedGP"]


class DistributedGP:
    """Estimator facade over one :class:`~repro.core.config.DGPConfig`.

    Construct with a config (or config fields as keyword overrides) and use
    ``fit`` / ``predict`` / ``update`` / ``save`` / ``load``.  The instance is
    stateless beyond its config: ``fit`` returns the artifact, and every other
    method takes it explicitly — the fit-once/serve-many split stays visible.
    """

    def __init__(self, config: DGPConfig | None = None, **overrides):
        if config is None:
            config = DGPConfig(**overrides)
        elif not isinstance(config, DGPConfig):
            raise TypeError(
                f"DistributedGP expects a DGPConfig, got {type(config).__name__}"
            )
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config

    def __repr__(self):
        return f"DistributedGP({self.config!r})"

    # -- lifecycle -----------------------------------------------------------

    def fit(
        self, X=None, y=None, m: int | None = None, *, parts=None, key=None,
        params: GPParams | None = None,
    ):
        """Run the configured protocol ONCE and return the serving artifact.

        Either pass the pooled dataset ``(X, y, m)`` — it is split uniformly
        at random across ``m`` machines (paper §6), ``key`` seeding the split
        — or pass ``parts`` (a list of per-machine ``(X_j, y_j)`` shards,
        e.g. from :func:`~repro.core.protocols.base.split_machines`) when the
        placement is already decided.

        Returns a :class:`~repro.core.protocols.base.FittedProtocol` for the
        batched/mesh impls; ``impl="host"`` returns the serial oracle model
        (same ``.predict`` surface, no artifact/streaming)."""
        if parts is None:
            if X is None or y is None or m is None:
                raise ValueError(
                    "fit() needs either (X, y, m) or parts=[(X_j, y_j), ...]"
                )
            if key is None:
                key = jax.random.PRNGKey(0)
            parts = split_machines(X, y, m, key)
        elif X is not None or y is not None or m is not None or key is not None:
            raise ValueError(
                "pass either (X, y, m[, key]) or parts, not both — parts are "
                "already placed, so a split key would be silently unused"
            )
        cfg = self.config
        spec = PROTOCOLS.get(cfg.protocol)
        if cfg.impl == "host":
            if spec.fit_host is None:
                raise NotImplementedError(
                    f"protocol {cfg.protocol!r} has no host oracle"
                )
            return spec.fit_host(parts, cfg, params)
        return spec.fit(parts, cfg, params)

    def predict(self, art, X_star, available=None):
        """Serve one query batch: (mean, var) at ``X_star`` from the cached
        factors — no refit, no refactorization (see
        :func:`~repro.core.protocols.base.predict`).

        ``available``: optional (m,) machine-availability mask for
        degraded-mode serving — fusion renormalizes over surviving machines
        (see :func:`~repro.core.protocols.base.serve_health` and
        docs/fault_model.md)."""
        if isinstance(art, FittedProtocol):
            return _base.predict(art, X_star, available)
        return art.predict(X_star, available)  # host oracle models

    def health(self, art, available=None):
        """Degradation report for a fitted artifact (machines lost, rows
        demoted, variance inflation) — see
        :func:`~repro.core.protocols.base.serve_health`."""
        if not isinstance(art, FittedProtocol):
            raise TypeError(
                "health() needs a FittedProtocol artifact (impl='host' oracle "
                "models carry no shard table to report on)"
            )
        return _base.serve_health(art, available)

    def update(self, art, X_new, y_new, machine: int = 0):
        """Stream new points into a fitted artifact (frozen codebooks, rank-k
        factor growth — see :func:`~repro.core.protocols.base.update`)."""
        if not isinstance(art, FittedProtocol):
            raise TypeError(
                "update() needs a FittedProtocol artifact (impl='host' oracle "
                "models do not support streaming)"
            )
        return _base.update(art, X_new, y_new, machine)

    def save(self, art, directory: str, step: int = 0) -> str:
        """Checkpoint an artifact (config recorded in ``meta.json``)."""
        if not isinstance(art, FittedProtocol):
            raise TypeError("save() needs a FittedProtocol artifact")
        return _base.save_artifact(art, directory, step)

    @staticmethod
    def load(directory: str, step: int | None = None, shardings=None) -> FittedProtocol:
        """Restore an artifact checkpoint (pre-redesign checkpoints load with
        a reconstructed default config) — see
        :func:`~repro.core.protocols.base.load_artifact`."""
        return _base.load_artifact(directory, step, shardings)
