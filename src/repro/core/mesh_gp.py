"""DEPRECATED — merged into :mod:`repro.core.distributed_gp`.

The one-shot mesh prototype that lived here is now
``distributed_gp.broadcast_gp_mesh`` (unchanged semantics), and the
first-class machines-as-devices execution path is
``distributed_gp.fit(..., impl="mesh")`` / ``predict`` — one shard_map
program per stage with ``repro.comm`` collectives as the wire, per-machine
factors sharded along the mesh axis, streaming updates, and checkpointing.
This module remains as an import shim only.
"""
from __future__ import annotations

import warnings

from .distributed_gp import broadcast_gp_mesh

__all__ = ["broadcast_gp_mesh"]

warnings.warn(
    "repro.core.mesh_gp is deprecated: use repro.core.distributed_gp "
    '(broadcast_gp_mesh, or the first-class fit(..., impl="mesh") path)',
    DeprecationWarning,
    stacklevel=2,
)
