"""The §5.2 broadcast protocol directly on a DEVICE MESH (production path).

`distributed_gp` simulates m machines on one host; here machines ARE devices
along a mesh axis and the wire is repro.comm.q_all_gather (int8 codes + O(d²)
side info).  Each device ends up with every peer's reconstructed block (its
own exact), builds its local gram view, computes its local GP predictive, and
the per-point predictives are fused with the KL barycenter — all inside one
jit/shard_map program.

This is also what models/gp_head.py uses to put a communication-limited GP
readout on transformer features.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..comm import q_all_gather
from ..compat import shard_map
from .gp import GPParams, gram_fn, posterior_from_gram
from .fusion import kl_fuse_diag

__all__ = ["broadcast_gp_mesh"]


def _local_predict(X_all_blocks, y_all, own_idx, X_star, params: GPParams, kernel: str):
    """One device's §5.2 view: own block exact, peers reconstructed."""
    m, n_loc, d = X_all_blocks.shape
    # reorder so the exact (own) block is first — matches the Nyström layout
    order = jnp.argsort(jnp.where(jnp.arange(m) == own_idx, -1, jnp.arange(m)))
    Xv = X_all_blocks[order].reshape(m * n_loc, d)
    yv = y_all[order].reshape(m * n_loc)
    k = gram_fn(kernel)
    G = k(params, Xv)
    G_sn = k(params, X_star, Xv)
    g_ss = jnp.diagonal(k(params, X_star, X_star))
    return posterior_from_gram(G, G_sn, g_ss, yv, jnp.exp(params.log_noise))


def broadcast_gp_mesh(
    mesh,
    axis: str,
    X,
    y,
    X_star,
    params: GPParams,
    *,
    kernel: str = "se",
    bits_per_sample: int = 32,
    max_bits: int = 8,
):
    """Run the broadcast protocol with devices along ``axis`` as machines.

    X: (n, d) globally, sharded over ``axis`` on dim 0 (n % n_devices == 0);
    y: (n,) likewise; X_star: (t, d) replicated.  Returns fused (mean, var).
    """

    def body(x_l, y_l, xs_l):
        m = jax.lax.psum(1, axis)
        idx = jax.lax.axis_index(axis)
        # the paper's wire: quantized codes, own block exact (repro.comm)
        x_blocks = q_all_gather(x_l, axis, bits_per_sample, max_bits)  # (m, n_loc, d)
        y_all = jax.lax.all_gather(y_l, axis)  # targets are scalars (unquantized)
        mu_i, s2_i = _local_predict(x_blocks, y_all, idx, xs_l, params, kernel)
        # KL-barycenter fusion (eqs. 62-64) across the machine axis
        mus = jax.lax.all_gather(mu_i, axis)
        s2s = jax.lax.all_gather(s2_i, axis)
        return kl_fuse_diag(mus, s2s)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(None, None)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)(X, y, X_star)
