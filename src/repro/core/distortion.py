"""Inner-product distortion measures (paper eqs. 6 and 7)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["second_moment", "distortion_pairwise", "distortion_quadratic"]


def second_moment(Y):
    """S_y = (1/n) Y^T Y — samples are modeled zero-mean (paper §3)."""
    Y = jnp.asarray(Y)
    return Y.T @ Y / Y.shape[0]


def distortion_pairwise(X, Xhat, Y):
    """Eq. (6): (1/n^2) sum_ij (<x_i,y_j> - <xhat_i,y_j>)^2."""
    X, Xhat, Y = map(jnp.asarray, (X, Xhat, Y))
    E = (X - Xhat) @ Y.T  # (n, n_y)
    return jnp.sum(E**2) / (X.shape[0] * Y.shape[0])


def distortion_quadratic(X, Xhat, Sy):
    """Eq. (7): (1/n) sum_i (x_i - xhat_i)^T S_y (x_i - xhat_i)."""
    X, Xhat = jnp.asarray(X), jnp.asarray(Xhat)
    E = X - Xhat
    return jnp.mean(jnp.einsum("nd,de,ne->n", E, jnp.asarray(Sy, E.dtype), E))
