"""Nyström completion of the gram matrix (paper §5, eq. 61).

Given the first K rows ``G_KN`` of an N x N gram matrix (the center machine's
exact local block plus the quantization-estimated cross blocks), approximate

    Ghat = G_NK  G_KK^{-1}  G_KN .

Ghat agrees with G on the first K rows/cols; the error is the Schur complement
of G_KK.  Optionally make the diagonal exact (Snelson & Ghahramani '05 /
FITC-style correction mentioned by the paper) when local diagonals are shipped
(O(N) extra floats).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["nystrom_complete", "nystrom_cross", "nystrom_posterior"]

_JITTER = 1e-6


def nystrom_complete(G_KK, G_KN, exact_diag=None):
    """Ghat = G_NK G_KK^{-1} G_KN   (eq. 61).

    G_KK: (K, K) exact; G_KN: (K, N) first K rows (incl. the K x K block).
    exact_diag: optional (N,) true diagonal to pin (FITC correction)."""
    K = G_KK.shape[0]
    L = jnp.linalg.cholesky(G_KK + _JITTER * jnp.trace(G_KK) / K * jnp.eye(K, dtype=G_KK.dtype))
    W = jax.scipy.linalg.solve_triangular(L, G_KN, lower=True)  # (K, N)
    Ghat = W.T @ W
    if exact_diag is not None:
        Ghat = Ghat + jnp.diag(jnp.maximum(exact_diag - jnp.diagonal(Ghat), 0.0))
    return Ghat


def nystrom_cross(G_KK, G_KN, G_star_K):
    """Test-train covariance through the SAME Nyström map:
    Q_*N = G_*K G_KK^{-1} G_KN (Quiñonero-Candela & Rasmussen's FITC test
    covariance).  Pairing the raw k(x*, x) cross-covariance with a
    Nyström-structured train gram amplifies y-components outside the rank-K
    span — see CenterGP.predict."""
    K = G_KK.shape[0]
    L = jnp.linalg.cholesky(G_KK + _JITTER * jnp.trace(G_KK) / K * jnp.eye(K, dtype=G_KK.dtype))
    W = jax.scipy.linalg.solve_triangular(L, G_KN, lower=True)  # (K, N)
    B = jax.scipy.linalg.solve_triangular(L, G_star_K.T, lower=True)  # (K, t)
    return B.T @ W


def nystrom_posterior(G_KK, G_KN, y, noise_var, G_star_K, g_star_star, exact_diag=None):
    """GP posterior with the Nyström gram, solved in O(N K^2) woodbury form.

    Ghat + s^2 I = s^2 I + W^T W with W = L^{-1} G_KN — avoid forming N x N when
    no exact_diag correction is requested.
    """
    K = G_KK.shape[0]
    if exact_diag is not None:
        # fall back to the dense path (still fine for the paper's N ~ 1e3)
        Ghat = nystrom_complete(G_KK, G_KN, exact_diag)
        from .gp import posterior_from_gram

        return posterior_from_gram(Ghat, G_star_K, g_star_star, y, noise_var)
    L = jnp.linalg.cholesky(G_KK + _JITTER * jnp.trace(G_KK) / K * jnp.eye(K, dtype=G_KK.dtype))
    W = jax.scipy.linalg.solve_triangular(L, G_KN, lower=True)  # (K, N)
    s2 = noise_var + _JITTER
    # (s2 I + W^T W)^{-1} = (I - W^T (s2 I + W W^T)^{-1} W) / s2
    M = s2 * jnp.eye(K, dtype=W.dtype) + W @ W.T
    Lm = jnp.linalg.cholesky(M)

    def kinv(v):  # (Ghat + s2 I)^{-1} v
        t = W @ v
        t = jax.scipy.linalg.cho_solve((Lm, True), t)
        return (v - W.T @ t) / s2

    alpha = kinv(y)
    # test cross-covariances via the same Nyström map: G_*N = G_*K G_KK^{-1} G_KN
    B = jax.scipy.linalg.solve_triangular(L, G_star_K.T, lower=True)  # (K, t)
    G_sN = B.T @ W  # (t, N)
    mean = G_sN @ alpha
    V = jax.vmap(kinv, in_axes=1, out_axes=1)(G_sN.T)  # (N, t)
    var = g_star_star - jnp.sum(G_sN.T * V, axis=0)
    return mean, jnp.maximum(var, 1e-12)
