"""Nyström completion of the gram matrix (paper §5, eq. 61).

Given the first K rows ``G_KN`` of an N x N gram matrix (the center machine's
exact local block plus the quantization-estimated cross blocks), approximate

    Ghat = G_NK  G_KK^{-1}  G_KN .

Ghat agrees with G on the first K rows/cols; the error is the Schur complement
of G_KK.  Optionally make the diagonal exact (Snelson & Ghahramani '05 /
FITC-style correction mentioned by the paper) when local diagonals are shipped
(O(N) extra floats).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .linalg_safe import DEFAULT_JITTER, chol_jittered, chol_safe

__all__ = [
    "nystrom_complete",
    "nystrom_cross",
    "nystrom_posterior",
    "nystrom_factors",
    "nystrom_apply",
    "nystrom_serve_cache",
    "nystrom_apply_cached",
    "nystrom_kinv",
    "chol_update",
    "chol_update_rank",
    "chol_append",
    "chol_append_at",
]


def nystrom_complete(G_KK, G_KN, exact_diag=None):
    """Ghat = G_NK G_KK^{-1} G_KN   (eq. 61).

    G_KK: (K, K) exact; G_KN: (K, N) first K rows (incl. the K x K block).
    exact_diag: optional (N,) true diagonal to pin (FITC correction)."""
    K = G_KK.shape[0]
    # differentiated (training-loss gram_override path): one-shot jitter —
    # lax.while_loop escalation has no reverse-mode rule
    L = chol_jittered(G_KK, DEFAULT_JITTER * jnp.trace(G_KK) / K)
    W = jax.scipy.linalg.solve_triangular(L, G_KN, lower=True)  # (K, N)
    Ghat = W.T @ W
    if exact_diag is not None:
        Ghat = Ghat + jnp.diag(jnp.maximum(exact_diag - jnp.diagonal(Ghat), 0.0))
    return Ghat


def nystrom_cross(G_KK, G_KN, G_star_K):
    """Test-train covariance through the SAME Nyström map:
    Q_*N = G_*K G_KK^{-1} G_KN (Quiñonero-Candela & Rasmussen's FITC test
    covariance).  Pairing the raw k(x*, x) cross-covariance with a
    Nyström-structured train gram amplifies y-components outside the rank-K
    span — see CenterGP.predict."""
    K = G_KK.shape[0]
    L = chol_jittered(G_KK, DEFAULT_JITTER * jnp.trace(G_KK) / K)
    W = jax.scipy.linalg.solve_triangular(L, G_KN, lower=True)  # (K, N)
    B = jax.scipy.linalg.solve_triangular(L, G_star_K.T, lower=True)  # (K, t)
    return B.T @ W


def nystrom_kinv(W, L_M, s2, v):
    """(Ghat + s2 I)^{-1} v in woodbury form:
    (s2 I + W^T W)^{-1} = (I - W^T (s2 I + W W^T)^{-1} W) / s2."""
    t = W @ v
    t = jax.scipy.linalg.cho_solve((L_M, True), t)
    return (v - W.T @ t) / s2


def nystrom_factors(G_KK, G_KN, y, noise_var):
    """Fit-time factorization of the Nyström predictive — everything
    query-independent, computed ONCE:

      L_KK = chol(G_KK + jitter)          (K, K)
      W    = L_KK^{-1} G_KN               (K, N)
      L_M  = chol(s2 I + W W^T)           (K, K)
      alpha = (Ghat + s2 I)^{-1} y        (N,)

    Returned as a dict of arrays so the factor set round-trips through
    ``repro.checkpoint`` with stable key paths.  :func:`nystrom_apply`
    consumes it per query batch with NO further factorization (triangular
    solves only) — the serve-path invariant ``FittedProtocol`` relies on."""
    K = G_KK.shape[0]
    # fit-time: escalate jitter on non-finite factors (rank-deficient grams
    # from corrupted/demoted wire rows) rather than serving NaNs
    L = chol_safe(G_KK, DEFAULT_JITTER * jnp.trace(G_KK) / K)
    W = jax.scipy.linalg.solve_triangular(L, G_KN, lower=True)  # (K, N)
    s2 = noise_var + DEFAULT_JITTER
    M = s2 * jnp.eye(K, dtype=W.dtype) + W @ W.T
    Lm = chol_safe(M)
    alpha = nystrom_kinv(W, Lm, s2, y)
    return {"L_KK": L, "W": W, "L_M": Lm, "alpha": alpha}


def nystrom_apply(factors, G_star_K, g_star_star, noise_var):
    """Query-time half of the Nyström predictive: O(t N K) triangular solves
    against cached :func:`nystrom_factors` — no Cholesky factorization."""
    L, W, Lm, alpha = factors["L_KK"], factors["W"], factors["L_M"], factors["alpha"]
    s2 = noise_var + DEFAULT_JITTER
    # test cross-covariances via the same Nyström map: G_*N = G_*K G_KK^{-1} G_KN
    B = jax.scipy.linalg.solve_triangular(L, G_star_K.T, lower=True)  # (K, t)
    G_sN = B.T @ W  # (t, N)
    mean = G_sN @ alpha
    V = jax.vmap(lambda v: nystrom_kinv(W, Lm, s2, v), in_axes=1, out_axes=1)(G_sN.T)
    var = g_star_star - jnp.sum(G_sN.T * V, axis=0)
    return mean, jnp.maximum(var, 1e-12)


def nystrom_serve_cache(factors):
    """Fused-serve-epilogue operands, precomputed from :func:`nystrom_factors`
    output — all K-sized and CAPACITY-INDEPENDENT (K never grows under
    streaming updates, so these need no ``streaming._GROWTH`` entries):

      Ainv   = L_KK^{-1}        (K, K)  explicit triangular inverse
      U      = W W^T            (K, K)
      walpha = W alpha          (K,)

    With these, :func:`nystrom_apply_cached` serves a query batch with
    matmuls only — no triangular solve against the O(N)-sized ``W`` in the
    hot path.  The keys live in the artifact's ``factors`` dict, so they
    round-trip through checkpoints; artifacts saved before the cache existed
    simply lack the keys and serve on the unfused path."""
    L, W, alpha = factors["L_KK"], factors["W"], factors["alpha"]
    K = L.shape[0]
    Ainv = jax.scipy.linalg.solve_triangular(
        L, jnp.eye(K, dtype=L.dtype), lower=True
    )
    return {"Ainv": Ainv, "U": W @ W.T, "walpha": W @ alpha}


def nystrom_apply_cached(factors, G_star_K, g_star_star, noise_var):
    """Fused-epilogue twin of :func:`nystrom_apply`: algebraically equal, but
    O(t K^2 + K^3) matmuls against the :func:`nystrom_serve_cache` operands
    instead of O(t N K) solves against W.  Derivation: with
    B = L_KK^{-1} G_*K^T the Nyström cross-covariance is G_*N = B^T W, so

      mean = G_*N alpha = B^T (W alpha)
      quad = diag(G_*N (Ghat + s2 I)^{-1} G_*N^T) = diag(B^T P B),
      P    = (U - U M^{-1} U) / s2            (woodbury through L_M)

    — no per-column :func:`nystrom_kinv`, no O(N) operand anywhere."""
    Ainv, U, Lm, walpha = (
        factors["Ainv"], factors["U"], factors["L_M"], factors["walpha"],
    )
    s2 = noise_var + DEFAULT_JITTER
    B = Ainv @ G_star_K.T  # (K, t)
    mean = B.T @ walpha
    P = (U - U @ jax.scipy.linalg.cho_solve((Lm, True), U)) / s2  # (K, K)
    var = g_star_star - jnp.sum(B * (P @ B), axis=0)
    return mean, jnp.maximum(var, 1e-12)


def nystrom_posterior(G_KK, G_KN, y, noise_var, G_star_K, g_star_star, exact_diag=None):
    """GP posterior with the Nyström gram, solved in O(N K^2) woodbury form:
    factorize (:func:`nystrom_factors`) then apply (:func:`nystrom_apply`).

    Ghat + s^2 I = s^2 I + W^T W with W = L^{-1} G_KN — avoid forming N x N when
    no exact_diag correction is requested.
    """
    if exact_diag is not None:
        # fall back to the dense path (still fine for the paper's N ~ 1e3)
        Ghat = nystrom_complete(G_KK, G_KN, exact_diag)
        from .gp import posterior_from_gram

        return posterior_from_gram(Ghat, G_star_K, g_star_star, y, noise_var)
    f = nystrom_factors(G_KK, G_KN, y, noise_var)
    return nystrom_apply(f, G_star_K, g_star_star, noise_var)


# --------------------------------------------------------------------------
# streaming rank-k factor maintenance (FittedProtocol.update)
# --------------------------------------------------------------------------


def chol_update(L, x):
    """Rank-1 Cholesky update: chol(L L^T + x x^T) in O(K^2) — the classic
    Givens sweep, written as a fori_loop so it jits and vmaps."""
    K = L.shape[0]
    idx = jnp.arange(K)

    def body(k, carry):
        L, x = carry
        Lkk, xk = L[k, k], x[k]
        r = jnp.sqrt(Lkk * Lkk + xk * xk)
        c, s = r / Lkk, xk / Lkk
        below = idx > k
        col = L[:, k]
        newcol = jnp.where(below, (col + s * x) / c, col).at[k].set(r)
        x = jnp.where(below, c * x - s * newcol, x)
        return L.at[:, k].set(newcol), x

    L, _ = jax.lax.fori_loop(0, K, body, (L, x))
    return L


def chol_update_rank(L, V):
    """Rank-k update chol(L L^T + V V^T): scan of rank-1 sweeps over the
    columns of V (k, n_new) — O(n_new K^2), never refactorizes the K x K."""
    L, _ = jax.lax.scan(lambda Lc, v: (chol_update(Lc, v), None), L, V.T)
    return L


def chol_append(L, C_on, C_nn):
    """Grow a Cholesky factor by appended rows/cols WITHOUT refactorizing the
    existing block: given L = chol(A) and the bordered matrix
    [[A, C_on], [C_on^T, C_nn]], return its (n+k, n+k) factor

        [[L, 0], [X^T, chol(S)]],   X = L^{-1} C_on,  S = C_nn - X^T X.

    Only the NEW k x k Schur block is factorized — O(n k^2 + k^3)."""
    X = jax.scipy.linalg.solve_triangular(L, C_on, lower=True)  # (n, k)
    S = C_nn - X.T @ X
    Ls = chol_safe(S)
    n, k = C_on.shape
    top = jnp.concatenate([L, jnp.zeros((n, k), L.dtype)], axis=1)
    bot = jnp.concatenate([X.T, Ls], axis=1)
    return jnp.concatenate([top, bot], axis=0)


def chol_append_at(L, C_on, C_nn, pos):
    """Capacity-aware :func:`chol_append`: write the bordered factor rows IN
    PLACE at (traced) slot ``pos`` of a padded-capacity factor buffer instead
    of growing the array.

    ``L`` is (C, C) with the live block in ``[:pos, :pos]`` and every padded
    slot holding the identity pattern (unit diagonal, zeros elsewhere — see
    ``streaming.grow_to_capacity``); ``C_on`` is (C, k) with zero rows at
    every slot >= ``pos``.  Under that contract the forward solve is EXACT:
    padded rows of ``X = L^{-1} C_on`` come out zero (0 right-hand side, zero
    off-diagonals, unit pivot), so ``S = C_nn - X^T X`` equals the true Schur
    complement of the live block and the written rows ``[X^T | chol(S)]``
    reproduce :func:`chol_append` bit-for-bit in the occupied slots.  Shapes
    never change, so consecutive in-bucket appends reuse one traced program
    (the retrace-free streaming contract of ``base.update``)."""
    X = jax.scipy.linalg.solve_triangular(L, C_on, lower=True)  # (C, k)
    S = C_nn - X.T @ X
    rows = jax.lax.dynamic_update_slice(X.T, chol_safe(S), (0, pos))  # (k, C)
    return jax.lax.dynamic_update_slice(L, rows, (pos, 0))
