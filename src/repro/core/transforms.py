"""Linear transforms of the paper: the decorrelating transform of §4.2 and the
inner-product-optimal dimension reduction of Theorem 3 (§4.3), plus the PCA
baseline it is compared against.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from .rate_distortion import product_eigs, _sqrt_psd

__all__ = [
    "DecorrelatingTransform",
    "make_decorrelating_transform",
    "DimReduction",
    "make_dim_reduction",
    "make_pca",
    "dr_encode",
    "dr_decode",
]


class DecorrelatingTransform(NamedTuple):
    """x' = T x has independent (Gaussian) dims with variances ``variances``;
    x  = T_inv x' inverts it.  T = U^T Qy^{1/2}, T_inv = Qy^{-1/2} U (§4.2)."""

    T: np.ndarray
    T_inv: np.ndarray
    variances: np.ndarray  # Lambda (eigenvalues of Qx Qy), descending


def make_decorrelating_transform(Qx, Qy) -> DecorrelatingTransform:
    lam, U, Qy_half, Qy_inv_half = product_eigs(Qx, Qy)
    return DecorrelatingTransform(
        T=U.T @ Qy_half, T_inv=Qy_inv_half @ U, variances=lam
    )


class DimReduction(NamedTuple):
    """Theorem-3 reduction: U (d, m) basis; encoder P (m, d) with z = P x;
    decoder is x̂ = U z.  ``left_out`` is the claimed distortion
    (sum of the d-m smallest eigenvalues of Sx Sy)."""

    U: np.ndarray
    P: np.ndarray
    eigenvalues: np.ndarray
    left_out: float


def _right_eigvecs_product(Sx, Sy):
    """Right eigenvectors of Sx @ Sy via the symmetric surrogate
    B = Sy^{1/2} Sx Sy^{1/2} = W M W^T  =>  V = Sy^{-1/2} W (unit-normalized).

    Sx Sy (Sy^{-1/2} w) = Sx Sy^{1/2} w = Sy^{-1/2} B w = mu Sy^{-1/2} w."""
    Sy_half, Sy_inv_half = _sqrt_psd(Sy)
    B = Sy_half @ np.asarray(Sx, dtype=np.float64) @ Sy_half
    B = 0.5 * (B + B.T)
    mu, W = np.linalg.eigh(B)
    order = np.argsort(mu)[::-1]
    mu, W = np.clip(mu[order], 0.0, None), W[:, order]
    V = Sy_inv_half @ W
    V = V / np.maximum(np.linalg.norm(V, axis=0, keepdims=True), 1e-30)
    return mu, V


def make_dim_reduction(Sx, Sy, m: int) -> DimReduction:
    """Theorem 3: keep the top-m right eigenvectors of Sx Sy; z given by (48)."""
    mu, V = _right_eigvecs_product(Sx, Sy)
    U = V[:, :m]
    Sy = np.asarray(Sy, dtype=np.float64)
    # eq. (48): z = (U^T Sy U)^{-1} U^T Sy x  — Sy-metric projection
    P = np.linalg.solve(U.T @ Sy @ U, U.T @ Sy)
    return DimReduction(U=U, P=P, eigenvalues=mu, left_out=float(mu[m:].sum()))


def make_pca(Sx, m: int) -> DimReduction:
    """Standard PCA baseline: top-m eigenvectors of Sx; orthogonal projection."""
    w, v = np.linalg.eigh(np.asarray(Sx, dtype=np.float64))
    order = np.argsort(w)[::-1]
    w, v = w[order], v[:, order]
    U = v[:, :m]
    return DimReduction(U=U, P=U.T, eigenvalues=np.clip(w, 0, None), left_out=float(w[m:].sum()))


def dr_encode(dr: DimReduction, X):
    """(n, d) -> (n, m)."""
    return jnp.asarray(X) @ jnp.asarray(dr.P, dtype=jnp.asarray(X).dtype).T


def dr_decode(dr: DimReduction, Z):
    """(n, m) -> (n, d)."""
    return jnp.asarray(Z) @ jnp.asarray(dr.U, dtype=jnp.asarray(Z).dtype).T
