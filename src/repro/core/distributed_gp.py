"""Distributed GP learning under communication limits (paper §5).

Two protocols:

* **single-center** (§5.1): machine 0 is the center.  It ships its local
  second-moment S_c to every machine; machine j fits the per-symbol scheme to
  (Qx=S_j, Qy=S_c), transmits int codes; the center decodes X̂_j, forms the
  first-block rows of the gram matrix (its own block exact), Nyström-completes
  (eq. 61), trains hyperparameters on the completed gram, and serves
  predictions.
* **broadcast** (§5.2): every machine broadcasts codes fitted against
  Qy = sum of the *other* machines' covariances; each machine builds its own
  Nyström gram (own block exact), forms a local predictive, and the per-point
  predictives are fused with the KL barycenter (eqs. 62-64).

Execution modes:

* ``impl="batched"`` (default) — machines live on uniform padded shards
  ``(m, n_pad, d)`` with validity masks; scheme fitting
  (core.jax_scheme.fit_scheme), encode/decode, per-machine Nyström
  predictives, and PoE experts all run under ``jax.vmap`` — one batched
  eigh/Cholesky instead of m serial ones, and the whole wire protocol is ONE
  compiled program;
* ``impl="host"`` — the original serial reference/oracle: one host-side scipy
  ``PerSymbolScheme`` fit and one dense Cholesky per machine.  Protocol
  semantics (own block exact, wire-bit accounting) are identical; the batched
  path is locked to it by tests/test_batched_protocol.py;
* ``impl="mesh"`` — the production SPMD path: machines ARE devices along a
  ``("machines",)`` mesh axis, the wire protocol runs as ONE
  ``compat.shard_map`` program whose only inter-machine channel is
  ``repro.comm.q_all_gather`` (int codes on the wire + O(d²) fp32 side info;
  the ledger is computed from what the collective actually moves), per-machine
  factors are built device-local and live SHARDED along the mesh axis, and
  ``predict`` runs as one shard_map program with a psum/KL fusion epilogue
  (broadcast/PoE; §5.1 serving is center-local by construction).  All three
  impls are locked to each other by tests/test_conformance.py.

``gram_backend="pallas"`` routes gram assembly through the Pallas tiled-gram
kernel (kernels/gram) and — for reconstructed blocks — feeds the int wire
codes straight to the fused dequantize+gram kernel (kernels/qgram), so X̂
never round-trips through HBM for the big matmuls (SE kernels ride the same
inner products via ‖x−x'‖² = |x|² + |x'|² − 2⟨x,x'⟩).

Serving (fit once / serve many):

The paper's economics are *amortized*: a machine spends a few bits per symbol
ONCE, and the receiver then answers arbitrarily many GP queries from the
reconstructed inner products.  The serving API makes that split explicit:

* :func:`fit` runs the wire protocol + hyperparameter training + ONE
  factorization and returns a :class:`FittedProtocol` — a checkpointable
  pytree artifact holding the frozen scheme state (codebooks/transforms, int
  wire codes), the decoded shards, the per-machine Nyström/Cholesky factors,
  the fusion method, trained hypers, and the wire-bit ledger;
* :func:`predict` is ONE jitted program per artifact: O(t)-per-query-batch
  triangular solves against the cached factors — no scheme refit, no
  Cholesky refactorization (verify with :func:`predict_op_counts`);
* :func:`update` streams in new points: re-encodes ONLY the new symbols with
  the frozen per-machine codebooks (charging ``rates.sum()`` bits each to the
  ledger) and grows the factors by rank-k updates
  (``nystrom.chol_update_rank`` / ``nystrom.chol_append``) instead of
  refactorizing;
* :func:`save_artifact` / :func:`load_artifact` round-trip the artifact
  through ``repro.checkpoint`` — predictions from a loaded artifact are
  bitwise identical to pre-save.

``single_center_gp`` / ``broadcast_gp`` / ``poe_baseline`` (the paper-facing
entry points) are thin ``fit()`` (+ ``predict()``) compositions.

Targets y are transmitted unquantized (scalars; the paper quantizes inputs
only).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from functools import partial
from typing import Callable, NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from .distortion import second_moment
from . import jax_scheme
from . import quantizers as Q
from .schemes import PerSymbolScheme
from .gp import (
    GPParams,
    init_params,
    gram_fn,
    kernel_from_inner,
    prior_diag,
    nlml_from_gram,
    posterior_factors,
    posterior_apply,
    posterior_from_gram,
    train_gp,
)
from .nystrom import (
    nystrom_complete,
    nystrom_cross,
    nystrom_posterior,
    nystrom_factors,
    nystrom_apply,
    nystrom_kinv,
    chol_update_rank,
    chol_append,
    _JITTER,
)
from .fusion import kl_fuse_diag, kl_fuse_diag_psum
from .poe import combine, combine_psum

__all__ = [
    "split_machines",
    "pad_parts",
    "PaddedShards",
    "WireState",
    "FittedProtocol",
    "fit",
    "predict",
    "update",
    "save_artifact",
    "load_artifact",
    "serve_trace_count",
    "predict_op_counts",
    "quantize_to_center",
    "single_center_gp",
    "broadcast_gp",
    "poe_baseline",
    "broadcast_gp_mesh",
    "machine_mesh",
    "MESH_AXIS",
]


def split_machines(X, y, m: int, key) -> list[tuple[jnp.ndarray, jnp.ndarray]]:
    """Random uniform split across m machines (paper §6: 'randomly distributed
    across 40 machines')."""
    n = X.shape[0]
    perm = jax.random.permutation(key, n)
    chunks = np.array_split(np.asarray(perm), m)
    return [(jnp.asarray(X)[c], jnp.asarray(y)[c]) for c in chunks]


# --------------------------------------------------------------------------
# uniform padded shards — the layout every vmapped protocol stage runs on
# --------------------------------------------------------------------------


class PaddedShards(NamedTuple):
    """(m, n_pad, d) machine shards; invalid rows are zero with mask 0."""

    X: jnp.ndarray  # (m, n_pad, d)
    y: jnp.ndarray  # (m, n_pad)
    mask: jnp.ndarray  # (m, n_pad) float32 validity
    lengths: tuple  # per-machine true row counts (python ints)


def pad_parts(parts) -> PaddedShards:
    m = len(parts)
    d = parts[0][0].shape[1]
    lengths = tuple(int(p[0].shape[0]) for p in parts)
    n_pad = max(lengths)
    X = np.zeros((m, n_pad, d), np.float32)
    y = np.zeros((m, n_pad), np.float32)
    mask = np.zeros((m, n_pad), np.float32)
    for j, (Xj, yj) in enumerate(parts):
        X[j, : lengths[j]] = np.asarray(Xj, np.float32)
        y[j, : lengths[j]] = np.asarray(yj, np.float32)
        mask[j, : lengths[j]] = 1.0
    return PaddedShards(jnp.asarray(X), jnp.asarray(y), jnp.asarray(mask), lengths)


class WireState(NamedTuple):
    """Everything the wire protocol produced, for every machine at once.

    This is the fit-once scheme state: ``(T, T_inv, sigma, rates)`` per machine
    are the frozen codebooks/transforms that :func:`update` reuses to encode
    NEW symbols without refitting (only their ``rates.sum()`` wire bits are
    spent), and ``codes``/``scaled_cents`` feed the fused dequantize+gram
    kernel under ``gram_backend="pallas"``."""

    codes: jnp.ndarray  # (m, n_pad, d) int32; padded rows = -1 (decode to 0)
    decoded: jnp.ndarray  # (m, n_pad, d) reconstructions; padded rows zero
    T_inv: jnp.ndarray  # (m, d, d) decorrelating inverses
    rates: jnp.ndarray  # (m, d) int32 per-dim bit allocation
    sigma: jnp.ndarray  # (m, d)
    scaled_cents: jnp.ndarray  # (m, d, C) qgram decode tables
    T: jnp.ndarray  # (m, d, d) decorrelating forward transforms


@partial(jax.jit, static_argnames=("total_bits", "max_bits", "mode", "center"))
def _run_wire_protocol(X, mask, total_bits: int, max_bits: int, mode: str, center: int):
    """Fit + encode + decode for EVERY machine under one jit: a single batched
    eigh pair (fit), one batched quantize and one batched dequantize.

    mode="center": every machine targets the center's covariance (§5.1);
    mode="broadcast": machine j targets the sum of the others' (§5.2)."""
    m, n_pad, d = X.shape
    n = jnp.maximum(mask.sum(axis=1), 1.0)
    S = jnp.einsum("mnd,mne->mde", X, X) / n[:, None, None]  # padded rows are 0
    if mode == "center":
        Qy = jnp.broadcast_to(S[center], (m, d, d))
    elif mode == "broadcast":
        Qy = jnp.sum(S, axis=0)[None] - S
    else:
        raise ValueError(f"unknown wire mode {mode!r}")
    cap = jax_scheme.codebook_cap(total_bits, max_bits)
    tables = jax_scheme.scheme_tables(total_bits, max_bits)
    states = jax_scheme.fit_scheme_batched(S, Qy, total_bits, cap)
    codes = jax.vmap(lambda st, x: jax_scheme.encode(st, x, tables))(states, X)
    decoded = jax.vmap(lambda st, c: jax_scheme.decode(st, c, tables))(states, codes)
    decoded = decoded * mask[..., None]
    codes = jnp.where(mask[..., None] > 0, codes, -1)
    cents = jax.vmap(lambda st: jax_scheme.scaled_centroids(st, tables))(states)
    return WireState(
        codes, decoded, states["T_inv"], states["rates"], states["sigma"], cents,
        states["T"],
    )


def _wire_bits(rates, lengths, d: int, skip=None) -> int:
    """Paper §4 accounting: R bits/sample on the wire + O(2 d²) fp32 side info
    per transmitting machine."""
    rates = np.asarray(rates)
    total = 0
    for j, n_j in enumerate(lengths):
        if j == skip:
            continue
        total += int(rates[j].sum()) * n_j + 2 * d * d * 32
    return total


# --------------------------------------------------------------------------
# impl="mesh": machines are devices, the collectives are the wire
# --------------------------------------------------------------------------

MESH_AXIS = "machines"


def machine_mesh(m: int) -> Mesh:
    """A 1-D ``("machines",)`` mesh over the first m local devices — the
    execution substrate of ``impl="mesh"``.  On CPU, force placeholder
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (tests/conftest.py does; launch/serve_gp.py --mesh does it for you)."""
    devs = jax.devices()
    if m > len(devs):
        raise ValueError(
            f'impl="mesh" needs one device per machine: m={m} > '
            f"{len(devs)} available devices (hint: "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={m})"
        )
    return Mesh(np.asarray(devs[:m]), (MESH_AXIS,))


@functools.lru_cache(maxsize=None)
def _mesh_wire_fn(m: int, total_bits: int, max_bits: int, mode: str, center: int):
    """One compiled SPMD wire program per (m, R, mode): every device fits its
    scheme, the int codes + O(d²) side info move through comm.q_all_gather,
    and everything the collective moved comes back replicated."""
    from ..comm import q_all_gather

    mesh = machine_mesh(m)

    def body(x_blk, mask_blk):
        _, st = q_all_gather(
            x_blk[0], MESH_AXIS, total_bits, max_bits, mask=mask_blk[0],
            mode=mode, center=center, return_state=True,
        )
        return st

    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P(MESH_AXIS), P(MESH_AXIS)),
        out_specs=P(), check_vma=False,
    ))


def _run_wire_protocol_mesh(X, mask, total_bits: int, max_bits: int, mode: str, center: int):
    """The wire protocol as a REAL device-mesh program (machines = devices
    along ``MESH_AXIS``; ``comm.q_all_gather`` is the only inter-machine
    channel).  Returns the same :class:`WireState` layout as
    :func:`_run_wire_protocol` (replicated arrays) plus the wire-bit ledger
    computed from what the collective actually moved — integer-equal to the
    host oracle's §4 accounting (tests/test_conformance.py)."""
    m, n_pad, d = X.shape
    st = _mesh_wire_fn(m, total_bits, max_bits, mode, center)(X, mask)
    tables = jax_scheme.scheme_tables(total_bits, max_bits)
    cents = jax_scheme.scaled_centroids_batched(st["rates"], st["sigma"], tables)
    ws = WireState(
        st["codes"], st["decoded"], st["T_inv"], st["rates"], st["sigma"],
        cents, st["T"],
    )
    return ws, int(st["wire_bits"])


def _shard_machine_axis(tree, mesh: Mesh):
    """device_put every leaf with its leading (machine) axis along the mesh."""
    sh = NamedSharding(mesh, P(MESH_AXIS))
    return jax.tree.map(lambda a: jax.device_put(a, sh), tree)


@functools.lru_cache(maxsize=None)
def _mesh_broadcast_factor_fn(m: int, kernel: str):
    """Per-machine §5.2 Nyström factor build as ONE shard_map program: device i
    assembles ITS view (own block exact, peers from the wire reconstructions)
    and factorizes it locally; the factor set comes out SHARDED along the
    mesh axis (out_specs P(MESH_AXIS))."""
    mesh = machine_mesh(m)

    def body(x_blk, mask_blk, dec, sq_dec, mask_flat, y_flat, p):
        i = jax.lax.axis_index(MESH_AXIS)
        x, mi = x_blk[0], mask_blk[0]
        n_pad = x.shape[0]
        noise = jnp.exp(p.log_noise)
        sqx = jnp.sum(x**2, -1)
        cols = dec.at[i].set(x)  # own (exact) block replaces its reconstruction
        sq_cols = sq_dec.at[i].set(sqx).reshape(-1)
        ip_KK = x @ x.T
        ip_KN = jnp.moveaxis(
            jnp.einsum("nd,jNd->jnN", x, cols), 0, 1
        ).reshape(n_pad, m * n_pad)
        G_KK = _mask_gram(kernel_from_inner(kernel, p, ip_KK, sqx, sqx), mi)
        G_KN = kernel_from_inner(kernel, p, ip_KN, sqx, sq_cols) * (
            mi[:, None] * mask_flat[None, :]
        )
        fac = nystrom_factors(G_KK, G_KN, y_flat, noise)
        return jax.tree.map(lambda a: a[None], fac)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(MESH_AXIS), P(MESH_AXIS), P(), P(), P(), P(), P()),
        out_specs=P(MESH_AXIS), check_vma=False,
    ))


@functools.lru_cache(maxsize=None)
def _mesh_poe_factor_fn(m: int, kernel: str):
    """Zero-rate expert factorization, one dense Cholesky per device (own
    shard only — no wire at all), factors sharded along the mesh axis."""
    mesh = machine_mesh(m)

    def body(x_blk, y_blk, mask_blk, p):
        x, yj, mj = x_blk[0], y_blk[0], mask_blk[0]
        noise = jnp.exp(p.log_noise)
        sqj = jnp.sum(x**2, -1)
        G = _mask_gram(kernel_from_inner(kernel, p, x @ x.T, sqj, sqj), mj)
        fac = posterior_factors(G, yj * mj, noise)
        return jax.tree.map(lambda a: a[None], fac)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS), P()),
        out_specs=P(MESH_AXIS), check_vma=False,
    ))


def _pallas_ip_rows(wire: WireState, block_order, lengths, Xc, Y):
    """⟨x_i, y_j⟩ for every x in the center gram-row layout (N, p): center rows
    via the Pallas tiled gram on exact points; reconstructed rows straight
    from int codes via the fused dequantize+gram kernel —
    X̂ = dequant(codes) @ T_inv^T, so ⟨x̂, y⟩ = qgram(codes, Y @ T_inv).
    Shared by the CenterGP fit-time builder and the FittedProtocol serve path."""
    from ..kernels.gram.ops import gram as gram_kernel
    from ..kernels.qgram.ops import qgram_batched

    idx = list(block_order[1:])
    codes = wire.codes[jnp.asarray(idx)]
    cents = wire.scaled_cents[jnp.asarray(idx)]
    T_inv = wire.T_inv[jnp.asarray(idx)]
    top = gram_kernel(Xc, Y)  # (n_c, p)
    proj = jnp.einsum("pd,mde->mpe", Y, T_inv)  # Y in each decorrelated basis
    blocks = qgram_batched(codes, cents, proj)  # (m-1, n_pad, p)
    rows = [top] + [blocks[i, : lengths[j]] for i, j in enumerate(idx)]
    return jnp.concatenate(rows, axis=0)


def _mask_gram(G, mask_r, mask_c=None, pin_diag=True):
    """Zero padded rows/cols; optionally pin their diagonal to 1 so Cholesky
    stays SPD.  A point with k(·, pad)=0, y_pad=0 contributes nothing to the
    posterior, which makes the padded program bit-compatible with the
    unpadded one."""
    mask_c = mask_r if mask_c is None else mask_c
    Gm = G * (mask_r[:, None] * mask_c[None, :])
    if pin_diag:
        Gm = Gm + jnp.diag(1.0 - mask_r)
    return Gm


# --------------------------------------------------------------------------
# §5.1 single-center protocol
# --------------------------------------------------------------------------


def _quantize_to_center_host(
    parts, bits_per_sample: int, center: int = 0, max_bits: int = Q.DEFAULT_MAX_BITS
):
    """Serial reference protocol: host-side scipy PerSymbolScheme per machine."""
    S_c = second_moment(parts[center][0])
    Xs, ys, sqs, wire = [], [], [], 0
    for j, (Xj, yj) in enumerate(parts):
        if j == center:
            Xs.append(Xj)
        else:
            S_j = second_moment(Xj)
            sch = PerSymbolScheme(bits_per_sample, max_bits).fit(
                np.asarray(S_j), np.asarray(S_c)
            )
            Xs.append(sch.decode(sch.encode(Xj)))
            wire += sch.wire_bits(Xj.shape[0]) + sch.side_info_bits(Xj.shape[1])
            # (the optional FITC diagonal costs an extra 32 bits/point of
            #  exact |x|^2 — accounted by the caller when gram_mode uses it)
        ys.append(yj)
        sqs.append(jnp.sum(jnp.asarray(Xj) ** 2, axis=-1))
    order = [center] + [j for j in range(len(parts)) if j != center]
    X_recon = jnp.concatenate([Xs[j] for j in order], axis=0)
    y_all = jnp.concatenate([ys[j] for j in order], axis=0)
    sq_norms = jnp.concatenate([sqs[j] for j in order], axis=0)
    n_center = parts[center][0].shape[0]
    return X_recon, y_all, wire, n_center, sq_norms


def _quantize_to_center_batched(
    parts, bits_per_sample: int, center: int, max_bits: int, impl: str = "batched"
):
    """Batched §5.1 wire: one vmapped fit/encode/decode, then assemble the
    center's gram-row layout (exact center block first).  ``impl="mesh"``
    runs the same wire as one shard_map program on a machines-as-devices
    mesh (comm.q_all_gather is the channel; ledger from the actual payload)."""
    shards = pad_parts(parts)
    m, _, d = shards.X.shape
    if impl == "mesh":
        wire_state, wire = _run_wire_protocol_mesh(
            shards.X, shards.mask, bits_per_sample, max_bits, "center", center
        )
    else:
        wire_state = _run_wire_protocol(
            shards.X, shards.mask, bits_per_sample, max_bits, "center", center
        )
        wire = _wire_bits(wire_state.rates, shards.lengths, d, skip=center)
    order = [center] + [j for j in range(m) if j != center]
    blocks = [parts[center][0]] + [
        wire_state.decoded[j, : shards.lengths[j]] for j in order[1:]
    ]
    X_recon = jnp.concatenate(blocks, axis=0)
    y_all = jnp.concatenate([parts[j][1] for j in order], axis=0)
    sq_norms = jnp.concatenate(
        [jnp.sum(jnp.asarray(parts[j][0]) ** 2, axis=-1) for j in order], axis=0
    )
    return X_recon, y_all, wire, shards.lengths[center], sq_norms, shards, wire_state, order


def quantize_to_center(
    parts, bits_per_sample: int, center: int = 0, impl: str = "batched",
    max_bits: int = Q.DEFAULT_MAX_BITS,
):
    """Run the single-center wire protocol; returns
    (X_recon, y_all, wire_bits, n_center, sq_norms).

    X_recon stacks the center's exact block first, then every machine's decoded
    points, matching the paper's gram-row layout.  ``sq_norms`` carries each
    point's EXACT |x|² (an O(32 n)-bit extra the Snelson–Ghahramani/FITC
    diagonal correction needs; included in the wire accounting).

    impl: "host" (serial scipy oracle), "batched" (one vmapped jit), or
    "mesh" (machines are devices; the wire is comm.q_all_gather inside one
    shard_map program) — all three produce integer-identical wire ledgers and
    matching reconstructions (tests/test_conformance.py)."""
    if impl == "host":
        return _quantize_to_center_host(parts, bits_per_sample, center, max_bits)
    if impl not in ("batched", "mesh"):
        raise ValueError(f"unknown impl {impl!r}")
    out = _quantize_to_center_batched(parts, bits_per_sample, center, max_bits, impl)
    return out[:5]


@dataclasses.dataclass
class CenterGP:
    kernel: str
    params: GPParams
    X_recon: jnp.ndarray  # center block exact, rest reconstructed
    y: jnp.ndarray
    n_center: int
    wire_bits: int
    gram_mode: str = "nystrom"
    sq_norms: jnp.ndarray | None = None  # exact |x|^2 for the FITC diagonal
    gram_backend: str = "xla"
    wire: WireState | None = None  # int codes + tables (pallas/qgram path)
    block_order: tuple | None = None  # non-center machine ids, X_recon order
    block_lengths: tuple | None = None  # their true row counts
    _ip_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.gram_backend == "pallas":
            if self.wire is None:
                raise ValueError(
                    'gram_backend="pallas" requires the batched wire protocol '
                    "(int codes) — use impl=\"batched\""
                )
            # materialize the inner-product cache NOW, outside any jit trace:
            # a cache miss inside train_gp's scan would store a leaked tracer
            self.warm_ip()

    def _exact_diag(self, params):
        """k(x_i, x_i) from the EXACT squared norms the machines shipped."""
        return prior_diag(self.kernel, params, self.sq_norms)

    # -- pallas/qgram inner-product assembly --------------------------------

    def _ip_rows(self, Y):
        """⟨x_i, y_j⟩ for every x in X_recon layout — see :func:`_pallas_ip_rows`."""
        return _pallas_ip_rows(
            self.wire, self.block_order, self.block_lengths,
            self.X_recon[: self.n_center], Y,
        )

    def _ip(self, key: str):
        """Cached param-independent inner products (pallas backend): computed
        once with the kernels, then reused as constants by every training step
        and prediction."""
        if key not in self._ip_cache:
            Xc = self.X_recon[: self.n_center]
            if key == "KN":
                self._ip_cache[key] = self._ip_rows(Xc).T  # (n_c, N)
            elif key == "NN":
                self._ip_cache[key] = self._ip_rows(self.X_recon)  # (N, N)
            elif key == "sq":
                self._ip_cache[key] = jnp.sum(self.X_recon**2, axis=-1)
        return self._ip_cache[key]

    def warm_ip(self):
        """Materialize the inner-product cache eagerly (before train_gp's scan
        traces _gram) so the Pallas kernels run once, not once per trace."""
        if self.gram_backend != "pallas":
            return self
        self._ip("sq")
        self._ip("NN" if self.gram_mode == "direct" else "KN")
        return self

    def _gram_pallas(self, params):
        sq = self._ip("sq")
        K = self.n_center
        if self.gram_mode == "direct":
            return kernel_from_inner(self.kernel, params, self._ip("NN"), sq, sq)
        ip_KN = self._ip("KN")
        G_KK = kernel_from_inner(self.kernel, params, ip_KN[:, :K], sq[:K], sq[:K])
        G_KN = kernel_from_inner(self.kernel, params, ip_KN, sq[:K], sq)
        if self.gram_mode == "nystrom_fitc" and self.sq_norms is not None:
            return nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(params))
        return nystrom_complete(G_KK, G_KN)

    def _gram(self, params):
        if self.gram_backend == "pallas":
            return self._gram_pallas(params)
        k = gram_fn(self.kernel)
        if self.gram_mode == "direct":
            # beyond-paper: all blocks straight from the reconstructed points;
            # converges to the full GP as R -> inf (Nyström caps at rank K)
            return k(params, self.X_recon)
        Xc = self.X_recon[: self.n_center]
        G_KK = k(params, Xc)
        G_KN = k(params, Xc, self.X_recon)
        if self.gram_mode == "nystrom_fitc" and self.sq_norms is not None:
            # Snelson & Ghahramani: make the Nyström diagonal exact (the
            # correction acts like per-point noise, taming the rank-K inverse)
            return nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(params))
        return nystrom_complete(G_KK, G_KN)


    def predict(self, X_star):
        if self.gram_backend == "pallas":
            return self._predict_pallas(X_star)
        k = gram_fn(self.kernel)
        g_ss = jnp.diagonal(k(self.params, X_star, X_star))
        noise = jnp.exp(self.params.log_noise)
        if self.gram_mode == "nystrom_fitc":
            # dense path: the FITC-corrected gram is full-rank (the exact
            # diagonal acts as per-point noise), so the direct predictive is
            # well-conditioned.  The test cross-covariance must still pass
            # through the Nyström map — the raw k(x*, x) against a
            # Nyström-structured train gram badly mis-weights y-components
            # outside the rank-K span (was the out-of-range seed bug).
            Xc = self.X_recon[: self.n_center]
            G_KK = k(self.params, Xc)
            G_KN = k(self.params, Xc, self.X_recon)
            G = nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(self.params))
            G_sn = nystrom_cross(G_KK, G_KN, k(self.params, X_star, Xc))
            return posterior_from_gram(G, G_sn, g_ss, self.y, noise)
        if self.gram_mode == "nystrom":
            # consistent low-rank predictive: the test cross-covariances must
            # pass through the same Nyström map (G_*N = G_*K G_KK^{-1} G_KN),
            # else y-components outside the rank-K span are amplified by 1/s^2
            Xc = self.X_recon[: self.n_center]
            return nystrom_posterior(
                k(self.params, Xc), k(self.params, Xc, self.X_recon),
                self.y, noise, k(self.params, X_star, Xc), g_ss,
            )
        G = self._gram(self.params)
        G_sn = k(self.params, X_star, self.X_recon)
        return posterior_from_gram(G, G_sn, g_ss, self.y, noise)

    def _predict_pallas(self, X_star):
        from ..kernels.gram.ops import gram as gram_kernel

        X_star = jnp.asarray(X_star, jnp.float32)
        p = self.params
        sq = self._ip("sq")
        sq_star = jnp.sum(X_star**2, -1)
        K = self.n_center
        Xc = self.X_recon[:K]
        g_ss = prior_diag(self.kernel, p, sq_star)
        noise = jnp.exp(p.log_noise)
        ip_KN = self._ip("KN")
        G_KK = kernel_from_inner(self.kernel, p, ip_KN[:, :K], sq[:K], sq[:K])
        if self.gram_mode == "nystrom":
            ip_sK = gram_kernel(X_star, Xc)
            G_sK = kernel_from_inner(self.kernel, p, ip_sK, sq_star, sq[:K])
            G_KN = kernel_from_inner(self.kernel, p, ip_KN, sq[:K], sq)
            return nystrom_posterior(G_KK, G_KN, self.y, noise, G_sK, g_ss)
        G = self._gram_pallas(p)
        if self.gram_mode == "nystrom_fitc":
            # FITC-consistent test covariance (see the xla path)
            ip_sK = gram_kernel(X_star, Xc)
            G_sK = kernel_from_inner(self.kernel, p, ip_sK, sq_star, sq[:K])
            G_KN = kernel_from_inner(self.kernel, p, ip_KN, sq[:K], sq)
            G_sn = nystrom_cross(G_KK, G_KN, G_sK)
        else:
            ip_sN = self._ip_rows(X_star).T  # (t, N)
            G_sn = kernel_from_inner(self.kernel, p, ip_sN, sq_star, sq)
        return posterior_from_gram(G, G_sn, g_ss, self.y, noise)


def single_center_gp(
    parts,
    bits_per_sample: int,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    params: GPParams | None = None,
    gram_mode: str = "nystrom",
    impl: str = "batched",
    gram_backend: str = "xla",
    max_bits: int = Q.DEFAULT_MAX_BITS,
    train_impl: str = "scan",
):
    """Full §5.1 protocol: quantize-in, Nyström-complete (eq. 61), train hypers
    on the completed gram by marginal likelihood, return a predictor.

    This is now a thin composition over the serving API: the default
    ``impl="batched"`` simply returns ``fit(parts, R, protocol="center", ...)``
    — a :class:`FittedProtocol` artifact whose ``.predict(X_star)`` serves
    queries from cached factors (and which additionally supports
    :func:`update`, :func:`save_artifact` / :func:`load_artifact`).

    Parameters
    ----------
    parts : list of (X_j, y_j) per machine (see :func:`split_machines`); machine
        0 is the center.
    bits_per_sample : the paper's R — total wire bits each non-center machine
        spends per transmitted point (greedily allocated across dimensions).
    kernel : "se" (paper eq. 65) or "linear" (eq. 4).
    gram_mode : how the center assembles the train gram —
        ``"nystrom"`` (eq.-61 completion + consistent low-rank predictive),
        ``"nystrom_fitc"`` (Snelson–Ghahramani exact diagonal; costs an extra
        32 bits/point of exact |x|² on the wire),
        ``"direct"`` (all blocks from reconstructed points; beyond-paper,
        converges to the full GP as R→∞).
    impl : ``"batched"`` (default) runs the wire protocol vmapped over machines
        inside one jit and returns the serving artifact; ``"host"`` is the
        serial scipy reference/oracle (returns the legacy :class:`CenterGP`).
    gram_backend : ``"xla"`` or ``"pallas"`` — the latter routes gram assembly
        through the tiled Pallas gram kernel and feeds int wire codes straight
        to the fused dequantize+gram kernel (batched impl only).
    train_impl : ``"scan"`` compiles the whole Adam loop into one lax.scan
        program; ``"loop"`` is the legacy per-step dispatch baseline.
    """
    if impl == "host":
        X_recon, y_all, wire, n_c, sq_norms = _quantize_to_center_host(
            parts, bits_per_sample, 0, max_bits
        )
        if gram_mode == "nystrom_fitc":  # exact |x|^2 side-channel (32 bits/pt)
            wire += 32 * (X_recon.shape[0] - n_c)
        model = CenterGP(
            kernel=kernel,
            params=params or init_params(),
            X_recon=X_recon,
            y=y_all,
            n_center=n_c,
            wire_bits=wire,
            gram_mode=gram_mode,
            sq_norms=sq_norms,
            gram_backend=gram_backend,
        )
        trained = train_gp(
            X_recon, y_all, kernel=kernel, params=model.params, steps=steps,
            lr=lr, gram_override=model._gram, impl=train_impl,
        )
        model.params = trained.params
        return model
    return fit(
        parts, bits_per_sample, protocol="center", kernel=kernel, steps=steps,
        lr=lr, params=params, gram_mode=gram_mode, gram_backend=gram_backend,
        max_bits=max_bits, train_impl=train_impl, impl=impl,
    )


# --------------------------------------------------------------------------
# §5.2 broadcast protocol
# --------------------------------------------------------------------------


def _broadcast_gp_host(
    parts, bits_per_sample, X_star, kernel, steps, lr, fuse, gram_mode, train_impl,
    max_bits=Q.DEFAULT_MAX_BITS,
):
    """Serial reference §5.2: one scipy scheme fit and one dense solve per
    machine (m host dispatches)."""
    m = len(parts)
    S = [second_moment(Xj) for Xj, _ in parts]
    S_tot = sum(S)
    # every machine encodes ONCE against the sum of the others' covariances
    wire = 0
    decoded = []
    for j, (Xj, yj) in enumerate(parts):
        sch = PerSymbolScheme(bits_per_sample, max_bits).fit(
            np.asarray(S[j]), np.asarray(S_tot - S[j])
        )
        decoded.append(sch.decode(sch.encode(Xj)))
        wire += sch.wire_bits(Xj.shape[0]) + sch.side_info_bits(Xj.shape[1])

    k = gram_fn(kernel)
    y_parts = [yj for _, yj in parts]

    def machine_view(i):
        blocks = [parts[j][0] if j == i else decoded[j] for j in range(m)]
        order = [i] + [j for j in range(m) if j != i]
        Xv = jnp.concatenate([blocks[j] for j in order], axis=0)
        yv = jnp.concatenate([y_parts[j] for j in order], axis=0)
        return Xv, yv, parts[i][0].shape[0]

    # train shared hypers at machine 0 on its own completed gram
    X0, y0, nc0 = machine_view(0)

    def gram0(p):
        Xc = X0[:nc0]
        return nystrom_complete(k(p, Xc), k(p, Xc, X0))

    trained = train_gp(
        X0, y0, kernel=kernel, steps=steps, lr=lr, gram_override=gram0, impl=train_impl
    )
    p = trained.params

    @partial(jax.jit, static_argnums=(2,))
    def local_predict(Xv, yv, nc):
        Xc = Xv[:nc]
        g_ss = jnp.diagonal(k(p, X_star, X_star))
        if gram_mode == "nystrom":
            # consistent low-rank predictive (see CenterGP.predict)
            return nystrom_posterior(
                k(p, Xc), k(p, Xc, Xv), yv, jnp.exp(p.log_noise),
                k(p, X_star, Xc), g_ss,
            )
        G = k(p, Xv)  # "direct": all blocks from reconstructed points
        G_sn = k(p, X_star, Xv)
        return posterior_from_gram(G, G_sn, g_ss, yv, jnp.exp(p.log_noise))

    mus, s2s = [], []
    for i in range(m):
        Xv, yv, nc = machine_view(i)
        mu_i, s2_i = local_predict(Xv, yv, nc)
        mus.append(mu_i)
        s2s.append(s2_i)
    mus = jnp.stack(mus)
    s2s = jnp.stack(s2s)
    if fuse == "kl":
        mu, s2 = kl_fuse_diag(mus, s2s)
    else:
        prior = jnp.diagonal(k(p, X_star, X_star)) + jnp.exp(p.log_noise)
        mu, s2 = combine(fuse, mus, s2s, prior)
    return mu, s2, wire, p


def _train_inner_products(shards: PaddedShards, wire: WireState, backend: str):
    """The query-independent inner-product tensors every machine view is
    assembled from (computed ONCE at fit time):

    A (m, n, n): exact own-block products Xs_i Xs_i^T
    B (m, m, n, n): B[j, i] = X̂_j Xs_i^T (decoded j against exact i)

    backend="pallas" computes A with the tiled gram kernel and B straight
    from int codes with the fused dequantize+gram kernel."""
    X = shards.X
    if backend == "pallas":
        from ..kernels.gram.ops import gram as gram_kernel
        from ..kernels.qgram.ops import qgram

        A = jax.vmap(lambda a: gram_kernel(a, a))(X)
        proj = jnp.einsum("ind,jde->jine", X, wire.T_inv)  # (m_j, m_i, n, d)
        B = jax.vmap(
            lambda c, t, ys: jax.vmap(lambda yy: qgram(c, t, yy))(ys)
        )(wire.codes, wire.scaled_cents, proj)
        return A, B
    A = jnp.einsum("ind,imd->inm", X, X)
    B = jnp.einsum("jnd,imd->jinm", wire.decoded, X)
    return A, B


def _star_exact_products(Xs, X_star, backend: str):
    """C (m, t, n): X_star Xs_i^T — the query-time products against every
    machine's EXACT shard (the Nyström bases)."""
    if backend == "pallas":
        from ..kernels.gram.ops import gram as gram_kernel

        return jax.vmap(lambda a: gram_kernel(X_star, a))(Xs)
    return jnp.einsum("td,ind->itn", X_star, Xs)


def _decoded_inner_products(shards: PaddedShards, wire: WireState, backend: str):
    """D (m, n_pad, m*n_pad): D[j] = X̂_j [X̂_0..X̂_m]^T (decoded-vs-decoded) —
    only the gram_mode="direct" views consume this, so it is computed only for
    them (fit time)."""
    m, n_pad, d = shards.X.shape
    dec_flat = wire.decoded.reshape(m * n_pad, d)
    if backend == "pallas":
        from ..kernels.qgram.ops import qgram_batched

        proj = jnp.einsum("nd,jde->jne", dec_flat, wire.T_inv)
        return qgram_batched(wire.codes, wire.scaled_cents, proj)
    return jnp.einsum("jnd,Nd->jnN", wire.decoded, dec_flat)


def _star_decoded_products(wire: WireState, X_star, backend: str):
    """E (m, t, n_pad): E[j] = X_star X̂_j^T — query-time products against the
    reconstructions (gram_mode="direct" views only); straight from int codes
    under the pallas backend."""
    if backend == "pallas":
        from ..kernels.qgram.ops import qgram_batched

        proj_star = jnp.einsum("td,jde->jte", X_star, wire.T_inv)
        return qgram_batched(wire.codes, wire.scaled_cents, proj_star).transpose(0, 2, 1)
    return jnp.einsum("td,jnd->jtn", X_star, wire.decoded)


def broadcast_gp(
    parts,
    bits_per_sample: int,
    X_star,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    fuse: str = "kl",
    gram_mode: str = "nystrom",
    impl: str = "batched",
    gram_backend: str = "xla",
    max_bits: int = Q.DEFAULT_MAX_BITS,
    train_impl: str = "scan",
):
    """Full §5.2 protocol.  Hyperparameters are trained once (at machine 0, on
    its Nyström view) and shared — a cheap O(#hypers) extra broadcast; the
    paper trains per-machine, which is embarrassingly parallel on a real
    cluster but m-times serial here.  Returns fused (mean, var) at X_star plus
    total wire bits.

    The default ``impl="batched"`` is a thin serving composition:
    ``fit(parts, R, protocol="broadcast", ...)`` builds the
    :class:`FittedProtocol` artifact (every machine's scheme fit, decode, and
    Nyström factorization under jax.vmap on padded shards — one batched
    Cholesky for all m local predictives instead of m serial ones), and
    :func:`predict` serves X_star from the cached factors.  Call :func:`fit`
    directly to keep the artifact and amortize the protocol over many query
    batches."""
    if impl == "host":
        if gram_backend == "pallas":
            raise ValueError('gram_backend="pallas" requires impl="batched"')
        return _broadcast_gp_host(
            parts, bits_per_sample, X_star, kernel, steps, lr, fuse, gram_mode,
            train_impl, max_bits,
        )
    art = fit(
        parts, bits_per_sample, protocol="broadcast", kernel=kernel, steps=steps,
        lr=lr, gram_mode=gram_mode, fuse=fuse, gram_backend=gram_backend,
        max_bits=max_bits, train_impl=train_impl, impl=impl,
    )
    mu, s2 = predict(art, X_star)
    return mu, s2, art.wire_bits, art.params


# --------------------------------------------------------------------------
# zero-rate baselines
# --------------------------------------------------------------------------


def poe_baseline(
    parts,
    X_star,
    kernel: str = "se",
    method: str = "rbcm",
    steps: int = 150,
    lr: float = 0.05,
    impl: str = "batched",
    gram_backend: str = "xla",
    train_impl: str = "scan",
):
    """Zero-rate baselines: each machine trains on its local data only (the
    block-diagonal-gram assumption), predictions combined by PoE/BCM/rBCM.

    ``impl="batched"`` (default) is a thin serving composition:
    ``fit(parts, 0, protocol="poe", method=...)`` factorizes all m experts
    under one vmapped Cholesky on padded shards, and :func:`predict` combines
    the per-expert posteriors.  Call :func:`fit` directly to keep the
    artifact."""
    if impl == "host":
        if gram_backend == "pallas":
            raise ValueError('gram_backend="pallas" requires impl="batched"')
        # shared hypers trained on machine 0's local data (standard practice:
        # the PoE family shares one hyperparameter set across experts)
        trained = train_gp(
            parts[0][0], parts[0][1], kernel=kernel, steps=steps, lr=lr,
            impl=train_impl,
        )
        p = trained.params
        k = gram_fn(kernel)
        noise = jnp.exp(p.log_noise)
        X_star = jnp.asarray(X_star, jnp.float32)

        @jax.jit
        def expert(Xj, yj):
            G = k(p, Xj)
            G_sn = k(p, X_star, Xj)
            g_ss = jnp.diagonal(k(p, X_star, X_star))
            return posterior_from_gram(G, G_sn, g_ss, yj, noise)

        mus, s2s = zip(*[expert(Xj, yj) for Xj, yj in parts])
        mus, s2s = jnp.stack(mus), jnp.stack(s2s)
        prior = jnp.diagonal(k(p, X_star, X_star)) + noise
        return (*combine(method, mus, s2s, prior), p)

    art = fit(
        parts, 0, protocol="poe", kernel=kernel, steps=steps, lr=lr,
        method=method, gram_backend=gram_backend, train_impl=train_impl,
        impl=impl,
    )
    mu, s2 = predict(art, X_star)
    return mu, s2, art.params


# --------------------------------------------------------------------------
# fit-once / serve-many: the FittedProtocol artifact
# --------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["params", "y", "factors", "data", "wire"],
    meta_fields=[
        "protocol", "kernel", "gram_mode", "fuse", "gram_backend",
        "n_center", "lengths", "block_order", "bits_per_sample", "max_bits",
        "wire_bits", "impl",
    ],
)
@dataclasses.dataclass
class FittedProtocol:
    """The serving artifact of a communication-limited distributed GP.

    Produced by :func:`fit`, consumed by :func:`predict` (one jitted program;
    triangular solves only) and :func:`update` (rank-k factor growth).  It is
    a registered JAX pytree: array leaves checkpoint through
    ``repro.checkpoint`` (:func:`save_artifact` / :func:`load_artifact`,
    shardings respected on restore) and the static metadata rides in the
    treedef, so :func:`predict` retraces only when the protocol shape
    actually changes (e.g. after an :func:`update` grows the factors).

    Array fields (pytree leaves)
    ----------------------------
    params : trained :class:`~repro.core.gp.GPParams` (log-space hypers).
    y : targets in the artifact's column layout — center: (N,) flat
        [center block first]; broadcast: (m·n_pad,) mask-zeroed; poe:
        (m, n_pad) mask-zeroed.
    factors : dict of cached solve factors, keyed per gram_mode —
        ``L_KK``/``W``/``L_M``/``alpha`` (Nyström woodbury form, see
        ``nystrom.nystrom_factors``) and/or ``L``/``alpha`` (dense
        ``gp.posterior_factors``).  Broadcast/PoE hold a leading machine
        axis (one batched factor set, NOT m objects).
    data : dict of query-time arrays — the Nyström bases (``Xc`` for center,
        ``Xs``+``mask`` for broadcast/poe), reconstructions (``X_recon``),
        squared norms (``sq_cols``/``sq_exact``/``sq_dec``), and — after a
        PoE :func:`update` — streamed extras (``X_extra``/``extra_mask``/
        ``y_extra``).
    wire : :class:`WireState` — the frozen fit-once scheme state (codebooks,
        transforms, int codes).  :func:`update` re-encodes new symbols with
        it; the pallas backend decodes grams straight from its codes.  None
        for the zero-rate PoE baseline.

    Static metadata (treedef)
    -------------------------
    protocol ("center" | "broadcast" | "poe"), kernel, gram_mode, fuse
    (fusion/combiner name), gram_backend, n_center (center's exact-block
    size K), lengths (per-machine true row counts), block_order (center's
    gram-row machine order), bits_per_sample, max_bits, wire_bits — the
    paper's §4 ledger: R bits/sample per transmitted point + O(2d²) fp32
    side info per machine, extended by every :func:`update` — and impl:
    ``"batched"`` (single-host artifact) or ``"mesh"`` (machines-as-devices:
    broadcast/PoE factors live sharded along the mesh axis and
    :func:`predict` runs as one shard_map program with a psum/KL fusion
    epilogue; a checkpoint round-trip yields the single-host artifact).
    """

    params: GPParams
    y: jnp.ndarray
    factors: dict
    data: dict
    wire: WireState | None
    protocol: str
    kernel: str
    gram_mode: str
    fuse: str
    gram_backend: str
    n_center: int
    lengths: tuple
    block_order: tuple | None
    bits_per_sample: int
    max_bits: int
    wire_bits: int
    impl: str = "batched"

    # -- conveniences (the paper-facing entry points return artifacts) ------

    def predict(self, X_star):
        """Serve one query batch from the cached factors — see :func:`predict`."""
        return predict(self, X_star)

    def update(self, X_new, y_new, machine: int = 0):
        """Stream in new points — see :func:`update`."""
        return update(self, X_new, y_new, machine)

    def save(self, directory: str, step: int = 0) -> str:
        """Checkpoint this artifact — see :func:`save_artifact`."""
        return save_artifact(self, directory, step)

    def _gram(self, params):
        """Rebuild the TRAIN-time gram at the given params (debug/inspection;
        the serve path never calls this — predictions run off cached
        factors).  Center protocol, xla assembly."""
        if self.protocol != "center":
            raise NotImplementedError("_gram inspection is center-protocol only")
        k = gram_fn(self.kernel)
        X = self.data["X_recon"]
        if self.gram_mode == "direct":
            return k(params, X)
        Xc = self.data["Xc"]
        G_KK = k(params, Xc)
        G_KN = k(params, Xc, X)
        if self.gram_mode == "nystrom_fitc":
            exact = prior_diag(self.kernel, params, self.data["sq_exact"])
            return nystrom_complete(G_KK, G_KN, exact_diag=exact)
        return nystrom_complete(G_KK, G_KN)


def fit(
    parts,
    bits_per_sample: int = 0,
    protocol: str = "center",
    *,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    params: GPParams | None = None,
    gram_mode: str = "nystrom",
    fuse: str = "kl",
    method: str = "rbcm",
    gram_backend: str = "xla",
    max_bits: int = Q.DEFAULT_MAX_BITS,
    train_impl: str = "scan",
    impl: str = "batched",
) -> FittedProtocol:
    """Run a distributed-GP protocol ONCE and return the serving artifact.

    This is the fit half of the fit/predict split: wire protocol (scheme fit +
    encode + decode, one vmapped jit), hyperparameter training (one lax.scan
    program), and ONE factorization of every predictive the protocol needs.
    The returned :class:`FittedProtocol` then serves any number of
    :func:`predict` query batches with no scheme refit and no Cholesky
    refactorization, supports streaming :func:`update`, and checkpoints via
    :func:`save_artifact`.

    protocol="center" (§5.1): every machine quantizes toward the center's
    covariance; the center Nyström-completes and holds one factor set.
    protocol="broadcast" (§5.2): every machine broadcasts once; m local
    Nyström factor sets are built under one vmap and fused (``fuse``:
    "kl" = eqs. 62-64 barycenter, or a ``repro.core.poe`` combiner name).
    protocol="poe": the zero-rate baseline (``method``: poe/gpoe/bcm/rbcm);
    ``bits_per_sample`` is ignored and the wire ledger is 0.

    impl="batched" (default) simulates the machines under one vmapped jit;
    impl="mesh" puts machines on a real device mesh — the wire protocol,
    factor builds, and (broadcast/PoE) predict run as shard_map programs
    whose only inter-machine channel is ``repro.comm``, per-machine factors
    come out sharded along the mesh axis, and the wire ledger is computed
    from what the collectives actually move.

    Other knobs (``gram_mode``, ``gram_backend``, ``max_bits``,
    ``train_impl``) as in :func:`single_center_gp`.
    """
    if impl not in ("batched", "mesh"):
        raise ValueError(f'fit() impl must be "batched" or "mesh", got {impl!r}')
    if protocol == "center":
        return _fit_center(
            parts, bits_per_sample, kernel, steps, lr, params, gram_mode,
            gram_backend, max_bits, train_impl, impl,
        )
    if protocol == "broadcast":
        return _fit_broadcast(
            parts, bits_per_sample, kernel, steps, lr, gram_mode, fuse,
            gram_backend, max_bits, train_impl, impl,
        )
    if protocol == "poe":
        return _fit_poe(
            parts, kernel, steps, lr, method, gram_backend, train_impl, impl,
        )
    raise ValueError(f"unknown protocol {protocol!r}")


def _fit_center(
    parts, bits, kernel, steps, lr, params, gram_mode, gram_backend, max_bits,
    train_impl, impl="batched",
):
    (X_recon, y_all, wire, n_c, sq_norms, shards, wire_state, order) = (
        _quantize_to_center_batched(parts, bits, 0, max_bits, impl)
    )
    if gram_mode == "nystrom_fitc":  # exact |x|^2 side-channel (32 bits/point)
        wire += 32 * (X_recon.shape[0] - n_c)
    builder = CenterGP(
        kernel=kernel,
        params=params or init_params(),
        X_recon=X_recon,
        y=y_all,
        n_center=n_c,
        wire_bits=wire,
        gram_mode=gram_mode,
        sq_norms=sq_norms,
        gram_backend=gram_backend,
        wire=wire_state,
        block_order=tuple(order),
        block_lengths=shards.lengths,
    )
    trained = train_gp(
        X_recon, y_all, kernel=kernel, params=builder.params, steps=steps,
        lr=lr, gram_override=builder._gram, impl=train_impl,
    )
    builder.params = trained.params
    p = builder.params
    noise = jnp.exp(p.log_noise)
    K = n_c
    Xc = X_recon[:K]

    # ---- the one-time factorization ----
    if gram_backend == "pallas":
        sq_cols = builder._ip("sq")
        if gram_mode == "direct":
            G_KK = G_KN = None
        else:
            ip_KN = builder._ip("KN")
            G_KK = kernel_from_inner(kernel, p, ip_KN[:, :K], sq_cols[:K], sq_cols[:K])
            G_KN = kernel_from_inner(kernel, p, ip_KN, sq_cols[:K], sq_cols)
    else:
        sq_cols = jnp.sum(X_recon**2, axis=-1)
        if gram_mode == "direct":
            G_KK = G_KN = None
        else:
            k = gram_fn(kernel)
            G_KK = k(p, Xc)
            G_KN = k(p, Xc, X_recon)

    if gram_mode == "nystrom":
        factors = nystrom_factors(G_KK, G_KN, y_all, noise)
    elif gram_mode == "nystrom_fitc":
        G = nystrom_complete(G_KK, G_KN, exact_diag=builder._exact_diag(p))
        factors = posterior_factors(G, y_all, noise)
        # FITC-consistent test map Q_*N = G_*K G_KK^{-1} G_KN needs (L_KK, W)
        L_KK = jnp.linalg.cholesky(
            G_KK + _JITTER * jnp.trace(G_KK) / K * jnp.eye(K, dtype=G_KK.dtype)
        )
        factors["L_KK"] = L_KK
        factors["W"] = jax.scipy.linalg.solve_triangular(L_KK, G_KN, lower=True)
    elif gram_mode == "direct":
        factors = posterior_factors(builder._gram(p), y_all, noise)
    else:
        raise ValueError(f"unknown gram mode {gram_mode!r}")

    return FittedProtocol(
        params=p,
        y=y_all,
        factors=factors,
        data={"Xc": Xc, "X_recon": X_recon, "sq_cols": sq_cols, "sq_exact": sq_norms},
        wire=wire_state,
        protocol="center",
        kernel=kernel,
        gram_mode=gram_mode,
        fuse="",
        gram_backend=gram_backend,
        n_center=K,
        lengths=shards.lengths,
        block_order=tuple(order),
        bits_per_sample=bits,
        max_bits=max_bits,
        wire_bits=int(wire),
        impl=impl,
    )


def _fit_broadcast(
    parts, bits, kernel, steps, lr, gram_mode, fuse, gram_backend, max_bits,
    train_impl, impl="batched",
):
    m = len(parts)
    shards = pad_parts(parts)
    _, n_pad, d = shards.X.shape
    if impl == "mesh":
        if gram_mode != "nystrom":
            raise NotImplementedError(
                'impl="mesh" broadcast supports gram_mode="nystrom" only'
            )
        if gram_backend != "xla":
            raise NotImplementedError(
                'impl="mesh" assembles grams device-local (gram_backend="xla")'
            )
        wire_state, wire = _run_wire_protocol_mesh(
            shards.X, shards.mask, bits, max_bits, "broadcast", 0
        )
    else:
        wire_state = _run_wire_protocol(
            shards.X, shards.mask, bits, max_bits, "broadcast", 0
        )
        wire = _wire_bits(wire_state.rates, shards.lengths, d)

    sq_exact = jnp.sum(shards.X**2, -1)  # (m, n)
    sq_dec = jnp.sum(wire_state.decoded**2, -1)

    # ---- train shared hypers at machine 0 on its completed Nyström gram ----
    # (unpadded slices; the inner products are param-independent constants, so
    # the 150-step scan only re-does the cheap kernel map + Cholesky)
    L = shards.lengths
    n0 = L[0]
    if impl == "mesh":
        # machine-0-local training inputs, straight from the wire output (the
        # batched A/B tensors below exist only to vmap the m simulated views)
        X0s = jnp.asarray(parts[0][0], jnp.float32)
        ip_KK0 = X0s @ X0s.T
        X_cols0 = jnp.concatenate(
            [X0s] + [wire_state.decoded[j, : L[j]] for j in range(1, m)], axis=0
        )
        ip_KN0 = X0s @ X_cols0.T
    else:
        A, B = _train_inner_products(shards, wire_state, gram_backend)
        ip_KK0 = A[0][:n0, :n0]
        ip_KN0 = jnp.concatenate(
            [ip_KK0] + [B[j, 0][: L[j], :n0].T for j in range(1, m)], axis=1
        )
    sq0 = sq_exact[0][:n0]
    sq_cols0 = jnp.concatenate([sq0] + [sq_dec[j][: L[j]] for j in range(1, m)])
    y0 = jnp.concatenate([p[1] for p in parts], axis=0)
    X0 = jnp.concatenate(
        [parts[0][0]] + [wire_state.decoded[j, : L[j]] for j in range(1, m)], axis=0
    )

    def gram0(p):
        G_KK = kernel_from_inner(kernel, p, ip_KK0, sq0, sq0)
        G_KN = kernel_from_inner(kernel, p, ip_KN0, sq0, sq_cols0)
        return nystrom_complete(G_KK, G_KN)

    trained = train_gp(
        X0, y0, kernel=kernel, steps=steps, lr=lr, gram_override=gram0, impl=train_impl
    )
    p = trained.params
    noise = jnp.exp(p.log_noise)

    # ---- factorize every machine's local predictive under ONE vmap ----
    mask_flat = shards.mask.reshape(-1)  # column layout is block j at slot j
    y_flat = (shards.y * shards.mask).reshape(-1)

    if impl == "mesh":
        # one shard_map program: device i assembles & factorizes ITS view;
        # the factor set lives sharded along the mesh axis
        mesh = machine_mesh(m)
        factors = _mesh_broadcast_factor_fn(m, kernel)(
            shards.X, shards.mask, wire_state.decoded, sq_dec, mask_flat,
            y_flat, p,
        )
        data = _shard_machine_axis(
            {"Xs": shards.X, "mask": shards.mask,
             "sq_exact": sq_exact, "sq_dec": sq_dec},
            mesh,
        )
        return FittedProtocol(
            params=p, y=y_flat, factors=factors, data=data, wire=wire_state,
            protocol="broadcast", kernel=kernel, gram_mode=gram_mode,
            fuse=fuse, gram_backend=gram_backend, n_center=0,
            lengths=shards.lengths, block_order=None, bits_per_sample=bits,
            max_bits=max_bits, wire_bits=int(wire), impl="mesh",
        )

    if gram_mode == "nystrom":

        def build(i):
            mask_i = shards.mask[i]
            # own (exact) block is the Nyström center; peers are reconstructions
            ip_KK = A[i]
            blocks = B[:, i].transpose(0, 2, 1)  # block j: Xs_i X̂_j^T (n, n)
            blocks = blocks.at[i].set(ip_KK)  # own block exact
            ip_KN = jnp.moveaxis(blocks, 0, 1).reshape(n_pad, m * n_pad)
            sq_cols = sq_dec.at[i].set(sq_exact[i]).reshape(-1)
            G_KK = _mask_gram(
                kernel_from_inner(kernel, p, ip_KK, sq_exact[i], sq_exact[i]), mask_i
            )
            G_KN = kernel_from_inner(kernel, p, ip_KN, sq_exact[i], sq_cols) * (
                mask_i[:, None] * mask_flat[None, :]
            )
            return nystrom_factors(G_KK, G_KN, y_flat, noise)

        factors = jax.vmap(build)(jnp.arange(m))
    elif gram_mode == "direct":
        D = _decoded_inner_products(shards, wire_state, gram_backend)

        def build(i):
            mask_i = shards.mask[i]
            own_cols = B[:, i].transpose(0, 2, 1)  # block j: Xs_i X̂_j^T
            own_cols = own_cols.at[i].set(A[i])
            row_i = jnp.moveaxis(own_cols, 0, 1).reshape(n_pad, m * n_pad)
            # non-own rows: decoded-vs-decoded, with column block i swapped to
            # decoded-vs-exact (B[r, i])
            rows = D.reshape(m, n_pad, m, n_pad).at[:, :, i, :].set(B[:, i])
            rows = rows.reshape(m, n_pad, m * n_pad).at[i].set(row_i)
            ip_NN = rows.reshape(m * n_pad, m * n_pad)
            sq_cols = sq_dec.at[i].set(sq_exact[i]).reshape(-1)
            G = _mask_gram(
                kernel_from_inner(kernel, p, ip_NN, sq_cols, sq_cols), mask_flat
            )
            return posterior_factors(G, y_flat, noise)

        factors = jax.vmap(build)(jnp.arange(m))
    else:
        raise ValueError(f"unknown broadcast gram mode {gram_mode!r}")

    return FittedProtocol(
        params=p,
        y=y_flat,
        factors=factors,
        data={
            "Xs": shards.X, "mask": shards.mask,
            "sq_exact": sq_exact, "sq_dec": sq_dec,
        },
        wire=wire_state,
        protocol="broadcast",
        kernel=kernel,
        gram_mode=gram_mode,
        fuse=fuse,
        gram_backend=gram_backend,
        n_center=0,
        lengths=shards.lengths,
        block_order=None,
        bits_per_sample=bits,
        max_bits=max_bits,
        wire_bits=int(wire),
    )


def _fit_poe(parts, kernel, steps, lr, method, gram_backend, train_impl,
             impl="batched"):
    # shared hypers trained on machine 0's local data (standard practice: the
    # PoE family shares one hyperparameter set across experts)
    trained = train_gp(
        parts[0][0], parts[0][1], kernel=kernel, steps=steps, lr=lr, impl=train_impl
    )
    p = trained.params
    noise = jnp.exp(p.log_noise)
    shards = pad_parts(parts)
    sq_exact = jnp.sum(shards.X**2, -1)
    m = len(parts)
    if impl == "mesh":
        if gram_backend != "xla":
            raise NotImplementedError(
                'impl="mesh" assembles grams device-local (gram_backend="xla")'
            )
        mesh = machine_mesh(m)
        factors = _mesh_poe_factor_fn(m, kernel)(shards.X, shards.y, shards.mask, p)
        data = _shard_machine_axis(
            {"Xs": shards.X, "mask": shards.mask, "sq_exact": sq_exact}, mesh
        )
        return FittedProtocol(
            params=p, y=shards.y * shards.mask, factors=factors, data=data,
            wire=None, protocol="poe", kernel=kernel, gram_mode="dense",
            fuse=method, gram_backend=gram_backend, n_center=0,
            lengths=shards.lengths, block_order=None, bits_per_sample=0,
            max_bits=0, wire_bits=0, impl="mesh",
        )
    if gram_backend == "pallas":
        from ..kernels.gram.ops import gram as gram_kernel

        A = jax.vmap(lambda a: gram_kernel(a, a))(shards.X)
    else:
        A = jnp.einsum("ind,imd->inm", shards.X, shards.X)

    def build(ipA, sqj, yj, mask_j):
        G = _mask_gram(kernel_from_inner(kernel, p, ipA, sqj, sqj), mask_j)
        return posterior_factors(G, yj * mask_j, noise)

    factors = jax.vmap(build)(A, sq_exact, shards.y, shards.mask)
    return FittedProtocol(
        params=p,
        y=shards.y * shards.mask,
        factors=factors,
        data={"Xs": shards.X, "mask": shards.mask, "sq_exact": sq_exact},
        wire=None,
        protocol="poe",
        kernel=kernel,
        gram_mode="dense",
        fuse=method,
        gram_backend=gram_backend,
        n_center=0,
        lengths=shards.lengths,
        block_order=None,
        bits_per_sample=0,
        max_bits=0,
        wire_bits=0,
    )


# --------------------------------------------------------------------------
# predict: one jitted program per artifact, cached factors only
# --------------------------------------------------------------------------

# Incremented INSIDE the traced function body, so it counts (re)traces, not
# calls: a warm serve loop must leave it flat (benchmarks/serve_bench.py and
# tests/test_serving.py assert exactly that).
_SERVE_TRACES: collections.Counter = collections.Counter()


def serve_trace_count(protocol: str = "center") -> int:
    """How many times :func:`predict` has been (re)traced for a protocol —
    a warm serve loop holds this constant (no refit, no recompile)."""
    return _SERVE_TRACES[protocol]


def _predict_impl(art: FittedProtocol, X_star):
    _SERVE_TRACES[art.protocol] += 1  # runs at trace time only
    p = art.params
    noise = jnp.exp(p.log_noise)
    sq_star = jnp.sum(X_star**2, -1)
    g_ss = prior_diag(art.kernel, p, sq_star)
    if art.protocol == "center":
        return _predict_center(art, X_star, sq_star, g_ss, noise)
    if art.protocol == "broadcast":
        mus, s2s = _predict_broadcast_experts(art, X_star, sq_star, g_ss, noise)
        if art.fuse == "kl":
            return kl_fuse_diag(mus, s2s)
        return combine(art.fuse, mus, s2s, g_ss + noise)
    # poe
    mus, s2s = _predict_poe_experts(art, X_star, sq_star, g_ss)
    return combine(art.fuse, mus, s2s, g_ss + noise)


_predict_jit = jax.jit(_predict_impl)


def _predict_mesh_impl(art: FittedProtocol, X_star):
    """Mesh serving: ONE shard_map program — each device applies ITS machine's
    cached factors to the query batch (triangular solves only, exactly like
    the batched path) and the predictives meet in a psum/KL fusion epilogue
    (eqs. 62-64 as two psums; the PoE combiners as precision-weighted psums).
    Factors/data stay sharded along the mesh axis throughout."""
    _SERVE_TRACES[art.protocol] += 1  # runs at trace time only
    m = len(art.lengths)
    mesh = machine_mesh(m)
    has_extra = "X_extra" in art.data

    def body(fac, Xs_blk, mask_blk, sq_blk, em_blk, Xe, X_star, p):
        fac_i = jax.tree.map(lambda a: a[0], fac)
        Xi, mi, sqi = Xs_blk[0], mask_blk[0], sq_blk[0]
        noise = jnp.exp(p.log_noise)
        sq_star = jnp.sum(X_star**2, -1)
        g_ss = prior_diag(art.kernel, p, sq_star)
        G_sK = kernel_from_inner(
            art.kernel, p, X_star @ Xi.T, sq_star, sqi
        ) * mi[None, :]
        if art.protocol == "broadcast":
            mu_i, s2_i = nystrom_apply(fac_i, G_sK, g_ss, noise)
            if art.fuse == "kl":
                return kl_fuse_diag_psum(mu_i, s2_i, MESH_AXIS)
            return combine_psum(art.fuse, mu_i, s2_i, g_ss + noise, MESH_AXIS)
        # poe: streamed extras (update()) ride along as appended columns
        G_sn = G_sK
        if has_extra:
            sq_e = jnp.sum(Xe**2, -1)
            G_e = kernel_from_inner(art.kernel, p, X_star @ Xe.T, sq_star, sq_e)
            G_sn = jnp.concatenate([G_sn, G_e * em_blk[0][None, :]], axis=1)
        mu_i, s2_i = posterior_apply(fac_i, G_sn, g_ss)
        return combine_psum(art.fuse, mu_i, s2_i, g_ss + noise, MESH_AXIS)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(
            P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS),
            P(MESH_AXIS), P(), P(), P(),
        ),
        out_specs=(P(), P()), check_vma=False,
    )
    em = art.data["extra_mask"] if has_extra else art.data["mask"][:, :0]
    Xe = art.data["X_extra"] if has_extra else X_star[:0]
    return fn(
        art.factors, art.data["Xs"], art.data["mask"], art.data["sq_exact"],
        em, Xe, X_star, art.params,
    )


_predict_mesh_jit = jax.jit(_predict_mesh_impl)


def _uses_mesh_predict(art: FittedProtocol) -> bool:
    # §5.1 serving is center-local by construction (one factor set at the
    # center, nothing to fuse) — center artifacts serve on the host path
    return art.impl == "mesh" and art.protocol in ("broadcast", "poe")


def predict(art: FittedProtocol, X_star):
    """Serve one query batch from a fitted artifact: (mean, var) at X_star.

    ONE jitted program per artifact shape, O(t) per query batch: the cross
    inner products against the stored bases, the kernel map, and triangular
    solves against the cached factors.  No scheme refit, no Cholesky
    refactorization, no hyperparameter step happens here — verify with
    :func:`predict_op_counts` / :func:`serve_trace_count`.  Retraces only
    when the artifact's shapes change (a fresh :func:`fit`, an
    :func:`update`, or a new query-batch size).  Mesh broadcast/PoE
    artifacts serve through one shard_map program with a psum/KL fusion
    epilogue instead (:func:`_predict_mesh_impl`)."""
    X_star = jnp.asarray(X_star, jnp.float32)
    if _uses_mesh_predict(art):
        return _predict_mesh_jit(art, X_star)
    return _predict_jit(art, X_star)


def _predict_center(art, X_star, sq_star, g_ss, noise):
    p = art.params
    Xc = art.data["Xc"]
    K = art.n_center
    sq_cols = art.data["sq_cols"]
    if art.gram_backend == "pallas":
        from ..kernels.gram.ops import gram as gram_kernel

        ip_sK = gram_kernel(X_star, Xc)
        G_sK = kernel_from_inner(art.kernel, p, ip_sK, sq_star, sq_cols[:K])
    else:
        G_sK = gram_fn(art.kernel)(p, X_star, Xc)
    if art.gram_mode == "nystrom":
        return nystrom_apply(art.factors, G_sK, g_ss, noise)
    if art.gram_mode == "nystrom_fitc":
        # FITC-consistent test covariance: Q_*N = G_*K G_KK^{-1} G_KN from the
        # cached (L_KK, W) — raw k(x*, x) against a Nyström-structured train
        # gram badly mis-weights y-components outside the rank-K span
        B = jax.scipy.linalg.solve_triangular(
            art.factors["L_KK"], G_sK.T, lower=True
        )
        return posterior_apply(art.factors, B.T @ art.factors["W"], g_ss)
    # direct
    if art.gram_backend == "pallas":
        ip_sN = _artifact_ip_rows(art, X_star).T  # (t, N)
        G_sn = kernel_from_inner(art.kernel, p, ip_sN, sq_star, sq_cols)
    else:
        G_sn = gram_fn(art.kernel)(p, X_star, art.data["X_recon"])
    return posterior_apply(art.factors, G_sn, g_ss)


def _artifact_ip_rows(art, Y):
    """⟨x_i, y_j⟩ in the artifact's X_recon layout — see :func:`_pallas_ip_rows`."""
    return _pallas_ip_rows(art.wire, art.block_order, art.lengths, art.data["Xc"], Y)


def _predict_broadcast_experts(art, X_star, sq_star, g_ss, noise):
    p = art.params
    Xs, mask = art.data["Xs"], art.data["mask"]
    sq_exact = art.data["sq_exact"]
    m, n_pad, _ = Xs.shape
    C = _star_exact_products(Xs, X_star, art.gram_backend)
    if art.gram_mode == "nystrom":

        def apply_i(fac, Ci, sqi, mi):
            G_sK = kernel_from_inner(art.kernel, p, Ci, sq_star, sqi) * mi[None, :]
            return nystrom_apply(fac, G_sK, g_ss, noise)

        return jax.vmap(apply_i)(art.factors, C, sq_exact, mask)
    # direct views
    sq_dec = art.data["sq_dec"]
    mask_flat = mask.reshape(-1)
    E = _star_decoded_products(art.wire, X_star, art.gram_backend)

    def apply_i(i, fac):
        star_cols = E.at[i].set(C[i])  # (m, t, n_pad); block i exact
        ip_sN = jnp.moveaxis(star_cols, 0, 1).reshape(-1, m * n_pad)
        sq_cols = sq_dec.at[i].set(sq_exact[i]).reshape(-1)
        G_sn = kernel_from_inner(art.kernel, p, ip_sN, sq_star, sq_cols) * (
            mask_flat[None, :]
        )
        return posterior_apply(fac, G_sn, g_ss)

    return jax.vmap(apply_i)(jnp.arange(m), art.factors)


def _predict_poe_experts(art, X_star, sq_star, g_ss):
    p = art.params
    Xs, mask = art.data["Xs"], art.data["mask"]
    sq_exact = art.data["sq_exact"]
    C = _star_exact_products(Xs, X_star, art.gram_backend)
    has_extra = "X_extra" in art.data
    if has_extra:
        Xe = art.data["X_extra"]
        C_e = X_star @ Xe.T  # (t, e); streamed extras ride the xla path
        sq_e = jnp.sum(Xe**2, -1)
        G_e = kernel_from_inner(art.kernel, p, C_e, sq_star, sq_e)

    def apply_j(fac, Cj, sqj, mj, emj):
        G_sn = kernel_from_inner(art.kernel, p, Cj, sq_star, sqj) * mj[None, :]
        if has_extra:
            G_sn = jnp.concatenate([G_sn, G_e * emj[None, :]], axis=1)
        return posterior_apply(fac, G_sn, g_ss)

    em = art.data["extra_mask"] if has_extra else mask[:, :0]
    return jax.vmap(apply_j)(art.factors, C, sq_exact, mask, em)


# --------------------------------------------------------------------------
# update: streaming append via rank-k factor updates
# --------------------------------------------------------------------------


def update(art: FittedProtocol, X_new, y_new, machine: int = 0) -> FittedProtocol:
    """Stream (X_new, y_new) arriving at ``machine`` into a fitted artifact.

    The fit-once economics in action: machine ``machine``'s FROZEN scheme
    state (codebooks + decorrelating transform fitted at :func:`fit` time)
    re-encodes only the new symbols, charging ``rates[machine].sum()`` wire
    bits per point to the ledger — no scheme refit, no new side info.  The
    cached factors then grow by rank-k updates (``nystrom.chol_update_rank``
    for the Nyström woodbury core, ``nystrom.chol_append`` for dense factors)
    instead of refactorizing the train gram.  Returns a NEW artifact (the
    input is unchanged); the next :func:`predict` retraces once for the grown
    shapes, then serves warm again.

    Center protocol: points landing on the center (``machine=0``) are exact
    and cost 0 wire bits; the rank-K Nyström basis stays fixed either way
    (appended points extend the columns, not the basis).  Broadcast: default
    "nystrom" mode only.  PoE: the new points extend ``machine``'s expert
    (zero-rate, exact).  Within-tolerance agreement with a from-scratch refit
    on the concatenated data is locked by tests/test_serving.py."""
    X_new = jnp.asarray(X_new, jnp.float32)
    y_new = jnp.asarray(y_new, jnp.float32)
    if X_new.ndim != 2 or y_new.ndim != 1 or y_new.shape[0] != X_new.shape[0]:
        raise ValueError("update expects X_new (n_new, d), y_new (n_new,)")
    if not 0 <= machine < len(art.lengths):
        raise ValueError(f"machine {machine} out of range (m={len(art.lengths)})")
    if art.impl == "mesh":
        # the rank-k growth runs on host arrays (mixing mesh-sharded and
        # fresh single-device operands in eager ops is ill-defined); the next
        # mesh predict reshards the grown factors along the machine axis
        pull = lambda t: jax.tree.map(lambda a: jnp.asarray(jax.device_get(a)), t)
        art = dataclasses.replace(art, factors=pull(art.factors), data=pull(art.data))
    if art.protocol == "center":
        return _update_center(art, X_new, y_new, machine)
    if art.protocol == "broadcast":
        return _update_broadcast(art, X_new, y_new, machine)
    if art.protocol == "poe":
        return _update_poe(art, X_new, y_new, machine)
    raise ValueError(f"unknown protocol {art.protocol!r}")


def _reencode(art, machine: int, X_new):
    """(codes, X̂, wire_bits) for new symbols under machine's frozen scheme."""
    w = art.wire
    state = {
        "T": w.T[machine], "T_inv": w.T_inv[machine],
        "sigma": w.sigma[machine], "rates": w.rates[machine],
    }
    tables = jax_scheme.scheme_tables(art.bits_per_sample, art.max_bits)
    codes, decoded = jax_scheme.roundtrip(state, X_new, tables)
    bits = int(np.asarray(w.rates[machine]).sum()) * X_new.shape[0]
    return codes, decoded, bits


def _bump_length(lengths: tuple, j: int, n_new: int) -> tuple:
    return tuple(n + (n_new if i == j else 0) for i, n in enumerate(lengths))


def _update_center(art, X_new, y_new, j):
    if art.gram_backend == "pallas" and art.gram_mode != "nystrom":
        raise NotImplementedError(
            "streaming update of pallas-backed center artifacts supports "
            'gram_mode="nystrom" only (direct/fitc query paths read the '
            "fit-time wire codes, which update does not extend)"
        )
    p = art.params
    noise = jnp.exp(p.log_noise)
    n_new = X_new.shape[0]
    if j == 0:  # the center's own data is local: exact, zero wire cost
        decoded, wire_add = X_new, 0
    else:
        _, decoded, wire_add = _reencode(art, j, X_new)
        if art.gram_mode == "nystrom_fitc":
            wire_add += 32 * n_new  # exact |x|^2 side channel
    sq_new = jnp.sum(decoded**2, -1)
    sq_new_exact = jnp.sum(X_new**2, -1)
    k = gram_fn(art.kernel)
    Xc = art.data["Xc"]
    y2 = jnp.concatenate([art.y, y_new])
    f = dict(art.factors)
    s2 = noise + _JITTER

    if art.gram_mode == "nystrom":
        # columns append on the woodbury form: W gains L_KK^{-1} G_K,new and
        # L_M = chol(s2 I + W W^T) takes a rank-n_new update
        W_new = jax.scipy.linalg.solve_triangular(
            f["L_KK"], k(p, Xc, decoded), lower=True
        )
        f["W"] = jnp.concatenate([f["W"], W_new], axis=1)
        f["L_M"] = chol_update_rank(f["L_M"], W_new)
        f["alpha"] = nystrom_kinv(f["W"], f["L_M"], s2, y2)
    elif art.gram_mode == "direct":
        G_on = k(p, art.data["X_recon"], decoded)  # (N, n_new)
        G_nn = k(p, decoded) + s2 * jnp.eye(n_new, dtype=G_on.dtype)
        f["L"] = chol_append(f["L"], G_on, G_nn)
        f["alpha"] = jax.scipy.linalg.cho_solve((f["L"], True), y2)
    else:  # nystrom_fitc: bordered dense factor through the Nyström map
        W_new = jax.scipy.linalg.solve_triangular(
            f["L_KK"], k(p, Xc, decoded), lower=True
        )
        G_on = f["W"].T @ W_new
        corr = jnp.maximum(
            prior_diag(art.kernel, p, sq_new_exact) - jnp.sum(W_new**2, 0), 0.0
        )
        G_nn = W_new.T @ W_new + jnp.diag(corr) + s2 * jnp.eye(n_new)
        f["L"] = chol_append(f["L"], G_on, G_nn)
        f["alpha"] = jax.scipy.linalg.cho_solve((f["L"], True), y2)
        f["W"] = jnp.concatenate([f["W"], W_new], axis=1)

    data = dict(art.data)
    data["X_recon"] = jnp.concatenate([data["X_recon"], decoded], axis=0)
    data["sq_cols"] = jnp.concatenate([data["sq_cols"], sq_new])
    data["sq_exact"] = jnp.concatenate([data["sq_exact"], sq_new_exact])
    return dataclasses.replace(
        art, y=y2, factors=f, data=data,
        lengths=_bump_length(art.lengths, j, n_new),
        wire_bits=art.wire_bits + wire_add,
    )


def _update_broadcast(art, X_new, y_new, j):
    if art.gram_mode != "nystrom":
        raise NotImplementedError(
            'streaming update of broadcast artifacts supports gram_mode='
            '"nystrom" only'
        )
    p = art.params
    noise = jnp.exp(p.log_noise)
    m = len(art.lengths)
    n_new = X_new.shape[0]
    _, decoded, wire_add = _reencode(art, j, X_new)
    # machine j broadcast its codes once: every peer i sees X̂_new; machine j
    # itself keeps the exact points.  The new points extend every view's
    # COLUMNS (the rank-n_pad Nyström bases stay fixed).
    reps = jnp.broadcast_to(decoded, (m, n_new, decoded.shape[1]))
    reps = reps.at[j].set(X_new)
    sq_new = jnp.sum(reps**2, -1)  # (m, n_new)
    ip_new = jnp.einsum("ind,ied->ine", art.data["Xs"], reps)  # (m, n_pad, n_new)
    y2 = jnp.concatenate([art.y, y_new])
    s2 = noise + _JITTER

    def upd(fac, ipn, sqi, sqn, mi):
        G_KN_new = kernel_from_inner(art.kernel, p, ipn, sqi, sqn) * mi[:, None]
        W_new = jax.scipy.linalg.solve_triangular(fac["L_KK"], G_KN_new, lower=True)
        W2 = jnp.concatenate([fac["W"], W_new], axis=1)
        L_M2 = chol_update_rank(fac["L_M"], W_new)
        return {
            "L_KK": fac["L_KK"], "W": W2, "L_M": L_M2,
            "alpha": nystrom_kinv(W2, L_M2, s2, y2),
        }

    factors = jax.vmap(upd)(
        art.factors, ip_new, art.data["sq_exact"], sq_new, art.data["mask"]
    )
    return dataclasses.replace(
        art, y=y2, factors=factors,
        lengths=_bump_length(art.lengths, j, n_new),
        wire_bits=art.wire_bits + wire_add,
    )


def _update_poe(art, X_new, y_new, j):
    p = art.params
    noise = jnp.exp(p.log_noise)
    m = len(art.lengths)
    n_new = X_new.shape[0]
    k = gram_fn(art.kernel)
    s2 = noise + _JITTER
    Xs, mask = art.data["Xs"], art.data["mask"]
    # zero-rate: the points are machine j's own exact data; other experts
    # never see them (valid only on row j), matching the fit-time masking
    valid = jnp.zeros((m, n_new), jnp.float32).at[j].set(1.0)
    Xe_old = art.data.get("X_extra")
    em_old = art.data.get("extra_mask")
    ye_old = art.data.get("y_extra")

    def upd(fac, Xi, sqi, mi, vi, emi, yi, yei):
        G_on = k(p, Xi, X_new) * (mi[:, None] * vi[None, :])
        if Xe_old is not None:
            G_on_e = k(p, Xe_old, X_new) * (emi[:, None] * vi[None, :])
            G_on = jnp.concatenate([G_on, G_on_e], axis=0)
        G_nn = _mask_gram(k(p, X_new), vi) + s2 * jnp.eye(n_new)
        L2 = chol_append(fac["L"], G_on, G_nn)
        y_cols = jnp.concatenate(
            [yi] + ([yei * emi] if Xe_old is not None else []) + [y_new * vi]
        )
        return {"L": L2, "alpha": jax.scipy.linalg.cho_solve((L2, True), y_cols)}

    em_arg = em_old if em_old is not None else mask[:, :0]
    factors = jax.vmap(
        lambda fac, Xi, sqi, mi, vi, emi, yi: upd(fac, Xi, sqi, mi, vi, emi, yi, ye_old)
    )(art.factors, Xs, art.data["sq_exact"], mask, valid, em_arg, art.y)
    data = dict(art.data)
    data["X_extra"] = (
        jnp.concatenate([Xe_old, X_new]) if Xe_old is not None else X_new
    )
    data["extra_mask"] = (
        jnp.concatenate([em_old, valid], axis=1) if em_old is not None else valid
    )
    data["y_extra"] = (
        jnp.concatenate([ye_old, y_new]) if ye_old is not None else y_new
    )
    return dataclasses.replace(
        art, factors=factors, data=data,
        lengths=_bump_length(art.lengths, j, n_new),
    )


# --------------------------------------------------------------------------
# legacy one-shot mesh entry point (absorbed from core.mesh_gp)
# --------------------------------------------------------------------------


def broadcast_gp_mesh(
    mesh,
    axis: str,
    X,
    y,
    X_star,
    params: GPParams,
    *,
    kernel: str = "se",
    bits_per_sample: int = 32,
    max_bits: int = 8,
):
    """One-shot §5.2 broadcast on a caller-supplied mesh: devices along
    ``axis`` are machines, the wire is ``comm.q_all_gather`` (int codes),
    each device solves its dense local view, and the per-point predictives
    are KL-fused (eqs. 62-64) — all inside one jit/shard_map program.

    This is the original ``core.mesh_gp`` prototype, kept for fixed-hyper
    one-shot runs (no training, no serving artifact).  The first-class mesh
    path is ``fit(..., impl="mesh")`` — it adds hyperparameter training,
    Nyström factor caching sharded along the mesh axis, streaming
    :func:`update`, and checkpointing.

    X: (n, d) globally, sharded over ``axis`` on dim 0 (n % n_devices == 0);
    y: (n,) likewise; X_star: (t, d) replicated.  Returns fused (mean, var).
    """
    from ..comm import q_all_gather

    k = gram_fn(kernel)

    def local_predict(X_all_blocks, y_all, own_idx, xs_l):
        """One device's §5.2 view: own block exact, peers reconstructed."""
        m, n_loc, d = X_all_blocks.shape
        # reorder so the exact (own) block is first — matches the Nyström layout
        order = jnp.argsort(
            jnp.where(jnp.arange(m) == own_idx, -1, jnp.arange(m))
        )
        Xv = X_all_blocks[order].reshape(m * n_loc, d)
        yv = y_all[order].reshape(m * n_loc)
        G = k(params, Xv)
        G_sn = k(params, xs_l, Xv)
        g_ss = jnp.diagonal(k(params, xs_l, xs_l))
        return posterior_from_gram(G, G_sn, g_ss, yv, jnp.exp(params.log_noise))

    def body(x_l, y_l, xs_l):
        idx = jax.lax.axis_index(axis)
        # the paper's wire: quantized codes, own block exact (repro.comm)
        x_blocks = q_all_gather(x_l, axis, bits_per_sample, max_bits)
        y_all = jax.lax.all_gather(y_l, axis)  # targets are scalars (unquantized)
        mu_i, s2_i = local_predict(x_blocks, y_all, idx, xs_l)
        # KL-barycenter fusion (eqs. 62-64) across the machine axis
        mus = jax.lax.all_gather(mu_i, axis)
        s2s = jax.lax.all_gather(s2_i, axis)
        return kl_fuse_diag(mus, s2s)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(None, None)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)(X, y, X_star)


# --------------------------------------------------------------------------
# artifact persistence (repro.checkpoint) + serve-path introspection
# --------------------------------------------------------------------------


def save_artifact(art: FittedProtocol, directory: str, step: int = 0) -> str:
    """Checkpoint a fitted artifact: array leaves through
    ``repro.checkpoint.save_checkpoint`` (atomic npz), static metadata to a
    sidecar json.  :func:`load_artifact` restores without needing the
    original object; predictions from the restored artifact are bitwise
    identical (tests/test_serving.py)."""
    from ..checkpoint import save_artifact as _save

    meta = {
        "protocol": art.protocol, "kernel": art.kernel,
        "gram_mode": art.gram_mode, "fuse": art.fuse,
        "gram_backend": art.gram_backend, "n_center": art.n_center,
        "lengths": list(art.lengths),
        "block_order": list(art.block_order) if art.block_order is not None else None,
        "bits_per_sample": art.bits_per_sample, "max_bits": art.max_bits,
        "wire_bits": art.wire_bits, "has_wire": art.wire is not None,
        "impl": art.impl,  # provenance; restore is always single-host
    }
    return _save(directory, step, art, meta)


def load_artifact(directory: str, step: int | None = None, shardings=None) -> FittedProtocol:
    """Restore a :func:`save_artifact` checkpoint into a fresh artifact.

    Always restores as a SINGLE-HOST artifact (``impl="batched"``): a mesh
    fit's checkpoint round-trips to an equivalent host-serving artifact
    (sharded factors were gathered at save time).  ``shardings``: optional —
    a single ``Sharding``/device applied to every leaf, or a
    ``{leaf_key: sharding}`` dict (keys as in the npz: ``factors/W``,
    ``data/Xc``, ``wire/codes``, ...) for per-leaf placement; leaves are
    ``jax.device_put`` into place on restore."""
    from ..checkpoint import load_artifact_arrays

    meta, arrays = load_artifact_arrays(directory, step)

    def put(key):
        arr = arrays[key]
        sh = shardings.get(key) if isinstance(shardings, dict) else shardings
        return jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)

    params = GPParams(*(put(f"params/{f}") for f in GPParams._fields))
    factors = {
        k.split("/", 1)[1]: put(k) for k in arrays if k.startswith("factors/")
    }
    data = {k.split("/", 1)[1]: put(k) for k in arrays if k.startswith("data/")}
    wire = None
    if meta["has_wire"]:
        wire = WireState(*(put(f"wire/{f}") for f in WireState._fields))
    return FittedProtocol(
        params=params, y=put("y"), factors=factors, data=data, wire=wire,
        protocol=meta["protocol"], kernel=meta["kernel"],
        gram_mode=meta["gram_mode"], fuse=meta["fuse"],
        gram_backend=meta["gram_backend"], n_center=meta["n_center"],
        lengths=tuple(meta["lengths"]),
        block_order=tuple(meta["block_order"]) if meta["block_order"] is not None else None,
        bits_per_sample=meta["bits_per_sample"], max_bits=meta["max_bits"],
        wire_bits=meta["wire_bits"], impl="batched",
    )


def _walk_jaxpr(jaxpr):
    from jax.core import Jaxpr, ClosedJaxpr

    def subs(v):
        if isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, (list, tuple)):
            for x in v:
                yield from subs(x)

    for eqn in jaxpr.eqns:
        yield eqn
        for pv in eqn.params.values():
            for sub in subs(pv):
                yield from _walk_jaxpr(sub)


def predict_op_counts(art: FittedProtocol, X_star, ops=("cholesky", "eigh")) -> dict:
    """Count primitives in the :func:`predict` program for this artifact —
    the structural serve-path check: a warm predict must contain ZERO
    ``cholesky`` (no refactorization) and ZERO ``eigh`` (no scheme refit)
    equations.  Mesh artifacts are checked on their actual shard_map serve
    program (the walk descends into the shard_map body jaxpr).
    benchmarks/serve_bench.py records these counts in BENCH_serve.json and
    tests/test_serving.py locks them."""
    fn = _predict_mesh_impl if _uses_mesh_predict(art) else _predict_impl
    jaxpr = jax.make_jaxpr(fn)(art, jnp.asarray(X_star, jnp.float32))
    counts = {op: 0 for op in ops}
    for eqn in _walk_jaxpr(jaxpr.jaxpr):
        if eqn.primitive.name in counts:
            counts[eqn.primitive.name] += 1
    return counts
