"""Distributed GP learning under communication limits (paper §5).

Two protocols:

* **single-center** (§5.1): machine 0 is the center.  It ships its local
  second-moment S_c to every machine; machine j fits the per-symbol scheme to
  (Qx=S_j, Qy=S_c), transmits int codes; the center decodes X̂_j, forms the
  first-block rows of the gram matrix (its own block exact), Nyström-completes
  (eq. 61), trains hyperparameters on the completed gram, and serves
  predictions.
* **broadcast** (§5.2): every machine broadcasts codes fitted against
  Qy = sum of the *other* machines' covariances; each machine builds its own
  Nyström gram (own block exact), forms a local predictive, and the per-point
  predictives are fused with the KL barycenter (eqs. 62-64).

Two execution modes:

* ``m`` simulated machines on one host (vmapped / python-loop) — bit-exact
  protocol semantics, used for the paper's 40-machine experiments;
* a ``shard_map`` mode where machines are devices along a mesh axis and the
  wire is a real ``jax.lax.all_gather`` of int8 codes (see repro.comm) — the
  production path, shared with the transformer GP head.

Targets y are transmitted unquantized (scalars; the paper quantizes inputs
only).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .distortion import second_moment
from .schemes import PerSymbolScheme, DimReductionScheme
from .gp import GPParams, init_params, gram_fn, nlml_from_gram, posterior_from_gram, train_gp
from .nystrom import nystrom_complete, nystrom_posterior
from .fusion import kl_fuse_diag
from .poe import combine

__all__ = [
    "split_machines",
    "quantize_to_center",
    "single_center_gp",
    "broadcast_gp",
    "poe_baseline",
]


def split_machines(X, y, m: int, key) -> list[tuple[jnp.ndarray, jnp.ndarray]]:
    """Random uniform split across m machines (paper §6: 'randomly distributed
    across 40 machines')."""
    n = X.shape[0]
    perm = jax.random.permutation(key, n)
    chunks = np.array_split(np.asarray(perm), m)
    return [(jnp.asarray(X)[c], jnp.asarray(y)[c]) for c in chunks]


def quantize_to_center(parts, bits_per_sample: int, center: int = 0):
    """Run the single-center wire protocol; returns
    (X_recon, y_all, wire_bits, n_center, sq_norms).

    X_recon stacks the center's exact block first, then every machine's decoded
    points, matching the paper's gram-row layout.  ``sq_norms`` carries each
    point's EXACT |x|² (an O(32 n)-bit extra the Snelson–Ghahramani/FITC
    diagonal correction needs; included in the wire accounting)."""
    S_c = second_moment(parts[center][0])
    Xs, ys, sqs, wire = [], [], [], 0
    for j, (Xj, yj) in enumerate(parts):
        if j == center:
            Xs.append(Xj)
        else:
            S_j = second_moment(Xj)
            sch = PerSymbolScheme(bits_per_sample).fit(np.asarray(S_j), np.asarray(S_c))
            Xs.append(sch.decode(sch.encode(Xj)))
            wire += sch.wire_bits(Xj.shape[0]) + sch.side_info_bits(Xj.shape[1])
            # (the optional FITC diagonal costs an extra 32 bits/point of
            #  exact |x|^2 — accounted by the caller when gram_mode uses it)
        ys.append(yj)
        sqs.append(jnp.sum(jnp.asarray(Xj) ** 2, axis=-1))
    order = [center] + [j for j in range(len(parts)) if j != center]
    X_recon = jnp.concatenate([Xs[j] for j in order], axis=0)
    y_all = jnp.concatenate([ys[j] for j in order], axis=0)
    sq_norms = jnp.concatenate([sqs[j] for j in order], axis=0)
    n_center = parts[center][0].shape[0]
    return X_recon, y_all, wire, n_center, sq_norms


@dataclasses.dataclass
class CenterGP:
    kernel: str
    params: GPParams
    X_recon: jnp.ndarray  # center block exact, rest reconstructed
    y: jnp.ndarray
    n_center: int
    wire_bits: int
    gram_mode: str = "nystrom"
    sq_norms: jnp.ndarray | None = None  # exact |x|^2 for the FITC diagonal

    def _exact_diag(self, params):
        """k(x_i, x_i) from the EXACT squared norms the machines shipped."""
        if self.kernel == "linear":
            return jnp.exp(params.log_a) * self.sq_norms + jnp.exp(params.log_b)
        return jnp.full_like(self.sq_norms, jnp.exp(params.log_a))  # SE: constant

    def _gram(self, params):
        k = gram_fn(self.kernel)
        if self.gram_mode == "direct":
            # beyond-paper: all blocks straight from the reconstructed points;
            # converges to the full GP as R -> inf (Nyström caps at rank K)
            return k(params, self.X_recon)
        Xc = self.X_recon[: self.n_center]
        G_KK = k(params, Xc)
        G_KN = k(params, Xc, self.X_recon)
        if self.gram_mode == "nystrom_fitc" and self.sq_norms is not None:
            # Snelson & Ghahramani: make the Nyström diagonal exact (the
            # correction acts like per-point noise, taming the rank-K inverse)
            return nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(params))
        return nystrom_complete(G_KK, G_KN)

    def predict(self, X_star):
        k = gram_fn(self.kernel)
        g_ss = jnp.diagonal(k(self.params, X_star, X_star))
        noise = jnp.exp(self.params.log_noise)
        if self.gram_mode == "nystrom_fitc":
            # dense path: the FITC-corrected gram is full-rank (the exact
            # diagonal acts as per-point noise), so the direct predictive is
            # well-conditioned
            G = self._gram(self.params)
            G_sn = k(self.params, X_star, self.X_recon)
            return posterior_from_gram(G, G_sn, g_ss, self.y, noise)
        if self.gram_mode == "nystrom":
            # consistent low-rank predictive: the test cross-covariances must
            # pass through the same Nyström map (G_*N = G_*K G_KK^{-1} G_KN),
            # else y-components outside the rank-K span are amplified by 1/s^2
            Xc = self.X_recon[: self.n_center]
            return nystrom_posterior(
                k(self.params, Xc), k(self.params, Xc, self.X_recon),
                self.y, noise, k(self.params, X_star, Xc), g_ss,
            )
        G = self._gram(self.params)
        G_sn = k(self.params, X_star, self.X_recon)
        return posterior_from_gram(G, G_sn, g_ss, self.y, noise)


def single_center_gp(
    parts,
    bits_per_sample: int,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    params: GPParams | None = None,
    gram_mode: str = "nystrom",
) -> CenterGP:
    """Full §5.1 protocol: quantize-in, Nyström-complete, train hypers on the
    completed gram by marginal likelihood, return a predictor."""
    X_recon, y_all, wire, n_c, sq_norms = quantize_to_center(parts, bits_per_sample)
    if gram_mode == "nystrom_fitc":  # exact |x|^2 side-channel (32 bits/point)
        wire += 32 * (X_recon.shape[0] - n_c)
    model = CenterGP(
        kernel=kernel,
        params=params or init_params(),
        X_recon=X_recon,
        y=y_all,
        n_center=n_c,
        wire_bits=wire,
        gram_mode=gram_mode,
        sq_norms=sq_norms,
    )
    trained = train_gp(
        X_recon,
        y_all,
        kernel=kernel,
        params=model.params,
        steps=steps,
        lr=lr,
        gram_override=model._gram,
    )
    model.params = trained.params
    return model


def broadcast_gp(
    parts,
    bits_per_sample: int,
    X_star,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    fuse: str = "kl",
    gram_mode: str = "nystrom",
):
    """Full §5.2 protocol.  Hyperparameters are trained once (at machine 0, on
    its Nyström view) and shared — a cheap O(#hypers) extra broadcast; the
    paper trains per-machine, which is embarrassingly parallel on a real
    cluster but m-times serial here.  Returns fused (mean, var) at X_star plus
    total wire bits.
    """
    m = len(parts)
    S = [second_moment(Xj) for Xj, _ in parts]
    S_tot = sum(S)
    # every machine encodes ONCE against the sum of the others' covariances
    wire = 0
    decoded = []
    for j, (Xj, yj) in enumerate(parts):
        sch = PerSymbolScheme(bits_per_sample).fit(
            np.asarray(S[j]), np.asarray(S_tot - S[j])
        )
        decoded.append(sch.decode(sch.encode(Xj)))
        wire += sch.wire_bits(Xj.shape[0]) + sch.side_info_bits(Xj.shape[1])

    k = gram_fn(kernel)
    y_parts = [yj for _, yj in parts]

    def machine_view(i):
        blocks = [parts[j][0] if j == i else decoded[j] for j in range(m)]
        order = [i] + [j for j in range(m) if j != i]
        Xv = jnp.concatenate([blocks[j] for j in order], axis=0)
        yv = jnp.concatenate([y_parts[j] for j in order], axis=0)
        return Xv, yv, parts[i][0].shape[0]

    # train shared hypers at machine 0 on its own completed gram
    X0, y0, nc0 = machine_view(0)

    def gram0(p):
        Xc = X0[:nc0]
        return nystrom_complete(k(p, Xc), k(p, Xc, X0))

    trained = train_gp(X0, y0, kernel=kernel, steps=steps, lr=lr, gram_override=gram0)
    p = trained.params

    @partial(jax.jit, static_argnums=(2,))
    def local_predict(Xv, yv, nc):
        Xc = Xv[:nc]
        g_ss = jnp.diagonal(k(p, X_star, X_star))
        if gram_mode == "nystrom":
            # consistent low-rank predictive (see CenterGP.predict)
            from .nystrom import nystrom_posterior

            return nystrom_posterior(
                k(p, Xc), k(p, Xc, Xv), yv, jnp.exp(p.log_noise),
                k(p, X_star, Xc), g_ss,
            )
        G = k(p, Xv)  # "direct": all blocks from reconstructed points
        G_sn = k(p, X_star, Xv)
        return posterior_from_gram(G, G_sn, g_ss, yv, jnp.exp(p.log_noise))

    mus, s2s = [], []
    for i in range(m):
        Xv, yv, nc = machine_view(i)
        mu_i, s2_i = local_predict(Xv, yv, nc)
        mus.append(mu_i)
        s2s.append(s2_i)
    mus = jnp.stack(mus)
    s2s = jnp.stack(s2s)
    if fuse == "kl":
        mu, s2 = kl_fuse_diag(mus, s2s)
    else:
        prior = jnp.diagonal(k(p, X_star, X_star)) + jnp.exp(p.log_noise)
        mu, s2 = combine(fuse, mus, s2s, prior)
    return mu, s2, wire, p


def poe_baseline(
    parts,
    X_star,
    kernel: str = "se",
    method: str = "rbcm",
    steps: int = 150,
    lr: float = 0.05,
):
    """Zero-rate baselines: each machine trains on its local data only (the
    block-diagonal-gram assumption), predictions combined by PoE/BCM/rBCM."""
    # shared hypers trained on machine 0's local data (standard practice: the
    # PoE family shares one hyperparameter set across experts)
    X_all = jnp.concatenate([p[0] for p in parts], axis=0)
    y_all = jnp.concatenate([p[1] for p in parts], axis=0)
    trained = train_gp(parts[0][0], parts[0][1], kernel=kernel, steps=steps, lr=lr)
    p = trained.params
    k = gram_fn(kernel)

    @jax.jit
    def expert(Xj, yj):
        G = k(p, Xj)
        G_sn = k(p, X_star, Xj)
        g_ss = jnp.diagonal(k(p, X_star, X_star))
        return posterior_from_gram(G, G_sn, g_ss, yj, jnp.exp(p.log_noise))

    mus, s2s = zip(*[expert(Xj, yj) for Xj, yj in parts])
    prior = jnp.diagonal(k(p, X_star, X_star)) + jnp.exp(p.log_noise)
    mu, s2 = combine(method, jnp.stack(mus), jnp.stack(s2s), prior)
    return mu, s2, p
