"""Distributed GP learning under communication limits (paper §5).

Two protocols:

* **single-center** (§5.1): machine 0 is the center.  It ships its local
  second-moment S_c to every machine; machine j fits the per-symbol scheme to
  (Qx=S_j, Qy=S_c), transmits int codes; the center decodes X̂_j, forms the
  first-block rows of the gram matrix (its own block exact), Nyström-completes
  (eq. 61), trains hyperparameters on the completed gram, and serves
  predictions.
* **broadcast** (§5.2): every machine broadcasts codes fitted against
  Qy = sum of the *other* machines' covariances; each machine builds its own
  Nyström gram (own block exact), forms a local predictive, and the per-point
  predictives are fused with the KL barycenter (eqs. 62-64).

Execution modes:

* ``impl="batched"`` (default) — machines live on uniform padded shards
  ``(m, n_pad, d)`` with validity masks; scheme fitting
  (core.jax_scheme.fit_scheme), encode/decode, per-machine Nyström
  predictives, and PoE experts all run under ``jax.vmap`` — one batched
  eigh/Cholesky instead of m serial ones, and the whole wire protocol is ONE
  compiled program;
* ``impl="host"`` — the original serial reference/oracle: one host-side scipy
  ``PerSymbolScheme`` fit and one dense Cholesky per machine.  Protocol
  semantics (own block exact, wire-bit accounting) are identical; the batched
  path is locked to it by tests/test_batched_protocol.py;
* a ``shard_map`` mode where machines are devices along a mesh axis and the
  wire is a real ``jax.lax.all_gather`` of int8 codes (core.mesh_gp +
  repro.comm) — the production path, shared with the transformer GP head.

``gram_backend="pallas"`` routes gram assembly through the Pallas tiled-gram
kernel (kernels/gram) and — for reconstructed blocks — feeds the int wire
codes straight to the fused dequantize+gram kernel (kernels/qgram), so X̂
never round-trips through HBM for the big matmuls (SE kernels ride the same
inner products via ‖x−x'‖² = |x|² + |x'|² − 2⟨x,x'⟩).

Targets y are transmitted unquantized (scalars; the paper quantizes inputs
only).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .distortion import second_moment
from . import jax_scheme
from . import quantizers as Q
from .schemes import PerSymbolScheme
from .gp import (
    GPParams,
    init_params,
    gram_fn,
    kernel_from_inner,
    prior_diag,
    nlml_from_gram,
    posterior_from_gram,
    train_gp,
)
from .nystrom import nystrom_complete, nystrom_cross, nystrom_posterior
from .fusion import kl_fuse_diag
from .poe import combine

__all__ = [
    "split_machines",
    "pad_parts",
    "PaddedShards",
    "WireState",
    "quantize_to_center",
    "single_center_gp",
    "broadcast_gp",
    "poe_baseline",
]


def split_machines(X, y, m: int, key) -> list[tuple[jnp.ndarray, jnp.ndarray]]:
    """Random uniform split across m machines (paper §6: 'randomly distributed
    across 40 machines')."""
    n = X.shape[0]
    perm = jax.random.permutation(key, n)
    chunks = np.array_split(np.asarray(perm), m)
    return [(jnp.asarray(X)[c], jnp.asarray(y)[c]) for c in chunks]


# --------------------------------------------------------------------------
# uniform padded shards — the layout every vmapped protocol stage runs on
# --------------------------------------------------------------------------


class PaddedShards(NamedTuple):
    """(m, n_pad, d) machine shards; invalid rows are zero with mask 0."""

    X: jnp.ndarray  # (m, n_pad, d)
    y: jnp.ndarray  # (m, n_pad)
    mask: jnp.ndarray  # (m, n_pad) float32 validity
    lengths: tuple  # per-machine true row counts (python ints)


def pad_parts(parts) -> PaddedShards:
    m = len(parts)
    d = parts[0][0].shape[1]
    lengths = tuple(int(p[0].shape[0]) for p in parts)
    n_pad = max(lengths)
    X = np.zeros((m, n_pad, d), np.float32)
    y = np.zeros((m, n_pad), np.float32)
    mask = np.zeros((m, n_pad), np.float32)
    for j, (Xj, yj) in enumerate(parts):
        X[j, : lengths[j]] = np.asarray(Xj, np.float32)
        y[j, : lengths[j]] = np.asarray(yj, np.float32)
        mask[j, : lengths[j]] = 1.0
    return PaddedShards(jnp.asarray(X), jnp.asarray(y), jnp.asarray(mask), lengths)


class WireState(NamedTuple):
    """Everything the wire protocol produced, for every machine at once."""

    codes: jnp.ndarray  # (m, n_pad, d) int32; padded rows = -1 (decode to 0)
    decoded: jnp.ndarray  # (m, n_pad, d) reconstructions; padded rows zero
    T_inv: jnp.ndarray  # (m, d, d) decorrelating inverses
    rates: jnp.ndarray  # (m, d) int32 per-dim bit allocation
    sigma: jnp.ndarray  # (m, d)
    scaled_cents: jnp.ndarray  # (m, d, C) qgram decode tables


@partial(jax.jit, static_argnames=("total_bits", "max_bits", "mode", "center"))
def _run_wire_protocol(X, mask, total_bits: int, max_bits: int, mode: str, center: int):
    """Fit + encode + decode for EVERY machine under one jit: a single batched
    eigh pair (fit), one batched quantize and one batched dequantize.

    mode="center": every machine targets the center's covariance (§5.1);
    mode="broadcast": machine j targets the sum of the others' (§5.2)."""
    m, n_pad, d = X.shape
    n = jnp.maximum(mask.sum(axis=1), 1.0)
    S = jnp.einsum("mnd,mne->mde", X, X) / n[:, None, None]  # padded rows are 0
    if mode == "center":
        Qy = jnp.broadcast_to(S[center], (m, d, d))
    elif mode == "broadcast":
        Qy = jnp.sum(S, axis=0)[None] - S
    else:
        raise ValueError(f"unknown wire mode {mode!r}")
    cap = jax_scheme.codebook_cap(total_bits, max_bits)
    tables = jax_scheme.scheme_tables(total_bits, max_bits)
    states = jax_scheme.fit_scheme_batched(S, Qy, total_bits, cap)
    codes = jax.vmap(lambda st, x: jax_scheme.encode(st, x, tables))(states, X)
    decoded = jax.vmap(lambda st, c: jax_scheme.decode(st, c, tables))(states, codes)
    decoded = decoded * mask[..., None]
    codes = jnp.where(mask[..., None] > 0, codes, -1)
    cents = jax.vmap(lambda st: jax_scheme.scaled_centroids(st, tables))(states)
    return WireState(
        codes, decoded, states["T_inv"], states["rates"], states["sigma"], cents
    )


def _wire_bits(rates, lengths, d: int, skip=None) -> int:
    """Paper §4 accounting: R bits/sample on the wire + O(2 d²) fp32 side info
    per transmitting machine."""
    rates = np.asarray(rates)
    total = 0
    for j, n_j in enumerate(lengths):
        if j == skip:
            continue
        total += int(rates[j].sum()) * n_j + 2 * d * d * 32
    return total


def _mask_gram(G, mask_r, mask_c=None, pin_diag=True):
    """Zero padded rows/cols; optionally pin their diagonal to 1 so Cholesky
    stays SPD.  A point with k(·, pad)=0, y_pad=0 contributes nothing to the
    posterior, which makes the padded program bit-compatible with the
    unpadded one."""
    mask_c = mask_r if mask_c is None else mask_c
    Gm = G * (mask_r[:, None] * mask_c[None, :])
    if pin_diag:
        Gm = Gm + jnp.diag(1.0 - mask_r)
    return Gm


# --------------------------------------------------------------------------
# §5.1 single-center protocol
# --------------------------------------------------------------------------


def _quantize_to_center_host(
    parts, bits_per_sample: int, center: int = 0, max_bits: int = Q.DEFAULT_MAX_BITS
):
    """Serial reference protocol: host-side scipy PerSymbolScheme per machine."""
    S_c = second_moment(parts[center][0])
    Xs, ys, sqs, wire = [], [], [], 0
    for j, (Xj, yj) in enumerate(parts):
        if j == center:
            Xs.append(Xj)
        else:
            S_j = second_moment(Xj)
            sch = PerSymbolScheme(bits_per_sample, max_bits).fit(
                np.asarray(S_j), np.asarray(S_c)
            )
            Xs.append(sch.decode(sch.encode(Xj)))
            wire += sch.wire_bits(Xj.shape[0]) + sch.side_info_bits(Xj.shape[1])
            # (the optional FITC diagonal costs an extra 32 bits/point of
            #  exact |x|^2 — accounted by the caller when gram_mode uses it)
        ys.append(yj)
        sqs.append(jnp.sum(jnp.asarray(Xj) ** 2, axis=-1))
    order = [center] + [j for j in range(len(parts)) if j != center]
    X_recon = jnp.concatenate([Xs[j] for j in order], axis=0)
    y_all = jnp.concatenate([ys[j] for j in order], axis=0)
    sq_norms = jnp.concatenate([sqs[j] for j in order], axis=0)
    n_center = parts[center][0].shape[0]
    return X_recon, y_all, wire, n_center, sq_norms


def _quantize_to_center_batched(parts, bits_per_sample: int, center: int, max_bits: int):
    """Batched §5.1 wire: one vmapped fit/encode/decode, then assemble the
    center's gram-row layout (exact center block first)."""
    shards = pad_parts(parts)
    m, _, d = shards.X.shape
    wire_state = _run_wire_protocol(
        shards.X, shards.mask, bits_per_sample, max_bits, "center", center
    )
    wire = _wire_bits(wire_state.rates, shards.lengths, d, skip=center)
    order = [center] + [j for j in range(m) if j != center]
    blocks = [parts[center][0]] + [
        wire_state.decoded[j, : shards.lengths[j]] for j in order[1:]
    ]
    X_recon = jnp.concatenate(blocks, axis=0)
    y_all = jnp.concatenate([parts[j][1] for j in order], axis=0)
    sq_norms = jnp.concatenate(
        [jnp.sum(jnp.asarray(parts[j][0]) ** 2, axis=-1) for j in order], axis=0
    )
    return X_recon, y_all, wire, shards.lengths[center], sq_norms, shards, wire_state, order


def quantize_to_center(
    parts, bits_per_sample: int, center: int = 0, impl: str = "batched",
    max_bits: int = Q.DEFAULT_MAX_BITS,
):
    """Run the single-center wire protocol; returns
    (X_recon, y_all, wire_bits, n_center, sq_norms).

    X_recon stacks the center's exact block first, then every machine's decoded
    points, matching the paper's gram-row layout.  ``sq_norms`` carries each
    point's EXACT |x|² (an O(32 n)-bit extra the Snelson–Ghahramani/FITC
    diagonal correction needs; included in the wire accounting)."""
    if impl == "host":
        return _quantize_to_center_host(parts, bits_per_sample, center, max_bits)
    out = _quantize_to_center_batched(parts, bits_per_sample, center, max_bits)
    return out[:5]


@dataclasses.dataclass
class CenterGP:
    kernel: str
    params: GPParams
    X_recon: jnp.ndarray  # center block exact, rest reconstructed
    y: jnp.ndarray
    n_center: int
    wire_bits: int
    gram_mode: str = "nystrom"
    sq_norms: jnp.ndarray | None = None  # exact |x|^2 for the FITC diagonal
    gram_backend: str = "xla"
    wire: WireState | None = None  # int codes + tables (pallas/qgram path)
    block_order: tuple | None = None  # non-center machine ids, X_recon order
    block_lengths: tuple | None = None  # their true row counts
    _ip_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.gram_backend == "pallas":
            if self.wire is None:
                raise ValueError(
                    'gram_backend="pallas" requires the batched wire protocol '
                    "(int codes) — use impl=\"batched\""
                )
            # materialize the inner-product cache NOW, outside any jit trace:
            # a cache miss inside train_gp's scan would store a leaked tracer
            self.warm_ip()

    def _exact_diag(self, params):
        """k(x_i, x_i) from the EXACT squared norms the machines shipped."""
        return prior_diag(self.kernel, params, self.sq_norms)

    # -- pallas/qgram inner-product assembly --------------------------------

    def _ip_rows(self, Y):
        """⟨x_i, y_j⟩ for every x in X_recon layout: (N, p).

        Center rows via the Pallas tiled gram on exact points; reconstructed
        rows straight from int codes via the fused dequantize+gram kernel —
        X̂ = dequant(codes) @ T_inv^T, so ⟨x̂, y⟩ = qgram(codes, Y @ T_inv)."""
        from ..kernels.gram.ops import gram as gram_kernel
        from ..kernels.qgram.ops import qgram_batched

        idx = list(self.block_order[1:])
        codes = self.wire.codes[jnp.asarray(idx)]
        cents = self.wire.scaled_cents[jnp.asarray(idx)]
        T_inv = self.wire.T_inv[jnp.asarray(idx)]
        Xc = self.X_recon[: self.n_center]
        top = gram_kernel(Xc, Y)  # (n_c, p)
        proj = jnp.einsum("pd,mde->mpe", Y, T_inv)  # Y in each decorrelated basis
        blocks = qgram_batched(codes, cents, proj)  # (m-1, n_pad, p)
        rows = [top] + [blocks[i, : self.block_lengths[j]] for i, j in enumerate(idx)]
        return jnp.concatenate(rows, axis=0)

    def _ip(self, key: str):
        """Cached param-independent inner products (pallas backend): computed
        once with the kernels, then reused as constants by every training step
        and prediction."""
        if key not in self._ip_cache:
            Xc = self.X_recon[: self.n_center]
            if key == "KN":
                self._ip_cache[key] = self._ip_rows(Xc).T  # (n_c, N)
            elif key == "NN":
                self._ip_cache[key] = self._ip_rows(self.X_recon)  # (N, N)
            elif key == "sq":
                self._ip_cache[key] = jnp.sum(self.X_recon**2, axis=-1)
        return self._ip_cache[key]

    def warm_ip(self):
        """Materialize the inner-product cache eagerly (before train_gp's scan
        traces _gram) so the Pallas kernels run once, not once per trace."""
        if self.gram_backend != "pallas":
            return self
        self._ip("sq")
        self._ip("NN" if self.gram_mode == "direct" else "KN")
        return self

    def _gram_pallas(self, params):
        sq = self._ip("sq")
        K = self.n_center
        if self.gram_mode == "direct":
            return kernel_from_inner(self.kernel, params, self._ip("NN"), sq, sq)
        ip_KN = self._ip("KN")
        G_KK = kernel_from_inner(self.kernel, params, ip_KN[:, :K], sq[:K], sq[:K])
        G_KN = kernel_from_inner(self.kernel, params, ip_KN, sq[:K], sq)
        if self.gram_mode == "nystrom_fitc" and self.sq_norms is not None:
            return nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(params))
        return nystrom_complete(G_KK, G_KN)

    def _gram(self, params):
        if self.gram_backend == "pallas":
            return self._gram_pallas(params)
        k = gram_fn(self.kernel)
        if self.gram_mode == "direct":
            # beyond-paper: all blocks straight from the reconstructed points;
            # converges to the full GP as R -> inf (Nyström caps at rank K)
            return k(params, self.X_recon)
        Xc = self.X_recon[: self.n_center]
        G_KK = k(params, Xc)
        G_KN = k(params, Xc, self.X_recon)
        if self.gram_mode == "nystrom_fitc" and self.sq_norms is not None:
            # Snelson & Ghahramani: make the Nyström diagonal exact (the
            # correction acts like per-point noise, taming the rank-K inverse)
            return nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(params))
        return nystrom_complete(G_KK, G_KN)


    def predict(self, X_star):
        if self.gram_backend == "pallas":
            return self._predict_pallas(X_star)
        k = gram_fn(self.kernel)
        g_ss = jnp.diagonal(k(self.params, X_star, X_star))
        noise = jnp.exp(self.params.log_noise)
        if self.gram_mode == "nystrom_fitc":
            # dense path: the FITC-corrected gram is full-rank (the exact
            # diagonal acts as per-point noise), so the direct predictive is
            # well-conditioned.  The test cross-covariance must still pass
            # through the Nyström map — the raw k(x*, x) against a
            # Nyström-structured train gram badly mis-weights y-components
            # outside the rank-K span (was the out-of-range seed bug).
            Xc = self.X_recon[: self.n_center]
            G_KK = k(self.params, Xc)
            G_KN = k(self.params, Xc, self.X_recon)
            G = nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(self.params))
            G_sn = nystrom_cross(G_KK, G_KN, k(self.params, X_star, Xc))
            return posterior_from_gram(G, G_sn, g_ss, self.y, noise)
        if self.gram_mode == "nystrom":
            # consistent low-rank predictive: the test cross-covariances must
            # pass through the same Nyström map (G_*N = G_*K G_KK^{-1} G_KN),
            # else y-components outside the rank-K span are amplified by 1/s^2
            Xc = self.X_recon[: self.n_center]
            return nystrom_posterior(
                k(self.params, Xc), k(self.params, Xc, self.X_recon),
                self.y, noise, k(self.params, X_star, Xc), g_ss,
            )
        G = self._gram(self.params)
        G_sn = k(self.params, X_star, self.X_recon)
        return posterior_from_gram(G, G_sn, g_ss, self.y, noise)

    def _predict_pallas(self, X_star):
        from ..kernels.gram.ops import gram as gram_kernel

        X_star = jnp.asarray(X_star, jnp.float32)
        p = self.params
        sq = self._ip("sq")
        sq_star = jnp.sum(X_star**2, -1)
        K = self.n_center
        Xc = self.X_recon[:K]
        g_ss = prior_diag(self.kernel, p, sq_star)
        noise = jnp.exp(p.log_noise)
        ip_KN = self._ip("KN")
        G_KK = kernel_from_inner(self.kernel, p, ip_KN[:, :K], sq[:K], sq[:K])
        if self.gram_mode == "nystrom":
            ip_sK = gram_kernel(X_star, Xc)
            G_sK = kernel_from_inner(self.kernel, p, ip_sK, sq_star, sq[:K])
            G_KN = kernel_from_inner(self.kernel, p, ip_KN, sq[:K], sq)
            return nystrom_posterior(G_KK, G_KN, self.y, noise, G_sK, g_ss)
        G = self._gram_pallas(p)
        if self.gram_mode == "nystrom_fitc":
            # FITC-consistent test covariance (see the xla path)
            ip_sK = gram_kernel(X_star, Xc)
            G_sK = kernel_from_inner(self.kernel, p, ip_sK, sq_star, sq[:K])
            G_KN = kernel_from_inner(self.kernel, p, ip_KN, sq[:K], sq)
            G_sn = nystrom_cross(G_KK, G_KN, G_sK)
        else:
            ip_sN = self._ip_rows(X_star).T  # (t, N)
            G_sn = kernel_from_inner(self.kernel, p, ip_sN, sq_star, sq)
        return posterior_from_gram(G, G_sn, g_ss, self.y, noise)


def single_center_gp(
    parts,
    bits_per_sample: int,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    params: GPParams | None = None,
    gram_mode: str = "nystrom",
    impl: str = "batched",
    gram_backend: str = "xla",
    max_bits: int = Q.DEFAULT_MAX_BITS,
    train_impl: str = "scan",
) -> CenterGP:
    """Full §5.1 protocol: quantize-in, Nyström-complete, train hypers on the
    completed gram by marginal likelihood, return a predictor.

    ``impl="batched"`` runs the wire protocol vmapped over machines inside one
    jit; ``impl="host"`` is the serial scipy reference.  ``train_impl="scan"``
    makes hyperparameter training one compiled lax.scan program."""
    wire_state = None
    order = None
    lengths = None
    if impl == "host":
        X_recon, y_all, wire, n_c, sq_norms = _quantize_to_center_host(
            parts, bits_per_sample, 0, max_bits
        )
    else:
        (X_recon, y_all, wire, n_c, sq_norms, shards, wire_state, order) = (
            _quantize_to_center_batched(parts, bits_per_sample, 0, max_bits)
        )
        lengths = shards.lengths
    if gram_mode == "nystrom_fitc":  # exact |x|^2 side-channel (32 bits/point)
        wire += 32 * (X_recon.shape[0] - n_c)
    model = CenterGP(
        kernel=kernel,
        params=params or init_params(),
        X_recon=X_recon,
        y=y_all,
        n_center=n_c,
        wire_bits=wire,
        gram_mode=gram_mode,
        sq_norms=sq_norms,
        gram_backend=gram_backend,
        wire=wire_state,
        block_order=tuple(order) if order is not None else None,
        block_lengths=lengths,
    )
    trained = train_gp(
        X_recon,
        y_all,
        kernel=kernel,
        params=model.params,
        steps=steps,
        lr=lr,
        gram_override=model._gram,
        impl=train_impl,
    )
    model.params = trained.params
    return model


# --------------------------------------------------------------------------
# §5.2 broadcast protocol
# --------------------------------------------------------------------------


def _broadcast_gp_host(
    parts, bits_per_sample, X_star, kernel, steps, lr, fuse, gram_mode, train_impl,
    max_bits=Q.DEFAULT_MAX_BITS,
):
    """Serial reference §5.2: one scipy scheme fit and one dense solve per
    machine (m host dispatches)."""
    m = len(parts)
    S = [second_moment(Xj) for Xj, _ in parts]
    S_tot = sum(S)
    # every machine encodes ONCE against the sum of the others' covariances
    wire = 0
    decoded = []
    for j, (Xj, yj) in enumerate(parts):
        sch = PerSymbolScheme(bits_per_sample, max_bits).fit(
            np.asarray(S[j]), np.asarray(S_tot - S[j])
        )
        decoded.append(sch.decode(sch.encode(Xj)))
        wire += sch.wire_bits(Xj.shape[0]) + sch.side_info_bits(Xj.shape[1])

    k = gram_fn(kernel)
    y_parts = [yj for _, yj in parts]

    def machine_view(i):
        blocks = [parts[j][0] if j == i else decoded[j] for j in range(m)]
        order = [i] + [j for j in range(m) if j != i]
        Xv = jnp.concatenate([blocks[j] for j in order], axis=0)
        yv = jnp.concatenate([y_parts[j] for j in order], axis=0)
        return Xv, yv, parts[i][0].shape[0]

    # train shared hypers at machine 0 on its own completed gram
    X0, y0, nc0 = machine_view(0)

    def gram0(p):
        Xc = X0[:nc0]
        return nystrom_complete(k(p, Xc), k(p, Xc, X0))

    trained = train_gp(
        X0, y0, kernel=kernel, steps=steps, lr=lr, gram_override=gram0, impl=train_impl
    )
    p = trained.params

    @partial(jax.jit, static_argnums=(2,))
    def local_predict(Xv, yv, nc):
        Xc = Xv[:nc]
        g_ss = jnp.diagonal(k(p, X_star, X_star))
        if gram_mode == "nystrom":
            # consistent low-rank predictive (see CenterGP.predict)
            return nystrom_posterior(
                k(p, Xc), k(p, Xc, Xv), yv, jnp.exp(p.log_noise),
                k(p, X_star, Xc), g_ss,
            )
        G = k(p, Xv)  # "direct": all blocks from reconstructed points
        G_sn = k(p, X_star, Xv)
        return posterior_from_gram(G, G_sn, g_ss, yv, jnp.exp(p.log_noise))

    mus, s2s = [], []
    for i in range(m):
        Xv, yv, nc = machine_view(i)
        mu_i, s2_i = local_predict(Xv, yv, nc)
        mus.append(mu_i)
        s2s.append(s2_i)
    mus = jnp.stack(mus)
    s2s = jnp.stack(s2s)
    if fuse == "kl":
        mu, s2 = kl_fuse_diag(mus, s2s)
    else:
        prior = jnp.diagonal(k(p, X_star, X_star)) + jnp.exp(p.log_noise)
        mu, s2 = combine(fuse, mus, s2s, prior)
    return mu, s2, wire, p


def _view_inner_products(shards: PaddedShards, wire: WireState, X_star, backend: str):
    """The inner-product tensors every machine view is assembled from.

    A (m, n, n): exact own-block products Xs_i Xs_i^T
    B (m, m, n, n): B[j, i] = X̂_j Xs_i^T (decoded j against exact i)
    C (m, t, n): X_star Xs_i^T

    backend="pallas" computes A/C with the tiled gram kernel and B straight
    from int codes with the fused dequantize+gram kernel."""
    X = shards.X
    X_star = jnp.asarray(X_star, jnp.float32)
    if backend == "pallas":
        from ..kernels.gram.ops import gram as gram_kernel
        from ..kernels.qgram.ops import qgram

        A = jax.vmap(lambda a: gram_kernel(a, a))(X)
        proj = jnp.einsum("ind,jde->jine", X, wire.T_inv)  # (m_j, m_i, n, d)
        B = jax.vmap(
            lambda c, t, ys: jax.vmap(lambda yy: qgram(c, t, yy))(ys)
        )(wire.codes, wire.scaled_cents, proj)
        C = jax.vmap(lambda a: gram_kernel(X_star, a))(X)
        return A, B, C
    A = jnp.einsum("ind,imd->inm", X, X)
    B = jnp.einsum("jnd,imd->jinm", wire.decoded, X)
    C = jnp.einsum("td,ind->itn", X_star, X)
    return A, B, C


def broadcast_gp(
    parts,
    bits_per_sample: int,
    X_star,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    fuse: str = "kl",
    gram_mode: str = "nystrom",
    impl: str = "batched",
    gram_backend: str = "xla",
    max_bits: int = Q.DEFAULT_MAX_BITS,
    train_impl: str = "scan",
):
    """Full §5.2 protocol.  Hyperparameters are trained once (at machine 0, on
    its Nyström view) and shared — a cheap O(#hypers) extra broadcast; the
    paper trains per-machine, which is embarrassingly parallel on a real
    cluster but m-times serial here.  Returns fused (mean, var) at X_star plus
    total wire bits.

    The default ``impl="batched"`` runs every machine's scheme fit, decode,
    and Nyström predictive under jax.vmap on padded shards — one batched
    Cholesky for all m local predictives instead of m serial ones."""
    if impl == "host":
        if gram_backend == "pallas":
            raise ValueError('gram_backend="pallas" requires impl="batched"')
        return _broadcast_gp_host(
            parts, bits_per_sample, X_star, kernel, steps, lr, fuse, gram_mode,
            train_impl, max_bits,
        )
    m = len(parts)
    shards = pad_parts(parts)
    _, n_pad, d = shards.X.shape
    X_star = jnp.asarray(X_star, jnp.float32)
    wire_state = _run_wire_protocol(
        shards.X, shards.mask, bits_per_sample, max_bits, "broadcast", 0
    )
    wire = _wire_bits(wire_state.rates, shards.lengths, d)

    A, B, C = _view_inner_products(shards, wire_state, X_star, gram_backend)
    sq_exact = jnp.sum(shards.X**2, -1)  # (m, n)
    sq_dec = jnp.sum(wire_state.decoded**2, -1)
    sq_star = jnp.sum(X_star**2, -1)

    # ---- train shared hypers at machine 0 on its completed Nyström gram ----
    # (unpadded slices; the inner products are param-independent constants, so
    # the 150-step scan only re-does the cheap kernel map + Cholesky)
    L = shards.lengths
    n0 = L[0]
    ip_KK0 = A[0][:n0, :n0]
    ip_KN0 = jnp.concatenate(
        [ip_KK0] + [B[j, 0][: L[j], :n0].T for j in range(1, m)], axis=1
    )
    sq0 = sq_exact[0][:n0]
    sq_cols0 = jnp.concatenate([sq0] + [sq_dec[j][: L[j]] for j in range(1, m)])
    y0 = jnp.concatenate([p[1] for p in parts], axis=0)
    X0 = jnp.concatenate(
        [parts[0][0]] + [wire_state.decoded[j, : L[j]] for j in range(1, m)], axis=0
    )

    def gram0(p):
        G_KK = kernel_from_inner(kernel, p, ip_KK0, sq0, sq0)
        G_KN = kernel_from_inner(kernel, p, ip_KN0, sq0, sq_cols0)
        return nystrom_complete(G_KK, G_KN)

    trained = train_gp(
        X0, y0, kernel=kernel, steps=steps, lr=lr, gram_override=gram0, impl=train_impl
    )
    p = trained.params
    noise = jnp.exp(p.log_noise)

    # ---- every machine's local predictive under ONE vmap ----
    mask_flat = shards.mask.reshape(-1)  # column layout is block j at slot j
    y_flat = (shards.y * shards.mask).reshape(-1)
    g_ss = prior_diag(kernel, p, sq_star)

    def local_predict(i):
        mask_i = shards.mask[i]
        # own (exact) block is the Nyström center; peers are reconstructions
        ip_KK = A[i]
        blocks = B[:, i].transpose(0, 2, 1)  # block j: Xs_i X̂_j^T (n, n)
        blocks = blocks.at[i].set(ip_KK)  # own block exact
        ip_KN = jnp.moveaxis(blocks, 0, 1).reshape(n_pad, m * n_pad)
        sq_cols = sq_dec.at[i].set(sq_exact[i]).reshape(-1)
        G_KK = _mask_gram(
            kernel_from_inner(kernel, p, ip_KK, sq_exact[i], sq_exact[i]), mask_i
        )
        G_KN = kernel_from_inner(kernel, p, ip_KN, sq_exact[i], sq_cols) * (
            mask_i[:, None] * mask_flat[None, :]
        )
        G_sK = kernel_from_inner(kernel, p, C[i], sq_star, sq_exact[i]) * mask_i[None, :]
        return nystrom_posterior(G_KK, G_KN, y_flat, noise, G_sK, g_ss)

    if gram_mode == "nystrom":
        mus, s2s = jax.vmap(local_predict)(jnp.arange(m))
    else:
        mus, s2s = _direct_views_predict(
            kernel, p, shards, wire_state, A, B, C, X_star,
            sq_exact, sq_dec, sq_star, y_flat, mask_flat, g_ss, noise, gram_backend,
        )
    if fuse == "kl":
        mu, s2 = kl_fuse_diag(mus, s2s)
    else:
        prior = g_ss + noise
        mu, s2 = combine(fuse, mus, s2s, prior)
    return mu, s2, wire, p


def _direct_views_predict(
    kernel, p, shards, wire, A, B, C, X_star, sq_exact, sq_dec, sq_star,
    y_flat, mask_flat, g_ss, noise, backend,
):
    """gram_mode="direct" batched predictives: the full (N, N) view grams.

    Needs two extra tensors only this mode consumes (computed here, not in
    _view_inner_products, so the default nystrom path never pays for them):
    D[j] = X̂_j [X̂_0..X̂_m]^T (decoded-vs-decoded) and E[j] = X_star X̂_j^T —
    both straight from codes under the pallas backend."""
    m, n_pad, d = shards.X.shape
    dec_flat = wire.decoded.reshape(m * n_pad, d)
    if backend == "pallas":
        from ..kernels.qgram.ops import qgram_batched

        proj = jnp.einsum("nd,jde->jne", dec_flat, wire.T_inv)
        D = qgram_batched(wire.codes, wire.scaled_cents, proj)  # (m, n_pad, m*n_pad)
        proj_star = jnp.einsum("td,jde->jte", X_star, wire.T_inv)
        E = qgram_batched(wire.codes, wire.scaled_cents, proj_star).transpose(0, 2, 1)
    else:
        D = jnp.einsum("jnd,Nd->jnN", wire.decoded, dec_flat)
        E = jnp.einsum("td,jnd->jtn", X_star, wire.decoded)

    def view(i):
        mask_i = shards.mask[i]
        own_cols = B[:, i].transpose(0, 2, 1)  # block j: Xs_i X̂_j^T
        own_cols = own_cols.at[i].set(A[i])
        row_i = jnp.moveaxis(own_cols, 0, 1).reshape(n_pad, m * n_pad)
        # non-own rows: decoded-vs-decoded, with column block i swapped to
        # decoded-vs-exact (B[r, i])
        rows = D.reshape(m, n_pad, m, n_pad).at[:, :, i, :].set(B[:, i])
        rows = rows.reshape(m, n_pad, m * n_pad).at[i].set(row_i)
        ip_NN = rows.reshape(m * n_pad, m * n_pad)
        sq_cols = sq_dec.at[i].set(sq_exact[i]).reshape(-1)
        G = _mask_gram(
            kernel_from_inner(kernel, p, ip_NN, sq_cols, sq_cols), mask_flat
        )
        star_cols = E.at[i].set(C[i])  # (m, t, n_pad); block i exact
        ip_sN = jnp.moveaxis(star_cols, 0, 1).reshape(-1, m * n_pad)
        G_sn = kernel_from_inner(kernel, p, ip_sN, sq_star, sq_cols) * mask_flat[None, :]
        return posterior_from_gram(G, G_sn, g_ss, y_flat, noise)

    return jax.vmap(view)(jnp.arange(m))


# --------------------------------------------------------------------------
# zero-rate baselines
# --------------------------------------------------------------------------


def poe_baseline(
    parts,
    X_star,
    kernel: str = "se",
    method: str = "rbcm",
    steps: int = 150,
    lr: float = 0.05,
    impl: str = "batched",
    gram_backend: str = "xla",
    train_impl: str = "scan",
):
    """Zero-rate baselines: each machine trains on its local data only (the
    block-diagonal-gram assumption), predictions combined by PoE/BCM/rBCM.

    ``impl="batched"`` runs all m experts' posteriors under one vmapped
    Cholesky on padded shards."""
    # shared hypers trained on machine 0's local data (standard practice: the
    # PoE family shares one hyperparameter set across experts)
    trained = train_gp(
        parts[0][0], parts[0][1], kernel=kernel, steps=steps, lr=lr, impl=train_impl
    )
    p = trained.params
    k = gram_fn(kernel)
    noise = jnp.exp(p.log_noise)
    X_star = jnp.asarray(X_star, jnp.float32)

    if impl == "host":
        if gram_backend == "pallas":
            raise ValueError('gram_backend="pallas" requires impl="batched"')

        @jax.jit
        def expert(Xj, yj):
            G = k(p, Xj)
            G_sn = k(p, X_star, Xj)
            g_ss = jnp.diagonal(k(p, X_star, X_star))
            return posterior_from_gram(G, G_sn, g_ss, yj, noise)

        mus, s2s = zip(*[expert(Xj, yj) for Xj, yj in parts])
        mus, s2s = jnp.stack(mus), jnp.stack(s2s)
        prior = jnp.diagonal(k(p, X_star, X_star)) + noise
        return (*combine(method, mus, s2s, prior), p)

    shards = pad_parts(parts)
    sq_exact = jnp.sum(shards.X**2, -1)
    sq_star = jnp.sum(X_star**2, -1)
    if gram_backend == "pallas":
        from ..kernels.gram.ops import gram as gram_kernel

        A = jax.vmap(lambda a: gram_kernel(a, a))(shards.X)
        Cstar = jax.vmap(lambda a: gram_kernel(X_star, a))(shards.X)
    else:
        A = jnp.einsum("ind,imd->inm", shards.X, shards.X)
        Cstar = jnp.einsum("td,ind->itn", X_star, shards.X)
    g_ss = prior_diag(kernel, p, sq_star)

    def expert(ipA, ipC, sqj, yj, mask_j):
        G = _mask_gram(kernel_from_inner(kernel, p, ipA, sqj, sqj), mask_j)
        G_sn = kernel_from_inner(kernel, p, ipC, sq_star, sqj) * mask_j[None, :]
        return posterior_from_gram(G, G_sn, g_ss, yj * mask_j, noise)

    mus, s2s = jax.vmap(expert)(A, Cstar, sq_exact, shards.y, shards.mask)
    prior = g_ss + noise
    return (*combine(method, mus, s2s, prior), p)
