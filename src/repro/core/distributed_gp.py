"""DEPRECATED module-level entry points — the code moved to
:mod:`repro.core.protocols`.

The 2k-line monolith that lived here is now a package split along the
paper's seams (``protocols/base.py`` shared wire/padding/ledger machinery,
``center.py`` §5.1, ``broadcast.py`` §5.2, ``poe.py`` zero-rate baselines,
``mesh.py`` the machines-as-devices shard_map substrate, ``wire.py`` the
pluggable wire schemes), fronted by the registry-backed estimator API::

    from repro.core import DGPConfig, DistributedGP

    est = DistributedGP(DGPConfig(protocol="center", bits_per_sample=24))
    art = est.fit(X, y, m=40)
    mu, var = est.predict(art, X_query)

Everything importable from here keeps working: the classes/helpers are
re-exports, and the seven legacy entry points (``quantize_to_center``,
``single_center_gp``, ``broadcast_gp``, ``poe_baseline``, ``fit``,
``predict``, ``update``) are thin wrappers that emit a single
``DeprecationWarning`` (once per process per function) and delegate to the
new implementations — numerics, signatures, and return types unchanged.
See docs/migration.md for the old-call → ``DGPConfig`` mapping.
"""
from __future__ import annotations

import functools
import warnings

from .protocols import base as _base
from .protocols import broadcast as _broadcast
from .protocols import center as _center
from .protocols import poe as _poe

# -- re-exports: every non-entry-point name keeps its old import path --------
from .protocols.base import (  # noqa: F401
    FittedProtocol,
    PaddedShards,
    StreamState,
    WireState,
    load_artifact,
    pad_parts,
    predict_op_counts,
    save_artifact,
    serve_trace_count,
    split_machines,
    update_trace_count,
    _mask_gram,
    _reencode,
    _wire_bits,
    _SERVE_TRACES,
    _UPDATE_TRACES,
)
from .protocols.center import CenterGP, _pallas_ip_rows  # noqa: F401
from .protocols.broadcast import (  # noqa: F401
    HostBroadcastGP,
    _decoded_inner_products,
    _star_decoded_products,
    _star_exact_products,
    _train_inner_products,
)
from .protocols.poe import HostPoEGP  # noqa: F401
from .protocols.mesh import (  # noqa: F401
    MESH_AXIS,
    broadcast_gp_mesh,
    machine_mesh,
    _run_wire_protocol_mesh,
)
from .protocols.wire import _run_wire_protocol  # noqa: F401

__all__ = [
    "split_machines",
    "pad_parts",
    "PaddedShards",
    "WireState",
    "FittedProtocol",
    "fit",
    "predict",
    "update",
    "save_artifact",
    "load_artifact",
    "serve_trace_count",
    "update_trace_count",
    "predict_op_counts",
    "quantize_to_center",
    "single_center_gp",
    "broadcast_gp",
    "poe_baseline",
    "broadcast_gp_mesh",
    "machine_mesh",
    "MESH_AXIS",
]

# warn once per process per entry point (tests/test_deprecations.py asserts
# exactly-once), without touching the global warning filters
_WARNED: set[str] = set()


def _deprecated(replacement: str):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if fn.__name__ not in _WARNED:
                _WARNED.add(fn.__name__)
                warnings.warn(
                    f"repro.core.distributed_gp.{fn.__name__} is deprecated: "
                    f"use {replacement} (see docs/migration.md)",
                    DeprecationWarning,
                    stacklevel=2,
                )
            return fn(*args, **kwargs)

        return wrapper

    return deco


@_deprecated('DistributedGP(DGPConfig(protocol="center", ...)).fit(...)')
@functools.wraps(_center.quantize_to_center)
def quantize_to_center(*args, **kwargs):
    return _center.quantize_to_center(*args, **kwargs)


@_deprecated('DistributedGP(DGPConfig(protocol="center", ...))')
@functools.wraps(_center.single_center_gp)
def single_center_gp(*args, **kwargs):
    return _center.single_center_gp(*args, **kwargs)


@_deprecated('DistributedGP(DGPConfig(protocol="broadcast", ...))')
@functools.wraps(_broadcast.broadcast_gp)
def broadcast_gp(*args, **kwargs):
    return _broadcast.broadcast_gp(*args, **kwargs)


@_deprecated('DistributedGP(DGPConfig(protocol="poe", ...))')
@functools.wraps(_poe.poe_baseline)
def poe_baseline(*args, **kwargs):
    return _poe.poe_baseline(*args, **kwargs)


@_deprecated("DistributedGP(DGPConfig(...)).fit(...)")
@functools.wraps(_base.fit)
def fit(*args, **kwargs):
    return _base.fit(*args, **kwargs)


@_deprecated("DistributedGP(...).predict(art, X_star) or art.predict(X_star)")
@functools.wraps(_base.predict)
def predict(*args, **kwargs):
    return _base.predict(*args, **kwargs)


@_deprecated("DistributedGP(...).update(art, ...) or art.update(...)")
@functools.wraps(_base.update)
def update(*args, **kwargs):
    return _base.update(*args, **kwargs)
