"""KL-barycenter fusion of local predictive Gaussians (paper §5.2, eqs. 62-64).

(mu*, Sigma*) = argmin sum_i KL( N(mu_i, Sigma_i) || N(mu, Sigma) )
  =>  mu*    = mean_i mu_i                                   (63)
      Sigma* = mean_i [ Sigma_i + (mu* - mu_i)(mu* - mu_i)^T ] (64)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["kl_fuse", "kl_fuse_diag", "kl_fuse_diag_psum", "kl_moments",
           "kl_finalize"]


def kl_fuse(mus, Sigmas):
    """mus: (m, t); Sigmas: (m, t, t) full covariances over the test batch."""
    mu = jnp.mean(mus, axis=0)
    dev = mu[None, :] - mus  # (m, t)
    Sigma = jnp.mean(Sigmas + dev[:, :, None] * dev[:, None, :], axis=0)
    return mu, Sigma


def kl_fuse_diag(mus, s2s, w=None):
    """Diagonal/per-point special case: s2s (m, t) marginal variances.

    ``w``: optional (m,) availability weights for degraded-mode serving — the
    barycenter renormalizes over surviving experts, and the fused variance is
    inflated by the lost fraction ``m / sum(w)`` (losing experts must never
    SHRINK uncertainty; docs/fault_model.md).  ``w=None`` is the healthy
    fleet and keeps the original arithmetic bit-for-bit."""
    if w is None:
        mu = jnp.mean(mus, axis=0)
        s2 = jnp.mean(s2s + (mu[None, :] - mus) ** 2, axis=0)
        return mu, s2
    m = mus.shape[0]
    w = jnp.asarray(w, mus.dtype).reshape(m, 1)
    m_eff = jnp.maximum(jnp.sum(w), 1.0)
    mu = jnp.sum(w * mus, axis=0) / m_eff
    s2 = jnp.sum(w * (s2s + (mu[None, :] - mus) ** 2), axis=0) / m_eff
    return mu, s2 * (m / m_eff)


def kl_fuse_diag_psum(mu_i, s2_i, axis_name: str, w_i=None):
    """:func:`kl_fuse_diag` as a mesh collective epilogue: each device holds
    ITS machine's per-point predictive (mu_i, s2_i) (t,) and the barycenter is
    two psums over ``axis_name`` (must run inside shard_map).  ``w_i`` is the
    device's own availability weight (the degraded form mirrors the stacked
    one term for term)."""
    m = jax.lax.psum(1, axis_name)
    if w_i is None:
        mu = jax.lax.psum(mu_i, axis_name) / m
        s2 = jax.lax.psum(s2_i + (mu - mu_i) ** 2, axis_name) / m
        return mu, s2
    m_eff = jnp.maximum(jax.lax.psum(w_i, axis_name), 1.0)
    mu = jax.lax.psum(w_i * mu_i, axis_name) / m_eff
    s2 = jax.lax.psum(w_i * (s2_i + (mu - mu_i) ** 2), axis_name) / m_eff
    return mu, s2 * (m / m_eff)


def kl_moments(mu_i, s2_i, prior_var=None, w_i=None):
    """One machine's KL-barycenter moment rows: ``[w mu_i, w (s2_i + mu_i^2),
    w]`` — summing these across machines (ONE collective) is sufficient
    statistics for eqs. 63-64, since

        mean_i (s2_i + (mu - mu_i)^2) = mean_i (s2_i + mu_i^2) - mu^2 ."""
    one = jnp.ones_like(mu_i)
    if w_i is None:
        return jnp.stack([mu_i, s2_i + mu_i * mu_i, one])
    return jnp.stack([w_i * mu_i, w_i * (s2_i + mu_i * mu_i), w_i * one])


def kl_finalize(S, m, prior_var=None):
    """Fused KL barycenter from summed moments (degraded form mirrors
    :func:`kl_fuse_diag`: renormalize over survivors, inflate by the lost
    fraction ``m / m_eff``)."""
    m_eff = jnp.maximum(S[2], 1.0)
    mu = S[0] / m_eff
    s2 = (S[1] / m_eff - mu * mu) * (m / m_eff)
    return mu, jnp.maximum(s2, 1e-12)


# KL barycenter as a registered fusion rule: the §5.2 default, selectable by
# name next to the PoE-family combiners (see repro.core.registry).
from .registry import FusionSpec, register_fusion  # noqa: E402

register_fusion(FusionSpec(
    name="kl",
    fuse=lambda mus, s2s, prior_var=None, w=None: kl_fuse_diag(mus, s2s, w),
    fuse_psum=lambda mu_i, s2_i, prior_var, axis, w_i=None: kl_fuse_diag_psum(
        mu_i, s2_i, axis, w_i
    ),
    moments=kl_moments,
    finalize=kl_finalize,
))
