"""KL-barycenter fusion of local predictive Gaussians (paper §5.2, eqs. 62-64).

(mu*, Sigma*) = argmin sum_i KL( N(mu_i, Sigma_i) || N(mu, Sigma) )
  =>  mu*    = mean_i mu_i                                   (63)
      Sigma* = mean_i [ Sigma_i + (mu* - mu_i)(mu* - mu_i)^T ] (64)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["kl_fuse", "kl_fuse_diag", "kl_fuse_diag_psum"]


def kl_fuse(mus, Sigmas):
    """mus: (m, t); Sigmas: (m, t, t) full covariances over the test batch."""
    mu = jnp.mean(mus, axis=0)
    dev = mu[None, :] - mus  # (m, t)
    Sigma = jnp.mean(Sigmas + dev[:, :, None] * dev[:, None, :], axis=0)
    return mu, Sigma


def kl_fuse_diag(mus, s2s):
    """Diagonal/per-point special case: s2s (m, t) marginal variances."""
    mu = jnp.mean(mus, axis=0)
    s2 = jnp.mean(s2s + (mu[None, :] - mus) ** 2, axis=0)
    return mu, s2


def kl_fuse_diag_psum(mu_i, s2_i, axis_name: str):
    """:func:`kl_fuse_diag` as a mesh collective epilogue: each device holds
    ITS machine's per-point predictive (mu_i, s2_i) (t,) and the barycenter is
    two psums over ``axis_name`` (must run inside shard_map)."""
    m = jax.lax.psum(1, axis_name)
    mu = jax.lax.psum(mu_i, axis_name) / m
    s2 = jax.lax.psum(s2_i + (mu - mu_i) ** 2, axis_name) / m
    return mu, s2


# KL barycenter as a registered fusion rule: the §5.2 default, selectable by
# name next to the PoE-family combiners (see repro.core.registry).
from .registry import FusionSpec, register_fusion  # noqa: E402

register_fusion(FusionSpec(
    name="kl",
    fuse=lambda mus, s2s, prior_var=None: kl_fuse_diag(mus, s2s),
    fuse_psum=lambda mu_i, s2_i, prior_var, axis: kl_fuse_diag_psum(
        mu_i, s2_i, axis
    ),
))
