"""Numerically-guarded Cholesky factorizations.

Every factorization site in the repo used to carry its own ``_JITTER = 1e-6``
constant and hope.  This module centralizes that:

* :data:`DEFAULT_JITTER` — the one pinned constant (1e-6, unchanged from the
  legacy per-module copies so existing tolerances are untouched).
* :func:`chol_jittered` — the legacy behaviour as a named helper: one shot,
  fixed jitter, fully differentiable.  Used at every site that sits under
  ``jax.grad`` (training losses), because :func:`jax.lax.while_loop` is not
  reverse-mode differentiable.
* :func:`chol_safe` — fit-time factorizations: bit-identical first attempt,
  then geometric jitter escalation under ``lax.while_loop`` when the factor
  comes back non-finite (rank-deficient / badly-conditioned Gram).  On the
  well-conditioned path the loop body never executes, so the cost is one
  Cholesky plus an ``isfinite`` reduction — and since it is only called at
  fit/update time, the warm predict path still contains zero factorizations
  (``predict_op_counts`` unchanged).

Both helpers take the FULL jitter ``eps`` (already scaled by trace/size where
the call site wants that) so the first-attempt arithmetic is expression-
identical to the code it replaces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["DEFAULT_JITTER", "chol_jittered", "chol_safe", "eigh_sym"]

DEFAULT_JITTER = 1e-6


def eigh_sym(M):
    """Eigendecomposition of a symmetric matrix — the ONE on-device ``eigh``
    home (repro.analysis.lint: ``raw-eigh``).

    ``jnp.linalg.eigh`` silently reads only one triangle, so a nominally
    symmetric input hides asymmetry bugs; callers symmetrize explicitly at
    the call site (``eigh_sym(0.5 * (B + B.T))``) where the input is only
    symmetric up to roundoff.  Centralized so eigh policy changes (clipping,
    dtype promotion, a backend switch) happen in one place, like the Cholesky
    jitter policy above."""
    return jnp.linalg.eigh(M)


def chol_jittered(M, eps):
    """``cholesky(M + eps * I)`` — one shot, differentiable.

    Use at sites under ``jax.grad`` (NLML, ELBO, Nyström completion inside the
    training loss): ``lax.while_loop`` has no reverse-mode rule, so these
    cannot escalate.  ``eps`` is the full jitter value (may be a traced
    scalar, e.g. ``noise_var + DEFAULT_JITTER``)."""
    n = M.shape[-1]
    return jnp.linalg.cholesky(M + eps * jnp.eye(n, dtype=M.dtype))


def chol_safe(M, eps=0.0, *, growth=10.0, max_tries=6):
    """Cholesky with geometric jitter escalation on non-finite factors.

    First attempt is ``cholesky(M + eps * I)`` — bit-identical to the legacy
    call it replaces (``eps=0.0`` compiles to no added diagonal).  If that
    factor contains NaN/Inf (jnp.linalg.cholesky returns NaNs rather than
    raising), retries with ``M + (eps + base * growth**t) * I`` for
    t = 0..max_tries-1 under ``lax.while_loop``; ``base`` is scaled to the
    matrix (``max(eps, DEFAULT_JITTER * (|tr M|/n + DEFAULT_JITTER))``) so the
    escalation is meaningful for both unit-scale and large Grams.

    vmap-safe: the loop carry select is per-element (``jnp.where``), so in a
    batched call an already-finite element keeps its original factor even
    while a sibling element escalates.
    """
    n = M.shape[-1]
    eye = jnp.eye(n, dtype=M.dtype)
    eps = jnp.asarray(eps, M.dtype)
    L0 = jnp.linalg.cholesky(M + eps * eye)
    # escalation base: must be strictly positive even when eps == 0
    scale = jnp.abs(jnp.trace(M, axis1=-2, axis2=-1)) / n
    base = jnp.maximum(eps, DEFAULT_JITTER * (scale + DEFAULT_JITTER))

    def cond(carry):
        t, L = carry
        return (t < max_tries) & ~jnp.all(jnp.isfinite(L))

    growth = jnp.asarray(growth, M.dtype)

    def body(carry):
        t, L = carry
        # explicit cast: float ** int32 has no promotion path under
        # jax_numpy_dtype_promotion=strict (the strict-mode runtime contract)
        L_new = jnp.linalg.cholesky(M + (eps + base * growth ** t.astype(M.dtype)) * eye)
        ok = jnp.isfinite(L)
        return t + 1, jnp.where(ok, L, L_new)

    _, L = jax.lax.while_loop(cond, body, (jnp.int32(0), L0))
    return L
