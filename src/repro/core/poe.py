"""Zero-rate distributed-GP baselines the paper compares against (§5, §6):
Product of Experts (PoE), generalized PoE, Bayesian Committee Machine (BCM),
and robust BCM (rBCM, Deisenroth & Ng 2015).

Each expert i contributes a Gaussian predictive N(mu_i, s2_i) per test point;
the combiners differ in precision weighting.  ``prior_var`` is the prior
k(x*, x*) + sigma_eps^2 needed by (r)BCM.

Every combiner takes optional availability weights ``w`` (m,): degraded-mode
serving renormalizes the product over surviving experts (a 0 weight removes
that expert's factor entirely — and its prior correction, for the committee
machines).  ``w=None`` is the healthy fleet and keeps the original
arithmetic untouched (docs/fault_model.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["poe", "gpoe", "bcm", "rbcm", "combine", "combine_psum",
           "combine_moments", "combine_finalize"]


def _weights(w, m, dtype):
    return jnp.asarray(w, dtype).reshape(m, 1)


def poe(mus, s2s, prior_var=None, w=None):
    """PoE: precision-weighted product.  mus/s2s: (m, t)."""
    if w is None:
        prec = jnp.sum(1.0 / s2s, axis=0)
        mu = jnp.sum(mus / s2s, axis=0) / prec
        return mu, 1.0 / prec
    w = _weights(w, mus.shape[0], mus.dtype)
    prec = jnp.maximum(jnp.sum(w / s2s, axis=0), 1e-12)
    mu = jnp.sum(w * mus / s2s, axis=0) / prec
    return mu, 1.0 / prec


def gpoe(mus, s2s, prior_var=None, betas=None, w=None):
    """Generalized PoE with weights beta_i (default 1/m so variances don't
    collapse with m; under availability weights, beta_i = w_i / sum(w))."""
    m = mus.shape[0]
    if betas is None:
        if w is None:
            betas = jnp.full((m, 1), 1.0 / m)
        else:
            w = _weights(w, m, mus.dtype)
            betas = w / jnp.maximum(jnp.sum(w), 1.0)
    prec = jnp.maximum(jnp.sum(betas / s2s, axis=0), 1e-12)
    mu = jnp.sum(betas * mus / s2s, axis=0) / prec
    return mu, 1.0 / prec


def bcm(mus, s2s, prior_var, w=None):
    """BCM (Tresp 2000): PoE with the (m-1)-fold prior correction (under
    availability weights, the (sum(w)-1)-fold correction)."""
    m = mus.shape[0]
    if w is None:
        prec = jnp.sum(1.0 / s2s, axis=0) - (m - 1.0) / prior_var
        prec = jnp.maximum(prec, 1e-12)
        mu = jnp.sum(mus / s2s, axis=0) / prec
        return mu, 1.0 / prec
    w = _weights(w, m, mus.dtype)
    m_eff = jnp.sum(w)
    prec = jnp.sum(w / s2s, axis=0) - (m_eff - 1.0) / prior_var
    prec = jnp.maximum(prec, 1e-12)
    mu = jnp.sum(w * mus / s2s, axis=0) / prec
    return mu, 1.0 / prec


def rbcm(mus, s2s, prior_var, w=None):
    """Robust BCM: beta_i = 0.5 (log prior_var - log s2_i) (Deisenroth & Ng);
    availability weights scale the betas, so a lost expert contributes
    neither evidence nor prior correction."""
    betas = 0.5 * (jnp.log(prior_var) - jnp.log(s2s))  # (m, t)
    if w is not None:
        betas = betas * _weights(w, mus.shape[0], mus.dtype)
    prec = jnp.sum(betas / s2s, axis=0) + (1.0 - jnp.sum(betas, axis=0)) / prior_var
    prec = jnp.maximum(prec, 1e-12)
    mu = jnp.sum(betas * mus / s2s, axis=0) / prec
    return mu, 1.0 / prec


_COMBINERS = {"poe": poe, "gpoe": gpoe, "bcm": bcm, "rbcm": rbcm}


def combine(method: str, mus, s2s, prior_var=None, w=None):
    return _COMBINERS[method](jnp.asarray(mus), jnp.asarray(s2s), prior_var, w=w)


def combine_psum(method: str, mu_i, s2_i, prior_var, axis_name: str, w_i=None):
    """The PoE-family combiners as mesh collective epilogues: each device
    holds ITS expert's (mu_i, s2_i) (t,) and every sum over experts becomes a
    ``lax.psum`` over ``axis_name`` (must run inside shard_map).  Agrees with
    :func:`combine` on the stacked predictives (``w_i`` is the device's own
    availability weight; the degraded form mirrors the stacked one term for
    term)."""
    m = jax.lax.psum(1, axis_name)
    if method == "poe":
        if w_i is None:
            prec = jax.lax.psum(1.0 / s2_i, axis_name)
            mu = jax.lax.psum(mu_i / s2_i, axis_name) / prec
            return mu, 1.0 / prec
        prec = jnp.maximum(jax.lax.psum(w_i / s2_i, axis_name), 1e-12)
        mu = jax.lax.psum(w_i * mu_i / s2_i, axis_name) / prec
        return mu, 1.0 / prec
    if method == "gpoe":
        if w_i is None:
            beta_i = 1.0 / m
        else:
            beta_i = w_i / jnp.maximum(jax.lax.psum(w_i, axis_name), 1.0)
        prec = jax.lax.psum(beta_i / s2_i, axis_name)
        if w_i is not None:
            prec = jnp.maximum(prec, 1e-12)
        mu = jax.lax.psum(beta_i * mu_i / s2_i, axis_name) / prec
        return mu, 1.0 / prec
    if method == "bcm":
        if w_i is None:
            prec = jax.lax.psum(1.0 / s2_i, axis_name) - (m - 1.0) / prior_var
            prec = jnp.maximum(prec, 1e-12)
            mu = jax.lax.psum(mu_i / s2_i, axis_name) / prec
            return mu, 1.0 / prec
        m_eff = jax.lax.psum(w_i, axis_name)
        prec = jax.lax.psum(w_i / s2_i, axis_name) - (m_eff - 1.0) / prior_var
        prec = jnp.maximum(prec, 1e-12)
        mu = jax.lax.psum(w_i * mu_i / s2_i, axis_name) / prec
        return mu, 1.0 / prec
    if method == "rbcm":
        beta_i = 0.5 * (jnp.log(prior_var) - jnp.log(s2_i))
        if w_i is not None:
            beta_i = beta_i * w_i
        prec = jax.lax.psum(beta_i / s2_i, axis_name) + (
            1.0 - jax.lax.psum(beta_i, axis_name)
        ) / prior_var
        prec = jnp.maximum(prec, 1e-12)
        mu = jax.lax.psum(beta_i * mu_i / s2_i, axis_name) / prec
        return mu, 1.0 / prec
    raise ValueError(f"unknown combiner {method!r}")


def combine_moments(method: str, mu_i, s2_i, prior_var=None, w_i=None):
    """One expert's moment rows for the fused (single-collective) epilogue.

    PoE-family combiners are sums of per-expert precision terms, so the rows
    ``[w/s2_i, w mu_i/s2_i, w]`` (betas folded in for rbcm) summed across
    experts carry everything :func:`combine_finalize` needs."""
    w = jnp.ones_like(mu_i) if w_i is None else w_i * jnp.ones_like(mu_i)
    if method == "rbcm":
        beta = 0.5 * (jnp.log(prior_var) - jnp.log(s2_i)) * w
        return jnp.stack([beta / s2_i, beta * mu_i / s2_i, beta])
    if method not in _COMBINERS:
        raise ValueError(f"unknown combiner {method!r}")
    return jnp.stack([w / s2_i, w * mu_i / s2_i, w])


def combine_finalize(method: str, S, m, prior_var=None):
    """Fused combiner from summed moment rows ``S`` (healthy fleet has
    ``S[2] == m``, so the degraded renormalizations reduce to the original
    arithmetic term for term)."""
    if method == "poe":
        prec = jnp.maximum(S[0], 1e-12)
        return S[1] / prec, 1.0 / prec
    if method == "gpoe":
        # betas = w / m_eff: fold the normalization in at finalize time
        m_eff = jnp.maximum(S[2], 1.0)
        prec = jnp.maximum(S[0] / m_eff, 1e-12)
        return S[1] / jnp.maximum(S[0], 1e-12), 1.0 / prec
    if method == "bcm":
        prec = jnp.maximum(S[0] - (S[2] - 1.0) / prior_var, 1e-12)
        return S[1] / prec, 1.0 / prec
    if method == "rbcm":
        prec = jnp.maximum(S[0] + (1.0 - S[2]) / prior_var, 1e-12)
        return S[1] / prec, 1.0 / prec
    raise ValueError(f"unknown combiner {method!r}")


# The zero-rate combiners double as registered fusion rules so broadcast
# artifacts can fuse with any of them by name (fuse="rbcm" etc.).
from functools import partial as _partial  # noqa: E402

from .registry import FusionSpec, register_fusion  # noqa: E402

for _name in _COMBINERS:
    register_fusion(FusionSpec(
        name=_name,
        fuse=_partial(combine, _name),
        fuse_psum=_partial(combine_psum, _name),
        moments=_partial(combine_moments, _name),
        finalize=_partial(combine_finalize, _name),
    ))
del _name
