"""Zero-rate distributed-GP baselines the paper compares against (§5, §6):
Product of Experts (PoE), generalized PoE, Bayesian Committee Machine (BCM),
and robust BCM (rBCM, Deisenroth & Ng 2015).

Each expert i contributes a Gaussian predictive N(mu_i, s2_i) per test point;
the combiners differ in precision weighting.  ``prior_var`` is the prior
k(x*, x*) + sigma_eps^2 needed by (r)BCM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["poe", "gpoe", "bcm", "rbcm", "combine", "combine_psum"]


def poe(mus, s2s, prior_var=None):
    """PoE: precision-weighted product.  mus/s2s: (m, t)."""
    prec = jnp.sum(1.0 / s2s, axis=0)
    mu = jnp.sum(mus / s2s, axis=0) / prec
    return mu, 1.0 / prec


def gpoe(mus, s2s, prior_var=None, betas=None):
    """Generalized PoE with weights beta_i (default 1/m so variances don't
    collapse with m)."""
    m = mus.shape[0]
    betas = jnp.full((m, 1), 1.0 / m) if betas is None else betas
    prec = jnp.sum(betas / s2s, axis=0)
    mu = jnp.sum(betas * mus / s2s, axis=0) / prec
    return mu, 1.0 / prec


def bcm(mus, s2s, prior_var):
    """BCM (Tresp 2000): PoE with the (m-1)-fold prior correction."""
    m = mus.shape[0]
    prec = jnp.sum(1.0 / s2s, axis=0) - (m - 1.0) / prior_var
    prec = jnp.maximum(prec, 1e-12)
    mu = jnp.sum(mus / s2s, axis=0) / prec
    return mu, 1.0 / prec


def rbcm(mus, s2s, prior_var):
    """Robust BCM: beta_i = 0.5 (log prior_var - log s2_i) (Deisenroth & Ng)."""
    betas = 0.5 * (jnp.log(prior_var) - jnp.log(s2s))  # (m, t)
    prec = jnp.sum(betas / s2s, axis=0) + (1.0 - jnp.sum(betas, axis=0)) / prior_var
    prec = jnp.maximum(prec, 1e-12)
    mu = jnp.sum(betas * mus / s2s, axis=0) / prec
    return mu, 1.0 / prec


_COMBINERS = {"poe": poe, "gpoe": gpoe, "bcm": bcm, "rbcm": rbcm}


def combine(method: str, mus, s2s, prior_var=None):
    return _COMBINERS[method](jnp.asarray(mus), jnp.asarray(s2s), prior_var)


def combine_psum(method: str, mu_i, s2_i, prior_var, axis_name: str):
    """The PoE-family combiners as mesh collective epilogues: each device
    holds ITS expert's (mu_i, s2_i) (t,) and every sum over experts becomes a
    ``lax.psum`` over ``axis_name`` (must run inside shard_map).  Agrees with
    :func:`combine` on the stacked predictives."""
    m = jax.lax.psum(1, axis_name)
    if method == "poe":
        prec = jax.lax.psum(1.0 / s2_i, axis_name)
        mu = jax.lax.psum(mu_i / s2_i, axis_name) / prec
        return mu, 1.0 / prec
    if method == "gpoe":
        beta = 1.0 / m
        prec = jax.lax.psum(beta / s2_i, axis_name)
        mu = jax.lax.psum(beta * mu_i / s2_i, axis_name) / prec
        return mu, 1.0 / prec
    if method == "bcm":
        prec = jax.lax.psum(1.0 / s2_i, axis_name) - (m - 1.0) / prior_var
        prec = jnp.maximum(prec, 1e-12)
        mu = jax.lax.psum(mu_i / s2_i, axis_name) / prec
        return mu, 1.0 / prec
    if method == "rbcm":
        beta_i = 0.5 * (jnp.log(prior_var) - jnp.log(s2_i))
        prec = jax.lax.psum(beta_i / s2_i, axis_name) + (
            1.0 - jax.lax.psum(beta_i, axis_name)
        ) / prior_var
        prec = jnp.maximum(prec, 1e-12)
        mu = jax.lax.psum(beta_i * mu_i / s2_i, axis_name) / prec
        return mu, 1.0 / prec
    raise ValueError(f"unknown combiner {method!r}")


# The zero-rate combiners double as registered fusion rules so broadcast
# artifacts can fuse with any of them by name (fuse="rbcm" etc.).
from functools import partial as _partial  # noqa: E402

from .registry import FusionSpec, register_fusion  # noqa: E402

for _name in _COMBINERS:
    register_fusion(FusionSpec(
        name=_name,
        fuse=_partial(combine, _name),
        fuse_psum=_partial(combine_psum, _name),
    ))
del _name
