"""Rate-distortion theory of the inner-product problem (paper §4.1).

* Theorem 1: lower bound via reverse water-filling over eigenvalues of Qx @ Qy.
* Theorem 2: for Gaussian X the bound is achieved by the test channel
  x = xhat + z with Q = Qy^{-1/2} U Qtilde U^T Qy^{-1/2}; we simulate it by
  sampling xhat | x (block coding with 2^{nR} codebooks is intractable, as the
  paper notes).

Rates are in *bits* per sample (log2), matching the paper's figures.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "product_eigs",
    "reverse_waterfill",
    "rd_lower_bound_curve",
    "rate_for_distortion",
    "distortion_for_rate",
    "OptimalTestChannel",
    "make_test_channel",
]


def _sqrt_psd(Q):
    """Symmetric PSD square root (and inverse sqrt) via eigh."""
    w, v = np.linalg.eigh(np.asarray(Q, dtype=np.float64))
    w = np.clip(w, 0.0, None)
    s = np.sqrt(w)
    half = (v * s) @ v.T
    inv_s = np.where(s > 1e-12 * s.max(), 1.0 / np.where(s == 0, 1.0, s), 0.0)
    inv_half = (v * inv_s) @ v.T
    return half, inv_half


def product_eigs(Qx, Qy):
    """Eigendecomposition of Qy^{1/2} Qx Qy^{1/2} = U Lambda U^T (eq. 25/33).

    Returns (Lambda_desc, U, Qy_half, Qy_inv_half).  Lambda equals the
    eigenvalues of Qx @ Qy (real, >= 0, since both are PSD).
    """
    Qy_half, Qy_inv_half = _sqrt_psd(Qy)
    B = Qy_half @ np.asarray(Qx, dtype=np.float64) @ Qy_half
    B = 0.5 * (B + B.T)
    lam, U = np.linalg.eigh(B)
    order = np.argsort(lam)[::-1]
    return np.clip(lam[order], 0.0, None), U[:, order], Qy_half, Qy_inv_half


def reverse_waterfill(eigs: np.ndarray, distortion: float) -> np.ndarray:
    """q_i = min(lambda_wl, eig_i) with sum(q) == D (eq. 14/27-29)."""
    eigs = np.asarray(eigs, dtype=np.float64)
    total = eigs.sum()
    if distortion >= total:
        return eigs.copy()
    lo, hi = 0.0, float(eigs.max())
    for _ in range(200):  # bisection on the water level
        mid = 0.5 * (lo + hi)
        if np.minimum(mid, eigs).sum() > distortion:
            hi = mid
        else:
            lo = mid
    return np.minimum(0.5 * (lo + hi), eigs)


def rd_lower_bound_curve(Qx, Qy, n_points: int = 200):
    """The (R, D) lower-bound curve of Theorem 1 for Gaussian X.

    Parametrized by the water level; R(level) = 0.5*sum(log2(eig/q)),
    D(level) = sum(q).  Returns (rates_bits, distortions), rate-ascending.
    """
    eigs, _, _, _ = product_eigs(Qx, Qy)
    eigs = np.maximum(eigs, 1e-300)
    levels = np.geomspace(eigs.max(), eigs.max() * 1e-12, n_points)
    rates, dists = [], []
    for lv in levels:
        q = np.minimum(lv, eigs)
        rates.append(0.5 * np.sum(np.log2(eigs / q)))
        dists.append(q.sum())
    return np.asarray(rates), np.asarray(dists)


def rate_for_distortion(Qx, Qy, distortion: float) -> float:
    """R_lb(D) in bits (Theorem 1, eq. 13 specialized to Gaussian h(x))."""
    eigs, _, _, _ = product_eigs(Qx, Qy)
    q = reverse_waterfill(np.maximum(eigs, 1e-300), distortion)
    return float(0.5 * np.sum(np.log2(np.maximum(eigs, 1e-300) / np.maximum(q, 1e-300))))


def distortion_for_rate(Qx, Qy, rate_bits: float) -> float:
    """Invert the Theorem-1 curve: D such that R_lb(D) == rate_bits."""
    rates, dists = rd_lower_bound_curve(Qx, Qy, n_points=2000)
    return float(np.interp(rate_bits, rates, dists))


class OptimalTestChannel(NamedTuple):
    """xhat | x  ~  N(A x, W): the Theorem-2 achieving conditional."""

    A: np.ndarray
    W_half: np.ndarray  # W^{1/2} for sampling
    rate_bits: float
    distortion: float


def make_test_channel(Qx, Qy, distortion: float) -> OptimalTestChannel:
    """Build the Theorem-2 test channel for target distortion D.

    Q      = Qy^{-1/2} U Qtilde U^T Qy^{-1/2},  Qtilde = diag(min(level, Lambda))
    xhat   = A x + w,   A = (Qx - Q) Qx^{-1},   W = (Qx-Q) - (Qx-Q) Qx^{-1} (Qx-Q)
    which yields xhat ~ N(0, Qx - Q) and x - xhat with covariance Q, independent
    of xhat — exactly eq. (30).
    """
    eigs, U, Qy_half, Qy_inv_half = product_eigs(Qx, Qy)
    q = reverse_waterfill(np.maximum(eigs, 1e-300), distortion)
    Qtilde = np.diag(q)
    Q = Qy_inv_half @ U @ Qtilde @ U.T @ Qy_inv_half
    Qx = np.asarray(Qx, dtype=np.float64)
    QxmQ = Qx - Q
    Qx_inv = np.linalg.pinv(Qx)
    A = QxmQ @ Qx_inv
    W = QxmQ - QxmQ @ Qx_inv @ QxmQ
    W = 0.5 * (W + W.T)
    W_half, _ = _sqrt_psd(W)
    rate = 0.5 * np.sum(np.log2(np.maximum(eigs, 1e-300) / np.maximum(q, 1e-300)))
    return OptimalTestChannel(A=A, W_half=W_half, rate_bits=float(rate), distortion=float(q.sum()))


def sample_test_channel(channel: OptimalTestChannel, X, key):
    """Simulate the optimal scheme: Xhat = X A^T + N(0, W)."""
    X = jnp.asarray(X)
    noise = jax.random.normal(key, X.shape, dtype=X.dtype)
    return X @ jnp.asarray(channel.A, X.dtype).T + noise @ jnp.asarray(channel.W_half, X.dtype).T
