"""Exact Gaussian-process regression in JAX (paper §2).

Kernels: the paper's linear kernel (eq. 4) ``k = a x^T x' + b`` and the squared
exponential (eq. 65) ``k = s * exp(-||x-x'||^2 / l^2)``.

Hyperparameters are trained by maximizing the log marginal likelihood with
jax.grad + Adam (gradient-based, as in the paper §5.1).  All linear algebra is
Cholesky-based in float64-free JAX default (float32) but with jitter; set
``jax.config.update('jax_enable_x64', True)`` in experiments needing tighter
conditioning.

Everything here consumes *gram matrices*, so the distributed variants can feed
quantization-estimated grams straight in.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .linalg_safe import DEFAULT_JITTER, chol_jittered, chol_safe
from .registry import KERNELS, KernelSpec, register_kernel

__all__ = [
    "GPParams",
    "linear_gram",
    "se_gram",
    "kernel_from_inner",
    "prior_diag",
    "gram_fn",
    "posterior_factors",
    "posterior_apply",
    "posterior_from_gram",
    "nlml_from_gram",
    "GPModel",
    "make_adam_step",
    "train_gp",
]


def _inner_products(X, X2, backend: str):
    """X @ X2^T, optionally through the Pallas tiled-gram kernel.

    Every kernel in this module consumes inner products only, so this is the
    single routing point for ``gram_backend``."""
    if backend == "pallas":
        from ..kernels.gram.ops import gram as gram_kernel

        return gram_kernel(X, X2)
    if backend != "xla":
        raise ValueError(f"unknown gram backend {backend!r}")
    return X @ X2.T


class GPParams(NamedTuple):
    """Unconstrained (log-space) hyperparameters.

    linear kernel: a = exp(log_a), b = exp(log_b)
    se kernel:     s = exp(log_a), l^2 = exp(log_b)
    noise:         sigma_eps^2 = exp(log_noise)
    """

    log_a: jnp.ndarray
    log_b: jnp.ndarray
    log_noise: jnp.ndarray


def init_params(a=1.0, b=1.0, noise=0.1) -> GPParams:
    return GPParams(
        log_a=jnp.log(jnp.asarray(a, jnp.float32)),
        log_b=jnp.log(jnp.asarray(b, jnp.float32)),
        log_noise=jnp.log(jnp.asarray(noise, jnp.float32)),
    )


def linear_gram(params: GPParams, X, X2=None, *, backend: str = "xla"):
    """Paper eq. (4): k(x, x') = a <x, x'> + b.  Consumes inner products only."""
    X2 = X if X2 is None else X2
    return jnp.exp(params.log_a) * _inner_products(X, X2, backend) + jnp.exp(params.log_b)


def _sqdist(X, X2, backend: str = "xla"):
    n1 = jnp.sum(X**2, -1, keepdims=True)
    n2 = jnp.sum(X2**2, -1, keepdims=True)
    return jnp.maximum(n1 + n2.T - 2.0 * _inner_products(X, X2, backend), 0.0)


def se_gram(params: GPParams, X, X2=None, *, backend: str = "xla"):
    """Paper eq. (65): k = s exp(-||x - x'||^2 / l^2).

    Note ||x-x'||^2 = |x|^2 + |x'|^2 - 2<x,x'> — also inner-product based, which
    is why the paper's quantized-inner-product machinery covers RBF kernels."""
    X2 = X if X2 is None else X2
    return jnp.exp(params.log_a) * jnp.exp(
        -_sqdist(X, X2, backend) / jnp.exp(params.log_b)
    )


def _linear_from_inner(params: GPParams, ip, sq_x, sq_x2):
    return jnp.exp(params.log_a) * ip + jnp.exp(params.log_b)


def _se_from_inner(params: GPParams, ip, sq_x, sq_x2):
    sq = jnp.maximum(sq_x[:, None] + sq_x2[None, :] - 2.0 * ip, 0.0)
    return jnp.exp(params.log_a) * jnp.exp(-sq / jnp.exp(params.log_b))


def _linear_prior_diag(params: GPParams, sq_x):
    return jnp.exp(params.log_a) * sq_x + jnp.exp(params.log_b)


def _se_prior_diag(params: GPParams, sq_x):
    return jnp.full_like(jnp.asarray(sq_x), jnp.exp(params.log_a))


register_kernel(KernelSpec(
    name="linear", gram=linear_gram,
    from_inner=_linear_from_inner, prior_diag=_linear_prior_diag,
))
register_kernel(KernelSpec(
    name="se", gram=se_gram,
    from_inner=_se_from_inner, prior_diag=_se_prior_diag,
))


def kernel_from_inner(kernel: str, params: GPParams, ip, sq_x, sq_x2):
    """Gram block from precomputed inner products ``ip = X @ X2^T`` and squared
    norms — the form the fused dequantize+gram (qgram) path produces.

    ``kernel`` names a :data:`~repro.core.registry.KERNELS` entry (builtin:
    ``linear`` eq. 4, ``se`` eq. 65; extend with ``register_kernel``)."""
    return KERNELS.get(kernel).from_inner(params, ip, sq_x, sq_x2)


def prior_diag(kernel: str, params: GPParams, sq_x):
    """Prior variances k(x, x) from squared norms: the kernel-diagonal
    special case every predictive needs (linear: a|x|²+b; SE: constant s)."""
    return KERNELS.get(kernel).prior_diag(params, sq_x)


def gram_fn(kernel: str, backend: str = "xla") -> Callable:
    fn = KERNELS.get(kernel).gram
    if backend == "xla":
        return fn
    return functools.partial(fn, backend=backend)


def posterior_factors(G, y, noise_var):
    """Fit-time half of the dense GP predictive: factorize the train gram ONCE
    into ``{"L": chol(G + noise I), "alpha": (G + noise I)^{-1} y}``.
    :func:`posterior_apply` serves any number of query batches from these with
    triangular solves only (the ``FittedProtocol`` serve-path invariant)."""
    n = G.shape[0]
    noise = jnp.asarray(noise_var)
    noise = jnp.broadcast_to(noise, (n,)) if noise.ndim <= 1 else noise
    K = G + jnp.diag(noise + DEFAULT_JITTER)
    # fit-time: jitter already on the diagonal; escalate only if the factor
    # still comes back non-finite (rank-deficient gram)
    L = chol_safe(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    return {"L": L, "alpha": alpha}


def posterior_apply(factors, G_star_n, g_star_star):
    """Query-time half: O(t n^2) solves against cached :func:`posterior_factors`
    — no Cholesky factorization."""
    mean = G_star_n @ factors["alpha"]
    V = jax.scipy.linalg.solve_triangular(factors["L"], G_star_n.T, lower=True)
    var = g_star_star - jnp.sum(V**2, axis=0)
    return mean, jnp.maximum(var, 1e-12)


def posterior_from_gram(G, G_star_n, g_star_star, y, noise_var):
    """Posterior mean/variance given gram blocks (paper eqs. 2-3; eq. 3's sign
    typo fixed: the data term is SUBTRACTED).

    G: (n, n) train gram; G_star_n: (t, n) test-train; g_star_star: (t,) prior
    variances at test points; y: (n,); noise_var: scalar or per-point (n,)
    (heteroscedastic, used by pseudo-point aggregation).
    Returns (mean (t,), var (t,))."""
    return posterior_apply(
        posterior_factors(G, y, noise_var), G_star_n, g_star_star
    )


def nlml_from_gram(G, y, noise_var):
    """Negative log marginal likelihood -log N(y | 0, G + sigma^2 I)."""
    n = G.shape[0]
    # differentiated (training loss): one-shot jitter — while_loop escalation
    # has no reverse-mode rule
    L = chol_jittered(G, noise_var + DEFAULT_JITTER)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    return (
        0.5 * y @ alpha
        + jnp.sum(jnp.log(jnp.diagonal(L)))
        + 0.5 * n * jnp.log(2.0 * jnp.pi)
    )


@dataclasses.dataclass
class GPModel:
    """A trained GP bound to (possibly reconstructed/quantized) inputs."""

    kernel: str
    params: GPParams
    X: jnp.ndarray
    y: jnp.ndarray
    gram_backend: str = "xla"

    def predict(self, X_star):
        k = gram_fn(self.kernel, self.gram_backend)
        G = k(self.params, self.X)
        G_sn = k(self.params, X_star, self.X)
        g_ss = jnp.diagonal(k(self.params, X_star, X_star))
        return posterior_from_gram(
            G, G_sn, g_ss, self.y, jnp.exp(self.params.log_noise)
        )

    def nlml(self):
        G = gram_fn(self.kernel, self.gram_backend)(self.params, self.X)
        return nlml_from_gram(G, self.y, jnp.exp(self.params.log_noise))


def make_adam_step(loss: Callable, lr: float) -> Callable:
    """One Adam update ``step(i, params, m, v) -> (params, m, v)`` for the
    given scalar loss — minimal inline Adam (repro.optim is for the NN stack;
    keep core standalone).  Shared by train_gp and the warm-dispatch rows of
    benchmarks/hotpath_bench.py so the benchmark always times the shipped
    update rule."""
    b1, b2, eps = 0.9, 0.999, 1e-8

    def step(i, p, m, v):
        g = jax.grad(loss)(p)
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        t = i + 1.0
        mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
        vh = jax.tree.map(lambda a: a / (1 - b2**t), v)
        p = jax.tree.map(lambda a, mm, vv: a - lr * mm / (jnp.sqrt(vv) + eps), p, mh, vh)
        return p, m, v

    return step


def train_gp(
    X,
    y,
    kernel: str = "se",
    params: GPParams | None = None,
    steps: int = 200,
    lr: float = 0.05,
    gram_override: Callable | None = None,
    impl: str = "scan",
    gram_backend: str = "xla",
) -> GPModel:
    """Maximize marginal likelihood with Adam.

    ``gram_override(params) -> G`` lets distributed variants train on an
    externally assembled (e.g. Nyström-completed, quantized) gram matrix.

    ``impl="scan"`` (default) runs the whole optimizer loop as ONE compiled
    ``jax.lax.scan`` program — one trace, one device dispatch for all
    ``steps``.  ``impl="loop"`` keeps the legacy per-step jit dispatch
    (O(steps) host round-trips); it exists as the baseline for
    benchmarks/hotpath_bench.py.

    ``gram_backend="pallas"`` computes the training gram's inner products
    with the tiled Pallas kernel (differentiable via its custom VJP)."""
    X = jnp.asarray(X)
    y = jnp.asarray(y)
    params = params or init_params()
    k = gram_fn(kernel, gram_backend)

    def loss(p):
        G = gram_override(p) if gram_override is not None else k(p, X)
        return nlml_from_gram(G, y, jnp.exp(p.log_noise))

    step = make_adam_step(loss, lr)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    if impl == "loop":
        jstep = jax.jit(step)
        for i in range(steps):
            params, m, v = jstep(jnp.float32(i), params, m, v)
    elif impl == "scan":

        @jax.jit
        def run(p, m, v):
            def body(carry, i):
                return step(i, *carry), None

            (p, m, v), _ = jax.lax.scan(
                body, (p, m, v), jnp.arange(steps, dtype=jnp.float32)
            )
            return p, m, v

        params, m, v = run(params, m, v)
    else:
        raise ValueError(f"unknown train impl {impl!r}")
    return GPModel(kernel=kernel, params=params, X=X, y=y, gram_backend=gram_backend)
