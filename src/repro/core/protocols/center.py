"""§5.1 single-center protocol.

Machine ``center`` is the center: it ships its local second-moment S_c to
every machine; machine j fits the wire scheme to (Qx=S_j, Qy=S_c) and
transmits; the center decodes X̂_j, forms the first-block rows of the gram
matrix (its own block exact), Nyström-completes (eq. 61), trains
hyperparameters on the completed gram, and serves predictions from one
cached factor set.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from .. import quantizers as Q
from ..distortion import second_moment
from ..schemes import PerSymbolScheme
from ..gp import (
    GPParams,
    init_params,
    gram_fn,
    kernel_from_inner,
    prior_diag,
    posterior_factors,
    posterior_apply,
    posterior_from_gram,
    train_gp,
)
from ..nystrom import (
    nystrom_complete,
    nystrom_cross,
    nystrom_posterior,
    nystrom_factors,
    nystrom_apply,
    nystrom_serve_cache,
    nystrom_apply_cached,
    nystrom_kinv,
    chol_update_rank,
    chol_append_at,
)
from ..linalg_safe import DEFAULT_JITTER, chol_jittered
from ..registry import SCHEMES, ProtocolSpec, register_protocol
from . import base
from .base import (
    FittedProtocol,
    StreamState,
    WireState,
    pad_parts,
    _UPDATE_TRACES,
)

__all__ = ["quantize_to_center", "CenterGP", "single_center_gp"]


def _quantize_to_center_host(
    parts, bits_per_sample: int, center: int = 0, max_bits: int = Q.DEFAULT_MAX_BITS
):
    """Serial reference protocol: host-side scipy PerSymbolScheme per machine."""
    S_c = second_moment(parts[center][0])
    Xs, ys, sqs, wire = [], [], [], 0
    for j, (Xj, yj) in enumerate(parts):
        if j == center or np.asarray(Xj).shape[0] == 0:
            Xs.append(Xj)  # empty (dropped) machines transmit nothing
        else:
            S_j = second_moment(Xj)
            sch = PerSymbolScheme(bits_per_sample, max_bits).fit(
                np.asarray(S_j), np.asarray(S_c)
            )
            Xs.append(sch.decode(sch.encode(Xj)))
            wire += sch.wire_bits(Xj.shape[0]) + sch.side_info_bits(Xj.shape[1])
            # (the optional FITC diagonal costs an extra 32 bits/point of
            #  exact |x|^2 — accounted by the caller when gram_mode uses it)
        ys.append(yj)
        sqs.append(jnp.sum(jnp.asarray(Xj) ** 2, axis=-1))
    order = [center] + [j for j in range(len(parts)) if j != center]
    X_recon = jnp.concatenate([Xs[j] for j in order], axis=0)
    y_all = jnp.concatenate([ys[j] for j in order], axis=0)
    sq_norms = jnp.concatenate([sqs[j] for j in order], axis=0)
    n_center = parts[center][0].shape[0]
    return X_recon, y_all, wire, n_center, sq_norms


def _quantize_to_center_batched(
    parts, bits_per_sample: int, center: int, max_bits: int,
    impl: str = "batched", scheme: str = "per_symbol", faults=None,
):
    """Batched §5.1 wire: run the registered wire scheme for every machine at
    once, then assemble the center's gram-row layout (exact center block
    first).  ``impl="mesh"`` runs the per-symbol wire as one shard_map
    program on a machines-as-devices mesh (comm.q_all_gather is the channel,
    moving the packed code plane; payload measured from the buffer).

    Assembly reads the scheme run's RETURNED shards (not ``parts``): under a
    ``faults`` plan with wire corruption the run demotes CRC-flagged rows and
    compacts the survivors, so the shards are the receiver's honest view —
    for a clean run they are bitwise what ``pad_parts(parts)`` produced."""
    shards = pad_parts(parts)
    m, _, d = shards.X.shape
    run = SCHEMES.get(scheme).run(
        shards, bits_per_sample, max_bits, "center", center, impl, faults
    )
    wire_state, shards = run.state, run.shards
    order = [center] + [j for j in range(m) if j != center]
    blocks = [shards.X[center, : shards.lengths[center]]] + [
        wire_state.decoded[j, : shards.lengths[j]] for j in order[1:]
    ]
    X_recon = jnp.concatenate(blocks, axis=0)
    y_all = jnp.concatenate(
        [shards.y[j, : shards.lengths[j]] for j in order], axis=0
    )
    sq_norms = jnp.concatenate(
        [jnp.sum(shards.X[j, : shards.lengths[j]] ** 2, axis=-1) for j in order],
        axis=0,
    )
    return (
        X_recon, y_all, run.wire_bits, shards.lengths[center], sq_norms,
        shards, wire_state, order, run.extras, run.payload_bits,
        run.integrity_bits, run.rows_demoted,
    )


def quantize_to_center(
    parts, bits_per_sample: int, center: int = 0, impl: str = "batched",
    max_bits: int = Q.DEFAULT_MAX_BITS,
):
    """Run the single-center wire protocol; returns
    (X_recon, y_all, wire_bits, n_center, sq_norms).

    X_recon stacks the center's exact block first, then every machine's decoded
    points, matching the paper's gram-row layout.  ``sq_norms`` carries each
    point's EXACT |x|² (an O(32 n)-bit extra the Snelson–Ghahramani/FITC
    diagonal correction needs; included in the wire accounting).

    impl: "host" (serial scipy oracle), "batched" (one vmapped jit), or
    "mesh" (machines are devices; the wire is comm.q_all_gather inside one
    shard_map program) — all three produce integer-identical wire ledgers and
    matching reconstructions (tests/test_conformance.py)."""
    if impl == "host":
        return _quantize_to_center_host(parts, bits_per_sample, center, max_bits)
    if impl not in ("batched", "mesh"):
        raise ValueError(f"unknown impl {impl!r}")
    out = _quantize_to_center_batched(parts, bits_per_sample, center, max_bits, impl)
    return out[:5]


def _pallas_ip_rows(wire: WireState, block_order, lengths, Xc, Y, pack_bits: int):
    """⟨x_i, y_j⟩ for every x in the center gram-row layout (N, p): center rows
    via the Pallas tiled gram on exact points; reconstructed rows straight
    from the PACKED wire words via the fused unpack+dequantize+gram kernel —
    X̂ = dequant(unpack(words)) @ T_inv^T, so ⟨x̂, y⟩ =
    qgram_packed(words, Y @ T_inv).  ``pack_bits`` is the static row bit
    budget the words were packed under (``accounting.row_bits``).  Shared by
    the CenterGP fit-time builder and the FittedProtocol serve path."""
    from ...kernels.gram.ops import gram as gram_kernel
    from ...kernels.qgram.ops import qgram_packed_batched

    idx = list(block_order[1:])
    n_pad = wire.codes.shape[1]
    words = wire.codes[jnp.asarray(idx)]
    rates = wire.rates[jnp.asarray(idx)]
    cents = wire.scaled_cents[jnp.asarray(idx)]
    T_inv = wire.T_inv[jnp.asarray(idx)]
    mask = jnp.asarray(
        np.arange(n_pad)[None, :] < np.asarray([lengths[j] for j in idx])[:, None],
        jnp.float32,
    )
    top = gram_kernel(Xc, Y)  # (n_c, p)
    proj = jnp.einsum("pd,mde->mpe", Y, T_inv)  # Y in each decorrelated basis
    blocks = qgram_packed_batched(
        words, rates, cents, proj, total_bits=pack_bits, mask=mask
    )  # (m-1, n_pad, p)
    rows = [top] + [blocks[i, : lengths[j]] for i, j in enumerate(idx)]
    return jnp.concatenate(rows, axis=0)


@dataclasses.dataclass
class CenterGP:
    kernel: str
    params: GPParams
    X_recon: jnp.ndarray  # center block exact, rest reconstructed
    y: jnp.ndarray
    n_center: int
    wire_bits: int
    gram_mode: str = "nystrom"
    sq_norms: jnp.ndarray | None = None  # exact |x|^2 for the FITC diagonal
    gram_backend: str = "xla"
    wire: WireState | None = None  # packed words + tables (pallas/qgram path)
    block_order: tuple | None = None  # non-center machine ids, X_recon order
    block_lengths: tuple | None = None  # their true row counts
    pack_bits: int = 0  # static row bit budget of the packed wire codes
    payload_bits: int = 0  # measured packed payload (accounting formula)
    integrity_bits: int = 0  # CRC framing ledger (accounting.CRC_BITS/row)
    _ip_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.gram_backend == "pallas":
            if self.wire is None:
                raise ValueError(
                    'gram_backend="pallas" requires the batched wire protocol '
                    "(int codes) — use impl=\"batched\""
                )
            # materialize the inner-product cache NOW, outside any jit trace:
            # a cache miss inside train_gp's scan would store a leaked tracer
            self.warm_ip()

    def _exact_diag(self, params):
        """k(x_i, x_i) from the EXACT squared norms the machines shipped."""
        return prior_diag(self.kernel, params, self.sq_norms)

    # -- pallas/qgram inner-product assembly --------------------------------

    def _ip_rows(self, Y):
        """⟨x_i, y_j⟩ for every x in X_recon layout — see :func:`_pallas_ip_rows`."""
        return _pallas_ip_rows(
            self.wire, self.block_order, self.block_lengths,
            self.X_recon[: self.n_center], Y, self.pack_bits,
        )

    def _ip(self, key: str):
        """Cached param-independent inner products (pallas backend): computed
        once with the kernels, then reused as constants by every training step
        and prediction."""
        if key not in self._ip_cache:
            Xc = self.X_recon[: self.n_center]
            if key == "KN":
                self._ip_cache[key] = self._ip_rows(Xc).T  # (n_c, N)
            elif key == "NN":
                self._ip_cache[key] = self._ip_rows(self.X_recon)  # (N, N)
            elif key == "sq":
                self._ip_cache[key] = jnp.sum(self.X_recon**2, axis=-1)
        return self._ip_cache[key]

    def warm_ip(self):
        """Materialize the inner-product cache eagerly (before train_gp's scan
        traces _gram) so the Pallas kernels run once, not once per trace."""
        if self.gram_backend != "pallas":
            return self
        self._ip("sq")
        self._ip("NN" if self.gram_mode == "direct" else "KN")
        return self

    def _gram_pallas(self, params):
        sq = self._ip("sq")
        K = self.n_center
        if self.gram_mode == "direct":
            return kernel_from_inner(self.kernel, params, self._ip("NN"), sq, sq)
        ip_KN = self._ip("KN")
        G_KK = kernel_from_inner(self.kernel, params, ip_KN[:, :K], sq[:K], sq[:K])
        G_KN = kernel_from_inner(self.kernel, params, ip_KN, sq[:K], sq)
        if self.gram_mode == "nystrom_fitc" and self.sq_norms is not None:
            return nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(params))
        return nystrom_complete(G_KK, G_KN)

    def _gram(self, params):
        if self.gram_backend == "pallas":
            return self._gram_pallas(params)
        k = gram_fn(self.kernel)
        if self.gram_mode == "direct":
            # beyond-paper: all blocks straight from the reconstructed points;
            # converges to the full GP as R -> inf (Nyström caps at rank K)
            return k(params, self.X_recon)
        Xc = self.X_recon[: self.n_center]
        G_KK = k(params, Xc)
        G_KN = k(params, Xc, self.X_recon)
        if self.gram_mode == "nystrom_fitc" and self.sq_norms is not None:
            # Snelson & Ghahramani: make the Nyström diagonal exact (the
            # correction acts like per-point noise, taming the rank-K inverse)
            return nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(params))
        return nystrom_complete(G_KK, G_KN)

    def predict(self, X_star, available=None):
        # ``available`` is accepted for surface parity with the fused-family
        # models but ignored: the center already holds every decoded shard
        # locally, so serve-time machine loss does not change the predictive
        if self.gram_backend == "pallas":
            return self._predict_pallas(X_star)
        k = gram_fn(self.kernel)
        g_ss = jnp.diagonal(k(self.params, X_star, X_star))
        noise = jnp.exp(self.params.log_noise)
        if self.gram_mode == "nystrom_fitc":
            # dense path: the FITC-corrected gram is full-rank (the exact
            # diagonal acts as per-point noise), so the direct predictive is
            # well-conditioned.  The test cross-covariance must still pass
            # through the Nyström map — the raw k(x*, x) against a
            # Nyström-structured train gram badly mis-weights y-components
            # outside the rank-K span (was the out-of-range seed bug).
            Xc = self.X_recon[: self.n_center]
            G_KK = k(self.params, Xc)
            G_KN = k(self.params, Xc, self.X_recon)
            G = nystrom_complete(G_KK, G_KN, exact_diag=self._exact_diag(self.params))
            G_sn = nystrom_cross(G_KK, G_KN, k(self.params, X_star, Xc))
            return posterior_from_gram(G, G_sn, g_ss, self.y, noise)
        if self.gram_mode == "nystrom":
            # consistent low-rank predictive: the test cross-covariances must
            # pass through the same Nyström map (G_*N = G_*K G_KK^{-1} G_KN),
            # else y-components outside the rank-K span are amplified by 1/s^2
            Xc = self.X_recon[: self.n_center]
            return nystrom_posterior(
                k(self.params, Xc), k(self.params, Xc, self.X_recon),
                self.y, noise, k(self.params, X_star, Xc), g_ss,
            )
        G = self._gram(self.params)
        G_sn = k(self.params, X_star, self.X_recon)
        return posterior_from_gram(G, G_sn, g_ss, self.y, noise)

    def _predict_pallas(self, X_star):
        from ...kernels.gram.ops import gram as gram_kernel

        X_star = jnp.asarray(X_star, jnp.float32)
        p = self.params
        sq = self._ip("sq")
        sq_star = jnp.sum(X_star**2, -1)
        K = self.n_center
        Xc = self.X_recon[:K]
        g_ss = prior_diag(self.kernel, p, sq_star)
        noise = jnp.exp(p.log_noise)
        ip_KN = self._ip("KN")
        G_KK = kernel_from_inner(self.kernel, p, ip_KN[:, :K], sq[:K], sq[:K])
        if self.gram_mode == "nystrom":
            ip_sK = gram_kernel(X_star, Xc)
            G_sK = kernel_from_inner(self.kernel, p, ip_sK, sq_star, sq[:K])
            G_KN = kernel_from_inner(self.kernel, p, ip_KN, sq[:K], sq)
            return nystrom_posterior(G_KK, G_KN, self.y, noise, G_sK, g_ss)
        G = self._gram_pallas(p)
        if self.gram_mode == "nystrom_fitc":
            # FITC-consistent test covariance (see the xla path)
            ip_sK = gram_kernel(X_star, Xc)
            G_sK = kernel_from_inner(self.kernel, p, ip_sK, sq_star, sq[:K])
            G_KN = kernel_from_inner(self.kernel, p, ip_KN, sq[:K], sq)
            G_sn = nystrom_cross(G_KK, G_KN, G_sK)
        else:
            ip_sN = self._ip_rows(X_star).T  # (t, N)
            G_sn = kernel_from_inner(self.kernel, p, ip_sN, sq_star, sq)
        return posterior_from_gram(G, G_sn, g_ss, self.y, noise)


def _check_center(cfg, parts):
    if not cfg.center < len(parts):
        raise ValueError(
            f"center={cfg.center} out of range for m={len(parts)} machines"
        )


def fit_center_host(parts, cfg, params: GPParams | None = None) -> CenterGP:
    """The serial scipy oracle (``impl="host"``): one host-side scheme fit and
    one dense Cholesky per machine.  Returns the legacy :class:`CenterGP`
    model (protocol semantics identical to the batched artifact; locked by
    tests/test_batched_protocol.py / test_conformance.py)."""
    from ...comm.accounting import integrity_bits_formula, payload_bits_formula

    _check_center(cfg, parts)
    plan = getattr(cfg, "faults", None)
    if plan is not None and plan.flip_rate > 0.0:
        raise NotImplementedError(
            "wire corruption (flip_rate) needs the packed code plane — the "
            'host oracle has none; use impl="batched" or "mesh"'
        )
    parts, _ = base._apply_fit_faults(parts, cfg)
    X_recon, y_all, wire, n_c, sq_norms = _quantize_to_center_host(
        parts, cfg.bits_per_sample, cfg.center, cfg.max_bits
    )
    d = X_recon.shape[1]
    lengths = [p[0].shape[0] for p in parts]
    payload = payload_bits_formula(
        lengths, d, cfg.bits_per_sample, cfg.max_bits, skip=cfg.center,
    )
    integrity = integrity_bits_formula(lengths, skip=cfg.center)
    if cfg.gram_mode == "nystrom_fitc":  # exact |x|^2 side-channel (32 bits/pt)
        wire += 32 * (X_recon.shape[0] - n_c)
        payload += 32 * (X_recon.shape[0] - n_c)
    model = CenterGP(
        kernel=cfg.kernel,
        params=params or init_params(),
        X_recon=X_recon,
        y=y_all,
        n_center=n_c,
        wire_bits=wire,
        gram_mode=cfg.gram_mode,
        sq_norms=sq_norms,
        gram_backend=cfg.gram_backend,
        payload_bits=payload,
        integrity_bits=integrity,
    )
    trained = train_gp(
        X_recon, y_all, kernel=cfg.kernel, params=model.params, steps=cfg.steps,
        lr=cfg.lr, gram_override=model._gram, impl=cfg.train_impl,
    )
    model.params = trained.params
    return model


def single_center_gp(
    parts,
    bits_per_sample: int,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    params: GPParams | None = None,
    gram_mode: str = "nystrom",
    impl: str = "batched",
    gram_backend: str = "xla",
    max_bits: int = Q.DEFAULT_MAX_BITS,
    train_impl: str = "scan",
):
    """Full §5.1 protocol: quantize-in, Nyström-complete (eq. 61), train hypers
    on the completed gram by marginal likelihood, return a predictor.

    This is a thin composition over the serving API: the default
    ``impl="batched"`` simply returns ``fit(parts, R, protocol="center", ...)``
    — a :class:`~.base.FittedProtocol` artifact whose ``.predict(X_star)``
    serves queries from cached factors.  ``impl="host"`` is the serial scipy
    reference/oracle (returns the legacy :class:`CenterGP`).  New code should
    prefer ``DistributedGP(DGPConfig(protocol="center", ...))``.
    """
    if impl == "host":
        from ..config import DGPConfig

        cfg = DGPConfig(
            protocol="center", kernel=kernel, impl="host",
            gram_backend=gram_backend, gram_mode=gram_mode,
            bits_per_sample=int(bits_per_sample), max_bits=int(max_bits),
            steps=int(steps), lr=float(lr), train_impl=train_impl,
        )
        return fit_center_host(parts, cfg, params)
    return base.fit(
        parts, bits_per_sample, protocol="center", kernel=kernel, steps=steps,
        lr=lr, params=params, gram_mode=gram_mode, gram_backend=gram_backend,
        max_bits=max_bits, train_impl=train_impl, impl=impl,
    )


# --------------------------------------------------------------------------
# fit / predict / update (the registered protocol triple)
# --------------------------------------------------------------------------


def _fit_center(parts, cfg, params: GPParams | None = None) -> FittedProtocol:
    from ...comm.accounting import row_bits

    _check_center(cfg, parts)
    parts, _ = base._apply_fit_faults(parts, cfg)
    (X_recon, y_all, wire, n_c, sq_norms, shards, wire_state, order, extras,
     payload, integrity, rows_demoted) = (
        _quantize_to_center_batched(
            parts, cfg.bits_per_sample, cfg.center, cfg.max_bits, cfg.impl,
            cfg.scheme, getattr(cfg, "faults", None),
        )
    )
    kernel, gram_mode, gram_backend = cfg.kernel, cfg.gram_mode, cfg.gram_backend
    d = X_recon.shape[1]
    if gram_mode == "nystrom_fitc":  # exact |x|^2 side-channel (32 bits/point)
        wire += 32 * (X_recon.shape[0] - n_c)
        payload += 32 * (X_recon.shape[0] - n_c)
    builder = CenterGP(
        kernel=kernel,
        params=params or init_params(),
        X_recon=X_recon,
        y=y_all,
        n_center=n_c,
        wire_bits=wire,
        gram_mode=gram_mode,
        sq_norms=sq_norms,
        gram_backend=gram_backend,
        wire=wire_state,
        block_order=tuple(order),
        block_lengths=shards.lengths,
        pack_bits=row_bits(cfg.bits_per_sample, d, cfg.max_bits),
        payload_bits=payload,
        integrity_bits=integrity,
    )
    trained = train_gp(
        X_recon, y_all, kernel=kernel, params=builder.params, steps=cfg.steps,
        lr=cfg.lr, gram_override=builder._gram, impl=cfg.train_impl,
    )
    builder.params = trained.params
    p = builder.params
    noise = jnp.exp(p.log_noise)
    K = n_c
    Xc = X_recon[:K]
    # ---- the one-time factorization ----
    if gram_backend == "pallas":
        sq_cols = builder._ip("sq")
        if gram_mode == "direct":
            G_KK = G_KN = None
        else:
            ip_KN = builder._ip("KN")
            G_KK = kernel_from_inner(kernel, p, ip_KN[:, :K], sq_cols[:K], sq_cols[:K])
            G_KN = kernel_from_inner(kernel, p, ip_KN, sq_cols[:K], sq_cols)
    else:
        sq_cols = jnp.sum(X_recon**2, axis=-1)
        if gram_mode == "direct":
            G_KK = G_KN = None
        else:
            k = gram_fn(kernel)
            G_KK = k(p, Xc)
            G_KN = k(p, Xc, X_recon)

    if gram_mode == "nystrom":
        factors = nystrom_factors(G_KK, G_KN, y_all, noise)
        if getattr(cfg, "serve_epilogue", "fused") == "fused":
            factors.update(nystrom_serve_cache(factors))
    elif gram_mode == "nystrom_fitc":
        G = nystrom_complete(G_KK, G_KN, exact_diag=builder._exact_diag(p))
        factors = posterior_factors(G, y_all, noise)
        # FITC-consistent test map Q_*N = G_*K G_KK^{-1} G_KN needs (L_KK, W)
        L_KK = chol_jittered(G_KK, DEFAULT_JITTER * jnp.trace(G_KK) / K)
        factors["L_KK"] = L_KK
        factors["W"] = jax.scipy.linalg.solve_triangular(L_KK, G_KN, lower=True)
    elif gram_mode == "direct":
        factors = posterior_factors(builder._gram(p), y_all, noise)
    else:
        raise ValueError(f"unknown gram mode {gram_mode!r}")

    data = {
        "Xc": Xc, "X_recon": X_recon, "sq_cols": sq_cols,
        "sq_exact": sq_norms,
        # column-validity mask of the streaming buffers: all-live at fit time
        # (SE kernels do not vanish at padded zero points, so the padded
        # predict/update programs multiply this in)
        "valid": jnp.ones_like(y_all),
    }
    data.update(extras)
    return FittedProtocol(
        params=p,
        y=y_all,
        factors=factors,
        data=data,
        wire=wire_state,
        stream=StreamState.make(
            shards.lengths, y_all.shape[0], int(wire), int(payload),
            int(integrity), int(rows_demoted),
        ),
        protocol="center",
        kernel=kernel,
        gram_mode=gram_mode,
        fuse="",
        gram_backend=gram_backend,
        n_center=K,
        fit_lengths=shards.lengths,
        block_order=tuple(order),
        bits_per_sample=cfg.bits_per_sample,
        max_bits=cfg.max_bits,
        impl=cfg.impl,
        scheme=cfg.scheme,
        config=cfg,
    )


def _predict_center(art: FittedProtocol, X_star, sq_star, g_ss, noise, avail=None):
    # the center holds every factor locally, so machine availability cannot
    # change what it serves: the artifact IS the last-good decoded state
    # (losses are surfaced through base.serve_health instead)
    p = art.params
    Xc = art.data["Xc"]
    K = art.n_center
    sq_cols = art.data["sq_cols"]
    if art.gram_backend == "pallas":
        from ...kernels.gram.ops import gram as gram_kernel

        ip_sK = gram_kernel(X_star, Xc)
        G_sK = kernel_from_inner(art.kernel, p, ip_sK, sq_star, sq_cols[:K])
    else:
        G_sK = gram_fn(art.kernel)(p, X_star, Xc)
    if art.gram_mode == "nystrom":
        if "Ainv" in art.factors:  # fused serve epilogue: K-sized matmuls only
            return nystrom_apply_cached(art.factors, G_sK, g_ss, noise)
        return nystrom_apply(art.factors, G_sK, g_ss, noise)
    if art.gram_mode == "nystrom_fitc":
        # FITC-consistent test covariance: Q_*N = G_*K G_KK^{-1} G_KN from the
        # cached (L_KK, W) — raw k(x*, x) against a Nyström-structured train
        # gram badly mis-weights y-components outside the rank-K span
        B = jax.scipy.linalg.solve_triangular(
            art.factors["L_KK"], G_sK.T, lower=True
        )
        return posterior_apply(art.factors, B.T @ art.factors["W"], g_ss)
    # direct
    if art.gram_backend == "pallas":
        ip_sN = _artifact_ip_rows(art, X_star).T  # (t, N)
        G_sn = kernel_from_inner(art.kernel, p, ip_sN, sq_star, sq_cols)
    else:
        # padded capacity slots hold the zero point, where SE kernels do NOT
        # vanish — the validity mask zeroes those cross-columns exactly
        G_sn = gram_fn(art.kernel)(p, X_star, art.data["X_recon"]) \
            * art.data["valid"][None, :]
    return posterior_apply(art.factors, G_sn, g_ss)


def _artifact_ip_rows(art, Y):
    """⟨x_i, y_j⟩ in the artifact's X_recon layout — see :func:`_pallas_ip_rows`."""
    from ...comm.accounting import row_bits

    pack_bits = row_bits(art.bits_per_sample, art.data["Xc"].shape[1], art.max_bits)
    # fit_lengths, not the live counts: this path reads the fit-time wire
    # codes (pallas direct artifacts refuse streaming updates), and the
    # static tuple keeps the block layout out of the traced program
    return _pallas_ip_rows(
        art.wire, art.block_order, art.fit_lengths, art.data["Xc"], Y, pack_bits
    )


@jax.jit
def _update_center_jit(art, X_new, y_new, j, pre):
    """The device-resident streaming append: one traced program per
    (capacity, n_new, pre-treedef) — the machine index ``j`` is traced, so
    every machine shares the cache entry, and all state (factors, buffers,
    ledgers) moves as pytree leaves with fixed shapes."""
    _UPDATE_TRACES["center"] += 1  # runs at trace time only
    p = art.params
    noise = jnp.exp(p.log_noise)
    n_new = X_new.shape[0]
    s2 = noise + DEFAULT_JITTER
    if pre is None:
        # transmitting machine, jit-safe scheme: the full wire plane
        # (encode→pack→CRC→unpack→decode) runs inside this program
        decoded, w_add, p_add, i_add = SCHEMES.get(art.scheme).reencode_traced(
            art, j, X_new
        )
        d_add = jnp.int32(0)
        if art.gram_mode == "nystrom_fitc":
            w_add = w_add + 32 * n_new  # exact |x|^2 side channel
            p_add = p_add + 32 * n_new
    else:  # host-precomputed batch (center-local, vq channel, or faulted)
        decoded, w_add, p_add, i_add, d_add = pre
    pos = art.stream.cols
    sq_new = jnp.sum(decoded**2, -1)
    sq_new_exact = jnp.sum(X_new**2, -1)
    k = gram_fn(art.kernel)
    Xc = art.data["Xc"]
    valid = art.data["valid"]
    y2 = jax.lax.dynamic_update_slice(art.y, y_new, (pos,))
    f = dict(art.factors)

    if art.gram_mode == "nystrom":
        # columns append on the woodbury form: W gains L_KK^{-1} G_K,new IN
        # PLACE at the occupied-column cursor, and L_M = chol(s2 I + W W^T)
        # takes a rank-n_new update (zero padded W columns contribute nothing)
        W_new = jax.scipy.linalg.solve_triangular(
            f["L_KK"], k(p, Xc, decoded), lower=True
        )
        f["W"] = jax.lax.dynamic_update_slice(f["W"], W_new, (0, pos))
        f["L_M"] = chol_update_rank(f["L_M"], W_new)
        f["alpha"] = nystrom_kinv(f["W"], f["L_M"], s2, y2)
        if "U" in f:
            # fused-epilogue cache maintenance: U takes the same rank-n_new
            # update as L_M (padded W columns are zero, so the incremental
            # form is exact); walpha is an O(K C) recompute; Ainv is fixed
            f["U"] = f["U"] + W_new @ W_new.T
            f["walpha"] = f["W"] @ f["alpha"]
    elif art.gram_mode == "direct":
        # the validity mask zeroes cross-covariances against padded slots
        # (k(x, 0) != 0 for SE), keeping chol_append_at's zero-row contract
        G_on = k(p, art.data["X_recon"], decoded) * valid[:, None]
        G_nn = k(p, decoded) + s2 * jnp.eye(n_new, dtype=G_on.dtype)
        f["L"] = chol_append_at(f["L"], G_on, G_nn, pos)
        f["alpha"] = jax.scipy.linalg.cho_solve((f["L"], True), y2)
    else:  # nystrom_fitc: bordered dense factor through the Nyström map
        W_new = jax.scipy.linalg.solve_triangular(
            f["L_KK"], k(p, Xc, decoded), lower=True
        )
        G_on = f["W"].T @ W_new  # padded W columns are zero: zero rows, exact
        corr = jnp.maximum(
            prior_diag(art.kernel, p, sq_new_exact) - jnp.sum(W_new**2, 0), 0.0
        )
        G_nn = W_new.T @ W_new + jnp.diag(corr) + s2 * jnp.eye(n_new)
        f["L"] = chol_append_at(f["L"], G_on, G_nn, pos)
        f["alpha"] = jax.scipy.linalg.cho_solve((f["L"], True), y2)
        f["W"] = jax.lax.dynamic_update_slice(f["W"], W_new, (0, pos))

    data = dict(art.data)
    zero = jnp.int32(0)
    data["X_recon"] = jax.lax.dynamic_update_slice(
        data["X_recon"], decoded, (pos, zero)
    )
    data["sq_cols"] = jax.lax.dynamic_update_slice(data["sq_cols"], sq_new, (pos,))
    data["sq_exact"] = jax.lax.dynamic_update_slice(
        data["sq_exact"], sq_new_exact, (pos,)
    )
    data["valid"] = jax.lax.dynamic_update_slice(
        valid, jnp.ones((n_new,), valid.dtype), (pos,)
    )
    s = art.stream
    stream = StreamState(
        counts=s.counts.at[j].add(n_new), cols=s.cols + n_new,
        wire_bits=s.wire_bits + w_add, payload_bits=s.payload_bits + p_add,
        integrity_bits=s.integrity_bits + i_add,
        rows_demoted=s.rows_demoted + d_add,
    )
    return dataclasses.replace(art, y=y2, factors=f, data=data, stream=stream)


def _update_center(art: FittedProtocol, X_new, y_new, j, pre=None):
    if art.gram_backend == "pallas" and art.gram_mode != "nystrom":
        raise NotImplementedError(
            "streaming update of pallas-backed center artifacts supports "
            'gram_mode="nystrom" only (direct/fitc query paths read the '
            "fit-time wire codes, which update does not extend)"
        )
    return _update_center_jit(art, X_new, y_new, base._machine_index(j), pre)


register_protocol(ProtocolSpec(
    name="center",
    fit=_fit_center,
    predict=_predict_center,
    update=_update_center,
    fit_host=fit_center_host,
))


# --------------------------------------------------------------------------
# the program contract (repro.analysis.check_contracts enforces it)
# --------------------------------------------------------------------------
from ...analysis.contracts import (
    CollectiveBudget,
    Contract,
    LedgerAccounting,
    NoHostCallbacks,
    NoShardingLeak,
    forbid_primitives,
    register_contract,
)

# §5.1 serving: the center holds ONE factor set, so a warm predict is pure
# triangular algebra — zero factorizations, zero host round-trips, zero
# collectives (machines were a fit-time construct), and nothing committed to
# more than one device (impl="mesh" unshards at the fit boundary).
register_contract("center", "predict", Contract(
    name="center-serve",
    rules=(
        forbid_primitives(),
        NoHostCallbacks(),
        CollectiveBudget(max_count=0),
        NoShardingLeak(max_devices=1),
        LedgerAccounting(),
    ),
))
register_contract("center", "update", Contract(
    name="center-update",
    rules=(NoShardingLeak(max_devices=1), LedgerAccounting()),
))
