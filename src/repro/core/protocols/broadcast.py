"""§5.2 broadcast protocol.

Every machine broadcasts codes fitted against Qy = sum of the *other*
machines' covariances; each machine builds its own Nyström gram (own block
exact), forms a local predictive, and the per-point predictives are fused
with a registered fusion rule (default: the KL barycenter, eqs. 62-64).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from .. import quantizers as Q
from ..distortion import second_moment
from ..schemes import PerSymbolScheme
from ..gp import (
    GPParams,
    gram_fn,
    kernel_from_inner,
    posterior_factors,
    posterior_apply,
    posterior_from_gram,
    train_gp,
)
from ..nystrom import (
    nystrom_complete,
    nystrom_posterior,
    nystrom_factors,
    nystrom_apply,
    nystrom_serve_cache,
    nystrom_apply_cached,
    nystrom_kinv,
    chol_update_rank,
)
from ..linalg_safe import DEFAULT_JITTER
from ..registry import FUSIONS, SCHEMES, ProtocolSpec, register_protocol
from . import base, mesh
from .base import (
    FittedProtocol,
    PaddedShards,
    StreamState,
    WireState,
    pad_parts,
    _mask_gram,
    _UPDATE_TRACES,
)

__all__ = ["broadcast_gp", "HostBroadcastGP", "fit_broadcast_host"]


# --------------------------------------------------------------------------
# the serial host oracle
# --------------------------------------------------------------------------


@dataclasses.dataclass
class HostBroadcastGP:
    """The ``impl="host"`` oracle's fitted state: one scipy scheme fit per
    machine, shared hypers trained at machine 0.  ``predict`` runs one dense
    solve per machine view and fuses — m serial host dispatches, kept as the
    reference the batched/mesh artifacts are locked against."""

    kernel: str
    params: GPParams
    parts: list
    decoded: list
    wire_bits: int
    gram_mode: str
    fuse: str
    payload_bits: int = 0  # packed-payload formula (accounting), for parity
    integrity_bits: int = 0  # CRC framing formula (accounting), for parity

    def predict(self, X_star, available=None):
        m = len(self.parts)
        k = gram_fn(self.kernel)
        p = self.params
        X_star = jnp.asarray(X_star, jnp.float32)
        y_parts = [yj for _, yj in self.parts]

        def machine_view(i):
            blocks = [
                self.parts[j][0] if j == i else self.decoded[j] for j in range(m)
            ]
            order = [i] + [j for j in range(m) if j != i]
            Xv = jnp.concatenate([blocks[j] for j in order], axis=0)
            yv = jnp.concatenate([y_parts[j] for j in order], axis=0)
            return Xv, yv, self.parts[i][0].shape[0]

        gram_mode = self.gram_mode

        @partial(jax.jit, static_argnums=(2,))
        def local_predict(Xv, yv, nc):
            Xc = Xv[:nc]
            g_ss = jnp.diagonal(k(p, X_star, X_star))
            if gram_mode == "nystrom":
                # consistent low-rank predictive (see CenterGP.predict)
                return nystrom_posterior(
                    k(p, Xc), k(p, Xc, Xv), yv, jnp.exp(p.log_noise),
                    k(p, X_star, Xc), g_ss,
                )
            G = k(p, Xv)  # "direct": all blocks from reconstructed points
            G_sn = k(p, X_star, Xv)
            return posterior_from_gram(G, G_sn, g_ss, yv, jnp.exp(p.log_noise))

        mus, s2s = [], []
        for i in range(m):
            Xv, yv, nc = machine_view(i)
            mu_i, s2_i = local_predict(Xv, yv, nc)
            mus.append(mu_i)
            s2s.append(s2_i)
        mus = jnp.stack(mus)
        s2s = jnp.stack(s2s)
        prior = jnp.diagonal(k(p, X_star, X_star)) + jnp.exp(p.log_noise)
        spec = FUSIONS.get(self.fuse)
        if available is None:  # legacy 3-arg fusions keep the healthy path
            return spec.fuse(mus, s2s, prior)
        w = (jnp.asarray(available, jnp.float32) > 0).astype(jnp.float32)
        return spec.fuse(mus, s2s, prior, w)


def fit_broadcast_host(parts, cfg, params=None) -> HostBroadcastGP:
    """Serial reference §5.2 fit: one scipy scheme fit per machine and shared
    hypers trained at machine 0 on its Nyström view (warm-started from
    ``params`` when given)."""
    plan = getattr(cfg, "faults", None)
    if plan is not None and plan.flip_rate > 0:
        raise NotImplementedError(
            "the host oracle has no packed wire plane to corrupt: inject "
            'flip faults with impl="batched" or impl="mesh"'
        )
    parts, _ = base._apply_fit_faults(parts, cfg)
    m = len(parts)
    S = [
        second_moment(Xj) if np.asarray(Xj).shape[0]
        else np.zeros((np.asarray(Xj).shape[1],) * 2, np.float32)
        for Xj, _ in parts
    ]
    S_tot = sum(S)
    # every machine encodes ONCE against the sum of the others' covariances
    # (a machine emptied by faults transmits nothing and is charged nothing)
    wire = 0
    decoded = []
    for j, (Xj, yj) in enumerate(parts):
        if np.asarray(Xj).shape[0] == 0:
            decoded.append(jnp.asarray(Xj, jnp.float32))
            continue
        sch = PerSymbolScheme(cfg.bits_per_sample, cfg.max_bits).fit(
            np.asarray(S[j]), np.asarray(S_tot - S[j])
        )
        decoded.append(sch.decode(sch.encode(Xj)))
        wire += sch.wire_bits(Xj.shape[0]) + sch.side_info_bits(Xj.shape[1])

    k = gram_fn(cfg.kernel)

    # train shared hypers at machine 0 on its own completed gram
    blocks0 = [parts[0][0]] + [decoded[j] for j in range(1, m)]
    X0 = jnp.concatenate(blocks0, axis=0)
    y0 = jnp.concatenate([yj for _, yj in parts], axis=0)
    nc0 = parts[0][0].shape[0]

    def gram0(p):
        Xc = X0[:nc0]
        return nystrom_complete(k(p, Xc), k(p, Xc, X0))

    trained = train_gp(
        X0, y0, kernel=cfg.kernel, params=params, steps=cfg.steps, lr=cfg.lr,
        gram_override=gram0, impl=cfg.train_impl,
    )
    from ...comm.accounting import integrity_bits_formula, payload_bits_formula

    payload = payload_bits_formula(
        [p[0].shape[0] for p in parts], parts[0][0].shape[1],
        cfg.bits_per_sample, cfg.max_bits,
    )
    integrity = integrity_bits_formula([p[0].shape[0] for p in parts])
    return HostBroadcastGP(
        kernel=cfg.kernel, params=trained.params, parts=list(parts),
        decoded=decoded, wire_bits=wire, gram_mode=cfg.gram_mode,
        fuse=cfg.fusion, payload_bits=payload, integrity_bits=integrity,
    )


# --------------------------------------------------------------------------
# fit-time inner-product tensors (batched impl)
# --------------------------------------------------------------------------


def _train_inner_products(
    shards: PaddedShards, wire: WireState, backend: str, pack_bits: int = 0
):
    """The query-independent inner-product tensors every machine view is
    assembled from (computed ONCE at fit time):

    A (m, n, n): exact own-block products Xs_i Xs_i^T
    B (m, m, n, n): B[j, i] = X̂_j Xs_i^T (decoded j against exact i)

    backend="pallas" computes A with the tiled gram kernel and B straight
    from the PACKED wire words with the fused unpack+dequantize+gram kernel
    (``pack_bits``: the static row bit budget of the packed plane)."""
    X = shards.X
    if backend == "pallas":
        from ...kernels.gram.ops import gram as gram_kernel
        from ...kernels.qgram.ops import qgram_packed

        A = jax.vmap(lambda a: gram_kernel(a, a))(X)
        proj = jnp.einsum("ind,jde->jine", X, wire.T_inv)  # (m_j, m_i, n, d)
        B = jax.vmap(
            lambda w, r, t, mk, ys: jax.vmap(
                lambda yy: qgram_packed(
                    w, r, t, yy, total_bits=pack_bits, mask=mk
                )
            )(ys)
        )(wire.codes, wire.rates, wire.scaled_cents, shards.mask, proj)
        return A, B
    A = jnp.einsum("ind,imd->inm", X, X)
    B = jnp.einsum("jnd,imd->jinm", wire.decoded, X)
    return A, B


def _star_exact_products(Xs, X_star, backend: str):
    """C (m, t, n): X_star Xs_i^T — the query-time products against every
    machine's EXACT shard (the Nyström bases)."""
    if backend == "pallas":
        from ...kernels.gram.ops import gram as gram_kernel

        return jax.vmap(lambda a: gram_kernel(X_star, a))(Xs)
    return jnp.einsum("td,ind->itn", X_star, Xs)


def _decoded_inner_products(
    shards: PaddedShards, wire: WireState, backend: str, pack_bits: int = 0
):
    """D (m, n_pad, m*n_pad): D[j] = X̂_j [X̂_0..X̂_m]^T (decoded-vs-decoded) —
    only the gram_mode="direct" views consume this, so it is computed only for
    them (fit time)."""
    m, n_pad, d = shards.X.shape
    dec_flat = wire.decoded.reshape(m * n_pad, d)
    if backend == "pallas":
        from ...kernels.qgram.ops import qgram_packed_batched

        proj = jnp.einsum("nd,jde->jne", dec_flat, wire.T_inv)
        return qgram_packed_batched(
            wire.codes, wire.rates, wire.scaled_cents, proj,
            total_bits=pack_bits, mask=shards.mask,
        )
    return jnp.einsum("jnd,Nd->jnN", wire.decoded, dec_flat)


def _star_decoded_products(wire: WireState, X_star, backend: str,
                           pack_bits: int = 0, mask=None):
    """E (m, t, n_pad): E[j] = X_star X̂_j^T — query-time products against the
    reconstructions (gram_mode="direct" views only); straight from the packed
    wire words under the pallas backend."""
    if backend == "pallas":
        from ...kernels.qgram.ops import qgram_packed_batched

        proj_star = jnp.einsum("td,jde->jte", X_star, wire.T_inv)
        return qgram_packed_batched(
            wire.codes, wire.rates, wire.scaled_cents, proj_star,
            total_bits=pack_bits, mask=mask,
        ).transpose(0, 2, 1)
    return jnp.einsum("td,jnd->jtn", X_star, wire.decoded)


def broadcast_gp(
    parts,
    bits_per_sample: int,
    X_star,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    fuse: str = "kl",
    gram_mode: str = "nystrom",
    impl: str = "batched",
    gram_backend: str = "xla",
    max_bits: int = Q.DEFAULT_MAX_BITS,
    train_impl: str = "scan",
):
    """Full §5.2 protocol.  Hyperparameters are trained once (at machine 0, on
    its Nyström view) and shared — a cheap O(#hypers) extra broadcast; the
    paper trains per-machine, which is embarrassingly parallel on a real
    cluster but m-times serial here.  Returns fused (mean, var) at X_star plus
    total wire bits.

    The default ``impl="batched"`` is a thin serving composition:
    ``fit(parts, R, protocol="broadcast", ...)`` builds the
    :class:`~.base.FittedProtocol` artifact (every machine's scheme fit,
    decode, and Nyström factorization under jax.vmap on padded shards — one
    batched Cholesky for all m local predictives instead of m serial ones),
    and :func:`~.base.predict` serves X_star from the cached factors.  Call
    ``fit`` directly (or the ``DistributedGP`` facade) to keep the artifact
    and amortize the protocol over many query batches."""
    if impl == "host":
        if gram_backend == "pallas":
            raise ValueError('gram_backend="pallas" requires impl="batched"')
        from ..config import DGPConfig

        cfg = DGPConfig(
            protocol="broadcast", kernel=kernel, fusion=fuse, impl="host",
            gram_mode=gram_mode, bits_per_sample=int(bits_per_sample),
            max_bits=int(max_bits), steps=int(steps), lr=float(lr),
            train_impl=train_impl,
        )
        model = fit_broadcast_host(parts, cfg)
        mu, s2 = model.predict(X_star)
        return mu, s2, model.wire_bits, model.params
    art = base.fit(
        parts, bits_per_sample, protocol="broadcast", kernel=kernel, steps=steps,
        lr=lr, gram_mode=gram_mode, fuse=fuse, gram_backend=gram_backend,
        max_bits=max_bits, train_impl=train_impl, impl=impl,
    )
    mu, s2 = base.predict(art, X_star)
    return mu, s2, art.wire_bits, art.params


# --------------------------------------------------------------------------
# fit / predict / update (the registered protocol triple)
# --------------------------------------------------------------------------


def _fit_broadcast(parts, cfg, params=None) -> FittedProtocol:
    from ...comm.accounting import row_bits

    parts, _ = base._apply_fit_faults(parts, cfg)
    m = len(parts)
    shards = pad_parts(parts)
    _, n_pad, d = shards.X.shape
    bits, kernel, gram_mode = cfg.bits_per_sample, cfg.kernel, cfg.gram_mode
    gram_backend, fuse = cfg.gram_backend, cfg.fusion
    pack_bits = row_bits(bits, d, cfg.max_bits)
    if cfg.impl == "mesh":
        if gram_mode != "nystrom":
            raise NotImplementedError(
                'impl="mesh" broadcast supports gram_mode="nystrom" only'
            )
        if gram_backend != "xla":
            raise NotImplementedError(
                'impl="mesh" assembles grams device-local (gram_backend="xla")'
            )
    run = SCHEMES.get(cfg.scheme).run(
        shards, bits, cfg.max_bits, "broadcast", 0, cfg.impl,
        getattr(cfg, "faults", None),
    )
    # CRC demotion may have compacted rows out of the shard table: every
    # assembly below reads the (possibly shrunk) post-wire shards
    wire_state, shards = run.state, run.shards
    wire, payload = run.wire_bits, run.payload_bits
    extras = run.extras

    sq_exact = jnp.sum(shards.X**2, -1)  # (m, n)
    sq_dec = jnp.sum(wire_state.decoded**2, -1)

    # ---- train shared hypers at machine 0 on its completed Nyström gram ----
    # (unpadded slices; the inner products are param-independent constants, so
    # the 150-step scan only re-does the cheap kernel map + Cholesky)
    L = shards.lengths
    n0 = L[0]
    if cfg.impl == "mesh":
        # machine-0-local training inputs, straight from the wire output (the
        # batched A/B tensors below exist only to vmap the m simulated views)
        X0s = jnp.asarray(shards.X[0, :n0], jnp.float32)
        ip_KK0 = X0s @ X0s.T
        X_cols0 = jnp.concatenate(
            [X0s] + [wire_state.decoded[j, : L[j]] for j in range(1, m)], axis=0
        )
        ip_KN0 = X0s @ X_cols0.T
    else:
        A, B = _train_inner_products(shards, wire_state, gram_backend, pack_bits)
        ip_KK0 = A[0][:n0, :n0]
        ip_KN0 = jnp.concatenate(
            [ip_KK0] + [B[j, 0][: L[j], :n0].T for j in range(1, m)], axis=1
        )
    sq0 = sq_exact[0][:n0]
    sq_cols0 = jnp.concatenate([sq0] + [sq_dec[j][: L[j]] for j in range(1, m)])
    y0 = jnp.concatenate([shards.y[j, : L[j]] for j in range(m)], axis=0)
    X0 = jnp.concatenate(
        [shards.X[0, :n0]] + [wire_state.decoded[j, : L[j]] for j in range(1, m)],
        axis=0,
    )

    def gram0(p):
        G_KK = kernel_from_inner(kernel, p, ip_KK0, sq0, sq0)
        G_KN = kernel_from_inner(kernel, p, ip_KN0, sq0, sq_cols0)
        return nystrom_complete(G_KK, G_KN)

    trained = train_gp(
        X0, y0, kernel=kernel, params=params, steps=cfg.steps, lr=cfg.lr,
        gram_override=gram0, impl=cfg.train_impl,
    )
    p = trained.params
    noise = jnp.exp(p.log_noise)

    # ---- factorize every machine's local predictive under ONE vmap ----
    mask_flat = shards.mask.reshape(-1)  # column layout is block j at slot j
    y_flat = (shards.y * shards.mask).reshape(-1)

    fused_serve = getattr(cfg, "serve_epilogue", "fused") == "fused"
    if cfg.impl == "mesh":
        # one shard_map program: device i assembles & factorizes ITS view;
        # the factor set lives sharded along the mesh axis
        msh = mesh.machine_mesh(m)
        factors = mesh._mesh_broadcast_factor_fn(m, kernel, fused_serve)(
            shards.X, shards.mask, wire_state.decoded, sq_dec, mask_flat,
            y_flat, p,
        )
        data = mesh._shard_machine_axis(
            {"Xs": shards.X, "mask": shards.mask,
             "sq_exact": sq_exact, "sq_dec": sq_dec},
            msh,
        )
        return FittedProtocol(
            params=p, y=y_flat, factors=factors, data=data, wire=wire_state,
            stream=StreamState.make(
                shards.lengths, y_flat.shape[0], int(wire), int(payload),
                int(run.integrity_bits), int(run.rows_demoted),
            ),
            protocol="broadcast", kernel=kernel, gram_mode=gram_mode,
            fuse=fuse, gram_backend=gram_backend, n_center=0,
            fit_lengths=shards.lengths, block_order=None,
            bits_per_sample=bits, max_bits=cfg.max_bits, impl="mesh",
            scheme=cfg.scheme, config=cfg,
        )

    if gram_mode == "nystrom":

        def build(i):
            mask_i = shards.mask[i]
            # own (exact) block is the Nyström center; peers are reconstructions
            ip_KK = A[i]
            blocks = B[:, i].transpose(0, 2, 1)  # block j: Xs_i X̂_j^T (n, n)
            blocks = blocks.at[i].set(ip_KK)  # own block exact
            ip_KN = jnp.moveaxis(blocks, 0, 1).reshape(n_pad, m * n_pad)
            sq_cols = sq_dec.at[i].set(sq_exact[i]).reshape(-1)
            G_KK = _mask_gram(
                kernel_from_inner(kernel, p, ip_KK, sq_exact[i], sq_exact[i]), mask_i
            )
            G_KN = kernel_from_inner(kernel, p, ip_KN, sq_exact[i], sq_cols) * (
                mask_i[:, None] * mask_flat[None, :]
            )
            fac = nystrom_factors(G_KK, G_KN, y_flat, noise)
            if fused_serve:
                fac.update(nystrom_serve_cache(fac))
            return fac

        factors = jax.vmap(build)(jnp.arange(m))
    elif gram_mode == "direct":
        D = _decoded_inner_products(shards, wire_state, gram_backend, pack_bits)

        def build(i):
            mask_i = shards.mask[i]
            own_cols = B[:, i].transpose(0, 2, 1)  # block j: Xs_i X̂_j^T
            own_cols = own_cols.at[i].set(A[i])
            row_i = jnp.moveaxis(own_cols, 0, 1).reshape(n_pad, m * n_pad)
            # non-own rows: decoded-vs-decoded, with column block i swapped to
            # decoded-vs-exact (B[r, i])
            rows = D.reshape(m, n_pad, m, n_pad).at[:, :, i, :].set(B[:, i])
            rows = rows.reshape(m, n_pad, m * n_pad).at[i].set(row_i)
            ip_NN = rows.reshape(m * n_pad, m * n_pad)
            sq_cols = sq_dec.at[i].set(sq_exact[i]).reshape(-1)
            G = _mask_gram(
                kernel_from_inner(kernel, p, ip_NN, sq_cols, sq_cols), mask_flat
            )
            return posterior_factors(G, y_flat, noise)

        factors = jax.vmap(build)(jnp.arange(m))
    else:
        raise ValueError(f"unknown broadcast gram mode {gram_mode!r}")

    data = {
        "Xs": shards.X, "mask": shards.mask,
        "sq_exact": sq_exact, "sq_dec": sq_dec,
    }
    data.update(extras)
    return FittedProtocol(
        params=p,
        y=y_flat,
        factors=factors,
        data=data,
        wire=wire_state,
        stream=StreamState.make(
            shards.lengths, y_flat.shape[0], int(wire), int(payload),
            int(run.integrity_bits), int(run.rows_demoted),
        ),
        protocol="broadcast",
        kernel=kernel,
        gram_mode=gram_mode,
        fuse=fuse,
        gram_backend=gram_backend,
        n_center=0,
        fit_lengths=shards.lengths,
        block_order=None,
        bits_per_sample=bits,
        max_bits=cfg.max_bits,
        impl=cfg.impl,
        scheme=cfg.scheme,
        config=cfg,
    )


def _predict_broadcast_experts(art, X_star, sq_star, g_ss, noise):
    p = art.params
    Xs, mask = art.data["Xs"], art.data["mask"]
    sq_exact = art.data["sq_exact"]
    m, n_pad, _ = Xs.shape
    C = _star_exact_products(Xs, X_star, art.gram_backend)
    if art.gram_mode == "nystrom":

        cached = "Ainv" in art.factors  # static: key presence decides the path

        def apply_i(fac, Ci, sqi, mi):
            G_sK = kernel_from_inner(art.kernel, p, Ci, sq_star, sqi) * mi[None, :]
            if cached:
                return nystrom_apply_cached(fac, G_sK, g_ss, noise)
            return nystrom_apply(fac, G_sK, g_ss, noise)

        return jax.vmap(apply_i)(art.factors, C, sq_exact, mask)
    # direct views
    from ...comm.accounting import row_bits

    sq_dec = art.data["sq_dec"]
    mask_flat = mask.reshape(-1)
    E = _star_decoded_products(
        art.wire, X_star, art.gram_backend,
        row_bits(art.bits_per_sample, Xs.shape[-1], art.max_bits), mask,
    )

    def apply_i(i, fac):
        star_cols = E.at[i].set(C[i])  # (m, t, n_pad); block i exact
        ip_sN = jnp.moveaxis(star_cols, 0, 1).reshape(-1, m * n_pad)
        sq_cols = sq_dec.at[i].set(sq_exact[i]).reshape(-1)
        G_sn = kernel_from_inner(art.kernel, p, ip_sN, sq_star, sq_cols) * (
            mask_flat[None, :]
        )
        return posterior_apply(fac, G_sn, g_ss)

    return jax.vmap(apply_i)(jnp.arange(m), art.factors)


def _uses_fused_epilogue(art, spec) -> bool:
    """Static predicate: this artifact serves through the one-launch fused
    epilogue (pallas backend, cached Nyström serve operands, a fusion that
    exposes moment rows).  Shared with :mod:`repro.core.fleet`, which batches
    the same path over a leading tenant axis."""
    return (
        art.gram_backend == "pallas"
        and art.gram_mode == "nystrom"
        and "Ainv" in art.factors
        and spec.moments is not None
        and spec.finalize is not None
    )


def _epilogue_projector(art, noise=None):
    """The woodbury quad-form projector ``P = (U - U M^{-1} U)/s2`` per
    expert — the QUERY-INDEPENDENT half of the fused serve epilogue's
    operand set (it depends only on the artifact's cached factors and
    noise).  The single-tenant serve path rebuilds it inside each predict;
    the fleet stack (:mod:`repro.core.fleet`) precomputes it ONCE per
    admitted tenant and keeps it device-resident, amortizing the per-expert
    ``cho_solve`` chain across every query the tenant serves."""
    if noise is None:
        noise = jnp.exp(art.params.log_noise)
    f = art.factors
    s2 = noise + DEFAULT_JITTER
    return jax.vmap(
        lambda U, Lm: (U - U @ jax.scipy.linalg.cho_solve((Lm, True), U)) / s2
    )(f["U"], f["L_M"])


def _fused_epilogue_operands(art, X_star, sq_star, g_ss, noise, avail,
                             P=None):
    """Build the ``kernels.epilogue`` operand set ``(G, Ainv, P, walpha,
    prior, w)`` for one artifact's fused serve: the masked cross-gram tiles,
    the cached inverse, the woodbury quad-form projector, and the
    availability weights.  Split out of :func:`_predict_broadcast_fused` so
    the fleet path (:mod:`repro.core.fleet`) can vmap THIS over a stacked
    tenant axis and hand the batch to the tenant-batched epilogue kernel;
    ``P`` accepts that path's precomputed :func:`_epilogue_projector` (None
    = build it here, as the single-tenant serve does)."""
    p = art.params
    f = art.factors
    Xs, mask = art.data["Xs"], art.data["mask"]
    sq_exact = art.data["sq_exact"]
    m = Xs.shape[0]
    C = _star_exact_products(Xs, X_star, art.gram_backend)
    G = jax.vmap(
        lambda Ci, sqi, mi: kernel_from_inner(art.kernel, p, Ci, sq_star, sqi)
        * mi[None, :]
    )(C, sq_exact, mask)
    if P is None:
        P = _epilogue_projector(art, noise)
    w = jnp.ones((m,), jnp.float32) if avail is None else jnp.asarray(
        avail, jnp.float32
    )
    prior = g_ss + noise
    return G, f["Ainv"], P, f["walpha"], prior, w


def _predict_broadcast_fused(art, spec, X_star, sq_star, g_ss, noise, avail):
    """One-launch serve epilogue (pallas backend + cached Nyström factors):
    the per-expert cached apply AND the fusion moment rows run as a single
    ``kernels.epilogue`` call; only the method's cheap ``finalize`` remains
    outside.  Algebraically equal to experts + ``spec.fuse`` (asserted by
    tests/test_kernel_runtime.py for every fusion method)."""
    from ...kernels.epilogue.ops import epilogue_moments

    m = art.data["Xs"].shape[0]
    G, Ainv, P, walpha, prior, w = _fused_epilogue_operands(
        art, X_star, sq_star, g_ss, noise, avail
    )
    S = epilogue_moments(G, Ainv, P, walpha, g_ss, prior, w, fuse=art.fuse)
    return spec.finalize(S, m, prior)


def _predict_broadcast(art: FittedProtocol, X_star, sq_star, g_ss, noise,
                       avail=None):
    spec = FUSIONS.get(art.fuse)
    if _uses_fused_epilogue(art, spec):
        return _predict_broadcast_fused(art, spec, X_star, sq_star, g_ss,
                                        noise, avail)
    mus, s2s = _predict_broadcast_experts(art, X_star, sq_star, g_ss, noise)
    if avail is None:  # healthy fast path; legacy 3-arg fusions still plug in
        return spec.fuse(mus, s2s, g_ss + noise)
    # degraded serving: the fusion renormalizes over surviving machines
    return spec.fuse(mus, s2s, g_ss + noise, avail)


@jax.jit
def _update_broadcast_jit(art, X_new, y_new, j, pre):
    """Device-resident §5.2 streaming append (batched impl): machine ``j``
    broadcast its codes once — every peer i sees X̂_new, machine j itself
    keeps the exact points — and the new points extend every view's COLUMNS
    in place at the occupied-column cursor (the rank-n_pad Nyström bases
    stay fixed).  ``j`` is traced: one cache entry serves every machine."""
    _UPDATE_TRACES["broadcast"] += 1  # runs at trace time only
    p = art.params
    noise = jnp.exp(p.log_noise)
    m = len(art.fit_lengths)
    n_new = X_new.shape[0]
    if pre is None:
        decoded, w_add, p_add, i_add = SCHEMES.get(art.scheme).reencode_traced(
            art, j, X_new
        )
        d_add = jnp.int32(0)
    else:  # host-precomputed batch (vq channel or faulted transmission)
        decoded, w_add, p_add, i_add, d_add = pre
    reps = jnp.broadcast_to(decoded, (m, n_new, decoded.shape[1]))
    own = jnp.arange(m)[:, None, None] == j  # traced j: where, not .at[j]
    reps = jnp.where(own, X_new[None], reps)
    sq_new = jnp.sum(reps**2, -1)  # (m, n_new)
    ip_new = jnp.einsum("ind,ied->ine", art.data["Xs"], reps)  # (m, n_pad, n_new)
    pos = art.stream.cols
    y2 = jax.lax.dynamic_update_slice(art.y, y_new, (pos,))
    s2 = noise + DEFAULT_JITTER

    def upd(fac, ipn, sqi, sqn, mi):
        G_KN_new = kernel_from_inner(art.kernel, p, ipn, sqi, sqn) * mi[:, None]
        W_new = jax.scipy.linalg.solve_triangular(fac["L_KK"], G_KN_new, lower=True)
        W2 = jax.lax.dynamic_update_slice(fac["W"], W_new, (0, pos))
        L_M2 = chol_update_rank(fac["L_M"], W_new)
        out = {
            "L_KK": fac["L_KK"], "W": W2, "L_M": L_M2,
            "alpha": nystrom_kinv(W2, L_M2, s2, y2),
        }
        if "U" in fac:  # fused-serve cache rides along: U grows by the new
            # columns' outer product (exact — appended W columns), walpha
            # re-contracts against the updated alpha, Ainv never changes
            out["Ainv"] = fac["Ainv"]
            out["U"] = fac["U"] + W_new @ W_new.T
            out["walpha"] = W2 @ out["alpha"]
        return out

    factors = jax.vmap(upd)(
        art.factors, ip_new, art.data["sq_exact"], sq_new, art.data["mask"]
    )
    s = art.stream
    stream = StreamState(
        counts=s.counts.at[j].add(n_new), cols=s.cols + n_new,
        wire_bits=s.wire_bits + w_add, payload_bits=s.payload_bits + p_add,
        integrity_bits=s.integrity_bits + i_add,
        rows_demoted=s.rows_demoted + d_add,
    )
    return dataclasses.replace(art, y=y2, factors=factors, stream=stream)


def _update_broadcast(art: FittedProtocol, X_new, y_new, j, pre=None):
    if art.gram_mode != "nystrom":
        raise NotImplementedError(
            'streaming update of broadcast artifacts supports gram_mode='
            '"nystrom" only'
        )
    if art.impl == "mesh":
        # the sharded factors grow IN PLACE on their devices: re-encode and
        # rank-k growth run as one shard_map program, no host pull
        return mesh._update_mesh_jit(art, X_new, y_new, base._machine_index(j), pre)
    return _update_broadcast_jit(art, X_new, y_new, base._machine_index(j), pre)


register_protocol(ProtocolSpec(
    name="broadcast",
    fit=_fit_broadcast,
    predict=_predict_broadcast,
    update=_update_broadcast,
    fit_host=fit_broadcast_host,
))


# --------------------------------------------------------------------------
# the program contract (repro.analysis.check_contracts enforces it); the
# impl="mesh" substrate registers its own override in mesh.py
# --------------------------------------------------------------------------
from ...analysis.contracts import (
    CollectiveBudget,
    Contract,
    LedgerAccounting,
    NoHostCallbacks,
    NoShardingLeak,
    forbid_primitives,
    register_contract,
)

# §5.2 batched serving: m machines are a vmap axis inside one program —
# nothing may factorize, synchronize, or stay sharded.
register_contract("broadcast", "predict", Contract(
    name="broadcast-serve",
    rules=(
        forbid_primitives(),
        NoHostCallbacks(),
        CollectiveBudget(max_count=0),
        NoShardingLeak(max_devices=1),
        LedgerAccounting(),
    ),
))
register_contract("broadcast", "update", Contract(
    name="broadcast-update",
    rules=(NoShardingLeak(max_devices=1), LedgerAccounting()),
))
