"""Zero-rate baselines: PoE / gPoE / BCM / rBCM as a protocol.

Each machine trains on its local data only (the block-diagonal-gram
assumption); predictions are combined by a registered fusion rule (the PoE
family).  Nothing crosses the wire, so the ledger is 0 — this is the zero
point of the paper's rate/distortion axis the quantized protocols beat.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..gp import (
    GPParams,
    gram_fn,
    kernel_from_inner,
    posterior_factors,
    posterior_apply,
    posterior_from_gram,
    train_gp,
)
from ..linalg_safe import DEFAULT_JITTER
from ..nystrom import chol_append_at
from ..registry import FUSIONS, ProtocolSpec, register_protocol
from . import base, mesh
from .base import (
    FittedProtocol,
    StreamState,
    pad_parts,
    _mask_gram,
    _UPDATE_TRACES,
)

__all__ = ["poe_baseline", "HostPoEGP", "fit_poe_host"]


# --------------------------------------------------------------------------
# the serial host oracle
# --------------------------------------------------------------------------


@dataclasses.dataclass
class HostPoEGP:
    """The ``impl="host"`` oracle: shared hypers trained on machine 0's local
    data, one dense solve per expert at predict time (m serial dispatches)."""

    kernel: str
    params: GPParams
    parts: list
    method: str

    def predict(self, X_star, available=None):
        p = self.params
        k = gram_fn(self.kernel)
        noise = jnp.exp(p.log_noise)
        X_star = jnp.asarray(X_star, jnp.float32)

        @jax.jit
        def expert(Xj, yj):
            G = k(p, Xj)
            G_sn = k(p, X_star, Xj)
            g_ss = jnp.diagonal(k(p, X_star, X_star))
            return posterior_from_gram(G, G_sn, g_ss, yj, noise)

        mus, s2s = zip(*[expert(Xj, yj) for Xj, yj in self.parts])
        mus, s2s = jnp.stack(mus), jnp.stack(s2s)
        prior = jnp.diagonal(k(p, X_star, X_star)) + noise
        spec = FUSIONS.get(self.method)
        if available is None:  # legacy 3-arg fusions keep the healthy path
            return spec.fuse(mus, s2s, prior)
        w = (jnp.asarray(available, jnp.float32) > 0).astype(jnp.float32)
        return spec.fuse(mus, s2s, prior, w)


def fit_poe_host(parts, cfg, params=None) -> HostPoEGP:
    # zero-rate: nothing crosses the wire, so only fit-time data faults apply
    parts, _ = base._apply_fit_faults(parts, cfg)
    # shared hypers trained on machine 0's local data (standard practice:
    # the PoE family shares one hyperparameter set across experts)
    trained = train_gp(
        parts[0][0], parts[0][1], kernel=cfg.kernel, params=params,
        steps=cfg.steps, lr=cfg.lr, impl=cfg.train_impl,
    )
    return HostPoEGP(
        kernel=cfg.kernel, params=trained.params, parts=list(parts),
        method=cfg.fusion,
    )


def poe_baseline(
    parts,
    X_star,
    kernel: str = "se",
    method: str = "rbcm",
    steps: int = 150,
    lr: float = 0.05,
    impl: str = "batched",
    gram_backend: str = "xla",
    train_impl: str = "scan",
):
    """Zero-rate baselines: each machine trains on its local data only (the
    block-diagonal-gram assumption), predictions combined by PoE/BCM/rBCM.

    ``impl="batched"`` (default) is a thin serving composition:
    ``fit(parts, 0, protocol="poe", method=...)`` factorizes all m experts
    under one vmapped Cholesky on padded shards, and :func:`~.base.predict`
    combines the per-expert posteriors.  Call ``fit`` (or the
    ``DistributedGP`` facade) directly to keep the artifact."""
    if impl == "host":
        if gram_backend == "pallas":
            raise ValueError('gram_backend="pallas" requires impl="batched"')
        from ..config import DGPConfig

        cfg = DGPConfig(
            protocol="poe", kernel=kernel, fusion=method, impl="host",
            bits_per_sample=0, steps=int(steps), lr=float(lr),
            train_impl=train_impl,
        )
        model = fit_poe_host(parts, cfg)
        mu, s2 = model.predict(X_star)
        return mu, s2, model.params

    art = base.fit(
        parts, 0, protocol="poe", kernel=kernel, steps=steps, lr=lr,
        method=method, gram_backend=gram_backend, train_impl=train_impl,
        impl=impl,
    )
    mu, s2 = base.predict(art, X_star)
    return mu, s2, art.params


# --------------------------------------------------------------------------
# fit / predict / update (the registered protocol triple)
# --------------------------------------------------------------------------


def _fit_poe(parts, cfg, params=None) -> FittedProtocol:
    # zero-rate: nothing crosses the wire, so only fit-time data faults apply
    # (flip_rate has no packed plane to corrupt here and is a no-op)
    parts, _ = base._apply_fit_faults(parts, cfg)
    # shared hypers trained on machine 0's local data (standard practice: the
    # PoE family shares one hyperparameter set across experts)
    kernel, method, gram_backend = cfg.kernel, cfg.fusion, cfg.gram_backend
    trained = train_gp(
        parts[0][0], parts[0][1], kernel=kernel, params=params,
        steps=cfg.steps, lr=cfg.lr, impl=cfg.train_impl,
    )
    p = trained.params
    noise = jnp.exp(p.log_noise)
    shards = pad_parts(parts)
    sq_exact = jnp.sum(shards.X**2, -1)
    m = len(parts)
    if cfg.impl == "mesh":
        if gram_backend != "xla":
            raise NotImplementedError(
                'impl="mesh" assembles grams device-local (gram_backend="xla")'
            )
        msh = mesh.machine_mesh(m)
        factors = mesh._mesh_poe_factor_fn(m, kernel)(
            shards.X, shards.y, shards.mask, p
        )
        data = mesh._shard_machine_axis(
            {"Xs": shards.X, "mask": shards.mask, "sq_exact": sq_exact}, msh
        )
        return FittedProtocol(
            params=p, y=shards.y * shards.mask, factors=factors, data=data,
            wire=None,
            stream=StreamState.make(shards.lengths, shards.y.shape[-1]),
            protocol="poe", kernel=kernel, gram_mode="dense",
            fuse=method, gram_backend=gram_backend, n_center=0,
            fit_lengths=shards.lengths, block_order=None, bits_per_sample=0,
            max_bits=0, impl="mesh", scheme=cfg.scheme,
            config=cfg,
        )
    if gram_backend == "pallas":
        from ...kernels.gram.ops import gram as gram_kernel

        A = jax.vmap(lambda a: gram_kernel(a, a))(shards.X)
    else:
        A = jnp.einsum("ind,imd->inm", shards.X, shards.X)

    def build(ipA, sqj, yj, mask_j):
        G = _mask_gram(kernel_from_inner(kernel, p, ipA, sqj, sqj), mask_j)
        return posterior_factors(G, yj * mask_j, noise)

    factors = jax.vmap(build)(A, sq_exact, shards.y, shards.mask)
    return FittedProtocol(
        params=p,
        y=shards.y * shards.mask,
        factors=factors,
        data={"Xs": shards.X, "mask": shards.mask, "sq_exact": sq_exact},
        wire=None,
        stream=StreamState.make(shards.lengths, shards.y.shape[-1]),
        protocol="poe",
        kernel=kernel,
        gram_mode="dense",
        fuse=method,
        gram_backend=gram_backend,
        n_center=0,
        fit_lengths=shards.lengths,
        block_order=None,
        bits_per_sample=0,
        max_bits=0,
        impl=cfg.impl,
        scheme=cfg.scheme,
        config=cfg,
    )


def _predict_poe_experts(art, X_star, sq_star, g_ss):
    from .broadcast import _star_exact_products

    p = art.params
    Xs, mask = art.data["Xs"], art.data["mask"]
    sq_exact = art.data["sq_exact"]
    # streamed points live IN the capacity-padded expert buffers (the mask
    # zeroes non-own and padded columns), so one uniform apply serves both
    # fresh fits and updated artifacts with no shape-changing branches
    C = _star_exact_products(Xs, X_star, art.gram_backend)

    def apply_j(fac, Cj, sqj, mj):
        G_sn = kernel_from_inner(art.kernel, p, Cj, sq_star, sqj) * mj[None, :]
        return posterior_apply(fac, G_sn, g_ss)

    return jax.vmap(apply_j)(art.factors, C, sq_exact, mask)


def _predict_poe(art: FittedProtocol, X_star, sq_star, g_ss, noise, avail=None):
    mus, s2s = _predict_poe_experts(art, X_star, sq_star, g_ss)
    spec = FUSIONS.get(art.fuse)
    if avail is None:  # healthy fast path; legacy 3-arg fusions still plug in
        return spec.fuse(mus, s2s, g_ss + noise)
    # degraded serving: the combiner renormalizes over surviving experts
    return spec.fuse(mus, s2s, g_ss + noise, avail)


@jax.jit
def _update_poe_jit(art, X_new, y_new, j, pre):
    """Device-resident zero-rate streaming append (batched impl): the points
    are machine ``j``'s own exact data, written into EVERY expert's
    capacity-padded buffer at the shared occupied-column cursor but valid
    (mask 1) only on expert j — non-owners get decoupled unit rows in their
    bordered factor, exactly like fit-time padding.  ``j`` is traced."""
    _UPDATE_TRACES["poe"] += 1  # runs at trace time only
    del pre  # zero-rate: nothing crosses the wire, nothing to precompute
    p = art.params
    noise = jnp.exp(p.log_noise)
    m = len(art.fit_lengths)
    n_new = X_new.shape[0]
    k = gram_fn(art.kernel)
    s2 = noise + DEFAULT_JITTER
    Xs, mask = art.data["Xs"], art.data["mask"]
    pos = art.stream.cols
    zero = jnp.int32(0)
    valid = (jnp.arange(m)[:, None] == j).astype(jnp.float32)  # (m, 1)
    valid = jnp.broadcast_to(valid, (m, n_new))
    sq_new = jnp.sum(X_new**2, -1)
    y2 = jax.lax.dynamic_update_slice(
        art.y, valid * y_new[None, :], (zero, pos)
    )
    Xs2 = jax.lax.dynamic_update_slice(
        Xs, jnp.broadcast_to(X_new[None], (m,) + X_new.shape), (zero, pos, zero)
    )
    mask2 = jax.lax.dynamic_update_slice(mask, valid, (zero, pos))
    sq2 = jax.lax.dynamic_update_slice(
        art.data["sq_exact"], jnp.broadcast_to(sq_new[None], (m, n_new)),
        (zero, pos),
    )

    def upd(fac, Xi2, mi, vi, yi2):
        # OLD mask: zero at the cursor and beyond, so the cross block G_on
        # keeps chol_append_at's zero-rows-at-padded-slots contract
        G_on = k(p, Xi2, X_new) * (mi[:, None] * vi[None, :])
        G_nn = _mask_gram(k(p, X_new), vi) + s2 * jnp.eye(n_new)
        L2 = chol_append_at(fac["L"], G_on, G_nn, pos)
        return {"L": L2, "alpha": jax.scipy.linalg.cho_solve((L2, True), yi2)}

    factors = jax.vmap(upd)(art.factors, Xs2, mask, valid, y2)
    data = dict(art.data)
    data["Xs"], data["mask"], data["sq_exact"] = Xs2, mask2, sq2
    s = art.stream
    stream = StreamState(
        counts=s.counts.at[j].add(n_new), cols=s.cols + n_new,
        wire_bits=s.wire_bits, payload_bits=s.payload_bits,
        integrity_bits=s.integrity_bits, rows_demoted=s.rows_demoted,
    )
    return dataclasses.replace(art, y=y2, factors=factors, data=data,
                               stream=stream)


def _update_poe(art: FittedProtocol, X_new, y_new, j, pre=None):
    if art.impl == "mesh":
        # sharded expert buffers grow in place on their devices (shard_map)
        return mesh._update_mesh_jit(art, X_new, y_new, base._machine_index(j), pre)
    return _update_poe_jit(art, X_new, y_new, base._machine_index(j), pre)


register_protocol(ProtocolSpec(
    name="poe",
    fit=_fit_poe,
    predict=_predict_poe,
    update=_update_poe,
    fit_host=fit_poe_host,
))


# --------------------------------------------------------------------------
# the program contract (repro.analysis.check_contracts enforces it); the
# impl="mesh" substrate registers its own override in mesh.py
# --------------------------------------------------------------------------
from ...analysis.contracts import (
    CollectiveBudget,
    Contract,
    LedgerAccounting,
    NoHostCallbacks,
    NoShardingLeak,
    forbid_primitives,
    register_contract,
)

# zero-rate baseline: experts are a vmap axis; the wire ledger is 0 and the
# serve program must be as silent as the wire.
register_contract("poe", "predict", Contract(
    name="poe-serve",
    rules=(
        forbid_primitives(),
        NoHostCallbacks(),
        CollectiveBudget(max_count=0),
        NoShardingLeak(max_devices=1),
        LedgerAccounting(),
    ),
))
register_contract("poe", "update", Contract(
    name="poe-update",
    rules=(NoShardingLeak(max_devices=1), LedgerAccounting()),
))
