"""Zero-rate baselines: PoE / gPoE / BCM / rBCM as a protocol.

Each machine trains on its local data only (the block-diagonal-gram
assumption); predictions are combined by a registered fusion rule (the PoE
family).  Nothing crosses the wire, so the ledger is 0 — this is the zero
point of the paper's rate/distortion axis the quantized protocols beat.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..gp import (
    GPParams,
    gram_fn,
    kernel_from_inner,
    posterior_factors,
    posterior_apply,
    posterior_from_gram,
    train_gp,
)
from ..nystrom import chol_append, _JITTER
from ..registry import FUSIONS, ProtocolSpec, register_protocol
from . import base, mesh
from .base import FittedProtocol, pad_parts, _bump_length, _mask_gram

__all__ = ["poe_baseline", "HostPoEGP", "fit_poe_host"]


# --------------------------------------------------------------------------
# the serial host oracle
# --------------------------------------------------------------------------


@dataclasses.dataclass
class HostPoEGP:
    """The ``impl="host"`` oracle: shared hypers trained on machine 0's local
    data, one dense solve per expert at predict time (m serial dispatches)."""

    kernel: str
    params: GPParams
    parts: list
    method: str

    def predict(self, X_star, available=None):
        p = self.params
        k = gram_fn(self.kernel)
        noise = jnp.exp(p.log_noise)
        X_star = jnp.asarray(X_star, jnp.float32)

        @jax.jit
        def expert(Xj, yj):
            G = k(p, Xj)
            G_sn = k(p, X_star, Xj)
            g_ss = jnp.diagonal(k(p, X_star, X_star))
            return posterior_from_gram(G, G_sn, g_ss, yj, noise)

        mus, s2s = zip(*[expert(Xj, yj) for Xj, yj in self.parts])
        mus, s2s = jnp.stack(mus), jnp.stack(s2s)
        prior = jnp.diagonal(k(p, X_star, X_star)) + noise
        spec = FUSIONS.get(self.method)
        if available is None:  # legacy 3-arg fusions keep the healthy path
            return spec.fuse(mus, s2s, prior)
        w = (jnp.asarray(available, jnp.float32) > 0).astype(jnp.float32)
        return spec.fuse(mus, s2s, prior, w)


def fit_poe_host(parts, cfg, params=None) -> HostPoEGP:
    # zero-rate: nothing crosses the wire, so only fit-time data faults apply
    parts, _ = base._apply_fit_faults(parts, cfg)
    # shared hypers trained on machine 0's local data (standard practice:
    # the PoE family shares one hyperparameter set across experts)
    trained = train_gp(
        parts[0][0], parts[0][1], kernel=cfg.kernel, params=params,
        steps=cfg.steps, lr=cfg.lr, impl=cfg.train_impl,
    )
    return HostPoEGP(
        kernel=cfg.kernel, params=trained.params, parts=list(parts),
        method=cfg.fusion,
    )


def poe_baseline(
    parts,
    X_star,
    kernel: str = "se",
    method: str = "rbcm",
    steps: int = 150,
    lr: float = 0.05,
    impl: str = "batched",
    gram_backend: str = "xla",
    train_impl: str = "scan",
):
    """Zero-rate baselines: each machine trains on its local data only (the
    block-diagonal-gram assumption), predictions combined by PoE/BCM/rBCM.

    ``impl="batched"`` (default) is a thin serving composition:
    ``fit(parts, 0, protocol="poe", method=...)`` factorizes all m experts
    under one vmapped Cholesky on padded shards, and :func:`~.base.predict`
    combines the per-expert posteriors.  Call ``fit`` (or the
    ``DistributedGP`` facade) directly to keep the artifact."""
    if impl == "host":
        if gram_backend == "pallas":
            raise ValueError('gram_backend="pallas" requires impl="batched"')
        from ..config import DGPConfig

        cfg = DGPConfig(
            protocol="poe", kernel=kernel, fusion=method, impl="host",
            bits_per_sample=0, steps=int(steps), lr=float(lr),
            train_impl=train_impl,
        )
        model = fit_poe_host(parts, cfg)
        mu, s2 = model.predict(X_star)
        return mu, s2, model.params

    art = base.fit(
        parts, 0, protocol="poe", kernel=kernel, steps=steps, lr=lr,
        method=method, gram_backend=gram_backend, train_impl=train_impl,
        impl=impl,
    )
    mu, s2 = base.predict(art, X_star)
    return mu, s2, art.params


# --------------------------------------------------------------------------
# fit / predict / update (the registered protocol triple)
# --------------------------------------------------------------------------


def _fit_poe(parts, cfg, params=None) -> FittedProtocol:
    # zero-rate: nothing crosses the wire, so only fit-time data faults apply
    # (flip_rate has no packed plane to corrupt here and is a no-op)
    parts, _ = base._apply_fit_faults(parts, cfg)
    # shared hypers trained on machine 0's local data (standard practice: the
    # PoE family shares one hyperparameter set across experts)
    kernel, method, gram_backend = cfg.kernel, cfg.fusion, cfg.gram_backend
    trained = train_gp(
        parts[0][0], parts[0][1], kernel=kernel, params=params,
        steps=cfg.steps, lr=cfg.lr, impl=cfg.train_impl,
    )
    p = trained.params
    noise = jnp.exp(p.log_noise)
    shards = pad_parts(parts)
    sq_exact = jnp.sum(shards.X**2, -1)
    m = len(parts)
    if cfg.impl == "mesh":
        if gram_backend != "xla":
            raise NotImplementedError(
                'impl="mesh" assembles grams device-local (gram_backend="xla")'
            )
        msh = mesh.machine_mesh(m)
        factors = mesh._mesh_poe_factor_fn(m, kernel)(
            shards.X, shards.y, shards.mask, p
        )
        data = mesh._shard_machine_axis(
            {"Xs": shards.X, "mask": shards.mask, "sq_exact": sq_exact}, msh
        )
        return FittedProtocol(
            params=p, y=shards.y * shards.mask, factors=factors, data=data,
            wire=None, protocol="poe", kernel=kernel, gram_mode="dense",
            fuse=method, gram_backend=gram_backend, n_center=0,
            lengths=shards.lengths, block_order=None, bits_per_sample=0,
            max_bits=0, wire_bits=0, impl="mesh", scheme=cfg.scheme,
            config=cfg,
        )
    if gram_backend == "pallas":
        from ...kernels.gram.ops import gram as gram_kernel

        A = jax.vmap(lambda a: gram_kernel(a, a))(shards.X)
    else:
        A = jnp.einsum("ind,imd->inm", shards.X, shards.X)

    def build(ipA, sqj, yj, mask_j):
        G = _mask_gram(kernel_from_inner(kernel, p, ipA, sqj, sqj), mask_j)
        return posterior_factors(G, yj * mask_j, noise)

    factors = jax.vmap(build)(A, sq_exact, shards.y, shards.mask)
    return FittedProtocol(
        params=p,
        y=shards.y * shards.mask,
        factors=factors,
        data={"Xs": shards.X, "mask": shards.mask, "sq_exact": sq_exact},
        wire=None,
        protocol="poe",
        kernel=kernel,
        gram_mode="dense",
        fuse=method,
        gram_backend=gram_backend,
        n_center=0,
        lengths=shards.lengths,
        block_order=None,
        bits_per_sample=0,
        max_bits=0,
        wire_bits=0,
        impl=cfg.impl,
        scheme=cfg.scheme,
        config=cfg,
    )


def _predict_poe_experts(art, X_star, sq_star, g_ss):
    from .broadcast import _star_exact_products

    p = art.params
    Xs, mask = art.data["Xs"], art.data["mask"]
    sq_exact = art.data["sq_exact"]
    C = _star_exact_products(Xs, X_star, art.gram_backend)
    has_extra = "X_extra" in art.data
    if has_extra:
        Xe = art.data["X_extra"]
        C_e = X_star @ Xe.T  # (t, e); streamed extras ride the xla path
        sq_e = jnp.sum(Xe**2, -1)
        G_e = kernel_from_inner(art.kernel, p, C_e, sq_star, sq_e)

    def apply_j(fac, Cj, sqj, mj, emj):
        G_sn = kernel_from_inner(art.kernel, p, Cj, sq_star, sqj) * mj[None, :]
        if has_extra:
            G_sn = jnp.concatenate([G_sn, G_e * emj[None, :]], axis=1)
        return posterior_apply(fac, G_sn, g_ss)

    em = art.data["extra_mask"] if has_extra else mask[:, :0]
    return jax.vmap(apply_j)(art.factors, C, sq_exact, mask, em)


def _predict_poe(art: FittedProtocol, X_star, sq_star, g_ss, noise, avail=None):
    mus, s2s = _predict_poe_experts(art, X_star, sq_star, g_ss)
    spec = FUSIONS.get(art.fuse)
    if avail is None:  # healthy fast path; legacy 3-arg fusions still plug in
        return spec.fuse(mus, s2s, g_ss + noise)
    # degraded serving: the combiner renormalizes over surviving experts
    return spec.fuse(mus, s2s, g_ss + noise, avail)


def _update_poe(art: FittedProtocol, X_new, y_new, j):
    p = art.params
    noise = jnp.exp(p.log_noise)
    m = len(art.lengths)
    n_new = X_new.shape[0]
    k = gram_fn(art.kernel)
    s2 = noise + _JITTER
    Xs, mask = art.data["Xs"], art.data["mask"]
    # zero-rate: the points are machine j's own exact data; other experts
    # never see them (valid only on row j), matching the fit-time masking
    valid = jnp.zeros((m, n_new), jnp.float32).at[j].set(1.0)
    Xe_old = art.data.get("X_extra")
    em_old = art.data.get("extra_mask")
    ye_old = art.data.get("y_extra")

    def upd(fac, Xi, sqi, mi, vi, emi, yi, yei):
        G_on = k(p, Xi, X_new) * (mi[:, None] * vi[None, :])
        if Xe_old is not None:
            G_on_e = k(p, Xe_old, X_new) * (emi[:, None] * vi[None, :])
            G_on = jnp.concatenate([G_on, G_on_e], axis=0)
        G_nn = _mask_gram(k(p, X_new), vi) + s2 * jnp.eye(n_new)
        L2 = chol_append(fac["L"], G_on, G_nn)
        y_cols = jnp.concatenate(
            [yi] + ([yei * emi] if Xe_old is not None else []) + [y_new * vi]
        )
        return {"L": L2, "alpha": jax.scipy.linalg.cho_solve((L2, True), y_cols)}

    em_arg = em_old if em_old is not None else mask[:, :0]
    factors = jax.vmap(
        lambda fac, Xi, sqi, mi, vi, emi, yi: upd(fac, Xi, sqi, mi, vi, emi, yi, ye_old)
    )(art.factors, Xs, art.data["sq_exact"], mask, valid, em_arg, art.y)
    data = dict(art.data)
    data["X_extra"] = (
        jnp.concatenate([Xe_old, X_new]) if Xe_old is not None else X_new
    )
    data["extra_mask"] = (
        jnp.concatenate([em_old, valid], axis=1) if em_old is not None else valid
    )
    data["y_extra"] = (
        jnp.concatenate([ye_old, y_new]) if ye_old is not None else y_new
    )
    return dataclasses.replace(
        art, factors=factors, data=data,
        lengths=_bump_length(art.lengths, j, n_new),
    )


register_protocol(ProtocolSpec(
    name="poe",
    fit=_fit_poe,
    predict=_predict_poe,
    update=_update_poe,
    fit_host=fit_poe_host,
))
