"""impl="mesh": machines are devices, the collectives are the wire.

The production SPMD substrate shared by every protocol: machines live along a
1-D ``("machines",)`` device mesh, the per-symbol wire protocol runs as ONE
``compat.shard_map`` program whose only inter-machine channel is
``repro.comm.q_all_gather`` (int codes + O(d²) fp32 side info; the ledger is
computed from what the collective actually moves), per-machine factors are
built device-local and live SHARDED along the mesh axis, and broadcast/PoE
serving is one shard_map program with a psum/KL fusion epilogue.  All of it
is locked to the host/batched impls by tests/test_conformance.py.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ...compat import shard_map
from .. import jax_scheme
from ..gp import (
    GPParams,
    gram_fn,
    kernel_from_inner,
    prior_diag,
    posterior_factors,
    posterior_apply,
    posterior_from_gram,
)
from ..nystrom import (
    nystrom_factors,
    nystrom_apply,
    nystrom_serve_cache,
    nystrom_apply_cached,
    nystrom_kinv,
    chol_update_rank,
    chol_append_at,
)
from ..linalg_safe import DEFAULT_JITTER
from ..fusion import kl_fuse_diag
from ..registry import FUSIONS, SCHEMES
from .base import StreamState, WireState, _mask_gram, _SERVE_TRACES, _UPDATE_TRACES

__all__ = [
    "MESH_AXIS",
    "machine_mesh",
    "broadcast_gp_mesh",
]

MESH_AXIS = "machines"


def machine_mesh(m: int) -> Mesh:
    """A 1-D ``("machines",)`` mesh over the first m local devices — the
    execution substrate of ``impl="mesh"``.  On CPU, force placeholder
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (tests/conftest.py does; launch/serve_gp.py --mesh does it for you)."""
    devs = jax.devices()
    if m > len(devs):
        raise ValueError(
            f'impl="mesh" needs one device per machine: m={m} > '
            f"{len(devs)} available devices (hint: "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={m})"
        )
    return Mesh(np.asarray(devs[:m]), (MESH_AXIS,))


@functools.lru_cache(maxsize=None)
def _mesh_wire_fn(m: int, total_bits: int, max_bits: int, mode: str, center: int):
    """One compiled SPMD wire program per (m, R, mode): every device fits its
    scheme, the int codes + O(d²) side info move through comm.q_all_gather,
    and everything the collective moved comes back replicated."""
    from ...comm import q_all_gather

    mesh = machine_mesh(m)

    def body(x_blk, mask_blk):
        _, st = q_all_gather(
            x_blk[0], MESH_AXIS, total_bits, max_bits, mask=mask_blk[0],
            mode=mode, center=center, return_state=True,
        )
        return st

    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P(MESH_AXIS), P(MESH_AXIS)),
        out_specs=P(), check_vma=False,
    ))


def _run_wire_protocol_mesh(X, mask, total_bits: int, max_bits: int, mode: str, center: int):
    """The per-symbol wire protocol as a REAL device-mesh program (machines =
    devices along ``MESH_AXIS``; ``comm.q_all_gather`` is the only
    inter-machine channel, and what it gathers is the PACKED uint32 code
    plane).  Returns the same :class:`~.base.WireState` layout as the batched
    program (replicated arrays; ``codes`` are the gathered packed words),
    the Theorem-1 ledger, the payload bits MEASURED from the buffer the
    collective moved, and the CRC integrity bits — all integer-equal to the
    host oracle's §4 accounting / the shared formulas
    (tests/test_conformance.py)."""
    m, n_pad, d = X.shape
    st = _mesh_wire_fn(m, total_bits, max_bits, mode, center)(X, mask)
    # UNSHARD the replicated outputs.  shard_map's out_specs=P() leaves every
    # array COMMITTED to NamedSharding(mesh, P()) — replicated over all m
    # devices — and that sharding is sticky: any downstream jit that consumes
    # these arrays (the center protocol's host predict, train_gp's scan)
    # compiles as an m-way SPMD program with per-dispatch cross-device
    # synchronization, which is what collapsed mesh predict throughput as m
    # grew (23.2k -> 1.9k qps from m=2 to m=8).  One host pull here at fit
    # time erases the committed sharding (this function already host-syncs to
    # int() the ledger scalars); the mesh-served protocols explicitly
    # re-shard what they need via _shard_machine_axis.
    st = jax.tree.map(lambda a: jnp.asarray(jax.device_get(a)), st)
    tables = jax_scheme.scheme_tables(total_bits, max_bits)
    cents = jax_scheme.scaled_centroids_batched(st["rates"], st["sigma"], tables)
    ws = WireState(
        st["codes"], st["decoded"], st["T_inv"], st["rates"], st["sigma"],
        cents, st["T"],
    )
    return (
        ws, int(st["wire_bits"]), int(st["payload_bits"]),
        int(st["integrity_bits"]),
    )


def _shard_machine_axis(tree, mesh: Mesh):
    """device_put every leaf with its leading (machine) axis along the mesh."""
    sh = NamedSharding(mesh, P(MESH_AXIS))
    return jax.tree.map(lambda a: jax.device_put(a, sh), tree)


@functools.lru_cache(maxsize=None)
def _mesh_broadcast_factor_fn(m: int, kernel: str, fused_serve: bool = True):
    """Per-machine §5.2 Nyström factor build as ONE shard_map program: device i
    assembles ITS view (own block exact, peers from the wire reconstructions)
    and factorizes it locally; the factor set comes out SHARDED along the
    mesh axis (out_specs P(MESH_AXIS)).  ``fused_serve`` additionally builds
    the K-sized ``nystrom_serve_cache`` operands device-local, so mesh serving
    runs the fused matmul-only epilogue."""
    mesh = machine_mesh(m)

    def body(x_blk, mask_blk, dec, sq_dec, mask_flat, y_flat, p):
        i = jax.lax.axis_index(MESH_AXIS)
        x, mi = x_blk[0], mask_blk[0]
        n_pad = x.shape[0]
        noise = jnp.exp(p.log_noise)
        sqx = jnp.sum(x**2, -1)
        cols = dec.at[i].set(x)  # own (exact) block replaces its reconstruction
        sq_cols = sq_dec.at[i].set(sqx).reshape(-1)
        ip_KK = x @ x.T
        ip_KN = jnp.moveaxis(
            jnp.einsum("nd,jNd->jnN", x, cols), 0, 1
        ).reshape(n_pad, m * n_pad)
        G_KK = _mask_gram(kernel_from_inner(kernel, p, ip_KK, sqx, sqx), mi)
        G_KN = kernel_from_inner(kernel, p, ip_KN, sqx, sq_cols) * (
            mi[:, None] * mask_flat[None, :]
        )
        fac = nystrom_factors(G_KK, G_KN, y_flat, noise)
        if fused_serve:
            fac.update(nystrom_serve_cache(fac))
        return jax.tree.map(lambda a: a[None], fac)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(MESH_AXIS), P(MESH_AXIS), P(), P(), P(), P(), P()),
        out_specs=P(MESH_AXIS), check_vma=False,
    ))


@functools.lru_cache(maxsize=None)
def _mesh_poe_factor_fn(m: int, kernel: str):
    """Zero-rate expert factorization, one dense Cholesky per device (own
    shard only — no wire at all), factors sharded along the mesh axis."""
    mesh = machine_mesh(m)

    def body(x_blk, y_blk, mask_blk, p):
        x, yj, mj = x_blk[0], y_blk[0], mask_blk[0]
        noise = jnp.exp(p.log_noise)
        sqj = jnp.sum(x**2, -1)
        G = _mask_gram(kernel_from_inner(kernel, p, x @ x.T, sqj, sqj), mj)
        fac = posterior_factors(G, yj * mj, noise)
        return jax.tree.map(lambda a: a[None], fac)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS), P()),
        out_specs=P(MESH_AXIS), check_vma=False,
    ))


# --------------------------------------------------------------------------
# mesh serving: one shard_map program with a psum fusion epilogue
# --------------------------------------------------------------------------


def _predict_mesh_impl(art, X_star, avail=None):
    """Mesh serving: ONE shard_map program — each device applies ITS machine's
    cached factors to the query batch (triangular solves only, exactly like
    the batched path) and the predictives meet in a psum/KL fusion epilogue
    (eqs. 62-64 as two psums; the PoE combiners as precision-weighted psums;
    any registered fusion with a ``fuse_psum`` form plugs in).  Factors/data
    stay sharded along the mesh axis throughout.

    ``avail``: optional replicated (m,) float availability mask — degraded
    serving renormalizes the psum fusion over surviving machines (each device
    reads its own weight ``w_i = avail[axis_index]``).  ``None`` (the healthy
    fleet) keeps the unweighted epilogue; each distinct availability pattern
    costs one retrace, like any other static serve knob."""
    _SERVE_TRACES[art.protocol] += 1  # runs at trace time only
    m = len(art.fit_lengths)
    mesh = machine_mesh(m)
    weighted = avail is not None
    fusion = FUSIONS.get(art.fuse)
    fused_moments = fusion.moments is not None and fusion.finalize is not None
    if fusion.fuse_psum is None and not fused_moments:
        raise NotImplementedError(
            f"fusion {art.fuse!r} has no mesh (psum or moments) form — serve "
            "the checkpointed single-host artifact instead"
        )
    # static: key presence selects the fused matmul-only apply
    cached = art.protocol == "broadcast" and "Ainv" in art.factors

    def body(fac, Xs_blk, mask_blk, sq_blk, X_star, av, p):
        fac_i = jax.tree.map(lambda a: a[0], fac)
        Xi, mi, sqi = Xs_blk[0], mask_blk[0], sq_blk[0]
        noise = jnp.exp(p.log_noise)
        sq_star = jnp.sum(X_star**2, -1)
        g_ss = prior_diag(art.kernel, p, sq_star)
        w_i = av[jax.lax.axis_index(MESH_AXIS)] if weighted else None
        # streamed points live in the capacity-padded buffers (mask-zeroed
        # where invalid), so one uniform apply serves updated artifacts too
        G_sK = kernel_from_inner(
            art.kernel, p, X_star @ Xi.T, sq_star, sqi
        ) * mi[None, :]
        if art.protocol == "broadcast":
            if cached:
                mu_i, s2_i = nystrom_apply_cached(fac_i, G_sK, g_ss, noise)
            else:
                mu_i, s2_i = nystrom_apply(fac_i, G_sK, g_ss, noise)
        else:  # poe
            mu_i, s2_i = posterior_apply(fac_i, G_sK, g_ss)
        prior = g_ss + noise
        if fused_moments:
            # fused epilogue: ONE stacked psum carries the (3, t) moment rows
            # instead of the 2-3 collectives of fuse_psum — halves the
            # per-dispatch collective cost that dominates mesh serve latency
            # (m is static: no psum(1) just to count machines)
            S = jax.lax.psum(
                fusion.moments(mu_i, s2_i, prior, w_i), MESH_AXIS
            )
            return fusion.finalize(S, m, prior)
        if not weighted:  # legacy 4-arg fuse_psum keeps the healthy path
            return fusion.fuse_psum(mu_i, s2_i, prior, MESH_AXIS)
        return fusion.fuse_psum(mu_i, s2_i, prior, MESH_AXIS, w_i)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(
            P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS),
            P(), P(), P(),
        ),
        out_specs=(P(), P()), check_vma=False,
    )
    av = None if avail is None else jnp.asarray(avail, jnp.float32)
    return fn(
        art.factors, art.data["Xs"], art.data["mask"], art.data["sq_exact"],
        X_star, av, art.params,
    )


_predict_mesh_jit = jax.jit(_predict_mesh_impl)


# --------------------------------------------------------------------------
# mesh streaming: the update is a shard_map program too (no host pull)
# --------------------------------------------------------------------------


def _update_mesh_impl(art, X_new, y_new, j, pre):
    """Mesh streaming append: ONE jitted program in which the new batch is
    re-encoded through the frozen codebooks (encode→pack→CRC→unpack→decode,
    all on device via the scheme's traced reencode) and the SHARDED factors
    grow in place on their own devices under shard_map — the ledgers extend
    as device-resident int32 leaves and nothing is pulled to host.  The
    machine index ``j`` and append cursor are traced, so consecutive
    in-bucket updates hit one cache entry regardless of target machine."""
    _UPDATE_TRACES[art.protocol] += 1  # runs at trace time only
    m = len(art.fit_lengths)
    mesh = machine_mesh(m)
    kernel = art.kernel
    n_new = X_new.shape[0]
    pos = art.stream.cols
    zero = jnp.int32(0)

    if art.protocol == "broadcast":
        if pre is None:
            # the full wire plane runs inside this traced program; the
            # decoded batch is replicated to every device like fit time
            decoded, w_add, p_add, i_add = SCHEMES.get(
                art.scheme
            ).reencode_traced(art, j, X_new)
            d_add = jnp.int32(0)
        else:  # host-precomputed batch (faulted transmission)
            decoded, w_add, p_add, i_add, d_add = pre
        y2 = jax.lax.dynamic_update_slice(art.y, y_new, (pos,))

        def body(fac, Xs_blk, mask_blk, sq_blk, Xn, dec, y2r, pr, jj, ps):
            i = jax.lax.axis_index(MESH_AXIS)
            fac_i = jax.tree.map(lambda a: a[0], fac)
            Xi, mi, sqi = Xs_blk[0], mask_blk[0], sq_blk[0]
            s2 = jnp.exp(pr.log_noise) + DEFAULT_JITTER
            X_eff = jnp.where(i == jj, Xn, dec)  # own batch exact, peers X̂
            sqn = jnp.sum(X_eff**2, -1)
            G_KN_new = kernel_from_inner(
                kernel, pr, Xi @ X_eff.T, sqi, sqn
            ) * mi[:, None]
            W_new = jax.scipy.linalg.solve_triangular(
                fac_i["L_KK"], G_KN_new, lower=True
            )
            W2 = jax.lax.dynamic_update_slice(fac_i["W"], W_new, (0, ps))
            L_M2 = chol_update_rank(fac_i["L_M"], W_new)
            fac2 = {
                "L_KK": fac_i["L_KK"], "W": W2, "L_M": L_M2,
                "alpha": nystrom_kinv(W2, L_M2, s2, y2r),
            }
            if "U" in fac_i:  # fused-serve cache rides along device-local
                fac2["Ainv"] = fac_i["Ainv"]
                fac2["U"] = fac_i["U"] + W_new @ W_new.T
                fac2["walpha"] = W2 @ fac2["alpha"]
            return jax.tree.map(lambda a: a[None], fac2)

        factors = shard_map(
            body, mesh=mesh,
            in_specs=(
                P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS),
                P(), P(), P(), P(), P(), P(),
            ),
            out_specs=P(MESH_AXIS), check_vma=False,
        )(
            art.factors, art.data["Xs"], art.data["mask"],
            art.data["sq_exact"], X_new, decoded, y2, art.params, j, pos,
        )
        data = art.data
    else:  # poe: zero-rate, the batch is machine j's own exact data
        w_add = p_add = i_add = d_add = jnp.int32(0)
        valid = jnp.broadcast_to(
            (jnp.arange(m)[:, None] == j).astype(jnp.float32), (m, n_new)
        )
        y2 = jax.lax.dynamic_update_slice(
            art.y, valid * y_new[None, :], (zero, pos)
        )

        def body(fac, Xs_blk, mask_blk, sq_blk, Xn, y2r, pr, jj, ps):
            i = jax.lax.axis_index(MESH_AXIS)
            fac_i = jax.tree.map(lambda a: a[0], fac)
            Xi, mi, sqi = Xs_blk[0], mask_blk[0], sq_blk[0]
            s2 = jnp.exp(pr.log_noise) + DEFAULT_JITTER
            nn = Xn.shape[0]
            vi = jnp.where(i == jj, 1.0, 0.0) * jnp.ones((nn,), jnp.float32)
            Xi2 = jax.lax.dynamic_update_slice(Xi, Xn, (ps, 0))
            mi2 = jax.lax.dynamic_update_slice(mi, vi, (ps,))
            sqi2 = jax.lax.dynamic_update_slice(sqi, jnp.sum(Xn**2, -1), (ps,))
            kf = gram_fn(kernel)
            # OLD mask in the cross block: zero rows at/after the cursor keep
            # chol_append_at's contract; non-owners (vi=0) append decoupled
            # unit rows, masked out of their predict columns by mi2
            G_on = kf(pr, Xi2, Xn) * (mi[:, None] * vi[None, :])
            G_nn = _mask_gram(kf(pr, Xn), vi) + s2 * jnp.eye(nn)
            L2 = chol_append_at(fac_i["L"], G_on, G_nn, ps)
            fac2 = {
                "L": L2,
                "alpha": jax.scipy.linalg.cho_solve((L2, True), y2r[i]),
            }
            lift = lambda a: a[None]
            return jax.tree.map(lift, fac2), Xi2[None], mi2[None], sqi2[None]

        factors, Xs2, mask2, sq2 = shard_map(
            body, mesh=mesh,
            in_specs=(
                P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS), P(MESH_AXIS),
                P(), P(), P(), P(), P(),
            ),
            out_specs=(P(MESH_AXIS),) * 4, check_vma=False,
        )(
            art.factors, art.data["Xs"], art.data["mask"],
            art.data["sq_exact"], X_new, y2, art.params, j, pos,
        )
        data = dict(art.data)
        data["Xs"], data["mask"], data["sq_exact"] = Xs2, mask2, sq2

    s = art.stream
    stream = StreamState(
        counts=s.counts.at[j].add(n_new), cols=s.cols + n_new,
        wire_bits=s.wire_bits + w_add, payload_bits=s.payload_bits + p_add,
        integrity_bits=s.integrity_bits + i_add,
        rows_demoted=s.rows_demoted + d_add,
    )
    return dataclasses.replace(art, y=y2, factors=factors, data=data,
                               stream=stream)


_update_mesh_jit_raw = jax.jit(_update_mesh_impl)

# Leaf path prefixes that are SUPPOSED to live sharded along the machine
# axis (that is the point of the substrate); every other artifact leaf is
# single-device, enforced by the mesh-update contract (repro.analysis:
# NoShardingLeak).
_MESH_SHARDED_LEAVES = ("factors/", "data/")


def _update_mesh_jit(art, X_new, y_new, j, pre):
    """In-bucket mesh update plus sharding hygiene on the outputs.

    The update program consumes mesh-sharded factors, so GSPMD commits ALL
    of its outputs to the mesh — the logically-replicated leaves (params,
    y, wire state, stream ledger) come back COMMITTED to a replicated
    NamedSharding over every device.  That is the PR-8 leak class: the
    commitment is sticky, so downstream host/batched consumers of those
    leaves compile as m-way SPMD with per-dispatch device sync, and the
    update program itself re-specializes between the first dispatch
    (uncommitted fit-time leaves) and every later one.  A single-device
    commitment is no fix — one jit cannot mix a leaf pinned to device 0
    with factors pinned to the mesh — so do exactly what the fit boundary
    does (see ``_mesh_wire_state``): host-sync the leaked leaves to erase
    the commitment.  Only the O(1)/O(rows) bookkeeping moves; the O(cols²)
    factor and data buffers stay device-resident and mesh-sharded, which is
    the streaming contract that matters.
    """
    out = _update_mesh_jit_raw(art, X_new, y_new, j, pre)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(out)
    fixed = []
    for path, leaf in leaves:
        if (
            isinstance(leaf, jax.Array)
            and len(leaf.sharding.device_set) > 1
            and not _path_str(path).startswith(_MESH_SHARDED_LEAVES)
        ):
            leaf = jnp.asarray(jax.device_get(leaf))
        fixed.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, fixed)


# --------------------------------------------------------------------------
# legacy one-shot mesh entry point (absorbed from the old core.mesh_gp)
# --------------------------------------------------------------------------


def broadcast_gp_mesh(
    mesh,
    axis: str,
    X,
    y,
    X_star,
    params: GPParams,
    *,
    kernel: str = "se",
    bits_per_sample: int = 32,
    max_bits: int = 8,
):
    """One-shot §5.2 broadcast on a caller-supplied mesh: devices along
    ``axis`` are machines, the wire is ``comm.q_all_gather`` (int codes),
    each device solves its dense local view, and the per-point predictives
    are KL-fused (eqs. 62-64) — all inside one jit/shard_map program.

    This is the original mesh prototype, kept for fixed-hyper one-shot runs
    (no training, no serving artifact).  The first-class mesh path is
    ``fit(..., impl="mesh")`` — it adds hyperparameter training, Nyström
    factor caching sharded along the mesh axis, streaming
    :func:`~.base.update`, and checkpointing.

    X: (n, d) globally, sharded over ``axis`` on dim 0 (n % n_devices == 0);
    y: (n,) likewise; X_star: (t, d) replicated.  Returns fused (mean, var).
    """
    from ...comm import q_all_gather

    k = gram_fn(kernel)

    def local_predict(X_all_blocks, y_all, own_idx, xs_l):
        """One device's §5.2 view: own block exact, peers reconstructed."""
        m, n_loc, d = X_all_blocks.shape
        # reorder so the exact (own) block is first — matches the Nyström layout
        order = jnp.argsort(
            jnp.where(jnp.arange(m) == own_idx, -1, jnp.arange(m))
        )
        Xv = X_all_blocks[order].reshape(m * n_loc, d)
        yv = y_all[order].reshape(m * n_loc)
        G = k(params, Xv)
        G_sn = k(params, xs_l, Xv)
        g_ss = jnp.diagonal(k(params, xs_l, xs_l))
        return posterior_from_gram(G, G_sn, g_ss, yv, jnp.exp(params.log_noise))

    def body(x_l, y_l, xs_l):
        idx = jax.lax.axis_index(axis)
        # the paper's wire: quantized codes, own block exact (repro.comm)
        x_blocks = q_all_gather(x_l, axis, bits_per_sample, max_bits)
        y_all = jax.lax.all_gather(y_l, axis)  # targets are scalars (unquantized)
        mu_i, s2_i = local_predict(x_blocks, y_all, idx, xs_l)
        # KL-barycenter fusion (eqs. 62-64) across the machine axis
        mus = jax.lax.all_gather(mu_i, axis)
        s2s = jax.lax.all_gather(s2_i, axis)
        return kl_fuse_diag(mus, s2s)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(None, None)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)(X, y, X_star)


# --------------------------------------------------------------------------
# the impl="mesh" program contracts: overrides for the protocols whose serve
# program actually runs on the machine mesh (broadcast/PoE; center unshards
# at the fit boundary and keeps the batched contract)
# --------------------------------------------------------------------------
from ...analysis.contracts import (
    CollectiveBudget,
    Contract,
    LedgerAccounting,
    NoHostCallbacks,
    NoShardingLeak,
    _path_str,
    forbid_primitives,
    register_contract,
)

# _MESH_SHARDED_LEAVES (defined next to _update_mesh_jit above): factor and
# data leaves are deliberately mesh-sharded; anything else committed to more
# than one device is the PR-8 leak class (replicated-committed shard_map
# outputs turning every downstream jit m-way SPMD).

# The fused serve epilogue is ONE stacked psum of the (mu, s2-moment, weight)
# rows — the single collective the §4 wire model licenses at serve time.
# More than one means the legacy 2-3 psum epilogue (or an unaccounted
# channel) regressed in.
_MESH_SERVE_CONTRACT = Contract(
    name="mesh-serve",
    rules=(
        forbid_primitives(),
        NoHostCallbacks(),
        CollectiveBudget(max_count=1),
        NoShardingLeak(max_devices=1, allow_prefixes=_MESH_SHARDED_LEAVES),
        LedgerAccounting(),
    ),
)
_MESH_UPDATE_CONTRACT = Contract(
    name="mesh-update",
    rules=(
        NoShardingLeak(max_devices=1, allow_prefixes=_MESH_SHARDED_LEAVES),
        LedgerAccounting(),
    ),
)
for _protocol in ("broadcast", "poe"):
    register_contract(_protocol, "predict", _MESH_SERVE_CONTRACT, impl="mesh")
    register_contract(_protocol, "update", _MESH_UPDATE_CONTRACT, impl="mesh")
