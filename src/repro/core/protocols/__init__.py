"""The §5 distributed-GP protocols as a package.

Layout (the old 2k-line ``core/distributed_gp.py`` monolith, split along the
paper's own seams):

* :mod:`.base` — shared machinery: padded shards, the wire-bit ledger, the
  :class:`~.base.FittedProtocol` serving artifact, and the
  ``fit``/``predict``/``update``/``save_artifact``/``load_artifact``
  lifecycle (protocol/scheme dispatch via :mod:`repro.core.registry`);
* :mod:`.wire` — pluggable wire schemes: ``per_symbol`` (§4.2 int codes) and
  ``vq`` (the §4.1 Theorem-2 optimal test channel, runnable on the wire);
* :mod:`.center` — the §5.1 single-center protocol;
* :mod:`.broadcast` — the §5.2 broadcast protocol;
* :mod:`.poe` — the zero-rate PoE/BCM baselines as a protocol;
* :mod:`.mesh` — the machines-as-devices shard_map substrate
  (``impl="mesh"``) shared by all of the above.

Importing this package registers the builtin protocols and schemes.  The
public front door is :class:`repro.core.api.DistributedGP`; the legacy entry
points live on as deprecated wrappers in :mod:`repro.core.distributed_gp`.
"""
from . import base, wire, center, broadcast, poe, mesh  # noqa: F401 (registration)

from .base import (
    FittedProtocol,
    PaddedShards,
    StreamState,
    WireState,
    fit,
    load_artifact,
    pad_parts,
    predict,
    predict_op_counts,
    save_artifact,
    serve_trace_count,
    split_machines,
    update,
    update_trace_count,
)
from .center import CenterGP, quantize_to_center, single_center_gp
from .broadcast import HostBroadcastGP, broadcast_gp
from .poe import HostPoEGP, poe_baseline
from .mesh import MESH_AXIS, broadcast_gp_mesh, machine_mesh
from .wire import _run_wire_protocol  # noqa: F401 (benchmarks/tests import it)

__all__ = [
    "FittedProtocol",
    "PaddedShards",
    "StreamState",
    "WireState",
    "fit",
    "predict",
    "update",
    "save_artifact",
    "load_artifact",
    "pad_parts",
    "split_machines",
    "serve_trace_count",
    "update_trace_count",
    "predict_op_counts",
    "CenterGP",
    "quantize_to_center",
    "single_center_gp",
    "HostBroadcastGP",
    "broadcast_gp",
    "HostPoEGP",
    "poe_baseline",
    "MESH_AXIS",
    "machine_mesh",
    "broadcast_gp_mesh",
]
