"""Pluggable wire schemes: what actually crosses the machine boundary.

The paper's central design variable is the *scheme on the wire* — §4 develops
three: the optimal vector-quantization test channel (Theorem 2), the
near-optimal per-symbol scheme (§4.2), and dimension reduction — and §5's
protocols are parametric in it.  This module makes the first two selectable
by name (``repro.core.registry.SCHEMES``):

* ``per_symbol`` — the §4.2 scheme: decorrelating transform, greedy
  Algorithm-1 bit allocation, int codes on the wire.  Batched impl runs one
  vmapped fit/encode/decode jit (:func:`_run_wire_protocol`); mesh impl runs
  the same math through ``repro.comm.q_all_gather`` (see :mod:`.mesh`).
* ``vq`` — the §4.1 Theorem-2 *optimal* test channel, promoted from an
  offline rate/distortion curve (``core.rate_distortion``) to a runnable
  wire scheme: each machine builds the achieving conditional
  ``x̂ | x ~ N(Ax, W)`` at the distortion its bit budget buys
  (``distortion_for_rate``), and the receiver sees samples from it.  Block
  coding with 2^{nR} codebooks is intractable (as the paper notes), so the
  channel is *simulated* — but the ledger is honest: each machine is charged
  ``ceil(n_j · R_j)`` wire bits at the channel's ACHIEVED Theorem-1 rate
  ``R_j ≈ R`` plus the same O(2d²) fp32 side info as per-symbol (the
  receiver needs the channel/transform parameters either way).

Every scheme returns the shared :class:`~.base.WireState` layout (codes
PACKED into the uint32 code plane — ``jax_scheme.pack_codes``, the same
buffer the collectives move and checkpoints store), the Theorem-1 ledger,
the measured physical payload bits, and an ``extras`` dict of scheme-private
arrays that ride in the artifact's ``data`` (the vq channel state lives
there so streaming :func:`~.base.update` can re-encode new symbols under the
FROZEN channel).
"""
from __future__ import annotations

import math
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from .. import jax_scheme
from ..rate_distortion import distortion_for_rate, make_test_channel, sample_test_channel
from ..registry import SchemeSpec, register_scheme
from .base import PaddedShards, WireRun, WireState, _wire_bits

__all__ = ["_run_wire_protocol", "PER_SYMBOL", "VQ"]


# --------------------------------------------------------------------------
# per_symbol — §4.2 int codes (the default)
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("total_bits", "max_bits", "mode", "center"))
def _run_wire_protocol(X, mask, total_bits: int, max_bits: int, mode: str, center: int):
    """Fit + encode + decode for EVERY machine under one jit: a single batched
    eigh pair (fit), one batched quantize and one batched dequantize; codes
    leave the program PACKED (``jax_scheme.pack_codes`` — the physical code
    plane; padded rows are all-zero words).

    mode="center": every machine targets the center's covariance (§5.1);
    mode="broadcast": machine j targets the sum of the others' (§5.2)."""
    from ...comm.accounting import row_bits

    m, n_pad, d = X.shape
    n = jnp.maximum(mask.sum(axis=1), 1.0)
    S = jnp.einsum("mnd,mne->mde", X, X) / n[:, None, None]  # padded rows are 0
    if mode == "center":
        Qy = jnp.broadcast_to(S[center], (m, d, d))
    elif mode == "broadcast":
        Qy = jnp.sum(S, axis=0)[None] - S
    else:
        raise ValueError(f"unknown wire mode {mode!r}")
    cap = jax_scheme.codebook_cap(total_bits, max_bits)
    tables = jax_scheme.scheme_tables(total_bits, max_bits)
    states = jax_scheme.fit_scheme_batched(S, Qy, total_bits, cap)
    codes = jax.vmap(lambda st, x: jax_scheme.encode(st, x, tables))(states, X)
    decoded = jax.vmap(lambda st, c: jax_scheme.decode(st, c, tables))(states, codes)
    decoded = decoded * mask[..., None]
    rbits = row_bits(total_bits, d, max_bits)
    words = jax.vmap(
        lambda st, c, mk: jax_scheme.pack_codes(
            c, st["rates"], total_bits=rbits, mask=mk
        )
    )(states, codes, mask)
    cents = jax.vmap(lambda st: jax_scheme.scaled_centroids(st, tables))(states)
    return WireState(
        words, decoded, states["T_inv"], states["rates"], states["sigma"], cents,
        states["T"],
    )


def _corrupt_and_demote(ws: WireState, shards: PaddedShards, bits: int,
                        max_bits: int, skip, plan):
    """The noisy-channel receiver: flip bits in every transmitted machine's
    packed words (Bernoulli(``plan.flip_rate``) per bit, keyed per machine),
    recompute each row's CRC-16, and DEMOTE rows whose checksum mismatches to
    masked rows — compacting each machine's survivors to the front so the
    protocol assembly sees a plain shorter shard.  Rows whose corruption
    collides with the CRC (prob 2^-16) survive with their corrupted decode:
    the receiver is honest about what it can detect.

    Runs host-side on the packed plane AFTER the wire program: batched and
    mesh produce identical words (conformance-locked), so the demotion
    pattern is identical across impls by construction.  Returns
    ``(ws, shards, rows_demoted)`` with codes/decoded/X/y/mask/lengths all
    moved consistently; the ledgers are NOT touched (the bits were
    transmitted regardless of what survived)."""
    from ...comm.accounting import row_bits
    from ...faults import flip_words

    m, n_pad, d = shards.X.shape
    rbits = row_bits(bits, d, max_bits)
    tables = jax_scheme.scheme_tables(bits, max_bits)
    words = np.array(ws.codes)  # (m, n_pad, W)
    decoded = np.array(ws.decoded)
    X = np.array(shards.X)
    y = np.array(shards.y)
    mask = np.array(shards.mask)
    key = jax.random.PRNGKey(plan.seed)
    new_lengths, demoted = [], 0
    for j in range(m):
        L = int(shards.lengths[j])
        if j == skip or L == 0 or words.shape[-1] == 0:
            new_lengths.append(L)
            continue  # never transmits (or has nothing to) — nothing to flip
        wj = jnp.asarray(words[j, :L])
        crc_clean = jax_scheme.crc_words(wj)
        rx = flip_words(wj, plan.flip_rate, jax.random.fold_in(key, j))
        ok = np.asarray(jax_scheme.crc_words(rx) == crc_clean)
        state = {"T": ws.T[j], "T_inv": ws.T_inv[j],
                 "sigma": ws.sigma[j], "rates": ws.rates[j]}
        codes_rx = jax_scheme.unpack_codes(rx, ws.rates[j], total_bits=rbits)
        dec_rx = np.asarray(jax_scheme.decode(state, codes_rx, tables))
        idx = np.flatnonzero(ok)
        k = idx.size
        demoted += L - k
        rx = np.asarray(rx)
        for buf, rows in ((words, rx[idx]), (decoded, dec_rx[idx]),
                          (X, X[j, :L][idx]), (y, y[j, :L][idx])):
            buf[j] = 0
            buf[j, :k] = rows
        mask[j] = 0.0
        mask[j, :k] = 1.0
        new_lengths.append(k)
    shards = PaddedShards(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray(mask), tuple(new_lengths)
    )
    ws = ws._replace(codes=jnp.asarray(words), decoded=jnp.asarray(decoded))
    return ws, shards, demoted


def _per_symbol_run(
    shards: PaddedShards, bits: int, max_bits: int, mode: str, center: int,
    impl: str, faults=None,
):
    from ...comm.accounting import integrity_bits_formula, payload_bits_formula

    m, n_pad, d = shards.X.shape
    skip = center if mode == "center" else None
    if impl == "mesh":
        from . import mesh

        ws, wire, payload, integrity = mesh._run_wire_protocol_mesh(
            shards.X, shards.mask, bits, max_bits, mode, center
        )
    else:
        ws = _run_wire_protocol(shards.X, shards.mask, bits, max_bits, mode, center)
        wire = _wire_bits(ws.rates, shards.lengths, d, skip=skip)
        payload = payload_bits_formula(shards.lengths, d, bits, max_bits, skip=skip)
        integrity = integrity_bits_formula(shards.lengths, skip=skip)
    rows_demoted = 0
    if faults is not None and faults.flip_rate > 0.0:
        ws, shards, rows_demoted = _corrupt_and_demote(
            ws, shards, bits, max_bits, skip, faults
        )
    return WireRun(ws, int(wire), int(payload), int(integrity), {}, shards,
                   rows_demoted)


def _per_symbol_reencode(art, machine: int, X_new):
    """(X̂, wire_bits, payload_bits) for new symbols under machine's frozen
    codebooks — the stream passes through the SAME packed code plane as the
    fit-time wire (encode -> pack -> unpack -> decode), so the physical
    payload is whole uint32 words per point while the ledger charges the
    frozen allocated rate."""
    from ...comm.accounting import payload_row_bits, row_bits

    w = art.wire
    state = {
        "T": w.T[machine], "T_inv": w.T_inv[machine],
        "sigma": w.sigma[machine], "rates": w.rates[machine],
    }
    d = X_new.shape[1]
    tables = jax_scheme.scheme_tables(art.bits_per_sample, art.max_bits)
    codes = jax_scheme.encode(state, X_new, tables)
    rbits = row_bits(art.bits_per_sample, d, art.max_bits)
    words = jax_scheme.pack_codes(codes, state["rates"], total_bits=rbits)
    codes_rt = jax_scheme.unpack_codes(words, state["rates"], total_bits=rbits)
    decoded = jax_scheme.decode(state, codes_rt, tables)
    n_new = X_new.shape[0]
    bits = int(np.asarray(w.rates[machine]).sum()) * n_new
    payload = payload_row_bits(art.bits_per_sample, d, art.max_bits) * n_new
    return decoded, bits, payload


def _per_symbol_reencode_traced(art, machine, X_new):
    """The jit-safe form of :func:`_per_symbol_reencode`: ``machine`` is a
    TRACED int32 scalar (the frozen per-machine state is gathered, not
    indexed statically), every table/shape is derived from static artifact
    metadata, and the three ledger deltas come back as traced int32 scalars.
    This is what lets ``base.update`` run encode→pack→CRC→unpack→decode
    inside ONE device-resident program that is reused for every machine and
    every in-bucket batch without retracing."""
    from ...comm.accounting import CRC_BITS, payload_row_bits, row_bits

    w = art.wire
    state = {
        "T": w.T[machine], "T_inv": w.T_inv[machine],
        "sigma": w.sigma[machine], "rates": w.rates[machine],
    }
    n_new, d = X_new.shape
    tables = jax_scheme.scheme_tables(art.bits_per_sample, art.max_bits)
    codes = jax_scheme.encode(state, X_new, tables)
    rbits = row_bits(art.bits_per_sample, d, art.max_bits)
    words = jax_scheme.pack_codes(codes, state["rates"], total_bits=rbits)
    # the CRC the receiver checks rides the same plane (charged below)
    codes_rt = jax_scheme.unpack_codes(words, state["rates"], total_bits=rbits)
    decoded = jax_scheme.decode(state, codes_rt, tables)
    wire_add = jnp.sum(state["rates"]).astype(jnp.int32) * n_new
    payload_add = jnp.int32(
        payload_row_bits(art.bits_per_sample, d, art.max_bits) * n_new
    )
    integrity_add = jnp.int32(CRC_BITS * n_new)
    return decoded, wire_add, payload_add, integrity_add


def _per_symbol_update_corrupt(art, machine: int, X_new, plan):
    """Noisy-channel transmission of a STREAMED batch (the update-time analog
    of :func:`_corrupt_and_demote`): encode the new rows under machine's
    frozen codebooks, pack, flip bits at ``plan.flip_rate`` (keyed on the
    pre-update ledger so successive batches draw fresh corruption), CRC-check
    against the clean words, and demote failed rows.  Returns
    ``(keep_idx, decoded, wire_add, payload_add, integrity_add, demoted)`` —
    the ledger deltas charge the FULL transmitted batch (the bits moved
    regardless of what survived), ``decoded`` holds only the survivors'
    received reconstructions (CRC collisions keep their corrupted decode:
    the receiver is honest about what it can detect)."""
    from ...comm.accounting import CRC_BITS, payload_row_bits, row_bits
    from ...faults import flip_words

    w = art.wire
    state = {
        "T": w.T[machine], "T_inv": w.T_inv[machine],
        "sigma": w.sigma[machine], "rates": w.rates[machine],
    }
    n_new, d = X_new.shape
    tables = jax_scheme.scheme_tables(art.bits_per_sample, art.max_bits)
    codes = jax_scheme.encode(state, X_new, tables)
    rbits = row_bits(art.bits_per_sample, d, art.max_bits)
    words = jax_scheme.pack_codes(codes, state["rates"], total_bits=rbits)
    crc_clean = jax_scheme.crc_words(words)
    key = jax.random.fold_in(
        jax.random.PRNGKey(plan.seed), art.wire_bits + machine
    )
    rx = flip_words(words, plan.flip_rate, key)
    ok = np.asarray(jax_scheme.crc_words(rx) == crc_clean)
    codes_rx = jax_scheme.unpack_codes(rx, state["rates"], total_bits=rbits)
    dec_rx = jnp.asarray(jax_scheme.decode(state, codes_rx, tables))
    keep_idx = np.flatnonzero(ok)
    wire_add = int(np.asarray(w.rates[machine]).sum()) * n_new
    payload_add = payload_row_bits(art.bits_per_sample, d, art.max_bits) * n_new
    integrity_add = CRC_BITS * n_new
    demoted = n_new - keep_idx.size
    return (
        keep_idx, dec_rx[jnp.asarray(keep_idx)], wire_add, payload_add,
        integrity_add, demoted,
    )


PER_SYMBOL = register_scheme(SchemeSpec(
    name="per_symbol", run=_per_symbol_run, reencode=_per_symbol_reencode,
    reencode_traced=_per_symbol_reencode_traced,
    update_corrupt=_per_symbol_update_corrupt,
))


# --------------------------------------------------------------------------
# vq — the §4.1 Theorem-2 optimal test channel as a wire scheme
# --------------------------------------------------------------------------


def _vq_run(
    shards: PaddedShards, bits: int, max_bits: int, mode: str, center: int,
    impl: str, faults=None,
):
    if impl != "batched":
        raise NotImplementedError(
            'scheme="vq" runs on impl="batched" only (the test channel is '
            "simulated host-side; there are no int codes for the mesh "
            "collectives to carry)"
        )
    if faults is not None and faults.flip_rate > 0.0:
        raise NotImplementedError(
            'scheme="vq" simulates a continuous test channel — there are no '
            'packed words to bit-flip; use scheme="per_symbol" for wire '
            "corruption experiments"
        )
    from ...comm.accounting import side_info_bits

    X = np.asarray(shards.X, np.float64)
    m, n_pad, d = X.shape
    # honor the per-symbol allocator's ceiling: max_bits caps each dimension's
    # rate, so no scheme can spend more than d*max_bits per sample — clamping
    # the target here keeps the two schemes' budgets matched when it binds
    bits = min(bits, d * max_bits)
    L = shards.lengths
    S = [X[j, : L[j]].T @ X[j, : L[j]] / max(L[j], 1) for j in range(m)]
    S_tot = sum(S)

    decoded = np.zeros((m, n_pad, d), np.float32)
    A = np.zeros((m, d, d), np.float32)
    W_half = np.zeros((m, d, d), np.float32)
    rate_bits = np.zeros((m,), np.float32)
    wire = 0
    key = jax.random.PRNGKey(0)
    for j in range(m):
        if mode == "center" and j == center:
            continue  # never transmits: its block stays exact, update() is free
        if L[j] == 0:
            continue  # an empty (dropped) machine sends nothing
        Qy = S[center] if mode == "center" else S_tot - S[j]
        D = distortion_for_rate(S[j], Qy, float(bits))
        ch = make_test_channel(S[j], Qy, D)
        xh = sample_test_channel(
            ch, X[j, : L[j]].astype(np.float32), jax.random.fold_in(key, j)
        )
        decoded[j, : L[j]] = np.asarray(xh, np.float32)
        A[j] = ch.A
        W_half[j] = ch.W_half
        rate_bits[j] = ch.rate_bits
        # honest accounting at the channel's ACHIEVED rate (≈ the target
        # R by construction) + the per-symbol-matched side info (the ONE
        # shared formula: repro.comm.accounting.side_info_bits)
        wire += math.ceil(L[j] * float(ch.rate_bits)) + side_info_bits(d)

    eye = np.broadcast_to(np.eye(d, dtype=np.float32), (m, d, d))
    ws = WireState(
        # the vq channel is continuous — there are no codes, packed or
        # otherwise, so the packed-word slot is a zero-width uint32 buffer
        codes=jnp.zeros((m, n_pad, 0), jnp.uint32),
        decoded=jnp.asarray(decoded),
        T_inv=jnp.asarray(eye),
        rates=jnp.zeros((m, d), jnp.int32),
        sigma=jnp.ones((m, d), jnp.float32),
        scaled_cents=jnp.zeros((m, d, 1), jnp.float32),
        T=jnp.asarray(eye),
    )
    extras = {
        "vq_A": jnp.asarray(A),
        "vq_W_half": jnp.asarray(W_half),
        "vq_rate_bits": jnp.asarray(rate_bits),
    }
    # block coding is simulated, so the ledger at the achieved rate IS the
    # physical payload (no word quantization to pad against) — and with no
    # packed rows there is no CRC framing to charge (integrity_bits = 0)
    return WireRun(ws, int(wire), int(wire), 0, extras, shards, 0)


def _vq_reencode(art, machine: int, X_new):
    """Sample the FROZEN fit-time test channel for new symbols: the streaming
    ledger grows by the channel's achieved rate per point, mirroring the
    per-symbol frozen-codebook economics."""
    if "vq_A" not in art.data:
        raise ValueError(
            "artifact has no vq channel state (was it fitted with "
            'scheme="vq"?)'
        )
    A = art.data["vq_A"][machine]
    W_half = art.data["vq_W_half"][machine]
    rate = float(np.asarray(art.data["vq_rate_bits"][machine]))
    X_new = jnp.asarray(X_new, jnp.float32)
    # deterministic fresh noise: fold the ledger state so successive updates
    # draw independent channel samples without carrying a key around
    key = jax.random.fold_in(jax.random.PRNGKey(1), art.wire_bits + machine)
    noise = jax.random.normal(key, X_new.shape, dtype=X_new.dtype)
    decoded = X_new @ A.T + noise @ W_half.T
    bits = math.ceil(X_new.shape[0] * rate)
    return decoded, bits, bits  # simulated channel: payload == ledger


VQ = register_scheme(SchemeSpec(name="vq", run=_vq_run, reencode=_vq_reencode))
