"""Capacity-padded ("bucketed") factor buffers for retrace-free streaming.

The streaming contract of :func:`~.base.update` is that consecutive in-bucket
appends are ONE device-resident jitted program: traced shapes never change, so
the jit cache hits and the warm :func:`~.base.predict` program (which reads
the same buffers) does not recompile either.  That requires every
column-growable array of a :class:`~.base.FittedProtocol` — targets, factor
columns, reconstruction rows, validity masks — to live at a padded CAPACITY
(the bucket), with the occupied prefix tracked by the device-resident
``StreamState.cols`` counter instead of by array shape.

Capacity grows geometrically (:func:`next_pow2` of the required column
count), so a stream of updates crosses O(log n) buckets total; each crossing
is the only host round-trip (``np.pad`` + re-``device_put``) and the only
retrace.  A fresh :func:`~.base.fit` produces exact-size buffers (bitwise
identical to the pre-streaming artifacts), so the FIRST update always grows —
after that, updates within a bucket are pure cache hits.

Padding is constructed so the padded programs are EXACT, not approximate:

* targets / ``alpha`` / Nyström ``W`` columns pad with zeros (zero columns
  contribute nothing to means or variances);
* dense Cholesky factors pad with the identity pattern (unit diagonal, zeros
  elsewhere), so forward/backward solves against zero right-hand sides return
  exact zeros at the padded slots (see :func:`~repro.core.nystrom.
  chol_append_at`);
* kernel cross-columns against padded basis rows are zeroed through the
  artifact's validity masks (``data["valid"]`` for the center layout,
  ``data["mask"]`` for the expert layouts) — SE kernels do NOT vanish at the
  zero point, so masking is load-bearing.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["next_pow2", "ensure_capacity"]


def next_pow2(n: int) -> int:
    """The smallest power of two >= n (the capacity bucket for n columns)."""
    n = int(n)
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


# --------------------------------------------------------------------------
# host-side pad primitives (run only at bucket crossings)
# --------------------------------------------------------------------------


def _pad_last(a, cap: int):
    a = np.asarray(jax.device_get(a))
    pad = [(0, 0)] * a.ndim
    pad[-1] = (0, cap - a.shape[-1])
    return np.pad(a, pad)


def _pad_rows(a, cap: int):
    """Grow axis -2 (row axis of (..., n, d) point buffers) to ``cap``."""
    a = np.asarray(jax.device_get(a))
    pad = [(0, 0)] * a.ndim
    pad[-2] = (0, cap - a.shape[-2])
    return np.pad(a, pad)


def _pad_chol(L, cap: int):
    """Grow (..., n, n) Cholesky factors to (..., cap, cap) with the identity
    pattern in the new slots — the contract ``chol_append_at`` appends under
    (unit pivots keep the factor SPD and make padded solve outputs exact 0)."""
    L = np.asarray(jax.device_get(L))
    n = L.shape[-1]
    out = np.zeros(L.shape[:-2] + (cap, cap), L.dtype)
    out[..., :n, :n] = L
    idx = np.arange(n, cap)
    out[..., idx, idx] = 1.0
    return out


# --------------------------------------------------------------------------
# per-protocol growth
# --------------------------------------------------------------------------

# which leaves grow, and how, per protocol.  Everything NOT listed keeps its
# fit-time shape (and device placement) untouched: the Nyström core factors
# L_KK/L_M are rank-K and never grow; broadcast data (the fixed shard bases)
# never grows; scheme extras (vq_*) are fit-frozen.
_GROWTH = {
    "center": {
        "factors": {"W": _pad_last, "alpha": _pad_last, "L": _pad_chol},
        "data": {"X_recon": _pad_rows, "sq_cols": _pad_last,
                 "sq_exact": _pad_last, "valid": _pad_last},
    },
    "broadcast": {
        "factors": {"W": _pad_last, "alpha": _pad_last},
        "data": {},
    },
    "poe": {
        "factors": {"L": _pad_chol, "alpha": _pad_last},
        "data": {"Xs": _pad_rows, "mask": _pad_last, "sq_exact": _pad_last},
    },
}


def ensure_capacity(art, n_new: int):
    """Return ``art`` (unchanged) if ``n_new`` more columns fit the current
    bucket, else a grown copy at the next power-of-two capacity.

    This is the ONE host synchronization point of the streaming path: the
    occupied-column counter is pulled off device to decide whether the bucket
    overflows.  In-bucket updates take the first branch and stay fully
    device-resident."""
    cols = int(jax.device_get(art.stream.cols))
    capacity = int(art.y.shape[-1])
    need = cols + int(n_new)
    if need <= capacity:
        return art
    return _grow(art, next_pow2(need))


def _grow(art, cap: int):
    spec = _GROWTH.get(art.protocol)
    if spec is None:
        raise NotImplementedError(
            f"streaming capacity growth is not defined for protocol "
            f"{art.protocol!r}"
        )
    factors = dict(art.factors)
    for key, pad in spec["factors"].items():
        if key in factors:
            factors[key] = jnp.asarray(pad(factors[key], cap))
    data = dict(art.data)
    for key, pad in spec["data"].items():
        if key in data:
            data[key] = jnp.asarray(pad(data[key], cap))
    y = jnp.asarray(_pad_last(art.y, cap))
    if art.impl == "mesh" and art.protocol in ("broadcast", "poe"):
        # mesh artifacts keep their grown leaves sharded along the machine
        # axis (the update program is a shard_map over them)
        from . import mesh

        msh = mesh.machine_mesh(len(art.fit_lengths))
        sharded_factors = {
            k: v for k, v in factors.items() if k in spec["factors"]
        }
        factors.update(mesh._shard_machine_axis(sharded_factors, msh))
        if art.protocol == "poe":
            sharded_data = {k: v for k, v in data.items() if k in spec["data"]}
            data.update(mesh._shard_machine_axis(sharded_data, msh))
    return dataclasses.replace(art, y=y, factors=factors, data=data)
