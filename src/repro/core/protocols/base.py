"""Shared machinery of the §5 distributed-GP protocols.

This module owns everything the protocol implementations
(:mod:`.center`, :mod:`.broadcast`, :mod:`.poe`, :mod:`.mesh`) share:

* the padded-shard layout every vmapped stage runs on (:class:`PaddedShards`),
* the wire-state container and the §4 bit-accounting formula
  (:class:`WireState`, :func:`_wire_bits`),
* the serving artifact (:class:`FittedProtocol`) and its
  :func:`fit` / :func:`predict` / :func:`update` /
  :func:`save_artifact` / :func:`load_artifact` lifecycle,
* the serve-path introspection hooks (:func:`serve_trace_count`,
  :func:`predict_op_counts`).

Protocols and wire schemes are looked up in :mod:`repro.core.registry`
(``PROTOCOLS`` / ``SCHEMES``) — this module never names a concrete protocol,
which is what lets ``register_protocol`` / ``register_scheme`` extend the
system without touching the dispatch below.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from ..gp import GPParams, gram_fn, prior_diag
from ..nystrom import nystrom_complete
from ..registry import PROTOCOLS, SCHEMES

__all__ = [
    "split_machines",
    "pad_parts",
    "PaddedShards",
    "WireState",
    "WireRun",
    "ServeHealth",
    "serve_health",
    "StreamState",
    "FittedProtocol",
    "fit",
    "predict",
    "update",
    "save_artifact",
    "load_artifact",
    "serve_trace_count",
    "update_trace_count",
    "predict_op_counts",
]


def split_machines(X, y, m: int, key) -> list[tuple[jnp.ndarray, jnp.ndarray]]:
    """Random uniform split across m machines (paper §6: 'randomly distributed
    across 40 machines')."""
    n = X.shape[0]
    perm = jax.random.permutation(key, n)
    chunks = np.array_split(np.asarray(perm), m)
    return [(jnp.asarray(X)[c], jnp.asarray(y)[c]) for c in chunks]


# --------------------------------------------------------------------------
# uniform padded shards — the layout every vmapped protocol stage runs on
# --------------------------------------------------------------------------


class PaddedShards(collections.namedtuple("PaddedShards", "X y mask lengths")):
    """(m, n_pad, d) machine shards; invalid rows are zero with mask 0.

    ``lengths`` holds the per-machine true row counts (python ints)."""

    __slots__ = ()


def pad_parts(parts) -> PaddedShards:
    m = len(parts)
    d = parts[0][0].shape[1]
    lengths = tuple(int(p[0].shape[0]) for p in parts)
    n_pad = max(lengths)
    X = np.zeros((m, n_pad, d), np.float32)
    y = np.zeros((m, n_pad), np.float32)
    mask = np.zeros((m, n_pad), np.float32)
    for j, (Xj, yj) in enumerate(parts):
        X[j, : lengths[j]] = np.asarray(Xj, np.float32)
        y[j, : lengths[j]] = np.asarray(yj, np.float32)
        mask[j, : lengths[j]] = 1.0
    return PaddedShards(jnp.asarray(X), jnp.asarray(y), jnp.asarray(mask), lengths)


class WireState(collections.namedtuple(
    "WireState", "codes decoded T_inv rates sigma scaled_cents T"
)):
    """Everything the wire protocol produced, for every machine at once.

    This is the fit-once scheme state: ``(T, T_inv, sigma, rates)`` per machine
    are the frozen codebooks/transforms that :func:`update` reuses to encode
    NEW symbols without refitting (only their ``rates.sum()`` wire bits are
    spent), and ``codes``/``scaled_cents`` feed the fused dequantize+gram
    kernel under ``gram_backend="pallas"``.

    Fields: codes (m, n_pad, W) uint32 PACKED words — the physical code plane
    (``jax_scheme.pack_codes``: each row's d codes concatenated at their
    allocated widths, W = ceil(R/32); padded rows are all-zero words; unpack
    at the machine's ``rates``).  This is the SAME buffer the mesh collectives
    move, the packed qgram kernels consume, and format-v3 checkpoints store.
    decoded (m, n_pad, d) reconstructions [padded rows zero]; T_inv (m, d, d)
    decorrelating inverses; rates (m, d) int32 per-dim bit allocation;
    sigma (m, d); scaled_cents (m, d, C) qgram decode tables; T (m, d, d)
    forward transforms.  The ``vq`` scheme fills ``decoded`` only (identity
    transforms, a zero-width word buffer — its channel state rides in the
    artifact's ``data`` dict instead)."""

    __slots__ = ()


class WireRun(collections.namedtuple(
    "WireRun",
    "state wire_bits payload_bits integrity_bits extras shards rows_demoted",
)):
    """What one ``SchemeSpec.run`` produced: the :class:`WireState`, the three
    ledgers (Theorem-1 ``wire_bits``, measured packed ``payload_bits``, CRC
    ``integrity_bits`` — all integers, all charged for what was TRANSMITTED,
    before any demotion), scheme-private ``extras``, the possibly
    fault-compacted :class:`PaddedShards` the protocol must assemble from
    (compaction moves each machine's CRC-surviving rows to the front, with
    ``lengths``/``mask`` shrunk to match), and ``rows_demoted`` — how many
    transmitted rows the receiver's CRC check rejected and masked out."""

    __slots__ = ()


def _wire_bits(rates, lengths, d: int, skip=None) -> int:
    """Paper §4 accounting: R bits/sample on the wire + side info per
    transmitting machine (the shared formula:
    :func:`repro.comm.accounting.wire_bits_formula`)."""
    from ...comm.accounting import wire_bits_formula

    return wire_bits_formula(rates, lengths, d, skip=skip)


def _mask_gram(G, mask_r, mask_c=None, pin_diag=True):
    """Zero padded rows/cols; optionally pin their diagonal to 1 so Cholesky
    stays SPD.  A point with k(·, pad)=0, y_pad=0 contributes nothing to the
    posterior, which makes the padded program bit-compatible with the
    unpadded one."""
    mask_c = mask_r if mask_c is None else mask_c
    Gm = G * (mask_r[:, None] * mask_c[None, :])
    if pin_diag:
        Gm = Gm + jnp.diag(1.0 - mask_r)
    return Gm


# --------------------------------------------------------------------------
# fit-once / serve-many: the FittedProtocol artifact
# --------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "counts", "cols", "wire_bits", "payload_bits", "integrity_bits",
        "rows_demoted",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class StreamState:
    """The device-resident mutable state of a streaming artifact.

    Everything :func:`update` changes per batch that is NOT a factor/data
    buffer lives here as int32 ARRAY leaves — per-machine row counts, the
    occupied-column counter of the capacity-padded buffers, and the three §4
    ledgers plus the CRC demotion count.  Keeping these as pytree data (not
    treedef metadata) is what makes consecutive updates and the warm predict
    share one traced program: bumping a ledger changes a leaf's value, never
    the treedef, so the jit cache keyed on (treedef, avals) still hits.

    ``counts`` (m,): true rows per machine (fit survivors + streamed rows).
    ``cols`` (): occupied column slots of the padded buffers — the append
    position of the next update.  Distinct from ``counts.sum()`` in the
    expert layouts (broadcast columns start at m*n_pad; PoE at n_pad) and
    after CRC demotions (demoted fit rows keep their padded slot).
    ``wire_bits`` / ``payload_bits`` / ``integrity_bits`` (): the Theorem-1
    ledger, the measured packed payload, and the CRC framing ledger.
    ``rows_demoted`` (): transmitted rows rejected by the receiver's CRC."""

    counts: jnp.ndarray
    cols: jnp.ndarray
    wire_bits: jnp.ndarray
    payload_bits: jnp.ndarray
    integrity_bits: jnp.ndarray
    rows_demoted: jnp.ndarray

    @classmethod
    def make(cls, counts, cols, wire_bits=0, payload_bits=0,
             integrity_bits=0, rows_demoted=0) -> "StreamState":
        i32 = lambda v: jnp.asarray(v, jnp.int32)
        return cls(
            counts=i32(counts), cols=i32(cols), wire_bits=i32(wire_bits),
            payload_bits=i32(payload_bits), integrity_bits=i32(integrity_bits),
            rows_demoted=i32(rows_demoted),
        )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["params", "y", "factors", "data", "wire", "stream"],
    meta_fields=[
        "protocol", "kernel", "gram_mode", "fuse", "gram_backend",
        "n_center", "fit_lengths", "block_order", "bits_per_sample",
        "max_bits", "impl", "scheme", "config",
    ],
)
@dataclasses.dataclass
class FittedProtocol:
    """The serving artifact of a communication-limited distributed GP.

    Produced by :func:`fit`, consumed by :func:`predict` (one jitted program;
    triangular solves only) and :func:`update` (rank-k factor growth).  It is
    a registered JAX pytree: array leaves checkpoint through
    ``repro.checkpoint`` (:func:`save_artifact` / :func:`load_artifact`,
    shardings respected on restore) and the static metadata rides in the
    treedef, so :func:`predict` retraces only when the protocol shape
    actually changes (e.g. after an :func:`update` grows the factors).

    Array fields (pytree leaves)
    ----------------------------
    params : trained :class:`~repro.core.gp.GPParams` (log-space hypers).
    y : targets in the artifact's column layout — center: (C,) flat
        [center block first]; broadcast: (C,) mask-zeroed; poe: (m, C)
        mask-zeroed — where C is the CAPACITY of the streaming buffers
        (``stream.cols`` columns occupied; a fresh fit is exact-size).
    factors : dict of cached solve factors, keyed per gram_mode —
        ``L_KK``/``W``/``L_M``/``alpha`` (Nyström woodbury form, see
        ``nystrom.nystrom_factors``) and/or ``L``/``alpha`` (dense
        ``gp.posterior_factors``).  Broadcast/PoE hold a leading machine
        axis (one batched factor set, NOT m objects).  The column-growable
        members live at capacity (padded exactly: zero columns / identity
        Cholesky slots — see :mod:`.streaming`).
    data : dict of query-time arrays — the Nyström bases (``Xc`` for center,
        ``Xs``+``mask`` for broadcast/poe), reconstructions (``X_recon``)
        with their column-validity mask (``valid``), squared norms
        (``sq_cols``/``sq_exact``/``sq_dec``), and scheme extras (the ``vq``
        test-channel state ``vq_A``/``vq_W_half``/``vq_rate_bits``).
    wire : :class:`WireState` — the frozen fit-once scheme state (codebooks,
        transforms, int codes).  :func:`update` re-encodes new symbols with
        it; the pallas backend decodes grams straight from its codes.  None
        for the zero-rate PoE baseline.
    stream : :class:`StreamState` — the device-resident row counts, occupied
        column counter, and §4 ledgers :func:`update` extends.  The legacy
        integer views (``lengths``/``wire_bits``/``payload_bits``/
        ``integrity_bits``/``rows_demoted``) are read-only properties that
        synchronize these leaves to host.

    Static metadata (treedef)
    -------------------------
    protocol / kernel / gram_mode / fuse / gram_backend / scheme — registry
    names (see :mod:`repro.core.registry`); n_center (center's exact-block
    size K), fit_lengths (per-machine FIT-TIME row counts — frozen, the
    streaming counts live in ``stream``), block_order (center's gram-row
    machine order), bits_per_sample, max_bits, impl (``"batched"``
    single-host or ``"mesh"`` machines-as-devices: factors live sharded
    along the mesh axis and :func:`predict` runs as one shard_map program
    with a psum/KL fusion epilogue), and config — the full
    :class:`~repro.core.config.DGPConfig` this artifact was fitted under
    (recorded in the checkpoint's ``meta.json``; ``None`` only on artifacts
    restored from pre-config checkpoints before defaults kick in).
    """

    params: GPParams
    y: jnp.ndarray
    factors: dict
    data: dict
    wire: WireState | None
    stream: StreamState
    protocol: str
    kernel: str
    gram_mode: str
    fuse: str
    gram_backend: str
    n_center: int
    fit_lengths: tuple
    block_order: tuple | None
    bits_per_sample: int
    max_bits: int
    impl: str = "batched"
    scheme: str = "per_symbol"
    config: object | None = None  # DGPConfig (opaque here: no import cycle)

    # -- legacy integer views (host sync of the StreamState leaves) ---------

    @property
    def lengths(self) -> tuple:
        """Per-machine true row counts (fit survivors + streamed rows)."""
        return tuple(
            int(v) for v in np.asarray(jax.device_get(self.stream.counts))
        )

    @property
    def wire_bits(self) -> int:
        """The paper's §4 Theorem-1 ledger, extended by every update."""
        return int(jax.device_get(self.stream.wire_bits))

    @property
    def payload_bits(self) -> int:
        """The packed payload PHYSICALLY moved (whole uint32 words per valid
        row + side info); exceeds the ledger only by per-word padding."""
        return int(jax.device_get(self.stream.payload_bits))

    @property
    def integrity_bits(self) -> int:
        """The CRC framing ledger (accounting.CRC_BITS per transmitted row)."""
        return int(jax.device_get(self.stream.integrity_bits))

    @property
    def rows_demoted(self) -> int:
        """Transmitted rows the receiver's CRC check demoted to masked rows."""
        return int(jax.device_get(self.stream.rows_demoted))

    # -- conveniences (the paper-facing entry points return artifacts) ------

    def predict(self, X_star, available=None):
        """Serve one query batch from the cached factors — see :func:`predict`."""
        return predict(self, X_star, available)

    def health(self, available=None) -> "ServeHealth":
        """Degradation status of this artifact — see :func:`serve_health`."""
        return serve_health(self, available)

    def update(self, X_new, y_new, machine: int = 0):
        """Stream in new points — see :func:`update`."""
        return update(self, X_new, y_new, machine)

    def save(self, directory: str, step: int = 0) -> str:
        """Checkpoint this artifact — see :func:`save_artifact`."""
        return save_artifact(self, directory, step)

    def _gram(self, params):
        """Rebuild the TRAIN-time gram at the given params (debug/inspection;
        the serve path never calls this — predictions run off cached
        factors).  Center protocol, xla assembly."""
        if self.protocol != "center":
            raise NotImplementedError("_gram inspection is center-protocol only")
        k = gram_fn(self.kernel)
        X = self.data["X_recon"]
        if self.gram_mode == "direct":
            return k(params, X)
        Xc = self.data["Xc"]
        G_KK = k(params, Xc)
        G_KN = k(params, Xc, X)
        if self.gram_mode == "nystrom_fitc":
            exact = prior_diag(self.kernel, params, self.data["sq_exact"])
            return nystrom_complete(G_KK, G_KN, exact_diag=exact)
        return nystrom_complete(G_KK, G_KN)


def _as_config(
    bits_per_sample, protocol, kernel, steps, lr, gram_mode, fuse, method,
    gram_backend, max_bits, train_impl, impl, scheme,
):
    """The loose legacy kwargs as one validated DGPConfig (``method`` wins
    over ``fuse`` for the PoE protocol, matching the old signatures)."""
    from ..config import DGPConfig

    return DGPConfig(
        protocol=protocol,
        scheme=scheme,
        kernel=kernel,
        fusion=method if protocol == "poe" else fuse,
        impl=impl,
        gram_backend=gram_backend,
        gram_mode=gram_mode,
        bits_per_sample=int(bits_per_sample),
        max_bits=int(max_bits),
        steps=int(steps),
        lr=float(lr),
        train_impl=train_impl,
    )


def _apply_fit_faults(parts, cfg):
    """Dataset-level fault injection at fit() entry (drop/NaN shards from
    ``cfg.faults``) plus the guards that make the remaining fleet trainable:
    the §5.1 center and the broadcast/PoE training machine (machine 0) must
    survive — predict-time availability masks are where arbitrary machine
    loss is served.  Returns ``(parts, rows_removed)``."""
    plan = getattr(cfg, "faults", None) if cfg is not None else None
    if plan is None:
        return parts, 0
    from ...faults import apply_to_parts

    new_parts, removed = apply_to_parts(parts, plan)
    lengths = [int(p[0].shape[0]) for p in new_parts]
    if not any(lengths):
        raise ValueError(
            "fault plan removed every row from every machine — nothing to fit"
        )
    if cfg.protocol == "center" and lengths[cfg.center] == 0:
        raise ValueError(
            f"fault plan emptied the center machine ({cfg.center}) — the "
            "§5.1 protocol cannot fit without its exact block; drop a "
            "non-center machine or serve an old artifact degraded instead"
        )
    if cfg.protocol in ("broadcast", "poe") and lengths[0] == 0:
        raise ValueError(
            "fault plan emptied machine 0, where broadcast/poe train their "
            "hyperparameters — drop a different machine (prediction-time "
            "availability masks handle arbitrary loss)"
        )
    return new_parts, removed


def fit(
    parts,
    bits_per_sample: int = 0,
    protocol: str = "center",
    *,
    kernel: str = "se",
    steps: int = 150,
    lr: float = 0.05,
    params: GPParams | None = None,
    gram_mode: str = "nystrom",
    fuse: str = "kl",
    method: str = "rbcm",
    gram_backend: str = "xla",
    max_bits: int | None = None,
    train_impl: str = "scan",
    impl: str = "batched",
    scheme: str = "per_symbol",
) -> FittedProtocol:
    """Run a distributed-GP protocol ONCE and return the serving artifact.

    This is the fit half of the fit/predict split: wire protocol (scheme fit +
    encode + decode, one vmapped jit), hyperparameter training (one lax.scan
    program), and ONE factorization of every predictive the protocol needs.
    The returned :class:`FittedProtocol` then serves any number of
    :func:`predict` query batches with no scheme refit and no Cholesky
    refactorization, supports streaming :func:`update`, and checkpoints via
    :func:`save_artifact`.

    protocol="center" (§5.1): every machine quantizes toward the center's
    covariance; the center Nyström-completes and holds one factor set.
    protocol="broadcast" (§5.2): every machine broadcasts once; m local
    Nyström factor sets are built under one vmap and fused (``fuse``: a
    ``repro.core.registry.FUSIONS`` name — "kl" = eqs. 62-64 barycenter, or
    a PoE-family combiner).
    protocol="poe": the zero-rate baseline (``method``: poe/gpoe/bcm/rbcm);
    ``bits_per_sample`` is ignored and the wire ledger is 0.

    scheme="per_symbol" (§4.2, default) puts int codes on the wire;
    scheme="vq" simulates the §4.1 Theorem-2 optimal test channel at the
    matched bit budget (batched impl, xla backend).

    impl="batched" (default) simulates the machines under one vmapped jit;
    impl="mesh" puts machines on a real device mesh — the wire protocol,
    factor builds, and (broadcast/PoE) predict run as shard_map programs
    whose only inter-machine channel is ``repro.comm``, per-machine factors
    come out sharded along the mesh axis, and the wire ledger is computed
    from what the collectives actually move.

    This is the engine under :meth:`repro.core.api.DistributedGP.fit`; prefer
    the facade (one validated :class:`~repro.core.config.DGPConfig` instead
    of loose kwargs) in new code.
    """
    if impl not in ("batched", "mesh"):
        raise ValueError(f'fit() impl must be "batched" or "mesh", got {impl!r}')
    from .. import quantizers as Q

    cfg = _as_config(
        bits_per_sample, protocol, kernel, steps, lr, gram_mode, fuse, method,
        gram_backend, Q.DEFAULT_MAX_BITS if max_bits is None else max_bits,
        train_impl, impl, scheme,
    )
    return PROTOCOLS.get(cfg.protocol).fit(parts, cfg, params)


# --------------------------------------------------------------------------
# predict: one jitted program per artifact, cached factors only
# --------------------------------------------------------------------------

# Incremented INSIDE the traced function body, so it counts (re)traces, not
# calls: a warm serve loop must leave it flat (benchmarks/serve_bench.py and
# tests/test_serving.py assert exactly that).
_SERVE_TRACES: collections.Counter = collections.Counter()


def serve_trace_count(protocol: str = "center") -> int:
    """How many times :func:`predict` has been (re)traced for a protocol —
    a warm serve loop holds this constant (no refit, no recompile)."""
    return _SERVE_TRACES[protocol]


def _machine_index(j):
    """The update() machine index as a device scalar via an EXPLICIT
    device_put of a numpy scalar.  ``jnp.int32(j)`` would materialize the
    same buffer through an IMPLICIT host-to-device transfer, which the
    strict-mode runtime contract (``jax.transfer_guard("disallow")`` around
    the streaming-update tests) rejects."""
    return jax.device_put(np.int32(j))


def _predict_impl(art: FittedProtocol, X_star, avail=None):
    _SERVE_TRACES[art.protocol] += 1  # runs at trace time only
    p = art.params
    noise = jnp.exp(p.log_noise)
    # tripwire: non-finite query rows are sanitized before the kernel map
    # (one NaN row would otherwise poison the whole batch through the solve)
    # and answered with the prior predictive below.  For finite inputs every
    # select is an identity, so the healthy path is bitwise unchanged.
    finite_row = jnp.isfinite(X_star).all(axis=-1)
    Xq = jnp.where(finite_row[:, None], X_star, 0.0)
    sq_star = jnp.sum(Xq**2, -1)
    g_ss = prior_diag(art.kernel, p, sq_star)
    mu, var = PROTOCOLS.get(art.protocol).predict(
        art, Xq, sq_star, g_ss, noise, avail
    )
    ok = finite_row & jnp.isfinite(mu) & jnp.isfinite(var)
    mu = jnp.where(ok, mu, 0.0)
    var = jnp.where(ok, var, g_ss + noise)  # degrade to the prior, not NaN
    return mu, var


_predict_jit = jax.jit(_predict_impl)


def _uses_mesh_predict(art: FittedProtocol) -> bool:
    # §5.1 serving is center-local by construction (one factor set at the
    # center, nothing to fuse) — center artifacts serve on the host path
    return art.impl == "mesh" and art.protocol in ("broadcast", "poe")


def _availability(art: FittedProtocol, available):
    """Normalize a machine-availability mask to (m,) float32 — or ``None``
    for the all-alive fast path (statically identical to the pre-fault
    program).  ``None`` in means "derive from the artifact": machines whose
    shards were emptied by fit-time faults are marked down automatically."""
    # fit_lengths is the sync-free source of truth for the zero pattern:
    # update() refuses machines that transmitted nothing at fit time, so a
    # machine's row count is zero iff its FIT row count is zero
    m = len(art.fit_lengths)
    if available is None:
        if all(n > 0 for n in art.fit_lengths):
            return None
        return jnp.asarray([1.0 if n > 0 else 0.0 for n in art.fit_lengths],
                           jnp.float32)
    av = np.asarray(available, np.float32).reshape(-1)
    if av.shape[0] != m:
        raise ValueError(
            f"available mask has {av.shape[0]} entries for m={m} machines"
        )
    return jnp.asarray((av > 0).astype(np.float32))


def predict(art: FittedProtocol, X_star, available=None):
    """Serve one query batch from a fitted artifact: (mean, var) at X_star.

    ONE jitted program per artifact shape, O(t) per query batch: the cross
    inner products against the stored bases, the kernel map, and triangular
    solves against the cached factors.  No scheme refit, no Cholesky
    refactorization, no hyperparameter step happens here — verify with
    :func:`predict_op_counts` / :func:`serve_trace_count`.  Retraces only
    when the artifact's shapes change (a fresh :func:`fit`, an
    :func:`update`, a new query-batch size, or a new availability pattern).
    Mesh broadcast/PoE artifacts serve through one shard_map program with a
    psum/KL fusion epilogue instead (:func:`.mesh._predict_mesh_impl`).

    ``available``: optional (m,) machine-availability mask (1 = alive) for
    degraded-mode serving — broadcast/PoE fusions renormalize over the
    surviving experts (variance inflated accordingly, see
    docs/fault_model.md); the center protocol serves its last-good factor
    set regardless (the center holds everything), with the loss reported by
    :func:`serve_health`.  ``None`` derives the mask from the artifact
    (machines emptied by fit-time faults are already marked down)."""
    X_star = jnp.asarray(X_star, jnp.float32)
    avail = _availability(art, available)
    if _uses_mesh_predict(art):
        from . import mesh

        return mesh._predict_mesh_jit(art, X_star, avail)
    return _predict_jit(art, X_star, avail)


# --------------------------------------------------------------------------
# update: streaming append via rank-k factor updates (device-resident)
# --------------------------------------------------------------------------

# Incremented INSIDE each protocol's traced update body (the serve-trace
# idiom): consecutive in-bucket update() calls must leave it flat —
# tests/test_streaming.py and benchmarks/stream_bench.py assert exactly that.
_UPDATE_TRACES: collections.Counter = collections.Counter()


def update_trace_count(protocol: str = "center") -> int:
    """How many times the streaming :func:`update` program has been
    (re)traced for a protocol — consecutive in-bucket updates hold this
    constant (the retrace-free streaming contract; a bucket crossing costs
    exactly one retrace)."""
    return _UPDATE_TRACES[protocol]


def update(art: FittedProtocol, X_new, y_new, machine: int = 0) -> FittedProtocol:
    """Stream (X_new, y_new) arriving at ``machine`` into a fitted artifact.

    The fit-once economics in action: machine ``machine``'s FROZEN scheme
    state (codebooks + decorrelating transform fitted at :func:`fit` time;
    the test-channel parameters for ``scheme="vq"``) re-encodes only the new
    symbols, charging the frozen per-machine rate to the ledger — no scheme
    refit, no new side info.  The cached factors then grow by rank-k updates
    (``nystrom.chol_update_rank`` for the Nyström woodbury core,
    ``nystrom.chol_append_at`` for dense factors) written IN PLACE into the
    capacity-padded buffers (:mod:`.streaming`), so the whole append runs as
    ONE device-resident jitted program whose traced shapes never change
    within a bucket: consecutive updates hit the jit cache
    (:func:`update_trace_count` stays flat), and the warm :func:`predict`
    program reads the same buffers, so the first predict after an in-bucket
    update does not recompile either.  Per-symbol streams run the full wire
    plane (encode→pack→CRC→unpack→decode) INSIDE the traced program; the
    ``machine`` index is traced too, so every machine shares one cache
    entry.  Returns a NEW artifact (the input is unchanged).

    Center protocol: points landing on the center are exact and cost 0 wire
    bits; the rank-K Nyström basis stays fixed either way (appended points
    extend the columns, not the basis).  Broadcast: default "nystrom" mode
    only.  PoE: the new points extend ``machine``'s expert (zero-rate,
    exact).  A machine that transmitted no rows at fit time (dropped or
    fully demoted) has no frozen codebooks and is REFUSED.  Under a
    ``flip_rate`` fault plan the streamed batch is corrupted on the wire
    like a fit-time batch: CRC-failing rows are demoted (only the new rows
    are at risk), the full transmission is still charged to the ledgers.
    Within-tolerance agreement with a from-scratch refit on the concatenated
    data is locked by tests/test_serving.py and tests/test_streaming.py."""
    X_new = jnp.asarray(X_new, jnp.float32)
    y_new = jnp.asarray(y_new, jnp.float32)
    if X_new.ndim != 2 or y_new.ndim != 1 or y_new.shape[0] != X_new.shape[0]:
        raise ValueError("update expects X_new (n_new, d), y_new (n_new,)")
    m = len(art.fit_lengths)
    if not 0 <= machine < m:
        raise ValueError(f"machine {machine} out of range (m={m})")
    if art.fit_lengths[machine] == 0:
        raise ValueError(
            f"machine {machine} transmitted no rows at fit time (dropped or "
            "fully demoted) — it has no frozen codebooks to stream under; "
            "route the batch to a surviving machine or refit"
        )
    # tripwire: a NaN/Inf point would poison the rank-k factor growth (and
    # every subsequent predict) — drop hostile rows, loudly, instead
    finite = np.isfinite(np.asarray(X_new)).all(axis=1) & np.isfinite(
        np.asarray(y_new)
    )
    if not finite.all():
        import warnings

        warnings.warn(
            f"update(): dropping {int((~finite).sum())} non-finite point(s) "
            f"of {finite.size} (machine {machine})",
            stacklevel=2,
        )
        if not finite.any():
            return art  # nothing usable arrived; the artifact is unchanged
        keep = jnp.asarray(np.flatnonzero(finite))
        X_new, y_new = X_new[keep], y_new[keep]
    if X_new.shape[0] == 0:
        return art  # a (0, d) batch: nothing to append, nothing to charge
    pre = _prepare_update(art, X_new, y_new, machine)
    if isinstance(pre, FittedProtocol):
        return pre  # every transmitted row was demoted: ledger-only bump
    X_new, y_new, pre = pre
    from . import streaming

    art = streaming.ensure_capacity(art, X_new.shape[0])
    return PROTOCOLS.get(art.protocol).update(art, X_new, y_new, machine, pre)


def _prepare_update(art: FittedProtocol, X_new, y_new, machine: int):
    """Host-side update prep: decide which re-encode path the batch takes.

    Returns ``(X_new, y_new, pre)`` where ``pre`` is either ``None`` — the
    fully-traced path: the protocol's jitted update program re-encodes
    in-jit via ``SchemeSpec.reencode_traced`` (per-symbol transmitting
    machines; one cache entry shared by every machine) — or a 5-tuple
    ``(decoded, wire_add, payload_add, integrity_add, demoted_add)`` of
    precomputed arrays (the vq scheme's host-sampled channel, the center's
    own exact points, and fault-corrupted batches).  When a fault plan
    demotes EVERY row, returns the ledger-bumped artifact directly."""
    n_new = X_new.shape[0]
    spec = SCHEMES.get(art.scheme)
    center = art.block_order[0] if art.block_order else 0
    is_center_point = art.protocol == "center" and machine == center
    transmits = art.wire is not None and art.protocol != "poe" \
        and not is_center_point
    plan = getattr(art.config, "faults", None) if art.config is not None \
        else None
    fitc_side = 32 * n_new if (
        art.protocol == "center" and art.gram_mode == "nystrom_fitc"
    ) else 0  # exact |x|^2 side channel rides along with transmitted rows

    if transmits and plan is not None and \
            getattr(plan, "flip_rate", 0.0) > 0.0 and \
            spec.update_corrupt is not None:
        keep_idx, decoded, w_add, p_add, i_add, demoted = spec.update_corrupt(
            art, machine, X_new, plan
        )
        w_add, p_add = w_add + fitc_side, p_add + fitc_side
        if keep_idx.size == 0:
            # the receiver kept nothing, but the bits still moved: charge the
            # ledgers and the demotion count, leave factors/counts untouched
            s = art.stream
            return dataclasses.replace(art, stream=StreamState.make(
                s.counts, s.cols,
                s.wire_bits + w_add, s.payload_bits + p_add,
                s.integrity_bits + i_add, s.rows_demoted + demoted,
            ))
        idx = jnp.asarray(keep_idx)
        pre = (decoded, jnp.int32(w_add), jnp.int32(p_add), jnp.int32(i_add),
               jnp.int32(demoted))
        return X_new[idx], y_new[idx], pre
    if transmits and spec.reencode_traced is None:
        # host-side scheme (vq samples its simulated channel eagerly); its
        # test-channel stream carries no CRC framing (integrity delta 0)
        decoded, w_add, p_add = spec.reencode(art, machine, X_new)
        pre = (jnp.asarray(decoded, jnp.float32), jnp.int32(w_add + fitc_side),
               jnp.int32(p_add + fitc_side), jnp.int32(0), jnp.int32(0))
        return X_new, y_new, pre
    if is_center_point:
        # the center's own data is local: exact, zero wire cost
        pre = (X_new, jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0))
        return X_new, y_new, pre
    # per-symbol transmitting machines (and the zero-rate PoE experts, which
    # never re-encode): fully traced — the jitted program does the wire work
    return X_new, y_new, None


def _reencode(art: FittedProtocol, machine: int, X_new):
    """(X̂, wire_bits, payload_bits) for new symbols under ``machine``'s
    frozen scheme — dispatched on the artifact's wire scheme (registry
    lookup).  Per-symbol streams pass through the packed code plane (encode
    -> pack -> unpack -> decode), so the payload charge is whole uint32
    words per point while the ledger charge is the frozen allocated rate."""
    return SCHEMES.get(art.scheme).reencode(art, machine, X_new)


# --------------------------------------------------------------------------
# degraded-mode health reporting
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeHealth:
    """Degradation status of a serving artifact — what :func:`predict` is
    actually working with, instead of NaNs.

    status : ``"ok"`` (full fleet, nothing demoted) or ``"degraded"``.
    machines / machines_lost : fleet size and the indices serving no rows
        (dropped at fit time or masked out by the availability argument).
    rows_demoted : transmitted rows the receiver's CRC check rejected.
    variance_inflation : the factor applied to the fused predictive variance
        by the KL barycenter's survivor renormalization (``m / m_alive``);
        1.0 for precision-weighted PoE-family fusions (their variance widens
        intrinsically as experts leave) and for the center protocol."""

    status: str
    machines: int
    machines_lost: tuple
    rows_demoted: int
    variance_inflation: float


def serve_health(art: FittedProtocol, available=None) -> ServeHealth:
    """Report what :func:`predict` degrades to under the given availability
    (``None`` = derived from the artifact, as in :func:`predict`)."""
    m = len(art.fit_lengths)
    avail = _availability(art, available)
    if avail is None:
        alive = [True] * m
    else:
        alive = [bool(a) for a in np.asarray(avail) > 0]
    lost = tuple(
        j for j in range(m) if not alive[j] or art.fit_lengths[j] == 0
    )
    n_alive = m - len(lost)
    demoted = int(getattr(art, "rows_demoted", 0))
    inflation = 1.0
    if lost and art.protocol in ("broadcast", "poe") and art.fuse == "kl" \
            and n_alive > 0:
        inflation = m / n_alive
    status = "ok" if not lost and demoted == 0 else "degraded"
    return ServeHealth(
        status=status, machines=m, machines_lost=lost,
        rows_demoted=demoted, variance_inflation=inflation,
    )


# --------------------------------------------------------------------------
# artifact persistence (repro.checkpoint) + serve-path introspection
# --------------------------------------------------------------------------


def save_artifact(art: FittedProtocol, directory: str, step: int = 0) -> str:
    """Checkpoint a fitted artifact: array leaves through
    ``repro.checkpoint.save_checkpoint`` (atomic npz), static metadata to a
    sidecar json — including the full :class:`~repro.core.config.DGPConfig`
    and an artifact format version, so :func:`load_artifact` can rebuild the
    exact configuration years later.  Predictions from the restored artifact
    are bitwise identical (tests/test_serving.py)."""
    from ...checkpoint import save_artifact as _save
    from ..config import ARTIFACT_FORMAT_VERSION

    cfg = getattr(art, "config", None)
    meta = {
        "format_version": ARTIFACT_FORMAT_VERSION,
        "protocol": art.protocol, "kernel": art.kernel,
        "gram_mode": art.gram_mode, "fuse": art.fuse,
        "gram_backend": art.gram_backend, "n_center": art.n_center,
        "lengths": list(art.lengths),
        "fit_lengths": list(art.fit_lengths),  # v5: frozen fit-time counts
        "block_order": list(art.block_order) if art.block_order is not None else None,
        "bits_per_sample": art.bits_per_sample, "max_bits": art.max_bits,
        "wire_bits": art.wire_bits, "has_wire": art.wire is not None,
        "payload_bits": art.payload_bits,  # v3: measured packed payload
        "integrity_bits": art.integrity_bits,  # v4: CRC framing ledger
        "rows_demoted": art.rows_demoted,
        "impl": art.impl,  # provenance; restore is always single-host
        "scheme": art.scheme,
        "config": cfg.asdict() if cfg is not None else None,
    }
    return _save(directory, step, art, meta)


def _pack_legacy_wire(wire: WireState, meta: dict) -> WireState:
    """Pre-v3 wire state (unpacked int32 codes) -> the packed code plane."""
    from ...comm.accounting import row_bits
    from .. import jax_scheme

    m, n_pad, d = wire.codes.shape
    if meta.get("scheme", "per_symbol") == "vq":
        # vq never had codes (the stored plane was all -1 sentinels)
        return wire._replace(codes=jnp.zeros((m, n_pad, 0), jnp.uint32))
    rbits = row_bits(meta["bits_per_sample"], d, meta["max_bits"])
    words = jax.vmap(
        lambda c, r: jax_scheme.pack_codes(c, r, total_bits=rbits)
    )(jnp.asarray(wire.codes), jnp.asarray(wire.rates))
    return wire._replace(codes=words)


def load_artifact(directory: str, step: int | None = None, shardings=None) -> FittedProtocol:
    """Restore a :func:`save_artifact` checkpoint into a fresh artifact.

    Always restores as a SINGLE-HOST artifact (``impl="batched"``): a mesh
    fit's checkpoint round-trips to an equivalent host-serving artifact
    (sharded factors were gathered at save time).  Format version 3 stores
    the wire codes PACKED (uint32 words — 4-16x smaller than the old int32
    plane at b<=8); older checkpoints store unpacked int32 codes, which are
    packed on load so every restored artifact carries the same in-memory
    representation (predictions are bitwise identical either way —
    tests/test_ckpt_backcompat.py).  Pre-redesign checkpoints
    (format version 1: no ``config``/``scheme`` in ``meta.json``) load too —
    the scheme defaults to ``per_symbol`` and a
    :class:`~repro.core.config.DGPConfig` is reconstructed from the legacy
    metadata fields.  Format version 5 persists the streaming state
    (``stream/*`` leaves: per-machine counts, occupied-column counter, the
    ledgers) and capacity-padded factor buffers; v1-v4 checkpoints load at
    exact capacity with the state rebuilt from the json integers (their
    first :func:`update` pads up), and pre-v5 PoE streamed extras are folded
    into the shared capacity layout.  ``shardings``:
    optional — a single ``Sharding``/device applied to every leaf, or a
    ``{leaf_key: sharding}`` dict (keys as in the npz: ``factors/W``,
    ``data/Xc``, ``wire/codes``, ...) for per-leaf placement; leaves are
    ``jax.device_put`` into place on restore."""
    from ...checkpoint import load_artifact_arrays
    from ..config import ARTIFACT_FORMAT_VERSION, DGPConfig

    meta, arrays = load_artifact_arrays(directory, step)
    version = meta.get("format_version", 1)  # pre-redesign checkpoints: v1
    if version > ARTIFACT_FORMAT_VERSION:
        raise ValueError(
            f"artifact format version {version} is newer than this code "
            f"supports ({ARTIFACT_FORMAT_VERSION}) — upgrade the package to "
            "load this checkpoint"
        )

    def put(key):
        arr = arrays[key]
        sh = shardings.get(key) if isinstance(shardings, dict) else shardings
        return jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)

    params = GPParams(*(put(f"params/{f}") for f in GPParams._fields))
    factors = {
        k.split("/", 1)[1]: put(k) for k in arrays if k.startswith("factors/")
    }
    data = {k.split("/", 1)[1]: put(k) for k in arrays if k.startswith("data/")}
    wire = None
    if meta["has_wire"]:
        wire = WireState(*(put(f"wire/{f}") for f in WireState._fields))
        if version < 3 and wire.codes.dtype != jnp.uint32:
            # pre-v3 checkpoints stored the unpacked int32 code plane; pack
            # it into the uint32 wire representation every consumer (qgram
            # kernels, update(), re-save) now shares.  -1 sentinel rows pack
            # to all-zero words, matching a fresh fit's layout.
            wire = _pack_legacy_wire(wire, meta)
    cfg_dict = meta.get("config")
    config = (
        DGPConfig.from_dict(cfg_dict) if cfg_dict
        else DGPConfig.from_legacy_meta(meta)
    )
    # restored artifacts always serve single-host; the recorded config keeps
    # the fit-time impl as provenance, the reconstruction pins "batched"
    config = dataclasses.replace(config, impl="batched")
    protocol, y = meta["protocol"], put("y")
    stream_fields = [f.name for f in dataclasses.fields(StreamState)]
    if all(f"stream/{f}" in arrays for f in stream_fields):
        # v5 streaming checkpoints persist the StreamState leaves directly
        # (checked by presence, not version: re-stamped copies keep working)
        stream = StreamState(*(put(f"stream/{f}") for f in stream_fields))
    else:
        # v1-v4: derive the occupied-column count from the exact-size arrays
        # (pre-streaming artifacts ARE their own capacity) and lift the json
        # integer ledgers onto device
        if protocol == "poe":
            cols = int(y.shape[-1])
            if "X_extra" in data:  # legacy streamed extras: folded below
                cols += int(data["X_extra"].shape[0])
        else:
            cols = int(y.shape[0])
        stream = StreamState.make(
            meta["lengths"], cols, meta["wire_bits"],
            meta.get("payload_bits", 0),  # pre-v3: not recorded
            meta.get("integrity_bits", 0),  # pre-v4: not recorded
            meta.get("rows_demoted", 0),
        )
    if protocol == "center" and "valid" not in data:
        # pre-v5 center artifacts carried no column-validity mask (every
        # column was live); the padded predict path multiplies it in
        data["valid"] = jnp.ones_like(y)
    if protocol == "poe" and "X_extra" in data:
        # pre-v5 streamed PoE extras lived in side arrays (X_extra/extra_mask/
        # y_extra); fold them into the capacity layout every expert now
        # shares — the dense factors already carry the [n_pad | extras]
        # column order, so the fold appends in that same order
        Xe = data.pop("X_extra")
        em = data.pop("extra_mask")
        ye = data.pop("y_extra")
        mcnt = em.shape[0]
        y = jnp.concatenate([y, ye[None, :] * em], axis=1)
        data["Xs"] = jnp.concatenate(
            [data["Xs"], jnp.broadcast_to(Xe[None], (mcnt,) + Xe.shape)], axis=1
        )
        data["mask"] = jnp.concatenate([data["mask"], em], axis=1)
        sq_e = jnp.sum(Xe**2, -1)
        data["sq_exact"] = jnp.concatenate(
            [data["sq_exact"], jnp.broadcast_to(sq_e[None], em.shape)], axis=1
        )
    return FittedProtocol(
        params=params, y=y, factors=factors, data=data, wire=wire,
        stream=stream,
        protocol=protocol, kernel=meta["kernel"],
        gram_mode=meta["gram_mode"], fuse=meta["fuse"],
        gram_backend=meta["gram_backend"], n_center=meta["n_center"],
        fit_lengths=tuple(meta.get("fit_lengths", meta["lengths"])),
        block_order=tuple(meta["block_order"]) if meta["block_order"] is not None else None,
        bits_per_sample=meta["bits_per_sample"], max_bits=meta["max_bits"],
        impl="batched",
        scheme=meta.get("scheme", "per_symbol"), config=config,
    )


def predict_op_counts(art: FittedProtocol, X_star, ops=("cholesky", "eigh")) -> dict:
    """Count primitives in the :func:`predict` program for this artifact —
    the structural serve-path check: a warm predict must contain ZERO
    ``cholesky`` (no refactorization) and ZERO ``eigh`` (no scheme refit)
    equations.  Mesh artifacts are checked on their actual shard_map serve
    program (the walk descends into the shard_map body jaxpr).

    Thin wrapper over :mod:`repro.analysis` (which generalizes this into the
    declarative :func:`repro.analysis.check_contracts` rule system); kept for
    benchmarks/serve_bench.py's BENCH_serve.json and the existing test
    suites.  Trace-neutral: the abstract trace this performs is excluded from
    ``serve_trace_count``, so callers may order it freely around retrace
    assertions."""
    from ...analysis.contracts import predict_jaxpr

    jaxpr = predict_jaxpr(art, X_star)
    counts = {op: 0 for op in ops}
    for eqn in _walk_jaxpr(jaxpr.jaxpr):
        if eqn.primitive.name in counts:
            counts[eqn.primitive.name] += 1
    return counts


def _walk_jaxpr(jaxpr):
    from ...analysis.jaxpr_walk import walk_jaxpr

    return walk_jaxpr(jaxpr)
