"""repro.core — the paper's contribution as a composable JAX library.

Layers:
  quantizers / rate_distortion / transforms / distortion  — §4 math
  schemes                                                 — the 3 wire protocols
  gp / nystrom / poe / sparse_gp / fusion                 — GP substrate
  distributed_gp                                          — §5 protocols
"""
from . import quantizers, rate_distortion, transforms, distortion, schemes
from . import gp, nystrom, poe, sparse_gp, fusion, distributed_gp

from .schemes import PerSymbolScheme, OptimalScheme, DimReductionScheme, PCAScheme
from .gp import GPModel, GPParams, train_gp, init_params
from .sparse_gp import SGPR, train_sgpr
from .distributed_gp import (
    split_machines,
    single_center_gp,
    broadcast_gp,
    poe_baseline,
    FittedProtocol,
    fit,
    predict,
    update,
    save_artifact,
    load_artifact,
)

__all__ = [
    "quantizers", "rate_distortion", "transforms", "distortion", "schemes",
    "gp", "nystrom", "poe", "sparse_gp", "fusion", "distributed_gp",
    "PerSymbolScheme", "OptimalScheme", "DimReductionScheme", "PCAScheme",
    "GPModel", "GPParams", "train_gp", "init_params",
    "SGPR", "train_sgpr",
    "split_machines", "single_center_gp", "broadcast_gp", "poe_baseline",
    "FittedProtocol", "fit", "predict", "update", "save_artifact", "load_artifact",
]
