"""repro.core — the paper's contribution as a composable JAX library.

Layers:
  quantizers / rate_distortion / transforms / distortion  — §4 math
  schemes                                                 — the 3 wire protocols
  gp / nystrom / poe / sparse_gp / fusion                 — GP substrate
  registry / config / api / protocols                     — §5 protocols behind
                                                            the DistributedGP
                                                            estimator facade

The front door is ``DistributedGP(DGPConfig(...))``; the legacy module-level
entry points (``single_center_gp`` & co.) remain as deprecated wrappers in
``distributed_gp`` (see docs/migration.md).
"""
from . import quantizers, rate_distortion, transforms, distortion, schemes
from . import gp, nystrom, poe, sparse_gp, fusion
from . import registry, config, protocols, api, distributed_gp, fleet

from .schemes import PerSymbolScheme, OptimalScheme, DimReductionScheme, PCAScheme
from .gp import GPModel, GPParams, train_gp, init_params
from .sparse_gp import SGPR, train_sgpr
from .registry import (
    KERNELS, SCHEMES, FUSIONS, PROTOCOLS,
    register_kernel, register_scheme, register_fusion, register_protocol,
    KernelSpec, SchemeSpec, FusionSpec, ProtocolSpec,
)
from .config import DGPConfig
from .api import DistributedGP
from .protocols import (
    split_machines,
    FittedProtocol,
    save_artifact,
    load_artifact,
)
from .fleet import (
    FleetStack,
    ArtifactCache,
    ArtifactStore,
    stack_artifacts,
    pad_to_capacity,
    scale_targets,
    bucket_key,
    fleet_trace_count,
)
# legacy entry points: deprecated wrappers (warn once, then delegate)
from .distributed_gp import (
    single_center_gp,
    broadcast_gp,
    poe_baseline,
    fit,
    predict,
    update,
)

__all__ = [
    "quantizers", "rate_distortion", "transforms", "distortion", "schemes",
    "gp", "nystrom", "poe", "sparse_gp", "fusion",
    "registry", "config", "protocols", "api", "distributed_gp", "fleet",
    "PerSymbolScheme", "OptimalScheme", "DimReductionScheme", "PCAScheme",
    "GPModel", "GPParams", "train_gp", "init_params",
    "SGPR", "train_sgpr",
    "KERNELS", "SCHEMES", "FUSIONS", "PROTOCOLS",
    "register_kernel", "register_scheme", "register_fusion", "register_protocol",
    "KernelSpec", "SchemeSpec", "FusionSpec", "ProtocolSpec",
    "DGPConfig", "DistributedGP",
    "split_machines", "single_center_gp", "broadcast_gp", "poe_baseline",
    "FittedProtocol", "fit", "predict", "update", "save_artifact", "load_artifact",
    "FleetStack", "ArtifactCache", "ArtifactStore", "stack_artifacts",
    "pad_to_capacity", "scale_targets", "bucket_key", "fleet_trace_count",
]
