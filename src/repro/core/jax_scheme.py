"""Fully-traceable (jit/shard_map-compatible) per-symbol scheme (§4.2).

The host-side PerSymbolScheme uses scipy + a heap; inside a compiled collective
we need the same math as jax ops:

  * decorrelating transform via jnp.linalg.eigh,
  * greedy Algorithm-1 bit allocation as a fori_loop over total_bits of
    argmax(Delta sigma) steps — identical output to the heap version,
  * quantize/dequantize with rate-indexed padded codebook tables.

This is what repro.comm's quantized collectives run on-device.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from . import quantizers as Q
from .linalg_safe import eigh_sym

__all__ = [
    "fit_scheme",
    "fit_scheme_batched",
    "encode",
    "decode",
    "roundtrip",
    "codebook_cap",
    "scheme_tables",
    "scaled_centroids",
    "scaled_centroids_batched",
    "masked_second_moment",
    "pack_codes",
    "unpack_codes",
    "row_words",
    "crc_words",
    "WORD_BITS",
    "SchemeState",
]


def masked_second_moment(X, mask=None):
    """S = X_valid^T X_valid / n_valid for a padded shard: ``mask`` (n,) marks
    valid rows; invalid rows contribute nothing to the moment estimate.  This
    is the moment every wire-protocol scheme fit consumes (distributed_gp's
    padded layout and repro.comm's ragged mesh shards share it)."""
    X = X.astype(jnp.float32)
    if mask is None:
        return X.T @ X / X.shape[0]
    Xm = X * mask[:, None]
    n = jnp.maximum(mask.sum(), 1.0)
    return Xm.T @ Xm / n


def _unit_distortion_table(max_bits: int) -> jnp.ndarray:
    return jnp.asarray([Q.unit_distortion(r) for r in range(max_bits + 2)], jnp.float32)


def _sqrt_psd_jax(M):
    w, v = eigh_sym(M)
    w = jnp.clip(w, 0.0, None)
    s = jnp.sqrt(w)
    inv_s = jnp.where(s > 1e-12 * jnp.max(s), 1.0 / jnp.where(s == 0, 1.0, s), 0.0)
    return (v * s) @ v.T, (v * inv_s) @ v.T


@partial(jax.jit, static_argnames=("total_bits", "max_bits"))
def fit_scheme(Qx, Qy, total_bits: int, max_bits: int = 8):
    """Returns dict(T, T_inv, sigma, rates) — the on-device scheme state."""
    Qy_half, Qy_inv_half = _sqrt_psd_jax(Qy.astype(jnp.float32))
    B = Qy_half @ Qx.astype(jnp.float32) @ Qy_half
    lam, U = eigh_sym(0.5 * (B + B.T))
    lam = jnp.clip(lam[::-1], 0.0, None)
    U = U[:, ::-1]
    T = U.T @ Qy_half
    T_inv = Qy_inv_half @ U

    e_tab = _unit_distortion_table(max_bits)
    d = lam.shape[0]

    def body(_, rates):
        e_cur = e_tab[rates]
        e_nxt = e_tab[jnp.minimum(rates + 1, max_bits + 1)]
        gain = lam * (e_cur - e_nxt)
        gain = jnp.where(rates >= max_bits, -jnp.inf, gain)
        j = jnp.argmax(gain)
        # no dimension gains anything (all capped, or only zero-variance dims
        # left — their gain is 0): stop allocating, matching the host heap's
        # `neg_g >= 0` early exit so wire-bit accounting stays identical
        return rates.at[j].add((gain[j] > 0.0).astype(jnp.int32))

    # init derived from lam so the carry inherits lam's varying-manual-axes
    # (vma) type under shard_map — a literal zeros() would be vma-unvarying
    # and fail the scan carry check.
    rates0 = (lam * 0.0).astype(jnp.int32)
    rates = jax.lax.fori_loop(0, total_bits, body, rates0)
    return {"T": T, "T_inv": T_inv, "sigma": jnp.sqrt(lam), "rates": rates}


def fit_scheme_batched(Qxs, Qys, total_bits: int, max_bits: int = 8):
    """vmapped :func:`fit_scheme` over a leading machine axis: one batched eigh
    pair instead of m serial ones.  Qxs/Qys: (m, d, d)."""
    return jax.vmap(lambda qx, qy: fit_scheme(qx, qy, total_bits, max_bits))(Qxs, Qys)


def codebook_cap(total_bits: int, max_bits: int) -> int:
    """Largest rate any dimension can be allocated: greedy allocation hands out
    ``total_bits`` bits in total, so tables never need more than
    ``min(max_bits, total_bits)`` rows — capping here keeps the padded
    quantize/dequantize broadcasts at (n, d, 2^cap) instead of (n, d, 2^max)."""
    return max(min(max_bits, total_bits), 0)


def scheme_tables(total_bits: int, max_bits: int):
    """Codebook tables sized to the max *allocatable* rate (see codebook_cap)."""
    return Q.build_codebook_tables(codebook_cap(total_bits, max_bits))


def scaled_centroids(state, tables):
    """Per-dimension centroid tables at each dim's allocated rate, scaled by its
    sigma: (d, C) — the table the fused dequantize+gram (qgram) kernel eats."""
    _, cents = tables
    return cents[state["rates"]] * state["sigma"][:, None]


def scaled_centroids_batched(rates, sigma, tables):
    """:func:`scaled_centroids` over a leading machine axis: rates (m, d),
    sigma (m, d) -> (m, d, C)."""
    return jax.vmap(
        lambda r, s: scaled_centroids({"rates": r, "sigma": s}, tables)
    )(rates, sigma)


# --------------------------------------------------------------------------
# the packed code plane: b-bit codes <-> uint32 words
#
# This is THE on-wire / at-rest representation of quantized data: the
# collectives all-gather these words (repro.comm), the fused dequantize+gram
# kernels unpack them in-block (repro.kernels.qgram), WireState carries them,
# and checkpoints persist them (format_version 3).  Layout (docs/wire_format.md):
# the d codes of one row are concatenated LSB-first at their per-dimension
# widths — dimension i occupies bits [sum(w[:i]), sum(w[:i]) + w[i]) of the
# row's bitstream, and bit b of the stream lives in bit (b % 32) of word
# (b // 32).  A row occupies ceil(total_bits / 32) words; trailing pad bits
# are zero.  Width-0 dimensions occupy no bits and unpack to code 0.
# --------------------------------------------------------------------------

WORD_BITS = 32


def row_words(total_bits: int) -> int:
    """uint32 words per packed row of ``total_bits`` payload bits."""
    return (int(total_bits) + WORD_BITS - 1) // WORD_BITS


def _pack_layout(widths, num: int, total_bits):
    """(widths (num,) uint32, offsets (num,) uint32, W) for one packed row.

    ``widths`` may be a static python int (uniform b-bit codes; b in 0..32)
    or a (num,) integer array (possibly traced — e.g. the scheme's per-dim
    ``rates``), in which case the static ``total_bits`` upper bound on
    ``widths.sum()`` is required to size the word buffer."""
    if isinstance(widths, (int, np.integer)):
        b = int(widths)
        if not 0 <= b <= WORD_BITS:
            raise ValueError(f"uniform code width must be in 0..32, got {b}")
        w = jnp.full((num,), b, jnp.uint32)
        total = num * b
        if total >= 2**31:
            # bit offsets are computed in uint32; a wider row would silently
            # wrap.  Split the data into multiple rows instead (q_psum packs
            # its flat tensor in fixed-size chunks for exactly this reason).
            raise ValueError(
                f"packed row of {total} bits overflows 32-bit offsets — "
                "split into multiple rows"
            )
    else:
        w = jnp.asarray(widths).astype(jnp.uint32)
        if w.ndim != 1 or w.shape[0] != num:
            raise ValueError(f"widths must be ({num},), got shape {w.shape}")
        if total_bits is None:
            raise ValueError(
                "per-dimension widths need a static total_bits bound to size "
                "the word buffer (shapes cannot depend on traced values)"
            )
        total = int(total_bits)
    offs = jnp.cumsum(w) - w  # exclusive prefix sum
    return w, offs, row_words(total)


def _width_mask(w):
    """(1 << w) - 1 as uint32, exact for w == 32 too."""
    full = jnp.uint32(0xFFFFFFFF)
    m = (jnp.uint32(1) << jnp.minimum(w, jnp.uint32(WORD_BITS - 1))) - jnp.uint32(1)
    return jnp.where(w >= WORD_BITS, full, m)


def pack_codes(codes, widths, *, total_bits=None, mask=None):
    """Pack integer codes along the last axis into uint32 words.

    codes : (..., d) integer array; dimension i holds values in
        [0, 2^widths[i]).  Negative entries (the -1 padded-row sentinel) pack
        as 0 — validity is the caller's ``mask``/lengths bookkeeping, exactly
        as for the decoded arrays.  (Sentinel detection needs a sign bit, so
        pass uint32 codes for uniform width 32.)
    widths : static int b (uniform b-bit codes, b in 0..32) or a (d,) integer
        array of per-dimension widths (the scheme's ``rates``; may be traced).
    total_bits : static upper bound on ``sum(widths)`` — required when
        ``widths`` is an array, ignored otherwise.
    mask : optional (...,) row validity; invalid rows pack to all-zero words.

    Returns (..., W) uint32, W = ceil(total/32).  jit/vmap/shard_map-safe:
    shapes depend only on the static ``widths``/``total_bits``.
    """
    codes = jnp.asarray(codes)
    d = codes.shape[-1]
    w, offs, W = _pack_layout(widths, d, total_bits)
    valid = jnp.ones(codes.shape, bool)
    if jnp.issubdtype(codes.dtype, jnp.signedinteger):
        valid &= codes >= 0
    if mask is not None:
        valid &= (jnp.asarray(mask) > 0)[..., None]
    c = jnp.where(valid, codes, 0).astype(jnp.uint32) & _width_mask(w)
    word = (offs // WORD_BITS).astype(jnp.int32)  # (d,)
    bit = offs % WORD_BITS  # (d,) uint32
    lo = c << bit
    # bits that overflow word `word` spill into word+1; when bit == 0 nothing
    # spills (and a shift by 32 would be undefined, hence the clamp)
    hi = jnp.where(
        bit > 0, c >> (WORD_BITS - jnp.maximum(bit, jnp.uint32(1))), jnp.uint32(0)
    )
    # disjoint bit fields: scatter-ADD never carries, so add == bitwise-or.
    # The buffer has one spare word so `word + 1` of the last dimension stays
    # in bounds (its `hi` is necessarily 0 there).
    out = jnp.zeros(codes.shape[:-1] + (W + 1,), jnp.uint32)
    out = out.at[..., word].add(lo).at[..., word + 1].add(hi)
    return out[..., :W]


def unpack_codes(words, widths, *, num=None, total_bits=None, mask=None,
                 dtype=jnp.int32):
    """Inverse of :func:`pack_codes`: (..., W) uint32 -> (..., d) codes.

    widths : as in :func:`pack_codes`; ``num`` (the number of codes per row)
        is required when ``widths`` is a static int, inferred from the array
        otherwise.
    mask : optional (...,) row validity; invalid rows come back as the -1
        sentinel (matching the unpacked wire convention).
    dtype : output dtype (int32 default; use uint32 for full-width codes).
    """
    words = jnp.asarray(words).astype(jnp.uint32)
    if not isinstance(widths, (int, np.integer)):
        num = jnp.asarray(widths).shape[0] if num is None else num
    elif num is None:
        raise ValueError("uniform-width unpack needs num (codes per row)")
    w, offs, W = _pack_layout(widths, num, total_bits)
    if words.shape[-1] != W:
        raise ValueError(
            f"expected {W} words per row for this layout, got {words.shape[-1]}"
        )
    if W == 0:  # zero-rate rows: every width is 0, every code is 0
        out = jnp.zeros(words.shape[:-1] + (num,), dtype)
    else:
        word = (offs // WORD_BITS).astype(jnp.int32)
        bit = offs % WORD_BITS
        lo = words[..., word] >> bit
        # the clamp keeps the gather in bounds for codes that end exactly at
        # the buffer's edge; their spill contribution is masked to 0 below
        hi_src = words[..., jnp.minimum(word + 1, W - 1)]
        hi = jnp.where(
            bit > 0,
            hi_src << (WORD_BITS - jnp.maximum(bit, jnp.uint32(1))),
            jnp.uint32(0),
        )
        out = ((lo | hi) & _width_mask(w)).astype(dtype)
    if mask is not None:
        out = jnp.where((jnp.asarray(mask) > 0)[..., None], out,
                        jnp.asarray(-1, dtype))
    return out


_CRC16_POLY = jnp.uint32(0x1021)  # CRC-16-CCITT
_CRC16_INIT = jnp.uint32(0xFFFF)


def crc_words(words, mask=None):
    """Per-row CRC-16-CCITT over packed uint32 words — jit/vmap/shard_map-safe.

    words : (..., W) uint32 packed rows (see :func:`pack_codes`).  The CRC is
        computed bit-serially LSB-first over the row's W*32-bit stream —
        exactly the order the bits occupy the wire — so any single flipped
        bit (and any burst up to 16 bits) changes the checksum.
    mask : optional (...,) row validity; invalid rows checksum to 0 (they
        occupy no wire bits, so they carry no CRC either).

    Returns (...,) uint32 in [0, 2^16).  W == 0 rows checksum to the init
    value.  The 16 CRC bits per transmitted row are charged to the ledger as
    ``integrity_bits`` (see :mod:`repro.comm.accounting`)."""
    words = jnp.asarray(words).astype(jnp.uint32)
    W = words.shape[-1]
    if W == 0:
        out = jnp.full(words.shape[:-1], _CRC16_INIT, jnp.uint32)
    else:
        def word_step(i, crc):
            wd = words[..., i]

            def bit_step(b, c):
                bit = (wd >> b) & jnp.uint32(1)
                fb = ((c >> 15) ^ bit) & jnp.uint32(1)
                return (((c << 1) & jnp.uint32(0xFFFF))
                        ^ (fb * _CRC16_POLY))

            return jax.lax.fori_loop(0, WORD_BITS, bit_step, crc)

        crc0 = jnp.full(words.shape[:-1], _CRC16_INIT, jnp.uint32)
        out = jax.lax.fori_loop(0, W, word_step, crc0)
    if mask is not None:
        out = jnp.where(jnp.asarray(mask) > 0, out, jnp.uint32(0))
    return out


def encode(state, X, tables):
    """X: (n, d) -> int32 codes (n, d).  tables from Q.build_codebook_tables."""
    edges, _ = tables
    Xp = X.astype(jnp.float32) @ state["T"].T
    return Q.quantize(Xp, state["sigma"], state["rates"], edges)


def decode(state, codes, tables):
    _, cents = tables
    Xp = Q.dequantize(codes, state["sigma"], state["rates"], cents)
    return Xp @ state["T_inv"].T


def roundtrip(state, X, tables):
    """Encode-then-decode NEW symbols with an already-fitted (frozen) scheme
    state: ``(codes, X̂)``.  This is the streaming-serve path
    (``distributed_gp.update``): the codebooks/transform fitted once at
    protocol-fit time are reused, so only the new symbols' wire bits
    (``rates.sum()`` per point) are spent — no scheme refit, no new side
    info."""
    codes = encode(state, X, tables)
    return codes, decode(state, codes, tables)


SchemeState = dict
