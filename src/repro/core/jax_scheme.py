"""Fully-traceable (jit/shard_map-compatible) per-symbol scheme (§4.2).

The host-side PerSymbolScheme uses scipy + a heap; inside a compiled collective
we need the same math as jax ops:

  * decorrelating transform via jnp.linalg.eigh,
  * greedy Algorithm-1 bit allocation as a fori_loop over total_bits of
    argmax(Delta sigma) steps — identical output to the heap version,
  * quantize/dequantize with rate-indexed padded codebook tables.

This is what repro.comm's quantized collectives run on-device.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from . import quantizers as Q

__all__ = [
    "fit_scheme",
    "fit_scheme_batched",
    "encode",
    "decode",
    "roundtrip",
    "codebook_cap",
    "scheme_tables",
    "scaled_centroids",
    "scaled_centroids_batched",
    "masked_second_moment",
    "SchemeState",
]


def masked_second_moment(X, mask=None):
    """S = X_valid^T X_valid / n_valid for a padded shard: ``mask`` (n,) marks
    valid rows; invalid rows contribute nothing to the moment estimate.  This
    is the moment every wire-protocol scheme fit consumes (distributed_gp's
    padded layout and repro.comm's ragged mesh shards share it)."""
    X = X.astype(jnp.float32)
    if mask is None:
        return X.T @ X / X.shape[0]
    Xm = X * mask[:, None]
    n = jnp.maximum(mask.sum(), 1.0)
    return Xm.T @ Xm / n


def _unit_distortion_table(max_bits: int) -> jnp.ndarray:
    return jnp.asarray([Q.unit_distortion(r) for r in range(max_bits + 2)], jnp.float32)


def _sqrt_psd_jax(M):
    w, v = jnp.linalg.eigh(M)
    w = jnp.clip(w, 0.0, None)
    s = jnp.sqrt(w)
    inv_s = jnp.where(s > 1e-12 * jnp.max(s), 1.0 / jnp.where(s == 0, 1.0, s), 0.0)
    return (v * s) @ v.T, (v * inv_s) @ v.T


@partial(jax.jit, static_argnames=("total_bits", "max_bits"))
def fit_scheme(Qx, Qy, total_bits: int, max_bits: int = 8):
    """Returns dict(T, T_inv, sigma, rates) — the on-device scheme state."""
    Qy_half, Qy_inv_half = _sqrt_psd_jax(Qy.astype(jnp.float32))
    B = Qy_half @ Qx.astype(jnp.float32) @ Qy_half
    lam, U = jnp.linalg.eigh(0.5 * (B + B.T))
    lam = jnp.clip(lam[::-1], 0.0, None)
    U = U[:, ::-1]
    T = U.T @ Qy_half
    T_inv = Qy_inv_half @ U

    e_tab = _unit_distortion_table(max_bits)
    d = lam.shape[0]

    def body(_, rates):
        e_cur = e_tab[rates]
        e_nxt = e_tab[jnp.minimum(rates + 1, max_bits + 1)]
        gain = lam * (e_cur - e_nxt)
        gain = jnp.where(rates >= max_bits, -jnp.inf, gain)
        j = jnp.argmax(gain)
        # no dimension gains anything (all capped, or only zero-variance dims
        # left — their gain is 0): stop allocating, matching the host heap's
        # `neg_g >= 0` early exit so wire-bit accounting stays identical
        return rates.at[j].add((gain[j] > 0.0).astype(jnp.int32))

    # init derived from lam so the carry inherits lam's varying-manual-axes
    # (vma) type under shard_map — a literal zeros() would be vma-unvarying
    # and fail the scan carry check.
    rates0 = (lam * 0.0).astype(jnp.int32)
    rates = jax.lax.fori_loop(0, total_bits, body, rates0)
    return {"T": T, "T_inv": T_inv, "sigma": jnp.sqrt(lam), "rates": rates}


def fit_scheme_batched(Qxs, Qys, total_bits: int, max_bits: int = 8):
    """vmapped :func:`fit_scheme` over a leading machine axis: one batched eigh
    pair instead of m serial ones.  Qxs/Qys: (m, d, d)."""
    return jax.vmap(lambda qx, qy: fit_scheme(qx, qy, total_bits, max_bits))(Qxs, Qys)


def codebook_cap(total_bits: int, max_bits: int) -> int:
    """Largest rate any dimension can be allocated: greedy allocation hands out
    ``total_bits`` bits in total, so tables never need more than
    ``min(max_bits, total_bits)`` rows — capping here keeps the padded
    quantize/dequantize broadcasts at (n, d, 2^cap) instead of (n, d, 2^max)."""
    return max(min(max_bits, total_bits), 0)


def scheme_tables(total_bits: int, max_bits: int):
    """Codebook tables sized to the max *allocatable* rate (see codebook_cap)."""
    return Q.build_codebook_tables(codebook_cap(total_bits, max_bits))


def scaled_centroids(state, tables):
    """Per-dimension centroid tables at each dim's allocated rate, scaled by its
    sigma: (d, C) — the table the fused dequantize+gram (qgram) kernel eats."""
    _, cents = tables
    return cents[state["rates"]] * state["sigma"][:, None]


def scaled_centroids_batched(rates, sigma, tables):
    """:func:`scaled_centroids` over a leading machine axis: rates (m, d),
    sigma (m, d) -> (m, d, C)."""
    return jax.vmap(
        lambda r, s: scaled_centroids({"rates": r, "sigma": s}, tables)
    )(rates, sigma)


def encode(state, X, tables):
    """X: (n, d) -> int32 codes (n, d).  tables from Q.build_codebook_tables."""
    edges, _ = tables
    Xp = X.astype(jnp.float32) @ state["T"].T
    return Q.quantize(Xp, state["sigma"], state["rates"], edges)


def decode(state, codes, tables):
    _, cents = tables
    Xp = Q.dequantize(codes, state["sigma"], state["rates"], cents)
    return Xp @ state["T_inv"].T


def roundtrip(state, X, tables):
    """Encode-then-decode NEW symbols with an already-fitted (frozen) scheme
    state: ``(codes, X̂)``.  This is the streaming-serve path
    (``distributed_gp.update``): the codebooks/transform fitted once at
    protocol-fit time are reused, so only the new symbols' wire bits
    (``rates.sum()`` per point) are spent — no scheme refit, no new side
    info."""
    codes = encode(state, X, tables)
    return codes, decode(state, codes, tables)


SchemeState = dict
