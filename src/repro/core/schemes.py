"""The paper's three transmission schemes behind one encode/decode API (§4).

Every scheme answers: given dataset X at machine M_x and the receiver-side
covariance Q_y, produce a wire message of bounded size whose decoding X̂
minimizes the inner-product distortion (7).

* ``OptimalScheme``      — §4.1, Theorem-2 Gaussian test channel (simulated;
                           block coding is exponential, per the paper).
* ``PerSymbolScheme``    — §4.2, decorrelate + greedy bit loading + scalar
                           equiprobable-bin quantizer.  The practical one.
* ``DimReductionScheme`` — §4.3, Theorem-3 projection (16 bits/coefficient as
                           in the paper's Fig. 2 protocol).
* ``PCAScheme``          — the baseline PCA projection (Fig. 3 comparison).

Wire-cost accounting (bits) matches the paper's §4 cost analysis; side-info
(covariances, d x d fp32) is reported separately, as the paper amortizes it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import quantizers as Q
from .rate_distortion import make_test_channel, sample_test_channel, distortion_for_rate
from .transforms import (
    make_decorrelating_transform,
    make_dim_reduction,
    make_pca,
)

__all__ = [
    "PerSymbolScheme",
    "OptimalScheme",
    "DimReductionScheme",
    "PCAScheme",
]


@dataclasses.dataclass
class PerSymbolScheme:
    """Paper §4.2.  ``bits_per_sample`` = R (total across the d dimensions)."""

    bits_per_sample: int
    max_bits_per_dim: int = Q.DEFAULT_MAX_BITS

    def fit(self, Qx, Qy):
        tr = make_decorrelating_transform(Qx, Qy)
        rates = Q.allocate_bits_greedy(
            tr.variances, self.bits_per_sample, self.max_bits_per_dim
        )
        self._tr = tr
        self.rates = rates
        self.sigma = np.sqrt(np.maximum(tr.variances, 0.0)).astype(np.float32)
        self._edges, self._cents = Q.build_codebook_tables(int(rates.max(initial=0)))
        # expected distortion sum_i e(Lambda_ii, R_i) (eq. 35 + 40)
        self.expected_distortion = float(
            sum(Q.expected_distortion(v, int(r)) for v, r in zip(tr.variances, rates))
        )
        return self

    def encode(self, X):
        """(n, d) -> int32 codes (n, d)."""
        Xp = jnp.asarray(X) @ jnp.asarray(self._tr.T, dtype=jnp.float32).T
        return Q.quantize(Xp, jnp.asarray(self.sigma), jnp.asarray(self.rates), self._edges)

    def decode(self, codes):
        Xp = Q.dequantize(codes, jnp.asarray(self.sigma), jnp.asarray(self.rates), self._cents)
        return Xp @ jnp.asarray(self._tr.T_inv, dtype=jnp.float32).T

    def roundtrip(self, X, key=None):
        return self.decode(self.encode(X))

    def wire_bits(self, n: int) -> int:
        return int(self.rates.sum()) * n

    def side_info_bits(self, d: int) -> int:
        # Qx and Qy exchanged (paper: O(2 d^2 + R n)) — the ONE shared
        # formula, repro.comm.accounting (deferred import: no core<->comm
        # cycle at module load)
        from ..comm.accounting import side_info_bits

        return side_info_bits(d)


@dataclasses.dataclass
class OptimalScheme:
    """Theorem-2 test channel at the Theorem-1 rate (simulated block coding)."""

    bits_per_sample: float

    def fit(self, Qx, Qy):
        D = distortion_for_rate(Qx, Qy, self.bits_per_sample)
        self.channel = make_test_channel(Qx, Qy, D)
        self.expected_distortion = self.channel.distortion
        return self

    def roundtrip(self, X, key):
        return sample_test_channel(self.channel, X, key)

    def wire_bits(self, n: int) -> int:
        return int(np.ceil(self.channel.rate_bits * n))

    def side_info_bits(self, d: int) -> int:
        from ..comm.accounting import side_info_bits

        return side_info_bits(d)


@dataclasses.dataclass
class DimReductionScheme:
    """Theorem-3 projection; m coefficients x ``coeff_bits`` bits each."""

    m: int
    coeff_bits: int = 16  # the paper's Fig. 2 assumption

    def fit(self, Sx, Sy):
        self.dr = make_dim_reduction(Sx, Sy, self.m)
        self.expected_distortion = self.dr.left_out
        return self

    def encode(self, X):
        return jnp.asarray(X) @ jnp.asarray(self.dr.P, dtype=jnp.float32).T

    def decode(self, Z):
        return jnp.asarray(Z) @ jnp.asarray(self.dr.U, dtype=jnp.float32).T

    def roundtrip(self, X, key=None):
        return self.decode(self.encode(X))

    def wire_bits(self, n: int) -> int:
        d = self.dr.U.shape[0]
        return self.coeff_bits * (self.m * n + self.m * d)  # z's and U (paper §4.3)

    def side_info_bits(self, d: int) -> int:
        return d * d * 32  # S_y only


@dataclasses.dataclass
class PCAScheme(DimReductionScheme):
    """PCA baseline (uses only S_x)."""

    def fit(self, Sx, Sy=None):
        self.dr = make_pca(Sx, self.m)
        self.expected_distortion = None  # PCA's objective is not (7)
        return self

    def side_info_bits(self, d: int) -> int:
        return 0
