"""``DGPConfig`` — the one typed config behind :class:`~repro.core.api.DistributedGP`.

Every knob the four legacy entry points took as loose stringly-typed kwargs
(``protocol=``, ``impl=``, ``gram_backend=``, ``kernel=``, ``fuse=``/
``method=``, ...) lives here as a validated field of ONE frozen dataclass.
Validation happens at construction — a typo'd scheme name fails with the
registry's known names in the message, not 40 frames deep inside ``fit`` —
and the config rides on the fitted artifact (and its checkpoint ``meta.json``)
so a served model always knows exactly how it was produced.
"""
from __future__ import annotations

import dataclasses

from . import quantizers as Q
from .registry import FUSIONS, KERNELS, PROTOCOLS, SCHEMES

__all__ = ["DGPConfig", "IMPLS", "GRAM_BACKENDS", "GRAM_MODES", "TRAIN_IMPLS",
           "SERVE_EPILOGUES"]

IMPLS = ("host", "batched", "mesh")
GRAM_BACKENDS = ("xla", "pallas")
GRAM_MODES = ("nystrom", "nystrom_fitc", "direct", "dense")
TRAIN_IMPLS = ("scan", "loop")
SERVE_EPILOGUES = ("fused", "unfused")

# the artifact format written by save_artifact; bumped when the checkpoint
# layout changes.  1 = pre-DGPConfig artifacts (loaded via defaults);
# 2 = config in meta.json, unpacked int32 wire codes; 3 = PACKED uint32 wire
# codes + recorded payload_bits (v1/v2 still load — codes pack on restore;
# see docs/wire_format.md); 4 = per-array CRC32 checksums + the integrity
# ledger in meta.json (v1-v3 load unverified); 5 = streaming buffers:
# capacity-padded factor arrays plus the stream/* leaves (per-machine counts,
# occupied-column counter, device-resident ledgers) — v1-v4 load at exact
# capacity and pad up on their first update(); 6 = fused-serve-epilogue
# cache keys (factors/Ainv, factors/U, factors/walpha) on Nyström artifacts —
# v1-v5 load fine and simply serve on the unfused path (the keys are absent)
ARTIFACT_FORMAT_VERSION = 6


def _ensure_registered() -> None:
    """Builtins register at import time; importing the protocols package here
    makes a bare ``from repro.core.config import DGPConfig`` self-sufficient."""
    from . import protocols  # noqa: F401  (registers schemes + protocols)


def _check_choice(kind: str, value: str, choices: tuple) -> None:
    if value not in choices:
        raise ValueError(
            f"unknown {kind} {value!r}: known {kind}s are {', '.join(choices)}"
        )


@dataclasses.dataclass(frozen=True)
class DGPConfig:
    """Validated, hashable description of one distributed-GP configuration.

    Fields
    ------
    protocol : ``center`` (§5.1) | ``broadcast`` (§5.2) | ``poe`` (zero-rate
        baseline) — a :data:`~repro.core.registry.PROTOCOLS` name.
    scheme : what actually crosses the wire — ``per_symbol`` (§4.2 int codes)
        or ``vq`` (the §4.1 Theorem-2 optimal test channel); a
        :data:`~repro.core.registry.SCHEMES` name.  Ignored by ``poe``
        (nothing crosses the wire at zero rate).
    kernel : ``se`` | ``linear`` — a :data:`~repro.core.registry.KERNELS` name.
    fusion : how per-machine predictives meet (broadcast fusion rule or PoE
        combiner): ``kl`` | ``poe`` | ``gpoe`` | ``bcm`` | ``rbcm`` — a
        :data:`~repro.core.registry.FUSIONS` name.
    impl : execution substrate — ``host`` (serial scipy oracle), ``batched``
        (one vmapped jit), ``mesh`` (machines are devices).
    gram_backend : ``xla`` | ``pallas`` (tiled gram + fused dequantize+gram
        kernels; batched impl only).
    gram_mode : train-gram assembly — ``nystrom`` (eq. 61), ``nystrom_fitc``
        (Snelson–Ghahramani exact diagonal), ``direct``, or ``dense`` (PoE).
    bits_per_sample : the paper's R — wire bits each transmitting machine
        spends per point (0 = zero-rate).
    max_bits : per-dimension rate cap of the per-symbol allocator.
    steps, lr, train_impl : hyperparameter-training knobs (Adam by marginal
        likelihood; ``scan`` compiles the loop into one program).
    center : which machine is the §5.1 center.
    serve_epilogue : ``fused`` (default) precomputes the K-sized serve cache
        (``nystrom_serve_cache``) at fit time so predict runs the fused
        matmul-only epilogue; ``unfused`` keeps the legacy O(t N K)
        solve-based serve path (parity/debugging — the two are algebraically
        equal, asserted by tests/test_kernel_runtime.py).
    faults : optional :class:`~repro.faults.FaultPlan` injected at fit time —
        dropped/NaN shards and packed-word bit flips (with CRC demotion of
        corrupted rows); ``None`` = a healthy fleet (see docs/fault_model.md).
    """

    protocol: str = "center"
    scheme: str = "per_symbol"
    kernel: str = "se"
    fusion: str = "kl"
    impl: str = "batched"
    gram_backend: str = "xla"
    gram_mode: str = "nystrom"
    bits_per_sample: int = 24
    max_bits: int = Q.DEFAULT_MAX_BITS
    steps: int = 150
    lr: float = 0.05
    train_impl: str = "scan"
    center: int = 0
    serve_epilogue: str = "fused"
    faults: object = None  # FaultPlan | None (frozen+hashable, rides as static meta)

    def __post_init__(self):
        _ensure_registered()
        # registry-backed names: the error carries the menu
        for registry, value in (
            (PROTOCOLS, self.protocol), (SCHEMES, self.scheme),
            (KERNELS, self.kernel), (FUSIONS, self.fusion),
        ):
            registry.get(value)
        _check_choice("impl", self.impl, IMPLS)
        _check_choice("gram_backend", self.gram_backend, GRAM_BACKENDS)
        _check_choice("gram_mode", self.gram_mode, GRAM_MODES)
        _check_choice("train_impl", self.train_impl, TRAIN_IMPLS)
        _check_choice("serve_epilogue", self.serve_epilogue, SERVE_EPILOGUES)
        if self.bits_per_sample < 0:
            raise ValueError(f"bits_per_sample must be >= 0, got {self.bits_per_sample}")
        if self.max_bits < 0:
            raise ValueError(f"max_bits must be >= 0, got {self.max_bits}")
        if self.steps < 0:
            raise ValueError(f"steps must be >= 0, got {self.steps}")
        if self.center < 0:
            raise ValueError(f"center must be >= 0, got {self.center}")
        if self.gram_backend == "pallas" and self.impl != "batched":
            # the pallas gram/qgram kernels eat the batched wire's int codes;
            # the host oracle has no wire state and the mesh path assembles
            # grams device-local
            raise ValueError(
                f'gram_backend="pallas" requires impl="batched", got '
                f"{self.impl!r}"
            )
        if self.scheme == "vq":
            # the test channel is simulated host-side on the batched substrate;
            # there are no int codes for the pallas qgram kernels to eat, and
            # poe has no wire at all
            if self.protocol == "poe":
                raise ValueError(
                    'scheme="vq" does not apply to protocol="poe" '
                    "(zero-rate: nothing crosses the wire)"
                )
            if self.impl != "batched":
                raise ValueError(
                    f'scheme="vq" supports impl="batched" only, got {self.impl!r}'
                )
            if self.gram_backend != "xla":
                raise ValueError(
                    'scheme="vq" has no int wire codes for the pallas qgram '
                    'path: use gram_backend="xla"'
                )
        if self.faults is not None:
            from ..faults import FaultPlan

            if not isinstance(self.faults, FaultPlan):
                raise TypeError(
                    f"faults must be a repro.faults.FaultPlan or None, got "
                    f"{type(self.faults).__name__}"
                )

    # -- conversions ---------------------------------------------------------

    def asdict(self) -> dict:
        """JSON-ready dict (checkpoint ``meta.json`` records this)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "DGPConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        if isinstance(d.get("faults"), dict):
            from ..faults import FaultPlan

            d["faults"] = FaultPlan.from_dict(d["faults"])
        return cls(**d)

    @classmethod
    def from_legacy_meta(cls, meta: dict) -> "DGPConfig":
        """Reconstruct a best-effort config from a pre-redesign artifact's
        ``meta.json`` (format version 1: no ``config`` block).  Training knobs
        (steps/lr) are not recorded in old checkpoints, so they stay at
        defaults; everything the serve path needs is recovered exactly."""
        return cls(
            protocol=meta["protocol"],
            scheme=meta.get("scheme", "per_symbol"),
            kernel=meta["kernel"],
            fusion=meta["fuse"] or "kl",
            impl="batched",  # checkpoints always restore single-host
            gram_backend=meta["gram_backend"],
            gram_mode=meta["gram_mode"],
            bits_per_sample=meta["bits_per_sample"],
            max_bits=meta["max_bits"],
        )
