"""Name registries backing the :class:`~repro.core.api.DistributedGP` API.

The paper's framing is that the *scheme on the wire* is the design variable:
optimal vector quantization (§4.1), near-optimal per-symbol (§4.2), and the
zero-rate PoE/BCM baselines are points on one rate/distortion axis.  The
registries make that axis (and the other protocol knobs) first-class: kernels,
wire schemes, fusion rules, and protocols are looked up by name, so a new one
plugs into every entry point — ``DGPConfig`` validation, ``fit``/``predict``,
the benchmarks — by registering instead of by editing dispatch chains.

Builtins register themselves at import time:

* kernels ``se`` / ``linear`` — :mod:`repro.core.gp`;
* fusions ``kl`` (eqs. 62-64) and the PoE-family combiners ``poe`` / ``gpoe``
  / ``bcm`` / ``rbcm`` — :mod:`repro.core.fusion` / :mod:`repro.core.poe`;
* wire schemes ``per_symbol`` (§4.2) and ``vq`` (the §4.1 Theorem-2 test
  channel) — :mod:`repro.core.protocols.wire`;
* protocols ``center`` / ``broadcast`` / ``poe`` —
  :mod:`repro.core.protocols`.

This module is dependency-free so every layer (``gp``, ``fusion``, ``poe``,
``protocols``) can import it without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable


class Registry:
    """A named table of pluggable components.

    ``register`` rejects duplicates (a silent overwrite would make the
    "which scheme actually ran?" question unanswerable); ``get`` raises a
    ``ValueError`` that lists the known names, so a typo'd config fails with
    the menu in hand.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, Any] = {}

    def register(self, name: str, entry: Any) -> Any:
        if not isinstance(name, str) or not name:
            raise ValueError(f"{self.kind} name must be a non-empty string")
        if name in self._entries:
            raise ValueError(
                f"duplicate {self.kind} {name!r}: already registered "
                f"(known {self.kind}s: {', '.join(self.names())})"
            )
        self._entries[name] = entry
        return entry

    def get(self, name: str) -> Any:
        try:
            return self._entries[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} {name!r}: known {self.kind}s are "
                f"{', '.join(self.names())}"
            ) from None

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._entries))

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self):
        return iter(self.names())


# -- entry shapes ------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A GP kernel: dense gram builder plus the inner-product/diagonal forms
    the quantized-wire paths consume (see ``gp.kernel_from_inner``)."""

    name: str
    gram: Callable  # (params, X, X2=None, *, backend="xla") -> (n, n2)
    from_inner: Callable  # (params, ip, sq_x, sq_x2) -> gram block
    prior_diag: Callable  # (params, sq_x) -> k(x, x) vector


@dataclasses.dataclass(frozen=True)
class FusionSpec:
    """How per-machine predictive Gaussians meet: ``fuse`` on stacked
    ``(m, t)`` predictives (batched/host impls), ``fuse_psum`` as a mesh
    collective epilogue (``None`` if the fusion has no mesh form).

    Both MAY accept an optional machine-availability weight vector ``w``
    (per-device scalar ``w_i`` in the psum form): degraded-mode serving
    renormalizes the fusion over surviving machines (docs/fault_model.md).
    The protocols only pass ``w`` when a degraded mask is actually in play,
    so a fusion registered without the parameter still serves the healthy
    path — it just cannot be used with ``predict(..., available=...)``.

    ``moments`` / ``finalize`` are the optional ONE-COLLECTIVE decomposition
    of the fusion, used by the fused serve epilogue: ``moments`` maps one
    machine's predictive to a fixed (3, t) stack of locally-computable moment
    rows, ``finalize`` maps the ACROSS-MACHINE SUM of those stacks (one
    ``psum`` on mesh, one reduce in the fused kernel) plus the static fleet
    size ``m`` back to the fused ``(mu, s2)``.  Every builtin fusion provides
    them; a custom fusion registered without them still serves through
    ``fuse``/``fuse_psum`` (the mesh epilogue then pays the legacy
    multi-psum path).
    """

    name: str
    fuse: Callable  # (mus, s2s, prior_var, w=None) -> (mu, s2)
    fuse_psum: Callable | None = None  # (mu_i, s2_i, prior_var, axis, w_i=None) -> ...
    moments: Callable | None = None  # (mu_i, s2_i, prior_var, w_i=None) -> (3, t)
    finalize: Callable | None = None  # (S, m, prior_var) -> (mu, s2)


@dataclasses.dataclass(frozen=True)
class SchemeSpec:
    """A wire scheme: how machine shards become what the receiver sees.

    ``run`` executes the fit-time wire protocol for every machine at once and
    returns a :class:`~repro.core.protocols.base.WireRun`: the shared
    ``WireState``, three ledgers (``wire_bits`` the Theorem-1 formula,
    ``payload_bits`` the packed payload physically moved, ``integrity_bits``
    the per-row CRC framing — ``repro.comm.accounting``), an ``extras`` dict
    of scheme-private arrays stashed in the artifact's ``data`` (e.g. the vq
    test-channel parameters), the possibly fault-compacted ``shards`` the
    protocol must assemble from, and the count of CRC-demoted rows.  The
    optional ``faults`` plan injects wire corruption (docs/fault_model.md).
    ``reencode`` encodes NEW symbols under the frozen fit-time state for
    streaming :func:`~repro.core.protocols.base.update`.

    ``reencode_traced`` is the optional jit-safe form of ``reencode``: it runs
    INSIDE the protocols' device-resident update programs (``machine`` is a
    traced int32 scalar) and returns the decoded batch plus the three traced
    int32 ledger deltas, so consecutive in-bucket updates hit one jit cache
    entry.  Schemes whose reencode is inherently host-side (``vq`` samples a
    simulated channel keyed on the python ledger) leave it ``None`` and the
    update dispatch precomputes the batch eagerly instead.

    ``update_corrupt`` is the optional noisy-channel hook for streamed
    batches: under a ``flip_rate`` fault plan it transmits the new rows
    through the scheme's physical plane (encode→pack→flip→CRC→unpack→decode,
    host-side like the fit-time ``_corrupt_and_demote``), returning the
    surviving row indices, their received decodes, the FULL transmitted
    ledger deltas, and the demoted-row count."""

    name: str
    run: Callable  # (shards, bits, max_bits, mode, center, impl, faults=None) -> WireRun
    reencode: Callable  # (art, machine, X_new) -> (decoded, wire_bits_added, payload_bits_added)
    reencode_traced: Callable | None = None  # (art, machine_traced, X_new) -> (decoded, wire+, payload+, integrity+)
    update_corrupt: Callable | None = None  # (art, machine, X_new, plan) -> (keep_idx, decoded, wire+, payload+, integrity+, demoted)


@dataclasses.dataclass(frozen=True)
class ProtocolSpec:
    """A distributed-GP protocol: the fit/predict/update triple the facade
    dispatches on.  ``fit`` consumes a validated ``DGPConfig``; ``predict``
    serves one query batch from a ``FittedProtocol`` (fusion included);
    ``update`` streams new points in."""

    name: str
    fit: Callable  # (parts, cfg, params=None) -> FittedProtocol
    predict: Callable  # (art, X_star, sq_star, g_ss, noise, avail=None) -> (mu, s2)
    update: Callable  # (art, X_new, y_new, machine, pre=None) -> FittedProtocol
    fit_host: Callable | None = None  # (parts, cfg, params=None) -> oracle model


KERNELS = Registry("kernel")
SCHEMES = Registry("scheme")
FUSIONS = Registry("fusion")
PROTOCOLS = Registry("protocol")


def register_kernel(spec: KernelSpec) -> KernelSpec:
    return KERNELS.register(spec.name, spec)


def register_scheme(spec: SchemeSpec) -> SchemeSpec:
    return SCHEMES.register(spec.name, spec)


def register_fusion(spec: FusionSpec) -> FusionSpec:
    return FUSIONS.register(spec.name, spec)


def register_protocol(spec: ProtocolSpec) -> ProtocolSpec:
    return PROTOCOLS.register(spec.name, spec)
