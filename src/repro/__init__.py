"""repro — JAX/Pallas reproduction of "Learning of Gaussian Processes in
Distributed and Communication Limited Systems" (arXiv:1705.02627), grown into
a servable distributed-GP system.  See repro.core for the paper machinery and
repro.core.api.DistributedGP for the front-door estimator."""
