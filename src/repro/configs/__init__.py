"""Architecture registry: one module per assigned architecture (exact values
from the cited source), plus the paper's own GP experiment configs.

The LLM architecture modules themselves are quarantined under
``repro.configs.legacy`` (they are seed-era transformer workloads, unrelated
to the distributed-GP paper — see that package's docstring); ``get_config``
resolves names into it transparently.  ``input_specs`` builds
ShapeDtypeStruct stand-ins for every model input of a (config, shape) pair —
weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig, ShapeConfig, SHAPES

ARCHS = [
    "gemma_7b",
    "whisper_medium",
    "internvl2_2b",
    "mistral_large_123b",
    "arctic_480b",
    "stablelm_12b",
    "gemma2_2b",
    "xlstm_125m",
    "qwen2_moe_a2_7b",
    "zamba2_2_7b",
]



def get_config(arch_id: str) -> ModelConfig:
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f".legacy.{mod_name}", __package__)
    return mod.CONFIG


def list_archs():
    """Canonical assigned ids (e.g. 'qwen2-moe-a2.7b')."""
    return [
        importlib.import_module(f".legacy.{a}", __package__).CONFIG.name
        for a in ARCHS
    ]


def input_specs(cfg: ModelConfig, shape: ShapeConfig, batch_override=None):
    """ShapeDtypeStruct batch for train/prefill kinds.  Decode state specs are
    built separately (launch/dryrun.py) via jax.eval_shape on init_decode_state."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch = {"tokens": tok}
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_embed"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embed"] = jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    return batch
