"""arctic-480b [moe] — 128 experts top-2 + dense residual branch
[hf:Snowflake/snowflake-arctic-base]."""
from ...models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    activation="swiglu", tie_embeddings=False,
    num_experts=128, top_k=2, moe_d_ff=4864, moe_dense_residual=True,
    train_mb_tokens=262144,  # §Perf A4: fewer grad-sync rounds (collective-bound)
    source="hf:Snowflake/snowflake-arctic-base",
)
