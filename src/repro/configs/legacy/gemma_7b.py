"""gemma-7b [dense] — GeGLU, head_dim 256, MQA on the 2b sibling [arXiv:2403.08295]."""
from ...models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    d_ff=24576, vocab_size=256000, head_dim=256,
    activation="geglu", embed_scale=True, tie_embeddings=True,
    source="arXiv:2403.08295",
)
