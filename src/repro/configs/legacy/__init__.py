"""Quarantined seed-era LLM architecture configs.

These transformer/SSM/MoE model configs (gemma, whisper, arctic, ...) came
with the seed repo's generic serving scaffold and are UNRELATED to the
distributed-GP paper this repo reproduces — the GP system never reads them.
They are kept (a) because the dryrun/roofline harness and its tests
(tests/test_archs.py, tests/test_system.py) still exercise the transformer
stack against them, and (b) as workload stand-ins for the LM-feature GP head
example.  New GP work should not add configs here; the paper's own experiment
configs live one level up (repro.configs.gp_paper).

``repro.configs.get_config`` resolves names into this package transparently,
so external callers are unaffected by the quarantine.
"""
