"""xlstm-125m [ssm] — alternating mLSTM/sLSTM blocks [arXiv:2405.04517]."""
from ...models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    activation="gelu", tie_embeddings=True,
    xlstm_slstm_every=2,
    source="arXiv:2405.04517",
)
