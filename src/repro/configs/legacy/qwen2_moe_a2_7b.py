"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from ...models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    activation="swiglu", tie_embeddings=True,
    num_experts=60, top_k=4, moe_d_ff=1408,
    num_shared_experts=4, shared_d_ff=5632,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
