"""zamba2-2.7b [hybrid] — Mamba2 backbone + weight-shared attention block
every 6 layers (ssm_state 64) [arXiv:2411.15242]."""
from ...models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    activation="geglu", tie_embeddings=True,
    ssm_state=64, ssm_expand=2, ssm_conv=4, hybrid_attn_every=6,
    source="arXiv:2411.15242",
)
