"""internvl2-2b [vlm] — InternLM2 decoder; InternViT frontend STUBBED
(input_specs feeds (B, 256, d) patch embeddings) [arXiv:2404.16821]."""
from ...models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    activation="swiglu", tie_embeddings=True,
    num_patches=256,
    source="arXiv:2404.16821",
)
