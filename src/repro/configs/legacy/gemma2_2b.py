"""gemma2-2b [dense] — alternating local(4096)/global attention, logit
softcapping [arXiv:2408.00118]."""
from ...models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    d_ff=9216, vocab_size=256000, head_dim=256,
    activation="geglu", embed_scale=True, tie_embeddings=True,
    sliding_window=4096, local_global_alternating=True,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    source="arXiv:2408.00118",
)
