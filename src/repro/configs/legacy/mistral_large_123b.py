"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407]."""
from ...models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=28672, vocab_size=32768, head_dim=128,
    activation="swiglu", tie_embeddings=False,
    train_mb_tokens=65536,  # §Perf B2: 60 -> 34 GB/device on train_4k
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)
