"""whisper-medium [audio] — enc-dec; conv/mel frontend STUBBED (input_specs
feeds (B, 1500, d) frame embeddings) [arXiv:2212.04356]."""
from ...models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    activation="gelu", tie_embeddings=True,
    enc_layers=24, enc_seq=1500,
    source="arXiv:2212.04356",
)
