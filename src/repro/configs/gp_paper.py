"""The paper's OWN experiment configurations (§6) — the GP side of the repo,
as data objects the benchmarks and examples consume.

Each entry fixes: dataset (paper scale), machine count, kernel, rate sweep and
the zero-rate baselines, mirroring Figs. 2-7.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Optional


@dataclasses.dataclass(frozen=True)
class GPExperimentConfig:
    name: str
    figure: str
    dataset: Optional[str]  # repro.data.regression_dataset name, or None
    n_train: int
    n_machines: int
    kernel: str
    rates: Sequence[int]
    baselines: Sequence[str]
    notes: str = ""
    source: str = "arXiv Tavassolipour et al. 2017"


FIG2 = GPExperimentConfig(
    name="fig2_rate_distortion", figure="Fig. 2", dataset=None,
    n_train=4000, n_machines=2, kernel="linear",
    rates=tuple(range(5, 121, 5)), baselines=("lower_bound", "dim_reduction"),
    notes="20-d Gaussian, random covariance; distortion eq. (7)",
)

FIG4 = GPExperimentConfig(
    name="fig4_gp1d", figure="Fig. 4", dataset=None,
    n_train=200, n_machines=1, kernel="se",
    rates=tuple(range(1, 9)), baselines=("full_gp",),
    notes="1-d GP trained on quantized inputs",
)

FIG5_SARCOS = GPExperimentConfig(
    name="fig5_sarcos_linear", figure="Fig. 5a", dataset="sarcos",
    n_train=1000, n_machines=40, kernel="linear",
    rates=(2, 5, 8, 12, 16, 25, 40, 64, 100),
    baselines=("full_gp", "bcm", "rbcm"),
)

FIG6 = tuple(
    GPExperimentConfig(
        name=f"fig6_{ds}_se", figure="Fig. 6", dataset=ds,
        n_train=1000, n_machines=40, kernel="se",
        rates=(2, 5, 8, 12, 16, 25, 40, 64, 100),
        baselines=("full_gp", "bcm", "rbcm"),
    )
    for ds in ("sarcos", "kin40k", "abalone")
)

FIG7 = GPExperimentConfig(
    name="fig7_sparse_kin40k", figure="Fig. 7", dataset="kin40k",
    n_train=1000, n_machines=40, kernel="se",
    rates=(1, 2, 4, 8, 16, 32, 64), baselines=("rbcm",),
    notes="Titsias inducing points, quantized (15 per machine)",
)

ALL = (FIG2, FIG4, FIG5_SARCOS, *FIG6, FIG7)
