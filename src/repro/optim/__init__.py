from .adamw import AdamWState, adamw_init, adamw_update
from .schedules import cosine_warmup
