"""AdamW with global-norm clipping, pure JAX, pytree-native.

State is sharded exactly like the parameters (fsdp), so the optimizer adds
2x fp32 per parameter per device shard.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr,
    *,
    b1=0.9,
    b2=0.95,
    eps=1e-8,
    weight_decay=0.1,
    clip_norm=1.0,
):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    t = step.astype(jnp.float32)
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, state.v, grads)

    def upd(p, mm, vv):
        mh = mm / (1 - b1**t)
        vh = vv / (1 - b2**t)
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v), gnorm
