"""The one wire-bit accounting used by every ledger in the repo.

Two numbers describe what a protocol run cost (docs/wire_format.md):

* the **Theorem-1 ledger** (``wire_bits``): the paper's §4 formula —
  ``rates.sum()`` bits per valid transmitted row plus :func:`side_info_bits`
  per transmitting machine.  Integer-identical across the host scipy oracle,
  the batched vmapped wire, and the mesh collectives
  (tests/test_conformance.py).
* the **physical payload** (``payload_bits``): the bits of the packed uint32
  words the wire actually carries (:func:`repro.core.jax_scheme.pack_codes`),
  measured from the buffers (``dtype.itemsize * 8 * size``), plus the same
  side info.  Exceeds the ledger only by per-word padding:
  ``payload_bits - wire_bits == sum_j n_valid_j * (32 * W - rates_j.sum())``
  with ``W = ceil(row_bits / 32)`` words per row.

This module is import-cycle-free (stdlib only) so both ``repro.comm`` and
``repro.core`` call sites can share it; ``wire_bits_all_gather`` and
``q_all_gather``'s ``return_state`` ledger are pinned integer-equal to these
helpers by tests/test_comm.py.
"""
from __future__ import annotations

FP_BITS = 32  # fp32 side-info width
WORD_BITS = 32  # the packed code plane's word width (jax_scheme.WORD_BITS)
CRC_BITS = 16  # per-row CRC-16-CCITT framing (jax_scheme.crc_words)

__all__ = [
    "FP_BITS",
    "WORD_BITS",
    "CRC_BITS",
    "side_info_bits",
    "row_bits",
    "payload_row_bits",
    "wire_bits_formula",
    "payload_bits_formula",
    "integrity_bits_formula",
]


def side_info_bits(d: int, fp_bits: int = FP_BITS) -> int:
    """Per-transmitting-machine side info: the paper's O(2 d^2) accounting —
    one d x d covariance each way (Qy to the transmitter, the decode
    transform back).  The simulation's collectives also move the per-dim
    sigma/rates vectors and a redundant forward transform for the serving
    artifact; those O(d) extras are not charged (see docs/wire_format.md)."""
    return 2 * d * d * fp_bits


def row_bits(bits_per_sample: int, d: int, max_bits: int) -> int:
    """Payload bits one packed row can carry: the rate budget, capped by the
    allocator's ceiling of ``max_bits`` bits per dimension."""
    return min(int(bits_per_sample), d * int(max_bits))


def payload_row_bits(bits_per_sample: int, d: int, max_bits: int) -> int:
    """Physical bits per packed row: ``row_bits`` rounded up to whole uint32
    words — the only slack between the ledger and the payload."""
    r = row_bits(bits_per_sample, d, max_bits)
    return ((r + WORD_BITS - 1) // WORD_BITS) * WORD_BITS


def wire_bits_formula(rates, lengths, d: int, skip=None) -> int:
    """The Theorem-1 ledger: ``rates_j.sum() * n_j`` + side info per
    transmitting machine (machine ``skip`` — the §5.1 center — pays
    nothing)."""
    import numpy as np

    rates = np.asarray(rates)
    total = 0
    for j, n_j in enumerate(lengths):
        if j == skip or int(n_j) == 0:
            continue  # a machine with nothing to send sends nothing
        total += int(rates[j].sum()) * int(n_j) + side_info_bits(d)
    return total


def payload_bits_formula(
    lengths, d: int, bits_per_sample: int, max_bits: int, skip=None
) -> int:
    """The physical packed-payload bits: whole uint32 words per valid row plus
    side info per transmitting machine.  What the packed collectives measure
    (tests/test_conformance.py pins measurement == formula)."""
    per_row = payload_row_bits(bits_per_sample, d, max_bits)
    total = 0
    for j, n_j in enumerate(lengths):
        if j == skip or int(n_j) == 0:
            continue
        total += per_row * int(n_j) + side_info_bits(d)
    return total


def integrity_bits_formula(lengths, skip=None, crc_bits: int = CRC_BITS) -> int:
    """The **integrity ledger**: CRC framing bits per valid transmitted row —
    ``crc_bits * n_j`` for every transmitting machine (machine ``skip`` — the
    §5.1 center — transmits nothing, so it carries no CRC either).  Charged
    separately from ``wire_bits``/``payload_bits`` so the detection overhead
    is visible in rate/distortion plots (docs/fault_model.md)."""
    total = 0
    for j, n_j in enumerate(lengths):
        if j == skip or int(n_j) == 0:
            continue
        total += crc_bits * int(n_j)
    return total
