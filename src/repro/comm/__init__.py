from .quantized_collectives import q_all_gather, q_psum, wire_bits_all_gather
