from . import accounting
from .accounting import (
    payload_bits_formula,
    payload_row_bits,
    side_info_bits,
    wire_bits_formula,
)
from .quantized_collectives import q_all_gather, q_psum, wire_bits_all_gather
