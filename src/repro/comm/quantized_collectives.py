"""The paper's wire protocol as mesh collectives.

``q_all_gather(x, axis_name, bits)`` — inside shard_map: every shard holds a
local dataset block (n_loc, d) and wants every other shard's block for gram
computation.  Instead of all-gathering fp32 (32d bits/sample), each shard

  1. computes its local second moment and the target covariance Qy —
     ``mode="broadcast"`` (§5.2): psum to get the *other* shards' sum;
     ``mode="center"`` (§5.1): psum-select the center shard's covariance,
  2. fits the per-symbol scheme on-device (core.jax_scheme),
  3. packs its codes into the physical bit plane
     (``jax_scheme.pack_codes``: R bits/row in whole uint32 words) and
     all-gathers THOSE words — the wire carries ceil(R/32) words per row, not
     a uint8/int32 per symbol — plus the fp32 side info (T_inv/sigma/rates,
     O(d^2) per shard, the paper's O(d^2 + Rn) accounting),
  4. unpacks + decodes every peer's block with the peer's tables and
     substitutes its own exact block.

``mask`` marks valid rows of a padded shard (ragged machines on a uniform
SPMD layout): masked rows are excluded from the moment estimate, decode to
zero, pack to all-zero words, and are NOT charged to the wire ledger.
``return_state=True`` additionally returns everything the collective moved
(gathered packed words/side-info) plus two ledgers (repro.comm.accounting):
``wire_bits`` — the Theorem-1 formula (rates.sum() per valid row +
side_info_bits(d) per transmitting shard) — and ``payload_bits`` — the bits
of the packed payload the collective PHYSICALLY moved, measured from the
word buffer itself (dtype.itemsize * 8 per word), equal to the formula up to
per-word padding.  The center shard transmits nothing in center mode.

``q_psum(g, axis_name, bits)`` — gradient compression for the cross-pod
all-reduce: per-tensor Gaussian scalar quantization (equiprobable-bin codebook
with on-the-fly sigma), all-gather codes + per-shard sigma, decode and sum.
This is the paper's scheme with Qx = sigma^2 I (no covariance side-info), the
natural degenerate case for i.i.d.-ish gradient entries.  ``bits >= 32`` is
the fp fallback: an exact ``lax.psum`` (the codebook would be wider than the
payload).  Differentiating through ``q_psum`` uses a straight-through custom
VJP — the backward pass is that of the exact psum, so the quantizer's
zero-derivative staircase does not kill the gradient signal.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core import quantizers as Q
from ..core import jax_scheme
from .accounting import CRC_BITS, row_bits, side_info_bits


def wire_bits_all_gather(n_per_shard: int, d: int, bits: int, n_shards: int, fp_bits=32):
    """Bits each shard puts on the wire: codes + side info (vs fp32 baseline).

    Side info charges :func:`repro.comm.accounting.side_info_bits` — the ONE
    formula shared with ``q_all_gather``'s ``return_state`` ledger and the
    protocol ledgers (tests/test_comm.py pins both call sites equal)."""
    quantized = n_per_shard * bits + side_info_bits(d, fp_bits)
    baseline = n_per_shard * d * fp_bits
    return quantized, baseline


def q_all_gather(
    x,
    axis_name: str,
    bits_per_sample: int,
    max_bits: int = 8,
    *,
    mask=None,
    mode: str = "broadcast",
    center: int = 0,
    return_state: bool = False,
    faults=None,
):
    """x: (n_loc, d) per shard -> (m, n_loc, d) reconstructions of every
    shard's block (own block exact).  Must run inside shard_map with
    ``axis_name`` bound.

    mask : optional (n_loc,) float validity of rows (padded/ragged shards);
        None = every row valid (the original uniform-shard behavior).
    mode : "broadcast" (§5.2, Qy = sum of the other shards' covariances) or
        "center" (§5.1, every shard targets the covariance of shard
        ``center``).
    return_state : also return a dict of what the collective moved —
        ``codes`` (m, n_loc, W) uint32 PACKED words (the physical wire;
        masked rows are all-zero words; unpack with
        ``jax_scheme.unpack_codes`` at each shard's ``rates``), ``decoded``
        (m, n_loc, d) reconstructions WITHOUT the own-block substitution,
        ``T``/``T_inv``/``sigma``/``rates`` side info per shard, ``mask``
        (m, n_loc), ``wire_bits`` — the Theorem-1 ledger (each shard's
        allocated rate over its VALID rows + ``accounting.side_info_bits``)
        — and ``payload_bits`` — the packed payload physically moved,
        measured from the word buffer (itemsize * 8 per word per valid row
        + the same side info) — and ``integrity_bits`` — the per-row CRC
        framing (``accounting.CRC_BITS`` per valid row).  The center shard
        is not charged in center mode; a shard with no valid rows transmits
        (and is charged) nothing.
    faults : optional :class:`repro.faults.FaultPlan` injected INTO the
        collective itself (docs/fault_model.md): ``drop`` zeroes the listed
        machines' masks (they transmit nothing), non-finite rows are masked
        out before the moment estimate (the NaN tripwire), and
        ``flip_rate > 0`` XORs random bits into the gathered packed words —
        rows whose CRC no longer matches are demoted to masked.  ``None``
        (the default) leaves the collective's arithmetic untouched.
    """
    n_loc, d = x.shape
    m = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)

    if faults is not None and (faults.drop or faults.nan):
        # collective-level injection: fold drops + the NaN tripwire into the
        # validity mask BEFORE the moment estimate (a healthy fleet with
        # faults=None never enters this branch, so the fault-free jaxpr —
        # and its conformance-locked arithmetic — is untouched)
        fmask = jnp.ones((n_loc,), jnp.float32) if mask is None else mask
        row_ok = jnp.isfinite(x).all(axis=-1)
        x = jnp.where(row_ok[:, None], x, 0.0)
        fmask = fmask * row_ok.astype(jnp.float32)
        if faults.drop:
            alive = jnp.all(jnp.asarray(faults.drop, jnp.int32) != idx)
            fmask = fmask * alive.astype(jnp.float32)
        mask = fmask

    if mask is None:
        n_valid = jnp.float32(n_loc)
        S_loc = x.T @ x / n_valid
    else:
        n_valid = jnp.maximum(mask.sum(), 1.0)
        S_loc = jax_scheme.masked_second_moment(x, mask)
    if mode == "center":
        # psum-select: O(d^2) on the wire, the center's S to every shard
        sel = (idx == center).astype(jnp.float32)
        Qy = jax.lax.psum(S_loc * sel, axis_name)
    elif mode == "broadcast":
        Qy = jax.lax.psum(S_loc, axis_name) - S_loc
    else:
        raise ValueError(f"unknown q_all_gather mode {mode!r}")
    # cap per-dim rates (and therefore codebook tables) at the max ALLOCATED
    # rate: greedy bit loading never hands one dimension more than
    # bits_per_sample bits, so a full 2^max_bits table only inflates the
    # (n, d, 2^cap) quantize/dequantize broadcast temporaries
    cap = jax_scheme.codebook_cap(bits_per_sample, max_bits)
    state = jax_scheme.fit_scheme(S_loc, Qy, bits_per_sample, cap)
    tables = jax_scheme.scheme_tables(bits_per_sample, max_bits)

    codes = jax_scheme.encode(state, x, tables)
    mask_l = jnp.ones((n_loc,), jnp.float32) if mask is None else mask
    # the physical wire: every row's codes concatenated at their allocated
    # widths into whole uint32 words (R bits/row + per-word padding), NOT a
    # uint8/int32 per symbol — this buffer IS what the collective moves
    rbits = row_bits(bits_per_sample, d, max_bits)
    words = jax_scheme.pack_codes(
        codes, state["rates"], total_bits=rbits, mask=mask_l
    )

    all_words = jax.lax.all_gather(words, axis_name)  # (m, n_loc, W) the wire
    all_Tinv = jax.lax.all_gather(state["T_inv"], axis_name)  # side info O(d^2)
    all_sigma = jax.lax.all_gather(state["sigma"], axis_name)
    all_rates = jax.lax.all_gather(state["rates"], axis_name)
    all_mask = jax.lax.all_gather(mask_l, axis_name)

    if faults is not None and faults.flip_rate > 0:
        # the bit-flip channel: the transmitter's per-row CRC rides ahead of
        # the payload; each receiver XORs the deterministic per-source noise
        # into the gathered words (every receiver sees the SAME corrupted
        # plane — the channel is between machines, not per link) and demotes
        # rows whose CRC no longer matches to masked
        from ..faults import flip_words

        clean_crc = jax_scheme.crc_words(words, mask_l)
        all_crc = jax.lax.all_gather(clean_crc, axis_name)
        key = jax.random.PRNGKey(faults.seed)
        all_words = jax.vmap(
            lambda j, w: flip_words(w, faults.flip_rate, jax.random.fold_in(key, j))
        )(jnp.arange(m), all_words)
        rx_crc = jax.vmap(jax_scheme.crc_words)(all_words, all_mask)
        surv = (rx_crc == all_crc).astype(jnp.float32)
        # own words never cross the wire: the own block is substituted exact
        own_row = jax.nn.one_hot(idx, m, dtype=jnp.float32)[:, None]
        all_mask = all_mask * (surv * (1 - own_row) + own_row)

    def dec(words_j, Tinv_j, sigma_j, rates_j):
        codes_j = jax_scheme.unpack_codes(words_j, rates_j, total_bits=rbits)
        _, cents = tables
        Xp = Q.dequantize(codes_j, sigma_j, rates_j, cents)
        return Xp @ Tinv_j.T

    xhat = jax.vmap(dec)(all_words, all_Tinv, all_sigma, all_rates)
    xhat = xhat * all_mask[..., None]  # masked rows decode to exactly zero
    # substitute own exact block
    own = jax.nn.one_hot(idx, m, dtype=x.dtype)[:, None, None]
    view = xhat * (1 - own) + x[None].astype(xhat.dtype) * own
    if not return_state:
        return view

    # three ledgers (repro.comm.accounting): the Theorem-1 formula, the
    # packed payload MEASURED from the buffer the collective moved, and the
    # CRC framing — each transmitting shard pays whole words per VALID row
    # plus side info; a shard with NO valid rows transmits nothing and is
    # charged nothing (matching the formulas' n_j == 0 skip)
    has_rows = (mask_l.sum() > 0).astype(jnp.int32)
    n_valid_i = n_valid.astype(jnp.int32)
    contrib = (state["rates"].sum() * n_valid_i + side_info_bits(d)) * has_rows
    row_payload = words.shape[-1] * words.dtype.itemsize * 8
    pcontrib = (row_payload * n_valid_i + side_info_bits(d)) * has_rows
    icontrib = CRC_BITS * n_valid_i * has_rows
    if mode == "center":
        transmits = (idx != center).astype(jnp.int32)
        contrib = contrib * transmits
        pcontrib = pcontrib * transmits
        icontrib = icontrib * transmits
    wire_bits = jax.lax.psum(contrib, axis_name)
    payload_bits = jax.lax.psum(pcontrib, axis_name)
    integrity_bits = jax.lax.psum(icontrib, axis_name)
    # T is the encoder's state, not wire traffic — gathered only because the
    # serving artifact freezes it for streaming update()
    all_T = jax.lax.all_gather(state["T"], axis_name)
    return view, {
        "codes": all_words,
        "decoded": xhat,
        "T": all_T,
        "T_inv": all_Tinv,
        "sigma": all_sigma,
        "rates": all_rates,
        "mask": all_mask,
        "wire_bits": wire_bits,
        "payload_bits": payload_bits,
        "integrity_bits": integrity_bits,
    }


# codes per packed q_psum row: keeps every row's bit offsets far below the
# uint32 ceiling of the packer (a single row would wrap past 2^32 bits for
# ~10^8-element gradients) at <= ROW_CODES*bits-1 bits of tail padding total
_PSUM_ROW_CODES = 1024


def _q_psum_impl(g, axis_name: str, bits: int, faults=None):
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    sigma = jnp.sqrt(jnp.mean(flat * flat) + 1e-30)
    edges = jnp.asarray(Q.gauss_bin_edges(bits), jnp.float32) * sigma
    cents = jnp.asarray(Q.gauss_centroids(bits), jnp.float32)
    codes = jnp.searchsorted(edges, flat).astype(jnp.int32)
    # the wire: the tensor as packed rows of uniform bits-wide codes
    k = min(_PSUM_ROW_CODES, n)
    codes = jnp.pad(codes, (0, (-n) % k))
    words = jax_scheme.pack_codes(codes.reshape(-1, k), bits)
    all_words = jax.lax.all_gather(words, axis_name)  # bits/elem + word pad
    if faults is not None and faults.flip_rate > 0:
        # flips-only injection: gradients carry no per-row CRC (a corrupted
        # code is just extra channel noise on an already-lossy reduce), so
        # flipped bits pass straight into the decode
        from ..faults import flip_words

        m = jax.lax.psum(1, axis_name)
        key = jax.random.PRNGKey(faults.seed)
        all_words = jax.vmap(
            lambda j, w: flip_words(w, faults.flip_rate, jax.random.fold_in(key, j))
        )(jnp.arange(m), all_words)
    all_sigma = jax.lax.all_gather(sigma, axis_name)
    all_codes = jax.vmap(
        lambda w: jax_scheme.unpack_codes(w, bits, num=k).reshape(-1)[:n]
    )(all_words)
    vals = cents[all_codes] * all_sigma[:, None]
    return jnp.sum(vals, axis=0).reshape(g.shape).astype(g.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _q_psum(g, axis_name: str, bits: int, faults=None):
    return _q_psum_impl(g, axis_name, bits, faults)


def _q_psum_fwd(g, axis_name, bits, faults):
    return _q_psum_impl(g, axis_name, bits, faults), None


def _q_psum_bwd(axis_name, bits, faults, _, ct):
    # straight-through: the backward pass of the EXACT psum.  y = psum(x) is
    # replicated, and every shard's downstream use of y produces its own
    # cotangent, so the adjoint sums them: grad_x = psum(ct).  (Returning ct
    # un-summed would scale gradients by 1/m versus the exact reduce.)
    return (jax.lax.psum(ct, axis_name),)


_q_psum.defvjp(_q_psum_fwd, _q_psum_bwd)


def q_psum(g, axis_name: str, bits: int = 8, faults=None):
    """Quantized all-reduce of a flat tensor g (any shape): per-shard Gaussian
    scalar quantization at ``bits`` bits/element, gather + decode + sum.
    Unbiased-ish (centroid decoder); exactness increases with bits.
    ``bits >= 32`` falls back to the exact fp ``lax.psum`` (quantizing at or
    above the payload width buys nothing).  Differentiable via a
    straight-through custom VJP (backward = exact psum's backward).

    ``faults``: optional :class:`repro.faults.FaultPlan`; only its
    ``flip_rate`` applies (bit flips on the packed code rows — extra channel
    noise, no CRC framing on gradients).  Must be hashable (it is static).

    NOTE: the result is replicated across ``axis_name`` by construction
    (sum of an all_gather), but shard_map's vma checker cannot infer that —
    pass ``check_vma=False`` to the enclosing jax.shard_map."""
    if bits >= 32:
        return jax.lax.psum(g, axis_name)
    return _q_psum(g, axis_name, bits, faults)
