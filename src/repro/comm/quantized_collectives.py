"""The paper's wire protocol as mesh collectives.

``q_all_gather(x, axis_name, bits)`` — inside shard_map: every shard holds a
local dataset block (n_loc, d) and wants every other shard's block for gram
computation.  Instead of all-gathering fp32 (32d bits/sample), each shard

  1. computes its local second moment and the target covariance Qy —
     ``mode="broadcast"`` (§5.2): psum to get the *other* shards' sum;
     ``mode="center"`` (§5.1): psum-select the center shard's covariance,
  2. fits the per-symbol scheme on-device (core.jax_scheme),
  3. all-gathers the int codes (R bits/sample on the wire; the fp32
     side-info — T/T_inv/sigma/rates, O(d^2) per shard — matches the paper's
     O(d^2 + Rn) accounting),
  4. decodes every peer's block with the peer's tables and substitutes its own
     exact block.

``mask`` marks valid rows of a padded shard (ragged machines on a uniform
SPMD layout): masked rows are excluded from the moment estimate, decode to
zero, carry the -1 sentinel code, and are NOT charged to the wire ledger.
``return_state=True`` additionally returns everything the collective moved
(gathered codes/side-info) plus ``wire_bits`` — the ledger computed from the
actual payload: sum over transmitting shards of rates.sum() * n_valid plus
2 d² fp32 of side info (the center shard transmits nothing in center mode).

``q_psum(g, axis_name, bits)`` — gradient compression for the cross-pod
all-reduce: per-tensor Gaussian scalar quantization (equiprobable-bin codebook
with on-the-fly sigma), all-gather codes + per-shard sigma, decode and sum.
This is the paper's scheme with Qx = sigma^2 I (no covariance side-info), the
natural degenerate case for i.i.d.-ish gradient entries.  ``bits >= 32`` is
the fp fallback: an exact ``lax.psum`` (the codebook would be wider than the
payload).  Differentiating through ``q_psum`` uses a straight-through custom
VJP — the backward pass is that of the exact psum, so the quantizer's
zero-derivative staircase does not kill the gradient signal.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core import quantizers as Q
from ..core import jax_scheme


def wire_bits_all_gather(n_per_shard: int, d: int, bits: int, n_shards: int, fp_bits=32):
    """Bits each shard puts on the wire: codes + side info (vs fp32 baseline)."""
    quantized = n_per_shard * bits + (d * d + 2 * d) * fp_bits
    baseline = n_per_shard * d * fp_bits
    return quantized, baseline


def q_all_gather(
    x,
    axis_name: str,
    bits_per_sample: int,
    max_bits: int = 8,
    *,
    mask=None,
    mode: str = "broadcast",
    center: int = 0,
    return_state: bool = False,
):
    """x: (n_loc, d) per shard -> (m, n_loc, d) reconstructions of every
    shard's block (own block exact).  Must run inside shard_map with
    ``axis_name`` bound.

    mask : optional (n_loc,) float validity of rows (padded/ragged shards);
        None = every row valid (the original uniform-shard behavior).
    mode : "broadcast" (§5.2, Qy = sum of the other shards' covariances) or
        "center" (§5.1, every shard targets the covariance of shard
        ``center``).
    return_state : also return a dict of what the collective moved —
        ``codes`` (m, n_loc, d) int32 with -1 on masked rows, ``decoded``
        (m, n_loc, d) reconstructions WITHOUT the own-block substitution,
        ``T``/``T_inv``/``sigma``/``rates`` side info per shard, ``mask``
        (m, n_loc), and ``wire_bits`` — the int32 ledger of actual payload
        bits (codes at each shard's allocated rate over its VALID rows +
        2 d² fp32 side info; the center shard is not charged in center mode).
    """
    n_loc, d = x.shape
    m = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)

    if mask is None:
        n_valid = jnp.float32(n_loc)
        S_loc = x.T @ x / n_valid
    else:
        n_valid = jnp.maximum(mask.sum(), 1.0)
        S_loc = jax_scheme.masked_second_moment(x, mask)
    if mode == "center":
        # psum-select: O(d^2) on the wire, the center's S to every shard
        sel = (idx == center).astype(jnp.float32)
        Qy = jax.lax.psum(S_loc * sel, axis_name)
    elif mode == "broadcast":
        Qy = jax.lax.psum(S_loc, axis_name) - S_loc
    else:
        raise ValueError(f"unknown q_all_gather mode {mode!r}")
    # cap per-dim rates (and therefore codebook tables) at the max ALLOCATED
    # rate: greedy bit loading never hands one dimension more than
    # bits_per_sample bits, so a full 2^max_bits table only inflates the
    # (n, d, 2^cap) quantize/dequantize broadcast temporaries
    cap = jax_scheme.codebook_cap(bits_per_sample, max_bits)
    state = jax_scheme.fit_scheme(S_loc, Qy, bits_per_sample, cap)
    tables = jax_scheme.scheme_tables(bits_per_sample, max_bits)

    codes = jax_scheme.encode(state, x, tables)
    codes_small = codes.astype(jnp.uint8 if cap <= 8 else jnp.int32)

    all_codes = jax.lax.all_gather(codes_small, axis_name)  # (m, n_loc, d) int wire
    all_T = jax.lax.all_gather(state["T"], axis_name)  # side info O(d^2)
    all_Tinv = jax.lax.all_gather(state["T_inv"], axis_name)
    all_sigma = jax.lax.all_gather(state["sigma"], axis_name)
    all_rates = jax.lax.all_gather(state["rates"], axis_name)
    mask_l = jnp.ones((n_loc,), jnp.float32) if mask is None else mask
    all_mask = jax.lax.all_gather(mask_l, axis_name)

    def dec(codes_j, Tinv_j, sigma_j, rates_j):
        _, cents = tables
        Xp = Q.dequantize(codes_j.astype(jnp.int32), sigma_j, rates_j, cents)
        return Xp @ Tinv_j.T

    xhat = jax.vmap(dec)(all_codes, all_Tinv, all_sigma, all_rates)
    xhat = xhat * all_mask[..., None]  # masked rows decode to exactly zero
    # substitute own exact block
    own = jax.nn.one_hot(idx, m, dtype=x.dtype)[:, None, None]
    view = xhat * (1 - own) + x[None].astype(xhat.dtype) * own
    if not return_state:
        return view

    # the ledger, from what actually moved: each transmitting shard pays its
    # allocated rate per VALID row plus 2 d^2 fp32 of side info
    contrib = state["rates"].sum() * n_valid.astype(jnp.int32) + 2 * d * d * 32
    if mode == "center":
        contrib = contrib * (idx != center).astype(jnp.int32)
    wire_bits = jax.lax.psum(contrib, axis_name)
    all_codes_i32 = jnp.where(
        all_mask[..., None] > 0, all_codes.astype(jnp.int32), -1
    )
    return view, {
        "codes": all_codes_i32,
        "decoded": xhat,
        "T": all_T,
        "T_inv": all_Tinv,
        "sigma": all_sigma,
        "rates": all_rates,
        "mask": all_mask,
        "wire_bits": wire_bits,
    }


def _q_psum_impl(g, axis_name: str, bits: int):
    flat = g.reshape(-1).astype(jnp.float32)
    sigma = jnp.sqrt(jnp.mean(flat * flat) + 1e-30)
    edges = jnp.asarray(Q.gauss_bin_edges(bits), jnp.float32) * sigma
    cents = jnp.asarray(Q.gauss_centroids(bits), jnp.float32)
    codes = jnp.searchsorted(edges, flat).astype(jnp.uint8 if bits <= 8 else jnp.int32)
    all_codes = jax.lax.all_gather(codes, axis_name)  # wire: bits/elem
    all_sigma = jax.lax.all_gather(sigma, axis_name)
    vals = cents[all_codes.astype(jnp.int32)] * all_sigma[:, None]
    return jnp.sum(vals, axis=0).reshape(g.shape).astype(g.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _q_psum(g, axis_name: str, bits: int):
    return _q_psum_impl(g, axis_name, bits)


def _q_psum_fwd(g, axis_name, bits):
    return _q_psum_impl(g, axis_name, bits), None


def _q_psum_bwd(axis_name, bits, _, ct):
    # straight-through: the backward pass of the EXACT psum.  y = psum(x) is
    # replicated, and every shard's downstream use of y produces its own
    # cotangent, so the adjoint sums them: grad_x = psum(ct).  (Returning ct
    # un-summed would scale gradients by 1/m versus the exact reduce.)
    return (jax.lax.psum(ct, axis_name),)


_q_psum.defvjp(_q_psum_fwd, _q_psum_bwd)


def q_psum(g, axis_name: str, bits: int = 8):
    """Quantized all-reduce of a flat tensor g (any shape): per-shard Gaussian
    scalar quantization at ``bits`` bits/element, gather + decode + sum.
    Unbiased-ish (centroid decoder); exactness increases with bits.
    ``bits >= 32`` falls back to the exact fp ``lax.psum`` (quantizing at or
    above the payload width buys nothing).  Differentiable via a
    straight-through custom VJP (backward = exact psum's backward).

    NOTE: the result is replicated across ``axis_name`` by construction
    (sum of an all_gather), but shard_map's vma checker cannot infer that —
    pass ``check_vma=False`` to the enclosing jax.shard_map."""
    if bits >= 32:
        return jax.lax.psum(g, axis_name)
    return _q_psum(g, axis_name, bits)
