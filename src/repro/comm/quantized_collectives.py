"""The paper's wire protocol as mesh collectives.

``q_all_gather(x, axis_name, bits)`` — inside shard_map: every shard holds a
local dataset block (n_loc, d) and wants every other shard's block for gram
computation (the §5.2 broadcast model).  Instead of all-gathering fp32 (32d
bits/sample), each shard

  1. computes its local second moment, psums to get the *other* shards' sum
     (the paper's Qy for broadcast),
  2. fits the per-symbol scheme on-device (core.jax_scheme),
  3. all-gathers the int8 codes (R bits/sample on the wire; the fp32
     side-info — T_inv/sigma/rates, O(d^2) per shard — matches the paper's
     O(d^2 + Rn) accounting),
  4. decodes every peer's block with the peer's tables and substitutes its own
     exact block.

``q_psum(g, axis_name, bits)`` — gradient compression for the cross-pod
all-reduce: per-tensor Gaussian scalar quantization (equiprobable-bin codebook
with on-the-fly sigma), all-gather codes + per-shard sigma, decode and sum.
This is the paper's scheme with Qx = sigma^2 I (no covariance side-info), the
natural degenerate case for i.i.d.-ish gradient entries.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core import quantizers as Q
from ..core import jax_scheme


def wire_bits_all_gather(n_per_shard: int, d: int, bits: int, n_shards: int, fp_bits=32):
    """Bits each shard puts on the wire: codes + side info (vs fp32 baseline)."""
    quantized = n_per_shard * bits + (d * d + 2 * d) * fp_bits
    baseline = n_per_shard * d * fp_bits
    return quantized, baseline


def q_all_gather(x, axis_name: str, bits_per_sample: int, max_bits: int = 8):
    """x: (n_loc, d) per shard -> (m, n_loc, d) reconstructions of every
    shard's block (own block exact).  Must run inside shard_map with
    ``axis_name`` bound.
    """
    n_loc, d = x.shape
    m = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)

    S_loc = x.T @ x / n_loc
    S_tot = jax.lax.psum(S_loc, axis_name)
    # cap per-dim rates (and therefore codebook tables) at the max ALLOCATED
    # rate: greedy bit loading never hands one dimension more than
    # bits_per_sample bits, so a full 2^max_bits table only inflates the
    # (n, d, 2^cap) quantize/dequantize broadcast temporaries
    cap = jax_scheme.codebook_cap(bits_per_sample, max_bits)
    state = jax_scheme.fit_scheme(S_loc, S_tot - S_loc, bits_per_sample, cap)
    tables = jax_scheme.scheme_tables(bits_per_sample, max_bits)

    codes = jax_scheme.encode(state, x, tables)
    codes_small = codes.astype(jnp.uint8 if cap <= 8 else jnp.int32)

    all_codes = jax.lax.all_gather(codes_small, axis_name)  # (m, n_loc, d) int8 wire
    all_Tinv = jax.lax.all_gather(state["T_inv"], axis_name)  # side info O(d^2)
    all_sigma = jax.lax.all_gather(state["sigma"], axis_name)
    all_rates = jax.lax.all_gather(state["rates"], axis_name)

    def dec(codes_j, Tinv_j, sigma_j, rates_j):
        _, cents = tables
        Xp = Q.dequantize(codes_j.astype(jnp.int32), sigma_j, rates_j, cents)
        return Xp @ Tinv_j.T

    xhat = jax.vmap(dec)(all_codes, all_Tinv, all_sigma, all_rates)
    # substitute own exact block
    own = jax.nn.one_hot(idx, m, dtype=x.dtype)[:, None, None]
    return xhat * (1 - own) + x[None].astype(xhat.dtype) * own


def q_psum(g, axis_name: str, bits: int = 8):
    """Quantized all-reduce of a flat tensor g (any shape): per-shard Gaussian
    scalar quantization at ``bits`` bits/element, gather + decode + sum.
    Unbiased-ish (centroid decoder); exactness increases with bits.

    NOTE: the result is replicated across ``axis_name`` by construction
    (sum of an all_gather), but shard_map's vma checker cannot infer that —
    pass ``check_vma=False`` to the enclosing jax.shard_map."""
    flat = g.reshape(-1).astype(jnp.float32)
    sigma = jnp.sqrt(jnp.mean(flat * flat) + 1e-30)
    edges = jnp.asarray(Q.gauss_bin_edges(bits), jnp.float32) * sigma
    cents = jnp.asarray(Q.gauss_centroids(bits), jnp.float32)
    codes = jnp.searchsorted(edges, flat).astype(jnp.uint8 if bits <= 8 else jnp.int32)
    all_codes = jax.lax.all_gather(codes, axis_name)  # wire: bits/elem
    all_sigma = jax.lax.all_gather(sigma, axis_name)
    vals = cents[all_codes.astype(jnp.int32)] * all_sigma[:, None]
    return jnp.sum(vals, axis=0).reshape(g.shape).astype(g.dtype)
