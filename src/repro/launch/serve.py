"""Batched decode serving driver: prefill a prompt batch, then autoregressively
decode with the per-family cache machinery.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduce \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from ..configs import get_config
    from ..models import make_decode_step
    from ..models.steps import init_train_state
    from ..models.decode import init_decode_state

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    B = args.batch
    max_len = args.prompt_len + args.gen
    state = init_decode_state(cfg, B, max_len)
    step = jax.jit(make_decode_step(cfg))

    rng = jax.random.PRNGKey(1)
    prompt = jax.random.randint(rng, (B, args.prompt_len), 0, cfg.vocab_size, jnp.int32)

    # prefill via repeated decode steps (cache-exact; a batched prefill kernel
    # is the prefill_32k dry-run path)
    t0 = time.time()
    tok = prompt[:, :1]
    for p in range(args.prompt_len):
        tok = prompt[:, p][:, None]
        nxt, state = step(params, state, tok, jnp.int32(p))
    out = [nxt]
    for g in range(args.gen - 1):
        nxt, state = step(params, state, nxt, jnp.int32(args.prompt_len + g))
        out.append(nxt)
    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    n_steps = args.prompt_len + args.gen - 1
    print(f"arch={cfg.name} batch={B} steps={n_steps} "
          f"{dt:.2f}s total, {1e3*dt/n_steps:.1f} ms/step")
    print("generated token ids (first row):", toks[0].tolist())


if __name__ == "__main__":
    main()
