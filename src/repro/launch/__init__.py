"""Drivers: serve_gp (distributed-GP serving), train/serve/dryrun (the
transformer stack with the GP head).  Modules are runnable via python -m."""
