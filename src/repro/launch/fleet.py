"""Fleet serving driver: many tenants, one stacked predict program.

  python -m repro.launch.fleet --tenants 64 --protocol broadcast \
      --gram-backend pallas --cache 32 --budget-ms 2 --slots 8 \
      --requests 400 --batch 16 --zipf 1.1 [--store-dir /tmp/fleet_store]

The pieces (design notes in docs/fleet_serving.md):

* :class:`MicroBatcher` — coalesces per-tenant queries into stacked
  micro-batches under a latency budget: a batch flushes when its ``slots``
  fill OR when the oldest queued request has waited ``budget_ms`` (whichever
  first).  The clock is injectable so tests drive deadlines without
  sleeping.
* :class:`FleetServer` — the serving loop's state: an
  :class:`~repro.core.fleet.ArtifactCache` (LRU, checkpoint-backed
  load-on-miss), one :class:`~repro.core.fleet.FleetStack` per homogeneity
  bucket, and the batcher.  ``submit()`` enqueues; a flush groups the batch
  by bucket, pads each group to the fixed flush width (repeating the first
  row, results sliced off — so the jitted program sees ONE batch shape and
  the steady state never retraces), and answers every tenant in one
  dispatch per bucket.
* :func:`build_fleet` / :func:`serve_loop` — shared by this CLI, the
  ``serve_gp.py --fleet`` passthrough, and benchmarks/fleet_bench.py: build
  a tenant store from a handful of base fits (exact y-scaled variants, see
  :func:`~repro.core.fleet.scale_targets`) and drive zipf-mixed traffic
  against the server, reporting qps / p50 / p99 / hit rate / retraces.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class _Pending:
    tenant: object
    X: object
    avail: object
    enqueued_at: float


class MicroBatcher:
    """Coalesce per-tenant requests into fixed-width micro-batches under a
    deadline: flush on ``slots`` full or on the oldest request aging past
    ``budget_ms``.  ``clock`` is injectable (seconds, monotonic) so tests
    exercise the deadline without sleeping."""

    def __init__(self, slots: int = 8, budget_ms: float = 2.0,
                 clock=time.monotonic):
        if slots < 1:
            raise ValueError("MicroBatcher: slots must be >= 1")
        self.slots = int(slots)
        self.budget_ms = float(budget_ms)
        self.clock = clock
        self._queue: list[_Pending] = []

    def __len__(self) -> int:
        return len(self._queue)

    def add(self, tenant, X, avail=None):
        """Enqueue one request; returns the flushed batch when this request
        fills the last slot, else None."""
        self._queue.append(_Pending(tenant, X, avail, self.clock()))
        if len(self._queue) >= self.slots:
            return self.flush()
        return None

    def due(self) -> bool:
        """True when the oldest queued request has exhausted the budget."""
        if not self._queue:
            return False
        age_ms = (self.clock() - self._queue[0].enqueued_at) * 1e3
        return age_ms >= self.budget_ms

    def flush(self) -> list:
        """Drain the queue (flush on budget: callers poll :meth:`due`)."""
        batch, self._queue = self._queue, []
        return batch


class FleetServer:
    """Multi-tenant GP serving: LRU artifact cache over a checkpoint store,
    device-resident :class:`~repro.core.fleet.FleetStack` per bucket, and
    latency-budgeted micro-batching in front.

    ``store`` is an :class:`~repro.core.fleet.ArtifactStore` (or any object
    with ``load(tenant)``); ``stack_slots`` fixes each stack's resident rows
    (default 2x the flush width, so a working set larger than one batch
    stays resident)."""

    def __init__(self, store, cache_artifacts: int | None = 64,
                 cache_bytes: int | None = None, slots: int = 8,
                 budget_ms: float = 2.0, stack_slots: int | None = None,
                 clock=time.monotonic):
        from repro.core.fleet import ArtifactCache

        self.store = store
        self.cache = ArtifactCache(store.load, capacity=cache_artifacts,
                                   capacity_bytes=cache_bytes)
        self.batcher = MicroBatcher(slots=slots, budget_ms=budget_ms,
                                    clock=clock)
        self.stack_slots = int(stack_slots) if stack_slots else 2 * int(slots)
        if self.stack_slots < int(slots):
            raise ValueError(
                f"FleetServer: stack_slots ({self.stack_slots}) must cover a "
                f"full flush width ({slots}) or a batch could evict its own "
                "members"
            )
        self.clock = clock
        self._stacks: dict = {}
        self.flushes = 0
        self.latencies_ms: list[float] = []

    # -- residency ---------------------------------------------------------

    def _resident(self, tenant):
        """(stack, art) with ``tenant`` resident — cache hit/miss and stack
        admit happen here, off the per-request hot path."""
        from repro.core.fleet import FleetStack, bucket_key

        art = self.cache.get(tenant)
        key = bucket_key(art)
        stack = self._stacks.get(key)
        if stack is None:
            stack = FleetStack({tenant: art}, slots=self.stack_slots)
            self._stacks[key] = stack
        elif tenant not in stack:
            stack.admit(tenant, art)
        else:
            # refresh recency so a later admit in this SAME batch can never
            # evict a tenant that is about to be co-batched
            stack.touch(tenant)
        return stack

    def stacks(self) -> list:
        return list(self._stacks.values())

    # -- request plane -----------------------------------------------------

    def submit(self, tenant, X, avail=None) -> list:
        """Enqueue one request; returns completed ``(tenant, mu, var,
        latency_ms)`` tuples when this submit triggered a flush (slots
        full), else []."""
        batch = self.batcher.add(tenant, X, avail)
        return self._serve(batch) if batch else []

    def poll(self) -> list:
        """Flush on deadline: serve the queue iff the oldest request has
        exhausted the latency budget."""
        if self.batcher.due():
            return self._serve(self.batcher.flush())
        return []

    def drain(self) -> list:
        """Serve whatever is queued regardless of deadline (shutdown)."""
        if len(self.batcher):
            return self._serve(self.batcher.flush())
        return []

    def _serve(self, batch) -> list:
        """Answer one flushed micro-batch: group by bucket, pad each group
        to the fixed flush width, ONE stacked dispatch per bucket."""
        import jax

        self.flushes += 1
        groups: dict = {}
        for req in batch:
            stack = self._resident(req.tenant)
            groups.setdefault(id(stack), (stack, []))[1].append(req)
        out = []
        width = self.batcher.slots
        for stack, reqs in groups.values():
            S = len(reqs)
            tids = [r.tenant for r in reqs]
            Xq = np.stack([np.asarray(r.X, np.float32) for r in reqs])
            avail = None
            if any(r.avail is not None for r in reqs):
                m = len(stack.tree.fit_lengths)
                avail = np.ones((S, m), np.float32)
                for s, r in enumerate(reqs):
                    if r.avail is not None:
                        avail[s] = np.asarray(r.avail, np.float32)
            if S < width:
                # pad to the flush width by repeating row 0: the jitted
                # program sees ONE (width, t, d) shape for every flush, so a
                # ragged tail batch never retraces; padded rows are sliced
                # off before anyone sees them
                reps = width - S
                tids = tids + [tids[0]] * reps
                Xq = np.concatenate([Xq, np.repeat(Xq[:1], reps, 0)])
                if avail is not None:
                    avail = np.concatenate(
                        [avail, np.repeat(avail[:1], reps, 0)]
                    )
            mu, var = stack.predict(tids, Xq, avail)
            jax.block_until_ready(mu)
            done = self.clock()
            for s, r in enumerate(reqs):
                lat = (done - r.enqueued_at) * 1e3
                self.latencies_ms.append(lat)
                out.append((r.tenant, mu[s], var[s], lat))
        return out

    def reset_stats(self) -> None:
        """Zero the latency/flush counters (called between the warm pass and
        the measured steady state so compile latency never pollutes p99)."""
        self.flushes = 0
        self.latencies_ms = []

    def stats(self) -> dict:
        lat = np.asarray(self.latencies_ms) if self.latencies_ms else \
            np.zeros(1)
        return {
            "flushes": self.flushes,
            "requests": len(self.latencies_ms),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "cache": self.cache.stats(),
            "stacks": len(self._stacks),
            "stack_swaps": sum(s.swaps for s in self._stacks.values()),
        }


# --------------------------------------------------------------------------
# fleet construction + traffic loop (CLI, serve_gp --fleet, fleet_bench)
# --------------------------------------------------------------------------


def build_fleet(base_arts, n_tenants: int, store_dir: str):
    """Populate an :class:`~repro.core.fleet.ArtifactStore` with
    ``n_tenants`` artifacts derived from a handful of base fits: tenant i is
    an EXACT y-scaled variant (:func:`~repro.core.fleet.scale_targets`) of
    ``base_arts[i % len(base_arts)]`` — genuinely distinct posteriors, same
    bucket, no per-tenant fit cost.  Returns ``(store, tenant_ids)``;
    tenant ids are zero-padded strings so directory listings sort."""
    from repro.core.fleet import ArtifactStore, scale_targets

    store = ArtifactStore(store_dir)
    width = max(4, len(str(n_tenants - 1)))
    tids = []
    for i in range(n_tenants):
        c = 0.25 + 1.5 * ((i * 2654435761) % 1000) / 1000.0  # spread scales
        art_i = scale_targets(base_arts[i % len(base_arts)], c)
        tid = str(i).zfill(width)
        store.save(tid, art_i)
        tids.append(tid)
    return store, tids


def zipf_tenants(tids, n_requests: int, a: float = 1.1, seed: int = 0):
    """A zipf-mixed request stream over the tenant ids: tenant popularity
    p(rank) ∝ 1/rank^a — a few hot tenants dominate, a long cold tail
    exercises cache misses and stack swaps."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(tids) + 1, dtype=np.float64)
    p = ranks ** (-float(a))
    p /= p.sum()
    order = rng.permutation(len(tids))  # popularity decoupled from id order
    return [tids[order[i]] for i in
            rng.choice(len(tids), size=n_requests, p=p)]


def serve_loop(server: FleetServer, tenant_stream, make_query,
               degraded_every: int = 0, degraded_avail=None) -> dict:
    """Drive a request stream through the server: submit every request,
    poll the deadline between submits, drain at the end.  Every
    ``degraded_every``-th flush-width block tags ONE tenant's request with
    the ``degraded_avail`` mask (per-tenant degraded-mode serving: chaos for
    one tenant must not perturb its co-batched neighbors — tests lock this).
    Returns the server's stats plus the completed-request count."""
    done = 0
    for i, tid in enumerate(tenant_stream):
        avail = None
        if degraded_every and degraded_avail is not None \
                and i % (degraded_every * server.batcher.slots) == 0:
            avail = degraded_avail
        done += len(server.submit(tid, make_query(i), avail))
        done += len(server.poll())
    done += len(server.drain())
    stats = server.stats()
    stats["completed"] = done
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--protocol", default="broadcast",
                    choices=["center", "broadcast", "poe"])
    ap.add_argument("--gram-backend", default="pallas",
                    choices=["xla", "pallas"],
                    help="pallas routes broadcast serving through the "
                         "tenant-batched fused epilogue")
    ap.add_argument("--tenants", type=int, default=64)
    ap.add_argument("--base-fits", type=int, default=2,
                    help="distinct fits; tenants are exact y-scaled variants")
    ap.add_argument("--m", type=int, default=4, help="machines per tenant")
    ap.add_argument("--n", type=int, default=256, help="points per tenant fit")
    ap.add_argument("--d", type=int, default=6)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--cache", type=int, default=32,
                    help="artifact cache capacity (count)")
    ap.add_argument("--cache-bytes", type=int, default=0,
                    help="artifact cache capacity in bytes (0 = unbounded)")
    ap.add_argument("--slots", type=int, default=8,
                    help="micro-batch flush width")
    ap.add_argument("--stack-slots", type=int, default=0,
                    help="resident stack rows (0 = 2x slots)")
    ap.add_argument("--budget-ms", type=float, default=2.0)
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--batch", type=int, default=16,
                    help="query points per request")
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--store-dir", default=None,
                    help="tenant checkpoint store (default: a temp dir)")
    args = ap.parse_args()

    import tempfile

    import jax
    from repro.core import DGPConfig, DistributedGP
    from repro.core.fleet import fleet_trace_count
    from repro.core.protocols import serve_trace_count

    cfg = DGPConfig(
        protocol=args.protocol,
        gram_backend=args.gram_backend,
        gram_mode="dense" if args.protocol == "poe" else "nystrom",
        bits_per_sample=0 if args.protocol == "poe" else args.bits,
        steps=args.steps,
    )
    est = DistributedGP(cfg)
    rng = np.random.default_rng(0)
    W = rng.normal(size=(args.d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])

    t0 = time.perf_counter()
    base_arts = []
    for b in range(args.base_fits):
        X = rng.normal(size=(args.n, args.d)).astype(np.float32)
        y = (f(X) + 0.05 * rng.normal(size=args.n)).astype(np.float32)
        base_arts.append(est.fit(X, y, args.m, key=jax.random.PRNGKey(b)))
    print(f"fit {args.base_fits} base artifact(s) in "
          f"{time.perf_counter() - t0:.2f}s")

    with tempfile.TemporaryDirectory() as td:
        store_dir = args.store_dir or td
        t0 = time.perf_counter()
        store, tids = build_fleet(base_arts, args.tenants, store_dir)
        print(f"stored {len(tids)} tenant artifacts under {store_dir} in "
              f"{time.perf_counter() - t0:.2f}s")
        server = FleetServer(
            store, cache_artifacts=args.cache,
            cache_bytes=args.cache_bytes or None, slots=args.slots,
            budget_ms=args.budget_ms,
            stack_slots=args.stack_slots or None,
        )
        stream = zipf_tenants(tids, args.requests, a=args.zipf)
        make_query = lambda i: rng.normal(
            size=(args.batch, args.d)
        ).astype(np.float32)
        # warm pass traces the per-bucket programs; the measured steady
        # state must then hold every trace counter flat
        serve_loop(server, stream[: 4 * args.slots], make_query)
        server.reset_stats()
        c0 = fleet_trace_count(args.protocol)
        s0 = serve_trace_count(args.protocol)
        t0 = time.perf_counter()
        stats = serve_loop(server, stream, make_query)
        wall = time.perf_counter() - t0
        retraces = (fleet_trace_count(args.protocol) - c0) + \
            (serve_trace_count(args.protocol) - s0)
        qps = args.requests * args.batch / wall
        print(f"served {stats['completed']} requests x {args.batch} pts in "
              f"{wall:.2f}s -> {qps:.0f} q/s aggregate")
        print(f"latency p50 {stats['p50_ms']:.2f} ms  p99 "
              f"{stats['p99_ms']:.2f} ms  (budget {args.budget_ms} ms, "
              f"flush width {args.slots})")
        c = stats["cache"]
        print(f"cache: {c['hits']} hits / {c['misses']} misses "
              f"(rate {c['hit_rate']:.2f}), {c['evictions']} evictions; "
              f"stacks: {stats['stacks']} bucket(s), "
              f"{stats['stack_swaps']} tenant swaps")
        print(f"steady-state retraces: {retraces}")
        if retraces:
            raise SystemExit("FATAL: steady-state fleet loop retraced")


if __name__ == "__main__":
    main()
