"""Training driver.

Runs on whatever devices exist: a reduced config on the CPU container, the
full config + production mesh on a real cluster.  Synthetic LM data by
default; checkpoints + metrics CSV to --workdir.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduce \
      --steps 50 --batch 8 --seq 128 --workdir /tmp/run
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true", help="CPU-scale reduced variant")
    ap.add_argument("--width", type=int, default=None, help="override d_model (reduced)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default=None, help="e.g. '16x16' to use the production mesh")
    ap.add_argument("--qcomm-bits", type=int, default=0,
                    help="quantize the data-parallel gradient all-reduce (paper's scheme; 0=off)")
    args = ap.parse_args()

    import dataclasses
    import jax
    import jax.numpy as jnp
    from ..configs import get_config
    from ..models import make_train_step
    from ..models.steps import init_train_state
    from ..data import lm_batch_stream
    from ..checkpoint import save_checkpoint

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    if args.width:
        cfg = dataclasses.replace(cfg, d_model=args.width, head_dim=args.width // cfg.num_heads)
    if args.layers:
        cfg = dataclasses.replace(cfg, num_layers=args.layers)

    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.1f}M "
          f"layers={cfg.num_layers} d={cfg.d_model}", flush=True)

    step_fn = jax.jit(make_train_step(cfg, peak_lr=args.lr, total_steps=args.steps))
    stream = lm_batch_stream(cfg.vocab_size, args.batch, args.seq)

    extra = {}
    if cfg.family == "encdec":
        extra["enc_embed"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        extra["patch_embed"] = jnp.zeros((args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)

    log_path = os.path.join(args.workdir, "metrics.csv") if args.workdir else None
    if log_path:
        os.makedirs(args.workdir, exist_ok=True)
        with open(log_path, "w") as f:
            f.write("step,loss,grad_norm,lr,sec_per_step\n")

    t_last = time.time()
    for i in range(args.steps):
        batch = {**next(stream), **extra}
        params, opt, metrics = step_fn(params, opt, batch)
        if (i + 1) % args.log_every == 0 or i == 0:
            loss = float(metrics["loss"])
            dt = (time.time() - t_last) / (args.log_every if i else 1)
            t_last = time.time()
            print(f"step {i+1:5d}  loss {loss:.4f}  gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}  {dt:.2f}s/step", flush=True)
            if log_path:
                with open(log_path, "a") as f:
                    f.write(f"{i+1},{loss},{float(metrics['grad_norm'])},{float(metrics['lr'])},{dt}\n")
        if args.workdir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.workdir, i + 1, params)
    if args.workdir:
        save_checkpoint(args.workdir, args.steps, params)
        print(f"final checkpoint in {args.workdir}")


if __name__ == "__main__":
    main()
