"""Distributed-GP serving driver: fit the communication-limited protocol ONCE,
checkpoint the artifact, then serve query batches (and optionally stream new
points) from the cached factors.

  python -m repro.launch.serve_gp --protocol center --m 40 \
      --bits 24 --n 2000 --d 8 --steps 60 --queries 50 --batch 128 \
      --artifact-dir /tmp/gp_artifact [--stream-every 20 --stream-size 16]

The driver builds ONE validated ``DGPConfig`` from the CLI flags and drives
everything through the ``DistributedGP`` facade — protocol, wire scheme
(``--scheme per_symbol|vq``), impl, and backend are all config fields, so the
command line is a 1:1 mirror of the API.  The serve loop deliberately
round-trips through the checkpoint (save -> load) so what is timed is exactly
the production story: a server process that never refits — it loads factors
and answers.  Warm-path structure is printed at the end (retraces,
cholesky/eigh equation counts) alongside latency/throughput.

The loop is hardened for unattended runs: fit and checkpoint-load retry with
exponential backoff, ``--timeout-ms`` tracks per-request latency against a
budget, ``--chaos`` injects a :class:`repro.faults.FaultPlan` (drops, NaN
shards, packed-word bit flips, stragglers) and periodically serves under a
degraded availability mask with a health report, and a mesh reload-parity
failure exits nonzero instead of serving a diverged artifact.
"""
from __future__ import annotations

import argparse
import sys
import time


def _retry(label: str, fn, attempts: int = 3, backoff: float = 0.5,
           sleep=time.sleep):
    """Run ``fn()`` with exponential-backoff retries; re-raise after the last
    attempt (transient load/fit failures should not kill an unattended
    server, persistent ones should).  ``sleep`` is injectable so tests
    exercise the backoff schedule without waiting it out."""
    for k in range(attempts):
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 - last attempt re-raises
            if k == attempts - 1:
                raise
            wait = backoff * (2 ** k)
            print(f"  [{label}] attempt {k + 1}/{attempts} failed "
                  f"({type(e).__name__}: {e}); retrying in {wait:.1f}s",
                  file=sys.stderr)
            sleep(wait)


def _parse_chaos(spec: str):
    """``--chaos`` spec -> FaultPlan: comma-joined ``drop:J``, ``nan:J``,
    ``flip:RATE``, ``straggle:J@SECONDS`` clauses, e.g.
    ``drop:1,flip:0.01,straggle:3@0.2``."""
    from repro.faults import FaultPlan, corrupt_words, drop_machine, nan_shard, straggler

    plan = FaultPlan()
    for clause in spec.split(","):
        clause = clause.strip()
        if not clause:
            continue
        kind, _, val = clause.partition(":")
        if kind == "drop":
            plan = plan | drop_machine(int(val))
        elif kind == "nan":
            plan = plan | nan_shard(int(val))
        elif kind == "flip":
            plan = plan | corrupt_words(float(val))
        elif kind == "straggle":
            j, _, delay = val.partition("@")
            plan = plan | straggler(int(j), float(delay or 0.1))
        else:
            raise ValueError(
                f"unknown chaos clause {clause!r} (known: drop:J, nan:J, "
                "flip:RATE, straggle:J@SECONDS)"
            )
    return plan


def _run_fleet(args, art, degraded_avail, rng):
    """``--fleet`` mode: serve a multi-tenant fleet derived from the fitted
    artifact through the launch.fleet server (LRU artifact cache,
    latency-budgeted micro-batching, one stacked dispatch per flush).  The
    chaos/degraded machinery applies PER TENANT: every 7th flush-width block
    tags one tenant's request with the degraded availability mask, and only
    that tenant's answers renormalize over survivors."""
    import tempfile

    import numpy as np
    from repro.core.fleet import fleet_trace_count
    from repro.core.protocols import serve_trace_count

    from .fleet import FleetServer, build_fleet, serve_loop, zipf_tenants

    n_requests = max(args.queries, 4 * args.fleet_slots)
    with tempfile.TemporaryDirectory() as td:
        store_dir = args.artifact_dir or td
        store, tids = build_fleet([art], args.fleet_tenants, store_dir)
        print(f"fleet: {len(tids)} tenants (y-scaled variants of the fit) "
              f"stored under {store_dir}")
        server = FleetServer(
            store,
            cache_artifacts=args.fleet_cache,
            cache_bytes=args.fleet_cache_bytes or None,
            slots=args.fleet_slots,
            budget_ms=args.fleet_budget_ms,
        )
        stream = zipf_tenants(tids, n_requests, a=args.fleet_zipf)
        make_query = lambda i: rng.normal(
            size=(args.batch, args.d)
        ).astype(np.float32)
        degraded_every = 7 if degraded_avail is not None else 0
        # warm pass traces the per-bucket programs (healthy + degraded
        # shapes); the measured loop must then hold every counter flat
        serve_loop(server, stream[: 4 * args.fleet_slots], make_query,
                   degraded_every=degraded_every,
                   degraded_avail=degraded_avail)
        server.reset_stats()
        c0 = fleet_trace_count(args.protocol)
        s0 = serve_trace_count(args.protocol)
        t0 = time.perf_counter()
        stats = serve_loop(server, stream, make_query,
                           degraded_every=degraded_every,
                           degraded_avail=degraded_avail)
        wall = time.perf_counter() - t0
        retraces = (fleet_trace_count(args.protocol) - c0) + \
            (serve_trace_count(args.protocol) - s0)
        qps = stats["completed"] * args.batch / wall
        c = stats["cache"]
        print(f"fleet serve: {stats['completed']} requests x {args.batch} "
              f"pts in {wall:.2f}s -> {qps:.0f} q/s | p50 "
              f"{stats['p50_ms']:.2f} ms p99 {stats['p99_ms']:.2f} ms "
              f"(budget {args.fleet_budget_ms} ms, flush width "
              f"{args.fleet_slots})")
        print(f"fleet cache: hit rate {c['hit_rate']:.2f} "
              f"({c['hits']}h/{c['misses']}m, {c['evictions']} evictions) | "
              f"{stats['stacks']} stack(s), {stats['stack_swaps']} tenant "
              f"swaps | steady-state retraces={retraces}")
        if retraces:
            print("FATAL: steady-state fleet loop retraced", file=sys.stderr)
            sys.exit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--protocol", default="center",
                    choices=["center", "broadcast", "poe"])
    ap.add_argument("--scheme", default="per_symbol",
                    choices=["per_symbol", "vq"],
                    help="wire scheme: §4.2 per-symbol int codes or the §4.1 "
                         "Theorem-2 optimal test channel (batched impl only)")
    ap.add_argument("--m", type=int, default=40, help="machines (paper §6: 40)")
    ap.add_argument("--bits", type=int, default=24, help="R bits/sample")
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--steps", type=int, default=60, help="hyperparameter steps")
    ap.add_argument("--gram-mode", default="nystrom")
    ap.add_argument("--gram-backend", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--fusion", default=None,
                    help="broadcast fusion / poe combiner (registry name); "
                         "default: kl for broadcast, rbcm for poe")
    ap.add_argument("--queries", type=int, default=50, help="warm query batches")
    ap.add_argument("--batch", type=int, default=128, help="points per query batch")
    ap.add_argument("--artifact-dir", default=None,
                    help="checkpoint the artifact here and serve from the "
                         "loaded copy (omit to serve the in-memory artifact)")
    ap.add_argument("--stream-every", type=int, default=0,
                    help="every k query batches, stream new points in via "
                         "update() (0 = never)")
    ap.add_argument("--stream-size", type=int, default=16,
                    help="points per streaming update")
    ap.add_argument("--mesh", action="store_true",
                    help="machines-as-devices: force --m host devices (CPU) "
                         "and run the wire protocol, factor builds, and "
                         "serving as shard_map programs (impl='mesh')")
    ap.add_argument("--chaos", default=None,
                    help="fault-injection spec, e.g. 'drop:1,flip:0.01,"
                         "straggle:3@0.2' (see docs/fault_model.md); every "
                         "7th serve batch also runs under a degraded "
                         "availability mask with a health report")
    ap.add_argument("--timeout-ms", type=float, default=0.0,
                    help="per-request latency budget; over-budget requests "
                         "are counted and reported (0 = no budget)")
    ap.add_argument("--retries", type=int, default=3,
                    help="fit/load attempts before giving up")
    ap.add_argument("--fleet", action="store_true",
                    help="multi-tenant mode: derive --fleet-tenants y-scaled "
                         "tenants from the fit and serve them through the "
                         "launch.fleet server (LRU artifact cache + "
                         "latency-budgeted micro-batching); chaos/degraded "
                         "masks apply per tenant")
    ap.add_argument("--fleet-tenants", type=int, default=16)
    ap.add_argument("--fleet-cache", type=int, default=8,
                    help="artifact cache capacity (count)")
    ap.add_argument("--fleet-cache-bytes", type=int, default=0,
                    help="artifact cache capacity in bytes (0 = unbounded)")
    ap.add_argument("--fleet-budget-ms", type=float, default=2.0,
                    help="micro-batch latency budget")
    ap.add_argument("--fleet-slots", type=int, default=4,
                    help="micro-batch flush width")
    ap.add_argument("--fleet-zipf", type=float, default=1.1,
                    help="zipf exponent of the tenant traffic mix")
    args = ap.parse_args()

    if args.mesh:
        # must happen before the jax backend initializes
        from repro.compat import force_host_device_count

        force_host_device_count(args.m)

    import numpy as np
    import jax
    from repro.core import DGPConfig, DistributedGP
    from repro.analysis import check_contracts
    from repro.core.protocols import serve_trace_count

    fusion = args.fusion
    if fusion is None:
        fusion = "rbcm" if args.protocol == "poe" else "kl"
    chaos = _parse_chaos(args.chaos) if args.chaos else None
    cfg = DGPConfig(
        protocol=args.protocol,
        scheme=args.scheme,
        fusion=fusion,
        impl="mesh" if args.mesh else "batched",
        gram_backend=args.gram_backend,
        gram_mode="dense" if args.protocol == "poe" else args.gram_mode,
        bits_per_sample=0 if args.protocol == "poe" else args.bits,
        steps=args.steps,
        faults=chaos,
    )
    est = DistributedGP(cfg)
    if chaos is not None:
        print(f"chaos: {chaos}")

    rng = np.random.default_rng(0)
    W = rng.normal(size=(args.d, 2))
    f = lambda Z: np.sin(Z @ W[:, 0]) + 0.4 * (Z @ W[:, 1])
    X = rng.normal(size=(args.n, args.d)).astype(np.float32)
    y = (f(X) + 0.05 * rng.normal(size=args.n)).astype(np.float32)

    t0 = time.perf_counter()
    art = _retry("fit", lambda: est.fit(X, y, args.m, key=jax.random.PRNGKey(0)),
                 attempts=args.retries)
    t_fit = time.perf_counter() - t0
    print(f"fit: protocol={cfg.protocol} scheme={cfg.scheme} impl={art.impl} "
          f"m={args.m} n={args.n} d={args.d} "
          f"R={cfg.bits_per_sample} -> {t_fit:.2f}s, "
          f"wire {art.wire_bits/1e3:.1f} kbit "
          f"(packed payload {art.payload_bits/1e3:.1f} kbit, "
          f"crc {art.integrity_bits/1e3:.1f} kbit, "
          f"{art.rows_demoted} rows demoted)")

    if args.artifact_dir:
        path = est.save(art, args.artifact_dir)
        if args.mesh:
            # the checkpoint round-trips to a single-host artifact; keep
            # serving the sharded mesh copy, but verify the round trip
            loaded = _retry("load", lambda: est.load(args.artifact_dir),
                            attempts=args.retries)
            Xv = rng.normal(size=(8, args.d)).astype(np.float32)
            dmu = float(np.max(np.abs(np.asarray(est.predict(art, Xv)[0])
                                      - np.asarray(est.predict(loaded, Xv)[0]))))
            if not np.isfinite(dmu) or dmu > 1e-4:
                print(f"FATAL: single-host reload of {path} diverges from the "
                      f"mesh artifact (max |dmu| = {dmu:.3e} > 1e-4) — "
                      "refusing to serve", file=sys.stderr)
                sys.exit(1)
            print(f"artifact: saved {path}; single-host reload agrees to "
                  f"{dmu:.1e} (serving the sharded mesh copy); recorded "
                  f"config: {loaded.config.protocol}/{loaded.config.scheme}")
        else:
            art = _retry("load", lambda: est.load(args.artifact_dir),
                         attempts=args.retries)
            print(f"artifact: saved+reloaded {path} (serving the loaded copy)")

    # degraded-mode serving under chaos: every 7th batch drops the chaos
    # plan's machines (or the last machine when the plan names none) and the
    # fusion renormalizes over survivors
    degraded_avail = None
    if chaos is not None and args.protocol in ("broadcast", "poe"):
        lost = set(chaos.drop) or {args.m - 1}
        degraded_avail = np.asarray(
            [0.0 if j in lost else 1.0 for j in range(args.m)], np.float32
        )
        h = est.health(art, degraded_avail)
        print(f"health (degraded mask): status={h.status} "
              f"lost={list(h.machines_lost)} demoted={h.rows_demoted} "
              f"var_inflation={h.variance_inflation:.2f}")
    stragglers = dict(chaos.straggle) if chaos is not None else {}

    if args.fleet:
        _run_fleet(args, art, degraded_avail, rng)
        return

    lat, machine, n_updates = [], 1 % args.m, 0
    n_over = 0  # requests over the --timeout-ms budget
    c0 = None  # trace-count snapshot taken after the first (tracing) batch
    for q in range(args.queries):
        Xq = rng.normal(size=(args.batch, args.d)).astype(np.float32)
        if stragglers and (q % args.m) in stragglers:
            # a straggler holds up its slot of the serve rotation
            time.sleep(stragglers[q % args.m])
        t0 = time.perf_counter()
        if degraded_avail is not None and (q + 1) % 7 == 0:
            mu, var = est.predict(art, Xq, available=degraded_avail)
        else:
            mu, var = est.predict(art, Xq)
        jax.block_until_ready(mu)
        dt = time.perf_counter() - t0
        lat.append(dt)
        if args.timeout_ms and dt * 1e3 > args.timeout_ms and q > 0:
            n_over += 1
        if c0 is None:
            c0 = serve_trace_count(args.protocol)
        if args.stream_every and (q + 1) % args.stream_every == 0:
            Xn = rng.normal(size=(args.stream_size, args.d)).astype(np.float32)
            yn = (f(Xn) + 0.05 * rng.normal(size=args.stream_size)).astype(np.float32)
            t0 = time.perf_counter()
            art = est.update(art, Xn, yn, machine=machine)
            # a growth only retraces the NEXT predict; the last batch's
            # update is never served in this loop
            n_updates += 1 if q + 1 < args.queries else 0
            print(f"  [q{q+1}] streamed {args.stream_size} pts -> machine "
                  f"{machine} in {time.perf_counter()-t0:.3f}s "
                  f"(ledger {art.wire_bits/1e3:.1f} kbit)")

    # contract check is trace-neutral (repro.analysis), so it can run before
    # the retrace delta is read — no snapshot-ordering fragility to maintain
    report = check_contracts(
        art, rng.normal(size=(args.batch, args.d)).astype(np.float32),
        raise_on_violation=False,
    )
    retraces = serve_trace_count(args.protocol) - c0
    lat_ms = np.asarray(lat[1:]) * 1e3  # drop the first (trace) batch
    print(f"serve: {args.queries} batches x {args.batch} pts | warm p50 "
          f"{np.percentile(lat_ms, 50):.2f} ms, p99 {np.percentile(lat_ms, 99):.2f} ms"
          f" | {args.batch/ (np.median(lat_ms)/1e3):.0f} queries/s")
    if args.timeout_ms:
        print(f"timeout budget: {n_over}/{args.queries - 1} warm requests over "
              f"{args.timeout_ms:.0f} ms")
    ops = report.op_counts
    n_coll = sum(v["count"] for v in report.collectives.values())
    print(f"warm path: retraces={retraces} (expected {n_updates}, one per "
          f"streamed growth) cholesky_eqns={ops.get('cholesky', 0)} "
          f"eigh_eqns={ops.get('eigh', 0)} collectives={n_coll} "
          f"contract={report.contract}:{'ok' if report.ok else 'VIOLATED'}")
    if not report.ok:
        for finding in report.findings:
            print(f"contract violation: {finding}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
