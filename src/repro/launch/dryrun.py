"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination
with ShapeDtypeStruct inputs (no allocation) and extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]

Shape skips (documented in DESIGN.md / EXPERIMENTS.md):
  * long_500k only for sub-quadratic-state archs (ssm / hybrid / gemma2
    sliding window); skipped for pure full-attention archs.

The 512 placeholder devices are forced only under __main__ (or an explicit
force_placeholder_devices() call) — importing this module leaves the
process's device configuration alone.
"""
import os

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config, list_archs, input_specs
from ..models import SHAPES, make_train_step, make_prefill_step, make_decode_step
from ..models.steps import init_train_state
from ..models.decode import init_decode_state, decode_state_specs
from ..models.sharding import (
    logical_rules,
    rules_single_pod,
    rules_multi_pod,
    rules_long_context,
    tree_param_specs,
)
from .mesh import make_production_mesh, PEAK_FLOPS_BF16, HBM_BW, ICI_BW
from ..roofline import analyze_hlo
from ..compat import set_mesh, cost_analysis_dict

LONG_CONTEXT_OK = {"xlstm-125m", "zamba2-2.7b", "gemma2-2b"}


def force_placeholder_devices(n: int = 512) -> None:
    """Force ``n`` placeholder host devices for the multi-pod dry-run.

    Must run before the jax backend initializes (first device query).  This
    is deliberately NOT done at import time: importing this module must not
    stomp the process's device configuration (e.g. the test conftest's
    8-device setting) — only the ``__main__`` entry point forces 512.
    """
    from ..compat import force_host_device_count

    force_host_device_count(n)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-buffer bytes of every collective op in the (per-device SPMD)
    optimized HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    # e.g.:  %ag = bf16[2,4096,3072] all-gather(...)
    pat = re.compile(
        r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\b(" + "|".join(_COLLECTIVES) + r")\b"
    )
    for m in pat.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        size = _DTYPE_BYTES.get(dtype, 4)
        for d in dims.split(","):
            if d:
                size *= int(d)
        out[op] += size
    return out


def skip_reason(arch: str, shape_name: str):
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return "full-attention arch: 500k dense KV decode is quadratic-state; skipped per assignment"
    return None


def build_lowerable(arch: str, shape_name: str, mesh, multi_pod: bool):
    """Returns (fn, example_args) ready for jit(...).lower(*args)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        rules = rules_long_context(multi_pod) if shape_name == "long_500k" else (
            rules_multi_pod() if multi_pod else rules_single_pod()
        )
    else:
        rules = rules_multi_pod() if multi_pod else rules_single_pod()

    with logical_rules(rules):
        params_sds = jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg))
        params_abs, opt_abs = params_sds
        pspecs = tree_param_specs(params_abs, mesh)
        ospecs = type(opt_abs)(step=P(), m=tree_param_specs(opt_abs.m, mesh), v=tree_param_specs(opt_abs.v, mesh))

        def shard(sds_tree, spec_tree):
            return jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
                sds_tree, spec_tree,
            )

        batch_rules = rules  # batch axes
        if shape.kind == "train":
            batch = input_specs(cfg, shape)
            bspec = jax.tree.map(
                lambda s: P(batch_rules["batch"], *([None] * (len(s.shape) - 1))), batch
            )
            # gradient accumulation: keep ~128k global tokens per microbatch
            # (REPRO_MB_TOKENS overrides; perf iterations sweep this)
            # per-device microbatch share halves across pods; scale the
            # global microbatch so per-device live activations stay constant
            default_mb = cfg.train_mb_tokens * (2 if multi_pod else 1)
            mb_tokens = int(os.environ.get("REPRO_MB_TOKENS", default_mb))
            mb = max(1, shape.global_batch * shape.seq_len // mb_tokens)
            while shape.global_batch % mb:
                mb -= 1
            qbits = int(os.environ.get("REPRO_QCOMM_BITS", 0))
            fn = make_train_step(cfg, microbatches=mb,
                                 qcomm_bits=qbits if multi_pod else 0)
            donate = (0, 1)  # params + opt state update in place
            args = (
                shard(params_abs, pspecs),
                type(opt_abs)(
                    step=jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
                    m=shard(opt_abs.m, ospecs.m),
                    v=shard(opt_abs.v, ospecs.v),
                ),
                shard(batch, bspec),
            )
            out_shardings = None
        elif shape.kind == "prefill":
            batch = input_specs(cfg, shape)
            bspec = jax.tree.map(
                lambda s: P(batch_rules["batch"], *([None] * (len(s.shape) - 1))), batch
            )
            fn = make_prefill_step(cfg)
            donate = ()
            args = (shard(params_abs, pspecs), shard(batch, bspec))
            out_shardings = None
        else:  # decode
            B = shape.global_batch
            state_abs = jax.eval_shape(lambda: init_decode_state(cfg, B, shape.seq_len))
            sspecs = decode_state_specs(state_abs, mesh)
            tok = jax.ShapeDtypeStruct(
                (B, 1), jnp.int32,
                sharding=NamedSharding(mesh, P(batch_rules.get("batch"), None)),
            )
            pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
            fn = make_decode_step(cfg)
            donate = (1,)  # cache state updates in place
            args = (shard(params_abs, pspecs), shard(state_abs, sspecs), tok, pos)
            out_shardings = None
    return fn, args, rules, donate


def model_flops_estimate(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode D = batch tokens."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_params, n_active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per sequence


def param_counts(cfg):
    """(total, active-per-token) parameter counts from the config algebra."""
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd, Hq, Hkv = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    attn = D * hd * (Hq + 2 * Hkv) + Hq * hd * D
    gate = 2 if cfg.activation in ("swiglu", "geglu") else 1
    mlp = D * F * gate + F * D if F else 0
    embed = V * D * (1 if cfg.tie_embeddings else 2)
    total = active = 0
    if cfg.family in ("dense", "vlm"):
        total = active = cfg.num_layers * (attn + mlp)
    elif cfg.family == "moe":
        e_mlp = D * cfg.moe_d_ff * gate + cfg.moe_d_ff * D
        shared = (D * cfg.shared_d_ff * gate + cfg.shared_d_ff * D) if cfg.num_shared_experts else 0
        dense_res = mlp if cfg.moe_dense_residual else 0
        total = cfg.num_layers * (attn + cfg.num_experts * e_mlp + shared + dense_res)
        active = cfg.num_layers * (attn + cfg.top_k * e_mlp + shared + dense_res)
    elif cfg.family == "ssm":
        # mLSTM ~ 4 D*Hq*hd + gates; sLSTM ~ 4 D*H*hd + rec
        pair = (4 * D * Hq * hd + D * 2 * Hq + D * Hq * hd) + (4 * D * Hq * hd + Hq * hd * 4 * hd + Hq * hd * D)
        total = active = (cfg.num_layers // 2) * pair
    elif cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * D
        mamba = D * (2 * d_inner + 2 * cfg.ssm_state + Hq) + d_inner * D
        total = active = cfg.num_layers * mamba + (attn + mlp)  # one shared block
    elif cfg.family == "encdec":
        total = active = cfg.enc_layers * (attn + mlp) + cfg.num_layers * (2 * attn + mlp)
    total += embed
    active += embed
    return float(total), float(active)


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True):
    reason = skip_reason(arch, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod, "skipped": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    fn, args, rules, donate = build_lowerable(arch, shape_name, mesh, multi_pod)
    with set_mesh(mesh), logical_rules(rules):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        # trip-count-aware walk of the optimized HLO (XLA's cost_analysis
        # counts while bodies once — see repro.roofline.hlo_cost)
        parsed = analyze_hlo(compiled.as_text())
        t_analyze = time.time() - t0 - t_lower - t_compile

    flops_dev = parsed.flops
    bytes_dev = parsed.bytes
    coll_bytes = parsed.collective_bytes
    coll = {k: v for k, v in parsed.collectives.items()}
    res = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_bytes,
            "collectives": coll,
            "xla_flops_noloop": float(cost.get("flops", 0.0)),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            # arguments + the temp allocation slab (buffer reuse is already
            # folded into the slab size).  NOTE peak_memory_in_bytes on the
            # CPU backend reports only args+outputs — not usable.
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "roofline": roofline_terms(flops_dev, bytes_dev, coll_bytes),
        "model_flops_global": model_flops_estimate(arch, shape_name),
    }
    res["roofline"]["useful_flops_ratio"] = (
        res["model_flops_global"] / (flops_dev * n_chips) if flops_dev else None
    )
    if verbose:
        r = res["roofline"]
        print(
            f"{arch:20s} {shape_name:12s} pods={2 if multi_pod else 1} "
            f"compile={t_compile:6.1f}s  compute={r['compute_s']:.3e}s "
            f"memory={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s "
            f"dom={r['dominant']}  peakGB={res['memory']['peak_bytes']/1e9 if res['memory']['peak_bytes'] else -1:.2f}",
            flush=True,
        )
    return res


def roofline_terms(flops_dev, bytes_dev, coll_bytes_dev):
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_bytes_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    return {**terms, "dominant": dom.replace("_s", "")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    combos = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    results = []
    for a, s, mp in combos:
        try:
            results.append(run_one(a, s, mp))
        except Exception as e:  # a failure here is a bug in the system
            results.append({"arch": a, "shape": s, "multi_pod": mp, "error": f"{type(e).__name__}: {e}"})
            print(f"{a:20s} {s:12s} FAILED: {type(e).__name__}: {str(e)[:200]}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_err = sum("error" in r for r in results)
    print(f"\n{len(results)} combos, {n_err} failures, "
          f"{sum('skipped' in r for r in results)} documented skips")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    force_placeholder_devices()
    main()
