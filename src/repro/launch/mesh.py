"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax import
so both meshes can be built on the CPU container.
"""
from __future__ import annotations

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link
