"""Repo-rule lint: the source-level plane of the contract checker.

The jaxpr contracts (:mod:`.contracts`) verify compiled programs; this AST
pass verifies the SOURCE conventions that keep those programs checkable —
factorizations funneled through ``core/linalg_safe.py``, one jitter constant,
XLA_FLAGS mutation only in ``compat.py``, no host pulls in hot modules,
registries populated at import time, trace counters touched only through the
contract API.  Run it as::

    python -m repro.analysis.lint src/            # exit 1 on any violation

CI runs exactly that; ``tests/test_analysis.py`` pins each rule firing on a
known-bad fixture and the real tree lint-clean.

Active rules
------------
raw-cholesky
    No on-device ``*.linalg.cholesky`` call outside ``core/linalg_safe.py``
    — every factorization goes through ``chol_jittered``/``chol_safe`` so
    jitter policy and escalation live in ONE place (numpy/scipy host-oracle
    calls are exempt).
raw-eigh
    Same for ``*.linalg.eigh``/``eig`` (``linalg_safe.eigh_sym`` is the
    on-device home).
local-jitter
    No module grows its own ``_JITTER`` constant (or rebinds
    ``DEFAULT_JITTER``): the one pinned value is
    ``linalg_safe.DEFAULT_JITTER``.
xla-env-mutation
    ``os.environ["XLA_FLAGS"]`` is process-global, order-sensitive state;
    only ``compat.force_host_device_count`` may touch it (a stray mutation
    after backend init silently does nothing — the PR-3 dryrun bug).
device-get-hot-path
    No ``device_get`` in ``kernels/`` at all, and in ``core/protocols/``
    only inside the named host-sync boundary functions (the ledger
    properties, the fit-time mesh unshard, the bucket-crossing growth) —
    anywhere else it is a per-call host round-trip in a hot path.
registry-top-level
    ``register_*`` calls (kernels, schemes, fusions, protocols, kernel ops,
    contracts) run at module top level only, so one import populates the
    registry deterministically and duplicate-registration errors surface at
    import time, not mid-serve.
trace-counter-encapsulation
    ``_SERVE_TRACES``/``_UPDATE_TRACES`` are implementation details of
    ``core/protocols`` (plus ``repro/analysis``, which implements the
    trace-neutral snapshot/restore); everything else budgets retraces
    through ``repro.analysis.retrace_budget`` / the ``*_trace_count``
    wrappers.
"""
from __future__ import annotations

import ast
import dataclasses
import sys
from pathlib import Path

__all__ = ["Violation", "RULES", "lint_source", "lint_file", "lint_paths", "main"]

# host numerics roots exempt from the factorization-funnel rules (scipy/numpy
# run on host, carry no jitter policy, and appear in the paper oracles only)
_HOST_ROOTS = {"np", "numpy", "scipy", "sp", "onp"}

# the sanctioned host-sync boundaries inside core/protocols/ — each is a
# documented ONE-host-round-trip point, not a hot loop (see the module
# docstrings at the definitions).  Keyed by module basename; any device_get
# lexically inside one of these functions is allowed, everything else fires.
_PROTOCOL_HOST_SYNC = {
    "base.py": {
        # FittedProtocol's legacy integer views: explicit host sync of the
        # device-resident StreamState ledgers
        "lengths", "wire_bits", "payload_bits", "integrity_bits",
        "rows_demoted",
    },
    "mesh.py": {
        # the PR-8 fix: ONE fit-time pull that erases the committed
        # replicated sharding before it can leak into serve jits
        "_run_wire_protocol_mesh",
        # the same boundary on the streaming side: the update wrapper
        # host-syncs only the leaked bookkeeping leaves (params/y/wire/
        # stream), never the mesh-sharded factor buffers
        "_update_mesh_jit",
    },
    "streaming.py": {
        # bucket-crossing growth: the ONE host synchronization of the
        # streaming path (ensure_capacity docstring)
        "ensure_capacity", "_pad_last", "_pad_rows", "_pad_chol",
    },
}

_REGISTER_CALLS = (
    "register_kernel", "register_scheme", "register_fusion",
    "register_protocol", "register_kernel_op", "register_contract",
    "register_tune_candidates",
)

RULES = {
    "raw-cholesky":
        "on-device cholesky outside core/linalg_safe.py (use chol_jittered/"
        "chol_safe)",
    "raw-eigh":
        "on-device eigh/eig outside core/linalg_safe.py (use eigh_sym)",
    "local-jitter":
        "local _JITTER constant / DEFAULT_JITTER rebinding (the one home is "
        "linalg_safe.DEFAULT_JITTER)",
    "xla-env-mutation":
        "XLA_FLAGS environment mutation outside repro/compat.py",
    "device-get-hot-path":
        "device_get in kernels/ or outside the named host-sync boundaries of "
        "core/protocols/",
    "registry-top-level":
        "register_* call below module top level (registries populate at "
        "import time)",
    "trace-counter-encapsulation":
        "_SERVE_TRACES/_UPDATE_TRACES touched outside core/protocols/ (use "
        "repro.analysis.retrace_budget)",
}


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _dotted(node) -> str:
    """``a.b.c`` for a Name/Attribute chain; '' for anything dynamic."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


@dataclasses.dataclass(frozen=True)
class _FileKind:
    """Which rule scopes apply to one file, derived from its repo path."""

    is_linalg_safe: bool
    is_compat: bool
    in_kernels: bool
    in_protocols: bool
    in_analysis: bool
    basename: str

    @classmethod
    def of(cls, path: str) -> "_FileKind":
        p = Path(path).as_posix()
        return cls(
            is_linalg_safe=p.endswith("core/linalg_safe.py"),
            is_compat=p.endswith("repro/compat.py"),
            in_kernels="repro/kernels/" in p or p.startswith("kernels/"),
            in_protocols="core/protocols/" in p,
            in_analysis="repro/analysis/" in p,
            basename=Path(path).name,
        )


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, kind: _FileKind):
        self.path = path
        self.kind = kind
        self.out: list[Violation] = []
        self._func_stack: list[str] = []

    def _flag(self, node, rule: str, message: str) -> None:
        self.out.append(Violation(
            self.path, node.lineno, node.col_offset, rule, message
        ))

    # -- scope tracking ----------------------------------------------------

    def visit_FunctionDef(self, node):
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        self._func_stack.append("<lambda>")
        self.generic_visit(node)
        self._func_stack.pop()

    # -- rules -------------------------------------------------------------

    def visit_Call(self, node):
        dotted = _dotted(node.func)
        root = dotted.split(".", 1)[0]
        tail = dotted.rsplit(".", 1)[-1]

        if not self.kind.is_linalg_safe and root not in _HOST_ROOTS:
            if dotted.endswith(".linalg.cholesky"):
                self._flag(node, "raw-cholesky",
                           f"{dotted}: factorizations go through "
                           "linalg_safe.chol_jittered/chol_safe")
            elif dotted.endswith((".linalg.eigh", ".linalg.eig")):
                self._flag(node, "raw-eigh",
                           f"{dotted}: eigendecompositions go through "
                           "linalg_safe.eigh_sym")

        if tail == "device_get":
            if self.kind.in_kernels:
                self._flag(node, "device-get-hot-path",
                           "device_get in a kernels/ module (host round-trip "
                           "in the dispatch path)")
            elif self.kind.in_protocols:
                allowed = _PROTOCOL_HOST_SYNC.get(self.kind.basename, set())
                if not any(f in allowed for f in self._func_stack):
                    self._flag(node, "device-get-hot-path",
                               "device_get outside the named host-sync "
                               "boundaries of core/protocols/")

        if tail in _REGISTER_CALLS and self._func_stack:
            self._flag(node, "registry-top-level",
                       f"{tail}() inside {self._func_stack[-1]!r}: registry "
                       "registration happens at module top level")

        if not self.kind.is_compat and dotted in (
            "os.environ.setdefault", "os.environ.update", "os.environ.pop",
            "os.putenv",
        ):
            for arg in node.args:
                if isinstance(arg, ast.Constant) and "XLA_FLAGS" in str(arg.value):
                    self._flag(node, "xla-env-mutation",
                               "XLA_FLAGS mutated outside repro/compat.py")
        self.generic_visit(node)

    def visit_Assign(self, node):
        for target in node.targets:
            self._check_store(target, node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node):
        self._check_store(node.target, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        self._check_store(node.target, node)
        self.generic_visit(node)

    def _check_store(self, target, node):
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._check_store(elt, node)
            return
        if isinstance(target, ast.Name) and not self.kind.is_linalg_safe:
            if target.id == "_JITTER" or target.id == "DEFAULT_JITTER":
                self._flag(node, "local-jitter",
                           f"{target.id} bound outside linalg_safe (import "
                           "linalg_safe.DEFAULT_JITTER instead)")
        if isinstance(target, ast.Subscript) and not self.kind.is_compat:
            base = _dotted(target.value)
            key = target.slice
            if base.endswith("environ") and isinstance(key, ast.Constant) \
                    and "XLA_FLAGS" in str(key.value):
                self._flag(node, "xla-env-mutation",
                           "XLA_FLAGS mutated outside repro/compat.py "
                           "(use compat.force_host_device_count)")

    def visit_ImportFrom(self, node):
        if not self.kind.is_linalg_safe:
            for alias in node.names:
                if alias.name == "_JITTER":
                    self._flag(node, "local-jitter",
                               "importing _JITTER (import "
                               "linalg_safe.DEFAULT_JITTER instead)")
        module = node.module or ""
        if module.startswith("jax") and module.endswith("linalg") \
                and not self.kind.is_linalg_safe:
            for alias in node.names:
                if alias.name == "cholesky":
                    self._flag(node, "raw-cholesky",
                               "importing cholesky from jax linalg (use "
                               "linalg_safe)")
                elif alias.name in ("eigh", "eig"):
                    self._flag(node, "raw-eigh",
                               "importing eigh from jax linalg (use "
                               "linalg_safe.eigh_sym)")
        self.generic_visit(node)

    def visit_Name(self, node):
        if node.id in ("_SERVE_TRACES", "_UPDATE_TRACES") \
                and not (self.kind.in_protocols or self.kind.in_analysis):
            self._flag(node, "trace-counter-encapsulation",
                       f"{node.id} accessed outside core/protocols/ (use "
                       "repro.analysis.retrace_budget / *_trace_count)")
        self.generic_visit(node)

    def visit_Attribute(self, node):
        if node.attr in ("_SERVE_TRACES", "_UPDATE_TRACES") \
                and not (self.kind.in_protocols or self.kind.in_analysis):
            self._flag(node, "trace-counter-encapsulation",
                       f"{node.attr} accessed outside core/protocols/ (use "
                       "repro.analysis.retrace_budget / *_trace_count)")
        self.generic_visit(node)


def lint_source(source: str, path: str = "<string>") -> list[Violation]:
    """Lint one source text as if it lived at ``path`` (the path decides
    which scoped rules apply — tests feed synthetic paths)."""
    tree = ast.parse(source, filename=path)
    linter = _Linter(path, _FileKind.of(path))
    linter.visit(tree)
    return sorted(linter.out, key=lambda v: (v.line, v.col, v.rule))


def lint_file(path) -> list[Violation]:
    return lint_source(Path(path).read_text(), str(path))


def lint_paths(paths) -> list[Violation]:
    """Lint files and/or directory trees (directories recurse over *.py)."""
    out: list[Violation] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            out.extend(lint_file(f))
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-rule lint (serve/wire source contracts)",
    )
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the active rule table and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule:28s} {desc}")
        return 0
    violations = lint_paths(args.paths or ["src"])
    for v in violations:
        print(v)
    n = len(violations)
    print(f"{n} violation(s), {len(RULES)} active rule(s)"
          if n else f"clean ({len(RULES)} active rule(s))")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
