"""Static analysis of the compiled programs and the source tree.

Two planes (see docs/program_contracts.md):

* :mod:`.jaxpr_walk` + :mod:`.contracts` — the program plane: recursive
  primitive visitation of the actual serve/update jaxprs, a declarative
  :class:`~.contracts.Contract` rule vocabulary (primitive budgets, host
  callbacks, collective accounting, sharding leaks, ledger cross-checks),
  per-protocol contracts registered next to each protocol, and the
  :func:`~.contracts.check_contracts` enforcement entry point (trace-neutral
  by construction).
* :mod:`.lint` — the source plane: ``python -m repro.analysis.lint src/``
  enforces the repo conventions that keep the program plane checkable.
"""
from .jaxpr_walk import (
    COLLECTIVE_PRIMITIVES,
    FACTORIZATION_PRIMITIVES,
    HOST_CALLBACK_PRIMITIVES,
    collective_stats,
    primitive_counts,
    walk_jaxpr,
)
from .contracts import (
    CollectiveBudget,
    Contract,
    ContractReport,
    ContractViolation,
    Finding,
    LedgerAccounting,
    NoHostCallbacks,
    NoShardingLeak,
    PrimitiveBudget,
    check_contracts,
    contract_for,
    find_sharding_leaks,
    forbid_primitives,
    predict_jaxpr,
    register_contract,
    retrace_budget,
)

__all__ = [
    "COLLECTIVE_PRIMITIVES",
    "FACTORIZATION_PRIMITIVES",
    "HOST_CALLBACK_PRIMITIVES",
    "walk_jaxpr",
    "primitive_counts",
    "collective_stats",
    "Contract",
    "ContractReport",
    "ContractViolation",
    "Finding",
    "PrimitiveBudget",
    "forbid_primitives",
    "NoHostCallbacks",
    "CollectiveBudget",
    "NoShardingLeak",
    "LedgerAccounting",
    "register_contract",
    "contract_for",
    "check_contracts",
    "predict_jaxpr",
    "find_sharding_leaks",
    "retrace_budget",
]
