"""Recursive jaxpr traversal — the primitive-level plane of the contract
checker.

A compiled program's jaxpr is the ground truth of what the hot path actually
does: every factorization is a ``cholesky``/``eigh`` equation, every host
round-trip is a callback primitive, every inter-machine byte is a collective
equation.  This module walks a (closed) jaxpr INCLUDING every sub-jaxpr a
primitive carries in its params — ``pjit`` bodies, ``shard_map`` bodies,
``scan``/``while``/``cond`` carries, ``custom_jvp``/``custom_vjp`` rules — so
counts cover the whole program, not just its top level.  It is deliberately
free of any ``repro`` import: :mod:`repro.analysis.contracts` builds the
declarative rule layer on top, and :func:`repro.core.protocols.base.
predict_op_counts` is a thin wrapper over :func:`primitive_counts`.
"""
from __future__ import annotations

import collections

import jax

try:  # jax >= 0.4.16 re-exports the core IR types under jax.extend
    from jax.extend.core import ClosedJaxpr, Jaxpr
except ImportError:  # pragma: no cover - older jax spells them jax.core
    from jax.core import ClosedJaxpr, Jaxpr

__all__ = [
    "HOST_CALLBACK_PRIMITIVES",
    "COLLECTIVE_PRIMITIVES",
    "FACTORIZATION_PRIMITIVES",
    "walk_jaxpr",
    "primitive_counts",
    "collective_stats",
    "aval_bytes",
    "jaxpr_of",
]

# primitives that punch through the device boundary at run time: any of these
# inside a hot-path program is a host round-trip per dispatch (the PR-7 bug
# class: update() pulling factors to host between jitted segments)
HOST_CALLBACK_PRIMITIVES = frozenset({
    "pure_callback",
    "io_callback",
    "debug_callback",
    "callback",
    "outside_call",  # legacy host_callback spelling
    "host_local_array_to_global_array",
    "global_array_to_host_local_array",
})

# cross-device communication primitives — the §4 wire is made of exactly
# these, so counting them per program IS the collective accounting plane
COLLECTIVE_PRIMITIVES = frozenset({
    "psum",
    "psum2",  # shard_map's replication-rewrite spelling (check_rep=True)
    "all_gather",
    "all_gather_invariant",
    "all_to_all",
    "ppermute",
    "pmax",
    "pmin",
    "psum_scatter",
    "reduce_scatter",
    "pbroadcast",
})

# one-shot O(n^3) decompositions — zero of these may appear in a warm serve
# program (triangular solves against cached factors are the only linalg)
FACTORIZATION_PRIMITIVES = frozenset({"cholesky", "eigh", "eig", "svd", "qr", "lu"})


def _as_jaxpr(jaxpr):
    return jaxpr.jaxpr if isinstance(jaxpr, ClosedJaxpr) else jaxpr


def _sub_jaxprs(param_value):
    """Every Jaxpr hiding in one eqn param value (covers the list-of-branches
    layout of ``cond``, the (jaxpr, consts) tuples of custom derivatives, and
    the plain ClosedJaxpr params of ``pjit``/``shard_map``/``scan``)."""
    if isinstance(param_value, ClosedJaxpr):
        yield param_value.jaxpr
    elif isinstance(param_value, Jaxpr):
        yield param_value
    elif isinstance(param_value, (list, tuple)):
        for item in param_value:
            yield from _sub_jaxprs(item)


def walk_jaxpr(jaxpr):
    """Yield every equation of ``jaxpr`` (Jaxpr or ClosedJaxpr) and of every
    sub-jaxpr reachable through equation params, depth-first."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for pv in eqn.params.values():
            for sub in _sub_jaxprs(pv):
                yield from walk_jaxpr(sub)


def primitive_counts(jaxpr, names=None) -> collections.Counter:
    """Count primitive names over the whole (recursive) program.  ``names``:
    restrict to these (the returned counter then has an entry — possibly 0 —
    for each requested name, so budget checks never KeyError)."""
    counts = collections.Counter()
    if names is not None:
        counts.update({name: 0 for name in names})
    for eqn in walk_jaxpr(jaxpr):
        name = eqn.primitive.name
        if names is None or name in names:
            counts[name] += 1
    return counts


def aval_bytes(aval) -> int:
    """Bytes of one abstract value (0 for abstract tokens/opaque avals)."""
    size = getattr(aval, "size", None)
    dtype = getattr(aval, "dtype", None)
    if size is None or dtype is None:
        return 0
    return int(size) * int(dtype.itemsize)


def collective_stats(jaxpr) -> dict:
    """Per-collective accounting over the whole program: for each collective
    primitive present, its equation count and the summed OUTPUT payload bytes
    (what the collective materializes on every participant — the quantity the
    §4 ledger budgets).  Returns ``{name: {"count": int, "bytes": int}}``."""
    stats: dict = {}
    for eqn in walk_jaxpr(jaxpr):
        name = eqn.primitive.name
        if name not in COLLECTIVE_PRIMITIVES:
            continue
        entry = stats.setdefault(name, {"count": 0, "bytes": 0})
        entry["count"] += 1
        entry["bytes"] += sum(aval_bytes(v.aval) for v in eqn.outvars)
    return stats


def jaxpr_of(fn, *args, **kwargs) -> ClosedJaxpr:
    """``jax.make_jaxpr`` as an expression (the contract checker's program
    builder); kwargs are passed through as static."""
    return jax.make_jaxpr(fn)(*args, **kwargs)
