"""Declarative program contracts for the serve/wire hot paths.

The repo's production guarantees are *structural* facts about compiled
programs — a warm predict contains zero factorizations, an update is one
jitted program with no host round-trip, the mesh wire is the only collective
channel, nothing escapes a fit committed to a mesh sharding.  Until now each
was enforced ad-hoc (``predict_op_counts`` asserts sprinkled through tests,
trace-counter deltas snapshotted in the right order by hand).  This module
makes them first-class:

* a :class:`Rule` vocabulary over the planes the checker inspects — the
  recursive jaxpr (:class:`PrimitiveBudget`, :class:`NoHostCallbacks`,
  :class:`CollectiveBudget`), the committed shardings of the artifact's
  array leaves (:class:`NoShardingLeak` — the PR-8 bug class), and the
  §4 ledgers cross-checked against :mod:`repro.comm.accounting`
  (:class:`LedgerAccounting`);
* a :class:`Contract` = named rule bundle, declared NEXT TO each protocol
  (``center.py``/``broadcast.py``/``poe.py``/``mesh.py`` call
  :func:`register_contract` at import time) and looked up per
  (protocol, impl, phase);
* one enforcement entry point, :func:`check_contracts`, which builds the
  artifact's actual serve program TRACE-NEUTRALLY (the serve/update trace
  counters are snapshotted and restored, so checking an artifact never
  perturbs a retrace-budget measurement) and raises
  :class:`ContractViolation` with every finding, or returns the full
  :class:`ContractReport`;
* :func:`retrace_budget` — the trace counters as a contract: a context
  manager that fails if the wrapped block (re)traces more than budgeted.

docs/program_contracts.md tabulates the shipped contracts per
protocol × phase and how to add a rule.
"""
from __future__ import annotations

import contextlib
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from .jaxpr_walk import (
    COLLECTIVE_PRIMITIVES,
    FACTORIZATION_PRIMITIVES,
    HOST_CALLBACK_PRIMITIVES,
    collective_stats,
    primitive_counts,
)

__all__ = [
    "Finding",
    "ContractViolation",
    "ContractReport",
    "Contract",
    "PrimitiveBudget",
    "forbid_primitives",
    "NoHostCallbacks",
    "CollectiveBudget",
    "NoShardingLeak",
    "LedgerAccounting",
    "register_contract",
    "contract_for",
    "check_contracts",
    "find_sharding_leaks",
    "retrace_budget",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation: which contract/rule fired and on what."""

    contract: str
    rule: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.contract}] {self.rule}: {self.detail}"


class ContractViolation(AssertionError):
    """Raised by :func:`check_contracts` (and :func:`retrace_budget`) with
    every finding attached — an AssertionError so existing pytest suites
    treat a broken contract exactly like a failed assert."""

    def __init__(self, findings):
        self.findings = tuple(findings)
        super().__init__(
            "program contract violated:\n  "
            + "\n  ".join(str(f) for f in self.findings)
        )


@dataclasses.dataclass(frozen=True)
class ContractReport:
    """What :func:`check_contracts` measured: the contract that ran, the
    primitive counts and collective stats of the actual serve program, the
    sharding-leak scan result, and any findings (empty = contract holds)."""

    contract: str
    protocol: str
    impl: str
    phase: str
    op_counts: dict
    collectives: dict
    leaks: tuple
    findings: tuple

    @property
    def ok(self) -> bool:
        return not self.findings


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PrimitiveBudget:
    """Per-primitive equation budgets over the recursive program jaxpr.

    ``budgets``: ``{primitive_name: max_allowed_count}`` — the warm-serve
    contract is ``{"cholesky": 0, "eigh": 0}`` (no refactorization, no scheme
    refit), generalizing the old ``predict_op_counts`` assert into a
    declarative rule."""

    budgets: tuple  # ((name, max_count), ...) — hashable/declarable inline
    name: str = "primitive-budget"

    def check(self, ctx) -> list:
        if ctx.jaxpr is None:
            return []
        budgets = dict(self.budgets)
        counts = primitive_counts(ctx.jaxpr, names=budgets.keys())
        return [
            f"{prim}: {counts[prim]} eqns > budget {cap}"
            for prim, cap in budgets.items()
            if counts[prim] > cap
        ]


def forbid_primitives(*names) -> PrimitiveBudget:
    """A zero budget for each named primitive (``forbid_primitives
    ("cholesky", "eigh")`` is the §5 warm-serve factorization contract);
    with no names, forbids every one-shot factorization decomposition."""
    names = names or tuple(sorted(FACTORIZATION_PRIMITIVES))
    return PrimitiveBudget(budgets=tuple((n, 0) for n in names))


@dataclasses.dataclass(frozen=True)
class NoHostCallbacks:
    """No host round-trip may hide inside the program: callback primitives
    (``pure_callback``/``io_callback``/``debug_callback``/...) punch through
    the device boundary once per dispatch — the PR-7 update() bug class."""

    allow: tuple = ()
    name: str = "no-host-callbacks"

    def check(self, ctx) -> list:
        if ctx.jaxpr is None:
            return []
        banned = HOST_CALLBACK_PRIMITIVES - set(self.allow)
        counts = primitive_counts(ctx.jaxpr, names=banned)
        return [
            f"host-transfer primitive {prim!r} appears {n}x in a hot-path "
            "program (one host round-trip per dispatch)"
            for prim, n in sorted(counts.items())
            if n > 0
        ]


@dataclasses.dataclass(frozen=True)
class CollectiveBudget:
    """The wire is the ONLY collective channel, and it is budgeted.

    ``max_count``: total collective equations allowed in the program (the
    batched serve path budgets 0 — machines are a vmap axis, nothing may
    synchronize; the fused mesh epilogue budgets exactly 1 stacked psum).
    ``max_bytes``: optional ceiling on the summed collective output payload —
    cross-checked against the Theorem-1 ledger by the mesh contracts (a
    collective moving more than the accounted payload is an unaccounted
    channel)."""

    max_count: int = 0
    max_bytes: int | None = None
    names: frozenset = COLLECTIVE_PRIMITIVES
    name: str = "collective-budget"

    def check(self, ctx) -> list:
        if ctx.jaxpr is None:
            return []
        stats = {
            k: v for k, v in collective_stats(ctx.jaxpr).items()
            if k in self.names
        }
        total = sum(v["count"] for v in stats.values())
        out = []
        if total > self.max_count:
            detail = ", ".join(
                "{} x{}".format(k, v["count"]) for k, v in sorted(stats.items())
            )
            out.append(
                f"{total} collective eqns ({detail}) > budget "
                f"{self.max_count} — an unaccounted collective channel "
                "beside the §4 wire"
            )
        if self.max_bytes is not None:
            nbytes = sum(v["bytes"] for v in stats.values())
            if nbytes > self.max_bytes:
                out.append(
                    f"collective payload {nbytes} B > budgeted "
                    f"{self.max_bytes} B (Theorem-1 ledger cross-check)"
                )
        return out


def find_sharding_leaks(tree, *, max_devices=1, allow=None) -> list:
    """Array leaves committed to more devices than allowed.

    The PR-8 bug class: a ``shard_map`` output with ``out_specs=P()`` comes
    back COMMITTED to a replicated ``NamedSharding`` over the whole mesh, and
    that sharding is sticky — every downstream jit consuming the leaf
    compiles as m-way SPMD with per-dispatch device sync.  A fit-time program
    must not let such arrays escape into a serving artifact.

    ``allow``: optional predicate over the leaf's ``/``-joined key path
    string (e.g. ``lambda p: p.startswith("factors")``) for leaves that are
    SUPPOSED to be sharded (mesh artifacts shard factors along the machine
    axis by design).  Returns ``[(path, n_devices), ...]``."""
    leaks = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if not isinstance(leaf, jax.Array):
            continue
        sharding = getattr(leaf, "sharding", None)
        if sharding is None:
            continue
        ndev = len(sharding.device_set)
        if ndev <= max_devices:
            continue
        pstr = _path_str(path)
        if allow is not None and allow(pstr):
            continue
        leaks.append((pstr, ndev))
    return leaks


def _path_str(path) -> str:
    """A pytree key path as a stable ``a/b/c`` string (GetAttrKey names,
    DictKey keys, and sequence indices, uniformly)."""
    parts = []
    for k in path:
        for attr in ("name", "key", "idx"):
            if hasattr(k, attr):
                parts.append(str(getattr(k, attr)))
                break
        else:
            parts.append(str(k))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class NoShardingLeak:
    """No artifact leaf may stay committed to a multi-device sharding unless
    the contract names it as deliberately sharded (``allow_prefixes``)."""

    max_devices: int = 1
    allow_prefixes: tuple = ()
    name: str = "no-sharding-leak"

    def check(self, ctx) -> list:
        if ctx.tree is None:
            return []
        allow = None
        if self.allow_prefixes:
            prefixes = self.allow_prefixes

            def allow(pstr):
                return any(p in pstr for p in prefixes)

        leaks = find_sharding_leaks(
            ctx.tree, max_devices=self.max_devices, allow=allow
        )
        return [
            f"leaf {path!r} is committed to {ndev} devices (> "
            f"{self.max_devices}) — a mesh sharding leaked out of the "
            "fit-time program (every downstream jit goes m-way SPMD)"
            for path, ndev in leaks
        ]


@dataclasses.dataclass(frozen=True)
class LedgerAccounting:
    """The three §4 ledgers must stay mutually consistent with
    :mod:`repro.comm.accounting` — Theorem 1 is an accounting identity, so a
    protocol whose measured payload undercuts its information ledger (or
    whose CRC ledger is not whole frames) has an unaccounted channel."""

    name: str = "ledger-accounting"

    def check(self, ctx) -> list:
        art = ctx.artifact
        if art is None or getattr(art, "stream", None) is None:
            return []
        from ..comm.accounting import CRC_BITS

        wire = int(art.wire_bits)
        payload = int(art.payload_bits)
        integrity = int(art.integrity_bits)
        out = []
        if payload < wire:
            out.append(
                f"payload_bits ({payload}) < wire_bits ({wire}): the wire "
                "physically moved fewer bits than the Theorem-1 ledger "
                "charges — an unaccounted side channel"
            )
        if integrity % CRC_BITS:
            out.append(
                f"integrity_bits ({integrity}) is not a whole number of "
                f"{CRC_BITS}-bit CRC frames"
            )
        if min(wire, payload, integrity) < 0:
            out.append(
                f"negative ledger (wire={wire}, payload={payload}, "
                f"crc={integrity})"
            )
        return out


# --------------------------------------------------------------------------
# contracts: named rule bundles, declared next to each protocol
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Contract:
    """A named bundle of rules enforced together over one program/artifact."""

    name: str
    rules: tuple

    def check(self, ctx) -> list:
        findings = []
        for rule in self.rules:
            findings.extend(
                Finding(self.name, rule.name, detail)
                for detail in rule.check(ctx)
            )
        return findings


@dataclasses.dataclass
class _CheckContext:
    """What one enforcement pass inspects: the program jaxpr (None for
    artifact-only phases), the artifact, and the pytree whose shardings the
    leak scan walks."""

    jaxpr: object = None
    artifact: object = None
    tree: object = None


# (protocol, impl, phase) -> Contract; impl "*" matches any.  Protocol
# modules register at import top level (repro.analysis.lint enforces that).
_CONTRACTS: dict = {}


def register_contract(protocol: str, phase: str, contract: Contract,
                      impl: str = "*") -> Contract:
    """Declare the contract for one (protocol, phase) — called at module top
    level next to the protocol's ``register_protocol``.  ``impl`` narrows to
    one execution substrate (``"mesh"``); ``"*"`` covers the rest."""
    key = (protocol, impl, phase)
    if key in _CONTRACTS:
        raise ValueError(f"contract already registered for {key}")
    _CONTRACTS[key] = contract
    return contract


def contract_for(protocol: str, impl: str, phase: str) -> Contract:
    """Most-specific registered contract for (protocol, impl, phase)."""
    for key in ((protocol, impl, phase), (protocol, "*", phase)):
        if key in _CONTRACTS:
            return _CONTRACTS[key]
    known = sorted({f"{p}/{i}/{ph}" for p, i, ph in _CONTRACTS})
    raise KeyError(
        f"no contract registered for {protocol}/{impl}/{phase} "
        f"(known: {', '.join(known)})"
    )


# --------------------------------------------------------------------------
# trace-neutral program building + the check_contracts entry point
# --------------------------------------------------------------------------


@contextlib.contextmanager
def _trace_neutral():
    """Snapshot/restore the serve/update trace counters around an abstract
    trace, so building a program to INSPECT it never shows up in a retrace
    budget (the old ``predict_op_counts`` traced the predict body and bumped
    the counter, forcing callers into a fragile snapshot-before ordering)."""
    from ..core.protocols import base

    saved_serve = dict(base._SERVE_TRACES)
    saved_update = dict(base._UPDATE_TRACES)
    try:
        yield
    finally:
        base._SERVE_TRACES.clear()
        base._SERVE_TRACES.update(saved_serve)
        base._UPDATE_TRACES.clear()
        base._UPDATE_TRACES.update(saved_update)


def predict_jaxpr(art, X_star):
    """The artifact's ACTUAL serve program as a closed jaxpr (the shard_map
    mesh program for mesh broadcast/PoE artifacts), built trace-neutrally.

    Trace-neutral means the counters are unchanged by this call.  Because
    ``make_jaxpr`` shares the pjit trace cache with ``jax.jit``, the abstract
    trace also WARMS the serve cache: a subsequent ``predict`` at the same
    shapes reuses it and performs no additional trace — so the counters stay
    an accurate record of tracing work actually performed, in either call
    order (the property ``launch/serve_gp.py`` used to guarantee by hand with
    a snapshot-before-check ordering)."""
    from ..core.protocols import base

    if base._uses_mesh_predict(art):
        from ..core.protocols import mesh

        fn = mesh._predict_mesh_impl
    else:
        fn = base._predict_impl
    with _trace_neutral():
        return jax.make_jaxpr(fn)(
            art, jnp.asarray(X_star, jnp.float32), base._availability(art, None)
        )


def check_contracts(art, X_star=None, phase: str = "predict", *,
                    raise_on_violation: bool = True) -> ContractReport:
    """Enforce the registered (protocol, impl, phase) contract on a fitted
    artifact.

    Builds the artifact's real serve program (trace-neutrally — calling this
    never perturbs ``serve_trace_count``/``update_trace_count``), runs every
    rule of the registered contract over the program jaxpr, the artifact's
    committed shardings, and its §4 ledgers, and raises
    :class:`ContractViolation` listing every finding (or returns the clean
    :class:`ContractReport` with the measured counts).  ``X_star``: query
    batch the program is traced at (a (8, d) probe is synthesized from the
    artifact when omitted)."""
    contract = contract_for(art.protocol, art.impl, phase)
    jaxpr = None
    if phase == "predict":
        if X_star is None:
            d = _query_dim(art)
            X_star = np.zeros((8, d), np.float32)
        jaxpr = predict_jaxpr(art, X_star)
    ctx = _CheckContext(jaxpr=jaxpr, artifact=art, tree=art)
    findings = contract.check(ctx)
    report = ContractReport(
        contract=contract.name,
        protocol=art.protocol,
        impl=art.impl,
        phase=phase,
        op_counts=dict(
            primitive_counts(jaxpr, names=FACTORIZATION_PRIMITIVES)
        ) if jaxpr is not None else {},
        collectives=collective_stats(jaxpr) if jaxpr is not None else {},
        leaks=tuple(find_sharding_leaks(art)),
        findings=tuple(findings),
    )
    if findings and raise_on_violation:
        raise ContractViolation(findings)
    return report


def _query_dim(art) -> int:
    """Feature dimension of the artifact's query space."""
    for key in ("Xc", "X_recon", "Xs"):
        if key in art.data:
            return int(art.data[key].shape[-1])
    raise ValueError("cannot infer query dimension; pass X_star explicitly")


@contextlib.contextmanager
def retrace_budget(protocol: str, *, serve: int = 0, update: int | None = None):
    """The retrace contract as a context manager: the wrapped block may
    (re)trace the protocol's serve program at most ``serve`` times (and, when
    given, its update program at most ``update`` times) — a warm serve loop
    budgets 0.  Raises :class:`ContractViolation` on exit otherwise.  Pair
    with :func:`check_contracts`, which is trace-neutral by construction, so
    ordering between structural checks and budget windows no longer
    matters."""
    from ..core.protocols import base

    s0 = base._SERVE_TRACES[protocol]
    u0 = base._UPDATE_TRACES[protocol]
    yield
    findings = []
    ds = base._SERVE_TRACES[protocol] - s0
    if ds > serve:
        findings.append(Finding(
            f"{protocol}-retrace-budget", "serve-retraces",
            f"{ds} serve (re)traces > budget {serve}",
        ))
    if update is not None:
        du = base._UPDATE_TRACES[protocol] - u0
        if du > update:
            findings.append(Finding(
                f"{protocol}-retrace-budget", "update-retraces",
                f"{du} update (re)traces > budget {update}",
            ))
    if findings:
        raise ContractViolation(findings)
