"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

Why: ``compiled.cost_analysis()`` visits every instruction ONCE — an 88-layer
``lax.scan`` or a gradient-accumulation loop contributes a single body's
FLOPs, under-counting by the trip count (verified on this jax/XLA build).
XLA annotates each ``while`` with ``backend_config={"known_trip_count"...}``,
so we walk the computation graph ourselves:

  cost(computation) = sum over instructions:
      dot          -> 2 * prod(result_shape) * contraction_size   [flops]
      fusion/call  -> flops of called computation + fusion-level bytes
      while        -> trip_count * (cost(body) + cost(cond))
      collective   -> result bytes, by type                       [wire bytes]
      any          -> result + operand bytes                      [HBM traffic]

Operand shapes are resolved through a per-computation symbol table (this HLO
dump style does not print operand shapes inline).  Bytes are counted at
top-level instruction granularity (fusion internals excluded) — a
no-cache-reuse HBM-traffic proxy, the right flavor for a bandwidth roofline.
All shapes in the optimized module are per-device (SPMD-partitioned).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?)(.*?)\s+([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _tuple_or_shape_bytes(type_str: str) -> int:
    return sum(
        _DTYPE_BYTES.get(dt, 4) * _elems(dims) for dt, dims in _SHAPE_RE.findall(type_str)
    )


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=lambda: {c: 0.0 for c in COLLECTIVES})

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.collective_bytes += o.collective_bytes
        for k in self.collectives:
            self.collectives[k] += o.collectives[k]
        return self

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k, self.bytes * k, self.collective_bytes * k,
            {c: v * k for c, v in self.collectives.items()},
        )

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": dict(self.collectives),
        }


class _Comp:
    def __init__(self):
        self.lines = []
        self.defs = {}  # instr name -> result type string


def _split_computations(hlo: str):
    comps = {}
    cur = None
    entry_name = None
    for line in hlo.splitlines():
        m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$", line)
        if m and not line.startswith(" "):
            cur = m.group(2)
            comps[cur] = _Comp()
            if m.group(1):
                entry_name = cur
            continue
        stripped = line.strip()
        if stripped == "}":
            cur = None
            continue
        if cur is None or not stripped or stripped.startswith("//"):
            continue
        comps[cur].lines.append(stripped)
        d = _DEF_RE.match(stripped)
        if d:
            name, is_tuple, type_str = d.group(1), d.group(2), d.group(3)
            comps[cur].defs[name] = (is_tuple + type_str) if is_tuple else type_str
    return comps, entry_name


def _operand_bytes(argstr: str, comp: _Comp) -> int:
    total = 0
    for name in _OPERAND_RE.findall(argstr):
        t = comp.defs.get(name)
        if t:
            total += _tuple_or_shape_bytes(t)
    return total


def _dot_flops(line: str, result_type: str, argstr: str, comp: _Comp) -> float:
    result_elems = sum(_elems(dims) for _, dims in _SHAPE_RE.findall(result_type))
    ops = _OPERAND_RE.findall(argstr)
    if not ops:
        return 0.0
    lhs_type = comp.defs.get(ops[0], "")
    lhs_shapes = _SHAPE_RE.findall(lhs_type)
    if not lhs_shapes:
        return 0.0
    lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
    m = _CONTRACT_RE.search(line)
    contraction = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contraction *= lhs_dims[i]
    return 2.0 * result_elems * contraction


_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "fusion", "call", "custom-call",
}


def _instruction_cost(line: str, comps, comp: _Comp, memo) -> HloCost:
    c = HloCost()
    d = _DEF_RE.match(line)
    if not d:
        return c
    result_type = d.group(2) + d.group(3) if d.group(2) else d.group(3)
    opcode = d.group(4)
    argstr = line[line.index(opcode + "(") + len(opcode) + 1 :]

    if opcode == "while":
        body = _CALL_RE.search(line)
        cond = _COND_RE.search(line)
        trip_m = _TRIP_RE.search(line)
        trips = int(trip_m.group(1)) if trip_m else 1
        inner = HloCost()
        if body:
            inner += _computation_cost(body.group(1), comps, memo)
        if cond:
            inner += _computation_cost(cond.group(1), comps, memo)
        return inner.scaled(trips)

    if opcode in ("fusion", "call", "custom-call"):
        m = _CALL_RE.search(line)
        if m:
            inner = _computation_cost(m.group(1), comps, memo)
            c.flops += inner.flops
            c.collective_bytes += inner.collective_bytes
            for k in c.collectives:
                c.collectives[k] += inner.collectives[k]
        c.bytes += _tuple_or_shape_bytes(result_type) + _operand_bytes(argstr, comp)
        return c

    if opcode == "conditional":
        for m in re.finditer(r"(?:true_computation|false_computation)=%?([\w.\-]+)", line):
            c += _computation_cost(m.group(1), comps, memo)
        return c

    for coll in COLLECTIVES:
        if opcode == coll or opcode.startswith(coll + "-"):
            b = _tuple_or_shape_bytes(result_type)
            c.collective_bytes += b
            c.collectives[coll] += b
            c.bytes += b
            return c

    if opcode in ("dot", "dot-general"):
        c.flops += _dot_flops(line, result_type, argstr, comp)

    if opcode in _SKIP_BYTES:
        return c
    c.bytes += _tuple_or_shape_bytes(result_type) + _operand_bytes(argstr, comp)
    return c


def _computation_cost(name: str, comps, memo) -> HloCost:
    if name in memo:
        return memo[name]
    memo[name] = HloCost()  # cycle guard
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    total = HloCost()
    for line in comp.lines:
        total += _instruction_cost(line, comps, comp, memo)
    memo[name] = total
    return total


def analyze_hlo(hlo_text: str) -> HloCost:
    """Per-device flops / HBM-traffic bytes / collective wire bytes with while
    trip-count multiplication."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        entry = list(comps)[-1]
    return _computation_cost(entry, comps, {})
