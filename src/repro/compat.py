"""Version guards for the jax API surface this repo targets.

The codebase is written against the current jax API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``); older releases (<= 0.4.x) spell these
``jax.experimental.shard_map.shard_map(check_rep=...)``, plain ``make_mesh``,
``with mesh:`` and the thread-resources physical mesh.  Everything that needs
one of these goes through this module so the rest of the tree stays written
against the new spelling.
"""
from __future__ import annotations

import contextlib
from functools import partial

import jax

__all__ = [
    "shard_map",
    "make_mesh",
    "set_mesh",
    "get_abstract_mesh",
    "cost_analysis_dict",
    "host_device_count_flags",
    "force_host_device_count",
]


def host_device_count_flags(n: int, existing: str = "") -> str:
    """An XLA_FLAGS string forcing ``n`` host platform devices, with any
    inherited ``--xla_force_host_platform_device_count`` stripped first
    (repeated XLA flags are last-wins, so a stale one would defeat ours) and
    every other inherited flag preserved."""
    import re

    stripped = re.sub(
        r"--xla_force_host_platform_device_count=\d+\s*", "", existing or ""
    )
    return (f"--xla_force_host_platform_device_count={n} " + stripped).strip()


def force_host_device_count(n: int) -> None:
    """Set XLA_FLAGS in os.environ to force ``n`` host devices — must run
    before the jax backend initializes (first device query; importing jax is
    fine).  Shared by launch/dryrun (512 placeholder devices), serve_gp
    --mesh (one device per machine), and the mesh benchmark subprocess."""
    import os

    os.environ["XLA_FLAGS"] = host_device_count_flags(
        n, os.environ.get("XLA_FLAGS", "")
    )


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _old_shard_map

    def shard_map(f=None, /, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        """New-style ``jax.shard_map``: keyword mesh/specs, ``check_vma``
        (mapped to the old ``check_rep``)."""
        if f is None:
            return partial(
                shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check_vma, **kw,
            )
        return _old_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma, **kw,
        )


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
    """``jax.make_mesh`` with ``axis_types`` dropped when unsupported.

    ``axis_types`` may be ``"auto"``/``"explicit"`` strings or actual
    ``jax.sharding.AxisType`` members; on jax without AxisType every mesh is
    implicitly Auto, which is what this repo uses everywhere.
    """
    AxisType = getattr(jax.sharding, "AxisType", None)
    if AxisType is None:
        return jax.make_mesh(axis_shapes, axis_names, **kw)
    if axis_types is None:
        axis_types = (AxisType.Auto,) * len(axis_names)
    axis_types = tuple(
        getattr(AxisType, t.capitalize()) if isinstance(t, str) else t
        for t in axis_types
    )
    return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types, **kw)


def set_mesh(mesh):
    """``jax.set_mesh`` context manager; on old jax, entering the Mesh sets the
    thread-resources env, which is what ``get_abstract_mesh`` falls back to."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # Mesh is itself a context manager on 0.4.x


def get_abstract_mesh():
    """Current mesh (abstract on new jax, physical thread-resources mesh on
    old jax — both expose ``.shape``, ``.axis_names`` and work as the ``mesh=``
    argument of :func:`shard_map`)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict.

    Old jax returns a one-element list of per-device dicts; new jax returns the
    dict directly; either may be None on some backends.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})
