"""Deterministic synthetic data generators.

* ``lm_batch_stream`` — token batches for the transformer drivers (Zipf-ish
  marginal + Markov bigram structure so the loss has signal).
* ``regression_dataset`` — GP-regression datasets statistically matched to the
  paper's benchmarks (same n/d/noise regime); real files are used instead when
  present (benchmarks pass --data-dir).
* ``mnist_like_two_digits`` — two-cluster high-dim image-like data for the
  Fig. 3c/d PCA comparison (28x28, digit-dependent covariance).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

DATASET_SPECS = {
    # name: (n_train, n_test, d) as in the paper §6
    "sarcos": (1000, 4449, 21),
    "kin40k": (1000, 30000, 8),
    "abalone": (1000, 1044, 8),
}


def lm_batch_stream(vocab_size: int, batch: int, seq: int, seed: int = 0):
    """Infinite deterministic stream of (tokens, labels) int32 batches."""
    rng = np.random.default_rng(seed)
    # fixed random bigram preference: tok -> preferred successor
    succ = rng.integers(0, vocab_size, size=vocab_size)
    step = 0
    while True:
        r = np.random.default_rng((seed, step))
        toks = np.empty((batch, seq + 1), dtype=np.int64)
        toks[:, 0] = r.zipf(1.3, size=batch) % vocab_size
        noise = r.random((batch, seq))
        rand_next = r.integers(0, vocab_size, size=(batch, seq))
        for t in range(seq):
            follow = succ[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.65, follow, rand_next[:, t])
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        step += 1


def regression_dataset(name: str, seed: int = 0, data_dir: str | None = None):
    """(X_train, y_train, X_test, y_test) float32, normalized like the paper:
    inputs zero-mean unit-variance, targets centered."""
    if data_dir is not None:
        loaded = _try_load_real(name, data_dir)
        if loaded is not None:
            return loaded
    n_train, n_test, d = DATASET_SPECS[name]
    rng = np.random.default_rng((hash(name) & 0xFFFF, seed))
    # anisotropic inputs (random covariance); target roughness matched to the
    # real dataset's character (KIN40K is famously high-frequency/nonlinear,
    # SARCOS moderately smooth, ABALONE nearly monotone)
    freq, feats = {"kin40k": (4.0, 64), "sarcos": (2.0, 16), "abalone": (1.0, 8)}[name]
    A = rng.normal(size=(d, d)) / np.sqrt(d)
    Xall = rng.normal(size=(n_train + n_test, d)) @ A.T
    W1 = rng.normal(size=(d, feats)) / np.sqrt(d)
    w2 = rng.normal(size=feats)
    f = np.tanh(Xall @ W1) @ w2 + 0.3 * np.sin(freq * Xall @ W1[:, 0])
    y = f + 0.05 * np.std(f) * rng.normal(size=f.shape[0])
    X_tr, X_te = Xall[:n_train], Xall[n_train:]
    y_tr, y_te = y[:n_train], y[n_train:]
    mu, sd = X_tr.mean(0), X_tr.std(0) + 1e-9
    X_tr = (X_tr - mu) / sd
    X_te = (X_te - mu) / sd
    ym = y_tr.mean()
    return (
        X_tr.astype(np.float32), (y_tr - ym).astype(np.float32),
        X_te.astype(np.float32), (y_te - ym).astype(np.float32),
    )


def _try_load_real(name: str, data_dir: str):
    import os

    path = os.path.join(data_dir, f"{name}.npz")
    if not os.path.exists(path):
        return None
    z = np.load(path)
    return (z["X_train"], z["y_train"], z["X_test"], z["y_test"])


def mnist_like_two_digits(n_per_digit: int = 1000, seed: int = 0):
    """Two 784-dim clusters with digit-specific low-rank covariance — the
    Fig. 3c/d setting (digit 6 on machine 1, digit 7 on machine 2)."""
    rng = np.random.default_rng(seed)
    d = 784

    def digit(k):
        basis = rng.normal(size=(d, 30)) / np.sqrt(d)
        scales = np.geomspace(5.0, 0.1, 30)
        z = rng.normal(size=(n_per_digit, 30)) * scales
        return (z @ basis.T + 0.05 * rng.normal(size=(n_per_digit, d))).astype(np.float32)

    return digit(6), digit(7)
