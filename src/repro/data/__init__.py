from .synthetic import (
    lm_batch_stream,
    regression_dataset,
    DATASET_SPECS,
    mnist_like_two_digits,
)
from .pipeline import ShardedBatcher
