"""Sharded batch delivery: host batches -> global jax.Arrays laid out for the
mesh (batch over the data/pod axes), via make_array_from_callback so each host
only materializes its addressable shards.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P


class ShardedBatcher:
    def __init__(self, mesh, batch_axes=("data",)):
        self.mesh = mesh
        self.batch_axes = batch_axes

    def sharding_for(self, arr):
        spec = P(self.batch_axes) if arr.ndim >= 1 else P()
        return NamedSharding(self.mesh, spec)

    def __call__(self, host_batch: dict):
        out = {}
        for k, v in host_batch.items():
            v = np.asarray(v)
            sh = self.sharding_for(v)
            out[k] = jax.make_array_from_callback(v.shape, sh, lambda idx, vv=v: vv[idx])
        return out
