"""Seedable, jit-compatible fault plans for the distributed-GP stack.

A :class:`FaultPlan` is a frozen, hashable description of what goes wrong —
which machines drop out, which shards are NaN-poisoned, the bit-flip rate on
the packed uint32 wire plane, and which machines straggle.  It rides on
:class:`~repro.core.config.DGPConfig` (static treedef metadata, hence the
all-tuple fields) and is consumed at three layers:

* **dataset faults** (:func:`apply_to_parts`) — drop/NaN whole shards before
  the protocol ever sees them; non-finite rows are filtered (and counted)
  rather than propagated, which is the generic hostile-input tripwire.
* **wire faults** (:func:`flip_words` + the CRC demotion path in
  ``protocols/wire.py`` and ``comm.q_all_gather(faults=...)``) — XOR random
  bit masks into the packed code words, exactly as a noisy channel would.
* **serve faults** (``launch/serve_gp.py --chaos``) — stragglers sleep
  host-side; drops become predict-time availability masks.

Constructors compose with ``|``::

    plan = drop_machine(1) | corrupt_words(0.01, seed=7)
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "FaultPlan",
    "drop_machine",
    "corrupt_words",
    "nan_shard",
    "straggler",
    "flip_words",
    "apply_to_parts",
]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """What goes wrong, declaratively.  All fields are tuples/scalars so the
    plan is hashable (it becomes static jit metadata via DGPConfig)."""

    drop: tuple = ()          # machine indices that send nothing
    nan: tuple = ()           # machine indices whose shards are NaN-poisoned
    nan_frac: float = 0.5     # fraction of rows poisoned in a nan shard
    flip_rate: float = 0.0    # per-bit flip probability on packed words
    straggle: tuple = ()      # ((machine, delay_seconds), ...)
    seed: int = 0             # PRNG seed for the bit-flip channel

    def __or__(self, other: "FaultPlan") -> "FaultPlan":
        if not isinstance(other, FaultPlan):
            return NotImplemented
        return FaultPlan(
            drop=tuple(sorted(set(self.drop) | set(other.drop))),
            nan=tuple(sorted(set(self.nan) | set(other.nan))),
            nan_frac=max(self.nan_frac, other.nan_frac),
            flip_rate=max(self.flip_rate, other.flip_rate),
            straggle=tuple(sorted(set(self.straggle) | set(other.straggle))),
            seed=self.seed if self.flip_rate >= other.flip_rate else other.seed,
        )

    @property
    def active(self) -> bool:
        return bool(self.drop or self.nan or self.flip_rate or self.straggle)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls(
            drop=tuple(d.get("drop", ())),
            nan=tuple(d.get("nan", ())),
            nan_frac=float(d.get("nan_frac", 0.5)),
            flip_rate=float(d.get("flip_rate", 0.0)),
            straggle=tuple(tuple(s) for s in d.get("straggle", ())),
            seed=int(d.get("seed", 0)),
        )


def drop_machine(*js: int) -> FaultPlan:
    """Machines ``js`` send nothing (empty shards / zeroed masks)."""
    return FaultPlan(drop=tuple(sorted(int(j) for j in js)))


def corrupt_words(rate: float, seed: int = 0) -> FaultPlan:
    """Flip each bit of every transmitted packed word with prob ``rate``."""
    return FaultPlan(flip_rate=float(rate), seed=int(seed))


def nan_shard(*js: int) -> FaultPlan:
    """NaN-poison (half of) the rows of machines ``js``."""
    return FaultPlan(nan=tuple(sorted(int(j) for j in js)))


def straggler(j: int, delay: float) -> FaultPlan:
    """Machine ``j`` answers ``delay`` seconds late (serve-loop only)."""
    return FaultPlan(straggle=((int(j), float(delay)),))


def flip_words(words, rate: float, key):
    """XOR a Bernoulli(rate) bit mask into uint32 ``words`` — jit-compatible.

    Each of the 32 bits of each word flips independently with probability
    ``rate``.  Returns the corrupted words (same shape/dtype)."""
    import jax
    import jax.numpy as jnp

    words = jnp.asarray(words, jnp.uint32)
    if rate <= 0.0:
        return words
    u = jax.random.uniform(key, words.shape + (32,))
    bits = (u < rate).astype(jnp.uint32)
    mask = (bits << jnp.arange(32, dtype=jnp.uint32)).sum(
        axis=-1, dtype=jnp.uint32
    )
    return words ^ mask


def apply_to_parts(parts, plan: "FaultPlan | None"):
    """Apply dataset-level faults to per-machine ``(X_j, y_j)`` shards.

    * dropped machines become empty shards (0 rows, d preserved);
    * NaN shards have ``nan_frac`` of their rows poisoned — then the generic
      finite-row filter removes every non-finite row and counts it.

    Returns ``(new_parts, rows_removed)``.  Host-side (numpy): this runs once
    at fit() entry, before any tracing."""
    if plan is None or not (plan.drop or plan.nan):
        return parts, 0
    drop, nan = set(plan.drop), set(plan.nan)
    rng = np.random.default_rng(plan.seed)
    out, removed = [], 0
    for j, (Xj, yj) in enumerate(parts):
        Xj = np.asarray(Xj)
        yj = np.asarray(yj)
        if j in drop:
            removed += Xj.shape[0]
            out.append((Xj[:0], yj[:0]))
            continue
        if j in nan and Xj.shape[0]:
            Xj, yj = Xj.copy(), yj.copy()
            k = max(1, int(round(plan.nan_frac * Xj.shape[0])))
            idx = rng.choice(Xj.shape[0], size=k, replace=False)
            Xj[idx] = np.nan
        finite = np.isfinite(Xj).all(axis=1) & np.isfinite(yj)
        if not finite.all():
            removed += int((~finite).sum())
            Xj, yj = Xj[finite], yj[finite]
        out.append((Xj, yj))
    return out, removed
