"""Model configuration for the architecture zoo.

One dataclass covers the six assigned families (dense / moe / ssm / hybrid /
audio enc-dec / vlm); family-specific fields are ignored elsewhere.  Configs
are plain frozen dataclasses so they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention features
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    local_global_alternating: bool = False  # gemma2: even layers local window
    attn_logit_softcap: Optional[float] = None  # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0

    # mlp
    activation: str = "swiglu"  # swiglu | geglu | gelu

    # moe
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # ssm / hybrid
    ssm_state: int = 0  # mamba2 d_state
    ssm_expand: int = 2
    ssm_conv: int = 4
    xlstm_slstm_every: int = 2  # xlstm: every k-th block is sLSTM
    hybrid_attn_every: int = 0  # zamba2: shared attention every k mamba layers

    # encdec (whisper): encoder config; frontend is stubbed (frame embeddings in)
    enc_layers: int = 0
    enc_seq: int = 0

    # vlm: number of stub patch embeddings prepended to the token stream
    num_patches: int = 0

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma family scales embeddings by sqrt(d)

    # training
    remat: bool = True
    # two-level layer-scan remat: outer group count (None = flat scan).
    # NOTE: measured WORSE than flat scan + smaller microbatch on this XLA
    # (EXPERIMENTS.md §Perf B1-refuted) — kept as an option, off by default.
    remat_blocks: Optional[int] = None
    # gradient-accumulation microbatch size in global tokens (§Perf A4/B2):
    # fewer tokens/microbatch -> less live activation memory, more per-step
    # FSDP gather + grad-sync rounds.  Tuned per arch in configs/.
    train_mb_tokens: int = 131072

    # citation for the config values (paper / model card)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.hd

    def reduced(self) -> "ModelConfig":
        """CPU-smoke-test variant: <=2 layers (pattern-preserving), d_model<=256,
        <=4 experts, tiny vocab."""
        layer_quantum = {
            "hybrid": max(self.hybrid_attn_every, 1),
            "ssm": max(self.xlstm_slstm_every, 1),
            "dense": 2 if self.local_global_alternating else 1,
        }.get(self.family, 1)
        L = max(layer_quantum, min(2, self.num_layers)) if layer_quantum <= 2 else layer_quantum
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, max(1, heads // 2))
        hd = d // heads
        return dataclasses.replace(
            self,
            num_layers=L,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 4 * d) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 2 * d) if self.moe_d_ff else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            shared_d_ff=min(self.shared_d_ff, 2 * d) if self.shared_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            enc_seq=min(self.enc_seq, 64) if self.enc_seq else 0,
            num_patches=min(self.num_patches, 16) if self.num_patches else 0,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else None,
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
