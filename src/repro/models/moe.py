"""Mixture-of-Experts FFN (arctic-480b, qwen2-moe).

Expert-parallel implementation:
  * router softmax -> top-k experts per token + gates (plain jit math),
  * capacity C per expert with GShard-style dropping,
  * dispatch/expert/combine under an explicit ``jax.shard_map`` when a mesh is
    active (§Perf A2): every (data, model) device scatters ITS batch-local
    tokens into a dense buffer for ITS model-local experts, runs the expert
    matmuls, gathers back, and the ONLY cross-device collective is a psum of
    the combined (T_local, D) output over the model axis.  Leaving the
    scatter/gather to the SPMD partitioner instead makes it replicate the full
    token tensor and all-reduce dense buffers (measured 23 TB/device/step on
    arctic-480b train_4k vs ~0.3 TB with this path — EXPERIMENTS.md §Perf).
  * experts that don't divide the model axis (qwen2's 60) are zero-padded to
    the next multiple; the router never selects the dead experts.
  * smoke tests / single-device runs use the same math without shard_map.

Aux losses: load-balance (Switch) + router z-loss, returned for logging.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import _init, mlp_apply, init_mlp
from .sharding import constrain, current_rules, _mesh_sizes
from ..compat import shard_map, get_abstract_mesh


def init_moe(key, cfg):
    ks = jax.random.split(key, 5)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    width = 2 * F if cfg.activation in ("swiglu", "geglu") else F
    p = {
        "router": _init(ks[0], (D, E), scale=0.02),
        "w_in_e": _init(ks[1], (E, D, width)),
        "w_out_e": _init(ks[2], (E, F, D)),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[3], D, cfg.shared_d_ff, cfg.activation)
    if cfg.moe_dense_residual:
        p["dense_res"] = init_mlp(ks[4], D, cfg.d_ff, cfg.activation)
    return p


def _routed_local(xt, expert_idx, gate_vals, w_in, w_out, cfg, e_offset, e_total):
    """Single-device dispatch/expert/combine over a LOCAL expert slab.

    xt: (T, D); expert_idx/gate_vals: (T, K) GLOBAL expert ids; w_in/w_out:
    (E_loc, ...) local expert weights; e_offset: first global id of the slab.
    Tokens routed to other slabs contribute zero (psum over the model axis
    restores the full combine).  Returns (combined (T, D), keep (T, K))."""
    T, D = xt.shape
    E_loc = w_in.shape[0]
    K = expert_idx.shape[1]
    # capacity budget per expert uses the GLOBAL expert count: this shard's
    # tokens spread over all e_total experts, of which E_loc live here
    capacity = int(max(1, round(T * K * cfg.capacity_factor / max(e_total, 1))))
    capacity = min(-(-capacity // 8) * 8, max(T, 8))

    flat_e = expert_idx.reshape(-1)  # (T*K,) global ids
    local_e = flat_e - e_offset
    mine = (local_e >= 0) & (local_e < E_loc)
    safe_e = jnp.where(mine, local_e, 0)
    # position within the LOCAL expert buffer (cumsum over this shard's tokens)
    onehot = jax.nn.one_hot(safe_e, E_loc, dtype=jnp.int32) * mine[:, None].astype(jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1, safe_e[:, None], 1)[:, 0]
    keep = mine & (pos < capacity)
    safe_pos = jnp.where(keep, pos, capacity - 1)

    tok_of_choice = jnp.repeat(jnp.arange(T), K)
    contrib = jnp.where(keep[:, None], xt[tok_of_choice], 0.0)
    buf = jnp.zeros((E_loc, capacity, D), xt.dtype).at[safe_e, safe_pos].add(contrib)

    width_gated = cfg.activation in ("swiglu", "geglu")
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    if width_gated:
        g, u = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g) * u if cfg.activation == "swiglu" else jax.nn.gelu(g) * u
    else:
        h = jax.nn.gelu(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_out)

    gathered = jnp.where(keep[:, None], out_buf[safe_e, safe_pos], 0.0)
    gates = gate_vals.reshape(-1)[:, None].astype(gathered.dtype)
    combined = (gathered * gates).reshape(T, K, D).sum(axis=1)
    return combined, keep.reshape(T, K)


def _pad_experts(w, n_pad):
    if n_pad == 0:
        return w
    return jnp.concatenate([w, jnp.zeros((n_pad,) + w.shape[1:], w.dtype)], axis=0)


def moe_apply(params, x, cfg):
    """x: (B, S, D) -> (out (B,S,D), aux dict)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt @ params["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    gate_vals = gate_vals.astype(xt.dtype)

    rules = current_rules()
    sizes = _mesh_sizes() or {}
    model_ax = rules.get("tensor")
    batch_ax = rules.get("batch")
    n_model = sizes.get(model_ax, 1) if isinstance(model_ax, str) else 1

    if rules and n_model > 1 and batch_ax is not None and T % _axes_size(batch_ax, sizes) == 0:
        # §Perf A2: explicit expert-parallel shard_map (see module docstring)
        n_pad = (-E) % n_model
        w_in = _pad_experts(params["w_in_e"], n_pad)
        w_out = _pad_experts(params["w_out_e"], n_pad)
        E_loc = (E + n_pad) // n_model
        mesh = get_abstract_mesh()

        def body(xt_l, ei_l, gv_l, w_in_l, w_out_l):
            off = jax.lax.axis_index(model_ax) * E_loc
            combined, keep = _routed_local(xt_l, ei_l, gv_l, w_in_l, w_out_l, cfg, off, E + n_pad)
            combined = jax.lax.psum(combined, model_ax)
            keep = jax.lax.psum(keep.astype(jnp.int32), model_ax)
            return combined, keep

        combined, keep_ct = shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P(batch_ax, None), P(batch_ax, None), P(batch_ax, None),
                P(model_ax, None, None), P(model_ax, None, None),
            ),
            out_specs=(P(batch_ax, None), P(batch_ax, None)),
            check_vma=False,
        )(xt, expert_idx, gate_vals, w_in, w_out)
        keep = keep_ct > 0
        flat_e = expert_idx.reshape(-1)
    else:
        combined, keep = _routed_local(
            xt, expert_idx, gate_vals, params["w_in_e"], params["w_out_e"], cfg, 0, E)
        flat_e = expert_idx.reshape(-1)

    if "shared" in params:
        combined = combined + mlp_apply(params["shared"], xt, cfg.activation)
    if "dense_res" in params:
        combined = combined + mlp_apply(params["dense_res"], xt, cfg.activation)

    # aux losses
    me = probs.mean(axis=0)  # mean router prob per expert
    ce = jnp.zeros((E,)).at[flat_e].add(
        keep.reshape(-1).astype(jnp.float32)) / jnp.maximum(keep.sum(), 1.0)
    aux = {
        "load_balance": E * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "drop_frac": 1.0 - keep.astype(jnp.float32).mean(),
    }
    return combined.reshape(B, S, D), aux


def _axes_size(ax, sizes):
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n
