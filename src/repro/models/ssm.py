"""Recurrent sequence-mixing blocks: chunked gated linear attention (the shared
engine), mLSTM + sLSTM (xlstm-125m, arXiv:2405.04517) and Mamba2/SSD
(zamba2-2.7b, arXiv:2411.15242).

The shared engine computes, exactly and in chunks of ``chunk`` steps,

    C_t = a_t C_{t-1} + w_t k_t v_t^T          (state  (dk, dv) per head)
    y_t = C_t^T q_t

with per-step per-head scalar decay a_t = exp(log_a_t), log_a_t <= 0 — the
common core of mLSTM matrix memory and the SSD recurrence.  Within a chunk the
contraction is a masked (q k^T)-style matmul (MXU-friendly); across chunks a
lax.scan carries the state.  All exponentials are of non-positive numbers, so
the computation is stable by construction.

Deviations from the papers (recorded in DESIGN.md): the mLSTM exponential
input gate is implemented as a sigmoid gate (drops the running-max stabilizer
in exchange for the provably stable chunked form); sLSTM keeps exponential
gating with the standard m_t stabilizer in a per-step scan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import _init

SSM_CHUNK = 128


def chunked_gla(q, k, v, log_a, w, state=None, chunk: int = SSM_CHUNK):
    """q,k: (B,S,H,dk); v: (B,S,H,dv); log_a,w: (B,S,H); state (B,H,dk,dv).

    Returns (y (B,S,H,dv), final_state)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    C = min(chunk, S)
    n = S // C
    assert S % C == 0, "sequence length must be a chunk multiple"
    if state is None:
        state = jnp.zeros((B, H, dk, dv), jnp.float32)

    qc = q.reshape(B, n, C, H, dk).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    kc = k.reshape(B, n, C, H, dk).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vc = v.reshape(B, n, C, H, dv).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    lac = log_a.reshape(B, n, C, H).transpose(1, 0, 3, 2).astype(jnp.float32)
    wc = w.reshape(B, n, C, H).transpose(1, 0, 3, 2).astype(jnp.float32)

    tri = jnp.tril(jnp.ones((C, C), bool))  # s <= t

    def body(st, inp):
        qq, kk, vv, la, ww = inp  # (B,H,C,dk) ... (B,H,C)
        L = jnp.cumsum(la, axis=-1)  # (B,H,C) inclusive
        # intra-chunk: y[t] += sum_{s<=t} exp(L_t - L_s) w_s (q_t . k_s) v_s
        scores = jnp.einsum("bhtd,bhsd->bhts", qq, kk)
        decay = jnp.exp(jnp.clip(L[..., :, None] - L[..., None, :], -60.0, 0.0))
        scores = scores * decay * ww[..., None, :]
        scores = jnp.where(tri[None, None], scores, 0.0)
        y = jnp.einsum("bhts,bhsv->bhtv", scores, vv)
        # cross-chunk: y[t] += exp(L_t) q_t^T state
        y = y + jnp.exp(L)[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qq, st)
        # state update: st' = exp(L_end) st + sum_s exp(L_end - L_s) w_s k_s v_s^T
        Lend = L[..., -1:]
        wdec = jnp.exp(jnp.clip(Lend - L, -60.0, 0.0)) * ww  # (B,H,C)
        st = jnp.exp(Lend)[..., None] * st + jnp.einsum(
            "bhs,bhsd,bhsv->bhdv", wdec, kk, vv
        )
        return st, y

    state, ys = jax.lax.scan(body, state, (qc, kc, vc, lac, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv)
    return y.astype(v.dtype), state


def gla_step(q, k, v, log_a, w, state):
    """Single decode step.  q,k: (B,H,dk); v: (B,H,dv); log_a,w: (B,H)."""
    a = jnp.exp(jnp.clip(log_a, -60.0, 0.0))[..., None, None]
    state = a * state + (w[..., None, None] * k[..., :, None] * v[..., None, :])
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return y.astype(v.dtype), state


# --- mLSTM (xLSTM matrix-memory block) ---------------------------------------

def init_mlstm(key, cfg):
    ks = jax.random.split(key, 6)
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    return {
        "wq": _init(ks[0], (D, H * hd)),
        "wk": _init(ks[1], (D, H * hd)),
        "wv": _init(ks[2], (D, H * hd)),
        "w_gates": _init(ks[3], (D, 2 * H), scale=0.02),  # input & forget pre-acts
        "w_og": _init(ks[4], (D, H * hd), scale=0.02),    # output gate
        "wo": _init(ks[5], (H * hd, D)),
    }


def _mlstm_qkv_gates(params, x, cfg):
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    q = (x @ params["wq"]).reshape(B, S, H, hd) / jnp.sqrt(hd).astype(x.dtype)
    k = (x @ params["wk"]).reshape(B, S, H, hd) / jnp.sqrt(hd).astype(x.dtype)
    v = (x @ params["wv"]).reshape(B, S, H, hd)
    gates = (x @ params["w_gates"]).reshape(B, S, 2, H).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gates[:, :, 0] + 3.0)  # forget-gate bias init ~ open
    w_i = jax.nn.sigmoid(gates[:, :, 1])
    og = jax.nn.sigmoid((x @ params["w_og"]).reshape(B, S, H, hd).astype(jnp.float32))
    return q, k, v, log_f, w_i, og


def mlstm_apply(params, x, cfg, state=None):
    q, k, v, log_f, w_i, og = _mlstm_qkv_gates(params, x, cfg)
    y, state = chunked_gla(q, k, v, log_f, w_i, state)
    y = (og * y.astype(jnp.float32)).astype(x.dtype)
    B, S = x.shape[:2]
    return (y.reshape(B, S, -1) @ params["wo"]), state


def mlstm_step(params, x, cfg, state):
    """x: (B, 1, D)."""
    q, k, v, log_f, w_i, og = _mlstm_qkv_gates(params, x, cfg)
    y, state = gla_step(q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], w_i[:, 0], state)
    y = (og[:, 0] * y.astype(jnp.float32)).astype(x.dtype)
    B = x.shape[0]
    return (y.reshape(B, 1, -1) @ params["wo"]), state


# --- sLSTM (scalar-memory, exponential gating + stabilizer) -------------------

def init_slstm(key, cfg):
    ks = jax.random.split(key, 3)
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    return {
        "wi": _init(ks[0], (D, 4 * H * hd)),  # z, i, f, o pre-activations
        "r_h": _init(ks[1], (H, hd, 4 * hd), scale=0.02),  # head-local recurrence
        "wo": _init(ks[2], (H * hd, D)),
    }


def _slstm_cell(pre, carry, H, hd):
    """pre: (B, 4, H, hd) pre-activations (input + recurrent)."""
    c, nrm, m, h = carry
    z = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1]
    f_t = pre[:, 2]
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(f_t + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c = f_p * c + i_p * z
    nrm = f_p * nrm + i_p
    h = o * c / jnp.maximum(nrm, 1.0)
    return (c, nrm, m_new, h)


def slstm_apply(params, x, cfg, state=None):
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    if state is None:
        z = jnp.zeros((B, H, hd), jnp.float32)
        state = (z, z, jnp.full((B, H, hd), -1e30), z)
    pre_x = (x @ params["wi"]).reshape(B, S, 4, H, hd).astype(jnp.float32)
    # recurrence: previous hidden (B, H*hd) -> 4 gate pre-activations
    rmat = params["r_h"]

    def step(carry, pre_t):
        h_prev = carry[3]  # (B, H, hd) fp32
        rec = jnp.einsum("bhd,hdk->bhk", h_prev.astype(x.dtype), rmat)  # (B,H,4*hd)
        rec = rec.reshape(B, H, 4, hd).transpose(0, 2, 1, 3)
        pre = pre_t + rec.astype(jnp.float32)
        carry = _slstm_cell(pre, carry, H, hd)
        return carry, carry[3]

    state, hs = jax.lax.scan(step, state, pre_x.transpose(1, 0, 2, 3, 4))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, H * hd).astype(x.dtype)
    return y @ params["wo"], state


def slstm_step(params, x, cfg, state):
    out, state = slstm_apply(params, x, cfg, state)
    return out, state


# --- Mamba2 / SSD -------------------------------------------------------------

def init_mamba2(key, cfg):
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    H = cfg.num_heads
    N = cfg.ssm_state
    # in_proj emits [gate z (d_inner), x (d_inner), B (N), C (N), dt (H)]
    return {
        "w_ssm_in": _init(ks[0], (D, 2 * d_inner + 2 * N + H)),
        "conv_w": _init(ks[1], (cfg.ssm_conv, d_inner + 2 * N), scale=0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "w_ssm_out": _init(ks[2], (d_inner, D)),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
    }


def _mamba_proj(params, x, cfg):
    B, S, D = x.shape
    d_inner = cfg.ssm_expand * D
    H, N = cfg.num_heads, cfg.ssm_state
    proj = x @ params["w_ssm_in"]
    z, xin, Bmat, Cmat, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return z, xin, Bmat, Cmat, dt, d_inner, H, N


def _causal_conv(seq, w, state=None):
    """Depthwise causal conv.  seq: (B,S,C); w: (K,C); state: (B,K-1,C)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((seq.shape[0], K - 1, seq.shape[2]), seq.dtype)
    else:
        pad = state.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(full[:, i : i + seq.shape[1]] * w[i] for i in range(K))
    new_state = full[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(out), new_state


def mamba2_apply(params, x, cfg, state=None, conv_state=None):
    B, S, D = x.shape
    z, xin, Bm, Cm, dt, d_inner, H, N = _mamba_proj(params, x, cfg)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"], conv_state)
    xin, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    hd = d_inner // H
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    log_a = -jnp.exp(params["a_log"])[None, None] * dt  # <= 0
    # SSD == GLA with q=C, k=B (shared across heads), v=x*dt
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))
    v = (xin.reshape(B, S, H, hd).astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    y, state = chunked_gla(q, k, v, log_a, jnp.ones_like(dt), state)
    y = y.reshape(B, S, d_inner)
    # gated RMS norm then out-projection
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    y = (yf * params["norm_scale"]).astype(x.dtype) * jax.nn.silu(z)
    return y @ params["w_ssm_out"], state, conv_state


def mamba2_step(params, x, cfg, state, conv_state):
    B = x.shape[0]
    z, xin, Bm, Cm, dt, d_inner, H, N = _mamba_proj(params, x, cfg)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"], conv_state)
    xin, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    hd = d_inner // H
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    log_a = -jnp.exp(params["a_log"])[None] * dt
    q = jnp.broadcast_to(Cm[:, 0, None, :], (B, H, N))
    k = jnp.broadcast_to(Bm[:, 0, None, :], (B, H, N))
    v = (xin[:, 0].reshape(B, H, hd).astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    y, state = gla_step(q, k, v, log_a, jnp.ones_like(dt), state)
    y = y.reshape(B, 1, d_inner)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    y = (yf * params["norm_scale"]).astype(x.dtype) * jax.nn.silu(z)
    return y @ params["w_ssm_out"], state, conv_state
