"""Single-token decode with caches for every family.

Cache design:
  * full KV cache  (B, S_max, KV, hd)  for global-attention layers,
  * ring KV cache  (B, window, KV, hd) + kpos (B, window) for sliding-window
    layers (gemma2 local layers stay O(window) even at 500k context),
  * mLSTM/SSD matrix state (B, H, dk, dv), sLSTM scalar carries, mamba conv
    state — O(1) in context length (why ssm/hybrid run long_500k),
  * whisper: decoder self caches + precomputed cross K/V from the encoder.

decode_step scans the stacked layer params together with the stacked caches,
carrying the hidden state; leaf names in the cache tree drive sharding
(see decode_state_specs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .backbone import COMPUTE_DTYPE, _stacked
from .layers import rmsnorm, rope, _group_q, _softcap, mlp_apply
from .moe import moe_apply
from . import ssm
from .sharding import current_rules, gather_layer_params


# --- cache construction -------------------------------------------------------

def _kv_cache(cfg, B, size):
    return {
        "k": jnp.zeros((B, size, cfg.num_kv_heads, cfg.hd), COMPUTE_DTYPE),
        "v": jnp.zeros((B, size, cfg.num_kv_heads, cfg.hd), COMPUTE_DTYPE),
        "kpos": jnp.full((B, size), -1, jnp.int32),
    }


def _stack0(n, tree):
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tree)


def init_decode_state(cfg: ModelConfig, B: int, max_len: int):
    fam = cfg.family
    win = cfg.sliding_window
    if fam in ("dense", "vlm", "moe"):
        if cfg.local_global_alternating:
            local_size = min(win or max_len, max_len)
            return {
                "pairs": _stack0(cfg.num_layers // 2, {
                    "local": _kv_cache(cfg, B, local_size),
                    "global": _kv_cache(cfg, B, max_len),
                })
            }
        size = min(win, max_len) if win else max_len
        return {"layers": _stack0(cfg.num_layers, _kv_cache(cfg, B, size))}
    if fam == "ssm":
        H, hd = cfg.num_heads, cfg.hd
        pair = {
            "mlstm_state": jnp.zeros((B, H, hd, hd), jnp.float32),
            "slstm_c": jnp.zeros((B, H, hd), jnp.float32),
            "slstm_n": jnp.zeros((B, H, hd), jnp.float32),
            "slstm_m": jnp.full((B, H, hd), -1e30, jnp.float32),
            "slstm_h": jnp.zeros((B, H, hd), jnp.float32),
        }
        return {"pairs": _stack0(cfg.num_layers // 2, pair)}
    if fam == "hybrid":
        H, N = cfg.num_heads, cfg.ssm_state
        d_inner = cfg.ssm_expand * cfg.d_model
        hd = d_inner // H
        k_every = cfg.hybrid_attn_every
        n_super = cfg.num_layers // k_every
        mamba = {
            "ssm_state": jnp.zeros((B, H, N, hd), jnp.float32),
            "conv_state": jnp.zeros((B, cfg.ssm_conv - 1, d_inner + 2 * N), COMPUTE_DTYPE),
        }
        attn_size = min(win, max_len) if win else max_len
        return {
            "blocks": _stack0(n_super, {
                "mamba_layers": _stack0(k_every, mamba),
                "attn": _kv_cache(cfg, B, attn_size),
            })
        }
    if fam == "encdec":
        return {
            "dec_layers": _stack0(cfg.num_layers, {
                **_kv_cache(cfg, B, max_len),
                "cross_k": jnp.zeros((B, cfg.enc_seq, cfg.num_kv_heads, cfg.hd), COMPUTE_DTYPE),
                "cross_v": jnp.zeros((B, cfg.enc_seq, cfg.num_kv_heads, cfg.hd), COMPUTE_DTYPE),
            })
        }
    raise ValueError(fam)


_CACHE_SPECS = {
    "k": ("batch", "seq", "tensor", None),
    "v": ("batch", "seq", "tensor", None),
    "kpos": ("batch", "seq"),
    "cross_k": ("batch", None, "tensor", None),
    "cross_v": ("batch", None, "tensor", None),
    "mlstm_state": ("batch", "tensor", None, None),
    "ssm_state": ("batch", "tensor", None, None),
    "conv_state": ("batch", None, "tensor"),
    "slstm_c": ("batch", "tensor", None),
    "slstm_n": ("batch", "tensor", None),
    "slstm_m": ("batch", "tensor", None),
    "slstm_h": ("batch", "tensor", None),
}


def decode_state_specs(state_tree, mesh=None):
    """PartitionSpec tree for a decode state, by leaf name (rules-resolved)."""
    from .sharding import fit_spec_to_mesh

    rules = current_rules()
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_tree)
    specs = []
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        logical = _CACHE_SPECS.get(keys[-1], ())
        axes = [rules.get(a, None) if a else None for a in logical]
        pad = leaf.ndim - len(axes)
        specs.append(fit_spec_to_mesh(P(*([None] * pad + axes)), leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


# --- decode attention ----------------------------------------------------------

def _attn_decode(ap, x, cfg, cache, pos, window):
    """x: (B,1,D); cache: {k, v, kpos}; pos: scalar int32. Ring-indexed."""
    B = x.shape[0]
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    pos_arr = jnp.full((B, 1), pos, jnp.int32)
    q = rope((x @ ap["wq"]).reshape(B, 1, Hq, hd), pos_arr, cfg.rope_theta)
    k_new = rope((x @ ap["wk"]).reshape(B, 1, Hkv, hd), pos_arr, cfg.rope_theta)
    v_new = (x @ ap["wv"]).reshape(B, 1, Hkv, hd)
    size = cache["k"].shape[1]
    slot = pos % size
    K = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, 1)
    V = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, 1)
    kpos = jax.lax.dynamic_update_slice_in_dim(cache["kpos"], pos_arr, slot, 1)
    mask = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        mask &= kpos > pos - window
    qg = _group_q(q, Hkv)  # (B,1,KV,G,hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32), K.astype(jnp.float32))
    scores = _softcap(scores, cfg.attn_logit_softcap)
    scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(V.dtype), V)
    out = out.reshape(B, 1, Hq * hd).astype(x.dtype)
    return out @ ap["wo"], {"k": K, "v": V, "kpos": kpos}


def _attn_cross_decode(ap, x, cfg, cross_k, cross_v):
    B = x.shape[0]
    Hq, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = (x @ ap["wq"]).reshape(B, 1, Hq, hd)
    qg = _group_q(q, Hkv)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32), cross_k.astype(jnp.float32))
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(cross_v.dtype), cross_v)
    return out.reshape(B, 1, Hq * hd).astype(x.dtype) @ ap["wo"]


# --- per-family decode blocks ---------------------------------------------------

def _dense_decode(bp, x, cfg, cache, pos, window):
    h, cache = _attn_decode(bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg, cache, pos, window)
    x = x + h
    h = mlp_apply(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg.activation)
    return x + h, cache


def decode_step(params, cfg: ModelConfig, state, tokens, pos):
    """tokens: (B, 1) int32; pos: scalar int32 (current cache length).
    Returns (logits (B, 1, V), new_state)."""
    from .backbone import cast_compute

    params = cast_compute(params)
    B = tokens.shape[0]
    x = params["embedding"].astype(COMPUTE_DTYPE)[tokens]
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(COMPUTE_DTYPE)
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        if cfg.local_global_alternating:
            def pair(h, xs):
                bp, c = xs
                bp = gather_layer_params(bp)
                h, cl = _dense_decode(bp["local"], h, cfg, c["local"], pos, cfg.sliding_window)
                h, cg = _dense_decode(bp["global"], h, cfg, c["global"], pos, None)
                return h, {"local": cl, "global": cg}
            x, new = jax.lax.scan(pair, x, (params["layers"], state["pairs"]))
            state = {"pairs": new}
        elif fam == "moe":
            def blk(h, xs):
                bp, c = xs
                bp = gather_layer_params(bp)
                a, c = _attn_decode(bp["attn"], rmsnorm(bp["ln1"], h, cfg.norm_eps), cfg, c, pos, cfg.sliding_window)
                h = h + a
                mo, _ = moe_apply(bp["moe"], rmsnorm(bp["ln2"], h, cfg.norm_eps), cfg)
                return h + mo, c
            x, new = jax.lax.scan(blk, x, (params["layers"], state["layers"]))
            state = {"layers": new}
        else:
            def blk(h, xs):
                bp, c = xs
                bp = gather_layer_params(bp)
                return _dense_decode(bp, h, cfg, c, pos, cfg.sliding_window)
            x, new = jax.lax.scan(blk, x, (params["layers"], state["layers"]))
            state = {"layers": new}
    elif fam == "ssm":
        def pair(h, xs):
            bp, c = xs
            bp = gather_layer_params(bp)
            o, ms = ssm.mlstm_step(bp["mlstm"], rmsnorm(bp["ln_m"], h, cfg.norm_eps), cfg, c["mlstm_state"])
            h = h + o
            carry = (c["slstm_c"], c["slstm_n"], c["slstm_m"], c["slstm_h"])
            o, carry = ssm.slstm_step(bp["slstm"], rmsnorm(bp["ln_s"], h, cfg.norm_eps), cfg, carry)
            h = h + o
            return h, {"mlstm_state": ms, "slstm_c": carry[0], "slstm_n": carry[1],
                       "slstm_m": carry[2], "slstm_h": carry[3]}
        x, new = jax.lax.scan(pair, x, (params["layers"], state["pairs"]))
        state = {"pairs": new}
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def superblock(h, xs):
            bp, c = xs

            def mamba_blk(hh, ys):
                mp, mc = ys
                mp = gather_layer_params(mp)
                o, s_new, cv_new = ssm.mamba2_step(
                    mp["mamba"], rmsnorm(mp["ln1"], hh, cfg.norm_eps), cfg,
                    mc["ssm_state"], mc["conv_state"])
                return hh + o, {"ssm_state": s_new, "conv_state": cv_new}

            h, mnew = jax.lax.scan(mamba_blk, h, (bp["mamba_layers"], c["mamba_layers"]))
            h, anew = _dense_decode(shared, h, cfg, c["attn"], pos, cfg.sliding_window)
            return h, {"mamba_layers": mnew, "attn": anew}

        x, new = jax.lax.scan(superblock, x, (params["blocks"], state["blocks"]))
        state = {"blocks": new}
    elif fam == "encdec":
        def dec_blk(h, xs):
            bp, c = xs
            bp = gather_layer_params(bp)
            a, cache = _attn_decode(bp["attn"], rmsnorm(bp["ln1"], h, cfg.norm_eps), cfg,
                                    {k: c[k] for k in ("k", "v", "kpos")}, pos, None)
            h = h + a
            a = _attn_cross_decode(bp["xattn"], rmsnorm(bp["ln_x"], h, cfg.norm_eps), cfg,
                                   c["cross_k"], c["cross_v"])
            h = h + a
            a = mlp_apply(bp["mlp"], rmsnorm(bp["ln2"], h, cfg.norm_eps), cfg.activation)
            return h + a, {**cache, "cross_k": c["cross_k"], "cross_v": c["cross_v"]}
        x, new = jax.lax.scan(dec_blk, x, (params["dec_layers"], state["dec_layers"]))
        state = {"dec_layers": new}
    else:
        raise ValueError(fam)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    unembed = (
        params["embedding"].astype(COMPUTE_DTYPE).T
        if cfg.tie_embeddings
        else params["unembed"].astype(COMPUTE_DTYPE)
    )
    logits = x @ unembed
    if cfg.final_logit_softcap is not None:
        logits = cfg.final_logit_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_logit_softcap
        ).astype(logits.dtype)
    return logits, state
