"""Architecture zoo: composable JAX backbones for the 6 assigned families."""
from .config import ModelConfig, ShapeConfig, SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K
from .backbone import init_model, forward
from .decode import init_decode_state, decode_step, decode_state_specs
from .steps import make_train_step, make_prefill_step, make_decode_step, init_train_state, loss_fn
